//! Web-page ranking scenario: PageRank over a scale-free "web" graph —
//! the workload the paper's PR benchmark models.
//!
//! ```bash
//! cargo run --release --example web_ranking [-- --pages 100000 --threads 4]
//! ```
//!
//! Runs the pull-based PR program under the paper's optimisation grid and
//! reports wall-clock per configuration plus the top-ranked pages, then
//! shows the same sweep on the 32-virtual-thread testbed (the Table II
//! methodology).

use ipregel::algos::PageRank;
use ipregel::config::Opts;
use ipregel::engine::{EngineConfig, GraphSession, RunOptions};
use ipregel::graph::gen;
use ipregel::layout::Layout;
use ipregel::sched::Schedule;
use ipregel::sim::SimEngine;
use ipregel::util::timer::{fmt_duration, Timer};

fn main() {
    let opts = Opts::parse(std::env::args().skip(1));
    let pages: usize = opts.get_num("pages", 100_000).unwrap();
    let threads: usize = opts.get_num("threads", 4).unwrap();

    println!("generating a {pages}-page web graph (Barabási–Albert, m=8)…");
    let g = gen::barabasi_albert(pages, 8, 7);
    println!(
        "  {} vertices, {} directed links",
        g.num_vertices(),
        g.num_edges()
    );

    let pr = PageRank::default();
    let grid = [
        ("baseline (interleaved, static)", EngineConfig::default()),
        (
            "externalised",
            EngineConfig::default().layout(Layout::Externalised),
        ),
        (
            "dynamic(256)",
            EngineConfig::default().schedule(Schedule::Dynamic { chunk: 256 }),
        ),
        (
            "externalised + dynamic (final)",
            EngineConfig::default()
                .layout(Layout::Externalised)
                .schedule(Schedule::Dynamic { chunk: 256 }),
        ),
    ];

    println!("\nreal engine, {threads} threads (one GraphSession, pooled state):");
    let session = GraphSession::new(&g);
    let mut reference: Option<Vec<f64>> = None;
    for (name, cfg) in grid {
        let t = Timer::start();
        let r = session.run_with(&pr, RunOptions::new().config(cfg.threads(threads)));
        println!("  {name:<34} {}", fmt_duration(t.elapsed()));
        if let Some(ref want) = reference {
            for v in 0..g.num_vertices() {
                assert!((want[v] - r.values[v]).abs() < 1e-12);
            }
        } else {
            reference = Some(r.values);
        }
    }

    println!("\nvirtual testbed, 32 threads (Table II methodology):");
    let base = SimEngine::new(&g, &pr, EngineConfig::default().threads(32)).run();
    println!(
        "  {:<34} {:.4} virtual s (imbalance {:.2})",
        "baseline", base.virtual_seconds, base.mean_imbalance
    );
    for (name, cfg) in [
        (
            "externalised",
            EngineConfig::default().threads(32).layout(Layout::Externalised),
        ),
        (
            "dynamic(256)",
            EngineConfig::default()
                .threads(32)
                .schedule(Schedule::Dynamic { chunk: 256 }),
        ),
        (
            "final",
            EngineConfig::default()
                .threads(32)
                .layout(Layout::Externalised)
                .schedule(Schedule::Dynamic { chunk: 256 }),
        ),
    ] {
        let r = SimEngine::new(&g, &pr, cfg).run();
        println!(
            "  {:<34} {:.4} virtual s  → speed-up {:.2}",
            name,
            r.virtual_seconds,
            base.virtual_seconds / r.virtual_seconds
        );
    }

    let ranks = reference.unwrap();
    let mut idx: Vec<usize> = (0..ranks.len()).collect();
    idx.sort_by(|&a, &b| ranks[b].partial_cmp(&ranks[a]).unwrap());
    println!("\ntop 5 pages by rank:");
    for &v in idx.iter().take(5) {
        println!(
            "  page {v:>7}  rank {:.4e}  in-links {}",
            ranks[v],
            g.in_degree(v as u32)
        );
    }
    // Sanity: the top page should be a hub.
    assert!(g.in_degree(idx[0] as u32) > g.num_edges() / g.num_vertices());
}
