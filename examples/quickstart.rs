//! Quickstart: write a vertex-centric program and run it through a
//! [`GraphSession`].
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Shows the complete public API surface in ~80 lines: define a
//! [`VertexProgram`], open a [`GraphSession`] over the graph, run the
//! program under several optimisation configurations (the same session
//! pools mailboxes, stores and bitsets across runs), and read the
//! metrics. The same program text runs under every configuration — the
//! paper's programmability thesis.

use ipregel::combine::SumCombiner;
use ipregel::engine::{
    CombinedPlane, Context, EngineConfig, GraphSession, Mode, NoAgg, RunOptions, VertexProgram,
};
use ipregel::graph::csr::{Csr, VertexId};
use ipregel::graph::gen;
use ipregel::layout::Layout;
use ipregel::sched::Schedule;

/// Each vertex computes the *sum of its neighbours' ids* — a toy program
/// exercising messages, combination and halting.
struct NeighbourSum;

impl VertexProgram for NeighbourSum {
    type Value = u64;
    type Message = u64;
    type Comb = SumCombiner;
    type Agg = NoAgg;
    type Delivery = CombinedPlane;

    fn mode(&self) -> Mode {
        Mode::Push
    }

    fn combiner(&self) -> SumCombiner {
        SumCombiner
    }

    fn aggregator(&self) -> NoAgg {
        NoAgg
    }

    fn init(&self, _g: &Csr, _v: VertexId) -> u64 {
        0
    }

    fn compute<C: Context<u64, u64>>(&self, ctx: &mut C, msg: Option<u64>) {
        match ctx.superstep() {
            0 => ctx.broadcast(ctx.id() as u64), // tell neighbours who I am
            _ => *ctx.value_mut() = msg.unwrap_or(0),
        }
        ctx.vote_to_halt();
    }
}

fn main() {
    // A small scale-free graph from the built-in generators.
    let g = gen::barabasi_albert(1_000, 3, 42);
    println!(
        "graph: {} vertices, {} directed edges",
        g.num_vertices(),
        g.num_edges()
    );

    // One session per graph: stores/mailboxes/bitsets are built on the
    // first run and recycled by every later one.
    let session = GraphSession::with_config(&g, EngineConfig::default().threads(4));

    // Baseline configuration…
    let base = session.run(&NeighbourSum);
    println!("baseline:  {}", base.metrics.summary());

    // …and the paper's "final"-style configuration: externalised vertex
    // layout + dynamic scheduling, as a per-run override. Same program,
    // same results.
    let tuned_cfg = EngineConfig::default()
        .threads(4)
        .layout(Layout::Externalised)
        .schedule(Schedule::Dynamic { chunk: 64 });
    let tuned = session.run_with(&NeighbourSum, RunOptions::new().config(tuned_cfg));
    println!("optimised: {}", tuned.metrics.summary());

    assert_eq!(base.values, tuned.values, "optimisations never change results");

    // A third run on the session hits the store pool (no reallocation).
    let again = session.run(&NeighbourSum);
    assert!(again.metrics.store_reused);
    println!(
        "third run reused pooled state ✓ ({} runs on this session)",
        session.runs_completed()
    );

    // Spot-check vertex 0 against the CSR.
    let expect: u64 = g.in_neighbors(0).iter().map(|&u| u as u64).sum();
    assert_eq!(base.values[0], expect);
    println!("vertex 0 neighbour-sum = {} ✓", base.values[0]);
}
