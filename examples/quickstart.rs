//! Quickstart: write a vertex-centric program and run it.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Shows the complete public API surface in ~60 lines: define a
//! [`VertexProgram`], pick an [`EngineConfig`], call [`run`]. The same
//! program text runs under every optimisation configuration — the paper's
//! programmability thesis.

use ipregel::combine::SumCombiner;
use ipregel::engine::{run, Context, EngineConfig, Mode, VertexProgram};
use ipregel::graph::csr::{Csr, VertexId};
use ipregel::graph::gen;
use ipregel::layout::Layout;
use ipregel::sched::Schedule;

/// Each vertex computes the *sum of its neighbours' ids* — a toy program
/// exercising messages, combination and halting.
struct NeighbourSum;

impl VertexProgram for NeighbourSum {
    type Value = u64;
    type Message = u64;
    type Comb = SumCombiner;

    fn mode(&self) -> Mode {
        Mode::Push
    }

    fn combiner(&self) -> SumCombiner {
        SumCombiner
    }

    fn init(&self, _g: &Csr, _v: VertexId) -> u64 {
        0
    }

    fn compute<C: Context<u64, u64>>(&self, ctx: &mut C, msg: Option<u64>) {
        match ctx.superstep() {
            0 => ctx.broadcast(ctx.id() as u64), // tell neighbours who I am
            _ => *ctx.value_mut() = msg.unwrap_or(0),
        }
        ctx.vote_to_halt();
    }
}

fn main() {
    // A small scale-free graph from the built-in generators.
    let g = gen::barabasi_albert(1_000, 3, 42);
    println!(
        "graph: {} vertices, {} directed edges",
        g.num_vertices(),
        g.num_edges()
    );

    // Baseline configuration…
    let base = run(&g, &NeighbourSum, EngineConfig::default().threads(4));
    println!("baseline:  {}", base.metrics.summary());

    // …and the paper's "final"-style configuration: externalised vertex
    // layout + dynamic scheduling. Same program, same results.
    let tuned_cfg = EngineConfig::default()
        .threads(4)
        .layout(Layout::Externalised)
        .schedule(Schedule::Dynamic { chunk: 64 });
    let tuned = run(&g, &NeighbourSum, tuned_cfg);
    println!("optimised: {}", tuned.metrics.summary());

    assert_eq!(base.values, tuned.values, "optimisations never change results");

    // Spot-check vertex 0 against the CSR.
    let expect: u64 = g.in_neighbors(0).iter().map(|&u| u as u64).sum();
    assert_eq!(base.values[0], expect);
    println!("vertex 0 neighbour-sum = {} ✓", base.values[0]);
}
