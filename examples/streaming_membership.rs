//! Streaming membership scenario: maintain community labels of an
//! evolving social network with **incremental CC** (the paper's §VIII
//! future-work direction), then answer multi-source distance queries
//! through the batched PJRT kernel.
//!
//! ```bash
//! cargo run --release --example streaming_membership
//! ```

use ipregel::algos::{incremental, ConnectedComponents, Sssp};
use ipregel::engine::{EngineConfig, GraphSession};
use ipregel::graph::csr::VertexId;
use ipregel::graph::gen;
use ipregel::runtime::{accel, default_artifact_dir, Runtime};
use ipregel::util::rng::Rng;
use ipregel::util::timer::{fmt_duration, Timer};

fn main() -> ipregel::util::error::Result<()> {
    // A network that starts fragmented: 40 communities of 500 members.
    let mut g = gen::disjoint_rings(40, 500);
    println!(
        "initial network: {} members, {} links, 40 communities",
        g.num_vertices(),
        g.num_edges()
    );
    let cfg = EngineConfig::default().threads(4);
    let base = GraphSession::with_config(&g, cfg.bypass(true)).run(&ConnectedComponents);
    let mut labels = base.values;

    // Stream in friendship batches; repair labels incrementally and
    // compare against cold recomputation.
    let mut rng = Rng::new(2024);
    let n = g.num_vertices();
    let mut inc_activations = 0u64;
    let mut cold_activations = 0u64;
    for batch in 0..8 {
        let inserts: Vec<(VertexId, VertexId)> = (0..3)
            .map(|_| {
                (
                    rng.below(n as u64) as VertexId,
                    rng.below(n as u64) as VertexId,
                )
            })
            .filter(|&(s, d)| s != d)
            .collect();
        assert!(incremental::IncrementalCc::supports(inserts.len(), 0));

        let t = Timer::start();
        let (g2, inc) = incremental::insert_edges(&g, &labels, &inserts, cfg);
        let inc_time = t.elapsed();
        let t = Timer::start();
        let cold = GraphSession::with_config(&g2, cfg.bypass(true)).run(&ConnectedComponents);
        let cold_time = t.elapsed();
        assert_eq!(inc.values, cold.values, "incremental must equal cold");
        inc_activations += inc.metrics.total_activations();
        cold_activations += cold.metrics.total_activations();

        let communities = {
            let mut u = inc.values.clone();
            u.sort_unstable();
            u.dedup();
            u.len()
        };
        println!(
            "batch {batch}: +{} links → {communities:>2} communities \
             (incremental {} vs cold {})",
            inserts.len(),
            fmt_duration(inc_time),
            fmt_duration(cold_time),
        );
        g = g2;
        labels = inc.values;
    }
    println!(
        "\ntotal vertex activations: incremental {} vs cold {} ({:.1}× less work)",
        inc_activations,
        cold_activations,
        cold_activations as f64 / inc_activations as f64
    );

    // Multi-source distance queries on a small subgraph via the batched
    // AOT kernel (requires `make artifacts`).
    let adir = default_artifact_dir();
    if adir.join("manifest.txt").exists() {
        let rt = Runtime::load(&adir)?;
        let q = gen::barabasi_albert(900, 3, 77);
        let block = accel::DenseBlock::from_graph(&rt, &q)?;
        let sources: Vec<VertexId> = (0..8).map(|k| k * 100).collect();
        let t = Timer::start();
        let dists = accel::multi_sssp(&rt, &block, &sources)?;
        println!(
            "\nbatched multi-source SSSP via PJRT: {} sources in {} (one fixpoint)",
            sources.len(),
            fmt_duration(t.elapsed())
        );
        // One session answers all per-source validation runs.
        let q_session = GraphSession::with_config(&q, cfg.bypass(true));
        for (k, &src) in sources.iter().enumerate() {
            let engine = q_session.run(&Sssp { source: src });
            let agree = dists[k]
                .iter()
                .zip(&engine.values)
                .all(|(&a, &b)| (b == u64::MAX && a.is_infinite()) || a as u64 == b);
            assert!(agree, "source {src}");
        }
        println!("all {} columns match per-source engine runs ✓", sources.len());
    } else {
        println!("\n(artifacts/ missing — run `make artifacts` for the PJRT demo)");
    }
    Ok(())
}
