//! Navigation scenario: shortest paths on a road-like grid vs a social
//! hub-and-spoke graph — the paper's SSSP benchmark in both its hard and
//! easy regimes, plus *weighted* roads through the v2 API.
//!
//! ```bash
//! cargo run --release --example road_navigation
//! ```
//!
//! The grid (high diameter, tiny frontiers) and the scale-free graph (low
//! diameter, huge frontiers) stress opposite parts of the push engine;
//! the example also compares combiner strategies on the contended
//! scale-free case, prints the BFS wave profile, and finishes with
//! weighted SSSP (travel times instead of hop counts) validated against
//! a serial Dijkstra.

use ipregel::algos::{reference, Sssp, WeightedSssp, UNREACHED};
use ipregel::combine::Strategy;
use ipregel::engine::{EngineConfig, GraphSession, RunOptions};
use ipregel::graph::gen;
use ipregel::util::timer::{fmt_duration, Timer};

fn wave_profile(label: &str, metrics: &ipregel::metrics::RunMetrics) {
    let peak = metrics
        .supersteps
        .iter()
        .map(|s| s.active_vertices)
        .max()
        .unwrap_or(0);
    println!(
        "  {label:<24} supersteps={:<5} peak frontier={peak}",
        metrics.num_supersteps()
    );
}

fn main() {
    // --- Road network: 600×600 grid -------------------------------------
    let grid = gen::grid(600, 600);
    println!(
        "road grid: {} junctions, {} road segments",
        grid.num_vertices(),
        grid.num_edges()
    );
    let grid_session =
        GraphSession::with_config(&grid, EngineConfig::default().threads(4).bypass(true));
    let p = Sssp { source: 0 };
    let t = Timer::start();
    let r = grid_session.run(&p);
    println!("  solved in {}", fmt_duration(t.elapsed()));
    wave_profile("grid (bypass)", &r.metrics);
    // Corner-to-corner Manhattan distance.
    assert_eq!(r.values[grid.num_vertices() - 1], (599 + 599) as u64);

    // --- Weighted roads: travel times, not hop counts --------------------
    // Same junction topology, but every segment gets a travel time in
    // [1, 5) minutes. WeightedSssp relaxes per-edge via Context::out_edge;
    // the unweighted program text above keeps working unchanged.
    let roads = gen::randomly_weighted(&grid, 1.0, 5.0, 77);
    let roads_session =
        GraphSession::with_config(&roads, EngineConfig::default().threads(4).bypass(true));
    let wp = WeightedSssp { source: 0 };
    let t = Timer::start();
    let wr = roads_session.run(&wp);
    println!(
        "\nweighted roads: corner-to-corner travel time {:.2} (solved in {})",
        wr.values[roads.num_vertices() - 1],
        fmt_duration(t.elapsed())
    );
    wave_profile("weighted grid (bypass)", &wr.metrics);
    // Cross-check a sample of junctions against serial Dijkstra.
    let dij = reference::dijkstra(&roads, 0);
    for v in (0..roads.num_vertices()).step_by(50_000) {
        assert!(
            (wr.values[v] - dij[v]).abs() < 1e-9,
            "junction {v}: engine {} vs dijkstra {}",
            wr.values[v],
            dij[v]
        );
    }
    println!("  matches serial Dijkstra ✓");

    // --- Social graph: contended hubs ------------------------------------
    let social = gen::rmat(17, 16, 0.57, 0.19, 0.19, 5);
    println!(
        "\nsocial graph: {} members, {} directed edges",
        social.num_vertices(),
        social.num_edges()
    );
    let social_session = GraphSession::new(&social);
    let p = Sssp::from_hub(&social);
    let mut reference_dist = None;
    for strategy in [Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid] {
        let t = Timer::start();
        let r = social_session.run_with(
            &p,
            RunOptions::new().config(
                EngineConfig::default()
                    .threads(4)
                    .bypass(true)
                    .strategy(strategy),
            ),
        );
        println!(
            "  {:<12} {:>10}  ({} messages)",
            format!("{strategy:?}"),
            fmt_duration(t.elapsed()),
            r.metrics.total_messages()
        );
        if let Some(ref want) = reference_dist {
            assert_eq!(want, &r.values, "{strategy:?} changed results");
        } else {
            wave_profile("rmat (bypass)", &r.metrics);
            reference_dist = Some(r.values);
        }
    }

    let dist = reference_dist.unwrap();
    let reached = dist.iter().filter(|&&d| d != UNREACHED).count();
    let mut histo = [0usize; 16];
    for &d in &dist {
        if d != UNREACHED {
            histo[(d as usize).min(15)] += 1;
        }
    }
    println!("\nhop-distance histogram from hub v{}:", p.source);
    for (h, &c) in histo.iter().enumerate() {
        if c > 0 {
            println!("  {h:>2} hops: {c:>8}");
        }
    }
    println!("reached {reached}/{} members", social.num_vertices());
}
