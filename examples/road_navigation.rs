//! Navigation scenario: shortest paths on a road-like grid vs a social
//! hub-and-spoke graph — the paper's SSSP benchmark in both its hard and
//! easy regimes.
//!
//! ```bash
//! cargo run --release --example road_navigation
//! ```
//!
//! The grid (high diameter, tiny frontiers) and the scale-free graph (low
//! diameter, huge frontiers) stress opposite parts of the push engine;
//! the example also compares combiner strategies on the contended
//! scale-free case and prints the BFS wave profile.

use ipregel::algos::{Sssp, UNREACHED};
use ipregel::combine::Strategy;
use ipregel::engine::{run, EngineConfig};
use ipregel::graph::gen;
use ipregel::util::timer::{fmt_duration, Timer};

fn wave_profile(label: &str, metrics: &ipregel::metrics::RunMetrics) {
    let peak = metrics
        .supersteps
        .iter()
        .map(|s| s.active_vertices)
        .max()
        .unwrap_or(0);
    println!(
        "  {label:<24} supersteps={:<5} peak frontier={peak}",
        metrics.num_supersteps()
    );
}

fn main() {
    // --- Road network: 600×600 grid -------------------------------------
    let grid = gen::grid(600, 600);
    println!(
        "road grid: {} junctions, {} road segments",
        grid.num_vertices(),
        grid.num_edges()
    );
    let p = Sssp { source: 0 };
    let t = Timer::start();
    let r = run(&grid, &p, EngineConfig::default().threads(4).bypass(true));
    println!("  solved in {}", fmt_duration(t.elapsed()));
    wave_profile("grid (bypass)", &r.metrics);
    // Corner-to-corner Manhattan distance.
    assert_eq!(r.values[grid.num_vertices() - 1], (599 + 599) as u64);

    // --- Social graph: contended hubs ------------------------------------
    let social = gen::rmat(17, 16, 0.57, 0.19, 0.19, 5);
    println!(
        "\nsocial graph: {} members, {} directed edges",
        social.num_vertices(),
        social.num_edges()
    );
    let p = Sssp::from_hub(&social);
    let mut reference = None;
    for strategy in [Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid] {
        let t = Timer::start();
        let r = run(
            &social,
            &p,
            EngineConfig::default()
                .threads(4)
                .bypass(true)
                .strategy(strategy),
        );
        println!(
            "  {:<12} {:>10}  ({} messages)",
            format!("{strategy:?}"),
            fmt_duration(t.elapsed()),
            r.metrics.total_messages()
        );
        if let Some(ref want) = reference {
            assert_eq!(want, &r.values, "{strategy:?} changed results");
        } else {
            wave_profile("rmat (bypass)", &r.metrics);
            reference = Some(r.values);
        }
    }

    let dist = reference.unwrap();
    let reached = dist.iter().filter(|&&d| d != UNREACHED).count();
    let mut histo = [0usize; 16];
    for &d in &dist {
        if d != UNREACHED {
            histo[(d as usize).min(15)] += 1;
        }
    }
    println!("\nhop-distance histogram from hub v{}:", p.source);
    for (h, &c) in histo.iter().enumerate() {
        if c > 0 {
            println!("  {h:>2} hops: {c:>8}");
        }
    }
    println!("reached {reached}/{} members", social.num_vertices());
}
