//! End-to-end paper reproduction driver.
//!
//! ```bash
//! cargo run --release --example e2e_paper            # tiny catalog (~1 min)
//! cargo run --release --example e2e_paper -- --full  # full catalog (the record run)
//! ```
//!
//! Exercises every layer of the system on a real workload, proving they
//! compose:
//!
//! 1. **substrate** — generate/cache the four paper-graph analogues;
//! 2. **Table I** — print the graph inventory next to the paper's counts;
//! 3. **real engine** — run all three benchmarks multithreaded and
//!    validate against serial references;
//! 4. **Table II** — the headline result: per-optimisation speed-ups on
//!    the 32-virtual-thread testbed, printed beside the paper's values,
//!    with the §VII aggregate summary;
//! 5. **accel path** — if `make artifacts` has run, execute PageRank/CC
//!    through the AOT-compiled JAX/Pallas kernels via PJRT and check the
//!    numbers against the engine.
//!
//! The output of the full run is recorded in EXPERIMENTS.md.

use ipregel::algos::{reference, ConnectedComponents, PageRank, Sssp};
use ipregel::config::Opts;
use ipregel::engine::{EngineConfig, GraphSession, RunOptions};
use ipregel::exp::{run_table1, table2, Bench, Table2Options};
use ipregel::graph::catalog;
use ipregel::runtime::{accel, default_artifact_dir, Runtime};
use ipregel::util::timer::{fmt_duration, Timer};
use std::path::PathBuf;

fn main() -> ipregel::util::error::Result<()> {
    let opts = Opts::parse(std::env::args().skip(1));
    let full = opts.flag("full");
    let dir = PathBuf::from(opts.get_or("dir", "data/graphs"));
    let entries = if full {
        catalog::catalog()
    } else {
        catalog::catalog_tiny()
    };
    let total = Timer::start();

    // ---- 1+2: substrate + Table I --------------------------------------
    println!("=== Table I: graphs ({} catalog) ===", if full { "full" } else { "tiny" });
    println!("{}", run_table1(&entries, &dir)?);

    // ---- 3: real multithreaded engine, validated -----------------------
    println!("=== real engine validation (4 threads, one GraphSession) ===");
    let probe = entries[0].load_or_generate(&dir)?;
    let probe_session = GraphSession::with_config(&probe, EngineConfig::default().threads(4));
    let pr = probe_session.run(&PageRank::default());
    let pr_ref = reference::pagerank(&probe, 10, 0.85);
    let max_err = pr
        .values
        .iter()
        .zip(&pr_ref)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("pagerank: {} | max |err| vs serial = {max_err:.2e}", pr.metrics.summary());
    assert!(max_err < 1e-9);

    let cc = probe_session.run_with(
        &ConnectedComponents,
        RunOptions::new().config(EngineConfig::default().threads(4).bypass(true)),
    );
    assert_eq!(cc.values, reference::connected_components(&probe));
    println!("cc:       {} | labels match union-find", cc.metrics.summary());

    let sp = Sssp::from_hub(&probe);
    let ss = probe_session.run_with(
        &sp,
        RunOptions::new().config(EngineConfig::default().threads(4).bypass(true)),
    );
    assert_eq!(ss.values, reference::bfs_levels(&probe, sp.source));
    println!("sssp:     {} | distances match BFS", ss.metrics.summary());

    // ---- 4: Table II on the virtual testbed ----------------------------
    println!("\n=== Table II: speed-ups at 32 virtual threads ===");
    let mut graphs = Vec::new();
    for e in &entries {
        graphs.push((e.stands_for.to_string(), e.load_or_generate(&dir)?));
    }
    let t2opts = Table2Options {
        threads: 32,
        benches: Bench::all().to_vec(),
        // The tiny graphs need a finer FCFS grain than the paper's 256
        // (they have 64× fewer vertices); the full catalog uses 256.
        dynamic_chunk_override: if full { None } else { Some(16) },
    };
    let t = Timer::start();
    let results = table2::run_table2(&graphs, &t2opts);
    let names: Vec<String> = graphs.iter().map(|(n, _)| n.clone()).collect();
    println!("{}", table2::render(&names, &results));
    println!("{}", table2::summary(&results));
    println!("(table II computed in {})", fmt_duration(t.elapsed()));

    // ---- 5: accelerated dense-block path (three-layer composition) -----
    println!("\n=== accel path (PJRT + AOT JAX/Pallas) ===");
    let adir = default_artifact_dir();
    if adir.join("manifest.txt").exists() {
        let rt = Runtime::load(&adir)?;
        println!("platform={} artifacts={:?}", rt.platform(), rt.executables());
        let small = ipregel::graph::gen::barabasi_albert(800, 3, 5);
        let block = accel::DenseBlock::from_graph(&rt, &small)?;
        let small_session = GraphSession::new(&small);
        let accel_pr = accel::pagerank(&rt, &small, &block)?;
        let eng_pr = small_session.run(&PageRank::default());
        let max_err = accel_pr
            .iter()
            .zip(&eng_pr.values)
            .map(|(&a, &b)| (a as f64 - b).abs())
            .fold(0.0f64, f64::max);
        println!("pagerank via PJRT: max |err| vs engine = {max_err:.2e}");
        assert!(max_err < 1e-6);
        let accel_cc = accel::connected_components(&rt, &small, &block)?;
        let eng_cc = small_session.run(&ConnectedComponents);
        assert_eq!(accel_cc, eng_cc.values);
        println!("cc via PJRT: labels identical to engine ✓");
    } else {
        println!("artifacts/ missing — run `make artifacts` to exercise the PJRT path");
    }

    println!("\ne2e complete in {}", fmt_duration(total.elapsed()));
    Ok(())
}
