//! Community detection scenario: connected components over a social
//! network with satellite communities — the paper's CC benchmark workload.
//!
//! ```bash
//! cargo run --release --example social_components [-- --members 200000]
//! ```
//!
//! Demonstrates the *selection bypass* engine version: CC's active set
//! collapses quickly, so the explicit active list does asymptotically
//! less work than the baseline full scan. The example measures both and
//! prints the per-superstep active counts that explain the gap.

use ipregel::algos::ConnectedComponents;
use ipregel::config::Opts;
use ipregel::engine::{EngineConfig, GraphSession, RunOptions};
use ipregel::graph::csr::VertexId;
use ipregel::graph::{gen, GraphBuilder};
use ipregel::util::rng::Rng;
use ipregel::util::timer::{fmt_duration, Timer};
use std::collections::HashMap;

fn main() {
    let opts = Opts::parse(std::env::args().skip(1));
    let members: usize = opts.get_num("members", 200_000).unwrap();

    // A main social graph plus isolated satellite communities (RMAT core
    // + disjoint rings), shuffled into one vertex space.
    println!("building a {members}-member network with satellite communities…");
    let core = gen::barabasi_albert(members, 4, 3);
    let satellites = 50usize;
    let sat_size = 100usize;
    let n = members + satellites * sat_size;
    let mut gb = GraphBuilder::new(n).symmetric(true).drop_self_loops(true);
    for (s, d) in core.edges() {
        if s < d {
            gb.push_edge(s, d);
        }
    }
    let mut rng = Rng::new(99);
    for c in 0..satellites {
        let base = (members + c * sat_size) as VertexId;
        for i in 0..sat_size as VertexId {
            gb.push_edge(base + i, base + (i + 1) % sat_size as VertexId);
            if rng.chance(0.2) {
                let j = rng.below(sat_size as u64) as VertexId;
                gb.push_edge(base + i, base + j);
            }
        }
    }
    let g = gb.build();
    println!("  {} vertices, {} directed edges", g.num_vertices(), g.num_edges());

    // Baseline: full-scan version.
    let session = GraphSession::with_config(&g, EngineConfig::default().threads(4));
    let t = Timer::start();
    let scan = session.run(&ConnectedComponents);
    let scan_time = t.elapsed();

    // Selection bypass: explicit active list. Same session — the second
    // run recycles the first run's store and bitsets.
    let t = Timer::start();
    let bypass = session.run_with(
        &ConnectedComponents,
        RunOptions::new().config(EngineConfig::default().threads(4).bypass(true)),
    );
    let bypass_time = t.elapsed();
    assert!(bypass.metrics.store_reused);

    assert_eq!(scan.values, bypass.values);
    println!(
        "\nfull scan      : {} ({} total activations)",
        fmt_duration(scan_time),
        scan.metrics.total_activations()
    );
    println!(
        "selection bypass: {} ({} total activations)",
        fmt_duration(bypass_time),
        bypass.metrics.total_activations()
    );

    println!("\nactive vertices per superstep (bypass run):");
    for (i, s) in bypass.metrics.supersteps.iter().enumerate() {
        println!("  superstep {i:>2}: {:>8}", s.active_vertices);
    }

    // Component census.
    let mut sizes: HashMap<u32, usize> = HashMap::new();
    for &l in &bypass.values {
        *sizes.entry(l).or_default() += 1;
    }
    let mut by_size: Vec<(u32, usize)> = sizes.into_iter().collect();
    by_size.sort_by_key(|&(_, s)| std::cmp::Reverse(s));
    println!("\ncomponents: {}", by_size.len());
    println!("  giant component: {} members", by_size[0].1);
    println!(
        "  satellites found: {} (expected {satellites})",
        by_size.len() - 1
    );
    assert_eq!(by_size.len(), 1 + satellites);
    assert_eq!(by_size[0].1, members);
}
