"""Layer-1 Pallas kernels: the dense-block superstep hot-spots.

The paper's three benchmarks all reduce, on a dense adjacency block, to a
tiled "matvec" with a semiring:

- PageRank:      sums[i]  = Σ_j  A[i,j] · contrib[j]          (+, ·)
- SSSP (unit):   cand[i]  = min_j A[i,j] ? dist[j] + 1 : ∞    (min, +1)
- CC min-label:  cand[i]  = min_j A[i,j] ? label[j] : ∞       (min, id)

``A[i, j] == 1`` iff the graph has a directed edge ``j → i`` (an
*in-neighbour* matrix), so one row gathers exactly what the pull-based
engine gathers per vertex.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the engine's
scattered per-neighbour loads become an HBM→VMEM *block schedule*: each
grid step stages one ``(TILE, TILE)`` adjacency tile and one ``(TILE,)``
message-vector tile in VMEM, and the sum semiring engages the MXU through
a dense contraction. ``interpret=True`` everywhere — the CPU PJRT plugin
cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 256


def _check_args(adj, x, tile):
    n = adj.shape[0]
    if adj.shape != (n, n):
        raise ValueError(f"adjacency must be square, got {adj.shape}")
    if x.shape != (n,):
        raise ValueError(f"vector shape {x.shape} does not match adjacency {adj.shape}")
    if n % tile != 0:
        raise ValueError(f"n={n} must be a multiple of tile={tile}")
    return n


def _sum_kernel(a_ref, x_ref, o_ref):
    """One (row-tile, col-tile) step of the (+, ·) matvec.

    The output tile is revisited across the column grid dimension and
    accumulated in place; col step 0 initialises it. The contraction
    ``a @ x`` is the MXU-shaped op on real hardware.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ x_ref[...]


def _min_plus_kernel(a_ref, x_ref, o_ref, *, increment):
    """One step of the (min, +increment) masked matvec."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, jnp.inf)

    a = a_ref[...]
    cand = jnp.where(a > 0, x_ref[...][None, :] + increment, jnp.inf)
    o_ref[...] = jnp.minimum(o_ref[...], jnp.min(cand, axis=1))


def _tiled_call(kernel, adj, x, tile):
    n = _check_args(adj, x, tile)
    grid = (n // tile, n // tile)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
            pl.BlockSpec((tile,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(adj, x)


def sum_matvec(adj, x, *, tile=DEFAULT_TILE):
    """``out[i] = Σ_j adj[i, j] * x[j]`` — the PageRank gather."""
    return _tiled_call(_sum_kernel, adj, x, tile)


def min_plus_matvec(adj, x, *, increment=1.0, tile=DEFAULT_TILE):
    """``out[i] = min_j (adj[i, j] > 0 ? x[j] + increment : ∞)``.

    ``increment=1.0`` is the unit-weight SSSP relaxation;
    ``increment=0.0`` is CC min-label propagation.
    """
    kernel = functools.partial(_min_plus_kernel, increment=increment)
    return _tiled_call(kernel, adj, x, tile)
