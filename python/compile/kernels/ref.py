"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

These are deliberately the most obvious possible implementations; every
kernel must match them to float tolerance for all shapes/dtypes pytest
sweeps (python/tests/test_kernels.py).
"""

import jax.numpy as jnp


def sum_matvec(adj, x):
    """out[i] = sum_j adj[i, j] * x[j]."""
    return adj @ x


def min_plus_matvec(adj, x, increment=1.0):
    """out[i] = min_j (adj[i, j] > 0 ? x[j] + increment : inf)."""
    cand = jnp.where(adj > 0, x[None, :] + increment, jnp.inf)
    return jnp.min(cand, axis=1)


def pagerank_step(adj, contrib, n_real, damping=0.85):
    """One pull-based PageRank update over the dense block."""
    return (1.0 - damping) / n_real + damping * (adj @ contrib)


def pagerank_run(adj, rank, inv_outdeg, n_real, iterations=10, damping=0.85):
    """``iterations`` PageRank updates (the fused artifact's semantics)."""
    for _ in range(iterations):
        contrib = rank * inv_outdeg
        rank = pagerank_step(adj, contrib, n_real, damping)
    return rank


def sssp_relax(adj, dist):
    """One unit-weight SSSP relaxation: dist' = min(dist, min-plus gather)."""
    return jnp.minimum(dist, min_plus_matvec(adj, dist, 1.0))


def cc_step(adj, label):
    """One CC min-label propagation step."""
    return jnp.minimum(label, min_plus_matvec(adj, label, 0.0))


def batched_sum_matmul(adj, x):
    """out[i, b] = sum_j adj[i, j] * x[j, b]."""
    return adj @ x


def batched_min_plus(adj, x, increment=1.0):
    """out[i, b] = min_j (adj[i, j] > 0 ? x[j, b] + increment : inf)."""
    cand = jnp.where(adj[:, :, None] > 0, x[None, :, :] + increment, jnp.inf)
    return jnp.min(cand, axis=1)


def multi_sssp_relax(adj, dists):
    """One relaxation wave for a batch of sources (columns of dists)."""
    return jnp.minimum(dists, batched_min_plus(adj, dists, 1.0))
