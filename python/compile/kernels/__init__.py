"""Layer-1 Pallas kernels for the dense-block accelerated supersteps."""

from .batched import batched_min_plus, batched_sum_matmul
from .matvec import DEFAULT_TILE, min_plus_matvec, sum_matvec

__all__ = [
    "DEFAULT_TILE",
    "batched_min_plus",
    "batched_sum_matmul",
    "min_plus_matvec",
    "sum_matvec",
]
