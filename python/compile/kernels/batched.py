"""Batched (multi-vector) semiring kernels — the MXU-utilisation variant.

The single-vector kernels in ``matvec.py`` occupy one MXU column lane
(rank-1 output). Batching ``B`` message vectors turns the contraction
into a true ``(TILE×TILE) @ (TILE×B)`` matmul that fills the systolic
array — the natural TPU extension for multi-source BFS/SSSP and
personalised-PageRank sweeps (EXPERIMENTS.md §Perf L1).

Same conventions as ``matvec.py``: ``adj[i, j] == 1`` iff edge ``j → i``,
``interpret=True`` for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matvec import DEFAULT_TILE


def _check_args(adj, x, tile):
    n = adj.shape[0]
    if adj.shape != (n, n):
        raise ValueError(f"adjacency must be square, got {adj.shape}")
    if x.ndim != 2 or x.shape[0] != n:
        raise ValueError(f"batch shape {x.shape} does not match adjacency {adj.shape}")
    if n % tile != 0:
        raise ValueError(f"n={n} must be a multiple of tile={tile}")
    return n, x.shape[1]


def _sum_kernel(a_ref, x_ref, o_ref):
    """(i, j) grid step of the batched (+, ·) matmul: o += a @ x."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ x_ref[...]


def _min_plus_kernel(a_ref, x_ref, o_ref, *, increment):
    """(i, j) grid step of the batched (min, +increment) product."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, jnp.inf)

    a = a_ref[...]  # (tile, tile)
    x = x_ref[...]  # (tile, B)
    cand = jnp.where(a[:, :, None] > 0, x[None, :, :] + increment, jnp.inf)
    o_ref[...] = jnp.minimum(o_ref[...], jnp.min(cand, axis=1))


def _tiled_call(kernel, adj, x, tile):
    n, batch = _check_args(adj, x, tile)
    grid = (n // tile, n // tile)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
            pl.BlockSpec((tile, batch), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile, batch), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, batch), x.dtype),
        interpret=True,
    )(adj, x)


def batched_sum_matmul(adj, x, *, tile=DEFAULT_TILE):
    """``out[i, b] = Σ_j adj[i, j] · x[j, b]`` — MXU-shaped."""
    return _tiled_call(_sum_kernel, adj, x, tile)


def batched_min_plus(adj, x, *, increment=1.0, tile=DEFAULT_TILE):
    """``out[i, b] = min_j (adj[i, j] > 0 ? x[j, b] + increment : ∞)``."""
    kernel = functools.partial(_min_plus_kernel, increment=increment)
    return _tiled_call(kernel, adj, x, tile)
