"""AOT lowering: JAX/Pallas supersteps -> HLO text artifacts.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids, which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Runs once at build time (``make artifacts``); the Rust binary is
self-contained afterwards.

Usage: python -m compile.aot [--out-dir ../artifacts] [--n 1024] [--tile 256]
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


MULTI_SOURCES = 32


def artifact_specs(n: int, tile: int):
    """(name, function, example-arg shapes) for every artifact."""
    mat = jax.ShapeDtypeStruct((n, n), jnp.float32)
    vec = jax.ShapeDtypeStruct((n,), jnp.float32)
    batch = jax.ShapeDtypeStruct((n, MULTI_SOURCES), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    return [
        (
            "multi_sssp_relax",
            functools.partial(model.multi_sssp_superstep, tile=tile),
            (mat, batch),
        ),
        (
            "pagerank_step",
            functools.partial(model.pagerank_step, tile=tile),
            (mat, vec, scalar),
        ),
        (
            "pagerank_run",
            functools.partial(model.pagerank_run, tile=tile),
            (mat, vec, vec, scalar),
        ),
        (
            "sssp_relax",
            functools.partial(model.sssp_superstep, tile=tile),
            (mat, vec),
        ),
        (
            "cc_label",
            functools.partial(model.cc_superstep, tile=tile),
            (mat, vec),
        ),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--n", type=int, default=1024, help="padded block size")
    ap.add_argument("--tile", type=int, default=256, help="Pallas tile size")
    args = ap.parse_args()
    if args.n % args.tile != 0:
        raise SystemExit(f"--n {args.n} must be a multiple of --tile {args.tile}")

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = [f"n={args.n}", f"tile={args.tile}", "dtype=f32",
                f"damping={model.DAMPING}", f"pr_iterations={model.PR_ITERATIONS}",
                f"multi_sources={MULTI_SOURCES}"]
    for name, fn, specs in artifact_specs(args.n, args.tile):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"artifact={name}.hlo.txt")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {os.path.join(args.out_dir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
