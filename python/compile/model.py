"""Layer-2 JAX model: the dense-block accelerated supersteps.

Each function composes the Layer-1 Pallas kernels into one engine
superstep over a padded dense adjacency block. ``aot.py`` lowers these
once to HLO text; the Rust runtime (``rust/src/runtime/``) executes them
via PJRT — Python never runs on the request path.

Conventions shared with the Rust side (rust/src/runtime/accel.rs):

- ``adj[i, j] == 1.0`` iff the graph has a directed edge ``j → i``
  (in-neighbour matrix), padded with zeros to the compiled size ``n``;
- PageRank: padded lanes carry ``inv_outdeg == 0`` so they contribute
  nothing; the returned rank of a padded lane is the harmless constant
  ``(1-d)/n_real``, which Rust ignores;
- SSSP distances / CC labels: padded lanes hold ``+inf``.
"""

import jax.numpy as jnp
from jax import lax

from .kernels import batched_min_plus, min_plus_matvec, sum_matvec

DAMPING = 0.85
PR_ITERATIONS = 10  # the paper's Table II PageRank configuration


def pagerank_step(adj, contrib, n_real, *, tile):
    """One PageRank update: ``(1-d)/n + d * (adj @ contrib)``.

    ``contrib[j] = rank[j] / out_degree[j]`` is prepared by the caller
    (Rust hot path or the fused loop below); ``n_real`` is the unpadded
    vertex count as a traced f32 scalar.
    """
    sums = sum_matvec(adj, contrib, tile=tile)
    return (1.0 - DAMPING) / n_real + DAMPING * sums


def pagerank_run(adj, rank, inv_outdeg, n_real, *, tile, iterations=PR_ITERATIONS):
    """The paper's full PR benchmark fused into one computation:
    ``iterations`` damped updates with dangling mass dropped
    (``inv_outdeg[j] == 0`` for dangling j), as in the Rust engine.
    """

    def body(_, r):
        contrib = r * inv_outdeg
        return pagerank_step(adj, contrib, n_real, tile=tile)

    return lax.fori_loop(0, iterations, body, rank)


def sssp_superstep(adj, dist, *, tile):
    """One unit-weight SSSP relaxation wave over the block."""
    cand = min_plus_matvec(adj, dist, increment=1.0, tile=tile)
    return jnp.minimum(dist, cand)


def cc_superstep(adj, label, *, tile):
    """One CC min-label propagation wave over the block."""
    cand = min_plus_matvec(adj, label, increment=0.0, tile=tile)
    return jnp.minimum(label, cand)


def multi_sssp_superstep(adj, dists, *, tile):
    """Batched unit-weight SSSP wave: one column per source.

    MXU-utilisation variant of ``sssp_superstep`` (EXPERIMENTS.md §Perf
    L1): the batch dimension fills the systolic array on real hardware.
    """
    cand = batched_min_plus(adj, dists, increment=1.0, tile=tile)
    return jnp.minimum(dists, cand)
