"""Build-time compile path: Layer-2 JAX model + Layer-1 Pallas kernels.

Imported only by ``aot.py`` and the pytest suite — never at runtime.
"""
