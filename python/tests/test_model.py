"""L2 correctness: model supersteps vs references and vs each other."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
import hypothesis.strategies as st

from compile import model
from compile.kernels import ref

TILE = 8
N = 32


def ring_adj(n):
    """In-neighbour matrix of an undirected ring."""
    adj = np.zeros((n, n), np.float32)
    for v in range(n):
        adj[v, (v - 1) % n] = 1.0
        adj[v, (v + 1) % n] = 1.0
    return adj


def test_pagerank_step_matches_ref():
    adj = jnp.asarray(ring_adj(N))
    contrib = jnp.full((N,), 1.0 / N, jnp.float32) / 2.0
    got = model.pagerank_step(adj, contrib, jnp.float32(N), tile=TILE)
    want = ref.pagerank_step(adj, contrib, N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_pagerank_run_uniform_on_ring():
    # Regular graph: ranks stay uniform across all 10 iterations.
    adj = jnp.asarray(ring_adj(N))
    rank = jnp.full((N,), 1.0 / N, jnp.float32)
    inv_deg = jnp.full((N,), 0.5, jnp.float32)
    got = model.pagerank_run(adj, rank, inv_deg, jnp.float32(N), tile=TILE)
    np.testing.assert_allclose(np.asarray(got), 1.0 / N, rtol=1e-5)


def test_pagerank_run_matches_unrolled_ref():
    rng = np.random.default_rng(3)
    adj = (rng.random((N, N)) < 0.2).astype(np.float32)
    outdeg = adj.sum(axis=0)
    inv = np.where(outdeg > 0, 1.0 / np.maximum(outdeg, 1), 0.0).astype(np.float32)
    rank = np.full(N, 1.0 / N, np.float32)
    got = model.pagerank_run(
        jnp.asarray(adj), jnp.asarray(rank), jnp.asarray(inv), jnp.float32(N), tile=TILE
    )
    want = ref.pagerank_run(jnp.asarray(adj), jnp.asarray(rank), jnp.asarray(inv), N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


def test_sssp_superstep_is_bfs_wave_on_ring():
    adj = jnp.asarray(ring_adj(N))
    dist = np.full(N, np.inf, np.float32)
    dist[0] = 0.0
    d = jnp.asarray(dist)
    for step in range(1, 4):
        d = model.sssp_superstep(adj, d, tile=TILE)
        got = np.asarray(d)
        for v in range(N):
            want = min(v, N - v)
            if want <= step:
                assert got[v] == want, (step, v)
            else:
                assert np.isinf(got[v])


@given(st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_sssp_monotone_and_cc_converges(seed):
    rng = np.random.default_rng(seed)
    adj_np = (rng.random((N, N)) < 0.1).astype(np.float32)
    adj_np = np.maximum(adj_np, adj_np.T)  # undirected
    adj = jnp.asarray(adj_np)

    dist = np.full(N, np.inf, np.float32)
    dist[0] = 0.0
    d = jnp.asarray(dist)
    for _ in range(5):
        d2 = model.sssp_superstep(adj, d, tile=TILE)
        assert np.all(np.asarray(d2) <= np.asarray(d)), "relaxation must not regress"
        d = d2

    label = jnp.asarray(np.arange(N, dtype=np.float32))
    for _ in range(N):
        nxt = model.cc_superstep(adj, label, tile=TILE)
        if np.array_equal(np.asarray(nxt), np.asarray(label)):
            break
        label = nxt
    # Converged labels are fixpoints and each label is a component member.
    final = model.cc_superstep(adj, label, tile=TILE)
    np.testing.assert_array_equal(np.asarray(final), np.asarray(label))
    lab = np.asarray(label).astype(int)
    assert np.all(lab <= np.arange(N))
