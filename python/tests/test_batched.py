"""Batched multi-source kernels vs oracles (hypothesis sweep)."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile import model
from compile.kernels import batched, ref


@st.composite
def batched_case(draw):
    tile = draw(st.sampled_from([4, 8]))
    blocks = draw(st.integers(min_value=1, max_value=3))
    batch = draw(st.sampled_from([1, 2, 5, 8]))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    density = draw(st.floats(min_value=0.0, max_value=0.8))
    return tile, tile * blocks, batch, seed, density


@given(batched_case())
@settings(max_examples=30, deadline=None)
def test_batched_sum_matches_ref(case):
    tile, n, b, seed, density = case
    rng = np.random.default_rng(seed)
    adj = (rng.random((n, n)) < density).astype(np.float32)
    x = rng.random((n, b)).astype(np.float32)
    got = batched.batched_sum_matmul(jnp.asarray(adj), jnp.asarray(x), tile=tile)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.batched_sum_matmul(adj, x)), rtol=1e-5, atol=1e-5
    )


@given(batched_case())
@settings(max_examples=30, deadline=None)
def test_batched_min_plus_matches_ref_and_columns(case):
    tile, n, b, seed, density = case
    rng = np.random.default_rng(seed)
    adj = (rng.random((n, n)) < density).astype(np.float32)
    x = rng.random((n, b)).astype(np.float32) * 50
    x[rng.random((n, b)) < 0.3] = np.inf
    got = batched.batched_min_plus(jnp.asarray(adj), jnp.asarray(x), tile=tile)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.batched_min_plus(adj, x)))
    # Column b of the batch must equal the single-vector kernel on column b.
    from compile.kernels import matvec

    for col in range(b):
        single = matvec.min_plus_matvec(jnp.asarray(adj), jnp.asarray(x[:, col]), tile=tile)
        np.testing.assert_allclose(np.asarray(got)[:, col], np.asarray(single))


def test_multi_sssp_superstep_waves():
    # Ring of 16, sources at 0 and 8: columns advance independent waves.
    n, tile = 16, 8
    adj = np.zeros((n, n), np.float32)
    for v in range(n):
        adj[v, (v - 1) % n] = adj[v, (v + 1) % n] = 1.0
    d = np.full((n, 2), np.inf, np.float32)
    d[0, 0] = 0.0
    d[8, 1] = 0.0
    cur = jnp.asarray(d)
    for _ in range(8):
        cur = model.multi_sssp_superstep(jnp.asarray(adj), cur, tile=tile)
    got = np.asarray(cur)
    for v in range(n):
        assert got[v, 0] == min(v, n - v), v
        assert got[v, 1] == min(abs(v - 8), n - abs(v - 8)), v
