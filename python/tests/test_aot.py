"""AOT pipeline checks: lowering works, HLO text parses, manifest sane.

Uses a small n to keep lowering fast; `make artifacts` produces the real
n=1024 artifacts.
"""

import os
import subprocess
import sys

import pytest

from compile import aot, model


def test_artifact_specs_cover_all_models():
    specs = aot.artifact_specs(n=16, tile=8)
    names = [s[0] for s in specs]
    assert names == [
        "multi_sssp_relax",
        "pagerank_step",
        "pagerank_run",
        "sssp_relax",
        "cc_label",
    ]


@pytest.mark.parametrize("name_idx", range(5))
def test_lowering_produces_parseable_hlo_text(name_idx):
    import jax

    name, fn, specs = aot.artifact_specs(n=16, tile=8)[name_idx]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), name
    # The fused PR loop must contain a while op; steps must not.
    if name == "pagerank_run":
        assert "while" in text
    assert "ENTRY" in text


def test_aot_cli_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--n", "16", "--tile", "8"],
        check=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env,
    )
    files = sorted(p.name for p in out.iterdir())
    assert "manifest.txt" in files
    for name in ["pagerank_step", "pagerank_run", "sssp_relax", "cc_label", "multi_sssp_relax"]:
        assert f"{name}.hlo.txt" in files
        assert (out / f"{name}.hlo.txt").read_text().startswith("HloModule")
    manifest = (out / "manifest.txt").read_text()
    assert "n=16" in manifest and "tile=8" in manifest
    assert f"pr_iterations={model.PR_ITERATIONS}" in manifest


def test_aot_rejects_bad_tile(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--n", "10", "--tile", "8"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
    )
    assert proc.returncode != 0
