"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, tiles, densities and value ranges; every kernel
must match ref.py to float tolerance. This is the core correctness signal
for the accelerated path.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import matvec, ref

jax.config.update("jax_enable_x64", True)


def random_adj(rng, n, density):
    return (rng.random((n, n)) < density).astype(np.float32)


@st.composite
def matvec_case(draw):
    tile = draw(st.sampled_from([4, 8, 16]))
    blocks = draw(st.integers(min_value=1, max_value=4))
    n = tile * blocks
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    density = draw(st.floats(min_value=0.0, max_value=1.0))
    return tile, n, seed, density


@given(matvec_case())
@settings(max_examples=40, deadline=None)
def test_sum_matvec_matches_ref(case):
    tile, n, seed, density = case
    rng = np.random.default_rng(seed)
    adj = random_adj(rng, n, density)
    x = rng.random(n).astype(np.float32)
    got = matvec.sum_matvec(jnp.asarray(adj), jnp.asarray(x), tile=tile)
    want = ref.sum_matvec(jnp.asarray(adj), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@given(matvec_case(), st.sampled_from([0.0, 1.0]))
@settings(max_examples=40, deadline=None)
def test_min_plus_matvec_matches_ref(case, increment):
    tile, n, seed, density = case
    rng = np.random.default_rng(seed)
    adj = random_adj(rng, n, density)
    # Mix of finite values and +inf (unreached vertices).
    x = rng.random(n).astype(np.float32) * 100
    x[rng.random(n) < 0.3] = np.inf
    got = matvec.min_plus_matvec(
        jnp.asarray(adj), jnp.asarray(x), increment=increment, tile=tile
    )
    want = ref.min_plus_matvec(jnp.asarray(adj), jnp.asarray(x), increment)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_kernels_support_dtypes(dtype):
    n, tile = 16, 8
    rng = np.random.default_rng(0)
    adj = jnp.asarray(random_adj(rng, n, 0.4), dtype=dtype)
    x = jnp.asarray(rng.random(n), dtype=dtype)
    got = matvec.sum_matvec(adj, x, tile=tile)
    assert got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.sum_matvec(adj, x)), rtol=1e-5
    )
    got_min = matvec.min_plus_matvec(adj, x, tile=tile)
    assert got_min.dtype == dtype


def test_empty_adjacency_gives_identity_semantics():
    n, tile = 8, 4
    adj = jnp.zeros((n, n), jnp.float32)
    x = jnp.arange(n, dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(matvec.sum_matvec(adj, x, tile=tile)), 0.0)
    got = matvec.min_plus_matvec(adj, x, tile=tile)
    assert np.all(np.isinf(np.asarray(got)))


def test_shape_validation():
    adj = jnp.zeros((8, 8), jnp.float32)
    with pytest.raises(ValueError, match="multiple of tile"):
        matvec.sum_matvec(adj, jnp.zeros(8), tile=3)
    with pytest.raises(ValueError, match="square"):
        matvec.sum_matvec(jnp.zeros((8, 4)), jnp.zeros(8), tile=4)
    with pytest.raises(ValueError, match="does not match"):
        matvec.sum_matvec(adj, jnp.zeros(4), tile=4)
