#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON file emitted by `ipregel run
--trace-out` (see DESIGN.md §2.10): parseable JSON, the shapes Perfetto
expects, and per-lane span sanity. Exits non-zero on the first failure.

Usage: python3 python/check_trace.py TRACE.json
"""
import json
import sys


def check(path):
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    assert isinstance(events, list) and events, "empty traceEvents"
    phases = {e["ph"] for e in events}
    assert phases <= {"X", "i", "C", "M"}, f"unexpected phases {phases}"
    assert all(e.get("pid") == 1 for e in events), "single-process trace"
    names = {e["args"]["name"] for e in events if e["ph"] == "M"}
    assert "ipregel run" in names and "engine" in names, f"metadata lanes: {names}"
    spans = [e for e in events if e["ph"] == "X"]
    assert spans, "no spans"
    for e in spans:
        assert e["dur"] >= 0 and e["ts"] >= 0, f"negative time in {e}"
        assert e["cat"] in ("phase", "shard"), f"bad span category {e}"
        assert "superstep" in e["args"], f"span without superstep {e}"
    for e in (e for e in events if e["ph"] == "i"):
        assert e["s"] == "t", f"instants are thread-scoped, got {e}"
    counters = {e["name"] for e in events if e["ph"] == "C"}
    assert counters == {"shard-skew", "contention", "messages"} or not counters, counters
    return len(events)


if __name__ == "__main__":
    if len(sys.argv) != 2:
        sys.exit(__doc__)
    n = check(sys.argv[1])
    print(f"{sys.argv[1]}: OK ({n} events)")
