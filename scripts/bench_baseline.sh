#!/usr/bin/env bash
# Emit the committed bench baseline: run the tracked benches in
# BENCH_SMOKE mode and merge their JSON outputs into BENCH_baseline.json
# at the repository root.
#
# Usage:  scripts/bench_baseline.sh [output-path]
#
# BENCH_SMOKE=1 keeps each bench to a small graph / few supersteps so
# the baseline exercises every code path (flat vs sharded, dynamic vs
# rebuild, the Table II switch grid) without measuring the clock for
# minutes; drop the env var below for a full run.
set -euo pipefail

repo_root="$(cd "$(dirname "$0")/.." && pwd)"
out="${1:-$repo_root/BENCH_baseline.json}"
tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT

cd "$repo_root"
# A bench whose smoke JSON already exists (e.g. produced by an earlier
# CI step) can be reused instead of re-run: point BENCH_TABLE2_JSON /
# BENCH_PARTITION_JSON / BENCH_DYNAMIC_JSON at the file.
reuse_for() {
  case "$1" in
    bench_table2) echo "${BENCH_TABLE2_JSON:-}" ;;
    bench_partition) echo "${BENCH_PARTITION_JSON:-}" ;;
    bench_dynamic) echo "${BENCH_DYNAMIC_JSON:-}" ;;
    bench_adaptive) echo "${BENCH_ADAPTIVE_JSON:-}" ;;
    bench_scatter) echo "${BENCH_SCATTER_JSON:-}" ;;
    bench_trace) echo "${BENCH_TRACE_JSON:-}" ;;
    bench_serve) echo "${BENCH_SERVE_JSON:-}" ;;
    bench_memory) echo "${BENCH_MEMORY_JSON:-}" ;;
  esac
}
for bench in bench_table2 bench_partition bench_dynamic bench_adaptive bench_scatter bench_trace bench_serve bench_memory; do
  reuse="$(reuse_for "$bench")"
  if [ -n "$reuse" ] && [ -f "$reuse" ]; then
    echo "== $bench (reusing $reuse) ==" >&2
    cp "$reuse" "$tmp/$bench.json"
  else
    echo "== $bench ==" >&2
    BENCH_SMOKE=1 BENCH_OUT="$tmp/$bench.json" cargo bench --bench "$bench"
  fi
done

# Merge: one top-level object keyed by bench name, with provenance.
{
  echo '{'
  echo "  \"generated_by\": \"scripts/bench_baseline.sh\","
  echo "  \"rustc\": \"$(rustc --version)\","
  echo "  \"smoke\": true,"
  first=1
  for bench in bench_table2 bench_partition bench_dynamic bench_adaptive bench_scatter bench_trace bench_serve bench_memory; do
    [ "$first" = 1 ] || echo ','
    first=0
    printf '  "%s": ' "$bench"
    sed 's/^/  /' "$tmp/$bench.json" | sed '1s/^  //'
  done
  echo '}'
} >"$out"

echo "wrote $out" >&2
