//! Vertex-layout micro-benchmark (§IV) — a *real*, single-core-measurable
//! effect: random pulls from interleaved records vs externalised hot
//! slots at working sets from cache-resident to DRAM-bound.
//!
//! Run: `cargo bench --bench bench_layout`

use ipregel::combine::MsgSlot;
use ipregel::engine::{EngineConfig, GraphSession, RunOptions};
use ipregel::algos::PageRank;
use ipregel::graph::gen;
use ipregel::layout::{AosStore, Layout, SoaStore, VertexStore};
use ipregel::metrics::TablePrinter;
use ipregel::util::rng::Rng;
use ipregel::util::timer::Timer;

/// Simulated pull scan: peek `probes` random vertices' current slots.
fn scan_ns_per_access<S: VertexStore<u64, f64>>(store: &S, probes: usize, seed: u64) -> f64 {
    let n = store.len();
    let mut rng = Rng::new(seed);
    // Pre-populate some outboxes so peeks read both flag and message.
    for v in 0..n as u32 {
        if v % 3 == 0 {
            store.cur_slot(v).store_first(v as f64);
        }
    }
    let idx: Vec<u32> = (0..65_536).map(|_| rng.below(n as u64) as u32).collect();
    let t = Timer::start();
    let mut acc = 0.0f64;
    for i in 0..probes {
        if let Some(m) = store.cur_slot(idx[i & 0xFFFF]).peek() {
            acc += m;
        }
    }
    std::hint::black_box(acc);
    t.elapsed().as_nanos() as f64 / probes as f64
}

fn main() {
    let probes: usize = std::env::var("BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4_000_000);
    println!("== layout micro-benchmark: random outbox peeks (ns/access) ==\n");
    let mut t = TablePrinter::new(&["vertices", "interleaved (AoS)", "externalised (SoA)", "ratio"]);
    for scale in [12u32, 16, 20, 22] {
        let n = 1usize << scale;
        let g = gen::ring(n);
        let aos: AosStore<u64, f64> = AosStore::build(&g, &mut |_| 0);
        let soa: SoaStore<u64, f64> = SoaStore::build(&g, &mut |_| 0);
        let a = scan_ns_per_access(&aos, probes, 1);
        let s = scan_ns_per_access(&soa, probes, 1);
        t.row(vec![
            format!("2^{scale}"),
            format!("{a:.2}"),
            format!("{s:.2}"),
            format!("{:.2}x", a / s),
        ]);
    }
    println!("{}", t.render());
    println!(
        "slot stride: SoA {}B vs AoS record >= 64B — beyond LLC the AoS\n\
         scan pays ~4x the lines (paper §IV).\n",
        std::mem::size_of::<MsgSlot<f64>>()
    );

    // End-to-end single-core effect on the real engine: PR on a large
    // power-law graph, both layouts.
    println!("== end-to-end: PageRank(10) wall clock, 1 thread ==\n");
    let g = gen::rmat(20, 8, 0.57, 0.19, 0.19, 11);
    let mut t2 = TablePrinter::new(&["layout", "wall", "speedup"]);
    let session = GraphSession::with_config(&g, EngineConfig::default().threads(1));
    let timer = Timer::start();
    let _ = session.run(&PageRank::default());
    let aos_t = timer.secs();
    let timer = Timer::start();
    let _ = session.run_with(
        &PageRank::default(),
        RunOptions::new().config(EngineConfig::default().threads(1).layout(Layout::Externalised)),
    );
    let soa_t = timer.secs();
    t2.row(vec!["interleaved".into(), format!("{aos_t:.2}s"), "1.00".into()]);
    t2.row(vec![
        "externalised".into(),
        format!("{soa_t:.2}s"),
        format!("{:.2}", aos_t / soa_t),
    ]);
    println!("{}", t2.render());
}
