//! Memory-plane bench: raw CSR slabs vs compressed varint row blocks vs
//! the out-of-core arena, per algorithm, emitting `BENCH_memory.json`.
//! The headline claims under test: delta-gap compression of the sorted
//! rows buys at least 1.5x on the scale-free catalog analogues, the
//! out-of-core arena runs with a bounded resident set, and neither
//! backing changes a single answer (bit-identity is asserted per run,
//! not assumed).
//!
//! Run: `cargo bench --bench bench_memory`            (friendster-s analogue)
//!      `BENCH_SMOKE=1 cargo bench --bench bench_memory`  (CI smoke:
//!       friendster-t analogue — exercises decode, streaming, eviction
//!       and the parity assertions, not the clock)
//!      `BENCH_OUT=path.json` overrides the output location.

use ipregel::algos::{ConnectedComponents, PageRank, Sssp};
use ipregel::engine::{EngineConfig, GraphSession, RunOptions, VertexProgram};
use ipregel::graph::csr::Csr;
use ipregel::graph::{gen, io, RowPlaneStats, RowPolicy};
use ipregel::util::timer::fmt_duration;
use std::fmt::Write as _;

struct Row {
    algo: &'static str,
    backing: &'static str,
    millis: f64,
    supersteps: usize,
    decodes: u64,
    row_faults: u64,
    evictions: u64,
    resident_kib: u64,
}

fn bench_one<P: VertexProgram>(
    session: &GraphSession<'_>,
    p: &P,
    cfg: EngineConfig,
    reps: usize,
) -> (usize, Option<RowPlaneStats>, Vec<P::Value>, f64) {
    let mut best: Option<(usize, Option<RowPlaneStats>, Vec<P::Value>, f64)> = None;
    for _ in 0..reps.max(1) {
        let r = session.run_with(p, RunOptions::new().config(cfg));
        let ms = r.metrics.total_time.as_secs_f64() * 1e3;
        if best.as_ref().map_or(true, |(_, _, _, b)| ms < *b) {
            best = Some((
                r.metrics.num_supersteps(),
                r.metrics.row_plane.clone(),
                r.values,
                ms,
            ));
        }
    }
    best.unwrap()
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_memory.json".to_string());

    // Catalog analogues (RMAT, Graph500 quadrants): friendster-t for the
    // smoke tier, friendster-s for the full clock — the scale-free skew
    // is the point, hub rows are where delta-gap coding earns its ratio.
    let (name, g, reps): (&str, Csr, usize) = if smoke {
        ("friendster-t", gen::rmat(10, 6, 0.57, 0.19, 0.19, 7), 1)
    } else {
        ("friendster-s", gen::rmat(14, 8, 0.57, 0.19, 0.19, 7), 3)
    };
    let block = if smoke { 64 } else { 1024 };
    eprintln!(
        "== bench_memory ({}, {name}): |V|={} |E|={} block={} ==",
        if smoke { "SMOKE" } else { "full" },
        g.num_vertices(),
        g.num_edges(),
        block
    );

    let dir = std::env::temp_dir().join(format!("ipregel_bench_mem_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench scratch dir");
    let raw_bytes = g.memory_bytes();

    let compressed = g.clone().compress(block);
    let external = io::externalize(&g, &dir.join("arena.ipgc"), block)
        .expect("externalising the bench graph");
    // Bounded working set: the out-of-core tier streams under a budget
    // of 1/4 of the blocks, so eviction pressure is part of the clock.
    let budget = (external.row_plane().expect("external plane").num_blocks() / 4).max(1);
    external.row_plane().expect("external plane").set_policy(RowPolicy {
        resident_blocks: Some(budget),
        cold_rounds: None,
    });
    let ratio = compressed
        .row_plane()
        .expect("compressed plane")
        .stats()
        .compression_ratio();
    eprintln!(
        "  compression ratio {ratio:.2}x ({} raw adjacency bytes), oocore budget {budget} blocks",
        raw_bytes
    );
    assert!(
        ratio >= 1.5,
        "{name}: compression ratio {ratio:.2} below the 1.5x floor"
    );

    let cfg = EngineConfig::default().threads(4);
    let backings: Vec<(&'static str, &Csr)> =
        vec![("raw", &g), ("compressed", &compressed), ("external", &external)];

    let mut rows: Vec<Row> = Vec::new();
    fn run_algo<P: VertexProgram>(
        name: &'static str,
        p: &P,
        backings: &[(&'static str, &Csr)],
        cfg: EngineConfig,
        reps: usize,
        rows: &mut Vec<Row>,
    ) where
        P::Value: PartialEq + std::fmt::Debug,
    {
        let mut reference: Option<Vec<P::Value>> = None;
        for (label, gb) in backings {
            let session = GraphSession::new(gb);
            let (supersteps, rp, values, ms) = bench_one(&session, p, cfg, reps);
            match &reference {
                None => reference = Some(values),
                Some(want) => {
                    assert_eq!(&values, want, "{name}/{label}: row backing changed answers")
                }
            }
            let (decodes, row_faults, evictions, resident_kib) = rp
                .as_ref()
                .map(|s| (s.decodes, s.row_faults, s.evictions, s.resident_bytes / 1024))
                .unwrap_or_default();
            eprintln!(
                "  {:<5} {:<10} {} supersteps in {} (decodes {decodes}, \
                 faults {row_faults}, evictions {evictions}, resident {resident_kib} KiB)",
                name,
                label,
                supersteps,
                fmt_duration(std::time::Duration::from_secs_f64(ms / 1e3)),
            );
            rows.push(Row {
                algo: name,
                backing: label,
                millis: ms,
                supersteps,
                decodes,
                row_faults,
                evictions,
                resident_kib,
            });
        }
    }

    run_algo("pr", &PageRank::default(), &backings, cfg, reps, &mut rows);
    run_algo("cc", &ConnectedComponents, &backings, cfg, reps, &mut rows);
    run_algo("sssp", &Sssp::from_hub(&g), &backings, cfg, reps, &mut rows);

    // Residency contracts, cheap enough to assert in the bench itself:
    // the compressed tier decodes, the external tier streams (faults
    // exceed one cold pass) and actually evicts under its budget.
    for r in &rows {
        match r.backing {
            "raw" => assert_eq!(r.decodes, 0, "{}: raw runs must not decode", r.algo),
            _ => assert!(r.decodes > 0, "{}/{}: nothing decoded", r.algo, r.backing),
        }
    }
    let pr_ext = rows
        .iter()
        .find(|r| r.algo == "pr" && r.backing == "external")
        .expect("external pr row");
    assert!(pr_ext.evictions > 0, "oocore budget never evicted");

    // ---- Emit BENCH_memory.json ------------------------------------
    let mut j = String::new();
    j.push_str("{\n");
    let _ = writeln!(j, "  \"bench\": \"memory\",");
    let _ = writeln!(j, "  \"smoke\": {},", smoke);
    let _ = writeln!(j, "  \"graph\": \"{name}\",");
    let _ = writeln!(
        j,
        "  \"shape\": {{\"vertices\": {}, \"edges\": {}}},",
        g.num_vertices(),
        g.num_edges()
    );
    let _ = writeln!(j, "  \"block_size\": {},", block);
    let _ = writeln!(j, "  \"resident_budget_blocks\": {},", budget);
    let _ = writeln!(j, "  \"raw_bytes\": {},", raw_bytes);
    let _ = writeln!(j, "  \"compression_ratio\": {:.4},", ratio);
    j.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            j,
            "    {{\"algo\": \"{}\", \"backing\": \"{}\", \"millis\": {:.3}, \
             \"supersteps\": {}, \"decodes\": {}, \"row_faults\": {}, \
             \"evictions\": {}, \"resident_kib\": {}}}",
            r.algo, r.backing, r.millis, r.supersteps, r.decodes, r.row_faults,
            r.evictions, r.resident_kib
        );
        j.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    j.push_str("  ]\n}\n");
    std::fs::write(&out_path, &j).expect("writing BENCH_memory.json");
    eprintln!("wrote {out_path} ({} result rows)", rows.len());
    std::fs::remove_dir_all(&dir).ok();
    eprintln!("parity checks passed");
}
