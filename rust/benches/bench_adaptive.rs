//! Adaptive-tuner bench: adaptive runs vs a grid of fixed configurations
//! per algorithm, emitting `BENCH_adaptive.json`. The headline claim
//! under test: an adaptive run is never (meaningfully) slower than the
//! best fixed configuration, and its decision trace proves it switched
//! modes mid-run rather than lucking into one good fixed choice.
//!
//! Run: `cargo bench --bench bench_adaptive`
//!      `BENCH_SMOKE=1 cargo bench --bench bench_adaptive`  (CI smoke:
//!       small catalog-analogue graph — exercises the adaptive path and
//!       the parity/trace assertions, not the clock)
//!      `BENCH_OUT=path.json` overrides the output location.

use ipregel::algos::{Bfs, ConnectedComponents, PageRank, Sssp};
use ipregel::combine::Strategy;
use ipregel::engine::{EngineConfig, GraphSession, Halt, RunOptions, VertexProgram};
use ipregel::graph::csr::Csr;
use ipregel::graph::gen;
use ipregel::metrics::RunMetrics;
use ipregel::sched::Schedule;
use ipregel::util::timer::fmt_duration;
use std::fmt::Write as _;

struct Row {
    algo: &'static str,
    config: String,
    millis: f64,
    supersteps: usize,
    messages: u64,
    switches: usize,
    modes: usize,
}

/// Best-of-`reps` wall time for one (program, config) pair.
fn bench_one<P: VertexProgram>(
    session: &GraphSession<'_>,
    p: &P,
    cfg: EngineConfig,
    halt: &Halt<ipregel::engine::AggValue<P>>,
    reps: usize,
) -> (RunMetrics, Vec<P::Value>, f64) {
    let mut best: Option<(RunMetrics, Vec<P::Value>, f64)> = None;
    for _ in 0..reps.max(1) {
        let r = session.run_with(p, RunOptions::new().config(cfg).halt(halt.clone()));
        let ms = r.metrics.total_time.as_secs_f64() * 1e3;
        let better = match &best {
            None => true,
            Some((_, _, b)) => ms < *b,
        };
        if better {
            best = Some((r.metrics, r.values, ms));
        }
    }
    best.unwrap()
}

fn json_escape_free(s: &str) -> &str {
    debug_assert!(!s.contains('"') && !s.contains('\\'));
    s
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_adaptive.json".to_string());

    // Catalog-analogue shape (RMAT with Graph500 quadrants); the full
    // run scales it up, the smoke keeps CI fast.
    let (g, reps): (Csr, usize) = if smoke {
        (gen::rmat(10, 6, 0.57, 0.19, 0.19, 7), 1)
    } else {
        (gen::rmat(14, 8, 0.57, 0.19, 0.19, 7), 3)
    };
    eprintln!(
        "== bench_adaptive ({}): |V|={} |E|={} ==",
        if smoke { "SMOKE" } else { "full" },
        g.num_vertices(),
        g.num_edges()
    );

    let threads = 4usize;
    let base = EngineConfig::default().threads(threads);
    let session = GraphSession::with_config(&g, base);

    // The fixed grid the adaptive run competes against: each config is
    // the "right" one for a different phase shape.
    let fixed: Vec<(&'static str, EngineConfig)> = vec![
        ("static-lock-scan", base),
        ("static-lock-list", base.bypass(true)),
        (
            "dynamic-hybrid-list",
            base.schedule(Schedule::Dynamic { chunk: 256 })
                .strategy(Strategy::Hybrid)
                .bypass(true),
        ),
        (
            "edge-hybrid-scan",
            base.schedule(Schedule::EdgeCentric).strategy(Strategy::Hybrid),
        ),
    ];

    fn fmt_ms(ms: f64) -> String {
        fmt_duration(std::time::Duration::from_secs_f64(ms / 1e3))
    }

    let mut rows: Vec<Row> = Vec::new();
    let mut ratios: Vec<(&'static str, f64)> = Vec::new();

    #[allow(clippy::too_many_arguments)]
    fn run_algo<P: VertexProgram>(
        session: &GraphSession<'_>,
        name: &'static str,
        p: &P,
        fixed: &[(&'static str, EngineConfig)],
        base: EngineConfig,
        halt: &Halt<ipregel::engine::AggValue<P>>,
        reps: usize,
        rows: &mut Vec<Row>,
        ratios: &mut Vec<(&'static str, f64)>,
    ) where
        P::Value: PartialEq + std::fmt::Debug,
    {
        let mut best_fixed_ms = f64::INFINITY;
        let mut reference: Option<Vec<P::Value>> = None;
        for (label, cfg) in fixed {
            let (m, values, ms) = bench_one(session, p, *cfg, halt, reps);
            eprintln!(
                "  {:<6} {:<20} {} ({})",
                name,
                label,
                m.summary(),
                fmt_ms(ms)
            );
            match &reference {
                None => reference = Some(values),
                Some(want) => assert_eq!(&values, want, "{name}/{label}: fixed configs diverge"),
            }
            best_fixed_ms = best_fixed_ms.min(ms);
            rows.push(Row {
                algo: name,
                config: (*label).to_string(),
                millis: ms,
                supersteps: m.num_supersteps(),
                messages: m.total_messages(),
                switches: 0,
                modes: 0,
            });
        }
        let (m, values, ms) = bench_one(session, p, base.adaptive(true), halt, reps);
        eprintln!(
            "  {:<6} {:<20} {} ({}; vs best fixed {})",
            name,
            "adaptive",
            m.summary(),
            fmt_ms(ms),
            fmt_ms(best_fixed_ms)
        );
        assert_eq!(
            &values,
            reference.as_ref().expect("fixed rows ran"),
            "{name}: adaptive diverged from fixed configs"
        );
        ratios.push((name, ms / best_fixed_ms));
        rows.push(Row {
            algo: name,
            config: "adaptive".to_string(),
            millis: ms,
            supersteps: m.num_supersteps(),
            messages: m.total_messages(),
            switches: m.tuner_switches(),
            modes: m.tuner_modes(),
        });
    }

    let halt_q: Halt<()> = Halt::quiescence();
    let halt_pr: Halt<()> = Halt::supersteps(if smoke { 5 } else { 10 });
    run_algo(
        &session,
        "bfs",
        &Bfs {
            root: g.max_out_degree_vertex(),
        },
        &fixed,
        base,
        &halt_q,
        reps,
        &mut rows,
        &mut ratios,
    );
    run_algo(
        &session,
        "pr",
        &PageRank::default(),
        &fixed,
        base,
        &halt_pr,
        reps,
        &mut rows,
        &mut ratios,
    );
    run_algo(
        &session,
        "cc",
        &ConnectedComponents,
        &fixed,
        base,
        &halt_q,
        reps,
        &mut rows,
        &mut ratios,
    );
    run_algo(
        &session,
        "sssp",
        &Sssp::from_hub(&g),
        &fixed,
        base,
        &halt_q,
        reps,
        &mut rows,
        &mut ratios,
    );

    // ---- Emit BENCH_adaptive.json ----------------------------------
    let mut j = String::new();
    j.push_str("{\n");
    let _ = writeln!(j, "  \"bench\": \"adaptive\",");
    let _ = writeln!(j, "  \"smoke\": {},", smoke);
    let _ = writeln!(
        j,
        "  \"graph\": {{\"vertices\": {}, \"edges\": {}}},",
        g.num_vertices(),
        g.num_edges()
    );
    let _ = writeln!(j, "  \"threads\": {},", threads);
    j.push_str("  \"adaptive_vs_best_fixed\": {\n");
    for (i, (name, ratio)) in ratios.iter().enumerate() {
        let _ = write!(j, "    \"{}\": {:.4}", json_escape_free(name), ratio);
        j.push_str(if i + 1 < ratios.len() { ",\n" } else { "\n" });
    }
    j.push_str("  },\n");
    j.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            j,
            "    {{\"algo\": \"{}\", \"config\": \"{}\", \"millis\": {:.3}, \
             \"supersteps\": {}, \"messages\": {}, \"tuner_switches\": {}, \
             \"tuner_modes\": {}}}",
            json_escape_free(r.algo),
            json_escape_free(&r.config),
            r.millis,
            r.supersteps,
            r.messages,
            r.switches,
            r.modes
        );
        j.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    j.push_str("  ]\n}\n");

    std::fs::write(&out_path, &j).expect("writing BENCH_adaptive.json");
    eprintln!("wrote {out_path} ({} result rows)", rows.len());

    // Sanity: the adaptive BFS row must have actually switched modes
    // (≥ 2 distinct (schedule, strategy, bypass) tuples) — the whole
    // point of the controller, asserted here AND in test_adaptive.rs.
    let bfs_adaptive = rows
        .iter()
        .find(|r| r.algo == "bfs" && r.config == "adaptive")
        .expect("bfs adaptive row");
    assert!(
        bfs_adaptive.modes >= 2,
        "adaptive BFS selected only {} mode(s)",
        bfs_adaptive.modes
    );
    // Message totals are knob-independent: every config of an algorithm
    // must agree (the bench-level echo of the bit-identity contract).
    for algo in ["bfs", "pr", "cc", "sssp"] {
        let mut totals = rows.iter().filter(|r| r.algo == algo).map(|r| r.messages);
        let first = totals.next().expect("rows exist");
        assert!(
            totals.all(|m| m == first),
            "{algo}: message totals diverge across configs"
        );
    }
    eprintln!("parity checks passed");
}
