//! Partitioned-substrate bench: flat vs sharded execution per algorithm,
//! emitting a machine-readable `BENCH_partition.json` so the repo's perf
//! trajectory is tracked run over run.
//!
//! Run: `cargo bench --bench bench_partition`
//!      `BENCH_SMOKE=1 cargo bench --bench bench_partition`  (CI smoke:
//!       one small graph, 2 supersteps — exercises the partition path,
//!       not the clock)
//!      `BENCH_OUT=path.json` overrides the output location.

use ipregel::algos::{ConnectedComponents, DegreeCount, PageRank, Sssp};
use ipregel::engine::{EngineConfig, GraphSession, Halt, RunOptions, VertexProgram};
use ipregel::graph::csr::Csr;
use ipregel::graph::gen;
use ipregel::metrics::RunMetrics;
use ipregel::util::timer::fmt_duration;
use std::fmt::Write as _;

struct Row {
    algo: &'static str,
    mode: String,
    millis: f64,
    supersteps: usize,
    messages: u64,
    intra: u64,
    cross: u64,
    imbalance: f64,
}

fn record(algo: &'static str, mode: String, m: &RunMetrics, millis: f64) -> Row {
    Row {
        algo,
        mode,
        millis,
        supersteps: m.num_supersteps(),
        messages: m.total_messages(),
        intra: m.intra_shard_messages,
        cross: m.cross_shard_messages,
        imbalance: m.shard_edge_imbalance,
    }
}

/// Best-of-`reps` wall time for one (program, config) pair.
fn bench_one<P: VertexProgram>(
    session: &GraphSession<'_>,
    p: &P,
    cfg: EngineConfig,
    halt: &Halt<ipregel::engine::AggValue<P>>,
    reps: usize,
) -> (RunMetrics, f64) {
    let mut best: Option<(RunMetrics, f64)> = None;
    for _ in 0..reps.max(1) {
        let r = session.run_with(p, RunOptions::new().config(cfg).halt(halt.clone()));
        let ms = r.metrics.total_time.as_secs_f64() * 1e3;
        let better = match &best {
            None => true,
            Some((_, b)) => ms < *b,
        };
        if better {
            best = Some((r.metrics, ms));
        }
    }
    best.unwrap()
}

fn json_escape_free(s: &str) -> &str {
    debug_assert!(!s.contains('"') && !s.contains('\\'));
    s
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_partition.json".to_string());

    let (g, reps, halt_cap): (Csr, usize, Option<usize>) = if smoke {
        (gen::rmat(9, 4, 0.57, 0.19, 0.19, 7), 1, Some(2))
    } else {
        (gen::rmat(15, 8, 0.57, 0.19, 0.19, 7), 3, None)
    };
    eprintln!(
        "== bench_partition ({}): |V|={} |E|={} ==",
        if smoke { "SMOKE" } else { "full" },
        g.num_vertices(),
        g.num_edges()
    );

    let threads = 4usize;
    let session = GraphSession::with_config(&g, EngineConfig::default().threads(threads));
    let shard_counts: &[usize] = &[4, 16];
    let mut rows: Vec<Row> = Vec::new();

    fn fmt_ms(ms: f64) -> String {
        fmt_duration(std::time::Duration::from_secs_f64(ms / 1e3))
    }

    struct BenchCtx<'a, 'g> {
        session: &'a GraphSession<'g>,
        reps: usize,
        shard_counts: &'a [usize],
    }

    fn run_algo<P: VertexProgram>(
        ctx: &BenchCtx<'_, '_>,
        name: &'static str,
        p: &P,
        base: EngineConfig,
        halt: &Halt<ipregel::engine::AggValue<P>>,
        rows: &mut Vec<Row>,
    ) {
        let (m, ms) = bench_one(ctx.session, p, base, halt, ctx.reps);
        eprintln!("  {:<8} flat      {} ({})", name, m.summary(), fmt_ms(ms));
        rows.push(record(name, "flat".into(), &m, ms));
        for &k in ctx.shard_counts {
            let (m, ms) = bench_one(ctx.session, p, base.shards(k), halt, ctx.reps);
            eprintln!(
                "  {:<8} shards={:<2} {} ({})",
                name,
                k,
                m.summary(),
                fmt_ms(ms)
            );
            rows.push(record(name, format!("shards{k}"), &m, ms));
        }
    }

    let ctx = BenchCtx {
        session: &session,
        reps,
        shard_counts,
    };
    let base = EngineConfig::default().threads(threads);
    let halt_pr: Halt<()> = match halt_cap {
        Some(n) => Halt::supersteps(n),
        None => Halt::supersteps(10),
    };
    run_algo(&ctx, "pr", &PageRank::default(), base, &halt_pr, &mut rows);
    let halt_cc: Halt<()> = match halt_cap {
        Some(n) => Halt::supersteps(n),
        None => Halt::quiescence(),
    };
    run_algo(
        &ctx,
        "cc",
        &ConnectedComponents,
        base.bypass(true),
        &halt_cc,
        &mut rows,
    );
    run_algo(
        &ctx,
        "sssp",
        &Sssp::from_hub(&g),
        base.bypass(true),
        &halt_cc,
        &mut rows,
    );
    run_algo(&ctx, "degree", &DegreeCount, base, &halt_cc, &mut rows);

    // ---- Emit BENCH_partition.json ---------------------------------
    let mut j = String::new();
    j.push_str("{\n");
    let _ = writeln!(j, "  \"bench\": \"partition\",");
    let _ = writeln!(j, "  \"smoke\": {},", smoke);
    let _ = writeln!(
        j,
        "  \"graph\": {{\"vertices\": {}, \"edges\": {}}},",
        g.num_vertices(),
        g.num_edges()
    );
    let _ = writeln!(j, "  \"threads\": {},", threads);
    j.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            j,
            "    {{\"algo\": \"{}\", \"mode\": \"{}\", \"millis\": {:.3}, \
             \"supersteps\": {}, \"messages\": {}, \"intra_shard\": {}, \
             \"cross_shard\": {}, \"edge_imbalance\": {:.4}}}",
            json_escape_free(r.algo),
            json_escape_free(&r.mode),
            r.millis,
            r.supersteps,
            r.messages,
            r.intra,
            r.cross,
            r.imbalance
        );
        j.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    j.push_str("  ]\n}\n");

    std::fs::write(&out_path, &j).expect("writing BENCH_partition.json");
    eprintln!("wrote {out_path} ({} result rows)", rows.len());

    // Smoke sanity: the sharded rows must have exercised the partition
    // path (message split recorded) and matched flat message totals.
    for algo in ["pr", "cc", "sssp", "degree"] {
        let flat = rows
            .iter()
            .find(|r| r.algo == algo && r.mode == "flat")
            .expect("flat row");
        for r in rows.iter().filter(|r| r.algo == algo && r.mode != "flat") {
            assert_eq!(
                r.messages, flat.messages,
                "{algo}/{}: sharded message total must match flat",
                r.mode
            );
            assert_eq!(
                r.intra + r.cross,
                r.messages,
                "{algo}/{}: intra + cross must cover the total",
                r.mode
            );
        }
    }
    eprintln!("parity checks passed");
}
