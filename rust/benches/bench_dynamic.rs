//! Dynamic-graph bench: cold rebuild-and-rerun vs incremental
//! recompute per mutation-batch size, emitting a machine-readable
//! `BENCH_dynamic.json` so the repo's perf trajectory is tracked run
//! over run.
//!
//! Run: `cargo bench --bench bench_dynamic`
//!      `BENCH_SMOKE=1 cargo bench --bench bench_dynamic`  (CI smoke:
//!       small graph, two batch sizes — exercises the mutate →
//!       incremental path and the parity checks, not the clock)
//!      `BENCH_OUT=path.json` overrides the output location.

use ipregel::algos::incremental::{
    delta_pagerank_halt, incremental_cc, incremental_pagerank, DeltaPageRank, IncrementalState,
};
use ipregel::algos::ConnectedComponents;
use ipregel::engine::{EngineConfig, GraphSession, RunOptions};
use ipregel::graph::dynamic::{DynamicGraph, MutationSet};
use ipregel::graph::{gen, Csr};
use ipregel::util::rng::Rng;
use ipregel::util::timer::{fmt_duration, Timer};
use std::fmt::Write as _;

struct Row {
    algo: &'static str,
    batch: usize,
    cold_ms: f64,
    inc_ms: f64,
    rebuild_ms: f64,
    apply_ms: f64,
    cold_supersteps: usize,
    inc_supersteps: usize,
    delta_occupancy: f64,
    compacted: bool,
}

/// Rebuild the merged view from scratch — what a system without the
/// delta subsystem pays before it can even start the cold rerun.
fn rebuild(g: &Csr) -> Csr {
    g.rebuilt()
}

fn random_batch(rng: &mut Rng, n: usize, batch: usize) -> MutationSet {
    let mut m = MutationSet::new();
    while m.inserts().len() < 2 * batch {
        let s = rng.below(n as u64) as u32;
        let d = rng.below(n as u64) as u32;
        if s != d {
            m.insert_undirected(s, d);
        }
    }
    m
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_dynamic.json".to_string());

    let (g, batch_sizes): (Csr, &[usize]) = if smoke {
        (gen::rmat(9, 4, 0.57, 0.19, 0.19, 7), &[8, 64])
    } else {
        (gen::rmat(14, 8, 0.57, 0.19, 0.19, 7), &[16, 128, 1024])
    };
    eprintln!(
        "== bench_dynamic ({}): |V|={} |E|={} ==",
        if smoke { "SMOKE" } else { "full" },
        g.num_vertices(),
        g.num_edges()
    );

    let threads = 4usize;
    let cfg = EngineConfig::default().threads(threads);
    let n = g.num_vertices();
    let mut rows: Vec<Row> = Vec::new();
    let mut rng = Rng::new(0xD1AC);

    // ---- PageRank: warm incremental vs rebuild + cold rerun ----------
    {
        let p = DeltaPageRank::default();
        let mut session = GraphSession::dynamic_with_config(DynamicGraph::new(g.clone()), cfg);
        let cold0 = session.run_with(&p, RunOptions::new().halt(delta_pagerank_halt(&p)));
        let mut state = IncrementalState::new(cold0.values, session.graph_epoch());
        for &batch in batch_sizes {
            let m = random_batch(&mut rng, n, batch);
            let t_apply = Timer::start();
            let receipt = session.apply_mutations(&m).expect("dynamic session");
            let apply_ms = t_apply.elapsed().as_secs_f64() * 1e3;

            let t_inc = Timer::start();
            let (inc_metrics, next) =
                incremental_pagerank(&session, &state, &receipt, &p).expect("epochs chain");
            let inc_ms = t_inc.elapsed().as_secs_f64() * 1e3;

            let t_rebuild = Timer::start();
            let rebuilt = rebuild(session.graph());
            let rebuild_ms = t_rebuild.elapsed().as_secs_f64() * 1e3;
            let cold_session = GraphSession::with_config(&rebuilt, cfg);
            let t_cold = Timer::start();
            let cold = cold_session.run_with(&p, RunOptions::new().halt(delta_pagerank_halt(&p)));
            let cold_ms = t_cold.elapsed().as_secs_f64() * 1e3;

            // Parity: warm fixpoint == cold fixpoint (to tolerance).
            for v in 0..n {
                let (a, b) = (next.values[v], cold.values[v]);
                assert!((a - b).abs() < 1e-6, "pr parity v{v}: {a} vs {b}");
            }
            eprintln!(
                "  pr  batch={batch:<5} apply {} + inc {} ({} steps)  vs  rebuild {} + cold {} ({} steps)",
                fmt_ms(apply_ms),
                fmt_ms(inc_ms),
                inc_metrics.num_supersteps(),
                fmt_ms(rebuild_ms),
                fmt_ms(cold_ms),
                cold.metrics.num_supersteps(),
            );
            rows.push(Row {
                algo: "pr",
                batch,
                cold_ms,
                inc_ms,
                rebuild_ms,
                apply_ms,
                cold_supersteps: cold.metrics.num_supersteps(),
                inc_supersteps: inc_metrics.num_supersteps(),
                delta_occupancy: inc_metrics.delta_occupancy,
                compacted: receipt.compacted,
            });
            state = next;
        }
    }

    // ---- CC: insert-only incremental vs rebuild + cold rerun ---------
    {
        let mut session = GraphSession::dynamic_with_config(DynamicGraph::new(g.clone()), cfg);
        let cold0 = session.run_with(
            &ConnectedComponents,
            RunOptions::new().config(cfg.bypass(true)),
        );
        let mut state = IncrementalState::new(cold0.values, session.graph_epoch());
        for &batch in batch_sizes {
            let m = random_batch(&mut rng, n, batch);
            let t_apply = Timer::start();
            let receipt = session.apply_mutations(&m).expect("dynamic session");
            let apply_ms = t_apply.elapsed().as_secs_f64() * 1e3;

            let t_inc = Timer::start();
            let (inc_metrics, next) =
                incremental_cc(&session, &state, &receipt).expect("insert-only");
            let inc_ms = t_inc.elapsed().as_secs_f64() * 1e3;

            let t_rebuild = Timer::start();
            let rebuilt = rebuild(session.graph());
            let rebuild_ms = t_rebuild.elapsed().as_secs_f64() * 1e3;
            let cold_session = GraphSession::with_config(&rebuilt, cfg);
            let t_cold = Timer::start();
            let cold = cold_session.run_with(
                &ConnectedComponents,
                RunOptions::new().config(cfg.bypass(true)),
            );
            let cold_ms = t_cold.elapsed().as_secs_f64() * 1e3;

            assert_eq!(next.values, cold.values, "cc parity at batch {batch}");
            eprintln!(
                "  cc  batch={batch:<5} apply {} + inc {} ({} steps)  vs  rebuild {} + cold {} ({} steps)",
                fmt_ms(apply_ms),
                fmt_ms(inc_ms),
                inc_metrics.num_supersteps(),
                fmt_ms(rebuild_ms),
                fmt_ms(cold_ms),
                cold.metrics.num_supersteps(),
            );
            rows.push(Row {
                algo: "cc",
                batch,
                cold_ms,
                inc_ms,
                rebuild_ms,
                apply_ms,
                cold_supersteps: cold.metrics.num_supersteps(),
                inc_supersteps: inc_metrics.num_supersteps(),
                delta_occupancy: inc_metrics.delta_occupancy,
                compacted: receipt.compacted,
            });
            state = next;
        }
    }

    // ---- Emit BENCH_dynamic.json -------------------------------------
    let mut j = String::new();
    j.push_str("{\n");
    let _ = writeln!(j, "  \"bench\": \"dynamic\",");
    let _ = writeln!(j, "  \"smoke\": {},", smoke);
    let _ = writeln!(
        j,
        "  \"graph\": {{\"vertices\": {}, \"edges\": {}}},",
        g.num_vertices(),
        g.num_edges()
    );
    let _ = writeln!(j, "  \"threads\": {},", threads);
    j.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            j,
            "    {{\"algo\": \"{}\", \"batch\": {}, \"apply_millis\": {:.3}, \
             \"incremental_millis\": {:.3}, \"rebuild_millis\": {:.3}, \
             \"cold_millis\": {:.3}, \"incremental_supersteps\": {}, \
             \"cold_supersteps\": {}, \"delta_occupancy\": {:.5}, \"compacted\": {}}}",
            r.algo,
            r.batch,
            r.apply_ms,
            r.inc_ms,
            r.rebuild_ms,
            r.cold_ms,
            r.inc_supersteps,
            r.cold_supersteps,
            r.delta_occupancy,
            r.compacted
        );
        j.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    j.push_str("  ]\n}\n");

    std::fs::write(&out_path, &j).expect("writing BENCH_dynamic.json");
    eprintln!("wrote {out_path} ({} result rows)", rows.len());

    // Smoke sanity: incremental CC must do no more supersteps than cold
    // (warm start from the previous fixpoint), and every row recorded a
    // parity-checked run.
    for r in &rows {
        if r.algo == "cc" {
            assert!(
                r.inc_supersteps <= r.cold_supersteps + 2,
                "cc batch {}: incremental {} vs cold {} supersteps",
                r.batch,
                r.inc_supersteps,
                r.cold_supersteps
            );
        }
    }
    eprintln!("parity checks passed");
}

fn fmt_ms(ms: f64) -> String {
    fmt_duration(std::time::Duration::from_secs_f64(ms / 1e3))
}
