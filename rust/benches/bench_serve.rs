//! Serving-layer tail-latency bench: a seeded stream of bounded
//! interactive queries (ego-net BFS / point SSSP) measured on an idle
//! [`QueryServer`], then again with a whole-graph batch PageRank
//! contending at the admission gate — emitting `BENCH_serve.json`. The
//! headline numbers: p50/p99 small-query latency in both phases (the
//! tail amplification multi-tenancy costs), query throughput, and the
//! pool-hit rate proving concurrent queries share warm stores. Answers
//! are asserted bit-identical to solo runs in both phases.
//!
//! Run: `cargo bench --bench bench_serve`
//!      `BENCH_SMOKE=1 cargo bench --bench bench_serve`   (CI smoke)
//!      `BENCH_OUT=path.json` overrides the output location.

use ipregel::algos::query::{EgoNetBfs, PointSssp};
use ipregel::algos::PageRank;
use ipregel::engine::{EngineConfig, GraphSession};
use ipregel::graph::csr::Csr;
use ipregel::graph::gen;
use ipregel::metrics::LatencyStats;
use ipregel::serve::{AdmissionController, QueryServer, QuerySpec};
use ipregel::util::rng::Rng;
use ipregel::util::timer::{fmt_duration, Timer};
use std::fmt::Write as _;
use std::sync::Mutex;
use std::time::Duration;

/// One workload item: a root and which of the two query programs to run.
#[derive(Clone, Copy)]
struct Item {
    root: u32,
    point_sssp: bool,
}

struct Phase {
    label: &'static str,
    stats: LatencyStats,
    wall: Duration,
    batch_supersteps: usize,
    batch_millis: f64,
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

fn json_escape_free(s: &str) -> &str {
    debug_assert!(!s.contains('"') && !s.contains('\\'));
    s
}

/// Drain the workload from `submitters` threads against `server`,
/// optionally alongside a batch PageRank, asserting every answer matches
/// its solo ground truth. Returns the phase's latency stats.
#[allow(clippy::too_many_arguments)]
fn run_phase(
    label: &'static str,
    server: &QueryServer,
    workload: &[Item],
    expected: &[Vec<u64>],
    expected_sssp: &[Vec<f64>],
    submitters: usize,
    radius: u64,
    batch: Option<&PageRank>,
) -> Phase {
    let next = Mutex::new(0usize);
    let latencies = Mutex::new(Vec::new());
    let batch_out = Mutex::new((0usize, 0.0f64));
    let t = Timer::start();
    std::thread::scope(|s| {
        if let Some(p) = batch {
            let batch_out = &batch_out;
            s.spawn(move || {
                let r = server
                    .execute(p, &QuerySpec::batch())
                    .expect("admission queue is unbounded");
                *batch_out.lock().unwrap() = (r.query.supersteps, ms(r.query.run_time));
            });
        }
        for _ in 0..submitters.max(1) {
            let (next, latencies) = (&next, &latencies);
            s.spawn(move || loop {
                let i = {
                    let mut ix = next.lock().unwrap();
                    let i = *ix;
                    *ix += 1;
                    i
                };
                let Some(&item) = workload.get(i) else {
                    break;
                };
                let spec = QuerySpec::interactive();
                let latency = if item.point_sssp {
                    let r = server
                        .execute(
                            &PointSssp {
                                source: item.root,
                                cutoff: radius as f64,
                            },
                            &spec,
                        )
                        .expect("admission queue is unbounded");
                    assert_eq!(
                        r.values, expected_sssp[i],
                        "{label}: served point-sssp diverged from solo (query {i})"
                    );
                    r.query.latency
                } else {
                    let r = server
                        .execute(
                            &EgoNetBfs {
                                root: item.root,
                                radius,
                            },
                            &spec,
                        )
                        .expect("admission queue is unbounded");
                    assert_eq!(
                        r.values, expected[i],
                        "{label}: served ego-net diverged from solo (query {i})"
                    );
                    r.query.latency
                };
                latencies.lock().unwrap().push(latency);
            });
        }
    });
    let wall = t.elapsed();
    let (batch_supersteps, batch_millis) = batch_out.into_inner().unwrap();
    Phase {
        label,
        stats: LatencyStats::from_durations(&latencies.into_inner().unwrap()),
        wall,
        batch_supersteps,
        batch_millis,
    }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());

    let (g, queries): (Csr, usize) = if smoke {
        (gen::rmat(10, 6, 0.57, 0.19, 0.19, 7), 24)
    } else {
        (gen::rmat(13, 8, 0.57, 0.19, 0.19, 7), 96)
    };
    let threads = 4usize;
    let gate = 4usize;
    let radius = 2u64;
    let batch_iterations = if smoke { 5 } else { 20 };
    eprintln!(
        "== bench_serve ({}): |V|={} |E|={} {} queries, gate {} ==",
        if smoke { "SMOKE" } else { "full" },
        g.num_vertices(),
        g.num_edges(),
        queries,
        gate
    );

    let n = g.num_vertices() as u64;
    let mut rng = Rng::new(0x5E44E);
    let workload: Vec<Item> = (0..queries)
        .map(|i| Item {
            root: rng.below(n) as u32,
            point_sssp: i % 2 == 1,
        })
        .collect();

    // Solo ground truth for every workload item, from a quiet session.
    let cfg = EngineConfig::default().threads(threads);
    let solo_graph = g.rebuilt();
    let solo = GraphSession::with_config(&solo_graph, cfg);
    let mut expected: Vec<Vec<u64>> = Vec::with_capacity(queries);
    let mut expected_sssp: Vec<Vec<f64>> = Vec::with_capacity(queries);
    for item in &workload {
        if item.point_sssp {
            expected.push(Vec::new());
            expected_sssp.push(
                solo.run(&PointSssp {
                    source: item.root,
                    cutoff: radius as f64,
                })
                .values,
            );
        } else {
            expected.push(
                solo.run(&EgoNetBfs {
                    root: item.root,
                    radius,
                })
                .values,
            );
            expected_sssp.push(Vec::new());
        }
    }

    let server = QueryServer::with_config(g, cfg, AdmissionController::new(gate));
    let pr = PageRank {
        iterations: batch_iterations,
        damping: 0.85,
    };
    let phases = [
        run_phase(
            "idle", &server, &workload, &expected, &expected_sssp, gate, radius, None,
        ),
        run_phase(
            "with-batch",
            &server,
            &workload,
            &expected,
            &expected_sssp,
            gate,
            radius,
            Some(&pr),
        ),
    ];
    for p in &phases {
        eprintln!(
            "  {:<10} {} queries: p50 {} p99 {} max {} ({:.1} q/s)",
            p.label,
            p.stats.count,
            fmt_duration(p.stats.p50()),
            fmt_duration(p.stats.p99()),
            fmt_duration(p.stats.max()),
            p.stats.count as f64 / p.wall.as_secs_f64().max(1e-9),
        );
    }
    let pool = server.pool_stats();

    // ---- Emit BENCH_serve.json -------------------------------------
    let mut j = String::new();
    j.push_str("{\n");
    let _ = writeln!(j, "  \"bench\": \"serve\",");
    let _ = writeln!(j, "  \"smoke\": {},", smoke);
    let _ = writeln!(
        j,
        "  \"graph\": {{\"vertices\": {}, \"edges\": {}}},",
        server.snapshot().session().graph().num_vertices(),
        server.snapshot().session().graph().num_edges()
    );
    let _ = writeln!(j, "  \"threads\": {},", threads);
    let _ = writeln!(j, "  \"gate\": {},", gate);
    let _ = writeln!(j, "  \"queries_per_phase\": {},", queries);
    let _ = writeln!(
        j,
        "  \"p99_tail_amplification\": {:.4},",
        phases[1].stats.p99_ns as f64 / (phases[0].stats.p99_ns as f64).max(1.0)
    );
    let _ = writeln!(
        j,
        "  \"pool\": {{\"store_checkouts\": {}, \"store_hits\": {}}},",
        pool.store_checkouts, pool.store_hits
    );
    j.push_str("  \"phases\": [\n");
    for (i, p) in phases.iter().enumerate() {
        let _ = write!(
            j,
            "    {{\"phase\": \"{}\", \"queries\": {}, \"p50_ms\": {:.4}, \
             \"p99_ms\": {:.4}, \"mean_ms\": {:.4}, \"max_ms\": {:.4}, \
             \"qps\": {:.2}, \"batch_supersteps\": {}, \"batch_millis\": {:.3}}}",
            json_escape_free(p.label),
            p.stats.count,
            ms(p.stats.p50()),
            ms(p.stats.p99()),
            ms(p.stats.mean()),
            ms(p.stats.max()),
            p.stats.count as f64 / p.wall.as_secs_f64().max(1e-9),
            p.batch_supersteps,
            p.batch_millis
        );
        j.push_str(if i + 1 < phases.len() { ",\n" } else { "\n" });
    }
    j.push_str("  ]\n}\n");

    std::fs::write(&out_path, &j).expect("writing BENCH_serve.json");
    eprintln!("wrote {out_path} ({} phases)", phases.len());

    // Acceptance gates (smoke only, where CI runs them). Values parity
    // was asserted inline per query; these pin the serving plumbing.
    if smoke {
        for p in &phases {
            assert_eq!(p.stats.count, queries, "{}: lost queries", p.label);
        }
        assert!(
            phases[1].batch_supersteps > 0,
            "the contended phase's batch run never ran"
        );
        assert!(
            pool.store_hits > 0,
            "concurrent queries never hit the store pool"
        );
        assert_eq!(server.queries_completed() as usize, 2 * queries + 1);
    }
    eprintln!("parity checks passed");
}
