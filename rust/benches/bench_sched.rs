//! Scheduling micro-benchmarks (§V): virtual-machine makespans for every
//! policy on power-law workloads, a dynamic-chunk-size sweep (the paper's
//! empirical 256), plus the real `parallel_for` dispatch overhead.
//!
//! Run: `cargo bench --bench bench_sched`

use ipregel::metrics::TablePrinter;
use ipregel::sched::{parallel_for, Schedule};
use ipregel::sim::VirtualMachine;
use ipregel::util::quick::skewed_degrees;
use ipregel::util::rng::Rng;
use ipregel::util::timer::Timer;

fn makespan(sched: Schedule, costs: &[f64], weights: &[u64], threads: usize) -> (f64, f64) {
    let mut vm = VirtualMachine::new(threads);
    let stats = vm.region(sched, costs, Some(weights), 25.0);
    (stats.makespan_ns, stats.imbalance)
}

fn main() {
    let mut rng = Rng::new(42);
    let n = 1 << 20;
    let threads = 32;
    // Per-item cost ∝ degree (the §V-A premise) over a power-law degree
    // sequence — the canonical vertex-centric workload shape.
    let degrees = skewed_degrees(&mut rng, n, 50_000);
    let costs: Vec<f64> = degrees.iter().map(|&d| 4.0 + d as f64 * 2.0).collect();

    println!("== schedule makespans: 2^20 power-law items, 32 virtual threads ==\n");
    let mut t = TablePrinter::new(&["schedule", "makespan (ms)", "imbalance"]);
    for (name, sched) in [
        ("static", Schedule::Static),
        ("dynamic:256", Schedule::Dynamic { chunk: 256 }),
        ("dynamic:16", Schedule::Dynamic { chunk: 16 }),
        ("guided", Schedule::Guided { min_chunk: 64 }),
        ("edge-centric", Schedule::EdgeCentric),
    ] {
        let (ms, imb) = makespan(sched, &costs, &degrees, threads);
        t.row(vec![
            name.into(),
            format!("{:.3}", ms / 1e6),
            format!("{imb:.3}"),
        ]);
    }
    println!("{}", t.render());

    println!("== dynamic chunk-size sweep (paper: 256 is the sweet spot) ==\n");
    let mut t2 = TablePrinter::new(&["chunk", "makespan (ms)", "imbalance"]);
    for chunk in [1usize, 16, 64, 256, 1024, 8192, 65_536] {
        let (ms, imb) = makespan(Schedule::Dynamic { chunk }, &costs, &degrees, threads);
        t2.row(vec![
            chunk.to_string(),
            format!("{:.3}", ms / 1e6),
            format!("{imb:.3}"),
        ]);
    }
    println!("{}", t2.render());

    println!("== real parallel_for dispatch overhead (4 threads, empty body) ==\n");
    let mut t3 = TablePrinter::new(&["schedule", "µs/region"]);
    for (name, sched) in [
        ("static", Schedule::Static),
        ("dynamic:256", Schedule::Dynamic { chunk: 256 }),
        ("edge-centric", Schedule::EdgeCentric),
    ] {
        let w: Vec<u64> = vec![1; 10_000];
        let reps = 200;
        let timer = Timer::start();
        for _ in 0..reps {
            parallel_for(4, 10_000, sched, Some(&w), |_, r| {
                std::hint::black_box(r.len());
            });
        }
        t3.row(vec![
            name.into(),
            format!("{:.1}", timer.elapsed().as_micros() as f64 / reps as f64),
        ]);
    }
    println!("{}", t3.render());
}
