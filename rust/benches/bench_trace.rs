//! Observability-plane overhead bench: the same runs with tracing off
//! vs on (and, compiled with `--features no-trace`, with the plane
//! removed entirely), emitting `BENCH_trace.json`. The headline claim
//! under test: per-worker segment recording drained only at barriers
//! keeps the traced/untraced ratio within noise of 1, and the answers
//! are bit-identical either way.
//!
//! Run: `cargo bench --bench bench_trace`
//!      `BENCH_SMOKE=1 cargo bench --bench bench_trace`   (CI smoke)
//!      `BENCH_OUT=path.json` overrides the output location.
//!
//! The compile-out axis is a separate invocation: rerun with
//! `--features no-trace` and diff the JSON (`trace_compiled_in` flags
//! which side a file came from).

use ipregel::algos::{ConnectedComponents, PageRank};
use ipregel::engine::{EngineConfig, GraphSession, Halt, RunOptions, VertexProgram};
use ipregel::graph::csr::Csr;
use ipregel::graph::gen;
use ipregel::util::timer::fmt_duration;
use std::fmt::Write as _;

struct Row {
    algo: &'static str,
    config: String,
    traced: bool,
    millis: f64,
    supersteps: usize,
    events: usize,
}

/// Best-of-`reps` wall time; returns (values, millis, trace-event count).
fn bench_one<P: VertexProgram>(
    session: &GraphSession<'_>,
    p: &P,
    cfg: EngineConfig,
    halt: &Halt<ipregel::engine::AggValue<P>>,
    reps: usize,
) -> (Vec<P::Value>, f64, usize, usize) {
    let mut best: Option<(Vec<P::Value>, f64, usize, usize)> = None;
    for _ in 0..reps.max(1) {
        let r = session.run_with(p, RunOptions::new().config(cfg).halt(halt.clone()));
        let ms = r.metrics.total_time.as_secs_f64() * 1e3;
        let events = r.metrics.trace.as_ref().map_or(0, |t| t.events.len());
        let better = match &best {
            None => true,
            Some((_, b, _, _)) => ms < *b,
        };
        if better {
            best = Some((r.values, ms, events, r.metrics.num_supersteps()));
        }
    }
    let (values, ms, events, steps) = best.unwrap();
    (values, ms, events, steps)
}

fn json_escape_free(s: &str) -> &str {
    debug_assert!(!s.contains('"') && !s.contains('\\'));
    s
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_trace.json".to_string());

    // Smoke still takes best-of-3: the <5% overhead acceptance gate
    // below needs best-of-N ratios, single-shot ms-scale runs are noise.
    let (g, reps): (Csr, usize) = if smoke {
        (gen::rmat(10, 6, 0.57, 0.19, 0.19, 7), 3)
    } else {
        (gen::rmat(14, 8, 0.57, 0.19, 0.19, 7), 3)
    };
    eprintln!(
        "== bench_trace ({}): |V|={} |E|={} trace compiled {} ==",
        if smoke { "SMOKE" } else { "full" },
        g.num_vertices(),
        g.num_edges(),
        if cfg!(feature = "no-trace") { "OUT" } else { "in" }
    );

    let threads = 4usize;
    let base = EngineConfig::default().threads(threads);
    // Flat and partitioned+steal: the two recording regimes (per-chunk
    // compute spans vs per-shard spans with steal attribution).
    let grid: Vec<(&'static str, EngineConfig)> = vec![
        ("flat", base),
        (
            "sharded-steal",
            base.shards(if smoke { 16 } else { 64 }).bypass(true).steal(true),
        ),
    ];

    let session = GraphSession::with_config(&g, base);
    let halt_q: Halt<()> = Halt::quiescence();
    let halt_pr: Halt<()> = Halt::supersteps(if smoke { 5 } else { 10 });

    let mut rows: Vec<Row> = Vec::new();
    let mut ratios: Vec<(String, f64)> = Vec::new();

    fn run_algo<P: VertexProgram>(
        session: &GraphSession<'_>,
        name: &'static str,
        p: &P,
        grid: &[(&'static str, EngineConfig)],
        halt: &Halt<ipregel::engine::AggValue<P>>,
        reps: usize,
        rows: &mut Vec<Row>,
        ratios: &mut Vec<(String, f64)>,
    ) where
        P::Value: PartialEq + std::fmt::Debug,
    {
        for (label, cfg) in grid {
            let (plain_vals, plain_ms, plain_events, plain_steps) =
                bench_one(session, p, *cfg, halt, reps);
            let (traced_vals, traced_ms, traced_events, traced_steps) =
                bench_one(session, p, cfg.trace(true), halt, reps);
            assert_eq!(plain_vals, traced_vals, "{name}/{label}: tracing changed answers");
            assert_eq!(plain_steps, traced_steps, "{name}/{label}: tracing changed supersteps");
            assert_eq!(plain_events, 0, "{name}/{label}: untraced run recorded events");
            if !cfg!(feature = "no-trace") {
                assert!(traced_events > 0, "{name}/{label}: traced run recorded nothing");
            }
            let ratio = traced_ms / plain_ms;
            eprintln!(
                "  {:<3} {:<14} off {} on {} ratio {:.3} ({} events)",
                name,
                label,
                fmt_duration(std::time::Duration::from_secs_f64(plain_ms / 1e3)),
                fmt_duration(std::time::Duration::from_secs_f64(traced_ms / 1e3)),
                ratio,
                traced_events
            );
            ratios.push((format!("{name}/{label}"), ratio));
            rows.push(Row {
                algo: name,
                config: (*label).to_string(),
                traced: false,
                millis: plain_ms,
                supersteps: plain_steps,
                events: 0,
            });
            rows.push(Row {
                algo: name,
                config: (*label).to_string(),
                traced: true,
                millis: traced_ms,
                supersteps: traced_steps,
                events: traced_events,
            });
        }
    }

    run_algo(&session, "pr", &PageRank::default(), &grid, &halt_pr, reps, &mut rows, &mut ratios);
    run_algo(&session, "cc", &ConnectedComponents, &grid, &halt_q, reps, &mut rows, &mut ratios);

    // ---- Emit BENCH_trace.json -------------------------------------
    let mut j = String::new();
    j.push_str("{\n");
    let _ = writeln!(j, "  \"bench\": \"trace\",");
    let _ = writeln!(j, "  \"smoke\": {},", smoke);
    let _ = writeln!(
        j,
        "  \"graph\": {{\"vertices\": {}, \"edges\": {}}},",
        g.num_vertices(),
        g.num_edges()
    );
    let _ = writeln!(j, "  \"threads\": {},", threads);
    let _ = writeln!(j, "  \"trace_compiled_in\": {},", !cfg!(feature = "no-trace"));
    j.push_str("  \"traced_vs_untraced\": {\n");
    for (i, (name, ratio)) in ratios.iter().enumerate() {
        let _ = write!(j, "    \"{}\": {:.4}", json_escape_free(name), ratio);
        j.push_str(if i + 1 < ratios.len() { ",\n" } else { "\n" });
    }
    j.push_str("  },\n");
    j.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            j,
            "    {{\"algo\": \"{}\", \"config\": \"{}\", \"traced\": {}, \
             \"millis\": {:.3}, \"supersteps\": {}, \"trace_events\": {}}}",
            json_escape_free(r.algo),
            json_escape_free(&r.config),
            r.traced,
            r.millis,
            r.supersteps,
            r.events
        );
        j.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    j.push_str("  ]\n}\n");

    std::fs::write(&out_path, &j).expect("writing BENCH_trace.json");
    eprintln!("wrote {out_path} ({} result rows)", rows.len());

    // Acceptance gate (smoke only, where CI runs it): barrier-drained
    // per-worker segments must keep tracing under 5% of the run.
    if smoke && !cfg!(feature = "no-trace") {
        for (name, ratio) in &ratios {
            assert!(
                *ratio < 1.05,
                "{name}: traced/untraced ratio {ratio:.3} exceeds the 5% overhead budget"
            );
        }
    }
    eprintln!("parity checks passed");
}
