//! Scatter memory-system bench: fixed shard dispatch vs work-stealing vs
//! the full memory pass (stealing + deep prefetch pipeline) per
//! algorithm, emitting `BENCH_scatter.json`. The headline claim under
//! test: on an irregular catalog-analogue graph the stealing dispatch is
//! never (meaningfully) slower than fixed cuts, and the full pass is
//! value-identical to both — the memory knobs buy locality and balance,
//! never answers.
//!
//! Run: `cargo bench --bench bench_scatter`
//!      `BENCH_SMOKE=1 cargo bench --bench bench_scatter`  (CI smoke:
//!       small graph — exercises stealing, the pipeline and the parity
//!       assertions, not the clock)
//!      `BENCH_OUT=path.json` overrides the output location.
//!
//! A/B of the prefetch *hints* themselves is a compile-time axis: rerun
//! with `--features no-prefetch` and diff the JSON.

use ipregel::algos::{Bfs, ConnectedComponents, PageRank, Sssp};
use ipregel::engine::{EngineConfig, GraphSession, Halt, RunOptions, VertexProgram};
use ipregel::graph::csr::Csr;
use ipregel::graph::gen;
use ipregel::metrics::RunMetrics;
use ipregel::util::timer::fmt_duration;
use std::fmt::Write as _;

struct Row {
    algo: &'static str,
    config: String,
    millis: f64,
    supersteps: usize,
    messages: u64,
    steals: u64,
    lanes_scanned: u64,
}

/// Best-of-`reps` wall time for one (program, config) pair.
fn bench_one<P: VertexProgram>(
    session: &GraphSession<'_>,
    p: &P,
    cfg: EngineConfig,
    halt: &Halt<ipregel::engine::AggValue<P>>,
    reps: usize,
) -> (RunMetrics, Vec<P::Value>, f64) {
    let mut best: Option<(RunMetrics, Vec<P::Value>, f64)> = None;
    for _ in 0..reps.max(1) {
        let r = session.run_with(p, RunOptions::new().config(cfg).halt(halt.clone()));
        let ms = r.metrics.total_time.as_secs_f64() * 1e3;
        let better = match &best {
            None => true,
            Some((_, _, b)) => ms < *b,
        };
        if better {
            best = Some((r.metrics, r.values, ms));
        }
    }
    best.unwrap()
}

fn json_escape_free(s: &str) -> &str {
    debug_assert!(!s.contains('"') && !s.contains('\\'));
    s
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_scatter.json".to_string());

    // Largest catalog-analogue shape (RMAT, Graph500 quadrants): the
    // skew is the point — power-law shard weights are what stealing and
    // the prefetch pipeline exist to absorb.
    let (g, reps): (Csr, usize) = if smoke {
        (gen::rmat(10, 6, 0.57, 0.19, 0.19, 7), 1)
    } else {
        (gen::rmat(14, 8, 0.57, 0.19, 0.19, 7), 3)
    };
    eprintln!(
        "== bench_scatter ({}): |V|={} |E|={} ==",
        if smoke { "SMOKE" } else { "full" },
        g.num_vertices(),
        g.num_edges()
    );

    let threads = 4usize;
    let shards = if smoke { 16 } else { 64 };
    // Sharded list-driven scatter is the hot loop under test; the grid
    // below toggles only the memory knobs on top of it.
    let base = EngineConfig::default().threads(threads).shards(shards).bypass(true);
    let session = GraphSession::with_config(&g, base);

    let grid: Vec<(&'static str, EngineConfig)> = vec![
        ("fixed", base),
        ("steal", base.steal(true)),
        ("deep-pipeline", base.pipeline_depth(32)),
        ("full-pass", base.steal(true).pipeline_depth(32)),
    ];

    fn fmt_ms(ms: f64) -> String {
        fmt_duration(std::time::Duration::from_secs_f64(ms / 1e3))
    }

    let mut rows: Vec<Row> = Vec::new();
    let mut ratios: Vec<(&'static str, f64)> = Vec::new();

    #[allow(clippy::too_many_arguments)]
    fn run_algo<P: VertexProgram>(
        session: &GraphSession<'_>,
        name: &'static str,
        p: &P,
        grid: &[(&'static str, EngineConfig)],
        halt: &Halt<ipregel::engine::AggValue<P>>,
        reps: usize,
        rows: &mut Vec<Row>,
        ratios: &mut Vec<(&'static str, f64)>,
    ) where
        P::Value: PartialEq + std::fmt::Debug,
    {
        let mut fixed_ms = f64::NAN;
        let mut full_ms = f64::NAN;
        let mut reference: Option<Vec<P::Value>> = None;
        for (label, cfg) in grid {
            let (m, values, ms) = bench_one(session, p, *cfg, halt, reps);
            eprintln!(
                "  {:<6} {:<14} {} ({}; steals {})",
                name,
                label,
                m.summary(),
                fmt_ms(ms),
                m.steals
            );
            match &reference {
                None => reference = Some(values),
                Some(want) => {
                    assert_eq!(&values, want, "{name}/{label}: memory knobs changed answers")
                }
            }
            match *label {
                "fixed" => fixed_ms = ms,
                "full-pass" => full_ms = ms,
                _ => {}
            }
            rows.push(Row {
                algo: name,
                config: (*label).to_string(),
                millis: ms,
                supersteps: m.num_supersteps(),
                messages: m.total_messages(),
                steals: m.steals,
                lanes_scanned: m.vector_lanes_scanned,
            });
        }
        ratios.push((name, full_ms / fixed_ms));
        eprintln!(
            "  {:<6} full-pass/fixed = {:.3}",
            name,
            full_ms / fixed_ms
        );
    }

    let halt_q: Halt<()> = Halt::quiescence();
    let halt_pr: Halt<()> = Halt::supersteps(if smoke { 5 } else { 10 });
    run_algo(
        &session,
        "bfs",
        &Bfs {
            root: g.max_out_degree_vertex(),
        },
        &grid,
        &halt_q,
        reps,
        &mut rows,
        &mut ratios,
    );
    run_algo(
        &session,
        "pr",
        &PageRank::default(),
        &grid,
        &halt_pr,
        reps,
        &mut rows,
        &mut ratios,
    );
    run_algo(
        &session,
        "cc",
        &ConnectedComponents,
        &grid,
        &halt_q,
        reps,
        &mut rows,
        &mut ratios,
    );
    run_algo(
        &session,
        "sssp",
        &Sssp::from_hub(&g),
        &grid,
        &halt_q,
        reps,
        &mut rows,
        &mut ratios,
    );

    // ---- Emit BENCH_scatter.json -----------------------------------
    let mut j = String::new();
    j.push_str("{\n");
    let _ = writeln!(j, "  \"bench\": \"scatter\",");
    let _ = writeln!(j, "  \"smoke\": {},", smoke);
    let _ = writeln!(
        j,
        "  \"graph\": {{\"vertices\": {}, \"edges\": {}}},",
        g.num_vertices(),
        g.num_edges()
    );
    let _ = writeln!(j, "  \"threads\": {},", threads);
    let _ = writeln!(j, "  \"shards\": {},", shards);
    let _ = writeln!(
        j,
        "  \"prefetch\": {},",
        !cfg!(feature = "no-prefetch")
    );
    j.push_str("  \"full_pass_vs_fixed\": {\n");
    for (i, (name, ratio)) in ratios.iter().enumerate() {
        let _ = write!(j, "    \"{}\": {:.4}", json_escape_free(name), ratio);
        j.push_str(if i + 1 < ratios.len() { ",\n" } else { "\n" });
    }
    j.push_str("  },\n");
    j.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            j,
            "    {{\"algo\": \"{}\", \"config\": \"{}\", \"millis\": {:.3}, \
             \"supersteps\": {}, \"messages\": {}, \"steals\": {}, \
             \"vector_lanes_scanned\": {}}}",
            json_escape_free(r.algo),
            json_escape_free(&r.config),
            r.millis,
            r.supersteps,
            r.messages,
            r.steals,
            r.lanes_scanned
        );
        j.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    j.push_str("  ]\n}\n");

    std::fs::write(&out_path, &j).expect("writing BENCH_scatter.json");
    eprintln!("wrote {out_path} ({} result rows)", rows.len());

    // Parity echoes of the test_scatter.rs contracts, cheap enough to
    // keep in the bench itself:
    //  - message totals are knob-independent per algorithm;
    //  - non-stealing rows never record a steal;
    //  - PageRank's pull gather reports lane traffic only if its
    //    combiner is a monoid (f64 sum is not — so zero).
    for algo in ["bfs", "pr", "cc", "sssp"] {
        let mut totals = rows.iter().filter(|r| r.algo == algo).map(|r| r.messages);
        let first = totals.next().expect("rows exist");
        assert!(
            totals.all(|m| m == first),
            "{algo}: message totals diverge across configs"
        );
    }
    for r in rows.iter().filter(|r| r.config == "fixed" || r.config == "deep-pipeline") {
        assert_eq!(r.steals, 0, "{}/{}: steals without stealing", r.algo, r.config);
    }
    eprintln!("parity checks passed");
}
