//! End-to-end Table II bench target: regenerates the paper's results
//! table on the catalog analogues (tiny catalog by default so
//! `cargo bench` stays fast; set `BENCH_FULL=1` for the record run used
//! in EXPERIMENTS.md).
//!
//! Run: `cargo bench --bench bench_table2`
//!      `BENCH_FULL=1 cargo bench --bench bench_table2`
//!      `BENCH_SMOKE=1 cargo bench --bench bench_table2`  (CI smoke /
//!       committed baseline: one tiny catalog graph, generated
//!       in-memory, all three benchmarks)
//!      `BENCH_OUT=path.json` additionally emits the speed-up grid as
//!       machine-readable JSON (per bench × variant × graph) — the
//!       Table II slice of `BENCH_baseline.json`.

use ipregel::exp::{table2, Bench, Table2Options};
use ipregel::graph::catalog;
use ipregel::util::timer::{fmt_duration, Timer};
use std::fmt::Write as _;
use std::path::PathBuf;

fn main() {
    let full = std::env::var("BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let smoke = std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let dir = PathBuf::from(
        std::env::var("IPREGEL_GRAPHS").unwrap_or_else(|_| "data/graphs".into()),
    );
    let entries = if full {
        catalog::catalog()
    } else if smoke {
        // One graph keeps the committed baseline cheap to regenerate
        // while still covering every benchmark × variant cell.
        catalog::catalog_tiny().into_iter().take(1).collect()
    } else {
        catalog::catalog_tiny()
    };
    println!(
        "== Table II end-to-end ({} catalog, 32 virtual threads) ==",
        if full {
            "FULL"
        } else if smoke {
            "SMOKE"
        } else {
            "tiny"
        }
    );
    let mut graphs = Vec::new();
    for e in &entries {
        let t = Timer::start();
        // Smoke runs generate in-memory: no cache-directory writes in CI.
        let g = if smoke {
            e.generate()
        } else {
            e.load_or_generate(&dir).expect("graph generation")
        };
        eprintln!(
            "  {:<16} |V|={:<9} |E|={:<11} ({})",
            e.name,
            g.num_vertices(),
            g.num_edges(),
            fmt_duration(t.elapsed())
        );
        graphs.push((e.stands_for.to_string(), g));
    }
    let opts = Table2Options {
        threads: 32,
        benches: Bench::all().to_vec(),
        dynamic_chunk_override: if full { None } else { Some(16) },
    };
    let t = Timer::start();
    let results = table2::run_table2(&graphs, &opts);
    let names: Vec<String> = graphs.iter().map(|(n, _)| n.clone()).collect();
    println!("{}", table2::render(&names, &results));
    println!("{}", table2::summary(&results));
    println!("\n(total bench time {})", fmt_duration(t.elapsed()));

    if let Ok(out_path) = std::env::var("BENCH_OUT") {
        let mut j = String::new();
        j.push_str("{\n");
        let _ = writeln!(j, "  \"bench\": \"table2\",");
        let _ = writeln!(j, "  \"smoke\": {},", smoke);
        let _ = writeln!(
            j,
            "  \"graphs\": [{}],",
            names
                .iter()
                .map(|n| format!("\"{n}\""))
                .collect::<Vec<_>>()
                .join(", ")
        );
        j.push_str("  \"results\": [\n");
        let mut rows: Vec<String> = Vec::new();
        for r in &results {
            for (i, _name) in names.iter().enumerate() {
                rows.push(format!(
                    "    {{\"bench\": \"{}\", \"variant\": \"Baseline\", \"graph\": {}, \
                     \"virtual_secs\": {:.6}}}",
                    r.bench.title(),
                    i,
                    r.baseline_secs[i]
                ));
            }
            for row in &r.rows {
                for (i, s) in row.speedups.iter().enumerate() {
                    rows.push(format!(
                        "    {{\"bench\": \"{}\", \"variant\": \"{}\", \"graph\": {}, \
                         \"speedup\": {:.4}}}",
                        r.bench.title(),
                        row.name,
                        i,
                        s
                    ));
                }
            }
        }
        j.push_str(&rows.join(",\n"));
        j.push_str("\n  ]\n}\n");
        std::fs::write(&out_path, &j).expect("writing BENCH_OUT json");
        eprintln!("wrote {out_path} ({} result rows)", rows.len());
    }
}
