//! End-to-end Table II bench target: regenerates the paper's results
//! table on the catalog analogues (tiny catalog by default so
//! `cargo bench` stays fast; set `BENCH_FULL=1` for the record run used
//! in EXPERIMENTS.md).
//!
//! Run: `cargo bench --bench bench_table2`
//!       BENCH_FULL=1 cargo bench --bench bench_table2

use ipregel::exp::{table2, Bench, Table2Options};
use ipregel::graph::catalog;
use ipregel::util::timer::{fmt_duration, Timer};
use std::path::PathBuf;

fn main() {
    let full = std::env::var("BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let dir = PathBuf::from(
        std::env::var("IPREGEL_GRAPHS").unwrap_or_else(|_| "data/graphs".into()),
    );
    let entries = if full {
        catalog::catalog()
    } else {
        catalog::catalog_tiny()
    };
    println!(
        "== Table II end-to-end ({} catalog, 32 virtual threads) ==",
        if full { "FULL" } else { "tiny" }
    );
    let mut graphs = Vec::new();
    for e in &entries {
        let t = Timer::start();
        let g = e.load_or_generate(&dir).expect("graph generation");
        eprintln!(
            "  {:<16} |V|={:<9} |E|={:<11} ({})",
            e.name,
            g.num_vertices(),
            g.num_edges(),
            fmt_duration(t.elapsed())
        );
        graphs.push((e.stands_for.to_string(), g));
    }
    let opts = Table2Options {
        threads: 32,
        benches: Bench::all().to_vec(),
        dynamic_chunk_override: if full { None } else { Some(16) },
    };
    let t = Timer::start();
    let results = table2::run_table2(&graphs, &opts);
    let names: Vec<String> = graphs.iter().map(|(n, _)| n.clone()).collect();
    println!("{}", table2::render(&names, &results));
    println!("{}", table2::summary(&results));
    println!("\n(total bench time {})", fmt_duration(t.elapsed()));
}
