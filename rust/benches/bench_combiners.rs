//! Combiner micro-benchmarks (§III) — the measurements that calibrate the
//! virtual testbed's synchronisation costs.
//!
//! Reports ns/delivery for lock, CAS-neutral and hybrid strategies:
//! uncontended single-thread, first-push-heavy, and multi-thread hammer
//! on one slot (real contention — threads interleave even on one core).
//!
//! Run: `cargo bench --bench bench_combiners`

use ipregel::combine::{MinCombiner, MsgSlot, Strategy, SumCombiner};
use ipregel::metrics::TablePrinter;
use ipregel::util::timer::ns_per_iter;
use std::sync::Arc;

const STRATEGIES: [Strategy; 3] = [Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid];

fn uncontended_steady(strategy: Strategy, iters: usize) -> f64 {
    // Slot already populated: measures the steady-state combine path.
    let slot: MsgSlot<u64> = MsgSlot::new();
    strategy.reset_slot(&slot, &MinCombiner);
    strategy.deliver(&slot, u64::MAX - 1, &MinCombiner);
    let mut x = 1u64;
    ns_per_iter(iters, || {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
        strategy.deliver(&slot, x | 1, &MinCombiner);
    })
}

fn first_push_heavy(strategy: Strategy, iters: usize) -> f64 {
    // Fresh slot every delivery: measures the first-push path (the case
    // hybrid routes through its lock).
    let slots: Vec<MsgSlot<u64>> = (0..4096).map(|_| MsgSlot::new()).collect();
    for s in &slots {
        strategy.reset_slot(s, &SumCombiner);
    }
    let mut i = 0usize;
    ns_per_iter(iters, || {
        strategy.deliver(&slots[i & 4095], 7, &SumCombiner);
        i += 1;
        if i & 4095 == 0 {
            for s in &slots {
                let _ = strategy.collect(s, &SumCombiner);
                strategy.reset_slot(s, &SumCombiner);
            }
        }
    })
}

fn contended(strategy: Strategy, threads: usize, per_thread: usize) -> f64 {
    let slot: Arc<MsgSlot<u64>> = Arc::new(MsgSlot::new());
    strategy.reset_slot(&slot, &SumCombiner);
    let t = std::time::Instant::now();
    std::thread::scope(|s| {
        for tid in 0..threads {
            let slot = Arc::clone(&slot);
            s.spawn(move || {
                for i in 0..per_thread {
                    strategy.deliver(&slot, ((tid * per_thread + i) % 97 + 1) as u64, &SumCombiner);
                }
            });
        }
    });
    let elapsed = t.elapsed().as_nanos() as f64;
    let got = strategy.collect(&slot, &SumCombiner).unwrap();
    assert!(got > 0);
    elapsed / (threads * per_thread) as f64
}

fn main() {
    let iters: usize = std::env::var("BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000_000);
    println!("== combiner micro-benchmarks (ns/delivery, iters={iters}) ==\n");
    let mut t = TablePrinter::new(&[
        "strategy",
        "steady (uncontended)",
        "first-push heavy",
        "contended x4",
    ]);
    for s in STRATEGIES {
        t.row(vec![
            format!("{s:?}"),
            format!("{:.1}", uncontended_steady(s, iters)),
            format!("{:.1}", first_push_heavy(s, iters)),
            format!("{:.1}", contended(s, 4, iters / 20)),
        ]);
    }
    println!("{}", t.render());
    println!(
        "expectation (paper §III): hybrid ≈ CAS in steady state, ≈ lock on\n\
         first push; lock worst under contention. Feeds sim::CostModel."
    );
}
