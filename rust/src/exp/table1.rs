//! Table I reproduction: the graph inventory.
//!
//! Prints, for each catalog analogue, the original SNAP graph's counts
//! (paper Table I) next to the generated analogue's counts and the degree
//! statistics that justify the substitution (DESIGN.md §3).

use crate::graph::catalog::CatalogEntry;
use crate::graph::stats;
use crate::metrics::TablePrinter;
use crate::util::commas;
use crate::util::error::Result;
use std::path::Path;

/// One row of the reproduced Table I.
#[derive(Debug, Clone)]
pub struct Table1Row {
    /// Analogue name.
    pub name: String,
    /// Original graph name.
    pub stands_for: String,
    /// Paper's vertex/undirected-edge counts.
    pub original_vertices: u64,
    pub original_edges: u64,
    /// Analogue counts (directed edges / 2 = undirected).
    pub vertices: u64,
    pub directed_edges: u64,
    pub avg_degree: f64,
    pub max_degree: u64,
    pub gini: f64,
}

/// Generate (or load cached) analogues and collect rows.
pub fn collect(entries: &[CatalogEntry], cache_dir: &Path) -> Result<Vec<Table1Row>> {
    let mut rows = Vec::new();
    for e in entries {
        let g = e.load_or_generate(cache_dir)?;
        let s = stats::degree_stats(&g);
        rows.push(Table1Row {
            name: e.name.to_string(),
            stands_for: e.stands_for.to_string(),
            original_vertices: e.original_vertices,
            original_edges: e.original_edges,
            vertices: s.num_vertices as u64,
            directed_edges: s.num_directed_edges as u64,
            avg_degree: s.avg_out_degree,
            max_degree: s.max_out_degree as u64,
            gini: s.gini,
        });
    }
    Ok(rows)
}

/// Render the table in the paper's shape (plus analogue diagnostics).
pub fn render(rows: &[Table1Row]) -> String {
    let mut t = TablePrinter::new(&[
        "Graph",
        "paper |V|",
        "paper |E|",
        "analogue",
        "|V|",
        "directed |E|",
        "avg deg",
        "max deg",
        "gini",
    ]);
    for r in rows {
        t.row(vec![
            r.stands_for.clone(),
            commas(r.original_vertices),
            commas(r.original_edges),
            r.name.clone(),
            commas(r.vertices),
            commas(r.directed_edges),
            format!("{:.1}", r.avg_degree),
            commas(r.max_degree),
            format!("{:.2}", r.gini),
        ]);
    }
    t.render()
}

/// Full Table I run: collect + render.
pub fn run_table1(entries: &[CatalogEntry], cache_dir: &Path) -> Result<String> {
    Ok(render(&collect(entries, cache_dir)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::catalog;

    #[test]
    fn tiny_table1_renders_all_rows() {
        let dir = std::env::temp_dir().join(format!("ipregel_t1_{}", std::process::id()));
        let out = run_table1(&catalog::catalog_tiny(), &dir).unwrap();
        for name in ["DBLP", "LiveJournal", "Orkut", "Friendster"] {
            assert!(out.contains(name), "{out}");
        }
        assert!(out.contains("317,080"));
        assert!(out.contains("1,806,067,135"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
