//! Experiment harness: regenerate the paper's Table I and Table II.
//!
//! Table II is reproduced on the virtual testbed ([`crate::sim`]) at 32
//! virtual threads, on the catalog analogue graphs; every cell is a
//! speed-up over the benchmark's baseline configuration, printed next to
//! the paper's value. DESIGN.md §6 maps each row to the module that
//! implements it.

pub mod table1;
pub mod table2;

pub use table1::run_table1;
pub use table2::{run_table2, Bench, Table2Options};
