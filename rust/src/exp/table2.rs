//! Table II reproduction: per-optimisation speed-ups.
//!
//! For each benchmark (PR, CC, SSSP) and each catalog graph, run the
//! baseline iPregel configuration and every optimisation variant on the
//! virtual testbed, and report `t_baseline / t_variant`, next to the
//! paper's measured value. The variant grid mirrors §VII exactly:
//!
//! - PR, CC (pull, lock-free by design): externalised structure,
//!   edge-centric workload, dynamic scheduling, final = externalised +
//!   dynamic (no combiner; edge-centric excluded from "final" as
//!   incompatible with dynamic — paper §VII-B);
//! - SSSP (push): hybrid combiner, externalised, edge-centric, dynamic,
//!   final = hybrid + externalised + dynamic.

use crate::algos::{ConnectedComponents, PageRank, Sssp};
use crate::combine::Strategy;
use crate::engine::EngineConfig;
use crate::graph::csr::Csr;
use crate::layout::Layout;
use crate::metrics::TablePrinter;
use crate::sched::Schedule;
use crate::sim::SimEngine;
use crate::util::geomean;

/// The paper's three benchmarks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bench {
    /// PageRank, 10 iterations, pull single-broadcast.
    Pr,
    /// Connected Components, pull + selection bypass.
    Cc,
    /// Unweighted SSSP from the max-degree hub, push + selection bypass.
    Sssp,
}

impl Bench {
    /// All benchmarks in the paper's order.
    pub fn all() -> [Bench; 3] {
        [Bench::Pr, Bench::Cc, Bench::Sssp]
    }

    /// Table section header, as printed in the paper.
    pub fn title(self) -> &'static str {
        match self {
            Bench::Pr => "PR (10 iterations)",
            Bench::Cc => "CC",
            Bench::Sssp => "SSSP",
        }
    }

    /// Parse from CLI text.
    pub fn parse(s: &str) -> Option<Bench> {
        match s.to_ascii_lowercase().as_str() {
            "pr" | "pagerank" => Some(Bench::Pr),
            "cc" => Some(Bench::Cc),
            "sssp" => Some(Bench::Sssp),
            _ => None,
        }
    }

    /// The benchmark's baseline engine configuration (paper §VI-C: PR =
    /// plain single-broadcast; CC and SSSP = selection-bypass versions).
    pub fn base_cfg(self, threads: usize) -> EngineConfig {
        let cfg = EngineConfig::default()
            .threads(threads)
            .schedule(Schedule::Static)
            .layout(Layout::Interleaved)
            .strategy(Strategy::Lock);
        match self {
            Bench::Pr => cfg,
            Bench::Cc | Bench::Sssp => cfg.bypass(true),
        }
    }
}

/// One optimisation variant: a name, a config transform, and the paper's
/// measured speed-ups on (DBLP, LiveJournal, Orkut, Friendster).
pub struct Variant {
    /// Row label (paper wording).
    pub name: &'static str,
    /// Applies the optimisation(s) to the baseline config.
    pub apply: fn(EngineConfig) -> EngineConfig,
    /// Paper Table II values for the four graphs.
    pub paper: [f64; 4],
}

/// The paper's variant grid for one benchmark.
pub fn variants(bench: Bench) -> Vec<Variant> {
    let externalise: fn(EngineConfig) -> EngineConfig = |c| c.layout(Layout::Externalised);
    let edge: fn(EngineConfig) -> EngineConfig = |c| c.schedule(Schedule::EdgeCentric);
    let dynamic: fn(EngineConfig) -> EngineConfig =
        |c| c.schedule(Schedule::Dynamic { chunk: 256 });
    match bench {
        Bench::Pr => vec![
            Variant { name: "Externalised structure", apply: externalise, paper: [1.31, 1.27, 1.51, 1.13] },
            Variant { name: "Edge-centric workload", apply: edge, paper: [1.01, 2.31, 1.67, 1.36] },
            Variant { name: "Dynamic scheduling", apply: dynamic, paper: [1.23, 2.31, 1.99, 1.44] },
            Variant {
                name: "Final",
                apply: |c| c.layout(Layout::Externalised).schedule(Schedule::Dynamic { chunk: 256 }),
                paper: [1.61, 3.14, 3.07, 1.63],
            },
        ],
        Bench::Cc => vec![
            Variant { name: "Externalised structure", apply: externalise, paper: [1.58, 1.66, 1.47, 1.65] },
            Variant { name: "Edge-centric workload", apply: edge, paper: [0.56, 1.12, 1.27, 1.41] },
            Variant { name: "Dynamic scheduling", apply: dynamic, paper: [1.23, 1.67, 1.69, 1.20] },
            Variant {
                name: "Final",
                apply: |c| c.layout(Layout::Externalised).schedule(Schedule::Dynamic { chunk: 256 }),
                paper: [2.05, 2.96, 2.41, 2.12],
            },
        ],
        Bench::Sssp => vec![
            Variant {
                name: "Hybrid combiner",
                apply: |c| c.strategy(Strategy::Hybrid),
                paper: [1.01, 1.12, 2.35, 4.07],
            },
            Variant { name: "Externalised structure", apply: externalise, paper: [1.08, 1.01, 1.07, 1.10] },
            Variant { name: "Edge-centric workload", apply: edge, paper: [0.91, 0.87, 1.28, 1.29] },
            Variant { name: "Dynamic scheduling", apply: dynamic, paper: [1.11, 1.33, 1.55, 1.69] },
            Variant {
                name: "Final",
                apply: |c| {
                    c.strategy(Strategy::Hybrid)
                        .layout(Layout::Externalised)
                        .schedule(Schedule::Dynamic { chunk: 256 })
                },
                paper: [1.09, 1.75, 3.18, 5.63],
            },
        ],
    }
}

/// Options for a Table II run.
#[derive(Clone, Debug)]
pub struct Table2Options {
    /// Virtual thread count (paper: 32).
    pub threads: usize,
    /// Which benchmarks to run.
    pub benches: Vec<Bench>,
    /// Dynamic-scheduling chunk for graphs too small for 256 (tests).
    pub dynamic_chunk_override: Option<usize>,
}

impl Default for Table2Options {
    fn default() -> Self {
        Table2Options {
            threads: 32,
            benches: Bench::all().to_vec(),
            dynamic_chunk_override: None,
        }
    }
}

/// One variant row of results across the graph columns.
#[derive(Clone, Debug)]
pub struct VariantRow {
    pub name: String,
    /// Measured speed-up per graph.
    pub speedups: Vec<f64>,
    /// Paper's speed-up per graph (empty unless 4 catalog graphs).
    pub paper: Vec<f64>,
}

/// Results for one benchmark section.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub bench: Bench,
    /// Baseline virtual seconds per graph.
    pub baseline_secs: Vec<f64>,
    pub rows: Vec<VariantRow>,
}

fn sim_virtual_secs(bench: Bench, g: &Csr, cfg: EngineConfig) -> f64 {
    match bench {
        Bench::Pr => SimEngine::new(g, &PageRank::default(), cfg).run().virtual_seconds,
        Bench::Cc => SimEngine::new(g, &ConnectedComponents, cfg).run().virtual_seconds,
        Bench::Sssp => {
            let p = Sssp::from_hub(g);
            SimEngine::new(g, &p, cfg).run().virtual_seconds
        }
    }
}

fn override_chunk(cfg: EngineConfig, chunk: Option<usize>) -> EngineConfig {
    match (cfg.schedule, chunk) {
        (Schedule::Dynamic { .. }, Some(c)) => cfg.schedule(Schedule::Dynamic { chunk: c }),
        _ => cfg,
    }
}

/// Run Table II over `graphs` (name, graph) columns.
pub fn run_table2(graphs: &[(String, Csr)], opts: &Table2Options) -> Vec<BenchResult> {
    let paper_columns = graphs.len() == 4;
    opts.benches
        .iter()
        .map(|&bench| {
            let base = bench.base_cfg(opts.threads);
            let baseline_secs: Vec<f64> = graphs
                .iter()
                .map(|(_, g)| sim_virtual_secs(bench, g, base))
                .collect();
            let rows = variants(bench)
                .into_iter()
                .map(|v| {
                    let speedups = graphs
                        .iter()
                        .zip(&baseline_secs)
                        .map(|((_, g), &tb)| {
                            let cfg = override_chunk((v.apply)(base), opts.dynamic_chunk_override);
                            let tv = sim_virtual_secs(bench, g, cfg);
                            tb / tv
                        })
                        .collect();
                    VariantRow {
                        name: v.name.to_string(),
                        speedups,
                        paper: if paper_columns { v.paper.to_vec() } else { vec![] },
                    }
                })
                .collect();
            BenchResult {
                bench,
                baseline_secs,
                rows,
            }
        })
        .collect()
}

/// Render the paper-shaped table: `measured (paper)` per cell.
pub fn render(graphs: &[String], results: &[BenchResult]) -> String {
    let mut headers: Vec<&str> = vec![""];
    for g in graphs {
        headers.push(g);
    }
    let mut out = String::new();
    for r in results {
        let mut t = TablePrinter::new(&headers);
        out.push_str(&format!("\n== {} ==\n", r.bench.title()));
        let mut base_row = vec!["Baseline (virtual s)".to_string()];
        for secs in &r.baseline_secs {
            base_row.push(format!("{secs:.3}s"));
        }
        t.row(base_row);
        for row in &r.rows {
            let mut cells = vec![row.name.clone()];
            for (i, s) in row.speedups.iter().enumerate() {
                let cell = match row.paper.get(i) {
                    Some(p) => format!("{s:.2} (paper {p:.2})"),
                    None => format!("{s:.2}"),
                };
                cells.push(cell);
            }
            t.row(cells);
        }
        out.push_str(&t.render());
    }
    out
}

/// The paper's §VII aggregate claims, computed over our measured cells.
pub fn summary(results: &[BenchResult]) -> String {
    let mut individual: Vec<f64> = Vec::new();
    let mut finals: Vec<f64> = Vec::new();
    let mut hybrid: Vec<f64> = Vec::new();
    let mut extern_: Vec<f64> = Vec::new();
    let mut edge: Vec<f64> = Vec::new();
    let mut dynamic: Vec<f64> = Vec::new();
    for r in results {
        for row in &r.rows {
            let bucket: Option<&mut Vec<f64>> = match row.name.as_str() {
                "Hybrid combiner" => Some(&mut hybrid),
                "Externalised structure" => Some(&mut extern_),
                "Edge-centric workload" => Some(&mut edge),
                "Dynamic scheduling" => Some(&mut dynamic),
                "Final" => {
                    finals.extend(&row.speedups);
                    None
                }
                _ => None,
            };
            if let Some(b) = bucket {
                b.extend(&row.speedups);
                individual.extend(&row.speedups);
            }
        }
    }
    let wins = individual.iter().filter(|&&s| s > 1.0).count();
    let cut: Vec<f64> = finals.iter().map(|s| (1.0 - 1.0 / s) * 100.0).collect();
    let mean_cut = cut.iter().sum::<f64>() / cut.len().max(1) as f64;
    let min_cut = cut.iter().copied().fold(f64::INFINITY, f64::min);
    let max_cut = cut.iter().copied().fold(0.0, f64::max);
    format!(
        "summary vs paper §VII:\n\
         \u{20} hybrid combiner geomean   {:>5.2}  (paper 1.81)\n\
         \u{20} externalisation geomean   {:>5.2}  (paper 1.30)\n\
         \u{20} edge-centric geomean      {:>5.2}  (paper 1.19)\n\
         \u{20} dynamic geomean           {:>5.2}  (paper 1.50)\n\
         \u{20} individual wins           {:>2}/{:<2} (paper 37/40)\n\
         \u{20} final runtime cut mean    {:>5.1}% (paper 59%)\n\
         \u{20} final runtime cut range   {:>4.1}%..{:>4.1}% (paper 8%..82%)",
        geomean(&hybrid),
        geomean(&extern_),
        geomean(&edge),
        geomean(&dynamic),
        wins,
        individual.len(),
        mean_cut,
        min_cut,
        max_cut,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn tiny_graphs() -> Vec<(String, Csr)> {
        vec![
            ("g1".into(), gen::barabasi_albert(1200, 3, 1)),
            ("g2".into(), gen::rmat(11, 8, 0.57, 0.19, 0.19, 2)),
        ]
    }

    #[test]
    fn table2_structure_is_paper_shaped() {
        let graphs = tiny_graphs();
        let opts = Table2Options {
            threads: 32,
            benches: vec![Bench::Pr, Bench::Sssp],
            dynamic_chunk_override: Some(16),
        };
        let results = run_table2(&graphs, &opts);
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].rows.len(), 4); // PR: extern, edge, dyn, final
        assert_eq!(results[1].rows.len(), 5); // SSSP: + hybrid
        for r in &results {
            for row in &r.rows {
                assert_eq!(row.speedups.len(), graphs.len());
                for &s in &row.speedups {
                    assert!(s.is_finite() && s > 0.0);
                }
            }
        }
        let names: Vec<String> = graphs.iter().map(|(n, _)| n.clone()).collect();
        let rendered = render(&names, &results);
        assert!(rendered.contains("PR (10 iterations)"));
        assert!(rendered.contains("SSSP"));
        assert!(rendered.contains("Final"));
    }

    #[test]
    fn sssp_hybrid_speedup_positive_on_skewed_graph() {
        let g = gen::rmat(12, 16, 0.57, 0.19, 0.19, 5);
        let opts = Table2Options {
            threads: 32,
            benches: vec![Bench::Sssp],
            dynamic_chunk_override: Some(32),
        };
        let results = run_table2(&[("rmat".into(), g)], &opts);
        let hybrid = &results[0].rows[0];
        assert_eq!(hybrid.name, "Hybrid combiner");
        assert!(
            hybrid.speedups[0] > 1.0,
            "hybrid speedup {}",
            hybrid.speedups[0]
        );
        // Final composes hybrid + extern + dynamic: at least as good as
        // hybrid alone on this workload.
        let final_ = results[0].rows.last().unwrap();
        assert!(final_.speedups[0] > hybrid.speedups[0] * 0.8);
    }

    #[test]
    fn summary_renders_paper_aggregates() {
        let graphs = tiny_graphs();
        let opts = Table2Options {
            threads: 32,
            benches: Bench::all().to_vec(),
            dynamic_chunk_override: Some(16),
        };
        let results = run_table2(&graphs, &opts);
        let s = summary(&results);
        assert!(s.contains("paper 1.81"));
        assert!(s.contains("individual wins"));
    }

    #[test]
    fn bench_parse() {
        assert_eq!(Bench::parse("pr"), Some(Bench::Pr));
        assert_eq!(Bench::parse("PageRank"), Some(Bench::Pr));
        assert_eq!(Bench::parse("cc"), Some(Bench::Cc));
        assert_eq!(Bench::parse("sssp"), Some(Bench::Sssp));
        assert_eq!(Bench::parse("nope"), None);
    }
}
