//! The atomic-ordering manifest (`rust/audit/orderings.toml`).
//!
//! Every atomic `Ordering` use in the tree must be covered by a manifest
//! entry naming the file, the enclosing symbol, the orderings that
//! symbol is allowed to use, and a one-line rationale. The audit fails
//! on any use outside the manifest — adding or strengthening an ordering
//! is a reviewed, documented act, never a drive-by.
//!
//! The format is the `[[site]]` array-of-tables subset of TOML, parsed
//! in-tree (the build is offline and dependency-free):
//!
//! ```toml
//! [[site]]
//! file = "src/combine/slot.rs"
//! symbol = "store_first"
//! orderings = ["SeqCst"]
//! why = "store msg then flag: a true flag must imply a visible message"
//! ```

use std::collections::HashMap;

/// One `[[site]]` entry.
#[derive(Debug, Clone, Default)]
pub struct Site {
    /// Crate-relative path, e.g. `src/combine/slot.rs`.
    pub file: String,
    /// Enclosing `fn` name (or `*` to cover a whole file).
    pub symbol: String,
    /// Allowed ordering variant names.
    pub orderings: Vec<String>,
    /// One-line rationale.
    pub why: String,
    /// 1-based line in the manifest (diagnostics).
    pub line: usize,
}

/// Parsed manifest with a by-(file, symbol) index.
#[derive(Debug, Default)]
pub struct Manifest {
    pub sites: Vec<Site>,
}

impl Manifest {
    /// Parse the manifest text. Errors carry 1-based line numbers.
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let mut sites: Vec<Site> = Vec::new();
        let mut cur: Option<Site> = None;
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if line == "[[site]]" {
                if let Some(s) = cur.take() {
                    Self::finish(s, &mut sites)?;
                }
                cur = Some(Site {
                    line: lineno,
                    ..Site::default()
                });
                continue;
            }
            let Some(eq) = line.find('=') else {
                return Err(format!("manifest line {lineno}: expected `key = value`"));
            };
            let key = line[..eq].trim();
            let val = line[eq + 1..].trim();
            let site = cur
                .as_mut()
                .ok_or_else(|| format!("manifest line {lineno}: `{key}` outside a [[site]]"))?;
            match key {
                "file" => site.file = parse_str(val, lineno)?,
                "symbol" => site.symbol = parse_str(val, lineno)?,
                "why" => site.why = parse_str(val, lineno)?,
                "orderings" => site.orderings = parse_str_array(val, lineno)?,
                other => {
                    return Err(format!("manifest line {lineno}: unknown key `{other}`"));
                }
            }
        }
        if let Some(s) = cur.take() {
            Self::finish(s, &mut sites)?;
        }
        Ok(Manifest { sites })
    }

    fn finish(s: Site, sites: &mut Vec<Site>) -> Result<(), String> {
        if s.file.is_empty() || s.symbol.is_empty() || s.orderings.is_empty() || s.why.is_empty() {
            return Err(format!(
                "manifest [[site]] at line {}: `file`, `symbol`, `orderings` and `why` \
                 are all required",
                s.line
            ));
        }
        sites.push(s);
        Ok(())
    }

    /// Allowed orderings for (`file`, `symbol`), merging exact-symbol and
    /// whole-file (`symbol = "*"`) entries. `None` when uncovered.
    pub fn allowed(&self, file: &str, symbol: &str) -> Option<Vec<&str>> {
        let mut found = false;
        let mut allowed: Vec<&str> = Vec::new();
        for s in &self.sites {
            if s.file == file && (s.symbol == symbol || s.symbol == "*") {
                found = true;
                allowed.extend(s.orderings.iter().map(|o| o.as_str()));
            }
        }
        found.then_some(allowed)
    }

    /// Index of sites that matched nothing during a run (stale entries).
    pub fn coverage_tracker(&self) -> CoverageTracker {
        CoverageTracker {
            used: vec![false; self.sites.len()],
        }
    }

    /// Mark every site matching (`file`, `symbol`) as used.
    pub fn mark_used(&self, tracker: &mut CoverageTracker, file: &str, symbol: &str) {
        for (i, s) in self.sites.iter().enumerate() {
            if s.file == file && (s.symbol == symbol || s.symbol == "*") {
                tracker.used[i] = true;
            }
        }
    }

    /// Group sites per file (used by the CLI summary).
    pub fn per_file_counts(&self) -> HashMap<&str, usize> {
        let mut m: HashMap<&str, usize> = HashMap::new();
        for s in &self.sites {
            *m.entry(s.file.as_str()).or_insert(0) += 1;
        }
        m
    }
}

/// Which manifest sites were matched by at least one scanned use.
pub struct CoverageTracker {
    used: Vec<bool>,
}

impl CoverageTracker {
    /// Sites never matched (candidates for deletion).
    pub fn unused<'m>(&self, m: &'m Manifest) -> Vec<&'m Site> {
        m.sites
            .iter()
            .enumerate()
            .filter(|(i, _)| !self.used[*i])
            .map(|(_, s)| s)
            .collect()
    }
}

fn parse_str(val: &str, lineno: usize) -> Result<String, String> {
    let v = val.trim();
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        Ok(v[1..v.len() - 1].to_string())
    } else {
        Err(format!("manifest line {lineno}: expected a quoted string, got `{v}`"))
    }
}

fn parse_str_array(val: &str, lineno: usize) -> Result<Vec<String>, String> {
    let v = val.trim();
    if !(v.starts_with('[') && v.ends_with(']')) {
        return Err(format!("manifest line {lineno}: expected `[ … ]`, got `{v}`"));
    }
    let inner = &v[1..v.len() - 1];
    let mut out = Vec::new();
    for part in inner.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        out.push(parse_str(p, lineno)?);
    }
    if out.is_empty() {
        return Err(format!("manifest line {lineno}: empty orderings array"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# comment
[[site]]
file = "src/a.rs"
symbol = "store"
orderings = ["SeqCst", "Release"]
why = "publication"

[[site]]
file = "src/a.rs"
symbol = "*"
orderings = ["Relaxed"]
why = "whole-file fallback"
"#;

    #[test]
    fn parses_sites_and_merges_wildcards() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.sites.len(), 2);
        let a = m.allowed("src/a.rs", "store").unwrap();
        assert!(a.contains(&"SeqCst") && a.contains(&"Release") && a.contains(&"Relaxed"));
        let b = m.allowed("src/a.rs", "other_fn").unwrap();
        assert_eq!(b, vec!["Relaxed"]);
        assert!(m.allowed("src/b.rs", "store").is_none());
    }

    #[test]
    fn coverage_tracks_unused_entries() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let mut t = m.coverage_tracker();
        m.mark_used(&mut t, "src/a.rs", "store");
        let unused = t.unused(&m);
        assert_eq!(unused.len(), 1);
        assert_eq!(unused[0].symbol, "*");
    }

    #[test]
    fn missing_fields_are_rejected() {
        let bad = "[[site]]\nfile = \"src/a.rs\"\nsymbol = \"f\"\nwhy = \"w\"\n";
        assert!(Manifest::parse(bad).is_err());
        let worse = "file = \"src/a.rs\"\n";
        assert!(Manifest::parse(worse).is_err());
        assert!(Manifest::parse("[[site]]\nfile = oops\n").is_err());
    }
}
