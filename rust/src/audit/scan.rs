//! Comment/string-aware Rust source scanning for `pallas-audit`.
//!
//! The analyzer's rules operate on *code text* with comments and string
//! literals separated out — `unsafe` inside a doc string must not count
//! as an unsafe site, and a `SAFETY:` justification must only count when
//! it appears in a real comment. Full parsing is out of scope (and out
//! of budget — the build is dependency-free); instead this module runs a
//! small state machine good enough for the repository's own idioms:
//!
//! - line (`//`) and nested block (`/* */`) comments, captured per line;
//! - plain, raw (`r#"…"#`) and byte string literals, blanked out;
//! - char literals vs. lifetimes (`'a'` vs. `'static`), by lookahead;
//! - per-line brace depth, enclosing `fn` name and `#[cfg(test)] mod`
//!   membership, tracked by [`annotate`].

/// One physical source line, split into code and comment text. String
/// and char literal *contents* are blanked from `code` (delimiters kept)
/// so rule patterns never match inside literals.
#[derive(Debug, Default, Clone)]
pub struct Line {
    /// Code text with literal contents blanked.
    pub code: String,
    /// Comment text (both `//…` and the parts of `/*…*/` on this line).
    pub comment: String,
}

/// A [`Line`] plus structural context assigned by [`annotate`].
#[derive(Debug, Clone)]
pub struct CtxLine {
    pub line: Line,
    /// Name of the innermost enclosing `fn`, if any.
    pub in_fn: Option<String>,
    /// Inside a `#[cfg(test)] mod … { }` body.
    pub in_test_mod: bool,
}

#[derive(Clone, Copy, PartialEq)]
enum State {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
    Char,
}

/// Split `source` into per-line code/comment text.
pub fn strip(source: &str) -> Vec<Line> {
    let mut lines: Vec<Line> = Vec::new();
    let mut cur = Line::default();
    let mut st = State::Code;
    let b: Vec<char> = source.chars().collect();
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            // Line comments end at the newline; other states span lines.
            if st == State::LineComment {
                st = State::Code;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match st {
            State::Code => {
                let next = b.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = State::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = State::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    // Raw-string heads were consumed below, so a bare
                    // quote is always a plain string start.
                    cur.code.push('"');
                    st = State::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_is_ident(&b, i) {
                    // Possible literal head: r"…", r#"…"#, br"…", b"…".
                    let raw_from = match c {
                        'r' => Some(i + 1),
                        _ if b.get(i + 1) == Some(&'r') => Some(i + 2),
                        _ => None,
                    };
                    let raw = raw_from.and_then(|mut j| {
                        let mut hashes = 0u32;
                        while b.get(j) == Some(&'#') {
                            hashes += 1;
                            j += 1;
                        }
                        (b.get(j) == Some(&'"')).then_some((j, hashes))
                    });
                    if let Some((open, hashes)) = raw {
                        cur.code.push('"');
                        st = State::RawStr(hashes);
                        i = open + 1;
                    } else if c == 'b' && b.get(i + 1) == Some(&'"') {
                        // b"…" plain byte string
                        cur.code.push(c);
                        cur.code.push('"');
                        st = State::Str;
                        i += 2;
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal or lifetime? `'\…` and `'x'` are
                    // literals; anything else (e.g. `'static`) is a
                    // lifetime and stays plain code.
                    if next == Some('\\') || (b.get(i + 2) == Some(&'\'') && next != Some('\'')) {
                        cur.code.push('\'');
                        st = State::Char;
                        i += 1;
                    } else {
                        cur.code.push('\'');
                        i += 1;
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                let next = b.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    st = State::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    st = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    // Skip the escaped char — but a line-continuation
                    // (`\` + newline) still ends the physical line, or
                    // every later line number would be off by one.
                    if b.get(i + 1) == Some(&'\n') {
                        lines.push(std::mem::take(&mut cur));
                    }
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    st = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' {
                    // Closing needs `"` followed by `hashes` hashes.
                    let mut ok = true;
                    for k in 0..hashes as usize {
                        if b.get(i + 1 + k) != Some(&'#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        cur.code.push('"');
                        st = State::Code;
                        i += 1 + hashes as usize;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
            State::Char => {
                if c == '\\' {
                    if b.get(i + 1) == Some(&'\n') {
                        lines.push(std::mem::take(&mut cur));
                    }
                    i += 2;
                } else if c == '\'' {
                    cur.code.push('\'');
                    st = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

/// Is the char before `b[i]` part of an identifier (so `b[i]` cannot
/// start a literal prefix like `r"…"`)?
fn prev_is_ident(b: &[char], i: usize) -> bool {
    i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_')
}

/// Is `text[at]` the start of the standalone word `word`?
fn word_at(text: &str, at: usize, word: &str) -> bool {
    if !text[at..].starts_with(word) {
        return false;
    }
    let before_ok = at == 0
        || !text[..at]
            .chars()
            .next_back()
            .is_some_and(|c| c.is_alphanumeric() || c == '_');
    let after = text[at + word.len()..].chars().next();
    before_ok && !after.is_some_and(|c| c.is_alphanumeric() || c == '_')
}

/// Find the standalone word `word` in `text`.
pub fn find_word(text: &str, word: &str) -> Option<usize> {
    let mut from = 0;
    while let Some(pos) = text[from..].find(word) {
        let at = from + pos;
        if word_at(text, at, word) {
            return Some(at);
        }
        from = at + 1;
    }
    None
}

/// Annotate stripped lines with enclosing-`fn` and test-mod context.
pub fn annotate(lines: Vec<Line>) -> Vec<CtxLine> {
    let mut out: Vec<CtxLine> = Vec::with_capacity(lines.len());
    // Stack of (depth_after_open, fn_name) for enclosing functions, and
    // the depths at which `#[cfg(test)] mod` bodies opened.
    let mut fn_stack: Vec<(i32, String)> = Vec::new();
    let mut test_depths: Vec<i32> = Vec::new();
    let mut depth: i32 = 0;
    // `fn name` seen, waiting for its `{` (or cancelled by `;`).
    let mut pending_fn: Option<String> = None;
    // `#[cfg(test)]` seen, arming the next `mod … {`.
    let mut pending_test_attr = false;
    let mut pending_test_mod = false;

    for line in lines {
        let code = line.code.clone();
        if code.contains("#[cfg(test)]") {
            pending_test_attr = true;
        }
        // Detect `fn <name>` declarations (not `Fn(` bounds / `fn(`
        // pointer types — those are never followed by an identifier).
        let mut from = 0;
        while let Some(pos) = code[from..].find("fn") {
            let at = from + pos;
            from = at + 1;
            if !word_at(&code, at, "fn") {
                continue;
            }
            let rest = code[at + 2..].trim_start();
            let name: String = rest
                .chars()
                .take_while(|&c| c.is_alphanumeric() || c == '_')
                .collect();
            if !name.is_empty() {
                pending_fn = Some(name);
                break;
            }
        }
        if pending_test_attr {
            if let Some(at) = find_word(&code, "mod") {
                let rest = code[at + 3..].trim_start();
                if rest.chars().next().is_some_and(|c| c.is_alphabetic() || c == '_') {
                    pending_test_mod = true;
                    pending_test_attr = false;
                }
            }
        }
        let in_fn = fn_stack.last().map(|(_, n)| n.clone()).or_else(|| {
            // A signature spanning lines attributes its own lines to the
            // declared fn as well.
            pending_fn.clone()
        });
        let in_test = !test_depths.is_empty() || pending_test_mod;
        out.push(CtxLine {
            line,
            in_fn,
            in_test_mod: in_test,
        });
        // Brace accounting after emitting the line's context.
        for c in code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    if let Some(name) = pending_fn.take() {
                        fn_stack.push((depth, name));
                    }
                    if pending_test_mod {
                        test_depths.push(depth);
                        pending_test_mod = false;
                    }
                }
                '}' => {
                    while fn_stack.last().is_some_and(|(d, _)| *d >= depth) {
                        fn_stack.pop();
                    }
                    while test_depths.last().is_some_and(|d| *d >= depth) {
                        test_depths.pop();
                    }
                    depth -= 1;
                }
                ';' => {
                    // Trait method declarations carry no body.
                    if pending_fn.is_some() {
                        pending_fn = None;
                    }
                }
                _ => {}
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_line_and_block_comments() {
        let src = "let a = 1; // trailing note\n/* block\nspans lines */ let b = 2;\n";
        let ls = strip(src);
        assert_eq!(ls.len(), 3);
        assert!(ls[0].code.contains("let a = 1;"));
        assert!(ls[0].comment.contains("trailing note"));
        assert!(!ls[0].code.contains("trailing"));
        assert!(ls[1].comment.contains("block"));
        assert!(ls[2].code.contains("let b = 2;"));
    }

    #[test]
    fn blanks_string_contents_including_raw_strings() {
        let src = "let s = \"unsafe { }\"; let r = r#\"static mut X\"#; let t = 'x';\n";
        let ls = strip(src);
        assert!(!ls[0].code.contains("unsafe"));
        assert!(!ls[0].code.contains("static mut"));
        assert!(ls[0].code.contains("let s ="));
        assert!(ls[0].code.contains("let r ="));
    }

    #[test]
    fn lifetimes_are_not_chars() {
        let src = "fn f<'a>(x: &'a str) -> &'static str { x }\n";
        let ls = strip(src);
        assert!(ls[0].code.contains("'static str"), "{:?}", ls[0].code);
    }

    #[test]
    fn escaped_quote_in_string() {
        let src = "let s = \"a\\\"unsafe\"; let x = 1;\n";
        let ls = strip(src);
        assert!(!ls[0].code.contains("unsafe"));
        assert!(ls[0].code.contains("let x = 1;"));
    }

    #[test]
    fn line_continuation_in_string_keeps_line_numbers() {
        // `"\` at end of line escapes the newline *inside the literal*,
        // but the physical line still ends — diagnostics on later lines
        // must not shift (regression: the escape skip used to swallow
        // the newline entirely).
        let src = "let s = \"a\\\nb\";\nlet t = 2;\n";
        let ls = strip(src);
        assert_eq!(ls.len(), 3);
        assert!(ls[2].code.contains("let t = 2;"));
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner */ still comment */ let z = 3;\n";
        let ls = strip(src);
        assert!(ls[0].code.contains("let z = 3;"));
        assert!(ls[0].comment.contains("outer"));
    }

    #[test]
    fn annotates_enclosing_fn_and_test_mods() {
        let src = "\
fn alpha() {\n\
    let x = 1;\n\
}\n\
#[cfg(test)]\n\
mod tests {\n\
    fn beta() {\n\
        let y = 2;\n\
    }\n\
}\n\
fn gamma() {}\n";
        let ls = annotate(strip(src));
        assert_eq!(ls[1].in_fn.as_deref(), Some("alpha"));
        assert!(!ls[1].in_test_mod);
        assert_eq!(ls[6].in_fn.as_deref(), Some("beta"));
        assert!(ls[6].in_test_mod);
        assert_eq!(ls[9].in_fn.as_deref(), Some("gamma"));
        assert!(!ls[9].in_test_mod);
    }

    #[test]
    fn fn_pointer_types_are_not_declarations() {
        let src = "fn outer(cb: fn(usize) -> u64, f: impl Fn(u32)) {\n    let q = 1;\n}\n";
        let ls = annotate(strip(src));
        // The *first* `fn` wins as the declaration; the type positions
        // must not override it.
        assert_eq!(ls[1].in_fn.as_deref(), Some("outer"));
    }

    #[test]
    fn word_find_respects_boundaries() {
        assert!(find_word("static mut X", "static").is_some());
        assert!(find_word("thread_static mut", "static").is_none());
        assert!(find_word("statically", "static").is_none());
    }
}
