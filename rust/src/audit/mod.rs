//! `pallas-audit` — the repository's own concurrency-correctness
//! static analyzer (`ipregel audit`, gated in CI).
//!
//! The hybrid combiner couples lock-free and lock-based combination; one
//! wrong atomic ordering or unjustified `unsafe` silently corrupts
//! results instead of crashing. This module walks the crate's own source
//! (zero dependencies — the scanner is in [`scan`], the ordering
//! manifest in [`manifest`]) and enforces four declared invariants:
//!
//! 1. **`unsafe` needs `SAFETY:`** — every `unsafe` block/impl/fn must
//!    be preceded by (or carry) a comment containing `SAFETY:` stating
//!    why it is sound.
//! 2. **atomic orderings are manifested** — every `Ordering::…` use
//!    must be covered by `rust/audit/orderings.toml`, which names the
//!    file, enclosing symbol, allowed orderings and a one-line
//!    rationale. An ordering the manifest doesn't allow is a violation;
//!    a manifest entry nothing uses is a warning (stale).
//! 3. **no `static mut`** — mutable statics are banned outright.
//! 4. **no `unwrap()/expect()` in engine/combine hot paths** — the
//!    scatter/deliver/collect paths must not panic per-message; the
//!    escape hatch is an `// audit:allow(panic): why` comment for
//!    phase-level invariants.
//!
//! Diagnostics print as `file:line: rule: message` and the CLI exits
//! non-zero on any violation.

pub mod manifest;
pub mod scan;

use manifest::{CoverageTracker, Manifest};
use scan::CtxLine;
use std::fmt;
use std::path::{Path, PathBuf};

/// The atomic ordering variants rule 2 tracks (`cmp::Ordering`'s
/// variants deliberately excluded).
const ATOMIC_ORDERINGS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// Files subject to the no-panic rule (rule 4): the per-message scatter,
/// deliver and collect paths plus the substrate they run on, the
/// serving-loop policy arithmetic that must never unwind mid-slice, and
/// the row-storage plane whose `row()` accessor sits under every edge
/// iteration.
const PANIC_DENY: [&str; 16] = [
    "src/serve/sched.rs",
    "src/graph/rows.rs",
    "src/engine/core.rs",
    "src/engine/shard.rs",
    "src/combine/strategy.rs",
    "src/combine/slot.rs",
    "src/combine/spinlock.rs",
    "src/combine/plane.rs",
    "src/combine/combiner.rs",
    "src/combine/vector.rs",
    "src/layout/aos.rs",
    "src/layout/soa.rs",
    "src/layout/store.rs",
    "src/sched/pool.rs",
    "src/sched/steal.rs",
    "src/trace/buf.rs",
];

/// Which invariant a diagnostic belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AuditRule {
    UnsafeNeedsSafety,
    UnlistedOrdering,
    StaticMut,
    PanicInHotPath,
    StaleManifestEntry,
}

impl AuditRule {
    /// Stable rule id used in diagnostics and asserted by tests.
    pub fn id(self) -> &'static str {
        match self {
            AuditRule::UnsafeNeedsSafety => "unsafe-needs-safety",
            AuditRule::UnlistedOrdering => "unlisted-ordering",
            AuditRule::StaticMut => "static-mut",
            AuditRule::PanicInHotPath => "panic-in-hot-path",
            AuditRule::StaleManifestEntry => "stale-manifest-entry",
        }
    }
}

/// One finding, printed as `file:line: rule: message`.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub file: String,
    pub line: usize,
    pub rule: AuditRule,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: {}: {}",
            self.file,
            self.line,
            self.rule.id(),
            self.message
        )
    }
}

/// The audit's outcome over a tree (or a set of in-memory sources).
#[derive(Debug, Default)]
pub struct AuditReport {
    /// Hard failures (exit non-zero).
    pub violations: Vec<Diagnostic>,
    /// Advisories (stale manifest entries); never fail the run.
    pub warnings: Vec<Diagnostic>,
    pub files_scanned: usize,
    pub unsafe_sites: usize,
    pub ordering_uses: usize,
}

impl AuditReport {
    /// True when the tree satisfies every invariant.
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    /// Human-readable summary (diagnostics first, totals last).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.violations {
            out.push_str(&format!("{d}\n"));
        }
        for d in &self.warnings {
            out.push_str(&format!("warning: {d}\n"));
        }
        out.push_str(&format!(
            "pallas-audit: {} files, {} unsafe sites, {} ordering uses — {} violation(s), \
             {} warning(s)\n",
            self.files_scanned,
            self.unsafe_sites,
            self.ordering_uses,
            self.violations.len(),
            self.warnings.len(),
        ));
        out
    }
}

/// Audit a set of `(relative_path, source_text)` pairs against a parsed
/// manifest. This is the engine behind both the CLI (which reads the
/// tree from disk) and the fixture tests (which feed snippets).
pub fn audit_sources(sources: &[(String, String)], manifest: &Manifest) -> AuditReport {
    let mut report = AuditReport {
        files_scanned: sources.len(),
        ..AuditReport::default()
    };
    let mut tracker = manifest.coverage_tracker();
    for (rel, text) in sources {
        audit_one(rel, text, manifest, &mut tracker, &mut report);
    }
    for stale in tracker.unused(manifest) {
        report.warnings.push(Diagnostic {
            file: "audit/orderings.toml".to_string(),
            line: stale.line,
            rule: AuditRule::StaleManifestEntry,
            message: format!(
                "manifest entry {}:{} matched no ordering use — delete it?",
                stale.file, stale.symbol
            ),
        });
    }
    report
}

fn audit_one(
    rel: &str,
    text: &str,
    manifest: &Manifest,
    tracker: &mut CoverageTracker,
    report: &mut AuditReport,
) {
    let lines = scan::annotate(scan::strip(text));
    let in_tests_dir = rel.starts_with("tests/") || rel.starts_with("benches/");
    let panic_ruled = PANIC_DENY.contains(&rel);
    for (idx, ctx) in lines.iter().enumerate() {
        let lineno = idx + 1;
        let code = ctx.line.code.as_str();

        // Rule 1: unsafe needs a SAFETY: justification.
        if scan::find_word(code, "unsafe").is_some() {
            report.unsafe_sites += 1;
            if !comment_justified(&lines, idx, "SAFETY:") {
                report.violations.push(Diagnostic {
                    file: rel.to_string(),
                    line: lineno,
                    rule: AuditRule::UnsafeNeedsSafety,
                    message: "`unsafe` without a `// SAFETY:` justification on or above it"
                        .to_string(),
                });
            }
        }

        // Rule 2: every atomic ordering use is in the manifest.
        let mut from = 0;
        while let Some(pos) = code[from..].find("Ordering::") {
            let at = from + pos;
            from = at + "Ordering::".len();
            let rest = &code[from..];
            let variant: String = rest
                .chars()
                .take_while(|&c| c.is_alphanumeric() || c == '_')
                .collect();
            if !ATOMIC_ORDERINGS.contains(&variant.as_str()) {
                continue; // cmp::Ordering or something else entirely
            }
            report.ordering_uses += 1;
            let symbol = ctx.in_fn.clone().unwrap_or_else(|| "(top-level)".to_string());
            manifest.mark_used(tracker, rel, &symbol);
            let allowed = manifest.allowed(rel, &symbol);
            let permitted = allowed
                .as_ref()
                .is_some_and(|a| a.iter().any(|o| *o == variant));
            if !permitted {
                let detail = match allowed {
                    Some(a) => format!("manifest allows only {:?} here", a),
                    None => "no manifest entry covers this site".to_string(),
                };
                report.violations.push(Diagnostic {
                    file: rel.to_string(),
                    line: lineno,
                    rule: AuditRule::UnlistedOrdering,
                    message: format!(
                        "`Ordering::{variant}` in `{symbol}` is not sanctioned — {detail} \
                         (add a [[site]] with a rationale to audit/orderings.toml)"
                    ),
                });
            }
        }

        // Rule 3: no mutable statics, anywhere, ever.
        if let Some(at) = scan::find_word(code, "static") {
            let rest = code[at + "static".len()..].trim_start();
            if rest.starts_with("mut")
                && !rest["mut".len()..]
                    .chars()
                    .next()
                    .is_some_and(|c| c.is_alphanumeric() || c == '_')
            {
                report.violations.push(Diagnostic {
                    file: rel.to_string(),
                    line: lineno,
                    rule: AuditRule::StaticMut,
                    message: "`static mut` is banned — use an atomic or interior \
                              mutability with a documented discipline"
                        .to_string(),
                });
            }
        }

        // Rule 4: no per-message panics in the hot paths.
        if panic_ruled && !ctx.in_test_mod && !in_tests_dir {
            let hit = if code.contains(".unwrap()") {
                Some("unwrap()")
            } else if code.contains(".expect(") {
                Some("expect(…)")
            } else {
                None
            };
            if let Some(what) = hit {
                if !comment_justified(&lines, idx, "audit:allow(panic)") {
                    report.violations.push(Diagnostic {
                        file: rel.to_string(),
                        line: lineno,
                        rule: AuditRule::PanicInHotPath,
                        message: format!(
                            "`{what}` in an engine/combine hot path — return an error, \
                             or annotate a phase-level invariant with \
                             `// audit:allow(panic): why`"
                        ),
                    });
                }
            }
        }
    }
}

/// Does line `idx` carry `needle` in its own comment, or in the block of
/// comment-only lines immediately above it?
fn comment_justified(lines: &[CtxLine], idx: usize, needle: &str) -> bool {
    if lines[idx].line.comment.contains(needle) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let l = &lines[j].line;
        if !l.code.trim().is_empty() {
            return false; // real code interrupts the comment block
        }
        if l.comment.contains(needle) {
            return true;
        }
        if l.comment.is_empty() {
            return false; // blank line ends the block
        }
    }
    false
}

/// Walk `root` (the crate directory) and audit `src/`, `tests/` and
/// `benches/` against the manifest at `manifest_path`.
pub fn audit_tree(root: &Path, manifest_path: &Path) -> Result<AuditReport, String> {
    let manifest_text = std::fs::read_to_string(manifest_path)
        .map_err(|e| format!("reading {}: {e}", manifest_path.display()))?;
    let manifest = Manifest::parse(&manifest_text)?;
    let mut sources: Vec<(String, String)> = Vec::new();
    for sub in ["src", "tests", "benches"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, root, &mut sources)?;
        }
    }
    if sources.is_empty() {
        return Err(format!(
            "no .rs files under {} — is this the crate root?",
            root.display()
        ));
    }
    sources.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(audit_sources(&sources, &manifest))
}

fn collect_rs(
    dir: &Path,
    root: &Path,
    out: &mut Vec<(String, String)>,
) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("reading {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("reading {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, root, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .map_err(|e| e.to_string())?
                .to_string_lossy()
                .replace('\\', "/");
            let text = std::fs::read_to_string(&path)
                .map_err(|e| format!("reading {}: {e}", path.display()))?;
            out.push((rel, text));
        }
    }
    Ok(())
}

/// Locate the crate root from an invocation directory: accepts either
/// the repository root (which holds `rust/`) or the crate dir itself.
pub fn resolve_root(given: Option<&str>) -> PathBuf {
    let base = PathBuf::from(given.unwrap_or("."));
    if base.join("src").is_dir() && base.join("audit").is_dir() {
        return base;
    }
    let nested = base.join("rust");
    if nested.join("src").is_dir() {
        return nested;
    }
    base
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_for(entries: &str) -> Manifest {
        Manifest::parse(entries).unwrap()
    }

    fn run_on(rel: &str, src: &str, manifest: &Manifest) -> AuditReport {
        audit_sources(&[(rel.to_string(), src.to_string())], manifest)
    }

    #[test]
    fn clean_source_passes() {
        let m = manifest_for("");
        let r = run_on("src/x.rs", "fn f() { let a = 1; }\n", &m);
        assert!(r.ok(), "{}", r.render());
    }

    #[test]
    fn unsafe_without_safety_is_flagged_and_with_safety_passes() {
        let m = manifest_for("");
        let bad = "fn f() {\n    unsafe { core(); }\n}\n";
        let r = run_on("src/x.rs", bad, &m);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].rule, AuditRule::UnsafeNeedsSafety);
        assert_eq!(r.violations[0].line, 2);

        let good = "fn f() {\n    // SAFETY: single-threaded here.\n    unsafe { core(); }\n}\n";
        assert!(run_on("src/x.rs", good, &m).ok());
    }

    #[test]
    fn ordering_must_be_manifested() {
        let m = manifest_for(
            "[[site]]\nfile = \"src/x.rs\"\nsymbol = \"f\"\norderings = [\"SeqCst\"]\n\
             why = \"publication\"\n",
        );
        let ok = "fn f() { a.store(1, Ordering::SeqCst); }\n";
        assert!(run_on("src/x.rs", ok, &m).ok());
        let bad = "fn f() { a.store(1, Ordering::Relaxed); }\n";
        let r = run_on("src/x.rs", bad, &m);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].rule, AuditRule::UnlistedOrdering);
    }

    #[test]
    fn cmp_ordering_is_ignored() {
        let m = manifest_for("");
        let src = "fn f() { if c == std::cmp::Ordering::Less { g(); } }\n";
        let r = run_on("src/x.rs", src, &m);
        assert!(r.ok());
        assert_eq!(r.ordering_uses, 0);
    }

    #[test]
    fn static_mut_is_banned() {
        let m = manifest_for("");
        let r = run_on("src/x.rs", "static mut COUNTER: u64 = 0;\n", &m);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].rule, AuditRule::StaticMut);
        // `static` alone is fine.
        assert!(run_on("src/x.rs", "static OK: u64 = 0;\n", &m).ok());
    }

    #[test]
    fn panics_banned_only_in_hot_paths_and_allowable() {
        let m = manifest_for("");
        let src = "fn f() { x.unwrap(); }\n";
        // Hot-path file: violation.
        let r = run_on("src/combine/slot.rs", src, &m);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].rule, AuditRule::PanicInHotPath);
        // Non-hot file: fine.
        assert!(run_on("src/exp/table.rs", src, &m).ok());
        // Escape hatch.
        let allowed =
            "fn f() {\n    // audit:allow(panic): setup-time invariant.\n    x.unwrap();\n}\n";
        assert!(run_on("src/combine/slot.rs", allowed, &m).ok());
        // Test modules are exempt.
        let test_src = "#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        assert!(run_on("src/combine/slot.rs", test_src, &m).ok());
        // unwrap_or is not unwrap.
        assert!(run_on("src/combine/slot.rs", "fn f() { x.unwrap_or(3); }\n", &m).ok());
    }

    #[test]
    fn literals_do_not_trip_rules() {
        let m = manifest_for("");
        let src = "fn f() { let s = \"unsafe static mut Ordering::Relaxed .unwrap()\"; }\n";
        let r = run_on("src/combine/slot.rs", src, &m);
        assert!(r.ok(), "{}", r.render());
    }

    #[test]
    fn stale_manifest_entries_warn_but_do_not_fail() {
        let m = manifest_for(
            "[[site]]\nfile = \"src/gone.rs\"\nsymbol = \"f\"\norderings = [\"SeqCst\"]\n\
             why = \"stale\"\n",
        );
        let r = run_on("src/x.rs", "fn f() { let a = 1; }\n", &m);
        assert!(r.ok());
        assert_eq!(r.warnings.len(), 1);
        assert_eq!(r.warnings[0].rule, AuditRule::StaleManifestEntry);
    }
}
