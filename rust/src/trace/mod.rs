//! Irregularity observability plane (DESIGN.md §2.10).
//!
//! The paper's thesis is that vertex-centric workloads are irregular in
//! ways aggregate timings hide — per-superstep skew, fine-grain
//! synchronisation, unpredictable access patterns. This module makes
//! that irregularity *visible*: the engine (and the cost-model
//! simulator, over its virtual clock) records per-worker phase spans,
//! per-shard execution spans with owner-vs-stolen attribution, instants
//! for tuner decisions / steals / graph epochs, and one per-superstep
//! sample of skew, fan-in, contention and lane utilisation.
//!
//! Structure:
//! * [`event`] — the event taxonomy and the finished [`RunTrace`];
//! * [`buf`] — hot-path recording: per-worker append segments
//!   (`MessageLog` discipline), drained only at barriers, pooled by the
//!   session;
//! * [`chrome`] — `--trace-out`: Chrome trace-event JSON for Perfetto;
//! * [`summary`] — `--trace-summary`: per-superstep terminal rendering.
//!
//! Tracing is runtime-opt-in (`EngineConfig::trace`, zero overhead when
//! off) and can be compiled out entirely with the `no-trace` feature,
//! which turns the two construction gates ([`TraceBuffers::checkout`],
//! [`RunTrace::for_run`]) into constant `None` so every recording site
//! is statically dead.

pub mod buf;
pub mod chrome;
pub mod event;
pub mod summary;

pub use buf::{BarrierSignals, TraceBuffers};
pub use chrome::chrome_trace_json;
pub use event::{Event, InstantKind, Phase, RunTrace};
pub use summary::render_summary;
