//! Event taxonomy of the observability plane (DESIGN.md §2.10).
//!
//! Three shapes cover everything the engine and the simulator emit:
//!
//! * [`Event::Span`] — a timed interval on one lane: a whole phase on a
//!   worker (scatter / flush / apply / compute / barrier) or, with
//!   `shard: Some(..)`, the execution of one shard including whether the
//!   lane *stole* it from another worker's deque.
//! * [`Event::Instant`] — a point event: a tuner decision, a steal
//!   episode, a mutation-epoch bump, a delta-log compaction.
//! * [`Event::Counter`] — one per-superstep sample of the irregularity
//!   signals the paper is about: shard-time skew, message fan-in,
//!   CAS-retry / lock-contention counts from the [`ContentionProbe`]s,
//!   and vector-lane utilisation.
//!
//! Timestamps are nanoseconds since the start of the run — wall-clock in
//! the real engine, the [`VirtualMachine`](crate::sim::machine::VirtualMachine)
//! clock in the simulator — so a real trace and a sim trace of the same
//! configuration share one schema and open side-by-side in Perfetto.
//!
//! [`ContentionProbe`]: crate::combine::strategy::ContentionProbe

/// Which part of a superstep a span covers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Flat engine: the single fused compute phase.
    Compute,
    /// Partitioned engine: owner-exclusive per-shard scatter.
    Scatter,
    /// Partitioned engine: owner-exclusive drain of the cross-shard
    /// remote buffers.
    Flush,
    /// Partitioned engine: the serial barrier section (epoch swap,
    /// aggregator merge, log rotation).
    Apply,
    /// Flat engine: the serial barrier section.
    Barrier,
}

impl Phase {
    /// Stable lower-case name (trace-event `name` field).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Compute => "compute",
            Phase::Scatter => "scatter",
            Phase::Flush => "flush",
            Phase::Apply => "apply",
            Phase::Barrier => "barrier",
        }
    }
}

/// What a point event marks.
#[derive(Clone, Debug, PartialEq)]
pub enum InstantKind {
    /// The adaptive tuner (re-)selected the superstep's knobs.
    TunerDecision {
        /// Rendered `schedule/strategy/iteration` triple of the chosen
        /// [`StepPlan`](crate::engine::tune::StepPlan).
        mode: String,
    },
    /// A worker stole the given shard from another worker's deque. One
    /// instant per successful steal — the count always matches
    /// [`RunMetrics::steals`](crate::metrics::RunMetrics::steals).
    Steal {
        /// The migrated shard.
        shard: u32,
    },
    /// The run executed against a mutated graph (delta overlay present).
    EpochBump {
        /// The graph's mutation epoch.
        epoch: u64,
    },
    /// The run executed against a freshly compacted graph (non-zero
    /// epoch, empty overlay).
    Compaction {
        /// The graph's mutation epoch.
        epoch: u64,
    },
    /// The run carried a serving-layer context tag
    /// (`RunOptions::tag`): emitted once at the head of the timeline so
    /// interleaved multi-tenant runs stay attributable in a merged
    /// trace. The software analogue of a hardware context id.
    QueryContext {
        /// The caller-chosen context tag.
        tag: u64,
    },
}

impl InstantKind {
    /// Stable name (trace-event `name` field).
    pub fn name(&self) -> &'static str {
        match self {
            InstantKind::TunerDecision { .. } => "tuner-decision",
            InstantKind::Steal { .. } => "steal",
            InstantKind::EpochBump { .. } => "epoch-bump",
            InstantKind::Compaction { .. } => "compaction",
            InstantKind::QueryContext { .. } => "query-context",
        }
    }
}

/// One trace event. `tid` is a worker index; the lane one past the last
/// worker ([`RunTrace::engine_lane`]) carries the engine's own serial
/// sections and whole-phase wall spans.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// A timed interval on lane `tid`.
    Span {
        /// Lane the interval ran on.
        tid: u32,
        /// Superstep it belongs to.
        superstep: u32,
        /// Phase it belongs to.
        phase: Phase,
        /// `Some((shard, stolen))` for per-shard execution spans;
        /// `None` for whole-phase spans.
        shard: Option<(u32, bool)>,
        /// Start, ns since run start.
        start_ns: u64,
        /// End, ns since run start.
        end_ns: u64,
    },
    /// A point event on lane `tid`.
    Instant {
        /// Lane the event fired on.
        tid: u32,
        /// Superstep it belongs to.
        superstep: u32,
        /// What happened.
        kind: InstantKind,
        /// Timestamp, ns since run start.
        ts_ns: u64,
    },
    /// Per-superstep irregularity sample, recorded at the barrier.
    Counter {
        /// Superstep the sample summarises.
        superstep: u32,
        /// Timestamp (the barrier), ns since run start.
        ts_ns: u64,
        /// Max-over-mean of the measured per-shard execution times this
        /// superstep (1.0 when balanced, or when the run has no shard
        /// spans — the flat engine).
        skew: f64,
        /// Messages per receiving vertex this superstep.
        fan_in: f64,
        /// CAS retries observed by the contention probes this superstep.
        cas_retries: u64,
        /// Lock acquisitions that had to spin, ditto.
        lock_contended: u64,
        /// Useful fraction of scanned vector lanes (1.0 when nothing
        /// vectorised — same convention as `LaneCounters::ratio`).
        lane_utilisation: f64,
    },
}

/// A finished run's event trace: what `--trace-out` serialises and
/// `--trace-summary` renders. Attached to
/// [`RunMetrics::trace`](crate::metrics::RunMetrics::trace) when
/// [`EngineConfig::trace`](crate::engine::EngineConfig) is set.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RunTrace {
    /// Worker-lane count; lane `workers` is the engine lane.
    pub workers: usize,
    /// All events, in per-lane append order (not globally sorted —
    /// Chrome trace-event consumers do not require it).
    pub events: Vec<Event>,
}

impl RunTrace {
    /// An empty trace for `workers` worker lanes when `enabled`, `None`
    /// otherwise. Compiled to a constant `None` under the `no-trace`
    /// feature — the simulator's gate (the real engine gates through
    /// [`TraceBuffers::checkout`](crate::trace::buf::TraceBuffers::checkout)).
    pub fn for_run(enabled: bool, workers: usize) -> Option<RunTrace> {
        #[cfg(feature = "no-trace")]
        {
            let _ = (enabled, workers);
            None
        }
        #[cfg(not(feature = "no-trace"))]
        {
            if enabled {
                Some(RunTrace {
                    workers,
                    events: Vec::new(),
                })
            } else {
                None
            }
        }
    }

    /// The lane carrying engine-serial sections and whole-phase spans.
    pub fn engine_lane(&self) -> u32 {
        self.workers as u32
    }

    /// Number of steal instants in the trace (tested against
    /// [`RunMetrics::steals`](crate::metrics::RunMetrics::steals)).
    pub fn steal_instants(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, Event::Instant { kind: InstantKind::Steal { .. }, .. }))
            .count()
    }

    /// Record the graph's mutation state as instants at the head of the
    /// timeline: an epoch bump when the run saw a non-zero epoch, a
    /// compaction marker when that epoch's delta overlay was empty
    /// (compaction folds the overlay into the base CSR). Called by the
    /// session after the run — graph mutation is a between-runs affair.
    pub fn note_epoch(&mut self, epoch: u64, delta_edges: u64) {
        if epoch == 0 {
            return;
        }
        let tid = self.engine_lane();
        let kind = if delta_edges == 0 {
            InstantKind::Compaction { epoch }
        } else {
            InstantKind::EpochBump { epoch }
        };
        self.events.push(Event::Instant {
            tid,
            superstep: 0,
            kind,
            ts_ns: 0,
        });
    }
}
