//! Hot-path trace recording: per-worker append segments drained at
//! barriers (DESIGN.md §2.10).
//!
//! Same discipline as `MessageLog`'s segments: each worker appends to
//! its own pre-sized, cache-padded buffer — owner-exclusive during a
//! parallel phase, so recording takes no lock and (until a segment
//! outgrows its reservation) no allocation — and the coordinator drains
//! every segment single-threaded at the barrier, the only point where
//! the phase discipline guarantees no worker is writing. One extra lane
//! past the workers carries the engine's own serial sections.
//!
//! This file is on the audit's PANIC_DENY list (it is called from the
//! scatter/flush hot loops) and deliberately carries **no atomics**: the
//! scope-join barrier at the end of each phase publishes the segments,
//! exactly as it publishes message-log segments.
//!
//! The `no-trace` feature compiles tracing out: [`TraceBuffers::checkout`]
//! (and `RunTrace::for_run`, the simulator's gate) become constant
//! `None`, so every recording site — all behind `if let Some(..)` — is
//! statically dead and the subsystem reduces to inert type definitions.

use crate::combine::strategy::ContentionProbe;
use crate::layout::store::SyncCell;
use crate::trace::event::{Event, InstantKind, Phase, RunTrace};
use crate::util::CachePadded;
use std::time::{Duration, Instant};

/// Events reserved per lane segment at checkout: enough for every phase
/// span, shard span and steal instant of a few hundred supersteps
/// without reallocating mid-phase.
const SEG_RESERVE: usize = 4096;

/// The per-superstep signals the engine hands to [`TraceBuffers::drain_barrier`];
/// shard-time skew is computed from the drained spans themselves.
#[derive(Clone, Copy, Debug)]
pub struct BarrierSignals {
    /// Superstep being sealed.
    pub superstep: usize,
    /// Messages per receiving vertex this superstep.
    pub fan_in: f64,
    /// CAS retries this superstep (peeked from the contention probes
    /// *before* the tuner's draining `observe`).
    pub cas_retries: u64,
    /// Contended lock acquisitions this superstep, ditto.
    pub lock_contended: u64,
    /// Useful fraction of scanned vector lanes.
    pub lane_utilisation: f64,
}

/// Pooled per-run trace recorder: `workers + 1` append lanes, a probe
/// array for non-adaptive runs, and the drained event accumulation.
/// Checked out of the session pool per traced run (like tuner state) and
/// returned after [`TraceBuffers::take_run`] empties it.
pub struct TraceBuffers {
    /// Run-start anchor; all timestamps are ns since this.
    start: Instant,
    /// Worker-lane count (lane `workers` is the engine lane).
    workers: usize,
    /// Append segments, one per lane, owner-exclusive during phases.
    segs: Vec<CachePadded<SyncCell<Vec<Event>>>>,
    /// Contention probes the trace plane owns so non-adaptive traced
    /// runs still measure CAS/lock traffic (adaptive runs share the
    /// tuner's probes instead, peeked before its draining `observe`).
    probes: Vec<CachePadded<ContentionProbe>>,
    /// Events drained so far, in barrier order.
    drained: Vec<Event>,
    /// Cumulative measured execution time per shard — the vector
    /// `RunMetrics::shard_times` hands to NUMA placement.
    shard_times: Vec<Duration>,
    /// Scratch: this superstep's per-shard span time (ns).
    step_shard_ns: Vec<u64>,
    /// Scratch: shards with a non-zero entry in `step_shard_ns`.
    touched_shards: Vec<usize>,
}

impl Default for TraceBuffers {
    fn default() -> Self {
        TraceBuffers {
            start: Instant::now(),
            workers: 0,
            segs: Vec::new(),
            probes: Vec::new(),
            drained: Vec::new(),
            shard_times: Vec::new(),
            step_shard_ns: Vec::new(),
            touched_shards: Vec::new(),
        }
    }
}

impl TraceBuffers {
    /// Check a recorder out for a run: recycle `pooled` when the session
    /// has one, else build fresh; size for `workers` lanes, clear every
    /// buffer, re-stamp the run-start anchor. Compiled to a constant
    /// `None` under `no-trace`.
    pub fn checkout(pooled: Option<TraceBuffers>, workers: usize) -> Option<TraceBuffers> {
        #[cfg(feature = "no-trace")]
        {
            let _ = (pooled, workers);
            None
        }
        #[cfg(not(feature = "no-trace"))]
        {
            let mut b = pooled.unwrap_or_default();
            b.reset(workers);
            Some(b)
        }
    }

    /// Size for `workers` lanes and clear all state (capacity is kept —
    /// the point of pooling).
    pub fn reset(&mut self, workers: usize) {
        let workers = workers.max(1);
        self.workers = workers;
        while self.segs.len() < workers + 1 {
            self.segs.push(CachePadded::new(SyncCell::new(Vec::new())));
        }
        while self.probes.len() < workers {
            self.probes.push(CachePadded::new(ContentionProbe::new()));
        }
        for seg in &self.segs {
            let s = seg.get_mut();
            s.clear();
            s.reserve(SEG_RESERVE);
        }
        for p in &self.probes {
            p.take();
        }
        self.drained.clear();
        self.shard_times.clear();
        self.step_shard_ns.clear();
        self.touched_shards.clear();
        self.start = Instant::now();
    }

    /// Nanoseconds since the run-start anchor.
    #[inline]
    pub fn now_ns(&self) -> u64 {
        self.start.elapsed().as_nanos() as u64
    }

    /// The engine lane's index (one past the last worker).
    #[inline]
    pub fn engine_lane(&self) -> usize {
        self.workers
    }

    /// Owner-exclusive append to lane `tid` (hot path: no lock, and no
    /// allocation while the segment stays within its reservation).
    #[inline]
    pub fn push(&self, tid: usize, ev: Event) {
        self.segs[tid].get_mut().push(ev);
    }

    /// Record a finished interval on lane `tid`.
    #[inline]
    pub fn span(
        &self,
        tid: usize,
        superstep: usize,
        phase: Phase,
        shard: Option<(u32, bool)>,
        start_ns: u64,
        end_ns: u64,
    ) {
        self.push(
            tid,
            Event::Span {
                tid: tid as u32,
                superstep: superstep as u32,
                phase,
                shard,
                start_ns,
                end_ns,
            },
        );
    }

    /// Record a point event on lane `tid`, stamped now.
    #[inline]
    pub fn instant(&self, tid: usize, superstep: usize, kind: InstantKind) {
        let ts_ns = self.now_ns();
        self.push(
            tid,
            Event::Instant {
                tid: tid as u32,
                superstep: superstep as u32,
                kind,
                ts_ns,
            },
        );
    }

    /// The trace plane's own contention probes (handed to the delivery
    /// path on traced non-adaptive runs).
    pub fn probes(&self) -> &[CachePadded<ContentionProbe>] {
        &self.probes
    }

    /// Drain-and-sum this plane's probes (non-adaptive runs; adaptive
    /// runs peek the tuner's probes instead).
    pub fn take_probe_counts(&self) -> (u64, u64) {
        let mut cas = 0u64;
        let mut lock = 0u64;
        for p in &self.probes {
            let (c, l) = p.take();
            cas += c;
            lock += l;
        }
        (cas, lock)
    }

    /// Barrier drain — the only point the segments may be read: move
    /// every lane's events into the run accumulation, fold this
    /// superstep's shard spans into the cumulative per-shard times,
    /// compute the measured shard-time skew, and seal the superstep with
    /// one [`Event::Counter`] sample.
    pub fn drain_barrier(&mut self, sig: BarrierSignals) {
        let cur = sig.superstep as u32;
        for seg in &self.segs {
            let s = seg.get_mut();
            for ev in s.drain(..) {
                if let Event::Span {
                    superstep,
                    shard: Some((shard, _)),
                    start_ns,
                    end_ns,
                    ..
                } = &ev
                {
                    if *superstep == cur {
                        let shard = *shard as usize;
                        let dur = end_ns.saturating_sub(*start_ns);
                        if self.shard_times.len() <= shard {
                            self.shard_times.resize(shard + 1, Duration::ZERO);
                            self.step_shard_ns.resize(shard + 1, 0);
                        }
                        self.shard_times[shard] += Duration::from_nanos(dur);
                        if dur > 0 {
                            if self.step_shard_ns[shard] == 0 {
                                self.touched_shards.push(shard);
                            }
                            self.step_shard_ns[shard] += dur;
                        }
                    }
                }
                self.drained.push(ev);
            }
        }
        let mut max = 0u64;
        let mut sum = 0u64;
        for &s in &self.touched_shards {
            let ns = self.step_shard_ns[s];
            max = max.max(ns);
            sum += ns;
        }
        let skew = if sum > 0 {
            max as f64 * self.touched_shards.len() as f64 / sum as f64
        } else {
            1.0
        };
        for &s in &self.touched_shards {
            self.step_shard_ns[s] = 0;
        }
        self.touched_shards.clear();
        self.drained.push(Event::Counter {
            superstep: cur,
            ts_ns: self.now_ns(),
            skew,
            fan_in: sig.fan_in,
            cas_retries: sig.cas_retries,
            lock_contended: sig.lock_contended,
            lane_utilisation: sig.lane_utilisation,
        });
    }

    /// End of run: sweep any straggler events out of the segments and
    /// hand the finished trace plus the measured per-shard timing vector
    /// to the caller, leaving this recorder empty for the pool.
    pub fn take_run(&mut self) -> (RunTrace, Vec<Duration>) {
        for seg in &self.segs {
            self.drained.append(seg.get_mut());
        }
        (
            RunTrace {
                workers: self.workers,
                events: std::mem::take(&mut self.drained),
            },
            std::mem::take(&mut self.shard_times),
        )
    }
}

#[cfg(all(test, not(feature = "no-trace")))]
mod tests {
    use super::*;

    #[test]
    fn drain_computes_skew_from_shard_spans_and_accumulates_shard_times() {
        let mut b = TraceBuffers::checkout(None, 2).expect("tracing enabled");
        // Worker 0 runs shard 0 for 300ns, worker 1 runs shard 1 for
        // 100ns: skew = 300 / mean(200) = 1.5.
        b.span(0, 0, Phase::Scatter, Some((0, false)), 0, 300);
        b.span(1, 0, Phase::Scatter, Some((1, true)), 0, 100);
        b.drain_barrier(BarrierSignals {
            superstep: 0,
            fan_in: 2.0,
            cas_retries: 7,
            lock_contended: 1,
            lane_utilisation: 1.0,
        });
        // Second superstep only touches shard 1.
        b.span(0, 1, Phase::Scatter, Some((1, false)), 400, 450);
        b.drain_barrier(BarrierSignals {
            superstep: 1,
            fan_in: 1.0,
            cas_retries: 0,
            lock_contended: 0,
            lane_utilisation: 1.0,
        });
        let (trace, shard_times) = b.take_run();
        assert_eq!(trace.workers, 2);
        assert_eq!(shard_times, vec![Duration::from_nanos(300), Duration::from_nanos(150)]);
        let skews: Vec<f64> = trace
            .events
            .iter()
            .filter_map(|e| match e {
                Event::Counter { skew, .. } => Some(*skew),
                _ => None,
            })
            .collect();
        assert_eq!(skews.len(), 2);
        assert!((skews[0] - 1.5).abs() < 1e-12, "skew {}", skews[0]);
        assert!((skews[1] - 1.0).abs() < 1e-12, "single shard is balanced");
        // Recorder is empty and reusable after take_run.
        let (empty, times) = b.take_run();
        assert!(empty.events.is_empty());
        assert!(times.is_empty());
    }

    #[test]
    fn pooled_checkout_resets_and_regrows() {
        let mut b = TraceBuffers::checkout(None, 1).expect("tracing enabled");
        b.instant(0, 0, InstantKind::Steal { shard: 3 });
        b.probes()[0].cas_retries.fetch_add(5, std::sync::atomic::Ordering::Relaxed);
        // Return dirty (as the engine would never do, but checkout must
        // cope), then check out wider.
        let b2 = TraceBuffers::checkout(Some(b), 4).expect("tracing enabled");
        assert_eq!(b2.engine_lane(), 4);
        assert_eq!(b2.probes().len(), 4);
        assert_eq!(b2.take_probe_counts(), (0, 0), "probes cleared at checkout");
        let mut b2 = b2;
        let (trace, _) = b2.take_run();
        assert!(trace.events.is_empty(), "segments cleared at checkout");
    }
}
