//! Human sink for a [`RunTrace`]: the `--trace-summary` rendering.
//!
//! One line per superstep — engine-lane phase wall times plus the
//! irregularity sample — and, per superstep, the top-k slowest shards by
//! measured execution time with their steal attribution. The same
//! numbers `--trace-out` ships to Perfetto, compressed for a terminal.

use crate::trace::event::{Event, InstantKind, Phase, RunTrace};
use crate::util::timer::fmt_duration;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::Duration;

/// Fixed render order for phase wall times.
const PHASES: [Phase; 5] = [
    Phase::Compute,
    Phase::Scatter,
    Phase::Flush,
    Phase::Apply,
    Phase::Barrier,
];

fn phase_idx(p: Phase) -> usize {
    match p {
        Phase::Compute => 0,
        Phase::Scatter => 1,
        Phase::Flush => 2,
        Phase::Apply => 3,
        Phase::Barrier => 4,
    }
}

#[derive(Default)]
struct StepAgg {
    /// Engine-lane wall ns per phase (indexed by `phase_idx`).
    phase_ns: [u64; 5],
    /// Per-shard measured ns + times stolen this superstep.
    shards: BTreeMap<u32, (u64, u32)>,
    steals: u64,
    mode: Option<String>,
    /// (skew, fan_in, cas, lock, lanes) from the barrier sample.
    sample: Option<(f64, f64, u64, u64, f64)>,
}

fn ns(d: u64) -> String {
    fmt_duration(Duration::from_nanos(d))
}

/// Render `trace` as a per-superstep text summary listing the `top_k`
/// slowest shards of each superstep.
pub fn render_summary(trace: &RunTrace, top_k: usize) -> String {
    let engine = trace.engine_lane();
    let mut steps: BTreeMap<u32, StepAgg> = BTreeMap::new();
    let mut epoch_note: Option<String> = None;
    let mut context_note: Option<String> = None;
    for ev in &trace.events {
        match ev {
            Event::Span {
                tid,
                superstep,
                phase,
                shard,
                start_ns,
                end_ns,
            } => {
                let agg = steps.entry(*superstep).or_default();
                let dur = end_ns.saturating_sub(*start_ns);
                match shard {
                    Some((shard, stolen)) => {
                        let e = agg.shards.entry(*shard).or_insert((0, 0));
                        e.0 += dur;
                        e.1 += u32::from(*stolen);
                    }
                    None if *tid == engine => agg.phase_ns[phase_idx(*phase)] += dur,
                    None => {}
                }
            }
            Event::Instant {
                superstep, kind, ..
            } => {
                let agg = steps.entry(*superstep).or_default();
                match kind {
                    InstantKind::Steal { .. } => agg.steals += 1,
                    InstantKind::TunerDecision { mode } => agg.mode = Some(mode.clone()),
                    InstantKind::EpochBump { epoch } => {
                        epoch_note = Some(format!("graph epoch {epoch} (delta overlay live)"));
                    }
                    InstantKind::Compaction { epoch } => {
                        epoch_note = Some(format!("graph epoch {epoch} (freshly compacted)"));
                    }
                    InstantKind::QueryContext { tag } => {
                        context_note = Some(format!("query context tag {tag}"));
                    }
                }
            }
            Event::Counter {
                superstep,
                skew,
                fan_in,
                cas_retries,
                lock_contended,
                lane_utilisation,
                ..
            } => {
                steps.entry(*superstep).or_default().sample =
                    Some((*skew, *fan_in, *cas_retries, *lock_contended, *lane_utilisation));
            }
        }
    }

    let total_steals: u64 = steps.values().map(|s| s.steals).sum();
    let mut out = String::new();
    let _ = writeln!(
        out,
        "== trace summary: {} workers, {} supersteps, {} steals ==",
        trace.workers,
        steps.len(),
        total_steals
    );
    if let Some(note) = epoch_note {
        let _ = writeln!(out, "   {note}");
    }
    if let Some(note) = context_note {
        let _ = writeln!(out, "   {note}");
    }
    for (step, agg) in &steps {
        let _ = write!(out, "step {step:>3} ");
        for p in PHASES {
            let d = agg.phase_ns[phase_idx(p)];
            if d > 0 {
                let _ = write!(out, " {} {}", p.name(), ns(d));
            }
        }
        if let Some((skew, fan_in, cas, lock, lanes)) = agg.sample {
            let _ = write!(
                out,
                " | skew {skew:.2} fan-in {fan_in:.2} cas {cas} lock {lock} lanes {lanes:.2}"
            );
        }
        if agg.steals > 0 {
            let _ = write!(out, " | steals {}", agg.steals);
        }
        if let Some(mode) = &agg.mode {
            let _ = write!(out, " | mode {mode}");
        }
        out.push('\n');
        if !agg.shards.is_empty() && top_k > 0 {
            let mut by_time: Vec<(u32, u64, u32)> =
                agg.shards.iter().map(|(&s, &(d, st))| (s, d, st)).collect();
            by_time.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
            let _ = write!(out, "         slowest shards:");
            for (i, (s, d, st)) in by_time.iter().take(top_k).enumerate() {
                let sep = if i == 0 { " " } else { ", " };
                let _ = write!(out, "{sep}#{s} {}", ns(*d));
                if *st > 0 {
                    let _ = write!(out, " (stolen {st}x)");
                }
            }
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_ranks_shards_and_reports_signals() {
        let trace = RunTrace {
            workers: 2,
            events: vec![
                Event::Span {
                    tid: 2,
                    superstep: 0,
                    phase: Phase::Scatter,
                    shard: None,
                    start_ns: 0,
                    end_ns: 1_000_000,
                },
                Event::Span {
                    tid: 0,
                    superstep: 0,
                    phase: Phase::Scatter,
                    shard: Some((5, false)),
                    start_ns: 0,
                    end_ns: 900_000,
                },
                Event::Span {
                    tid: 1,
                    superstep: 0,
                    phase: Phase::Scatter,
                    shard: Some((2, true)),
                    start_ns: 0,
                    end_ns: 100_000,
                },
                Event::Instant {
                    tid: 1,
                    superstep: 0,
                    kind: InstantKind::Steal { shard: 2 },
                    ts_ns: 10,
                },
                Event::Counter {
                    superstep: 0,
                    ts_ns: 1_000_000,
                    skew: 1.8,
                    fan_in: 1.2,
                    cas_retries: 4,
                    lock_contended: 0,
                    lane_utilisation: 1.0,
                },
            ],
        };
        let s = render_summary(&trace, 2);
        assert!(s.contains("2 workers, 1 supersteps, 1 steals"), "{s}");
        assert!(s.contains("skew 1.80"), "{s}");
        let five = s.find("#5").expect("slowest shard listed");
        let two = s.find("#2").expect("stolen shard listed");
        assert!(five < two, "shards ranked by time:\n{s}");
        assert!(s.contains("(stolen 1x)"), "{s}");
        assert!(s.contains("steals 1"), "{s}");
    }
}
