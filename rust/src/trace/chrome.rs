//! Chrome trace-event JSON sink: serialises a [`RunTrace`] into the
//! format `chrome://tracing` and Perfetto load directly.
//!
//! Hand-rolled like every other JSON emitter in the tree (the build is
//! dependency-free). One process (`pid` 1) per run; one `tid` per worker
//! lane plus the engine lane; spans as `"ph":"X"` complete events with
//! microsecond `ts`/`dur`, instants as thread-scoped `"ph":"i"`, and the
//! per-superstep irregularity sample as three `"ph":"C"` counter tracks
//! (`shard-skew`, `contention`, `messages`).

use crate::trace::event::{Event, InstantKind, RunTrace};
use std::fmt::Write as _;

/// Trace-event `ts`/`dur` are microseconds; ours are nanoseconds.
fn us(ns: u64) -> f64 {
    ns as f64 / 1000.0
}

/// JSON has no NaN/Infinity; clamp the (already finite by construction)
/// counter values defensively.
fn fin(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

/// Minimal string escape for the mode strings we embed (they are built
/// from `Debug` renderings of field-less enum variants, but escape
/// anyway so the emitter is safe for any input).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn push_event(out: &mut String, first: &mut bool, body: &str) {
    if *first {
        *first = false;
    } else {
        out.push_str(",\n");
    }
    out.push_str("  ");
    out.push_str(body);
}

/// Serialise `trace` as a Chrome trace-event JSON document.
pub fn chrome_trace_json(trace: &RunTrace) -> String {
    let mut out = String::with_capacity(trace.events.len() * 144 + 1024);
    out.push_str("{\"traceEvents\":[\n");
    let mut first = true;

    // Metadata: name the process and every lane so Perfetto's track
    // labels read "worker 0..n-1" / "engine" instead of bare tids.
    push_event(
        &mut out,
        &mut first,
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\
         \"args\":{\"name\":\"ipregel run\"}}",
    );
    for w in 0..trace.workers {
        push_event(
            &mut out,
            &mut first,
            &format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{w},\
                 \"args\":{{\"name\":\"worker {w}\"}}}}"
            ),
        );
    }
    push_event(
        &mut out,
        &mut first,
        &format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{},\
             \"args\":{{\"name\":\"engine\"}}}}",
            trace.workers
        ),
    );

    for ev in &trace.events {
        let body = match ev {
            Event::Span {
                tid,
                superstep,
                phase,
                shard,
                start_ns,
                end_ns,
            } => {
                let dur = end_ns.saturating_sub(*start_ns);
                match shard {
                    Some((shard, stolen)) => format!(
                        "{{\"name\":\"{}\",\"cat\":\"shard\",\"ph\":\"X\",\
                         \"pid\":1,\"tid\":{tid},\"ts\":{:.3},\"dur\":{:.3},\
                         \"args\":{{\"superstep\":{superstep},\"shard\":{shard},\
                         \"stolen\":{stolen}}}}}",
                        phase.name(),
                        us(*start_ns),
                        us(dur),
                    ),
                    None => format!(
                        "{{\"name\":\"{}\",\"cat\":\"phase\",\"ph\":\"X\",\
                         \"pid\":1,\"tid\":{tid},\"ts\":{:.3},\"dur\":{:.3},\
                         \"args\":{{\"superstep\":{superstep}}}}}",
                        phase.name(),
                        us(*start_ns),
                        us(dur),
                    ),
                }
            }
            Event::Instant {
                tid,
                superstep,
                kind,
                ts_ns,
            } => {
                let args = match kind {
                    InstantKind::TunerDecision { mode } => {
                        format!("\"superstep\":{superstep},\"mode\":\"{}\"", esc(mode))
                    }
                    InstantKind::Steal { shard } => {
                        format!("\"superstep\":{superstep},\"shard\":{shard}")
                    }
                    InstantKind::EpochBump { epoch } | InstantKind::Compaction { epoch } => {
                        format!("\"superstep\":{superstep},\"epoch\":{epoch}")
                    }
                    InstantKind::QueryContext { tag } => {
                        format!("\"superstep\":{superstep},\"tag\":{tag}")
                    }
                };
                format!(
                    "{{\"name\":\"{}\",\"cat\":\"instant\",\"ph\":\"i\",\"s\":\"t\",\
                     \"pid\":1,\"tid\":{tid},\"ts\":{:.3},\"args\":{{{args}}}}}",
                    kind.name(),
                    us(*ts_ns),
                )
            }
            Event::Counter {
                superstep: _,
                ts_ns,
                skew,
                fan_in,
                cas_retries,
                lock_contended,
                lane_utilisation,
            } => {
                // Three counter tracks per sample, rendered as one body
                // (push_event separates events with commas, so join the
                // three objects here).
                let ts = us(*ts_ns);
                format!(
                    "{{\"name\":\"shard-skew\",\"ph\":\"C\",\"pid\":1,\"ts\":{ts:.3},\
                     \"args\":{{\"max_over_mean\":{:.4}}}}},\n  \
                     {{\"name\":\"contention\",\"ph\":\"C\",\"pid\":1,\"ts\":{ts:.3},\
                     \"args\":{{\"cas_retries\":{cas_retries},\
                     \"lock_contended\":{lock_contended}}}}},\n  \
                     {{\"name\":\"messages\",\"ph\":\"C\",\"pid\":1,\"ts\":{ts:.3},\
                     \"args\":{{\"fan_in\":{:.4},\"lane_utilisation\":{:.4}}}}}",
                    fin(*skew),
                    fin(*fan_in),
                    fin(*lane_utilisation),
                )
            }
        };
        push_event(&mut out, &mut first, &body);
    }
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::event::Phase;

    #[test]
    fn emits_all_event_shapes_with_escapes() {
        let trace = RunTrace {
            workers: 2,
            events: vec![
                Event::Span {
                    tid: 0,
                    superstep: 0,
                    phase: Phase::Scatter,
                    shard: Some((3, true)),
                    start_ns: 1_500,
                    end_ns: 4_500,
                },
                Event::Span {
                    tid: 2,
                    superstep: 0,
                    phase: Phase::Apply,
                    shard: None,
                    start_ns: 5_000,
                    end_ns: 6_000,
                },
                Event::Instant {
                    tid: 2,
                    superstep: 0,
                    kind: InstantKind::TunerDecision {
                        mode: "a\"b\\c".to_string(),
                    },
                    ts_ns: 1_000,
                },
                Event::Counter {
                    superstep: 0,
                    ts_ns: 6_000,
                    skew: 1.5,
                    fan_in: f64::NAN,
                    cas_retries: 7,
                    lock_contended: 0,
                    lane_utilisation: 0.5,
                },
            ],
        };
        let j = chrome_trace_json(&trace);
        assert!(j.starts_with("{\"traceEvents\":[\n"));
        assert!(j.trim_end().ends_with("]}"));
        assert!(j.contains("\"name\":\"scatter\"") && j.contains("\"stolen\":true"));
        assert!(j.contains("\"ts\":1.500") && j.contains("\"dur\":3.000"), "{j}");
        assert!(j.contains("\"name\":\"worker 1\"") && j.contains("\"name\":\"engine\""));
        assert!(j.contains("a\\\"b\\\\c"), "mode string escaped");
        assert!(j.contains("\"fan_in\":0.0000"), "NaN clamped to a JSON number");
        assert!(j.contains("\"name\":\"shard-skew\"") && j.contains("\"cas_retries\":7"));
    }
}
