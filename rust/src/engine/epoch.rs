//! Mutation-epoch plumbing: how a [`GraphSession`] keeps its caches
//! valid while the graph underneath it evolves.
//!
//! A [`crate::graph::dynamic::DynamicGraph`] advances a monotonically
//! increasing **mutation epoch** with every applied
//! [`crate::graph::dynamic::MutationSet`]. Session-held state is tagged
//! with (or patched to) the epoch it reflects:
//!
//! - **partition plans** — cuts and owner maps survive mutations
//!   untouched (vertex ranges never move short of compaction), so the
//!   session patches each cached plan's per-shard edge censuses from the
//!   [`MutationReceipt`]'s edge-instance deltas, O(batch) instead of
//!   O(V + E) (`absorb_receipt` below). A **compaction** rebuilds the
//!   base CSR, so balance is re-derived from scratch: plans and pooled
//!   shard state are dropped and rebuilt lazily — the "full
//!   re-partition only on compaction" rule;
//! - **pooled shard state** — follows its plan's pointer
//!   (`ShardState::repoint_plan`); the activity slabs themselves are
//!   shaped by the cuts, which didn't move;
//! - **degree-weight vectors** (edge-centric full scans) — cheap to
//!   rebuild, so they are simply invalidated;
//! - **pooled vertex stores** — carry an epoch tag
//!   ([`crate::layout::VertexStore::epoch_tag`]); the session re-stamps
//!   them at checkout and surfaces a mismatch through
//!   `RunMetrics::store_epoch_refreshed`, and the incremental algorithms
//!   ([`crate::algos::incremental`]) refuse warm-start values whose
//!   epoch doesn't chain to the current graph epoch.
//!
//! [`GraphSession`]: crate::engine::GraphSession
//! [`MutationReceipt`]: crate::graph::dynamic::MutationReceipt

use crate::engine::shard::ShardState;
use crate::graph::dynamic::MutationReceipt;
use crate::graph::partition::PartitionPlan;
use std::collections::HashMap;
use std::sync::Arc;

/// A session's current epoch position, for callers that coordinate
/// warm-start state across mutations (see
/// [`crate::engine::GraphSession::epoch_watermark`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EpochWatermark {
    /// Current mutation epoch (0 = static graph or never mutated).
    pub epoch: u64,
    /// Mutation instances live in the delta overlay.
    pub delta_edges: usize,
    /// `delta_edges / num_edges` at this instant.
    pub delta_occupancy: f64,
}

/// Bring the session's partition caches up to `receipt`'s epoch:
/// patch every cached plan in place (repointing pooled shard state so
/// it keeps fitting), or drop everything when the batch compacted.
pub(crate) fn absorb_receipt(
    plans: &mut HashMap<usize, Arc<PartitionPlan>>,
    shard_states: &mut Vec<ShardState>,
    receipt: &MutationReceipt,
) {
    if receipt.compacted {
        plans.clear();
        shard_states.clear();
        return;
    }
    if receipt.inserted.is_empty() && receipt.removed.is_empty() {
        return;
    }
    for plan_arc in plans.values_mut() {
        let mut patched = (**plan_arc).clone();
        patched.apply_edge_deltas(&receipt.inserted, &receipt.removed);
        let patched = Arc::new(patched);
        for st in shard_states.iter_mut() {
            if Arc::ptr_eq(&st.plan, plan_arc) {
                st.repoint_plan(Arc::clone(&patched));
            }
        }
        *plan_arc = patched;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dynamic::{DynamicGraph, MutationSet};
    use crate::graph::gen;

    #[test]
    fn absorb_patches_plans_and_repoints_shard_state() {
        let g = gen::grid(8, 8);
        let plan = Arc::new(PartitionPlan::build(&g, 4));
        let mut plans = HashMap::new();
        plans.insert(4usize, Arc::clone(&plan));
        let mut states = vec![ShardState::new(Arc::clone(&plan), 2)];

        let mut dg = DynamicGraph::with_spill_threshold(g, 1_000_000);
        let mut m = MutationSet::new();
        m.insert(0, 63);
        let receipt = dg.apply(&m);
        absorb_receipt(&mut plans, &mut states, &receipt);

        let patched = &plans[&4];
        assert!(!Arc::ptr_eq(patched, &plan), "plan replaced by patched copy");
        assert_eq!(patched.cuts(), plan.cuts(), "cuts untouched");
        patched.validate(dg.graph()).unwrap();
        assert!(
            states[0].fits(patched, 2),
            "pooled state repointed to the patched plan"
        );
    }

    #[test]
    fn absorb_after_compaction_drops_partition_caches() {
        let g = gen::grid(6, 6);
        let plan = Arc::new(PartitionPlan::build(&g, 3));
        let mut plans = HashMap::new();
        plans.insert(3usize, Arc::clone(&plan));
        let mut states = vec![ShardState::new(Arc::clone(&plan), 1)];

        let mut dg = DynamicGraph::with_spill_threshold(g, 1);
        let mut m = MutationSet::new();
        m.insert(0, 35);
        let receipt = dg.apply(&m);
        assert!(receipt.compacted);
        absorb_receipt(&mut plans, &mut states, &receipt);
        assert!(plans.is_empty());
        assert!(states.is_empty());
    }

    #[test]
    fn empty_receipt_changes_nothing() {
        let g = gen::ring(8);
        let plan = Arc::new(PartitionPlan::build(&g, 2));
        let mut plans = HashMap::new();
        plans.insert(2usize, Arc::clone(&plan));
        let mut states: Vec<ShardState> = Vec::new();
        let mut dg = DynamicGraph::new(g);
        let receipt = dg.apply(&MutationSet::new());
        absorb_receipt(&mut plans, &mut states, &receipt);
        assert!(Arc::ptr_eq(&plans[&2], &plan));
    }
}
