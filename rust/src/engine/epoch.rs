//! Mutation-epoch plumbing: how a [`GraphSession`] keeps its caches
//! valid while the graph underneath it evolves.
//!
//! A [`crate::graph::dynamic::DynamicGraph`] advances a monotonically
//! increasing **mutation epoch** with every applied
//! [`crate::graph::dynamic::MutationSet`]. Session-held state is tagged
//! with (or patched to) the epoch it reflects:
//!
//! - **partition plans** — cuts and owner maps survive mutations
//!   untouched (vertex ranges never move short of compaction), so the
//!   session patches each cached plan's per-shard edge censuses from the
//!   [`MutationReceipt`]'s edge-instance deltas, O(batch) instead of
//!   O(V + E) (`absorb_receipt` below). A **compaction** rebuilds the
//!   base CSR, so balance is re-derived from scratch: plans and pooled
//!   shard state are dropped and rebuilt lazily — the "full
//!   re-partition only on compaction" rule;
//! - **pooled shard state** — follows its plan's pointer
//!   (`ShardState::repoint_plan`); the activity slabs themselves are
//!   shaped by the cuts, which didn't move;
//! - **degree-weight vectors** (edge-centric full scans) — cheap to
//!   rebuild, so they are simply invalidated;
//! - **pooled vertex stores** — carry an epoch tag
//!   ([`crate::layout::VertexStore::epoch_tag`]); the session re-stamps
//!   them at checkout and surfaces a mismatch through
//!   `RunMetrics::store_epoch_refreshed`, and the incremental algorithms
//!   ([`crate::algos::incremental`]) refuse warm-start values whose
//!   epoch doesn't chain to the current graph epoch.
//!
//! The serving layer (`serve/`) adds **epoch pinning** on top:
//! a concurrent reader takes an [`EpochPin`] on the epoch its snapshot
//! reflects, a writer applies mutations and publishes a *new* snapshot
//! without waiting for pins to drain (snapshot isolation by
//! copy-on-mutate), and [`EpochPins`] is the refcount registry that
//! makes the pinned population observable.
//!
//! [`GraphSession`]: crate::engine::GraphSession
//! [`MutationReceipt`]: crate::graph::dynamic::MutationReceipt

use crate::engine::shard::ShardState;
use crate::graph::dynamic::MutationReceipt;
use crate::graph::partition::PartitionPlan;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// A session's current epoch position, for callers that coordinate
/// warm-start state across mutations (see
/// [`crate::engine::GraphSession::epoch_watermark`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EpochWatermark {
    /// Current mutation epoch (0 = static graph or never mutated).
    pub epoch: u64,
    /// Mutation instances live in the delta overlay.
    pub delta_edges: usize,
    /// `delta_edges / num_edges` at this instant.
    pub delta_occupancy: f64,
}

/// Refcount registry of pinned mutation epochs: which epochs have live
/// readers, and how many. Writers never consult it to *block* — the
/// serving layer publishes new snapshots by pointer swap and old
/// snapshots stay alive for exactly as long as their pins (plus the
/// `Arc`s holding them) do — but it makes the pinned population
/// observable: tests assert on it, and a garbage-collection pass can ask
/// for the oldest epoch still pinned before retiring a snapshot.
#[derive(Debug, Default)]
pub struct EpochPins {
    /// epoch → live pin count. A `Mutex<HashMap>` rather than atomics:
    /// pin/unpin happens once per query, not per vertex, so contention
    /// is admission-rate, never hot-path.
    counts: Mutex<HashMap<u64, usize>>,
}

impl EpochPins {
    /// Fresh registry with nothing pinned.
    pub fn new() -> Arc<EpochPins> {
        Arc::new(EpochPins::default())
    }

    /// Pin `epoch`: the returned RAII guard holds the count up until it
    /// is dropped.
    pub fn pin(self: &Arc<EpochPins>, epoch: u64) -> EpochPin {
        let mut counts = self.counts.lock().expect("epoch pins poisoned");
        *counts.entry(epoch).or_insert(0) += 1;
        drop(counts);
        EpochPin {
            registry: Arc::clone(self),
            epoch,
        }
    }

    /// Live pins on `epoch`.
    pub fn pinned_readers(&self, epoch: u64) -> usize {
        self.counts
            .lock()
            .expect("epoch pins poisoned")
            .get(&epoch)
            .copied()
            .unwrap_or(0)
    }

    /// The oldest epoch with at least one live pin, if any — the
    /// retirement horizon for snapshot garbage collection.
    pub fn oldest_pinned(&self) -> Option<u64> {
        self.counts
            .lock()
            .expect("epoch pins poisoned")
            .keys()
            .min()
            .copied()
    }

    /// Total live pins across all epochs.
    pub fn total_pinned(&self) -> usize {
        self.counts
            .lock()
            .expect("epoch pins poisoned")
            .values()
            .sum()
    }
}

/// RAII guard for one reader's pin on one mutation epoch (see
/// [`EpochPins::pin`]). Dropping it releases the pin; the map entry is
/// removed when its count reaches zero so [`EpochPins::oldest_pinned`]
/// never reports a dead epoch.
#[derive(Debug)]
pub struct EpochPin {
    registry: Arc<EpochPins>,
    epoch: u64,
}

impl EpochPin {
    /// The pinned epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

impl Drop for EpochPin {
    fn drop(&mut self) {
        // A poisoned registry means a panic mid-pin elsewhere; don't
        // double-panic in drop — the process is going down anyway.
        if let Ok(mut counts) = self.registry.counts.lock() {
            if let Some(c) = counts.get_mut(&self.epoch) {
                *c -= 1;
                if *c == 0 {
                    counts.remove(&self.epoch);
                }
            }
        }
    }
}

/// Bring the session's partition caches up to `receipt`'s epoch:
/// patch every cached plan in place (repointing pooled shard state so
/// it keeps fitting), or drop everything when the batch compacted.
pub(crate) fn absorb_receipt(
    plans: &mut HashMap<usize, Arc<PartitionPlan>>,
    shard_states: &mut Vec<ShardState>,
    receipt: &MutationReceipt,
) {
    if receipt.compacted {
        plans.clear();
        shard_states.clear();
        return;
    }
    if receipt.inserted.is_empty() && receipt.removed.is_empty() {
        return;
    }
    for plan_arc in plans.values_mut() {
        let mut patched = (**plan_arc).clone();
        patched.apply_edge_deltas(&receipt.inserted, &receipt.removed);
        let patched = Arc::new(patched);
        for st in shard_states.iter_mut() {
            if Arc::ptr_eq(&st.plan, plan_arc) {
                st.repoint_plan(Arc::clone(&patched));
            }
        }
        *plan_arc = patched;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dynamic::{DynamicGraph, MutationSet};
    use crate::graph::gen;

    #[test]
    fn absorb_patches_plans_and_repoints_shard_state() {
        let g = gen::grid(8, 8);
        let plan = Arc::new(PartitionPlan::build(&g, 4));
        let mut plans = HashMap::new();
        plans.insert(4usize, Arc::clone(&plan));
        let mut states = vec![ShardState::new(Arc::clone(&plan), 2)];

        let mut dg = DynamicGraph::with_spill_threshold(g, 1_000_000);
        let mut m = MutationSet::new();
        m.insert(0, 63);
        let receipt = dg.apply(&m);
        absorb_receipt(&mut plans, &mut states, &receipt);

        let patched = &plans[&4];
        assert!(!Arc::ptr_eq(patched, &plan), "plan replaced by patched copy");
        assert_eq!(patched.cuts(), plan.cuts(), "cuts untouched");
        patched.validate(dg.graph()).unwrap();
        assert!(
            states[0].fits(patched, 2),
            "pooled state repointed to the patched plan"
        );
    }

    #[test]
    fn absorb_after_compaction_drops_partition_caches() {
        let g = gen::grid(6, 6);
        let plan = Arc::new(PartitionPlan::build(&g, 3));
        let mut plans = HashMap::new();
        plans.insert(3usize, Arc::clone(&plan));
        let mut states = vec![ShardState::new(Arc::clone(&plan), 1)];

        let mut dg = DynamicGraph::with_spill_threshold(g, 1);
        let mut m = MutationSet::new();
        m.insert(0, 35);
        let receipt = dg.apply(&m);
        assert!(receipt.compacted);
        absorb_receipt(&mut plans, &mut states, &receipt);
        assert!(plans.is_empty());
        assert!(states.is_empty());
    }

    #[test]
    fn epoch_pins_refcount_and_release() {
        let pins = EpochPins::new();
        assert_eq!(pins.oldest_pinned(), None);
        let a = pins.pin(3);
        let b = pins.pin(3);
        let c = pins.pin(7);
        assert_eq!(a.epoch(), 3);
        assert_eq!(pins.pinned_readers(3), 2);
        assert_eq!(pins.pinned_readers(7), 1);
        assert_eq!(pins.pinned_readers(99), 0);
        assert_eq!(pins.oldest_pinned(), Some(3));
        assert_eq!(pins.total_pinned(), 3);
        drop(a);
        assert_eq!(pins.pinned_readers(3), 1);
        drop(b);
        assert_eq!(pins.pinned_readers(3), 0);
        assert_eq!(pins.oldest_pinned(), Some(7), "dead epochs drop out");
        drop(c);
        assert_eq!(pins.oldest_pinned(), None);
        assert_eq!(pins.total_pinned(), 0);
    }

    #[test]
    fn epoch_pins_are_send_across_threads() {
        let pins = EpochPins::new();
        let guard = pins.pin(1);
        std::thread::scope(|s| {
            let p = Arc::clone(&pins);
            s.spawn(move || {
                let inner = p.pin(2);
                assert_eq!(p.pinned_readers(2), 1);
                drop(inner);
            });
        });
        assert_eq!(pins.pinned_readers(2), 0);
        assert_eq!(pins.pinned_readers(1), 1);
        drop(guard);
    }

    #[test]
    fn empty_receipt_changes_nothing() {
        let g = gen::ring(8);
        let plan = Arc::new(PartitionPlan::build(&g, 2));
        let mut plans = HashMap::new();
        plans.insert(2usize, Arc::clone(&plan));
        let mut states: Vec<ShardState> = Vec::new();
        let mut dg = DynamicGraph::new(g);
        let receipt = dg.apply(&MutationSet::new());
        absorb_receipt(&mut plans, &mut states, &receipt);
        assert!(Arc::ptr_eq(&plans[&2], &plan));
    }
}
