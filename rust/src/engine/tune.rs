//! The adaptive superstep tuner: one controller, re-deciding the
//! engine's execution knobs at every barrier.
//!
//! The paper's central observation is that vertex-centric workloads are
//! irregular **across supersteps**: frontier density, message volume and
//! mailbox contention swing by orders of magnitude within a single run
//! (a BFS starts at one vertex, peaks at most of the graph, and drains
//! back to a trickle). Yet the engine's knobs — [`Schedule`] dispatch,
//! combining [`Strategy`], dense-frontier bypass — are fixed once per
//! run at config time, so every fixed configuration is wrong for *some*
//! phase of the run. [`AdaptiveTuner`] closes that loop: each superstep
//! it reads cheap live signals (frontier density, messages per active
//! vertex, mailbox fan-in, [`ContentionProbe`] counters, cross-shard
//! flush imbalance) and re-selects, for the next superstep only:
//!
//! - **(a) vertex- vs edge-centric dispatch** — edge-centric cuts when
//!   per-vertex work is message-dominated, the configured vertex-centric
//!   policy otherwise (plus an FCFS upgrade under heavy flush skew on
//!   the sharded substrate);
//! - **(b) the combining strategy** — the paper's hybrid combiner when
//!   fan-in or measured contention justify its lock-free combining, the
//!   plain lock design when mailboxes are effectively private. The tuner
//!   only moves between [`Strategy::Lock`] and [`Strategy::Hybrid`],
//!   whose slot disciplines are interchangeable mid-run;
//!   [`Strategy::CasNeutral`] changes the mailbox *representation*
//!   (pre-loaded neutral element, no empty flag) and is therefore never
//!   entered or left adaptively;
//! - **(c) dense-frontier bypass** — the explicit active list while the
//!   frontier is sparse, the full scan once it is dense enough that list
//!   maintenance costs more than the activity checks it saves.
//!
//! **Bit-identity.** Every knob the tuner touches is an *execution*
//! knob: none of them changes which vertices run, what they observe, or
//! what gets delivered (the Strategy × Layout × Schedule × Partitioning
//! parity grid pins this for fixed configs, and
//! `rust/tests/test_adaptive.rs` extends the grid to adaptive runs).
//! Adaptive runs therefore produce bit-identical values *and* identical
//! superstep traces to any fixed configuration.
//!
//! **Hysteresis.** Each knob has a two-sided threshold band (switch up
//! at `hi`, down at `lo`, hold in between) plus a per-knob dwell
//! counter: after a switch the knob is frozen for
//! [`DecisionTable::dwell`] supersteps. A signal oscillating around a
//! single threshold therefore cannot make the tuner flip-flop.
//!
//! **Calibration.** The thresholds live in a [`DecisionTable`] derived
//! from the virtual testbed's [`CostModel`]
//! ([`DecisionTable::from_cost_model`]) — the same constants that price
//! simulated runs decide real ones, so the simulator
//! ([`crate::sim::SimEngine`] with `EngineConfig::adaptive`) and the
//! real engine share one decision table and their traces can be
//! compared like for like.

use crate::combine::{ContentionProbe, Strategy};
use crate::engine::{EngineConfig, Mode};
use crate::metrics::TunerDecision;
use crate::sched::{Schedule, DEFAULT_CHUNK};
use crate::sim::CostModel;
use crate::util::CachePadded;

/// The knob selection for one superstep. Fixed-config runs use
/// [`StepPlan::of`] (the `EngineConfig` verbatim) every superstep;
/// adaptive runs get a fresh plan from [`AdaptiveTuner::decide`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepPlan {
    /// Work-distribution policy for this superstep.
    pub schedule: Schedule,
    /// Mailbox synchronisation design for this superstep.
    pub strategy: Strategy,
    /// Explicit active list (`true`) vs full scan (`false`).
    pub bypass: bool,
    /// Software-prefetch look-ahead (vertices) in the scatter/gather hot
    /// loops; `0` means "auto" ([`DEFAULT_PIPELINE_DEPTH`], or the
    /// tuner's table value on adaptive runs). A pure memory-system knob:
    /// prefetch hints never change results.
    pub pipeline_depth: usize,
    /// Successive single-item steals per steal episode under
    /// work-stealing shard dispatch; `0` means "auto" (1, or the tuner's
    /// table value). Execution-placement only — see
    /// [`crate::sched::steal`].
    pub steal_chunk: usize,
}

/// Prefetch look-ahead used when [`StepPlan::pipeline_depth`] is left on
/// auto — the depth the pre-tunable engine hard-coded in its Pull-mode
/// slot prefetch.
pub const DEFAULT_PIPELINE_DEPTH: usize = 8;

impl StepPlan {
    /// The fixed plan an `EngineConfig` describes.
    pub fn of(cfg: &EngineConfig) -> StepPlan {
        StepPlan {
            schedule: cfg.schedule,
            strategy: cfg.strategy,
            bypass: cfg.bypass,
            pipeline_depth: cfg.pipeline_depth,
            steal_chunk: 0,
        }
    }

    /// The prefetch depth to actually use (resolves auto).
    pub fn effective_pipeline_depth(&self) -> usize {
        if self.pipeline_depth == 0 {
            DEFAULT_PIPELINE_DEPTH
        } else {
            self.pipeline_depth
        }
    }

    /// The steal-episode length to actually use (resolves auto).
    pub fn effective_steal_chunk(&self) -> usize {
        self.steal_chunk.max(1)
    }
}

/// Calibrated decision thresholds shared by the real engine and the
/// simulator. Derive one from a [`CostModel`] (the calibration path) or
/// take [`DecisionTable::default`], which is
/// `from_cost_model(&CostModel::default())` — the compiled-in constants
/// measured by `ipregel calibrate`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecisionTable {
    /// Frontier density at/above which the full scan replaces the active
    /// list (dense-frontier bypass-off).
    pub scan_density_hi: f64,
    /// Frontier density at/below which the active list replaces the full
    /// scan. Strictly below [`DecisionTable::scan_density_hi`] — the gap
    /// is the hysteresis band.
    pub list_density_lo: f64,
    /// Messages per active vertex at/above which edge-centric dispatch
    /// wins (per-vertex work is edge-dominated, so vertex-count cuts
    /// misbalance).
    pub edge_msgs_hi: f64,
    /// Messages per active vertex at/below which the vertex-centric
    /// policy returns.
    pub edge_msgs_lo: f64,
    /// Mean mailbox fan-in at/above which the hybrid combiner's
    /// amortised first-push beats the lock design.
    pub fanin_hybrid_hi: f64,
    /// Mean mailbox fan-in at/below which the plain lock design is
    /// selected (no fan-in to amortise over).
    pub fanin_lock_lo: f64,
    /// Measured (CAS retries + contended lock acquisitions) per message
    /// above which the tuner treats mailboxes as contended regardless of
    /// mean fan-in (a few hub vertices can be hammered while the mean
    /// stays low).
    pub contention_hi: f64,
    /// Max-over-mean cross-shard flush load above which shard dispatch
    /// is upgraded from static to FCFS claiming.
    pub flush_imbalance_hi: f64,
    /// Prefetch look-ahead (vertices) the memory model recommends: deep
    /// enough to cover one full cache-miss latency with hot-access work.
    pub pipeline_depth: usize,
    /// Single-item steals per steal episode: enough to amortise one
    /// steal's claim cost against per-item work.
    pub steal_chunk: usize,
    /// Vector-gather lane utilisation (useful lanes / scanned lanes)
    /// below which the prefetch window is widened — mostly-empty lanes
    /// mean the gather is ranging over cold, sparse rows.
    pub lane_util_lo: f64,
    /// Consecutive untouched barriers a decoded row block survives
    /// before the compressed row plane recycles its scratch
    /// ([`crate::graph::RowPolicy::cold_rounds`]). Derived from the
    /// decode price: expensive decodes earn longer residency.
    pub row_cold_rounds: u32,
    /// Supersteps a knob is frozen after switching (anti-flip-flop).
    pub dwell: usize,
}

impl DecisionTable {
    /// Derive thresholds from the virtual testbed's cost constants, so
    /// the simulator and the real engine decide from one table.
    pub fn from_cost_model(c: &CostModel) -> DecisionTable {
        // Bypass break-even: maintaining the active list costs one store
        // per activation; scanning costs half a hot access per visited
        // vertex (the sim's activity-check price). The list wins while
        //   density * t_store < (1 - density) * 0.5 * t_access_hit.
        let scan_check = 0.5 * c.t_access_hit;
        let d_star = scan_check / (c.t_store + scan_check);
        let scan_density_hi = (d_star * 1.25).min(0.9);
        let list_density_lo = (d_star * 0.75).max(0.05);

        // Strategy break-even: smallest mailbox fan-in where the hybrid
        // combiner (one locked first push amortised over c-1 CAS
        // combines) beats the lock design by a 5% margin, in the
        // hub-degenerate contention scenario `delivery_cost` models.
        // No break-even up to 64 means this model says hybrid never
        // pays: leave the threshold at infinity so fan-in alone can
        // never select it (measured contention still can).
        let mut fanin_hybrid_hi = f64::INFINITY;
        for cand in 2u32..=64 {
            let lock = c.delivery_cost(Strategy::Lock, cand, 32, cand as u64);
            let hybrid = c.delivery_cost(Strategy::Hybrid, cand, 32, cand as u64);
            if hybrid * 1.05 < lock {
                fanin_hybrid_hi = cand as f64;
                break;
            }
        }
        let fanin_lock_lo = 1.0 + (fanin_hybrid_hi - 1.0) * 0.5;

        // Edge-centric break-even: degree-weighted cuts pay roughly two
        // stores per item (prefix sum + cut search) and only help when
        // the work they balance — per-message combine + store — dwarfs
        // the fixed per-vertex overhead they cannot balance.
        let edge_msgs_hi = (2.0 * c.t_vertex / (c.t_combine + c.t_store)).max(2.0);
        let edge_msgs_lo = edge_msgs_hi * 0.5;

        DecisionTable {
            scan_density_hi,
            list_density_lo,
            edge_msgs_hi,
            edge_msgs_lo,
            fanin_hybrid_hi,
            fanin_lock_lo,
            // One retry in twenty deliveries: the point where the
            // expected retry overhead stops being measurement noise.
            contention_hi: 0.05,
            // FCFS shard claiming pays one chunk-claim per shard; a 1.5×
            // max-over-mean flush skew reliably buys that back.
            flush_imbalance_hi: 1.5,
            // Cover one miss latency with hot-access work, doubled
            // because roughly every other prefetched line is already
            // resident on the dense paths this knob serves.
            pipeline_depth: (((c.t_miss / c.t_access_hit).ceil() as usize) * 2).clamp(2, 32),
            // One steal claim (CAS + fence) per `chunk` items of
            // per-vertex work keeps steal overhead under t_vertex.
            steal_chunk: ((c.t_steal / c.t_vertex).ceil() as usize).clamp(1, 8),
            lane_util_lo: 0.25,
            // Cold-block retention break-even: holding a decoded block
            // for one more barrier costs roughly its cache footprint
            // (a handful of misses when the frontier sweeps past);
            // evicting too early re-pays the block fault. Retain for
            // fault / (4 misses) barriers, banded to [2, 8].
            row_cold_rounds: ((c.t_row_fault / (4.0 * c.t_miss)).ceil() as u32).clamp(2, 8),
            dwell: 2,
        }
    }
}

impl Default for DecisionTable {
    fn default() -> Self {
        Self::from_cost_model(&CostModel::default())
    }
}

/// The pooled allocation bundle behind an [`AdaptiveTuner`]: per-worker
/// contention probes and the decision-trace buffer. Sessions pool one
/// per [`crate::engine::GraphSession`] and recycle it across adaptive
/// runs, exactly like stores and delivery planes.
#[derive(Default)]
pub struct TunerState {
    /// One probe per worker, cache-padded so the counters never become
    /// the contention they measure.
    probes: Vec<CachePadded<ContentionProbe>>,
    /// Decision trace of the current run, drained into
    /// `RunMetrics::tuner_decisions` at run end.
    trace: Vec<TunerDecision>,
}

impl TunerState {
    /// Grow to at least `workers` probes (never shrinks — pooled state
    /// serves any smaller run).
    fn ensure_workers(&mut self, workers: usize) {
        if self.probes.len() < workers {
            self.probes
                .resize_with(workers, || CachePadded::new(ContentionProbe::new()));
        }
    }

    /// Re-prime for a fresh run: clear the trace, zero every probe.
    fn reset(&mut self) {
        self.trace.clear();
        for p in &self.probes {
            let _ = p.take();
        }
    }
}

/// The per-run adaptive controller. Owned by the engine for the duration
/// of one run; its [`TunerState`] goes back to the session pool
/// afterwards. See the [module docs](self) for the decision model.
pub struct AdaptiveTuner {
    table: DecisionTable,
    /// The configured plan — superstep 0's plan (no live signals exist
    /// before the first barrier) and the anchor the trace is read
    /// against.
    base: StepPlan,
    /// The vertex-centric policy the schedule knob falls back to (the
    /// configured schedule, or dynamic chunking when the config itself
    /// is edge-centric).
    vertex_schedule: Schedule,
    cur: StepPlan,
    /// Whether the strategy knob may move (push-mode, combined-plane,
    /// non-CasNeutral runs only — see the module docs).
    strategy_tunable: bool,
    /// Whether edge-centric full scans have precomputed degree weights
    /// available (flat substrate; the sharded scatter always weighs
    /// whole shards from the plan).
    can_edge_scan: bool,
    partitioned: bool,
    /// Whether the pipeline-depth knob is on auto (config left it 0);
    /// an explicit `--pipeline-depth` pins it for the whole run.
    auto_depth: bool,
    /// Whether work-stealing dispatch is on (`EngineConfig::steal`): the
    /// steal-granularity knob only means anything then.
    steal_enabled: bool,
    // Per-knob dwell counters (supersteps left before the knob may move).
    cool_bypass: usize,
    cool_schedule: usize,
    cool_strategy: usize,
    cool_depth: usize,
    // Signals observed at the previous barrier.
    last_messages: u64,
    /// Messages of the superstep before last — the send generation whose
    /// consumers `last_delivered` counted (a send is consumed one
    /// superstep after it is made, so the fan-in quotient must pair
    /// across that one-superstep lag).
    prev_messages: u64,
    last_delivered: u64,
    last_contention: u64,
    last_flush_imbalance: f64,
    /// Successful steals in the previous superstep (0 when stealing is
    /// off): steals mean the seeded cut misjudged the load, so episodes
    /// are lengthened to amortise the victim scans.
    last_steals: u64,
    /// Vector-gather lane utilisation of the previous superstep (1.0
    /// until a gather runs): sparse lanes widen the prefetch window.
    last_lane_util: f64,
    /// Active count of the superstep currently executing (denominator
    /// for the next decision's messages-per-active signal).
    last_active: usize,
    seen_barrier: bool,
    state: TunerState,
}

impl AdaptiveTuner {
    /// Controller for one run. `workers` sizes the probe array;
    /// `can_edge_scan` reports whether flat full scans have cached
    /// degree weights (sessions always provide them on adaptive flat
    /// runs; the guard keeps a mis-assembled engine from panicking in
    /// `Schedule::chunks`).
    pub(crate) fn new(
        cfg: &EngineConfig,
        mode: Mode,
        is_log: bool,
        partitioned: bool,
        can_edge_scan: bool,
        mut state: TunerState,
        workers: usize,
    ) -> AdaptiveTuner {
        state.ensure_workers(workers);
        state.reset();
        let base = StepPlan::of(cfg);
        AdaptiveTuner {
            table: DecisionTable::default(),
            base,
            vertex_schedule: match cfg.schedule {
                Schedule::EdgeCentric => Schedule::Dynamic {
                    chunk: DEFAULT_CHUNK,
                },
                s => s,
            },
            cur: base,
            strategy_tunable: mode == Mode::Push && !is_log && cfg.strategy != Strategy::CasNeutral,
            can_edge_scan,
            partitioned,
            auto_depth: cfg.pipeline_depth == 0,
            steal_enabled: cfg.steal,
            cool_bypass: 0,
            cool_schedule: 0,
            cool_strategy: 0,
            cool_depth: 0,
            last_messages: 0,
            prev_messages: 0,
            last_delivered: 0,
            last_contention: 0,
            last_flush_imbalance: 1.0,
            last_steals: 0,
            last_lane_util: 1.0,
            last_active: 0,
            seen_barrier: false,
            state,
        }
    }

    /// Override the decision table (e.g. with thresholds derived from a
    /// freshly calibrated or deliberately skewed cost model).
    pub(crate) fn with_table(mut self, table: DecisionTable) -> AdaptiveTuner {
        self.table = table;
        self
    }

    /// The per-worker contention probes (engine hands `probes()[tid]` to
    /// each worker's context).
    pub(crate) fn probes(&self) -> &[CachePadded<ContentionProbe>] {
        &self.state.probes
    }

    /// Select the plan for the superstep about to run. `active` is the
    /// frontier size (known before compute), `n` the vertex count; every
    /// other signal comes from the previous barrier's
    /// [`AdaptiveTuner::observe`].
    pub(crate) fn decide(&mut self, superstep: usize, active: usize, n: usize) -> StepPlan {
        let density = active as f64 / n.max(1) as f64;
        let msgs_per_active = if self.seen_barrier && self.last_active > 0 {
            self.last_messages as f64 / self.last_active as f64
        } else {
            0.0
        };
        // Generation-matched fan-in: `last_delivered` counts the
        // recipients that consumed the superstep-before-last's sends
        // (`prev_messages`) — dividing this superstep's send volume by
        // last superstep's consumers would wildly overestimate fan-in
        // while the frontier grows.
        let fan_in = if self.seen_barrier && self.last_delivered > 0 && self.prev_messages > 0 {
            self.prev_messages as f64 / self.last_delivered as f64
        } else {
            0.0
        };
        let contention_per_msg = if self.seen_barrier && self.last_messages > 0 {
            self.last_contention as f64 / self.last_messages as f64
        } else {
            0.0
        };

        let mut plan = self.cur;
        if self.seen_barrier {
            self.cool_bypass = self.cool_bypass.saturating_sub(1);
            self.cool_schedule = self.cool_schedule.saturating_sub(1);
            self.cool_strategy = self.cool_strategy.saturating_sub(1);
            self.cool_depth = self.cool_depth.saturating_sub(1);

            // (c) dense-frontier bypass: two-sided density band.
            if self.cool_bypass == 0 {
                let want = if density >= self.table.scan_density_hi {
                    false
                } else if density <= self.table.list_density_lo {
                    true
                } else {
                    plan.bypass
                };
                if want != plan.bypass {
                    plan.bypass = want;
                    self.cool_bypass = self.table.dwell;
                }
            }

            // (a) vertex- vs edge-centric dispatch. Edge-centric full
            // scans need precomputed weights; in list mode the weights
            // are rebuilt from the (sparse) active list — the documented
            // §V-A fallback, cheap exactly when the tuner would pick it.
            if self.cool_schedule == 0 {
                let edge_ok = self.partitioned || plan.bypass || self.can_edge_scan;
                let mut want = if msgs_per_active >= self.table.edge_msgs_hi && edge_ok {
                    Schedule::EdgeCentric
                } else if msgs_per_active <= self.table.edge_msgs_lo {
                    self.vertex_schedule
                } else {
                    plan.schedule
                };
                // Heavy cross-shard flush skew: static shard assignment
                // strands workers behind one hot destination shard —
                // upgrade to FCFS claiming.
                if self.partitioned
                    && want == Schedule::Static
                    && self.last_flush_imbalance >= self.table.flush_imbalance_hi
                {
                    want = Schedule::Dynamic {
                        chunk: DEFAULT_CHUNK,
                    };
                }
                if want != plan.schedule {
                    plan.schedule = want;
                    self.cool_schedule = self.table.dwell;
                }
            }

            // (d) memory-system knobs. Value-safe by construction
            // (prefetch hints and execution placement only), so no
            // bit-identity stakes — just throughput.
            if self.auto_depth && self.cool_depth == 0 {
                // Base depth from the memory model; widen it while the
                // vector gather reports mostly-empty lanes (sparse cold
                // rows need a longer window to hide their misses).
                let mut want = self.table.pipeline_depth;
                if self.last_lane_util < self.table.lane_util_lo {
                    want = (want * 2).min(32);
                }
                if want != plan.pipeline_depth {
                    plan.pipeline_depth = want;
                    self.cool_depth = self.table.dwell;
                }
            }
            if self.steal_enabled {
                // Steals observed: the seeded cut misjudged this phase's
                // load, so lengthen the episodes to amortise the victim
                // scans. No dwell — the knob is contention-free to move.
                let mut want = self.table.steal_chunk;
                if self.last_steals > 0 {
                    want = (want * 2).min(16);
                }
                plan.steal_chunk = want;
            }

            // (b) lock vs hybrid combining.
            if self.strategy_tunable && self.cool_strategy == 0 {
                let contended = contention_per_msg >= self.table.contention_hi;
                let want = if fan_in >= self.table.fanin_hybrid_hi || contended {
                    Strategy::Hybrid
                } else if fan_in > 0.0 && fan_in <= self.table.fanin_lock_lo && !contended {
                    Strategy::Lock
                } else {
                    plan.strategy
                };
                if want != plan.strategy {
                    plan.strategy = want;
                    self.cool_strategy = self.table.dwell;
                }
            }
        }

        let switched = self
            .state
            .trace
            .last()
            .is_some_and(|d| d.mode() != (plan.schedule, plan.strategy, plan.bypass));
        self.state.trace.push(TunerDecision {
            superstep,
            schedule: plan.schedule,
            strategy: plan.strategy,
            bypass: plan.bypass,
            frontier_density: density,
            msgs_per_active,
            fan_in,
            contention_per_msg,
            flush_imbalance: self.last_flush_imbalance,
            steals: self.last_steals,
            lane_utilisation: self.last_lane_util,
            pipeline_depth: plan.effective_pipeline_depth(),
            steal_chunk: plan.effective_steal_chunk(),
            switched,
        });
        self.cur = plan;
        self.last_active = active;
        plan
    }

    /// Feed the just-finished superstep's signals back at the barrier:
    /// total messages, recipients that consumed a payload, the
    /// cross-shard flush max-over-mean (1.0 when flat or nothing
    /// flushed), successful steals (0 when stealing is off), and
    /// vector-gather lane utilisation (1.0 when no gather ran). Drains
    /// the per-worker contention probes.
    pub(crate) fn observe(
        &mut self,
        messages: u64,
        delivered: u64,
        flush_imbalance: f64,
        steals: u64,
        lane_utilisation: f64,
    ) {
        let mut contention = 0u64;
        for p in &self.state.probes {
            let (retries, contended) = p.take();
            contention += retries + contended;
        }
        self.prev_messages = self.last_messages;
        self.last_messages = messages;
        self.last_delivered = delivered;
        self.last_contention = contention;
        self.last_flush_imbalance = flush_imbalance;
        self.last_steals = steals;
        self.last_lane_util = lane_utilisation;
        self.seen_barrier = true;
    }

    /// Drain the decision trace (into `RunMetrics::tuner_decisions`).
    pub(crate) fn take_trace(&mut self) -> Vec<TunerDecision> {
        std::mem::take(&mut self.state.trace)
    }

    /// Disassemble into the poolable state bundle.
    pub(crate) fn into_state(self) -> TunerState {
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tuner(cfg: &EngineConfig) -> AdaptiveTuner {
        AdaptiveTuner::new(cfg, Mode::Push, false, false, true, TunerState::default(), 2)
    }

    #[test]
    fn default_table_is_the_sim_cost_models_table() {
        // The calibration contract: the engine's default thresholds ARE
        // the simulator's — one decision table.
        assert_eq!(
            DecisionTable::default(),
            DecisionTable::from_cost_model(&CostModel::default())
        );
        let t = DecisionTable::default();
        assert!(t.list_density_lo < t.scan_density_hi, "hysteresis band");
        assert!(t.edge_msgs_lo < t.edge_msgs_hi);
        assert!(t.fanin_lock_lo < t.fanin_hybrid_hi);
        assert!(t.dwell >= 1);
        assert!((2..=8).contains(&t.row_cold_rounds), "retention band");
    }

    #[test]
    fn superstep_zero_runs_the_configured_plan() {
        let cfg = EngineConfig::default().bypass(false);
        let mut t = tuner(&cfg);
        // 1 active vertex out of 1000 — far below the list threshold, but
        // there are no live signals yet: the base plan applies verbatim.
        let plan = t.decide(0, 1, 1000);
        assert_eq!(plan, StepPlan::of(&cfg));
        assert!(!t.take_trace()[0].switched);
    }

    #[test]
    fn sparse_frontier_switches_to_the_active_list_after_first_barrier() {
        let cfg = EngineConfig::default().bypass(false);
        let mut t = tuner(&cfg);
        t.decide(0, 1, 1000);
        t.observe(10, 10, 1.0, 0, 1.0);
        let plan = t.decide(1, 5, 1000);
        assert!(plan.bypass, "density 0.005 is deep in list territory");
        let trace = t.take_trace();
        assert!(trace[1].switched);
        assert_eq!(trace[1].superstep, 1);
    }

    #[test]
    fn dense_frontier_switches_to_the_full_scan() {
        let cfg = EngineConfig::default().bypass(true);
        let mut t = tuner(&cfg);
        t.decide(0, 900, 1000);
        t.observe(1000, 900, 1.0, 0, 1.0);
        let plan = t.decide(1, 950, 1000);
        assert!(!plan.bypass, "density 0.95 is scan territory");
    }

    #[test]
    fn hysteresis_band_holds_the_previous_choice() {
        let cfg = EngineConfig::default().bypass(true);
        let table = DecisionTable::default();
        let mid = (table.scan_density_hi + table.list_density_lo) / 2.0;
        let mut t = tuner(&cfg);
        t.decide(0, 10, 1000);
        for s in 1..6 {
            t.observe(10, 10, 1.0, 0, 1.0);
            let plan = t.decide(s, (mid * 1000.0) as usize, 1000);
            assert!(plan.bypass, "mid-band density must not move the knob");
        }
        assert_eq!(t.take_trace().iter().filter(|d| d.switched).count(), 0);
    }

    #[test]
    fn dwell_freezes_a_knob_after_a_switch() {
        let cfg = EngineConfig::default().bypass(false);
        let mut t = tuner(&cfg);
        t.decide(0, 1, 1000);
        t.observe(10, 10, 1.0, 0, 1.0);
        let p1 = t.decide(1, 5, 1000);
        assert!(p1.bypass, "sparse: switch to list");
        // Immediately dense again — but the knob just moved and must
        // dwell, then move only after the cooldown expires.
        t.observe(10, 10, 1.0, 0, 1.0);
        let p2 = t.decide(2, 950, 1000);
        assert!(p2.bypass, "dwell holds the switch");
        t.observe(10, 10, 1.0, 0, 1.0);
        let p3 = t.decide(3, 950, 1000);
        assert!(!p3.bypass, "cooldown expired: dense wins");
    }

    #[test]
    fn high_fan_in_selects_hybrid_and_low_fan_in_returns_to_lock() {
        let cfg = EngineConfig::default(); // Strategy::Lock base
        let mut t = tuner(&cfg);
        t.decide(0, 500, 1000);
        // Superstep 0 sent 5000 messages; nothing consumed yet, so the
        // fan-in signal is still silent and the strategy must hold.
        t.observe(5000, 0, 1.0, 0, 1.0);
        let plan = t.decide(1, 500, 1000);
        assert_eq!(plan.strategy, Strategy::Lock, "no consumers observed yet");
        // Superstep 1: 500 recipients consumed those 5000 sends —
        // generation-matched fan-in 10 ≫ threshold.
        t.observe(5000, 500, 1.0, 0, 1.0);
        let plan = t.decide(2, 500, 1000);
        assert_eq!(plan.strategy, Strategy::Hybrid);
        // Fan-in collapses to 1: after the dwell, lock returns.
        for s in 3..6 {
            t.observe(500, 500, 1.0, 0, 1.0);
            t.decide(s, 500, 1000);
        }
        assert_eq!(t.cur.strategy, Strategy::Lock);
    }

    #[test]
    fn cas_neutral_strategy_is_never_touched() {
        let cfg = EngineConfig::default().strategy(Strategy::CasNeutral);
        let mut t = tuner(&cfg);
        t.decide(0, 500, 1000);
        t.observe(50_000, 0, 1.0, 0, 1.0);
        t.decide(1, 500, 1000);
        t.observe(50_000, 500, 1.0, 0, 1.0); // generation-matched fan-in 100
        let plan = t.decide(2, 500, 1000);
        assert_eq!(
            plan.strategy,
            Strategy::CasNeutral,
            "CasNeutral changes the slot representation; the tuner must not leave it"
        );
    }

    #[test]
    fn message_heavy_supersteps_select_edge_centric_dispatch() {
        let cfg = EngineConfig::default();
        let mut t = tuner(&cfg);
        t.decide(0, 100, 1000);
        // 100 active sent 5000 messages: 50 msgs/active ≫ edge_msgs_hi.
        t.observe(5000, 800, 1.0, 0, 1.0);
        let plan = t.decide(1, 800, 1000);
        assert_eq!(plan.schedule, Schedule::EdgeCentric);
        // Message volume collapses: vertex-centric returns post-dwell.
        for s in 2..6 {
            t.observe(100, 100, 1.0, 0, 1.0);
            t.decide(s, 100, 1000);
        }
        assert_eq!(t.cur.schedule, Schedule::Static);
    }

    #[test]
    fn edge_centric_scan_requires_weights() {
        let cfg = EngineConfig::default().bypass(false);
        let mut t = AdaptiveTuner::new(
            &cfg,
            Mode::Push,
            false,
            false,
            /* can_edge_scan = */ false,
            TunerState::default(),
            1,
        );
        // Density in the hold band keeps scan mode; message-heavy load
        // wants edge-centric — but scans have no weights, so the knob
        // must stay put.
        t.decide(0, 500, 1000);
        t.observe(50_000, 500, 1.0, 0, 1.0);
        let plan = t.decide(1, 500, 1000);
        assert!(!plan.bypass);
        assert_ne!(plan.schedule, Schedule::EdgeCentric);
    }

    #[test]
    fn flush_skew_upgrades_static_shard_dispatch_to_fcfs() {
        let cfg = EngineConfig::default();
        let mut t = AdaptiveTuner::new(
            &cfg,
            Mode::Push,
            false,
            /* partitioned = */ true,
            true,
            TunerState::default(),
            2,
        );
        t.decide(0, 500, 1000);
        t.observe(1000, 900, /* flush imbalance */ 3.0, 0, 1.0);
        let plan = t.decide(1, 500, 1000);
        assert_eq!(
            plan.schedule,
            Schedule::Dynamic {
                chunk: DEFAULT_CHUNK
            }
        );
        let trace = t.take_trace();
        assert_eq!(trace[1].flush_imbalance, 3.0, "signal lands in the trace");
    }

    #[test]
    fn memory_knobs_follow_the_table_after_first_barrier() {
        let cfg = EngineConfig::default().steal(true);
        let table = DecisionTable::default();
        let mut t = tuner(&cfg);
        // Superstep 0: the configured plan verbatim — knobs on auto.
        let p0 = t.decide(0, 500, 1000);
        assert_eq!(p0.pipeline_depth, 0);
        assert_eq!(p0.steal_chunk, 0);
        assert_eq!(p0.effective_pipeline_depth(), DEFAULT_PIPELINE_DEPTH);
        assert_eq!(p0.effective_steal_chunk(), 1);
        // After a barrier the table values land.
        t.observe(100, 100, 1.0, 0, 1.0);
        let p1 = t.decide(1, 500, 1000);
        assert_eq!(p1.pipeline_depth, table.pipeline_depth);
        assert_eq!(p1.steal_chunk, table.steal_chunk);
    }

    #[test]
    fn sparse_lanes_widen_the_prefetch_window() {
        let cfg = EngineConfig::default();
        let table = DecisionTable::default();
        let mut t = tuner(&cfg);
        t.decide(0, 500, 1000);
        // Lane utilisation far below the floor: depth doubles (capped).
        t.observe(100, 100, 1.0, 0, 0.05);
        let plan = t.decide(1, 500, 1000);
        assert_eq!(plan.pipeline_depth, (table.pipeline_depth * 2).min(32));
        // Dwell holds the widened window even after lanes fill back up.
        t.observe(100, 100, 1.0, 0, 1.0);
        let plan = t.decide(2, 500, 1000);
        assert_eq!(plan.pipeline_depth, (table.pipeline_depth * 2).min(32));
    }

    #[test]
    fn observed_steals_lengthen_the_episode() {
        let cfg = EngineConfig::default().steal(true);
        let table = DecisionTable::default();
        let mut t = tuner(&cfg);
        t.decide(0, 500, 1000);
        t.observe(100, 100, 1.0, /* steals */ 7, 1.0);
        let plan = t.decide(1, 500, 1000);
        assert_eq!(plan.steal_chunk, (table.steal_chunk * 2).min(16));
        // Steals stop: back to the table value.
        t.observe(100, 100, 1.0, 0, 1.0);
        let plan = t.decide(2, 500, 1000);
        assert_eq!(plan.steal_chunk, table.steal_chunk);
    }

    #[test]
    fn explicit_pipeline_depth_pins_the_knob() {
        let cfg = EngineConfig::default().pipeline_depth(3);
        let mut t = tuner(&cfg);
        let p0 = t.decide(0, 500, 1000);
        assert_eq!(p0.effective_pipeline_depth(), 3);
        t.observe(100, 100, 1.0, 0, 0.01); // would widen on auto
        let p1 = t.decide(1, 500, 1000);
        assert_eq!(p1.pipeline_depth, 3, "explicit depth is never retuned");
    }

    #[test]
    fn pooled_state_is_reset_at_checkout() {
        let cfg = EngineConfig::default();
        let mut t = tuner(&cfg);
        t.decide(0, 1, 10);
        t.probes()[0].cas_retries.fetch_add(5, std::sync::atomic::Ordering::Relaxed);
        let state = t.into_state();
        assert!(!state.trace.is_empty());
        let t2 = AdaptiveTuner::new(&cfg, Mode::Push, false, false, true, state, 4);
        assert_eq!(t2.state.trace.len(), 0, "trace cleared");
        assert_eq!(t2.probes().len(), 4, "probe array grown to the run's workers");
        assert_eq!(t2.probes()[0].take(), (0, 0), "probes zeroed");
    }
}
