//! Typed global aggregators (Pregel aggregators, generalised).
//!
//! The seed API hardcoded one `f64` sum per program; this module replaces
//! it with a typed [`Aggregator`] trait: a program declares its aggregator
//! *type* (`VertexProgram::Agg`), vertices [`contribute`] values of the
//! aggregator's `Value` type, the engine merges per-worker partials with
//! [`Aggregator::combine`] at the superstep barrier, and every vertex
//! reads the merged value next superstep via [`aggregated`].
//!
//! Multiple named aggregators compose structurally: pair two aggregators
//! with [`AggPair`] (values travel as a tuple), or define a struct-valued
//! aggregator with [`FnAgg`] whose fields *are* the names. Programs that
//! aggregate nothing use [`NoAgg`] (value `()`, zero cost).
//!
//! [`contribute`]: crate::engine::Context::contribute
//! [`aggregated`]: crate::engine::Context::aggregated

use std::marker::PhantomData;

/// A commutative, associative merge over values of one type, with a
/// neutral element. The engine keeps one padded partial per worker and
/// merges them single-threaded at the barrier, so `combine` needs no
/// interior synchronisation.
pub trait Aggregator: Send + Sync {
    /// The aggregated value type.
    type Value: Clone + Send + Sync + 'static;

    /// Element such that `combine(neutral(), x) == x`.
    fn neutral(&self) -> Self::Value;

    /// Commutative, associative merge of two partials.
    fn combine(&self, a: Self::Value, b: Self::Value) -> Self::Value;
}

/// The no-op aggregator for programs that aggregate nothing.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoAgg;

impl Aggregator for NoAgg {
    type Value = ();

    fn neutral(&self) {}

    fn combine(&self, _a: (), _b: ()) {}
}

/// Sum aggregator over a numeric type.
#[derive(Clone, Copy, Debug, Default)]
pub struct SumAgg<T>(PhantomData<T>);

/// Minimum aggregator over a numeric type.
#[derive(Clone, Copy, Debug, Default)]
pub struct MinAgg<T>(PhantomData<T>);

/// Maximum aggregator over a numeric type.
#[derive(Clone, Copy, Debug, Default)]
pub struct MaxAgg<T>(PhantomData<T>);

impl<T> SumAgg<T> {
    /// The sum aggregator.
    pub const fn new() -> Self {
        SumAgg(PhantomData)
    }
}

impl<T> MinAgg<T> {
    /// The minimum aggregator.
    pub const fn new() -> Self {
        MinAgg(PhantomData)
    }
}

impl<T> MaxAgg<T> {
    /// The maximum aggregator.
    pub const fn new() -> Self {
        MaxAgg(PhantomData)
    }
}

macro_rules! impl_numeric_aggs {
    ($($t:ty => $zero:expr, $min:expr, $max:expr);* $(;)?) => {$(
        impl Aggregator for SumAgg<$t> {
            type Value = $t;
            fn neutral(&self) -> $t {
                $zero
            }
            #[inline]
            fn combine(&self, a: $t, b: $t) -> $t {
                a + b
            }
        }
        impl Aggregator for MinAgg<$t> {
            type Value = $t;
            fn neutral(&self) -> $t {
                $max
            }
            #[inline]
            fn combine(&self, a: $t, b: $t) -> $t {
                if b < a { b } else { a }
            }
        }
        impl Aggregator for MaxAgg<$t> {
            type Value = $t;
            fn neutral(&self) -> $t {
                $min
            }
            #[inline]
            fn combine(&self, a: $t, b: $t) -> $t {
                if b > a { b } else { a }
            }
        }
    )*};
}

impl_numeric_aggs! {
    f64 => 0.0, f64::NEG_INFINITY, f64::INFINITY;
    f32 => 0.0, f32::NEG_INFINITY, f32::INFINITY;
    u64 => 0, u64::MIN, u64::MAX;
    u32 => 0, u32::MIN, u32::MAX;
    i64 => 0, i64::MIN, i64::MAX;
    i32 => 0, i32::MIN, i32::MAX;
    usize => 0, usize::MIN, usize::MAX;
}

/// Two aggregators running side by side; the value is the tuple of both.
/// Nest pairs for three or more, or use [`FnAgg`] with a struct value.
#[derive(Clone, Copy, Debug, Default)]
pub struct AggPair<A, B> {
    /// First component.
    pub a: A,
    /// Second component.
    pub b: B,
}

impl<A, B> AggPair<A, B> {
    /// Pair two aggregators.
    pub const fn new(a: A, b: B) -> Self {
        AggPair { a, b }
    }
}

impl<A: Aggregator, B: Aggregator> Aggregator for AggPair<A, B> {
    type Value = (A::Value, B::Value);

    fn neutral(&self) -> Self::Value {
        (self.a.neutral(), self.b.neutral())
    }

    #[inline]
    fn combine(&self, x: Self::Value, y: Self::Value) -> Self::Value {
        (self.a.combine(x.0, y.0), self.b.combine(x.1, y.1))
    }
}

/// An aggregator defined by a neutral value and a combine closure — the
/// quickest way to aggregate a custom (e.g. named-struct) value type.
pub struct FnAgg<V, F: Fn(V, V) -> V + Send + Sync> {
    neutral: V,
    f: F,
}

impl<V: Clone + Send + Sync + 'static, F: Fn(V, V) -> V + Send + Sync> FnAgg<V, F> {
    /// Aggregator from a neutral element and a merge closure.
    pub fn new(neutral: V, f: F) -> Self {
        FnAgg { neutral, f }
    }
}

impl<V: Clone + Send + Sync + 'static, F: Fn(V, V) -> V + Send + Sync> Aggregator for FnAgg<V, F> {
    type Value = V;

    fn neutral(&self) -> V {
        self.neutral.clone()
    }

    #[inline]
    fn combine(&self, a: V, b: V) -> V {
        (self.f)(a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numeric_aggregators_fold_correctly() {
        let sum = SumAgg::<f64>::new();
        assert_eq!(sum.combine(sum.neutral(), 2.5), 2.5);
        assert_eq!(sum.combine(1.0, 2.0), 3.0);
        let min = MinAgg::<u64>::new();
        assert_eq!(min.combine(min.neutral(), 9), 9);
        assert_eq!(min.combine(4, 9), 4);
        let max = MaxAgg::<i32>::new();
        assert_eq!(max.combine(max.neutral(), -3), -3);
        assert_eq!(max.combine(-3, 7), 7);
    }

    #[test]
    fn pair_aggregates_componentwise() {
        // Two *named* aggregators: total mass (sum) and slowest vertex (max).
        let agg = AggPair::new(SumAgg::<f64>::new(), MaxAgg::<u64>::new());
        let n = agg.neutral();
        let merged = agg.combine(agg.combine(n, (0.5, 3)), (0.25, 11));
        assert_eq!(merged, (0.75, 11));
    }

    #[test]
    fn fn_agg_wraps_custom_values() {
        #[derive(Clone, Debug, PartialEq)]
        struct Stats {
            count: u64,
            total: f64,
        }
        let agg = FnAgg::new(
            Stats { count: 0, total: 0.0 },
            |a: Stats, b: Stats| Stats {
                count: a.count + b.count,
                total: a.total + b.total,
            },
        );
        let m = agg.combine(
            Stats { count: 1, total: 2.0 },
            Stats { count: 2, total: 0.5 },
        );
        assert_eq!(m, Stats { count: 3, total: 2.5 });
    }

    #[test]
    fn no_agg_is_inert() {
        let a = NoAgg;
        a.combine(a.neutral(), ());
    }
}
