//! The vertex-centric execution engine.
//!
//! Users write a [`VertexProgram`] — the classic Pregel single
//! user-defined function — and run it through a [`GraphSession`]: load or
//! build a [`Csr`] once, then execute many programs against it
//! back-to-back (or concurrently) with amortised allocations. Each run
//! executes superstep by superstep under a chosen combination of the
//! paper's optimisations:
//!
//! - **communication mode** ([`Mode`]): `Push` (messages delivered into
//!   recipient mailboxes through a [`Strategy`]) or `Pull` (iPregel's
//!   *single-broadcast* version: vertices publish one message to their own
//!   outbox, recipients combine from in-neighbours, lock-free by design);
//! - **vertex layout** ([`Layout`]): interleaved baseline or externalised;
//! - **work distribution** ([`Schedule`]): static, dynamic, guided or
//!   edge-centric;
//! - **selection bypass** (`bypass`): maintain an explicit active-vertex
//!   list instead of scanning all vertices every superstep;
//! - **partitioning** ([`Partitioning`]): shard the graph into
//!   cache-sized, edge-balanced subgraphs executed scatter/flush/apply
//!   with buffered cross-shard message routing — bit-identical to flat
//!   execution, `Partitioning::None` preserving the flat path;
//! - **adaptive tuning** (`EngineConfig::adaptive`, [`tune`]): re-decide
//!   schedule / strategy / bypass at every superstep barrier from live
//!   signals, with hysteresis and a recorded decision trace —
//!   bit-identical to every fixed configuration.
//!
//! Sessions may also bind to a **mutable** graph
//! ([`GraphSession::dynamic`] over a
//! [`crate::graph::dynamic::DynamicGraph`]): batched edge mutations are
//! applied under mutation epochs ([`session::GraphSession::apply_mutations`]),
//! cached partition plans are patched instead of rebuilt (see
//! [`epoch`]), and runs transparently see the merged base + delta view.
//!
//! Orthogonally to all of the above, a program chooses its **delivery
//! plane** ([`VertexProgram::Delivery`]): [`CombinedPlane`] folds
//! concurrent messages into one mailbox slot through the strategies
//! above, while [`LogPlane`] retains every message in per-vertex
//! append-only logs (per-worker segments merged at the barrier, read
//! back via [`Context::recv`]) — unlocking non-combinable algorithms
//! like label propagation and triangle counting.
//!
//! None of these switches appear in user code — the same program text runs
//! under every configuration, which is the paper's programmability thesis.
//! The v2 API extends the *user-visible* surface without breaking it:
//! weighted-edge iteration ([`Context::out_edge`]), typed composable
//! aggregators ([`agg::Aggregator`]) and composable termination
//! ([`session::Halt`]).

pub mod agg;
pub(crate) mod core;
pub mod epoch;
pub mod session;
pub(crate) mod shard;
pub mod tune;

pub use agg::{AggPair, Aggregator, FnAgg, MaxAgg, MinAgg, NoAgg, SumAgg};
pub use crate::combine::{CombinedPlane, DeliveryPlane, LogPlane};
pub use crate::graph::partition::Partitioning;
pub use epoch::{EpochPin, EpochPins, EpochWatermark};
pub use session::{GraphSession, Halt, PoolStats, RunOptions};
pub use tune::{AdaptiveTuner, DecisionTable, StepPlan};

use crate::combine::{Combiner, MessageValue, Strategy};
use crate::graph::csr::{Csr, EdgeWeight, VertexId};
use crate::layout::Layout;
use crate::metrics::RunMetrics;
use crate::sched::Schedule;

/// The aggregated-value type of a program's aggregator.
pub type AggValue<P> = <<P as VertexProgram>::Agg as Aggregator>::Value;

/// Communication mode of a program (fixed per algorithm, as in iPregel's
/// internal versions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Arbitrary point-to-point sends into recipient mailboxes.
    Push,
    /// Single-broadcast: each vertex may only broadcast one message per
    /// superstep; recipients pull from in-neighbours' outboxes.
    Pull,
}

/// The per-vertex compute context handed to [`VertexProgram::compute`].
///
/// `A` is the program's aggregated-value type ([`AggValue`]); programs
/// without aggregators leave it at the default `()`.
pub trait Context<V, M, A = ()> {
    /// This vertex's id.
    fn id(&self) -> VertexId;
    /// Current superstep number (0-based).
    fn superstep(&self) -> usize;
    /// Total number of vertices in the graph.
    fn num_vertices(&self) -> usize;
    /// Shared borrow of this vertex's value.
    fn value(&self) -> &V;
    /// Exclusive borrow of this vertex's value.
    fn value_mut(&mut self) -> &mut V;
    /// Outgoing neighbours of this vertex.
    fn out_neighbors(&self) -> &[VertexId];
    /// Out-degree of this vertex.
    fn out_degree(&self) -> usize {
        self.out_neighbors().len()
    }
    /// In-degree of this vertex.
    fn in_degree(&self) -> usize;
    /// The `i`-th outgoing edge as `(target, weight)`; weight is `1.0` on
    /// unweighted graphs, so weight-aware programs run on any input.
    /// Returned by value, so `send` can be called inside the loop:
    ///
    /// ```ignore
    /// for i in 0..ctx.out_degree() {
    ///     let (dst, w) = ctx.out_edge(i);
    ///     ctx.send(dst, dist + w);
    /// }
    /// ```
    fn out_edge(&self, i: usize) -> (VertexId, EdgeWeight);
    /// Send `msg` to `dst` (push-mode programs only; a pull-mode program
    /// calling this panics — the same constraint iPregel's
    /// single-broadcast versions impose at compile time).
    fn send(&mut self, dst: VertexId, msg: M);
    /// All messages delivered to this vertex last superstep, for
    /// log-plane programs ([`VertexProgram::Delivery`] = [`LogPlane`]).
    /// The order is unspecified (it depends on worker scheduling), so
    /// fold commutatively. The engine's combined-plane contexts panic
    /// here (the payload arrives pre-folded as `compute`'s `msg`
    /// argument instead — the same loud-failure style as calling
    /// [`Context::send`] from a pull-mode program); the trait default
    /// returns the empty slice for third-party contexts.
    fn recv(&self) -> &[M] {
        &[]
    }
    /// Iterator convenience over [`Context::recv`].
    fn recv_iter(&self) -> std::slice::Iter<'_, M> {
        self.recv().iter()
    }
    /// Broadcast `msg` along all outgoing edges. In pull mode this is one
    /// lock-free store into the vertex's own outbox.
    fn broadcast(&mut self, msg: M);
    /// Vote to halt: stay inactive until a message arrives.
    fn vote_to_halt(&mut self);
    /// Contribute to the program's global aggregator: all contributions of
    /// a superstep are merged with [`Aggregator::combine`] and visible to
    /// every vertex next superstep via [`Context::aggregated`].
    fn contribute(&mut self, x: A);
    /// The merged aggregator value from the previous superstep, if any
    /// vertex contributed.
    fn aggregated(&self) -> Option<&A>;
}

/// A vertex-centric program: Pregel's user-defined function plus the
/// type-level choices (value, message, combiner, aggregator,
/// communication mode).
pub trait VertexProgram: Send + Sync {
    /// Per-vertex state.
    type Value: Clone + Send + Sync + 'static;
    /// Message type.
    type Message: MessageValue;
    /// Message combiner. Log-plane programs, whose messages are never
    /// folded, use the [`crate::combine::NullCombiner`] placeholder.
    type Comb: Combiner<Self::Message>;
    /// Global aggregator ([`NoAgg`] when the program aggregates nothing).
    type Agg: Aggregator;
    /// Message-delivery plane: [`CombinedPlane`] (one combinable mailbox
    /// slot per vertex — the paper's §III machinery and the right choice
    /// whenever a commutative combine exists) or [`LogPlane`]
    /// (per-vertex append-only logs; `compute` reads the full multiset
    /// via [`Context::recv`] — for non-combinable algorithms like label
    /// propagation or triangle counting). Log-plane programs must use
    /// [`Mode::Push`].
    type Delivery: DeliveryPlane<Self::Message>;

    /// Which communication mode this program uses.
    fn mode(&self) -> Mode;

    /// The combiner instance.
    fn combiner(&self) -> Self::Comb;

    /// The aggregator instance.
    fn aggregator(&self) -> Self::Agg;

    /// Initial value of vertex `v`.
    fn init(&self, g: &Csr, v: VertexId) -> Self::Value;

    /// Whether `v` starts active (default: all vertices, as in Pregel).
    fn initially_active(&self, _g: &Csr, _v: VertexId) -> bool {
        true
    }

    /// The user-defined function, applied to each active vertex each
    /// superstep. `msg` is the combined incoming message, if any.
    fn compute<C: Context<Self::Value, Self::Message, AggValue<Self>>>(
        &self,
        ctx: &mut C,
        msg: Option<Self::Message>,
    );
}

/// Engine configuration: the optimisation switches of Table II.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Worker threads (the paper's experiments fix this at 32).
    pub threads: usize,
    /// Work-distribution policy (§V).
    pub schedule: Schedule,
    /// Mailbox synchronisation design (§III; push mode only).
    pub strategy: Strategy,
    /// Vertex attribute layout (§IV).
    pub layout: Layout,
    /// Selection bypass: explicit active list vs full scan.
    pub bypass: bool,
    /// Partitioned execution substrate: cut the graph into cache-sized,
    /// edge-balanced shards with buffered cross-shard routing
    /// ([`Partitioning::None`] preserves the flat engine bit-for-bit).
    pub partitioning: Partitioning,
    /// Adaptive superstep tuning: re-decide schedule / strategy /
    /// bypass at every barrier from live signals ([`tune`]). The
    /// configured values above become the starting plan and the
    /// vertex-centric fallback; results stay bit-identical to any fixed
    /// configuration, and the per-superstep choices are recorded in
    /// [`RunMetrics::tuner_decisions`].
    ///
    /// [`RunMetrics::tuner_decisions`]: crate::metrics::RunMetrics::tuner_decisions
    pub adaptive: bool,
    /// Work-stealing shard dispatch: replace the fixed shard-chunk
    /// assignment of the partitioned scatter/flush loops with per-worker
    /// deques ([`crate::sched::steal`]) so drained workers steal from
    /// the most-loaded peer instead of idling at the barrier. Execution
    /// placement only — results and traces stay bit-identical. Ignored
    /// on the flat substrate.
    pub steal: bool,
    /// Software-prefetch look-ahead (vertices) in the scatter/gather hot
    /// loops; `0` (the default) means auto — [`tune::DEFAULT_PIPELINE_DEPTH`],
    /// or the tuner's per-superstep choice on adaptive runs. Compiled
    /// out entirely under `--features no-prefetch`.
    pub pipeline_depth: usize,
    /// Safety cap on supersteps.
    pub max_supersteps: usize,
    /// Record an execution trace ([`crate::trace`]): per-worker phase
    /// spans, per-shard spans with steal attribution, tuner/steal/epoch
    /// instants and per-superstep irregularity samples, attached to
    /// [`RunMetrics::trace`] and rendered by `--trace-summary` /
    /// `--trace-out`. Off (the default) costs nothing on the hot path;
    /// the `no-trace` feature compiles the recording out entirely.
    /// Values and superstep traces are bit-identical either way.
    ///
    /// [`RunMetrics::trace`]: crate::metrics::RunMetrics::trace
    pub trace: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            threads: 4,
            schedule: Schedule::Static,
            strategy: Strategy::Lock,
            layout: Layout::Interleaved,
            bypass: false,
            partitioning: Partitioning::None,
            adaptive: false,
            steal: false,
            pipeline_depth: 0,
            max_supersteps: 100_000,
            trace: false,
        }
    }
}

impl EngineConfig {
    /// The paper's baseline configuration.
    pub fn baseline() -> Self {
        Self::default()
    }

    /// Builder-style setters.
    pub fn threads(mut self, t: usize) -> Self {
        self.threads = t.max(1);
        self
    }
    /// Set the schedule.
    pub fn schedule(mut self, s: Schedule) -> Self {
        self.schedule = s;
        self
    }
    /// Set the combination strategy.
    pub fn strategy(mut self, s: Strategy) -> Self {
        self.strategy = s;
        self
    }
    /// Set the vertex layout.
    pub fn layout(mut self, l: Layout) -> Self {
        self.layout = l;
        self
    }
    /// Enable/disable selection bypass.
    pub fn bypass(mut self, b: bool) -> Self {
        self.bypass = b;
        self
    }
    /// Set the partitioning policy.
    pub fn partitioning(mut self, p: Partitioning) -> Self {
        self.partitioning = p;
        self
    }
    /// Shorthand: `k` edge-balanced shards (0 restores flat execution).
    pub fn shards(mut self, k: usize) -> Self {
        self.partitioning = if k == 0 {
            Partitioning::None
        } else {
            Partitioning::Shards(k)
        };
        self
    }
    /// Enable/disable adaptive superstep tuning ([`tune`]).
    pub fn adaptive(mut self, a: bool) -> Self {
        self.adaptive = a;
        self
    }
    /// Enable/disable work-stealing shard dispatch.
    pub fn steal(mut self, s: bool) -> Self {
        self.steal = s;
        self
    }
    /// Set the prefetch pipeline depth (`0` = auto).
    pub fn pipeline_depth(mut self, d: usize) -> Self {
        self.pipeline_depth = d;
        self
    }
    /// Cap the number of supersteps.
    pub fn max_supersteps(mut self, n: usize) -> Self {
        self.max_supersteps = n;
        self
    }
    /// Enable/disable execution tracing ([`crate::trace`]).
    pub fn trace(mut self, t: bool) -> Self {
        self.trace = t;
        self
    }
}

/// Result of an engine run: final vertex values plus metrics.
#[derive(Clone, Debug)]
pub struct RunResult<V> {
    /// Final value of each vertex, indexed by id.
    pub values: Vec<V>,
    /// Per-superstep and whole-run statistics.
    pub metrics: RunMetrics,
}
