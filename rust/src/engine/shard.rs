//! Per-shard runtime state for partitioned execution.
//!
//! The partitioned engine (see `engine/core.rs`) replaces the flat
//! engine's three global activity bitsets and single mailbox address
//! space with:
//!
//! - [`ShardedBits`] — one [`AtomicBitSet`] per shard (each with its own
//!   heap allocation, so no two shards' activity words share cache
//!   lines), addressed by *global* vertex id through the plan's owner
//!   map. Intra-shard activations touch only the owning shard's words;
//!   cross-shard activations are rare atomic writes into the target
//!   shard's set.
//! - [`RemoteBuffers`] — a workers × shards grid of append-only message
//!   buffers. During scatter, worker `w` writes only row `w` (no
//!   synchronisation); during flush, the task owning destination shard
//!   `d` drains only column `d`. The two phases are separated by a
//!   barrier, which is what makes the interior-mutable access sound —
//!   the same per-vertex ownership discipline the stores already use,
//!   lifted to shards.
//!
//! A [`ShardState`] bundles the three activity structures and the
//! buffers; the session pools one per partition plan and recycles it
//! across runs (cleared, never reallocated).

use crate::graph::csr::VertexId;
use crate::graph::partition::PartitionPlan;
use crate::layout::SyncCell;
use crate::util::bitset::{AtomicBitSet, BitSet};
use crate::util::CachePadded;
use std::sync::Arc;

/// A buffered cross-shard message: destination vertex plus the message's
/// 64-bit representation ([`crate::combine::MessageValue`] bits), so one
/// buffer type serves every program without generics. Both delivery
/// planes route through it: combined messages are folded
/// owner-exclusively at flush, log messages are appended to the flush
/// task's `MessageLog` segment — same batching, different landing.
pub(crate) type RemoteMsg = (VertexId, u64);

/// Dense per-shard activity bits addressed by global vertex id.
pub(crate) struct ShardedBits {
    plan: Arc<PartitionPlan>,
    sets: Vec<AtomicBitSet>,
}

impl ShardedBits {
    /// All-clear bits shaped to `plan`.
    pub fn new(plan: Arc<PartitionPlan>) -> Self {
        let sets = (0..plan.num_shards())
            .map(|s| AtomicBitSet::new(plan.shard_len(s).max(1)))
            .collect();
        ShardedBits { plan, sets }
    }

    /// Atomically set the bit for global vertex `v` (routes through the
    /// owner map; callable from any worker).
    #[inline]
    pub fn set(&self, v: usize) {
        let s = self.plan.shard_of(v as VertexId);
        self.set_in(s, v);
    }

    /// Atomically set the bit for global vertex `v`, whose owning shard
    /// the caller already knows — the per-message hot path (intra-shard
    /// delivery and flush both have the shard in hand, so this skips the
    /// owner-map load `set` would repeat).
    #[inline]
    pub fn set_in(&self, s: usize, v: usize) {
        debug_assert_eq!(self.plan.shard_of(v as VertexId), s);
        self.sets[s].set(v - self.plan.cuts()[s]);
    }

    /// Total set bits across all shards (quiescent only — the adaptive
    /// tuner reads the frontier size here at the superstep top).
    pub fn count(&self) -> usize {
        self.sets.iter().map(|b| b.count()).sum()
    }

    /// Iterate shard `s`'s set bits as global vertex ids (quiescent only).
    pub fn iter_shard(&self, s: usize) -> impl Iterator<Item = VertexId> + '_ {
        let base = self.plan.cuts()[s];
        self.sets[s].iter().map(move |i| (base + i) as VertexId)
    }

    /// Iterate every set bit across all shards, in ascending global id
    /// order (quiescent only).
    pub fn iter_all(&self) -> impl Iterator<Item = VertexId> + '_ {
        (0..self.sets.len()).flat_map(move |s| self.iter_shard(s))
    }

    /// Snapshot shard `s` into a plain bitset over *local* indices.
    pub fn snapshot_shard(&self, s: usize) -> BitSet {
        self.sets[s].snapshot()
    }

    /// Clear every bit (single-threaded phase).
    pub fn clear_all(&mut self) {
        for b in &mut self.sets {
            b.clear_all();
        }
    }
}

/// Workers × shards cross-shard message buffers (see module docs for the
/// phase discipline that makes the [`SyncCell`] access sound).
pub(crate) struct RemoteBuffers {
    /// Row-major `[worker][shard]` cells, each padded so two workers'
    /// cell headers never share a cache line.
    cells: Vec<CachePadded<SyncCell<Vec<RemoteMsg>>>>,
    workers: usize,
    shards: usize,
}

impl RemoteBuffers {
    /// Empty buffer grid.
    pub fn new(workers: usize, shards: usize) -> Self {
        let workers = workers.max(1);
        let shards = shards.max(1);
        let mut cells = Vec::with_capacity(workers * shards);
        cells.resize_with(workers * shards, || CachePadded::new(SyncCell::new(Vec::new())));
        RemoteBuffers {
            cells,
            workers,
            shards,
        }
    }

    /// Worker rows available.
    #[inline]
    pub fn workers(&self) -> usize {
        self.workers
    }

    #[inline]
    fn cell(&self, w: usize, d: usize) -> &SyncCell<Vec<RemoteMsg>> {
        &self.cells[w * self.shards + d]
    }

    /// Append a message from worker `w` to destination shard `d`.
    /// Scatter phase only: each worker writes its own row exclusively.
    #[inline]
    pub fn push(&self, w: usize, d: usize, msg: RemoteMsg) {
        self.cell(w, d).get_mut().push(msg);
    }

    /// Buffered message count for destination shard `d` (between phases).
    pub fn pending_for(&self, d: usize) -> usize {
        (0..self.workers).map(|w| self.cell(w, d).get().len()).sum()
    }

    /// Per-destination-shard pending counts, in shard order (between
    /// phases). One vector serves both flush-dispatch weighting and
    /// steal-queue seeding, replacing per-shard `pending_for` loops.
    pub fn pending_weights(&self) -> Vec<u64> {
        (0..self.shards)
            .map(|d| self.pending_for(d) as u64)
            .collect()
    }

    /// Drain every worker's buffer for destination shard `d` through
    /// `deliver`, in worker order then push order (deterministic).
    /// Flush phase only: exactly one task owns each destination shard.
    pub fn drain_for(&self, d: usize, mut deliver: impl FnMut(RemoteMsg)) {
        for w in 0..self.workers {
            let buf = self.cell(w, d).get_mut();
            for &m in buf.iter() {
                deliver(m);
            }
            buf.clear();
        }
    }

    /// Clear every cell, keeping capacity (pool recycling).
    pub fn clear_all(&mut self) {
        for c in &mut self.cells {
            c.get_mut().clear();
        }
    }
}

/// The pooled bundle of per-shard runtime state for one partition plan.
pub(crate) struct ShardState {
    /// The plan this state is shaped to.
    pub plan: Arc<PartitionPlan>,
    /// Vertices active next superstep.
    pub active: ShardedBits,
    /// Pull mode: broadcasters of this superstep.
    pub bcast_next: ShardedBits,
    /// Pull mode: broadcasters of the previous superstep.
    pub bcast_cur: ShardedBits,
    /// Cross-shard message buffers.
    pub buffers: RemoteBuffers,
}

impl ShardState {
    /// Fresh state for `plan` with `workers` buffer rows.
    pub fn new(plan: Arc<PartitionPlan>, workers: usize) -> Self {
        ShardState {
            active: ShardedBits::new(Arc::clone(&plan)),
            bcast_next: ShardedBits::new(Arc::clone(&plan)),
            bcast_cur: ShardedBits::new(Arc::clone(&plan)),
            buffers: RemoteBuffers::new(workers, plan.num_shards()),
            plan,
        }
    }

    /// Whether this pooled state can serve a run over `plan` with
    /// `workers` workers without reallocation.
    pub fn fits(&self, plan: &Arc<PartitionPlan>, workers: usize) -> bool {
        Arc::ptr_eq(&self.plan, plan) && self.buffers.workers() >= workers.max(1)
    }

    /// Swap in an epoch-patched plan with identical shard boundaries
    /// (see `engine/epoch.rs`): after a mutation batch the session
    /// replaces each cached plan with a census-patched copy, and pooled
    /// shard state keeps fitting by following the pointer. The inner
    /// [`ShardedBits`] keep their original `Arc` — they only consult the
    /// cuts/owner map, which patching never changes — so the slabs need
    /// no touch at all.
    pub fn repoint_plan(&mut self, plan: Arc<PartitionPlan>) {
        debug_assert_eq!(
            self.plan.cuts(),
            plan.cuts(),
            "repoint requires identical shard boundaries"
        );
        self.plan = plan;
    }

    /// Clear all activity and buffers for reuse (keeps allocations).
    pub fn reset(&mut self) {
        self.active.clear_all();
        self.bcast_next.clear_all();
        self.bcast_cur.clear_all();
        self.buffers.clear_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::graph::partition::PartitionPlan;

    fn plan4() -> Arc<PartitionPlan> {
        Arc::new(PartitionPlan::build(&gen::grid(8, 8), 4))
    }

    #[test]
    fn sharded_bits_route_globally() {
        let plan = plan4();
        let mut bits = ShardedBits::new(Arc::clone(&plan));
        let n = plan.num_vertices();
        bits.set(0);
        bits.set(n - 1);
        bits.set(n / 2);
        assert_eq!(bits.count(), 3);
        let all: Vec<VertexId> = bits.iter_all().collect();
        assert_eq!(all, vec![0, (n / 2) as VertexId, (n - 1) as VertexId]);
        // Per-shard iteration yields ids inside the shard's range.
        for s in 0..plan.num_shards() {
            for v in bits.iter_shard(s) {
                assert!(plan.shard_range(s).contains(&(v as usize)));
            }
        }
        bits.clear_all();
        assert_eq!(bits.count(), 0);
    }

    #[test]
    fn remote_buffers_drain_in_worker_then_push_order() {
        let bufs = RemoteBuffers::new(3, 2);
        bufs.push(2, 1, (10, 100));
        bufs.push(0, 1, (11, 101));
        bufs.push(0, 1, (12, 102));
        bufs.push(1, 0, (13, 103));
        assert_eq!(bufs.pending_for(1), 3);
        assert_eq!(bufs.pending_for(0), 1);
        assert_eq!(bufs.pending_weights(), vec![1, 3]);
        let mut seen = Vec::new();
        bufs.drain_for(1, |m| seen.push(m));
        assert_eq!(seen, vec![(11, 101), (12, 102), (10, 100)]);
        assert_eq!(bufs.pending_for(1), 0);
        assert_eq!(bufs.pending_for(0), 1, "other shard untouched");
    }

    #[test]
    fn shard_state_resets_for_reuse() {
        let plan = plan4();
        let mut st = ShardState::new(Arc::clone(&plan), 2);
        st.active.set(5);
        st.bcast_next.set(6);
        st.buffers.push(0, 0, (1, 2));
        assert!(st.fits(&plan, 2));
        assert!(st.fits(&plan, 1));
        assert!(!st.fits(&plan, 3), "needs more worker rows");
        st.reset();
        assert_eq!(st.active.count(), 0);
        assert_eq!(st.bcast_next.count(), 0);
        assert_eq!(st.buffers.pending_for(0), 0);
    }
}
