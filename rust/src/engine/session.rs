//! Long-lived, reusable execution sessions.
//!
//! A [`GraphSession`] binds to one [`Csr`] and runs many
//! [`VertexProgram`]s against it — back-to-back or concurrently — with
//! amortised allocations:
//!
//! - **vertex stores** (values + the two mailbox-slot epochs) are pooled
//!   by concrete store type and re-primed with [`VertexStore::reset`]
//!   instead of reallocated;
//! - **activity bitsets** (active/broadcast sets) are recycled;
//! - **scheduler state** (the degree-weight vectors edge-centric full
//!   scans need) is computed once per session and shared by `Arc`;
//! - **delivery planes**: log-plane runs check a
//!   [`MessageLog`](crate::combine::plane::MessageLog) out of a pool
//!   keyed by message type, re-primed and epoch-stamped like stores.
//!
//! Per run, callers can override the session's [`EngineConfig`], install
//! a composable [`Halt`] policy (superstep cap, aggregator-convergence
//! predicate — quiescence always applies), and **warm-start** vertex
//! values from a previous run's output ([`RunOptions::warm_start`]),
//! which is what incremental recomputation
//! ([`crate::algos::incremental`]) builds on.
//!
//! ```no_run
//! use ipregel::engine::{EngineConfig, GraphSession};
//! use ipregel::algos::{ConnectedComponents, PageRank};
//! # let g = ipregel::graph::gen::ring(8);
//!
//! let session = GraphSession::with_config(&g, EngineConfig::default().threads(4));
//! let labels = session.run(&ConnectedComponents);     // allocates
//! let ranks = session.run(&PageRank::default());      // reuses pools
//! ```

use crate::combine::plane::{DeliveryPlane, MessageLog};
use crate::engine::core::{Engine, EngineSetup};
use crate::engine::epoch::{absorb_receipt, EpochWatermark};
use crate::engine::shard::ShardState;
use crate::engine::tune::{AdaptiveTuner, TunerState};
use crate::engine::{AggValue, EngineConfig, Mode, RunResult, VertexProgram};
use crate::graph::csr::{Csr, VertexId};
use crate::graph::dynamic::{DynamicGraph, MutationReceipt, MutationSet};
use crate::graph::partition::PartitionPlan;
use crate::layout::{AosStore, Layout, SoaStore, VertexStore};
use crate::trace::TraceBuffers;
use crate::util::bitset::AtomicBitSet;
use crate::util::error::Result;
use crate::bail;
use std::any::{Any, TypeId};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Composable per-run termination policy. Quiescence (all vertices halted
/// with no pending messages) always terminates a run; a `Halt` adds an
/// optional superstep cap and an optional convergence predicate on the
/// program's aggregator stream. Set both to compose them: the run stops
/// at whichever fires first.
pub struct Halt<A> {
    /// Extra cap on supersteps for this run, on top of
    /// [`EngineConfig::max_supersteps`] (the effective cap is the
    /// minimum of the two).
    pub max_supersteps: Option<usize>,
    /// Called at each superstep barrier with the merged aggregator value
    /// of the previous and the just-finished superstep; returning `true`
    /// stops the run with [`HaltReason::Converged`]. The predicate is
    /// **not** consulted while the aggregator stream is silent (both
    /// values `None` — nothing has contributed yet), so `|a, b| a == b`
    /// cannot spuriously halt a program that aggregates late or never.
    ///
    /// [`HaltReason::Converged`]: crate::metrics::HaltReason::Converged
    #[allow(clippy::type_complexity)]
    pub converged: Option<Arc<dyn Fn(Option<&A>, Option<&A>) -> bool + Send + Sync>>,
    /// Token budget for this run: cumulative work units (each superstep
    /// contributes its messages plus its activations), checked at every
    /// superstep barrier. Crossing the cap stops the run with
    /// [`HaltReason::BudgetExhausted`]. `None` (the default) leaves the
    /// solo-run path untouched — no accounting branch fires.
    ///
    /// [`HaltReason::BudgetExhausted`]: crate::metrics::HaltReason::BudgetExhausted
    pub max_tokens: Option<u64>,
}

impl<A> Default for Halt<A> {
    fn default() -> Self {
        Halt {
            max_supersteps: None,
            converged: None,
            max_tokens: None,
        }
    }
}

impl<A> Clone for Halt<A> {
    fn clone(&self) -> Self {
        Halt {
            max_supersteps: self.max_supersteps,
            converged: self.converged.clone(),
            max_tokens: self.max_tokens,
        }
    }
}

impl<A> Halt<A> {
    /// Halt policy with only the implicit quiescence rule.
    pub fn quiescence() -> Self {
        Self::default()
    }

    /// Halt after at most `n` supersteps.
    pub fn supersteps(n: usize) -> Self {
        Self::default().and_supersteps(n)
    }

    /// Halt when `pred(prev_agg, cur_agg)` returns true (e.g. when two
    /// consecutive aggregator values differ by less than a tolerance).
    pub fn converged<F>(pred: F) -> Self
    where
        F: Fn(Option<&A>, Option<&A>) -> bool + Send + Sync + 'static,
    {
        Self::default().and_converged(pred)
    }

    /// Add (or tighten) a superstep cap.
    pub fn and_supersteps(mut self, n: usize) -> Self {
        self.max_supersteps = Some(match self.max_supersteps {
            Some(old) => old.min(n),
            None => n,
        });
        self
    }

    /// Add a convergence predicate (replaces any existing one).
    pub fn and_converged<F>(mut self, pred: F) -> Self
    where
        F: Fn(Option<&A>, Option<&A>) -> bool + Send + Sync + 'static,
    {
        self.converged = Some(Arc::new(pred));
        self
    }

    /// Halt when the cumulative work-token count (messages + activations
    /// per superstep) crosses `n`.
    pub fn tokens(n: u64) -> Self {
        Self::default().and_tokens(n)
    }

    /// Add (or tighten) a token budget.
    pub fn and_tokens(mut self, n: u64) -> Self {
        self.max_tokens = Some(match self.max_tokens {
            Some(old) => old.min(n),
            None => n,
        });
        self
    }
}

/// Per-run options for [`GraphSession::run_with`].
pub struct RunOptions<'a, P: VertexProgram> {
    /// Engine configuration override; `None` uses the session default.
    pub config: Option<EngineConfig>,
    /// Termination policy for this run.
    pub halt: Halt<AggValue<P>>,
    /// Seed vertex values from a previous run instead of
    /// [`VertexProgram::init`] — the warm-start path. Must hold exactly
    /// one value per vertex.
    pub warm_start: Option<&'a [P::Value]>,
    /// Serving-layer context tag: echoed into
    /// [`RunMetrics::query_tag`](crate::metrics::RunMetrics::query_tag)
    /// and, on traced runs, emitted as a `query-context` instant at the
    /// head of the timeline so interleaved multi-tenant runs stay
    /// attributable. `None` (the default) changes nothing.
    pub query_tag: Option<u64>,
}

impl<'a, P: VertexProgram> Default for RunOptions<'a, P> {
    fn default() -> Self {
        RunOptions {
            config: None,
            halt: Halt::default(),
            warm_start: None,
            query_tag: None,
        }
    }
}

impl<'a, P: VertexProgram> RunOptions<'a, P> {
    /// Fresh default options.
    pub fn new() -> Self {
        Self::default()
    }

    /// Override the engine configuration for this run.
    pub fn config(mut self, cfg: EngineConfig) -> Self {
        self.config = Some(cfg);
        self
    }

    /// Set the termination policy for this run.
    pub fn halt(mut self, halt: Halt<AggValue<P>>) -> Self {
        self.halt = halt;
        self
    }

    /// Warm-start vertex values from `values` (one per vertex).
    pub fn warm_start(mut self, values: &'a [P::Value]) -> Self {
        self.warm_start = Some(values);
        self
    }

    /// Attach a serving-layer context tag to this run.
    pub fn tag(mut self, tag: u64) -> Self {
        self.query_tag = Some(tag);
        self
    }
}

/// How a session holds its graph: borrowed and immutable (the classic
/// path), or owned and mutable through the dynamic-graph subsystem.
enum GraphHandle<'g> {
    /// A statically built graph the caller keeps ownership of.
    Borrowed(&'g Csr),
    /// An owned [`DynamicGraph`]: the session is the single writer, so
    /// [`GraphSession::apply_mutations`] can mutate the graph and patch
    /// the session's caches in one exclusive step.
    Dynamic(Box<DynamicGraph>),
}

impl GraphHandle<'_> {
    #[inline]
    fn csr(&self) -> &Csr {
        match self {
            GraphHandle::Borrowed(g) => g,
            GraphHandle::Dynamic(dg) => dg.graph(),
        }
    }
}

/// A reusable execution session over one graph. See the [module
/// docs](self) for the pooling model; construction is cheap (no
/// allocation proportional to the graph), so throwaway
/// `GraphSession::with_config(&g, cfg).run(&p)` one-liners are fine too.
///
/// A session built with [`GraphSession::dynamic`] additionally owns a
/// [`DynamicGraph`] and accepts [`GraphSession::apply_mutations`]
/// between runs: the graph evolves in place under mutation epochs while
/// the pools stay warm (plans patched, stores re-stamped — see
/// `engine/epoch.rs`).
pub struct GraphSession<'g> {
    g: GraphHandle<'g>,
    cfg: EngineConfig,
    /// Pooled vertex stores, keyed by concrete store type — a keyed
    /// **multi-checkout** pool: each key parks every store ever handed
    /// back, so N concurrent runs of the same type each pop their own
    /// warm store (first N-1 finishers re-park them; only a pool-empty
    /// checkout builds fresh).
    stores: Mutex<HashMap<TypeId, Vec<Box<dyn Any + Send>>>>,
    /// Recycled activity bitsets (all sized to this graph).
    bitsets: Mutex<Vec<AtomicBitSet>>,
    /// Out-/in-degree weight vectors for edge-centric full scans,
    /// computed on first use and shared across runs.
    out_degree_weights: Mutex<Option<Arc<Vec<u64>>>>,
    in_degree_weights: Mutex<Option<Arc<Vec<u64>>>>,
    /// Partition plans, built once per resolved shard count and shared
    /// across runs (the partition-config pooling key).
    plans: Mutex<HashMap<usize, Arc<PartitionPlan>>>,
    /// Pooled per-shard runtime state (activity bit slabs + remote
    /// buffers), recycled when a run uses the same plan again.
    shard_states: Mutex<Vec<ShardState>>,
    /// Pooled log-plane mailbox state, keyed by concrete
    /// `MessageLog<M>` type — the delivery-plane analogue of the store
    /// pool (multi-checkout, re-primed and epoch-stamped at checkout).
    planes: Mutex<HashMap<TypeId, Vec<Box<dyn Any + Send>>>>,
    /// Pooled adaptive-tuner state (per-worker contention probes + trace
    /// buffers), recycled across adaptive runs like stores/planes.
    tuners: Mutex<Vec<TunerState>>,
    /// Pooled edge-centric rebuild scratch vectors: the
    /// `EdgeCentricBypassRebuild` fallback recomputes weights every
    /// superstep, but the vector they land in is recycled here instead
    /// of reallocated per superstep (pooled like stores/planes).
    cut_scratches: Mutex<Vec<Vec<u64>>>,
    /// Pooled observability-plane recorders (per-lane event segments +
    /// contention probes), recycled across traced runs like tuner state.
    /// Always empty under the `no-trace` feature (checkout returns
    /// `None`, so nothing is ever handed back).
    traces: Mutex<Vec<TraceBuffers>>,
    runs: AtomicU64,
    /// Checkout/hit accounting for the store and plane pools — the
    /// counters the serving tests use to prove N concurrent queries were
    /// served from shared warm state rather than N cold builds.
    pool_stats: Mutex<PoolStats>,
}

/// Cumulative pool-checkout accounting for one [`GraphSession`]
/// (see [`GraphSession::pool_stats`]). A *hit* is a checkout satisfied
/// from the pool; `checkouts - hits` is the number of cold builds.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Vertex-store checkouts (one per run).
    pub store_checkouts: u64,
    /// Vertex-store checkouts served from the pool.
    pub store_hits: u64,
    /// Log-plane checkouts (one per log-plane run).
    pub plane_checkouts: u64,
    /// Log-plane checkouts served from the pool.
    pub plane_hits: u64,
}

impl<'g> GraphSession<'g> {
    /// Session over `g` with the default [`EngineConfig`].
    pub fn new(g: &'g Csr) -> Self {
        Self::with_config(g, EngineConfig::default())
    }

    /// Session over `g` with a session-wide default configuration
    /// (overridable per run via [`RunOptions::config`]).
    pub fn with_config(g: &'g Csr, cfg: EngineConfig) -> Self {
        Self::with_handle(GraphHandle::Borrowed(g), cfg)
    }

    /// Session that **owns** a mutable graph: runs see the merged
    /// base + delta view, and [`GraphSession::apply_mutations`] evolves
    /// it between runs. Default [`EngineConfig`].
    pub fn dynamic(dg: DynamicGraph) -> GraphSession<'static> {
        Self::dynamic_with_config(dg, EngineConfig::default())
    }

    /// [`GraphSession::dynamic`] with a session-wide configuration.
    pub fn dynamic_with_config(dg: DynamicGraph, cfg: EngineConfig) -> GraphSession<'static> {
        GraphSession::with_handle(GraphHandle::Dynamic(Box::new(dg)), cfg)
    }

    fn with_handle(g: GraphHandle<'g>, cfg: EngineConfig) -> Self {
        GraphSession {
            g,
            cfg,
            stores: Mutex::new(HashMap::new()),
            bitsets: Mutex::new(Vec::new()),
            out_degree_weights: Mutex::new(None),
            in_degree_weights: Mutex::new(None),
            plans: Mutex::new(HashMap::new()),
            shard_states: Mutex::new(Vec::new()),
            planes: Mutex::new(HashMap::new()),
            tuners: Mutex::new(Vec::new()),
            cut_scratches: Mutex::new(Vec::new()),
            traces: Mutex::new(Vec::new()),
            runs: AtomicU64::new(0),
            pool_stats: Mutex::new(PoolStats::default()),
        }
    }

    /// The session's graph (the merged view on dynamic sessions).
    pub fn graph(&self) -> &Csr {
        self.g.csr()
    }

    /// The owned dynamic graph, when this session was built with
    /// [`GraphSession::dynamic`].
    pub fn dynamic_graph(&self) -> Option<&DynamicGraph> {
        match &self.g {
            GraphHandle::Borrowed(_) => None,
            GraphHandle::Dynamic(dg) => Some(dg),
        }
    }

    /// Current mutation epoch (0 for sessions over static graphs).
    pub fn graph_epoch(&self) -> u64 {
        self.dynamic_graph().map_or(0, |dg| dg.epoch())
    }

    /// Epoch position snapshot for warm-start coordination.
    pub fn epoch_watermark(&self) -> EpochWatermark {
        let g = self.graph();
        EpochWatermark {
            epoch: self.graph_epoch(),
            delta_edges: g.delta_edge_count(),
            delta_occupancy: g.delta_occupancy(),
        }
    }

    /// Apply one mutation batch to the owned [`DynamicGraph`] under the
    /// next mutation epoch, then bring the session's caches with it:
    /// degree-weight vectors are invalidated, cached partition plans are
    /// census-patched in place (full re-partition only when the batch
    /// tripped a compaction — see `engine/epoch.rs`), and pooled shard
    /// state follows its plan. Errors on sessions over borrowed graphs.
    pub fn apply_mutations(&mut self, m: &MutationSet) -> Result<MutationReceipt> {
        let receipt = match &mut self.g {
            GraphHandle::Dynamic(dg) => dg.apply(m),
            GraphHandle::Borrowed(_) => bail!(
                "apply_mutations requires a session that owns its graph — \
                 build it with GraphSession::dynamic(DynamicGraph::new(csr))"
            ),
        };
        // Exclusive access (`&mut self`): no run is in flight, so the
        // cache surgery below races with nothing.
        *self
            .out_degree_weights
            .get_mut()
            .expect("weight cache poisoned") = None;
        *self
            .in_degree_weights
            .get_mut()
            .expect("weight cache poisoned") = None;
        absorb_receipt(
            self.plans.get_mut().expect("plan cache poisoned"),
            self.shard_states.get_mut().expect("shard pool poisoned"),
            &receipt,
        );
        Ok(receipt)
    }

    /// The session's default configuration.
    pub fn config(&self) -> EngineConfig {
        self.cfg
    }

    /// Number of runs this session has completed.
    pub fn runs_completed(&self) -> u64 {
        self.runs.load(Ordering::Relaxed)
    }

    /// Number of store *types* with at least one store currently parked
    /// in the pool (diagnostic; serial sessions park at most one per
    /// type, so this matches the pre-multi-checkout count).
    pub fn pooled_stores(&self) -> usize {
        self.stores
            .lock()
            .expect("store pool poisoned")
            .values()
            .filter(|v| !v.is_empty())
            .count()
    }

    /// Number of message *types* with at least one log-plane mailbox
    /// currently parked in the pool (diagnostic).
    pub fn pooled_planes(&self) -> usize {
        self.planes
            .lock()
            .expect("plane pool poisoned")
            .values()
            .filter(|v| !v.is_empty())
            .count()
    }

    /// Cumulative pool-checkout accounting (see [`PoolStats`]).
    pub fn pool_stats(&self) -> PoolStats {
        *self.pool_stats.lock().expect("pool stats poisoned")
    }

    /// Number of partition plans cached so far (diagnostic).
    pub fn cached_plans(&self) -> usize {
        self.plans.lock().expect("plan cache poisoned").len()
    }

    /// Number of adaptive-tuner state bundles currently parked in the
    /// pool (diagnostic).
    pub fn pooled_tuners(&self) -> usize {
        self.tuners.lock().expect("tuner pool poisoned").len()
    }

    /// Number of observability-plane recorders currently parked in the
    /// pool (diagnostic; always 0 under the `no-trace` feature).
    pub fn pooled_traces(&self) -> usize {
        self.traces.lock().expect("trace pool poisoned").len()
    }

    /// The partition plan for `shards` shards, built on first use and
    /// shared by `Arc` across runs.
    fn partition_plan(&self, shards: usize) -> Arc<PartitionPlan> {
        let mut cache = self.plans.lock().expect("plan cache poisoned");
        Arc::clone(
            cache
                .entry(shards)
                .or_insert_with(|| Arc::new(PartitionPlan::build(self.g.csr(), shards))),
        )
    }

    /// Run `program` under the session configuration with default
    /// termination (quiescence + config superstep cap).
    pub fn run<P: VertexProgram>(&self, program: &P) -> RunResult<P::Value> {
        self.run_with(program, RunOptions::default())
    }

    /// Run `program` with per-run options (config override, halt policy,
    /// warm start).
    pub fn run_with<P: VertexProgram>(
        &self,
        program: &P,
        opts: RunOptions<'_, P>,
    ) -> RunResult<P::Value> {
        let cfg = opts.config.unwrap_or(self.cfg);
        match cfg.layout {
            Layout::Interleaved => {
                self.run_typed::<P, AosStore<P::Value, P::Message>>(program, cfg, opts)
            }
            Layout::Externalised => {
                self.run_typed::<P, SoaStore<P::Value, P::Message>>(program, cfg, opts)
            }
        }
    }

    /// Degree-weight vector for edge-centric full scans, built lazily and
    /// shared session-wide (push scans weight by out-degree, pull scans by
    /// in-degree).
    fn degree_weights(&self, mode: Mode) -> Arc<Vec<u64>> {
        let slot = match mode {
            Mode::Push => &self.out_degree_weights,
            Mode::Pull => &self.in_degree_weights,
        };
        let mut cached = slot.lock().expect("weight cache poisoned");
        match &*cached {
            Some(w) => Arc::clone(w),
            None => {
                let w = Arc::new(match mode {
                    Mode::Push => self.g.csr().out_degrees_u64(),
                    Mode::Pull => self.g.csr().in_degrees_u64(),
                });
                *cached = Some(Arc::clone(&w));
                w
            }
        }
    }

    fn run_typed<P, S>(
        &self,
        program: &P,
        cfg: EngineConfig,
        opts: RunOptions<'_, P>,
    ) -> RunResult<P::Value>
    where
        P: VertexProgram,
        S: VertexStore<P::Value, P::Message> + Any + Send + 'static,
    {
        let g = self.g.csr();
        let n = g.num_vertices();
        let graph_epoch = self.graph_epoch();
        if let Some(w) = opts.warm_start {
            assert_eq!(
                w.len(),
                n,
                "warm_start must supply exactly one value per vertex"
            );
        }
        let mut init: Box<dyn FnMut(VertexId) -> P::Value + '_> = match opts.warm_start {
            Some(vals) => Box::new(move |v| vals[v as usize].clone()),
            None => Box::new(move |v| program.init(g, v)),
        };

        // ---- Partition: resolve the config to a plan + shard state -----
        let shards = cfg.partitioning.resolve(n);
        let partition: Option<ShardState> = if shards == 0 {
            None
        } else {
            let plan = self.partition_plan(shards);
            let workers = cfg.threads.max(1);
            let pooled = {
                let mut pool = self.shard_states.lock().expect("shard pool poisoned");
                let idx = pool.iter().position(|st| st.fits(&plan, workers));
                idx.map(|i| pool.swap_remove(i))
            };
            Some(match pooled {
                Some(mut st) => {
                    // The pool mutex ordered the previous owner's writes
                    // before this checkout — tell the race checker.
                    #[cfg(feature = "race-check")]
                    crate::util::shadow::sync_point();
                    st.reset();
                    st
                }
                None => ShardState::new(plan, workers),
            })
        };

        // ---- Store: recycle by concrete type, else build fresh ---------
        let key = TypeId::of::<S>();
        let pooled: Option<S> = self
            .stores
            .lock()
            .expect("store pool poisoned")
            .get_mut(&key)
            .and_then(|v| v.pop())
            .and_then(|b| b.downcast::<S>().ok())
            .map(|b| *b);
        {
            let mut stats = self.pool_stats.lock().expect("pool stats poisoned");
            stats.store_checkouts += 1;
            stats.store_hits += u64::from(pooled.is_some());
        }
        let (store, store_reused, store_epoch_refreshed) = match pooled {
            Some(mut s) => {
                // Pool-mutex handover is a sync point the race checker
                // cannot see on its own (see `util::shadow`).
                #[cfg(feature = "race-check")]
                crate::util::shadow::sync_point();
                // Epoch-tagged invalidation: a pooled store primed
                // against an older mutation epoch is still *shaped*
                // right (the vertex set never moves), but its contents
                // are stale by definition; the reset below re-primes it
                // and the mismatch is surfaced through RunMetrics.
                let epoch_stale = s.epoch_tag() != graph_epoch;
                match &partition {
                    // Partitioned runs prime shard-by-shard: each slab is
                    // rewritten as one contiguous sweep, so the first
                    // scatter finds its shard warm.
                    Some(state) => {
                        for sh in 0..state.plan.num_shards() {
                            s.reset_range(state.plan.shard_range(sh), &mut *init);
                        }
                        s.rewind_epochs();
                    }
                    None => s.reset(g, &mut *init),
                }
                s.set_epoch_tag(graph_epoch);
                (s, true, epoch_stale)
            }
            None => {
                let mut s = S::build(g, &mut *init);
                s.set_epoch_tag(graph_epoch);
                (s, false, false)
            }
        };

        // ---- Delivery plane: pool one MessageLog per message type ------
        // (Combined-plane runs carry no extra state — their mailboxes
        // are the store's slots, preserved bit-for-bit.)
        let is_log = <P::Delivery as DeliveryPlane<P::Message>>::IS_LOG;
        let (log, log_reused) = if is_log {
            let key = TypeId::of::<MessageLog<P::Message>>();
            let pooled: Option<MessageLog<P::Message>> = self
                .planes
                .lock()
                .expect("plane pool poisoned")
                .get_mut(&key)
                .and_then(|v| v.pop())
                .and_then(|b| b.downcast::<MessageLog<P::Message>>().ok())
                .map(|b| *b);
            {
                let mut stats = self.pool_stats.lock().expect("pool stats poisoned");
                stats.plane_checkouts += 1;
                stats.plane_hits += u64::from(pooled.is_some());
            }
            match pooled {
                Some(mut l) => {
                    // Pool-mutex handover sync point (as for stores above).
                    #[cfg(feature = "race-check")]
                    crate::util::shadow::sync_point();
                    l.ensure_shape(n, cfg.threads.max(1));
                    l.set_epoch_tag(graph_epoch);
                    (Some(l), true)
                }
                None => {
                    let mut l = MessageLog::new(n, cfg.threads.max(1));
                    l.set_epoch_tag(graph_epoch);
                    (Some(l), false)
                }
            }
        } else {
            (None, false)
        };

        // ---- Bitsets: recycle up to the three the engine needs ---------
        // (Partitioned runs track activity per shard and never touch the
        // flat bitsets, so leave the pool alone.)
        let mut recycled = Vec::new();
        if partition.is_none() {
            let mut pool = self.bitsets.lock().expect("bitset pool poisoned");
            while recycled.len() < 3 {
                match pool.pop() {
                    Some(mut b) => {
                        if b.len() == n {
                            b.clear_all();
                            recycled.push(b);
                        }
                    }
                    None => break,
                }
            }
        }

        // Full-scan edge-centric weights are only consulted by the flat
        // substrate (the partitioned scatter weighs whole shards from the
        // plan instead). Adaptive flat runs always get them, so the tuner
        // can switch scan-mode supersteps onto the edge-centric cut
        // without a per-superstep rebuild.
        let scan_weights = if partition.is_none()
            && ((cfg.schedule.needs_weights() && !cfg.bypass) || cfg.adaptive)
        {
            Some(self.degree_weights(program.mode()))
        } else {
            None
        };

        // ---- Adaptive tuner: pool the probe/trace state like stores ----
        let (tuner, tuner_reused) = if cfg.adaptive {
            let pooled = self.tuners.lock().expect("tuner pool poisoned").pop();
            let reused = pooled.is_some();
            let state = pooled.unwrap_or_default();
            (
                Some(AdaptiveTuner::new(
                    &cfg,
                    program.mode(),
                    is_log,
                    partition.is_some(),
                    scan_weights.is_some(),
                    state,
                    cfg.threads.max(1),
                )),
                reused,
            )
        } else {
            (None, false)
        };

        // Row-plane retention: adaptive runs hand the plane the decision
        // table's cold-block band, so compressed-scratch residency is
        // governed by the same calibrated constants as every other knob.
        // An explicit policy (CLI `--resident-blocks`/`--cold-rounds` or
        // `set_policy`) wins; fixed-config runs never touch the plane.
        if cfg.adaptive {
            if let Some(p) = g.row_plane() {
                let mut pol = p.policy();
                if pol.cold_rounds.is_none() {
                    pol.cold_rounds =
                        Some(crate::engine::tune::DecisionTable::default().row_cold_rounds);
                    p.set_policy(pol);
                }
            }
        }

        // Edge-centric rebuild scratch: plain data, fully rewritten
        // before every read, so checkout needs no epoch stamping.
        let cut_scratch = self
            .cut_scratches
            .lock()
            .expect("scratch pool poisoned")
            .pop()
            .unwrap_or_default();

        // ---- Observability plane: pool the recorder like tuner state ---
        // (`checkout` resets segments/probes and re-stamps the clock; it
        // is the `no-trace` feature's compile-out gate and returns `None`
        // there, so the pool never grows.)
        let trace = if cfg.trace {
            let pooled = self.traces.lock().expect("trace pool poisoned").pop();
            TraceBuffers::checkout(pooled, cfg.threads.max(1))
        } else {
            None
        };

        let mut engine = Engine::with_setup(
            g,
            program,
            cfg,
            opts.halt,
            EngineSetup {
                store,
                store_reused,
                bitsets: recycled,
                scan_weights,
                partition,
                log,
                tuner,
                cut_scratch,
                trace,
                query_tag: opts.query_tag,
            },
        );
        let mut result = engine.run();
        result.metrics.graph_epoch = graph_epoch;
        result.metrics.delta_edges = g.delta_edge_count() as u64;
        result.metrics.delta_occupancy = g.delta_occupancy();
        result.metrics.store_epoch_refreshed = store_epoch_refreshed;
        result.metrics.plane_reused = log_reused;
        result.metrics.tuner_reused = tuner_reused;
        if let Some(tr) = result.metrics.trace.as_mut() {
            // Stamp the graph's mutation state onto the timeline — the
            // session owns that knowledge (mutation is a between-runs
            // affair the engine never sees).
            tr.note_epoch(graph_epoch, g.delta_edge_count() as u64);
        }

        // ---- Return the parts to the pools -----------------------------
        let (store, bitsets, shard_state, log, tuner_state, cut_scratch, trace_buf) =
            engine.into_parts();
        self.stores
            .lock()
            .expect("store pool poisoned")
            .entry(key)
            .or_default()
            .push(Box::new(store));
        if let Some(l) = log {
            self.planes
                .lock()
                .expect("plane pool poisoned")
                .entry(TypeId::of::<MessageLog<P::Message>>())
                .or_default()
                .push(Box::new(l));
        }
        // Partitioned runs hand back zero-length placeholders — only
        // full-size bitsets are worth pooling.
        self.bitsets
            .lock()
            .expect("bitset pool poisoned")
            .extend(bitsets.into_iter().filter(|b| b.len() == n));
        if let Some(st) = shard_state {
            self.shard_states
                .lock()
                .expect("shard pool poisoned")
                .push(st);
        }
        if let Some(ts) = tuner_state {
            self.tuners.lock().expect("tuner pool poisoned").push(ts);
        }
        self.cut_scratches
            .lock()
            .expect("scratch pool poisoned")
            .push(cut_scratch);
        if let Some(tb) = trace_buf {
            self.traces.lock().expect("trace pool poisoned").push(tb);
        }
        self.runs.fetch_add(1, Ordering::Relaxed);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{ConnectedComponents, DegreeCount, PageRank};
    use crate::graph::gen;
    use crate::metrics::HaltReason;

    #[test]
    fn consecutive_runs_reuse_the_store() {
        let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 3);
        let session = GraphSession::new(&g);
        let a = session.run(&ConnectedComponents);
        assert!(!a.metrics.store_reused);
        let b = session.run(&ConnectedComponents);
        assert!(b.metrics.store_reused);
        assert_eq!(a.values, b.values);
        assert_eq!(session.runs_completed(), 2);
        assert_eq!(session.pooled_stores(), 1);
    }

    #[test]
    fn different_value_types_pool_separately() {
        let g = gen::ring(32);
        let session = GraphSession::new(&g);
        session.run(&ConnectedComponents); // (u32, u32) store
        session.run(&PageRank::default()); // (f64, f64) store
        assert_eq!(session.pooled_stores(), 2);
        // Second round reuses both.
        assert!(session.run(&ConnectedComponents).metrics.store_reused);
        assert!(session.run(&PageRank::default()).metrics.store_reused);
    }

    #[test]
    fn per_run_config_override_switches_layout() {
        let g = gen::grid(6, 6);
        let session = GraphSession::new(&g);
        let base = session.run(&ConnectedComponents);
        let soa = session.run_with(
            &ConnectedComponents,
            RunOptions::new().config(session.config().layout(Layout::Externalised)),
        );
        assert_eq!(base.values, soa.values);
        // Two layouts → two pooled store types.
        assert_eq!(session.pooled_stores(), 2);
    }

    #[test]
    fn halt_superstep_cap_applies() {
        let g = gen::path(200);
        let session = GraphSession::new(&g);
        let r = session.run_with(
            &ConnectedComponents,
            RunOptions::new().halt(Halt::supersteps(3)),
        );
        assert_eq!(r.metrics.num_supersteps(), 3);
        assert_eq!(r.metrics.halt_reason, HaltReason::SuperstepCap);
    }

    #[test]
    fn halt_combinators_compose() {
        let h: Halt<f64> = Halt::supersteps(10)
            .and_supersteps(5)
            .and_converged(|_, _| false);
        assert_eq!(h.max_supersteps, Some(5));
        assert!(h.converged.is_some());
        let cloned = h.clone();
        assert_eq!(cloned.max_supersteps, Some(5));
        let t: Halt<f64> = Halt::tokens(1000).and_tokens(200).and_supersteps(7);
        assert_eq!(t.max_tokens, Some(200), "and_tokens tightens");
        assert_eq!(t.max_supersteps, Some(7));
        assert_eq!(t.clone().max_tokens, Some(200));
        assert_eq!(Halt::<f64>::quiescence().max_tokens, None);
    }

    #[test]
    fn multi_checkout_pool_parks_every_store() {
        // Serial session: each finished run parks its store, so two
        // concurrent-style checkouts after two runs both hit the pool.
        let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 3);
        let session = GraphSession::new(&g);
        session.run(&ConnectedComponents);
        let s = session.pool_stats();
        assert_eq!((s.store_checkouts, s.store_hits), (1, 0), "cold first run");
        session.run(&ConnectedComponents);
        let s = session.pool_stats();
        assert_eq!((s.store_checkouts, s.store_hits), (2, 1), "warm second run");
        // Concurrent same-type runs: both pop independently; afterwards
        // the key parks two stores but still counts once per type.
        let solo = session.run(&ConnectedComponents).values;
        std::thread::scope(|scope| {
            let s1 = scope.spawn(|| session.run(&ConnectedComponents).values);
            let s2 = scope.spawn(|| session.run(&ConnectedComponents).values);
            assert_eq!(s1.join().expect("run thread"), solo);
            assert_eq!(s2.join().expect("run thread"), solo);
        });
        assert_eq!(session.pooled_stores(), 1, "one type, regardless of depth");
        // Both parked stores are reusable: the next two checkouts hit.
        let before = session.pool_stats();
        session.run(&ConnectedComponents);
        session.run(&ConnectedComponents);
        let after = session.pool_stats();
        assert_eq!(after.store_hits - before.store_hits, 2);
    }

    #[test]
    fn partitioned_runs_share_plan_and_recycle_state() {
        let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 5);
        let session = GraphSession::new(&g);
        let flat = session.run(&ConnectedComponents);
        assert_eq!(flat.metrics.shards, 0);
        let cfg = session.config().shards(4);
        let a = session.run_with(&ConnectedComponents, RunOptions::new().config(cfg));
        assert_eq!(a.values, flat.values, "sharded must match flat");
        assert_eq!(a.metrics.shards, 4);
        assert!(a.metrics.shard_edge_imbalance >= 1.0);
        assert_eq!(session.cached_plans(), 1);
        let b = session.run_with(&ConnectedComponents, RunOptions::new().config(cfg));
        assert_eq!(b.values, flat.values);
        assert!(b.metrics.store_reused);
        assert_eq!(session.cached_plans(), 1, "plan cached, not rebuilt");
    }

    #[test]
    fn shard_message_split_accounts_for_every_message() {
        // DegreeCount sends exactly one message per directed edge; the
        // intra/cross split must cover them all, and cross must match
        // the plan's cross-edge census.
        let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 11);
        let session = GraphSession::new(&g);
        let r = session.run_with(
            &DegreeCount,
            RunOptions::new().config(session.config().shards(5)),
        );
        let m = &r.metrics;
        assert_eq!(m.total_messages(), g.num_edges() as u64);
        assert_eq!(
            m.intra_shard_messages + m.cross_shard_messages,
            g.num_edges() as u64
        );
        let plan = crate::graph::partition::PartitionPlan::build(&g, 5);
        assert_eq!(m.cross_shard_messages, plan.total_cross());
    }

    #[test]
    fn log_plane_state_pools_like_stores() {
        use crate::algos::Lpa;
        use crate::metrics::DeliveryPlaneKind;
        let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 9);
        let session = GraphSession::new(&g);
        let a = session.run(&Lpa { rounds: 3 });
        assert_eq!(a.metrics.delivery_plane, DeliveryPlaneKind::Log);
        assert!(!a.metrics.plane_reused);
        assert_eq!(session.pooled_planes(), 1);
        let b = session.run(&Lpa { rounds: 3 });
        assert!(b.metrics.plane_reused, "second run must reuse the log");
        assert_eq!(a.values, b.values, "pooled plane must be bit-invisible");
        assert_eq!(session.pooled_planes(), 1);
        // Combined-plane programs never touch the plane pool.
        let c = session.run(&ConnectedComponents);
        assert_eq!(c.metrics.delivery_plane, DeliveryPlaneKind::Combined);
        assert!(!c.metrics.plane_reused);
        assert_eq!(session.pooled_planes(), 1);
    }

    #[test]
    fn adaptive_runs_pool_tuner_state_like_stores() {
        let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 3);
        let session = GraphSession::new(&g);
        let cfg = session.config().adaptive(true);
        let a = session.run_with(&ConnectedComponents, RunOptions::new().config(cfg));
        assert!(a.metrics.adaptive);
        assert!(!a.metrics.tuner_reused);
        assert_eq!(
            a.metrics.tuner_decisions.len(),
            a.metrics.num_supersteps(),
            "one decision per superstep"
        );
        assert_eq!(session.pooled_tuners(), 1);
        let b = session.run_with(&ConnectedComponents, RunOptions::new().config(cfg));
        assert!(b.metrics.tuner_reused, "second adaptive run recycles the state");
        assert_eq!(a.values, b.values, "pooled tuner state must be bit-invisible");
        assert_eq!(session.pooled_tuners(), 1);
        // Fixed-config runs bypass the pool and record no decisions.
        let c = session.run(&ConnectedComponents);
        assert!(!c.metrics.adaptive);
        assert!(c.metrics.tuner_decisions.is_empty());
        assert_eq!(session.pooled_tuners(), 1);
    }

    #[test]
    fn adaptive_runs_set_the_planes_retention_policy_from_the_table() {
        let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 3).compress(64);
        let plane = g.row_plane().expect("compressed");
        assert_eq!(plane.policy().cold_rounds, None);
        let session = GraphSession::new(&g);
        // Fixed-config runs leave the plane's policy alone.
        let fixed = session.run(&ConnectedComponents);
        assert_eq!(plane.policy().cold_rounds, None);
        // Adaptive runs install the decision table's retention band…
        let cfg = session.config().adaptive(true);
        let adapt = session.run_with(&ConnectedComponents, RunOptions::new().config(cfg));
        assert_eq!(
            plane.policy().cold_rounds,
            Some(crate::engine::DecisionTable::default().row_cold_rounds)
        );
        assert_eq!(fixed.values, adapt.values, "policy is bit-invisible");
        // …but never override an explicit one.
        plane.set_policy(crate::graph::RowPolicy {
            cold_rounds: Some(1),
            ..Default::default()
        });
        session.run_with(&ConnectedComponents, RunOptions::new().config(cfg));
        assert_eq!(plane.policy().cold_rounds, Some(1));
    }

    #[test]
    fn dynamic_session_patches_plan_cache_across_mutations() {
        use crate::graph::dynamic::{DynamicGraph, MutationSet};
        let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 5);
        let cfg = EngineConfig::default().shards(4);
        let mut session = GraphSession::dynamic_with_config(
            DynamicGraph::with_spill_threshold(g, 1_000_000),
            cfg,
        );
        let a = session.run(&ConnectedComponents);
        assert_eq!(a.metrics.graph_epoch, 0);
        assert_eq!(session.cached_plans(), 1);

        let mut m = MutationSet::new();
        m.insert_undirected(0, 100);
        let receipt = session.apply_mutations(&m).unwrap();
        assert_eq!(receipt.epoch, 1);
        assert!(!receipt.compacted);

        let b = session.run(&ConnectedComponents);
        assert_eq!(b.metrics.graph_epoch, 1);
        assert!(b.metrics.store_reused);
        assert!(
            b.metrics.store_epoch_refreshed,
            "pooled store was tagged with epoch 0 and must be re-primed"
        );
        assert_eq!(session.cached_plans(), 1, "plan patched, not rebuilt");
        // Patched plan still classifies the mutated graph correctly:
        // the run's values match a throwaway session over a rebuild.
        let rebuilt = session.graph().rebuilt();
        let want = GraphSession::with_config(&rebuilt, cfg).run(&ConnectedComponents);
        assert_eq!(b.values, want.values);
        // A third run sees a matching epoch tag: no refresh flagged.
        let c = session.run(&ConnectedComponents);
        assert!(!c.metrics.store_epoch_refreshed);
    }

    #[test]
    fn dynamic_session_compaction_drops_and_rebuilds_plans() {
        use crate::graph::dynamic::{DynamicGraph, MutationSet};
        let g = gen::grid(8, 8);
        let cfg = EngineConfig::default().shards(3);
        let mut session =
            GraphSession::dynamic_with_config(DynamicGraph::with_spill_threshold(g, 1), cfg);
        session.run(&ConnectedComponents);
        assert_eq!(session.cached_plans(), 1);
        let mut m = MutationSet::new();
        m.insert_undirected(0, 63);
        let receipt = session.apply_mutations(&m).unwrap();
        assert!(receipt.compacted, "threshold 1 compacts immediately");
        assert_eq!(session.cached_plans(), 0, "full re-partition on compaction");
        let r = session.run(&ConnectedComponents);
        assert_eq!(r.metrics.shards, 3);
        assert_eq!(session.cached_plans(), 1);
        assert_eq!(r.metrics.delta_edges, 0, "compacted graph has no overlay");
    }

    #[test]
    fn apply_mutations_on_borrowed_session_errors() {
        use crate::graph::dynamic::MutationSet;
        let g = gen::ring(8);
        let mut session = GraphSession::new(&g);
        let mut m = MutationSet::new();
        m.insert(0, 4);
        let e = session.apply_mutations(&m).unwrap_err();
        assert!(e.to_string().contains("GraphSession::dynamic"));
    }

    #[test]
    #[should_panic(expected = "one value per vertex")]
    fn warm_start_length_is_checked() {
        let g = gen::ring(8);
        let session = GraphSession::new(&g);
        let bad = vec![0u32; 3];
        session.run_with(
            &ConnectedComponents,
            RunOptions::new().warm_start(&bad),
        );
    }
}
