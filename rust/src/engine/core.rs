//! The superstep loop shared by all engine versions.
//!
//! One [`Engine`] implements both communication modes, both active-set
//! representations, and both execution substrates:
//!
//! - **flat** (`Partitioning::None`): one vertex range, one global
//!   mailbox array — the original engine, preserved bit-for-bit;
//! - **partitioned**: the graph is cut into cache-sized, edge-balanced
//!   shards ([`crate::graph::partition::PartitionPlan`]) and each
//!   superstep runs as three phases:
//!   1. **scatter** — shards are dispatched to workers (the schedule
//!      operates on shards, edge-centric weighting by shard edge
//!      count); the worker owning a shard computes its active vertices
//!      and delivers intra-shard messages straight into the shard's
//!      mailbox slab through the owner-exclusive combiner path
//!      ([`Strategy::deliver_exclusive`]), while cross-shard messages
//!      are appended to the worker's per-destination-shard remote
//!      buffer;
//!   2. **flush** — destination shards are dispatched to workers; the
//!      task owning shard `d` drains every worker's buffer for `d`
//!      (again owner-exclusive — the buffered extension of the paper's
//!      hybrid combiner: lock-free within the owning shard, batched
//!      hand-off across shards);
//!   3. **apply** — the old barrier: epoch swap, pull outbox clearing,
//!      aggregator merge, convergence.
//!
//! Both substrates serve both **delivery planes** (`combine/plane.rs`):
//! combined-plane sends run the strategy machinery above unchanged,
//! while log-plane sends append `(dst, msg)` to the sending worker's
//! segment (cross-shard ones batch-route through the same remote
//! buffers and are appended by the flush task), and the barrier merges
//! all segments into per-vertex logs served to `Context::recv`.
//!
//! The mode/bypass/substrate branches sit at superstep granularity,
//! outside the per-vertex hot loop, and the store type is monomorphised
//! so layout differences compile down to pointer arithmetic.
//!
//! Engines are constructed by [`crate::engine::GraphSession`] from pooled
//! parts (a primed [`VertexStore`], recycled activity bitsets, shared
//! edge-centric scan weights, and — when partitioned — a recycled
//! [`ShardState`]) and hand those parts back after the run so the next
//! run skips the allocations.

use crate::combine::plane::{MessageLog, Segment};
use crate::combine::vector::{reduce_gather, reduce_slice_u64, VECTOR_GATHER_MIN};
use crate::combine::{Combiner, ContentionProbe, MessageValue, MonoidKind, Strategy};
use crate::engine::session::Halt;
use crate::engine::shard::ShardState;
use crate::engine::tune::{AdaptiveTuner, StepPlan, TunerState};
use crate::engine::{AggValue, Aggregator, Context, EngineConfig, Mode, RunResult, VertexProgram};
use crate::graph::csr::{Csr, EdgeWeight, VertexId};
use crate::graph::partition::PartitionPlan;
use crate::graph::rows::Dir as RowDir;
use crate::layout::{SyncCell, VertexStore};
use crate::metrics::{DeliveryPlaneKind, HaltReason, RunMetrics, ScheduleFallback, SuperstepStats};
use crate::sched::{parallel_for, parallel_for_hinted, steal_execute_tagged, Schedule};
use crate::trace::{BarrierSignals, InstantKind, Phase, TraceBuffers};
use crate::util::bitset::{AtomicBitSet, BitSet};
use crate::util::timer::Timer;
use crate::util::CachePadded;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Once};
use std::time::Duration;

/// Reusable allocations a [`crate::engine::GraphSession`] threads through
/// consecutive runs.
pub(crate) struct EngineSetup<S, M: MessageValue> {
    /// Value-initialised store (fresh-built or pool-recycled and reset).
    pub store: S,
    /// Whether `store` came out of the session pool.
    pub store_reused: bool,
    /// Up to three recycled, cleared, `n`-bit activity bitsets.
    pub bitsets: Vec<AtomicBitSet>,
    /// Degree weights for edge-centric full scans, shared session-wide.
    pub scan_weights: Option<Arc<Vec<u64>>>,
    /// Per-shard runtime state when the run is partitioned (plan,
    /// activity bit slabs, remote buffers), pooled by the session.
    pub partition: Option<ShardState>,
    /// Log-plane mailbox state (`None` on combined-plane runs), pooled
    /// and epoch-stamped by the session like the store.
    pub log: Option<MessageLog<M>>,
    /// Adaptive superstep controller (`None` on fixed-config runs); its
    /// probe/trace state is pooled by the session like stores/planes.
    pub tuner: Option<AdaptiveTuner>,
    /// Pooled scratch for per-superstep edge-centric weight rebuilds (the
    /// `EdgeCentricBypassRebuild` fallback): the weights still have to be
    /// recomputed from each superstep's active list, but the vector they
    /// land in is session-owned, so the fallback stops allocating.
    pub cut_scratch: Vec<u64>,
    /// Observability-plane recorder (`None` when the run is untraced or
    /// the `no-trace` feature is on), pooled by the session like tuner
    /// state — see `trace/buf.rs`.
    pub trace: Option<TraceBuffers>,
    /// Serving-layer context tag ([`crate::engine::RunOptions::tag`]):
    /// stamped into [`RunMetrics`] and, on traced runs, emitted as a
    /// `QueryContext` instant so interleaved traces stay attributable.
    pub query_tag: Option<u64>,
}

/// The engine: graph + program + store + activity tracking.
pub struct Engine<'g, P: VertexProgram, S: VertexStore<P::Value, P::Message>> {
    g: &'g Csr,
    program: &'g P,
    store: S,
    cfg: EngineConfig,
    halt: Halt<AggValue<P>>,
    comb: P::Comb,
    agg: P::Agg,
    mode: Mode,
    store_reused: bool,
    /// Vertices active in the *next* superstep (set during compute).
    /// Flat substrate only; partitioned runs track activity per shard.
    active_next: AtomicBitSet,
    /// Pull mode: vertices that broadcast *this* superstep (their outbox
    /// slots need clearing two barriers later). Flat substrate only.
    bcast_next: AtomicBitSet,
    /// Pull mode: vertices whose outbox holds last superstep's broadcast.
    /// Flat substrate only.
    bcast_cur: AtomicBitSet,
    /// Degree weights for edge-centric scans (out- or in-degrees depending
    /// on mode; computed once per session and shared across runs).
    scan_weights: Option<Arc<Vec<u64>>>,
    /// Merged aggregator value from the previous superstep.
    agg_prev: Option<AggValue<P>>,
    /// Per-shard runtime state (None on flat runs).
    partition: Option<ShardState>,
    /// Log-plane mailbox state (None on combined-plane runs). When set,
    /// sends append to per-worker segments instead of combining into
    /// mailbox slots, and compute reads the merged log via
    /// `Context::recv` — see `combine/plane.rs`.
    log: Option<MessageLog<P::Message>>,
    /// Adaptive superstep controller (None on fixed-config runs): hands
    /// both loops a fresh [`StepPlan`] at each superstep top and absorbs
    /// the barrier's signals — see `engine/tune.rs`.
    tuner: Option<AdaptiveTuner>,
    /// Pooled edge-centric rebuild scratch (see [`EngineSetup`]).
    cut_scratch: Vec<u64>,
    /// Observability-plane recorder (None = untraced run). Recording
    /// sites sit behind `if let Some(..)` so untraced runs pay one
    /// branch per phase, and the `no-trace` feature makes this constant
    /// `None` so those sites are statically dead.
    trace: Option<TraceBuffers>,
    /// Serving-layer context tag (see [`EngineSetup::query_tag`]).
    query_tag: Option<u64>,
}

/// Shard routing for one vertex's context during partitioned scatter:
/// which shard the vertex's worker owns, where to buffer cross-shard
/// sends, and where cross-shard counts accumulate.
struct ShardRoute<'a> {
    plan: &'a PartitionPlan,
    state: &'a ShardState,
    shard: usize,
    tid: usize,
    cross: &'a AtomicU64,
}

/// Per-run counters behind the tuner's `lane_utilisation` signal: gather
/// positions scanned by the vectorised Pull kernel
/// ([`reduce_gather`], DESIGN.md §2.9) and how many actually held a
/// message. Swapped out at every barrier and accumulated into
/// [`RunMetrics`]; their ratio tells the tuner whether wide rows are
/// dense (lanes earning their keep) or sparse (prefetch window should
/// widen instead).
struct LaneCounters {
    scanned: AtomicU64,
    useful: AtomicU64,
}

impl LaneCounters {
    fn new() -> Self {
        LaneCounters {
            scanned: AtomicU64::new(0),
            useful: AtomicU64::new(0),
        }
    }

    /// Record one vectorised gather over `scanned` positions, `useful`
    /// of which held a message.
    #[inline]
    fn add(&self, scanned: u64, useful: u64) {
        self.scanned.fetch_add(scanned, Ordering::Relaxed);
        self.useful.fetch_add(useful, Ordering::Relaxed);
    }

    /// Drain this superstep's counts (barrier only — workers are joined).
    fn take(&self) -> (u64, u64) {
        (
            self.scanned.swap(0, Ordering::Relaxed),
            self.useful.swap(0, Ordering::Relaxed),
        )
    }

    /// Useful-per-scanned ratio; neutral `1.0` when the kernel never ran
    /// this superstep (short rows, inexact combiner, push mode) so the
    /// tuner's depth knob holds still.
    fn ratio(scanned: u64, useful: u64) -> f64 {
        if scanned == 0 {
            1.0
        } else {
            useful as f64 / scanned as f64
        }
    }
}

/// Per-vertex context implementation. Holds only shared references plus
/// the per-vertex mutable bits, so constructing one per vertex is free.
struct Ctx<'a, P: VertexProgram, S: VertexStore<P::Value, P::Message>> {
    g: &'a Csr,
    store: &'a S,
    comb: &'a P::Comb,
    agg: &'a P::Agg,
    /// This superstep's combining strategy (the config's, or the
    /// adaptive tuner's per-superstep re-selection within Lock/Hybrid).
    strategy: Strategy,
    /// Adaptive runs: this worker's contention probe (None = fixed
    /// config, probe-free delivery path).
    probe: Option<&'a ContentionProbe>,
    mode: Mode,
    active_next: &'a AtomicBitSet,
    bcast_next: &'a AtomicBitSet,
    msg_counter: &'a AtomicU64,
    /// This worker's aggregator partial: (accumulated, contributed?).
    agg_cell: &'a SyncCell<(AggValue<P>, bool)>,
    agg_prev: Option<&'a AggValue<P>>,
    /// Partitioned scatter: the shard-routing context (None = flat).
    route: Option<ShardRoute<'a>>,
    /// Log-plane: this vertex's merged inbox from last superstep
    /// (always empty on combined-plane runs).
    inbox: &'a [P::Message],
    /// Log-plane: this worker's append segment (None = combined plane,
    /// where sends go through the strategy into mailbox slots).
    log_seg: Option<&'a SyncCell<Segment<P::Message>>>,
    superstep: usize,
    v: VertexId,
    halted: bool,
}

impl<'a, P, S> Ctx<'a, P, S>
where
    P: VertexProgram,
    S: VertexStore<P::Value, P::Message>,
{
    /// Synchronised delivery into a shared slot, routed through the
    /// contention probe when the run is adaptive. Fixed-config runs take
    /// the `None` arm — exactly the pre-tuner code path.
    #[inline]
    fn deliver_shared(&self, slot: &crate::combine::MsgSlot<P::Message>, msg: P::Message) {
        match self.probe {
            None => self.strategy.deliver(slot, msg, self.comb),
            Some(p) => self.strategy.deliver_probed(slot, msg, self.comb, p),
        }
    }
}

impl<'a, P, S> Context<P::Value, P::Message, AggValue<P>> for Ctx<'a, P, S>
where
    P: VertexProgram,
    S: VertexStore<P::Value, P::Message>,
{
    #[inline]
    fn id(&self) -> VertexId {
        self.v
    }

    #[inline]
    fn superstep(&self) -> usize {
        self.superstep
    }

    #[inline]
    fn num_vertices(&self) -> usize {
        self.g.num_vertices()
    }

    #[inline]
    fn value(&self) -> &P::Value {
        self.store.value(self.v)
    }

    #[inline]
    fn value_mut(&mut self) -> &mut P::Value {
        self.store.value_mut(self.v)
    }

    #[inline]
    fn out_neighbors(&self) -> &[VertexId] {
        self.g.out_neighbors(self.v)
    }

    #[inline]
    fn in_degree(&self) -> usize {
        self.g.in_degree(self.v)
    }

    #[inline]
    fn out_edge(&self, i: usize) -> (VertexId, EdgeWeight) {
        self.g.out_edge(self.v, i)
    }

    #[inline]
    fn send(&mut self, dst: VertexId, msg: P::Message) {
        assert!(
            self.mode == Mode::Push,
            "send() requires a push-mode program; single-broadcast (pull) \
             versions only support broadcast() — see paper §II"
        );
        self.msg_counter.fetch_add(1, Ordering::Relaxed);
        match (&self.route, self.log_seg) {
            (None, None) => {
                self.deliver_shared(self.store.next_slot(dst), msg);
                self.active_next.set(dst as usize);
            }
            (None, Some(seg)) => {
                // Log plane, flat: contention-free append to this
                // worker's segment; merged at the barrier.
                seg.get_mut().push((dst, msg));
                self.active_next.set(dst as usize);
            }
            (Some(r), None) => {
                let d = r.plan.shard_of(dst);
                if d == r.shard {
                    // Shard-local: this worker owns the destination's
                    // mailbox slab for the whole scatter phase.
                    self.strategy
                        .deliver_exclusive(self.store.next_slot(dst), msg, self.comb);
                    r.state.active.set_in(d, dst as usize);
                } else {
                    // Cross-shard: batch for the flush phase.
                    r.cross.fetch_add(1, Ordering::Relaxed);
                    r.state.buffers.push(r.tid, d, (dst, msg.to_bits()));
                }
            }
            (Some(r), Some(seg)) => {
                let d = r.plan.shard_of(dst);
                if d == r.shard {
                    // Shard-local log append: same segment as flat (the
                    // merge at the barrier is global either way).
                    seg.get_mut().push((dst, msg));
                    r.state.active.set_in(d, dst as usize);
                } else {
                    // Cross-shard log messages batch-route through the
                    // same remote buffers as combined ones; the flush
                    // task appends them to its own segment.
                    r.cross.fetch_add(1, Ordering::Relaxed);
                    r.state.buffers.push(r.tid, d, (dst, msg.to_bits()));
                }
            }
        }
    }

    #[inline]
    fn broadcast(&mut self, msg: P::Message) {
        match self.mode {
            Mode::Push => {
                // Broadcast = send along every outgoing edge.
                let nbrs = self.g.out_neighbors(self.v);
                self.msg_counter
                    .fetch_add(nbrs.len() as u64, Ordering::Relaxed);
                match (&self.route, self.log_seg) {
                    (None, None) => {
                        for &dst in nbrs {
                            self.deliver_shared(self.store.next_slot(dst), msg);
                            self.active_next.set(dst as usize);
                        }
                    }
                    (None, Some(seg)) => {
                        let buf = seg.get_mut();
                        for &dst in nbrs {
                            buf.push((dst, msg));
                            self.active_next.set(dst as usize);
                        }
                    }
                    (Some(r), None) => {
                        for &dst in nbrs {
                            let d = r.plan.shard_of(dst);
                            if d == r.shard {
                                self.strategy.deliver_exclusive(
                                    self.store.next_slot(dst),
                                    msg,
                                    self.comb,
                                );
                                r.state.active.set_in(d, dst as usize);
                            } else {
                                r.cross.fetch_add(1, Ordering::Relaxed);
                                r.state.buffers.push(r.tid, d, (dst, msg.to_bits()));
                            }
                        }
                    }
                    (Some(r), Some(seg)) => {
                        let buf = seg.get_mut();
                        for &dst in nbrs {
                            let d = r.plan.shard_of(dst);
                            if d == r.shard {
                                buf.push((dst, msg));
                                r.state.active.set_in(d, dst as usize);
                            } else {
                                r.cross.fetch_add(1, Ordering::Relaxed);
                                r.state.buffers.push(r.tid, d, (dst, msg.to_bits()));
                            }
                        }
                    }
                }
            }
            Mode::Pull => {
                // One lock-free store into our own outbox; recipients pull
                // next superstep. Activation still walks out-edges (the
                // framework must know who has mail); cross-shard
                // activations are plain atomic bit sets in the target
                // shard — no message buffering needed, the *data* stays
                // in this vertex's outbox.
                self.store.next_slot(self.v).store_first(msg);
                match &self.route {
                    None => {
                        self.bcast_next.set(self.v as usize);
                        for &dst in self.g.out_neighbors(self.v) {
                            self.active_next.set(dst as usize);
                        }
                    }
                    Some(r) => {
                        r.state.bcast_next.set(self.v as usize);
                        for &dst in self.g.out_neighbors(self.v) {
                            r.state.active.set(dst as usize);
                        }
                    }
                }
            }
        }
    }

    #[inline]
    fn vote_to_halt(&mut self) {
        self.halted = true;
    }

    #[inline]
    fn contribute(&mut self, x: AggValue<P>) {
        // Per-thread cell: no synchronisation needed (engine hands each
        // worker its own padded cell); merged at the barrier.
        let (acc, used) = self.agg_cell.get().clone();
        let merged = if used { self.agg.combine(acc, x) } else { x };
        *self.agg_cell.get_mut() = (merged, true);
    }

    #[inline]
    fn aggregated(&self) -> Option<&AggValue<P>> {
        self.agg_prev
    }

    #[inline]
    fn recv(&self) -> &[P::Message] {
        // Loud failure for the one silent misuse the plane API would
        // otherwise allow: a multiset program left on the combined plane
        // would see permanently empty inboxes and quietly return its
        // init values (the inverse mistake — combined program on the
        // log plane — already panics via NullCombiner).
        assert!(
            self.log_seg.is_some(),
            "recv() requires a log-plane program; set `type Delivery = \
             LogPlane` — combined-plane messages arrive pre-folded as \
             compute's `msg` argument"
        );
        self.inbox
    }
}

/// Adaptive superstep preamble shared verbatim by the flat and
/// partitioned loops (they must stay in lock-step for the
/// adaptive ≡ fixed trace contract): run the termination checks on the
/// live frontier count, obtain the superstep's knob plan, and surface
/// the EdgeCentric + bypass rebuild fallback if the tuner selected that
/// combination. `None` means halt — `metrics.halt_reason` is already
/// set and the caller breaks its loop.
fn adaptive_step(
    tuner: &mut AdaptiveTuner,
    superstep: usize,
    active_now: usize,
    n: usize,
    max_supersteps: usize,
    metrics: &mut RunMetrics,
) -> Option<StepPlan> {
    if active_now == 0 {
        metrics.halt_reason = HaltReason::Quiescence;
        return None;
    }
    if superstep >= max_supersteps {
        metrics.halt_reason = HaltReason::SuperstepCap;
        return None;
    }
    let step = tuner.decide(superstep, active_now, n);
    if step.schedule == Schedule::EdgeCentric && step.bypass && metrics.schedule_fallback.is_none()
    {
        // The tuner priced the per-superstep weight rebuild in; surface
        // it the same way fixed configs do.
        metrics.schedule_fallback = Some(ScheduleFallback::EdgeCentricBypassRebuild);
        warn_edge_centric_bypass_once();
    }
    Some(step)
}

/// Non-destructive sum over a probe array — the trace plane samples the
/// tuner's probes at the barrier *before* its draining `observe`, so
/// tracing never perturbs the signals the tuner acts on.
fn sum_probe_peeks(probes: &[CachePadded<ContentionProbe>]) -> (u64, u64) {
    let mut cas = 0u64;
    let mut lock = 0u64;
    for p in probes {
        let (c, l) = p.peek();
        cas += c;
        lock += l;
    }
    (cas, lock)
}

/// Messages per receiving vertex this superstep (0.0 when nothing was
/// delivered — log-plane runs count fan-in at the merge instead).
fn fan_in_ratio(messages: u64, delivered: u64) -> f64 {
    if delivered > 0 {
        messages as f64 / delivered as f64
    } else {
        0.0
    }
}

/// Rendered `schedule/strategy/iteration` triple of a superstep's
/// [`StepPlan`] — the label carried by the trace plane's tuner-decision
/// instants (one per executed superstep on adaptive runs). Shared with
/// the simulator so real and virtual traces agree on labels.
pub(crate) fn step_mode_label(step: &StepPlan) -> String {
    format!(
        "{:?}/{:?}/{}",
        step.schedule,
        step.strategy,
        if step.bypass { "list" } else { "scan" }
    )
}

/// One-time stderr note for the documented EdgeCentric + bypass
/// fallback (see [`Schedule::EdgeCentric`] and
/// [`ScheduleFallback::EdgeCentricBypassRebuild`]).
fn warn_edge_centric_bypass_once() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        eprintln!(
            "ipregel: edge-centric schedule with selection bypass cannot use \
             precomputed degree weights; falling back to rebuilding weights \
             from the active list every superstep (documented — see \
             Schedule::EdgeCentric; surfaced in RunMetrics::schedule_fallback)"
        );
    });
}

impl<'g, P, S> Engine<'g, P, S>
where
    P: VertexProgram,
    S: VertexStore<P::Value, P::Message>,
{
    /// Assemble an engine from session-prepared parts. `setup.store` must
    /// already hold initial values; activity and (for CAS-neutral runs)
    /// slot pre-loading happen here.
    pub(crate) fn with_setup(
        g: &'g Csr,
        program: &'g P,
        cfg: EngineConfig,
        halt: Halt<AggValue<P>>,
        setup: EngineSetup<S, P::Message>,
    ) -> Self {
        let EngineSetup {
            store,
            store_reused,
            mut bitsets,
            scan_weights,
            partition,
            log,
            tuner,
            cut_scratch,
            trace,
            query_tag,
        } = setup;
        let comb = program.combiner();
        let agg = program.aggregator();
        let mode = program.mode();
        let n = g.num_vertices();

        // The log plane is push-only: a pull-mode program publishes one
        // outbox message per superstep, which is the combined plane's
        // shape by construction (and the slot machinery already serves).
        assert!(
            log.is_none() || mode == Mode::Push,
            "log-plane programs must use Mode::Push — pull single-broadcast \
             publishes one combinable outbox message by design"
        );

        // CAS-neutral slot pre-loading only applies to the combined
        // plane; log-plane sends never touch the slots (and the
        // NullCombiner placeholder has no neutral element to load).
        if mode == Mode::Push && cfg.strategy == Strategy::CasNeutral && log.is_none() {
            for v in g.vertices() {
                cfg.strategy.reset_slot(store.cur_slot(v), &comb);
                cfg.strategy.reset_slot(store.next_slot(v), &comb);
            }
        }

        // Partitioned runs track activity in the ShardState instead of
        // the three flat bitsets — don't pay n-bit allocations (or drain
        // the session pool) for structures the sharded loop never reads.
        let mut next_bitset = || {
            if partition.is_some() {
                AtomicBitSet::new(0)
            } else {
                bitsets.pop().unwrap_or_else(|| AtomicBitSet::new(n))
            }
        };
        let active_next = next_bitset();
        let bcast_next = next_bitset();
        let bcast_cur = next_bitset();
        match &partition {
            Some(state) => {
                for v in g.vertices() {
                    if program.initially_active(g, v) {
                        state.active.set(v as usize);
                    }
                }
            }
            None => {
                for v in g.vertices() {
                    if program.initially_active(g, v) {
                        active_next.set(v as usize);
                    }
                }
            }
        }

        Engine {
            g,
            program,
            store,
            cfg,
            halt,
            comb,
            agg,
            mode,
            store_reused,
            active_next,
            bcast_next,
            bcast_cur,
            scan_weights,
            agg_prev: None,
            partition,
            log,
            tuner,
            cut_scratch,
            trace,
            query_tag,
        }
    }

    /// Disassemble after a run so the session can pool the parts.
    #[allow(clippy::type_complexity)]
    pub(crate) fn into_parts(
        self,
    ) -> (
        S,
        Vec<AtomicBitSet>,
        Option<ShardState>,
        Option<MessageLog<P::Message>>,
        Option<TunerState>,
        Vec<u64>,
        Option<TraceBuffers>,
    ) {
        (
            self.store,
            vec![self.active_next, self.bcast_next, self.bcast_cur],
            self.partition,
            self.log,
            self.tuner.map(AdaptiveTuner::into_state),
            self.cut_scratch,
            self.trace,
        )
    }

    /// Assemble the per-vertex context — shared by the flat and
    /// partitioned `run_vertex` closures so the two substrates cannot
    /// silently diverge in what a program observes.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    fn make_ctx<'a>(
        &'a self,
        v: VertexId,
        superstep: usize,
        strategy: Strategy,
        probe: Option<&'a ContentionProbe>,
        msg_counter: &'a AtomicU64,
        agg_cell: &'a SyncCell<(AggValue<P>, bool)>,
        agg_prev: Option<&'a AggValue<P>>,
        route: Option<ShardRoute<'a>>,
        inbox: &'a [P::Message],
        log_seg: Option<&'a SyncCell<Segment<P::Message>>>,
    ) -> Ctx<'a, P, S> {
        Ctx {
            g: self.g,
            store: &self.store,
            comb: &self.comb,
            agg: &self.agg,
            strategy,
            probe,
            mode: self.mode,
            active_next: &self.active_next,
            bcast_next: &self.bcast_next,
            msg_counter,
            agg_cell,
            agg_prev,
            route,
            inbox,
            log_seg,
            superstep,
            v,
            halted: false,
        }
    }

    /// Prefetch the head of `v`'s CSR row — the neighbour list the vertex
    /// is about to walk (out-row in push, in-row in pull). This is the
    /// row half of the staged scatter pipeline (DESIGN.md §2.9): the
    /// dense-list loops call it `pipeline_depth` vertices ahead of the
    /// cursor, and `collect_msg` prefetches the destination slots the
    /// same distance ahead inside the row. No-op off `x86_64` or under
    /// the `no-prefetch` feature.
    #[inline]
    #[allow(unused_variables)]
    fn prefetch_row(&self, v: Option<&VertexId>) {
        #[cfg(all(target_arch = "x86_64", not(feature = "no-prefetch")))]
        if let Some(&v) = v {
            let row = match self.mode {
                Mode::Push => self.g.out_neighbors(v),
                Mode::Pull => self.g.in_neighbors(v),
            };
            if let Some(first) = row.first() {
                // SAFETY: prefetch is only a hint.
                unsafe {
                    std::arch::x86_64::_mm_prefetch(
                        first as *const VertexId as *const i8,
                        std::arch::x86_64::_MM_HINT_T0,
                    );
                }
            }
        }
    }

    /// Combined incoming message for `v` at superstep start. `cross`
    /// (partitioned pull runs) classifies each combined contribution by
    /// the owner map and accumulates foreign-outbox combines. `depth` is
    /// the superstep's pipeline depth (how many slots ahead the pull
    /// scan prefetches); `lanes` feeds the vector kernel's utilisation
    /// back to the tuner.
    ///
    /// Reads with the *configured* strategy even on adaptive runs: Lock
    /// and Hybrid (the only pair the tuner moves between) share one slot
    /// discipline and one `collect` path, and CasNeutral — whose collect
    /// differs — is never entered or left adaptively.
    #[inline]
    fn collect_msg(
        &self,
        v: VertexId,
        msgs_done: &AtomicU64,
        cross: Option<(&PartitionPlan, &AtomicU64)>,
        depth: usize,
        lanes: &LaneCounters,
    ) -> Option<P::Message> {
        match self.mode {
            Mode::Push => {
                // Consume and reset the mailbox (owner-exclusive here).
                let slot = self.store.cur_slot(v);
                let m = self.cfg.strategy.collect(slot, &self.comb);
                if self.cfg.strategy == Strategy::CasNeutral && m.is_some() {
                    self.cfg.strategy.reset_slot(slot, &self.comb);
                }
                m
            }
            Mode::Pull => {
                #[cfg(not(all(target_arch = "x86_64", not(feature = "no-prefetch"))))]
                let _ = depth;
                // Combine in-neighbours' outboxes locally — the lock-free
                // pull loop whose memory behaviour §IV optimises. The
                // neighbour list reveals the access pattern iterations in
                // advance, so software-prefetch the slot `depth` ahead
                // (§Perf L3 — see EXPERIMENTS.md; depth is the tuner's
                // pipeline knob, default 8).
                let in_nbrs = self.g.in_neighbors(v);
                // Cross-classification by shard *bounds*, not per-source
                // owner-map loads: `v`'s shard range is fixed for the whole
                // scan, so foreignness is two register compares instead of
                // a random access into the owner array per message.
                let my_bounds = cross.map(|(plan, _)| {
                    let r = plan.shard_range(plan.shard_of(v));
                    (r.start as VertexId, r.end as VertexId)
                });
                let mut crossed = 0u64;
                let mut gather = |i: usize| {
                    #[cfg(all(target_arch = "x86_64", not(feature = "no-prefetch")))]
                    if let Some(&ahead) = in_nbrs.get(i + depth) {
                        // SAFETY: prefetch is only a hint.
                        unsafe {
                            std::arch::x86_64::_mm_prefetch(
                                self.store.cur_slot(ahead) as *const _ as *const i8,
                                std::arch::x86_64::_MM_HINT_T0,
                            );
                        }
                    }
                    let src = in_nbrs[i];
                    let m = self.store.cur_slot(src).peek_scan();
                    if m.is_some() {
                        if let Some((lo, hi)) = my_bounds {
                            if src < lo || src >= hi {
                                crossed += 1;
                            }
                        }
                    }
                    m
                };
                // Vectorised gather (DESIGN.md §2.9): an exact monoid with
                // a neutral element licenses reassociating the fold across
                // accumulator lanes, so long rows take the 4-lane unrolled
                // kernel. Short rows and inexact combiners keep the scalar
                // left-fold; the monoid contract makes both paths return
                // identical bits.
                let vector_neutral = match self.comb.monoid_kind() {
                    Some(_) if in_nbrs.len() >= VECTOR_GATHER_MIN => self.comb.neutral(),
                    _ => None,
                };
                let (acc, combined) = match vector_neutral {
                    Some(neutral) => {
                        let (acc, found) =
                            reduce_gather(in_nbrs.len(), &self.comb, neutral, &mut gather);
                        lanes.add(in_nbrs.len() as u64, found);
                        (acc, found)
                    }
                    None => {
                        let mut acc: Option<P::Message> = None;
                        let mut combined = 0u64;
                        for i in 0..in_nbrs.len() {
                            if let Some(m) = gather(i) {
                                combined += 1;
                                acc = Some(match acc {
                                    None => m,
                                    Some(a) => self.comb.combine(a, m),
                                });
                            }
                        }
                        (acc, combined)
                    }
                };
                if combined > 0 {
                    msgs_done.fetch_add(combined, Ordering::Relaxed);
                }
                if crossed > 0 {
                    if let Some((_, ctr)) = cross {
                        ctr.fetch_add(crossed, Ordering::Relaxed);
                    }
                }
                acc
            }
        }
    }

    /// Run to quiescence, the superstep cap, or per-run [`Halt`]
    /// convergence. Returns final values and metrics.
    pub fn run(&mut self) -> RunResult<P::Value> {
        let total = Timer::start();
        let mut metrics = RunMetrics {
            store_reused: self.store_reused,
            adaptive: self.tuner.is_some(),
            delivery_plane: if self.log.is_some() {
                DeliveryPlaneKind::Log
            } else {
                DeliveryPlaneKind::Combined
            },
            ..RunMetrics::default()
        };
        if let Some(state) = &self.partition {
            metrics.shards = state.plan.num_shards();
            metrics.shard_edge_imbalance = state.plan.edge_imbalance();
        }
        if self.cfg.schedule == Schedule::EdgeCentric && self.cfg.bypass {
            metrics.schedule_fallback = Some(ScheduleFallback::EdgeCentricBypassRebuild);
            warn_edge_centric_bypass_once();
        }
        let max_supersteps = self
            .halt
            .max_supersteps
            .map_or(self.cfg.max_supersteps, |h| h.min(self.cfg.max_supersteps));

        // Serving-layer attribution: stamp the context tag into the
        // metrics, and mark the trace before superstep 0 so interleaved
        // Chrome traces can be sliced per query.
        metrics.query_tag = self.query_tag;
        if let (Some(tr), Some(tag)) = (self.trace.as_ref(), self.query_tag) {
            tr.instant(tr.engine_lane(), 0, InstantKind::QueryContext { tag });
        }

        // Row-plane run fencing: mark this run active (barrier-time
        // eviction requires exclusivity — serving-layer queries share one
        // plane) and snapshot the counters so metrics report this run's
        // delta rather than plane lifetime totals.
        let plane_start = self.g.row_plane().map(|p| {
            p.run_enter();
            p.stats()
        });

        if self.partition.is_some() {
            self.run_partitioned(&mut metrics, max_supersteps);
        } else {
            self.run_flat(&mut metrics, max_supersteps);
        }

        if let (Some(start), Some(p)) = (&plane_start, self.g.row_plane()) {
            metrics.row_plane = Some(p.stats().delta_from(start));
            p.run_exit();
        }
        if let Some(t) = self.tuner.as_mut() {
            metrics.tuner_decisions = t.take_trace();
        }
        if let Some(tr) = self.trace.as_mut() {
            // Harvest the observability plane: the finished event trace
            // and the measured per-shard timing vector (the engine's
            // answer to the paper's NUMA-placement question — where did
            // the time actually go, shard by shard).
            let (trace, shard_times) = tr.take_run();
            metrics.shard_times = shard_times;
            metrics.trace = Some(trace);
        }

        metrics.total_time = total.elapsed();
        let values = self
            .g
            .vertices()
            .map(|v| self.store.value(v).clone())
            .collect();
        RunResult { values, metrics }
    }

    /// The flat superstep loop (`Partitioning::None`) — one global
    /// mailbox array, the pre-partition engine bit-for-bit.
    fn run_flat(&mut self, metrics: &mut RunMetrics, max_supersteps: usize) {
        let n = self.g.num_vertices();
        let threads = self.cfg.threads.max(1);

        // Per-thread padded message counters (hot-path friendly).
        let counters: Vec<CachePadded<AtomicU64>> =
            (0..threads).map(|_| CachePadded::new(AtomicU64::new(0))).collect();
        let pull_comb_counter = AtomicU64::new(0);
        // Combined plane: payloads handed to compute (vertices whose
        // mailbox held a message); the run-level difference against
        // total sends/combines is what the combiner folded away.
        let delivered_counter = AtomicU64::new(0);
        let neutral = self.agg.neutral();
        let agg_cells: Vec<CachePadded<SyncCell<(AggValue<P>, bool)>>> = (0..threads)
            .map(|_| CachePadded::new(SyncCell::new((neutral.clone(), false))))
            .collect();
        let lane_counters = LaneCounters::new();
        // Session-pooled scratch for the edge-centric bypass weight
        // rebuild (weights change every superstep; the allocation should
        // not) — handed back to the pool at the end of the run.
        let mut scratch = std::mem::take(&mut self.cut_scratch);

        let mut superstep = 0usize;
        let mut delivered_total = 0u64;
        // Per-query token budget (serving layer): cumulative messages +
        // activations, checked at the barrier tail. `None` (every solo
        // run) never enters the check, so the solo path is untouched.
        let max_tokens = self.halt.max_tokens;
        let mut tokens_used = 0u64;
        loop {
            // ---- Per-superstep knob plan --------------------------------
            // Fixed-config runs use the config verbatim; adaptive runs
            // re-decide schedule/strategy/bypass from live signals (see
            // engine/tune.rs — results stay bit-identical either way).
            // The adaptive path counts the frontier (its primary signal)
            // and runs the termination checks BEFORE deciding, so the
            // trace holds exactly one decision per executed superstep.
            let step = match self.tuner.as_mut() {
                Some(t) => {
                    let active_now = self.active_next.count();
                    match adaptive_step(t, superstep, active_now, n, max_supersteps, metrics) {
                        Some(s) => s,
                        None => break,
                    }
                }
                None => StepPlan::of(&self.cfg),
            };
            let depth = step.effective_pipeline_depth();
            if self.tuner.is_some() {
                if let Some(tr) = self.trace.as_ref() {
                    tr.instant(
                        tr.engine_lane(),
                        superstep,
                        InstantKind::TunerDecision {
                            mode: step_mode_label(&step),
                        },
                    );
                }
            }

            // ---- Snapshot this superstep's active set -------------------
            let active_list: Option<Vec<VertexId>> = if step.bypass {
                Some(
                    self.active_next
                        .iter()
                        .map(|i| i as VertexId)
                        .collect(),
                )
            } else {
                None
            };
            let active_scan = if step.bypass {
                None
            } else {
                Some(self.active_next.snapshot())
            };
            let active_count = match (&active_list, &active_scan) {
                (Some(l), _) => l.len(),
                (_, Some(b)) => b.count(),
                _ => unreachable!(),
            };
            if active_count == 0 {
                metrics.halt_reason = HaltReason::Quiescence;
                break;
            }
            if superstep >= max_supersteps {
                metrics.halt_reason = HaltReason::SuperstepCap;
                break;
            }
            self.active_next.clear_all();

            // ---- Compute phase -----------------------------------------
            let t_compute = Timer::start();
            let c0 = self.trace.as_ref().map(|tr| tr.now_ns());
            {
                let engine = &self;
                let counters = &counters;
                let pull_comb_counter = &pull_comb_counter;
                let superstep_now = superstep;

                // Edge-centric weights for bypass runs are rebuilt every
                // superstep from the active list (the §V-A overhead the
                // paper attributes to selection-bypass benchmarks — the
                // documented fallback surfaced in
                // `RunMetrics::schedule_fallback`), into the pooled
                // scratch so the rebuild stops allocating.
                let bypass_weights: Option<&[u64]> = match (&active_list, step.schedule) {
                    (Some(list), Schedule::EdgeCentric) => {
                        scratch.clear();
                        scratch.extend(list.iter().map(|&v| match self.mode {
                            Mode::Push => self.g.out_degree(v) as u64,
                            Mode::Pull => self.g.in_degree(v) as u64,
                        }));
                        Some(scratch.as_slice())
                    }
                    _ => None,
                };

                let agg_cells = &agg_cells;
                let agg_prev_now = self.agg_prev.as_ref();
                let log_ref = self.log.as_ref();
                let trace_ref = self.trace.as_ref();
                // Traced non-adaptive runs route delivery through the
                // trace plane's own probes so contention is measured
                // either way (`deliver_probed` only counts — values stay
                // bit-identical to the probe-free path).
                let probes = self
                    .tuner
                    .as_ref()
                    .map(|t| t.probes())
                    .or_else(|| trace_ref.map(|tr| tr.probes()));
                let delivered_counter = &delivered_counter;
                let lanes = &lane_counters;
                let run_vertex = |tid: usize, v: VertexId| {
                    let (msg, inbox): (Option<P::Message>, &[P::Message]) = match log_ref {
                        None => {
                            let m = engine.collect_msg(v, pull_comb_counter, None, depth, lanes);
                            if m.is_some() {
                                delivered_counter.fetch_add(1, Ordering::Relaxed);
                            }
                            (m, &[])
                        }
                        Some(l) => (None, l.inbox(v)),
                    };
                    let mut ctx = engine.make_ctx(
                        v,
                        superstep_now,
                        step.strategy,
                        probes.map(|ps| &*ps[tid]),
                        &counters[tid],
                        &agg_cells[tid],
                        agg_prev_now,
                        None,
                        inbox,
                        log_ref.map(|l| l.seg(tid)),
                    );
                    engine.program.compute(&mut ctx, msg);
                    if !ctx.halted {
                        engine.active_next.set(v as usize);
                    }
                };

                match (&active_list, &active_scan) {
                    (Some(list), _) => {
                        // Selection bypass: iterate the dense active list,
                        // prefetching the CSR row `depth` vertices ahead
                        // (the list reveals the walk order in advance).
                        parallel_for(
                            threads,
                            list.len(),
                            step.schedule,
                            bypass_weights,
                            |tid, range| {
                                let t0 = trace_ref.map(|tr| tr.now_ns());
                                for i in range {
                                    engine.prefetch_row(list.get(i + depth));
                                    run_vertex(tid, list[i]);
                                }
                                if let (Some(tr), Some(t0)) = (trace_ref, t0) {
                                    tr.span(tid, superstep_now, Phase::Compute, None, t0, tr.now_ns());
                                }
                            },
                        );
                    }
                    (_, Some(bits)) => {
                        // Full scan: iterate all ids, skip inactive — the
                        // baseline behaviour bypass eliminates.
                        parallel_for(
                            threads,
                            n,
                            step.schedule,
                            self.scan_weights.as_ref().map(|w| w.as_slice()),
                            |tid, range| {
                                let t0 = trace_ref.map(|tr| tr.now_ns());
                                for i in range {
                                    if bits.get(i) {
                                        run_vertex(tid, i as VertexId);
                                    }
                                }
                                if let (Some(tr), Some(t0)) = (trace_ref, t0) {
                                    tr.span(tid, superstep_now, Phase::Compute, None, t0, tr.now_ns());
                                }
                            },
                        );
                    }
                    _ => unreachable!(),
                }
            }
            let compute_time = t_compute.elapsed();
            if let (Some(tr), Some(c0)) = (self.trace.as_ref(), c0) {
                tr.span(tr.engine_lane(), superstep, Phase::Compute, None, c0, tr.now_ns());
            }

            // ---- Barrier phase -----------------------------------------
            let t_barrier = Timer::start();
            let b0 = self.trace.as_ref().map(|tr| tr.now_ns());
            if self.mode == Mode::Pull {
                // Clear outboxes consumed this superstep, then rotate the
                // broadcaster sets.
                for v in self.bcast_cur.iter() {
                    self.store.cur_slot(v as VertexId).clear();
                }
                std::mem::swap(&mut self.bcast_cur, &mut self.bcast_next);
                self.bcast_next.clear_all();
            }
            if let Some(log) = self.log.as_mut() {
                // Log plane: fold the worker segments into next
                // superstep's per-vertex logs (every payload retained).
                metrics.retained_messages += log.merge_segments();
            }
            self.store.swap_epochs();
            let converged = self.merge_aggregators(&agg_cells, &neutral);
            if let Some(p) = self.g.row_plane() {
                // Workers are joined between supersteps: the plane may
                // apply its eviction policy (run-exclusive; graph/rows.rs).
                p.barrier_advise();
            }
            let barrier_time = t_barrier.elapsed();
            if let (Some(tr), Some(b0)) = (self.trace.as_ref(), b0) {
                tr.span(tr.engine_lane(), superstep, Phase::Barrier, None, b0, tr.now_ns());
            }

            let messages = counters
                .iter()
                .map(|c| c.swap(0, Ordering::Relaxed))
                .sum::<u64>()
                + pull_comb_counter.swap(0, Ordering::Relaxed);
            let delivered_step = delivered_counter.swap(0, Ordering::Relaxed);
            delivered_total += delivered_step;
            let (lanes_scanned, lanes_useful) = lane_counters.take();
            metrics.vector_lanes_scanned += lanes_scanned;
            metrics.vector_lanes_useful += lanes_useful;
            if let Some(tr) = self.trace.as_mut() {
                // Seal the superstep's events before `observe` drains the
                // probes the sample reads (peeked, so the tuner still
                // sees the full counts — decisions stay bit-identical).
                let (cas_retries, lock_contended) = match self.tuner.as_ref() {
                    Some(t) => sum_probe_peeks(t.probes()),
                    None => tr.take_probe_counts(),
                };
                tr.drain_barrier(BarrierSignals {
                    superstep,
                    fan_in: fan_in_ratio(messages, delivered_step),
                    cas_retries,
                    lock_contended,
                    lane_utilisation: LaneCounters::ratio(lanes_scanned, lanes_useful),
                });
            }
            if let Some(t) = self.tuner.as_mut() {
                // Flat runs have no flush phase or shard deques: imbalance
                // is neutral and steals are zero by construction.
                t.observe(
                    messages,
                    delivered_step,
                    1.0,
                    0,
                    LaneCounters::ratio(lanes_scanned, lanes_useful),
                );
            }

            metrics.supersteps.push(SuperstepStats {
                active_vertices: active_count,
                messages,
                compute_time,
                flush_time: Duration::ZERO,
                barrier_time,
            });
            superstep += 1;
            if converged {
                metrics.halt_reason = HaltReason::Converged;
                break;
            }
            tokens_used += messages + active_count as u64;
            if let Some(cap) = max_tokens {
                if tokens_used >= cap {
                    metrics.halt_reason = HaltReason::BudgetExhausted;
                    break;
                }
            }
        }
        self.cut_scratch = scratch;
        if self.log.is_none() {
            // Retained vs combined: on the combined plane, everything
            // sent (push) or scanned into a fold (pull) minus what
            // reached compute as a distinct payload was folded away.
            metrics.combined_messages = metrics
                .total_messages()
                .saturating_sub(delivered_total);
        }
    }

    /// The partitioned superstep loop: scatter / flush / apply over the
    /// shard substrate. Must produce bit-identical values, activation
    /// sets and message counts to [`Engine::run_flat`] — the parity
    /// matrix in `rust/tests/test_partition.rs` pins this down.
    fn run_partitioned(&mut self, metrics: &mut RunMetrics, max_supersteps: usize) {
        let mut part = self
            .partition
            .take()
            // audit:allow(panic): construction invariant — `Engine::run`
            // dispatches here only when `with_setup` installed shard state.
            .expect("run_partitioned requires shard state");
        let n = self.g.num_vertices();
        let n_shards = part.plan.num_shards();
        let threads = self.cfg.threads.max(1);

        let counters: Vec<CachePadded<AtomicU64>> =
            (0..threads).map(|_| CachePadded::new(AtomicU64::new(0))).collect();
        let pull_comb_counter = AtomicU64::new(0);
        let delivered_counter = AtomicU64::new(0);
        let cross_counter = AtomicU64::new(0);
        let neutral = self.agg.neutral();
        let agg_cells: Vec<CachePadded<SyncCell<(AggValue<P>, bool)>>> = (0..threads)
            .map(|_| CachePadded::new(SyncCell::new((neutral.clone(), false))))
            .collect();
        let lane_counters = LaneCounters::new();
        // Session-pooled scratch for the edge-centric bypass weight
        // rebuild (see run_flat) — handed back at the end of the run.
        let mut scratch = std::mem::take(&mut self.cut_scratch);

        let mut superstep = 0usize;
        let mut delivered_total = 0u64;
        // Per-query token budget — see run_flat; identical semantics so
        // budget-halted runs stay substrate-agnostic.
        let max_tokens = self.halt.max_tokens;
        let mut tokens_used = 0u64;
        loop {
            // ---- Per-superstep knob plan (see run_flat / engine/tune.rs)
            let step = match self.tuner.as_mut() {
                Some(t) => {
                    let active_now = part.active.count();
                    match adaptive_step(t, superstep, active_now, n, max_supersteps, metrics) {
                        Some(s) => s,
                        None => break,
                    }
                }
                None => StepPlan::of(&self.cfg),
            };
            let shard_sched = step.schedule.for_shards();
            let depth = step.effective_pipeline_depth();
            let mut steals_step = 0u64;
            if self.tuner.is_some() {
                if let Some(tr) = self.trace.as_ref() {
                    tr.instant(
                        tr.engine_lane(),
                        superstep,
                        InstantKind::TunerDecision {
                            mode: step_mode_label(&step),
                        },
                    );
                }
            }

            // ---- Snapshot each shard's active set ----------------------
            let shard_lists: Option<Vec<Vec<VertexId>>> = if step.bypass {
                Some(
                    (0..n_shards)
                        .map(|s| part.active.iter_shard(s).collect())
                        .collect(),
                )
            } else {
                None
            };
            let shard_scans: Option<Vec<BitSet>> = if step.bypass {
                None
            } else {
                Some((0..n_shards).map(|s| part.active.snapshot_shard(s)).collect())
            };
            let active_count = match (&shard_lists, &shard_scans) {
                (Some(ls), _) => ls.iter().map(|l| l.len()).sum(),
                (_, Some(bs)) => bs.iter().map(|b| b.count()).sum(),
                _ => unreachable!(),
            };
            if active_count == 0 {
                metrics.halt_reason = HaltReason::Quiescence;
                break;
            }
            if superstep >= max_supersteps {
                metrics.halt_reason = HaltReason::SuperstepCap;
                break;
            }
            part.active.clear_all();

            // Edge-centric shard weights: static shard edge totals for
            // scans (borrowed straight from the plan — the old path
            // copied them into a fresh Vec every superstep), active-degree
            // sums for bypass runs (rebuilt per superstep into the pooled
            // scratch — the documented bypass fallback).
            let scatter_weights: Option<&[u64]> = if step.schedule == Schedule::EdgeCentric {
                Some(match &shard_lists {
                    Some(lists) => {
                        scratch.clear();
                        scratch.extend(lists.iter().map(|l| {
                            l.iter()
                                .map(|&v| match self.mode {
                                    Mode::Push => self.g.out_degree(v) as u64,
                                    Mode::Pull => self.g.in_degree(v) as u64,
                                })
                                .sum::<u64>()
                        }));
                        scratch.as_slice()
                    }
                    None => match self.mode {
                        Mode::Push => part.plan.out_edges(),
                        Mode::Pull => part.plan.in_edges(),
                    },
                })
            } else {
                None
            };

            // ---- Scatter phase -----------------------------------------
            let t_scatter = Timer::start();
            let s0 = self.trace.as_ref().map(|tr| tr.now_ns());
            {
                let engine = &self;
                let part_ref = &part;
                let counters = &counters;
                let pull_comb_counter = &pull_comb_counter;
                let cross_counter = &cross_counter;
                let agg_cells = &agg_cells;
                let agg_prev_now = self.agg_prev.as_ref();
                let superstep_now = superstep;

                let plan: &PartitionPlan = &part_ref.plan;
                let log_ref = self.log.as_ref();
                let trace_ref = self.trace.as_ref();
                // As in run_flat: traced non-adaptive runs measure
                // contention through the trace plane's own probes.
                let probes = self
                    .tuner
                    .as_ref()
                    .map(|t| t.probes())
                    .or_else(|| trace_ref.map(|tr| tr.probes()));
                let delivered_counter = &delivered_counter;
                let lanes = &lane_counters;
                let run_vertex = |tid: usize, shard: usize, v: VertexId| {
                    let (msg, inbox): (Option<P::Message>, &[P::Message]) = match log_ref {
                        None => {
                            let m = engine.collect_msg(
                                v,
                                pull_comb_counter,
                                Some((plan, cross_counter)),
                                depth,
                                lanes,
                            );
                            if m.is_some() {
                                delivered_counter.fetch_add(1, Ordering::Relaxed);
                            }
                            (m, &[])
                        }
                        Some(l) => (None, l.inbox(v)),
                    };
                    let mut ctx = engine.make_ctx(
                        v,
                        superstep_now,
                        step.strategy,
                        probes.map(|ps| &*ps[tid]),
                        &counters[tid],
                        &agg_cells[tid],
                        agg_prev_now,
                        Some(ShardRoute {
                            plan,
                            state: part_ref,
                            shard,
                            tid,
                            cross: cross_counter,
                        }),
                        inbox,
                        log_ref.map(|l| l.seg(tid)),
                    );
                    engine.program.compute(&mut ctx, msg);
                    if !ctx.halted {
                        part_ref.active.set_in(shard, v as usize);
                    }
                };

                let shard_lists = &shard_lists;
                let shard_scans = &shard_scans;
                // Row-plane staging: the direction this superstep's
                // scatter walks (push reads out-rows, pull reads in-rows).
                let plane_ref = self.g.row_plane();
                let pin_dir = match self.mode {
                    Mode::Push => RowDir::Out,
                    Mode::Pull => RowDir::In,
                };
                let scatter_shard = |tid: usize, s: usize, stolen: bool| {
                    if stolen {
                        if let Some(tr) = trace_ref {
                            tr.instant(tid, superstep_now, InstantKind::Steal { shard: s as u32 });
                        }
                    }
                    if let Some(p) = plane_ref {
                        // Decode every block the shard's vertex range
                        // touches before walking it, so the per-vertex
                        // loop only ever takes the READY fast path
                        // (stats label these `staged_blocks`).
                        let r = plan.shard_range(s);
                        p.pin_range(pin_dir, r.start, r.end);
                    }
                    let t0 = trace_ref.map(|tr| tr.now_ns());
                    match (shard_lists, shard_scans) {
                        (Some(lists), _) => {
                            // Dense per-shard list: prefetch the CSR row
                            // `depth` vertices ahead of the cursor (the
                            // list reveals the walk order in advance).
                            for (j, &v) in lists[s].iter().enumerate() {
                                engine.prefetch_row(lists[s].get(j + depth));
                                run_vertex(tid, s, v);
                            }
                        }
                        (_, Some(scans)) => {
                            // Full scan semantics, per shard: every
                            // vertex pays the activity check, as in
                            // the flat scan — the §II baseline cost
                            // the bypass knob exists to remove (and
                            // what the sim prices for this path).
                            let range = part_ref.plan.shard_range(s);
                            let base = range.start;
                            for i in 0..range.len() {
                                if scans[s].get(i) {
                                    run_vertex(tid, s, (base + i) as VertexId);
                                }
                            }
                        }
                        _ => unreachable!(),
                    }
                    if let (Some(tr), Some(t0)) = (trace_ref, t0) {
                        tr.span(
                            tid,
                            superstep_now,
                            Phase::Scatter,
                            Some((s as u32, stolen)),
                            t0,
                            tr.now_ns(),
                        );
                    }
                };
                if self.cfg.steal {
                    // Work-stealing dispatch (DESIGN.md §2.9): shards seed
                    // per-worker deques — weight-balanced when edge-centric
                    // weights exist — and a drained worker steals from the
                    // most-loaded peer instead of idling at the flush
                    // barrier. Intra-shard owner exclusivity is preserved:
                    // a stolen shard runs on exactly one worker. The
                    // tagged variant tells the body which shards migrated
                    // so the trace can attribute them.
                    steals_step += steal_execute_tagged(
                        threads,
                        n_shards,
                        scatter_weights,
                        step.effective_steal_chunk(),
                        active_count,
                        &scatter_shard,
                    );
                } else {
                    parallel_for_hinted(
                        threads,
                        n_shards,
                        shard_sched,
                        scatter_weights,
                        active_count,
                        |tid, shard_range| {
                            for s in shard_range {
                                scatter_shard(tid, s, false);
                            }
                        },
                    );
                }
            }
            let compute_time = t_scatter.elapsed();
            if let (Some(tr), Some(s0)) = (self.trace.as_ref(), s0) {
                tr.span(tr.engine_lane(), superstep, Phase::Scatter, None, s0, tr.now_ns());
            }

            // ---- Flush phase: drain remote buffers shard-at-a-time -----
            // (Push mode only — pull never writes a remote buffer, so
            // skip even the pending scan on pull workloads.)
            let t_flush = Timer::start();
            let f0 = self.trace.as_ref().map(|tr| tr.now_ns());
            let flush_weights: Option<Vec<u64>> = if self.mode == Mode::Push {
                Some(part.buffers.pending_weights())
            } else {
                None
            };
            let cross_pending: u64 = match &flush_weights {
                // Dense u64 range: the §2.9 slice kernel (SSE2 sum on
                // x86_64, bit-identical scalar unroll elsewhere).
                Some(w) => reduce_slice_u64(w, MonoidKind::Sum),
                None => 0,
            };
            // Max-over-mean flush load: the tuner's shard-skew signal
            // (1.0 = balanced, nothing pending, or pull mode).
            let flush_imbalance = match &flush_weights {
                Some(w) if cross_pending > 0 => {
                    let max = w.iter().copied().max().unwrap_or(0) as f64;
                    max * n_shards as f64 / cross_pending as f64
                }
                _ => 1.0,
            };
            if cross_pending > 0 {
                let engine = &self;
                let part_ref = &part;
                let log_ref = self.log.as_ref();
                let trace_ref = self.trace.as_ref();
                let superstep_now = superstep;
                // audit:allow(panic): phase invariant — `cross_pending`
                // is only non-zero in push mode, which always builds
                // flush weights at superstep start.
                let weights = flush_weights.as_ref().expect("push mode");
                let flush_shard = |tid: usize, d: usize, stolen: bool| {
                    if stolen {
                        if let Some(tr) = trace_ref {
                            tr.instant(tid, superstep_now, InstantKind::Steal { shard: d as u32 });
                        }
                    }
                    let t0 = trace_ref.map(|tr| tr.now_ns());
                    part_ref.buffers.drain_for(d, |(dst, bits)| {
                        let m = <P::Message as MessageValue>::from_bits(bits);
                        match log_ref {
                            // Owner-exclusive: Lock and Hybrid
                            // share one fold here, so the tuner's
                            // per-superstep strategy is safe.
                            None => step.strategy.deliver_exclusive(
                                engine.store.next_slot(dst),
                                m,
                                &engine.comb,
                            ),
                            // Log plane: the flush task appends
                            // the batched remote messages to its
                            // own segment; the barrier merge
                            // folds them into the logs.
                            Some(l) => l.seg(tid).get_mut().push((dst, m)),
                        }
                        part_ref.active.set_in(d, dst as usize);
                    });
                    if let (Some(tr), Some(t0)) = (trace_ref, t0) {
                        tr.span(
                            tid,
                            superstep_now,
                            Phase::Flush,
                            Some((d as u32, stolen)),
                            t0,
                            tr.now_ns(),
                        );
                    }
                };
                if self.cfg.steal {
                    // Stealing drains destination shards too: the pending
                    // counts seed the deques, so a worker stuck behind one
                    // hot destination hands its remaining shards to peers.
                    steals_step += steal_execute_tagged(
                        threads,
                        n_shards,
                        Some(weights.as_slice()),
                        step.effective_steal_chunk(),
                        cross_pending as usize,
                        &flush_shard,
                    );
                } else {
                    parallel_for_hinted(
                        threads,
                        n_shards,
                        shard_sched,
                        if shard_sched.needs_weights() {
                            Some(weights.as_slice())
                        } else {
                            None
                        },
                        cross_pending as usize,
                        |tid, shard_range| {
                            for d in shard_range {
                                flush_shard(tid, d, false);
                            }
                        },
                    );
                }
            }
            let flush_time = t_flush.elapsed();
            if let (Some(tr), Some(f0)) = (self.trace.as_ref(), f0) {
                tr.span(tr.engine_lane(), superstep, Phase::Flush, None, f0, tr.now_ns());
            }

            // ---- Apply phase (barrier) ---------------------------------
            let t_apply = Timer::start();
            let a0 = self.trace.as_ref().map(|tr| tr.now_ns());
            if self.mode == Mode::Pull {
                for v in part.bcast_cur.iter_all() {
                    self.store.cur_slot(v).clear();
                }
                std::mem::swap(&mut part.bcast_cur, &mut part.bcast_next);
                part.bcast_next.clear_all();
            }
            if let Some(log) = self.log.as_mut() {
                metrics.retained_messages += log.merge_segments();
            }
            self.store.swap_epochs();
            let converged = self.merge_aggregators(&agg_cells, &neutral);
            if let Some(p) = self.g.row_plane() {
                // Workers are joined at the apply barrier: the plane may
                // apply its eviction policy (run-exclusive; graph/rows.rs).
                p.barrier_advise();
            }
            let barrier_time = t_apply.elapsed();
            if let (Some(tr), Some(a0)) = (self.trace.as_ref(), a0) {
                tr.span(tr.engine_lane(), superstep, Phase::Apply, None, a0, tr.now_ns());
            }

            let messages = counters
                .iter()
                .map(|c| c.swap(0, Ordering::Relaxed))
                .sum::<u64>()
                + pull_comb_counter.swap(0, Ordering::Relaxed);
            let cross_step = cross_counter.swap(0, Ordering::Relaxed);
            metrics.cross_shard_messages += cross_step;
            metrics.intra_shard_messages += messages - cross_step;
            let delivered_step = delivered_counter.swap(0, Ordering::Relaxed);
            delivered_total += delivered_step;
            metrics.steals += steals_step;
            let (lanes_scanned, lanes_useful) = lane_counters.take();
            metrics.vector_lanes_scanned += lanes_scanned;
            metrics.vector_lanes_useful += lanes_useful;
            if let Some(tr) = self.trace.as_mut() {
                // Seal the superstep before `observe` drains the probes
                // (see run_flat — peeks keep the tuner's view intact).
                let (cas_retries, lock_contended) = match self.tuner.as_ref() {
                    Some(t) => sum_probe_peeks(t.probes()),
                    None => tr.take_probe_counts(),
                };
                tr.drain_barrier(BarrierSignals {
                    superstep,
                    fan_in: fan_in_ratio(messages, delivered_step),
                    cas_retries,
                    lock_contended,
                    lane_utilisation: LaneCounters::ratio(lanes_scanned, lanes_useful),
                });
            }
            if let Some(t) = self.tuner.as_mut() {
                t.observe(
                    messages,
                    delivered_step,
                    flush_imbalance,
                    steals_step,
                    LaneCounters::ratio(lanes_scanned, lanes_useful),
                );
            }

            metrics.supersteps.push(SuperstepStats {
                active_vertices: active_count,
                messages,
                compute_time,
                flush_time,
                barrier_time,
            });
            superstep += 1;
            if converged {
                metrics.halt_reason = HaltReason::Converged;
                break;
            }
            tokens_used += messages + active_count as u64;
            if let Some(cap) = max_tokens {
                if tokens_used >= cap {
                    metrics.halt_reason = HaltReason::BudgetExhausted;
                    break;
                }
            }
        }
        self.cut_scratch = scratch;
        if self.log.is_none() {
            metrics.combined_messages = metrics
                .total_messages()
                .saturating_sub(delivered_total);
        }

        self.partition = Some(part);
    }

    /// Merge this superstep's per-worker aggregator partials and evaluate
    /// the convergence predicate (single-threaded barrier step; workers
    /// are joined, so the plain reads are race-free).
    fn merge_aggregators(
        &mut self,
        agg_cells: &[CachePadded<SyncCell<(AggValue<P>, bool)>>],
        neutral: &AggValue<P>,
    ) -> bool {
        let mut merged: Option<AggValue<P>> = None;
        for cell in agg_cells {
            let (acc, used) = cell.get().clone();
            if used {
                merged = Some(match merged {
                    None => acc,
                    Some(m) => self.agg.combine(m, acc),
                });
            }
            *cell.get_mut() = (neutral.clone(), false);
        }
        // The predicate only ever sees supersteps where the aggregator
        // stream is live: while nothing has contributed yet both values
        // are None, and a predicate like |a, b| a == b would otherwise
        // halt superstep 1 of every run that aggregates late (or not
        // at all).
        let converged = match &self.halt.converged {
            Some(pred) if self.agg_prev.is_some() || merged.is_some() => {
                pred(self.agg_prev.as_ref(), merged.as_ref())
            }
            _ => false,
        };
        self.agg_prev = merged;
        converged
    }
}
