//! The superstep loop shared by all engine versions.
//!
//! One [`Engine`] implements both communication modes and both active-set
//! representations; the mode/bypass branches sit at superstep granularity,
//! outside the per-vertex hot loop, and the store type is monomorphised so
//! layout differences compile down to pointer arithmetic.
//!
//! Engines are constructed by [`crate::engine::GraphSession`] from pooled
//! parts (a primed [`VertexStore`], recycled activity bitsets, shared
//! edge-centric scan weights) and hand those parts back after the run so
//! the next run skips the allocations.

use crate::combine::{Combiner, Strategy};
use crate::engine::session::Halt;
use crate::engine::{AggValue, Aggregator, Context, EngineConfig, Mode, RunResult, VertexProgram};
use crate::graph::csr::{Csr, EdgeWeight, VertexId};
use crate::layout::{SyncCell, VertexStore};
use crate::metrics::{HaltReason, RunMetrics, SuperstepStats};
use crate::sched::{parallel_for, Schedule};
use crate::util::bitset::AtomicBitSet;
use crate::util::timer::Timer;
use crate::util::CachePadded;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Reusable allocations a [`crate::engine::GraphSession`] threads through
/// consecutive runs.
pub(crate) struct EngineSetup<S> {
    /// Value-initialised store (fresh-built or pool-recycled and reset).
    pub store: S,
    /// Whether `store` came out of the session pool.
    pub store_reused: bool,
    /// Up to three recycled, cleared, `n`-bit activity bitsets.
    pub bitsets: Vec<AtomicBitSet>,
    /// Degree weights for edge-centric full scans, shared session-wide.
    pub scan_weights: Option<Arc<Vec<u64>>>,
}

/// The engine: graph + program + store + activity tracking.
pub struct Engine<'g, P: VertexProgram, S: VertexStore<P::Value, P::Message>> {
    g: &'g Csr,
    program: &'g P,
    store: S,
    cfg: EngineConfig,
    halt: Halt<AggValue<P>>,
    comb: P::Comb,
    agg: P::Agg,
    mode: Mode,
    store_reused: bool,
    /// Vertices active in the *next* superstep (set during compute).
    active_next: AtomicBitSet,
    /// Pull mode: vertices that broadcast *this* superstep (their outbox
    /// slots need clearing two barriers later).
    bcast_next: AtomicBitSet,
    /// Pull mode: vertices whose outbox holds last superstep's broadcast.
    bcast_cur: AtomicBitSet,
    /// Degree weights for edge-centric scans (out- or in-degrees depending
    /// on mode; computed once per session and shared across runs).
    scan_weights: Option<Arc<Vec<u64>>>,
    /// Merged aggregator value from the previous superstep.
    agg_prev: Option<AggValue<P>>,
}

/// Per-vertex context implementation. Holds only shared references plus
/// the per-vertex mutable bits, so constructing one per vertex is free.
struct Ctx<'a, P: VertexProgram, S: VertexStore<P::Value, P::Message>> {
    g: &'a Csr,
    store: &'a S,
    comb: &'a P::Comb,
    agg: &'a P::Agg,
    strategy: Strategy,
    mode: Mode,
    active_next: &'a AtomicBitSet,
    bcast_next: &'a AtomicBitSet,
    msg_counter: &'a AtomicU64,
    /// This worker's aggregator partial: (accumulated, contributed?).
    agg_cell: &'a SyncCell<(AggValue<P>, bool)>,
    agg_prev: Option<&'a AggValue<P>>,
    superstep: usize,
    v: VertexId,
    halted: bool,
}

impl<'a, P, S> Context<P::Value, P::Message, AggValue<P>> for Ctx<'a, P, S>
where
    P: VertexProgram,
    S: VertexStore<P::Value, P::Message>,
{
    #[inline]
    fn id(&self) -> VertexId {
        self.v
    }

    #[inline]
    fn superstep(&self) -> usize {
        self.superstep
    }

    #[inline]
    fn num_vertices(&self) -> usize {
        self.g.num_vertices()
    }

    #[inline]
    fn value(&self) -> &P::Value {
        self.store.value(self.v)
    }

    #[inline]
    fn value_mut(&mut self) -> &mut P::Value {
        self.store.value_mut(self.v)
    }

    #[inline]
    fn out_neighbors(&self) -> &[VertexId] {
        self.g.out_neighbors(self.v)
    }

    #[inline]
    fn in_degree(&self) -> usize {
        self.g.in_degree(self.v)
    }

    #[inline]
    fn out_edge(&self, i: usize) -> (VertexId, EdgeWeight) {
        self.g.out_edge(self.v, i)
    }

    #[inline]
    fn send(&mut self, dst: VertexId, msg: P::Message) {
        assert!(
            self.mode == Mode::Push,
            "send() requires a push-mode program; single-broadcast (pull) \
             versions only support broadcast() — see paper §II"
        );
        self.msg_counter.fetch_add(1, Ordering::Relaxed);
        self.strategy
            .deliver(self.store.next_slot(dst), msg, self.comb);
        self.active_next.set(dst as usize);
    }

    #[inline]
    fn broadcast(&mut self, msg: P::Message) {
        match self.mode {
            Mode::Push => {
                // Broadcast = send along every outgoing edge.
                let nbrs = self.g.out_neighbors(self.v);
                self.msg_counter
                    .fetch_add(nbrs.len() as u64, Ordering::Relaxed);
                for &dst in nbrs {
                    self.strategy
                        .deliver(self.store.next_slot(dst), msg, self.comb);
                    self.active_next.set(dst as usize);
                }
            }
            Mode::Pull => {
                // One lock-free store into our own outbox; recipients pull
                // next superstep. Activation still walks out-edges (the
                // framework must know who has mail).
                self.store.next_slot(self.v).store_first(msg);
                self.bcast_next.set(self.v as usize);
                for &dst in self.g.out_neighbors(self.v) {
                    self.active_next.set(dst as usize);
                }
            }
        }
    }

    #[inline]
    fn vote_to_halt(&mut self) {
        self.halted = true;
    }

    #[inline]
    fn contribute(&mut self, x: AggValue<P>) {
        // Per-thread cell: no synchronisation needed (engine hands each
        // worker its own padded cell); merged at the barrier.
        let (acc, used) = self.agg_cell.get().clone();
        let merged = if used { self.agg.combine(acc, x) } else { x };
        *self.agg_cell.get_mut() = (merged, true);
    }

    #[inline]
    fn aggregated(&self) -> Option<&AggValue<P>> {
        self.agg_prev
    }
}

impl<'g, P, S> Engine<'g, P, S>
where
    P: VertexProgram,
    S: VertexStore<P::Value, P::Message>,
{
    /// Assemble an engine from session-prepared parts. `setup.store` must
    /// already hold initial values; activity and (for CAS-neutral runs)
    /// slot pre-loading happen here.
    pub(crate) fn with_setup(
        g: &'g Csr,
        program: &'g P,
        cfg: EngineConfig,
        halt: Halt<AggValue<P>>,
        setup: EngineSetup<S>,
    ) -> Self {
        let EngineSetup {
            store,
            store_reused,
            mut bitsets,
            scan_weights,
        } = setup;
        let comb = program.combiner();
        let agg = program.aggregator();
        let mode = program.mode();
        let n = g.num_vertices();

        if mode == Mode::Push && cfg.strategy == Strategy::CasNeutral {
            for v in g.vertices() {
                cfg.strategy.reset_slot(store.cur_slot(v), &comb);
                cfg.strategy.reset_slot(store.next_slot(v), &comb);
            }
        }

        let mut next_bitset = || bitsets.pop().unwrap_or_else(|| AtomicBitSet::new(n));
        let active_next = next_bitset();
        let bcast_next = next_bitset();
        let bcast_cur = next_bitset();
        for v in g.vertices() {
            if program.initially_active(g, v) {
                active_next.set(v as usize);
            }
        }

        Engine {
            g,
            program,
            store,
            cfg,
            halt,
            comb,
            agg,
            mode,
            store_reused,
            active_next,
            bcast_next,
            bcast_cur,
            scan_weights,
            agg_prev: None,
        }
    }

    /// Disassemble after a run so the session can pool the parts.
    pub(crate) fn into_parts(self) -> (S, Vec<AtomicBitSet>) {
        (
            self.store,
            vec![self.active_next, self.bcast_next, self.bcast_cur],
        )
    }

    /// Combined incoming message for `v` at superstep start.
    #[inline]
    fn collect_msg(&self, v: VertexId, msgs_done: &AtomicU64) -> Option<P::Message> {
        match self.mode {
            Mode::Push => {
                // Consume and reset the mailbox (owner-exclusive here).
                let slot = self.store.cur_slot(v);
                let m = self.cfg.strategy.collect(slot, &self.comb);
                if self.cfg.strategy == Strategy::CasNeutral && m.is_some() {
                    self.cfg.strategy.reset_slot(slot, &self.comb);
                }
                m
            }
            Mode::Pull => {
                // Combine in-neighbours' outboxes locally — the lock-free
                // pull loop whose memory behaviour §IV optimises. The
                // neighbour list reveals the access pattern iterations in
                // advance, so software-prefetch the slot 8 ahead
                // (§Perf L3 — see EXPERIMENTS.md).
                let in_nbrs = self.g.in_neighbors(v);
                let mut acc: Option<P::Message> = None;
                let mut combined = 0u64;
                for (i, &src) in in_nbrs.iter().enumerate() {
                    #[cfg(all(target_arch = "x86_64", not(feature = "no-prefetch")))]
                    if let Some(&ahead) = in_nbrs.get(i + 8) {
                        // SAFETY: prefetch is only a hint.
                        unsafe {
                            std::arch::x86_64::_mm_prefetch(
                                self.store.cur_slot(ahead) as *const _ as *const i8,
                                std::arch::x86_64::_MM_HINT_T0,
                            );
                        }
                    }
                    if let Some(m) = self.store.cur_slot(src).peek_scan() {
                        combined += 1;
                        acc = Some(match acc {
                            None => m,
                            Some(a) => self.comb.combine(a, m),
                        });
                    }
                }
                if combined > 0 {
                    msgs_done.fetch_add(combined, Ordering::Relaxed);
                }
                acc
            }
        }
    }

    /// Run to quiescence, the superstep cap, or per-run [`Halt`]
    /// convergence. Returns final values and metrics.
    pub fn run(&mut self) -> RunResult<P::Value> {
        let total = Timer::start();
        let n = self.g.num_vertices();
        let threads = self.cfg.threads.max(1);
        let mut metrics = RunMetrics {
            store_reused: self.store_reused,
            ..RunMetrics::default()
        };
        let max_supersteps = self
            .halt
            .max_supersteps
            .map_or(self.cfg.max_supersteps, |h| h.min(self.cfg.max_supersteps));

        // Per-thread padded message counters (hot-path friendly).
        let counters: Vec<CachePadded<AtomicU64>> =
            (0..threads).map(|_| CachePadded::new(AtomicU64::new(0))).collect();
        let pull_comb_counter = AtomicU64::new(0);
        let neutral = self.agg.neutral();
        let agg_cells: Vec<CachePadded<SyncCell<(AggValue<P>, bool)>>> = (0..threads)
            .map(|_| CachePadded::new(SyncCell::new((neutral.clone(), false))))
            .collect();

        let mut superstep = 0usize;
        loop {
            // ---- Snapshot this superstep's active set -------------------
            let active_list: Option<Vec<VertexId>> = if self.cfg.bypass {
                Some(
                    self.active_next
                        .iter()
                        .map(|i| i as VertexId)
                        .collect(),
                )
            } else {
                None
            };
            let active_scan = if self.cfg.bypass {
                None
            } else {
                Some(self.active_next.snapshot())
            };
            let active_count = match (&active_list, &active_scan) {
                (Some(l), _) => l.len(),
                (_, Some(b)) => b.count(),
                _ => unreachable!(),
            };
            if active_count == 0 {
                metrics.halt_reason = HaltReason::Quiescence;
                break;
            }
            if superstep >= max_supersteps {
                metrics.halt_reason = HaltReason::SuperstepCap;
                break;
            }
            self.active_next.clear_all();

            // ---- Compute phase -----------------------------------------
            let t_compute = Timer::start();
            {
                let engine = &self;
                let counters = &counters;
                let pull_comb_counter = &pull_comb_counter;
                let superstep_now = superstep;

                // Edge-centric weights for bypass runs are rebuilt every
                // superstep from the active list (the §V-A overhead the
                // paper attributes to selection-bypass benchmarks).
                let bypass_weights: Option<Vec<u64>> = match (&active_list, self.cfg.schedule) {
                    (Some(list), Schedule::EdgeCentric) => Some(
                        list.iter()
                            .map(|&v| match self.mode {
                                Mode::Push => self.g.out_degree(v) as u64,
                                Mode::Pull => self.g.in_degree(v) as u64,
                            })
                            .collect(),
                    ),
                    _ => None,
                };

                let agg_cells = &agg_cells;
                let agg_prev_now = self.agg_prev.as_ref();
                let run_vertex = |tid: usize, v: VertexId| {
                    let msg = engine.collect_msg(v, pull_comb_counter);
                    let mut ctx: Ctx<'_, P, S> = Ctx {
                        g: engine.g,
                        store: &engine.store,
                        comb: &engine.comb,
                        agg: &engine.agg,
                        strategy: engine.cfg.strategy,
                        mode: engine.mode,
                        active_next: &engine.active_next,
                        bcast_next: &engine.bcast_next,
                        msg_counter: &counters[tid],
                        agg_cell: &agg_cells[tid],
                        agg_prev: agg_prev_now,
                        superstep: superstep_now,
                        v,
                        halted: false,
                    };
                    engine.program.compute(&mut ctx, msg);
                    if !ctx.halted {
                        engine.active_next.set(v as usize);
                    }
                };

                match (&active_list, &active_scan) {
                    (Some(list), _) => {
                        // Selection bypass: iterate the dense active list.
                        parallel_for(
                            threads,
                            list.len(),
                            self.cfg.schedule,
                            bypass_weights.as_deref(),
                            |tid, range| {
                                for i in range {
                                    run_vertex(tid, list[i]);
                                }
                            },
                        );
                    }
                    (_, Some(bits)) => {
                        // Full scan: iterate all ids, skip inactive — the
                        // baseline behaviour bypass eliminates.
                        parallel_for(
                            threads,
                            n,
                            self.cfg.schedule,
                            self.scan_weights.as_ref().map(|w| w.as_slice()),
                            |tid, range| {
                                for i in range {
                                    if bits.get(i) {
                                        run_vertex(tid, i as VertexId);
                                    }
                                }
                            },
                        );
                    }
                    _ => unreachable!(),
                }
            }
            let compute_time = t_compute.elapsed();

            // ---- Barrier phase -----------------------------------------
            let t_barrier = Timer::start();
            if self.mode == Mode::Pull {
                // Clear outboxes consumed this superstep, then rotate the
                // broadcaster sets.
                for v in self.bcast_cur.iter() {
                    self.store.cur_slot(v as VertexId).clear();
                }
                std::mem::swap(&mut self.bcast_cur, &mut self.bcast_next);
                self.bcast_next.clear_all();
            }
            self.store.swap_epochs();
            // Merge this superstep's aggregator partials (workers are
            // joined, so the plain reads are race-free).
            let mut merged: Option<AggValue<P>> = None;
            for cell in &agg_cells {
                let (acc, used) = cell.get().clone();
                if used {
                    merged = Some(match merged {
                        None => acc,
                        Some(m) => self.agg.combine(m, acc),
                    });
                }
                *cell.get_mut() = (neutral.clone(), false);
            }
            // The predicate only ever sees supersteps where the aggregator
            // stream is live: while nothing has contributed yet both values
            // are None, and a predicate like |a, b| a == b would otherwise
            // halt superstep 1 of every run that aggregates late (or not
            // at all).
            let converged = match &self.halt.converged {
                Some(pred) if self.agg_prev.is_some() || merged.is_some() => {
                    pred(self.agg_prev.as_ref(), merged.as_ref())
                }
                _ => false,
            };
            self.agg_prev = merged;
            let barrier_time = t_barrier.elapsed();

            let messages = counters
                .iter()
                .map(|c| c.swap(0, Ordering::Relaxed))
                .sum::<u64>()
                + pull_comb_counter.swap(0, Ordering::Relaxed);

            metrics.supersteps.push(SuperstepStats {
                active_vertices: active_count,
                messages,
                compute_time,
                barrier_time,
            });
            superstep += 1;
            if converged {
                metrics.halt_reason = HaltReason::Converged;
                break;
            }
        }

        metrics.total_time = total.elapsed();
        let values = self
            .g
            .vertices()
            .map(|v| self.store.value(v).clone())
            .collect();
        RunResult { values, metrics }
    }
}
