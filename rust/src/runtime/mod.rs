//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas supersteps.
//!
//! `make artifacts` lowers the Layer-2 model to HLO text once at build
//! time; this module compiles those artifacts on the PJRT CPU client and
//! exposes typed entry points the coordinator calls from its (pure-Rust)
//! hot path. Python is never on the request path.
//!
//! Interchange is HLO **text** — the xla crate's xla_extension 0.5.1
//! rejects jax≥0.5's 64-bit-instruction-id protos, while the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! The XLA dependency is only available inside the accelerator image, so
//! the whole execution path is gated behind the `pjrt` cargo feature.
//! Without it (the default, offline build) [`Runtime::load`] reports the
//! backend unavailable and every caller falls back to the pure-Rust
//! engine; [`Manifest`] parsing stays available everywhere so tooling can
//! still inspect artifact directories.

#[cfg(feature = "pjrt")]
pub mod accel;

#[cfg(not(feature = "pjrt"))]
#[path = "accel_stub.rs"]
pub mod accel;

use crate::util::error::{Context, Result};
use crate::{bail, err};
use std::path::{Path, PathBuf};

/// Parsed `artifacts/manifest.txt`.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// Padded dense block size every artifact was compiled for.
    pub n: usize,
    /// Pallas tile size (recorded for DESIGN.md perf estimates).
    pub tile: usize,
    /// Damping factor baked into the PageRank artifacts.
    pub damping: f64,
    /// Iterations fused into `pagerank_run`.
    pub pr_iterations: usize,
    /// Batch width of the multi-source artifacts.
    pub multi_sources: usize,
    /// Artifact file names.
    pub artifacts: Vec<String>,
}

impl Manifest {
    /// Parse the `key=value` manifest written by `aot.py`.
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut n = None;
        let mut tile = None;
        let mut damping = None;
        let mut pr_iterations = None;
        let mut multi_sources = None;
        let mut artifacts = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| err!("bad manifest line: {line}"))?;
            match k {
                "n" => n = Some(v.parse().context("n")?),
                "tile" => tile = Some(v.parse().context("tile")?),
                "damping" => damping = Some(v.parse().context("damping")?),
                "pr_iterations" => pr_iterations = Some(v.parse().context("pr_iterations")?),
                "multi_sources" => multi_sources = Some(v.parse().context("multi_sources")?),
                "artifact" => artifacts.push(v.to_string()),
                "dtype" => {
                    if v != "f32" {
                        bail!("unsupported artifact dtype {v}");
                    }
                }
                _ => bail!("unknown manifest key {k}"),
            }
        }
        Ok(Manifest {
            n: n.ok_or_else(|| err!("manifest missing n"))?,
            tile: tile.ok_or_else(|| err!("manifest missing tile"))?,
            damping: damping.unwrap_or(0.85),
            pr_iterations: pr_iterations.unwrap_or(10),
            multi_sources: multi_sources.unwrap_or(32),
            artifacts,
        })
    }

    /// Read and parse `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let p = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&p)
            .with_context(|| format!("reading {} (run `make artifacts`)", p.display()))?;
        Self::parse(&text)
    }
}

/// Default artifacts directory: `$IPREGEL_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("IPREGEL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod pjrt_backend {
    use super::Manifest;
    use crate::err;
    use crate::util::error::Result;
    use std::collections::HashMap;
    use std::path::Path;

    /// A device-resident buffer plus the host literal backing its (possibly
    /// still in-flight) transfer.
    pub struct DeviceBuf {
        /// The PJRT buffer to execute with.
        pub buf: xla::PjRtBuffer,
        _keepalive: xla::Literal,
    }

    /// A compiled artifact set on a live PJRT CPU client.
    pub struct Runtime {
        /// The manifest the artifacts were built under.
        pub manifest: Manifest,
        client: xla::PjRtClient,
        exes: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Runtime {
        /// Compile every artifact in `dir` on a fresh PJRT CPU client.
        pub fn load(dir: &Path) -> Result<Runtime> {
            let manifest = Manifest::load(dir)?;
            let client = xla::PjRtClient::cpu().map_err(|e| err!("PJRT client: {e:?}"))?;
            let mut exes = HashMap::new();
            for name in &manifest.artifacts {
                let path = dir.join(name);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| err!("non-utf8 path"))?,
                )
                .map_err(|e| err!("parsing {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| err!("compiling {}: {e:?}", path.display()))?;
                let key = name.trim_end_matches(".hlo.txt").to_string();
                exes.insert(key, exe);
            }
            Ok(Runtime {
                manifest,
                client,
                exes,
            })
        }

        /// PJRT platform name (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Names of loaded executables.
        pub fn executables(&self) -> Vec<&str> {
            let mut v: Vec<&str> = self.exes.keys().map(|s| s.as_str()).collect();
            v.sort_unstable();
            v
        }

        fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
            self.exes.get(name).ok_or_else(|| {
                err!("artifact '{name}' not loaded (have {:?})", self.executables())
            })
        }

        /// Execute `name` with the given literals; unwraps the 1-tuple result
        /// (artifacts are lowered with `return_tuple=True`) into a f32 vector.
        pub fn call_vec(&self, name: &str, args: &[&xla::Literal]) -> Result<Vec<f32>> {
            let exe = self.exe(name)?;
            let result = exe
                .execute::<&xla::Literal>(args)
                .map_err(|e| err!("executing {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| err!("fetching {name} result: {e:?}"))?;
            let out = result
                .to_tuple1()
                .map_err(|e| err!("untupling {name} result: {e:?}"))?;
            out.to_vec::<f32>()
                .map_err(|e| err!("reading {name} result: {e:?}"))
        }

        /// Upload a literal to the device once; reuse the returned buffer
        /// across many executions (§Perf: the n×n adjacency dominates the
        /// per-call transfer cost of iterated supersteps).
        pub fn to_device(&self, lit: xla::Literal) -> Result<DeviceBuf> {
            // Pass the first addressable device explicitly — the crate's
            // `None` path hands a null device pointer to the C++ side, which
            // the CPU plugin dereferences. The literal is kept alive inside
            // the returned [`DeviceBuf`]: the CPU client's host->device
            // transfer is asynchronous and may still read the host memory
            // after this call returns.
            let devices = self.client.addressable_devices();
            let dev = devices.first();
            let buf = self
                .client
                .buffer_from_host_literal(dev, &lit)
                .map_err(|e| err!("host->device transfer: {e:?}"))?;
            Ok(DeviceBuf {
                buf,
                _keepalive: lit,
            })
        }

        /// Execute `name` with device-resident buffers (see [`Self::to_device`]).
        pub fn call_vec_b(&self, name: &str, args: &[&xla::PjRtBuffer]) -> Result<Vec<f32>> {
            let exe = self.exe(name)?;
            let result = exe
                .execute_b::<&xla::PjRtBuffer>(args)
                .map_err(|e| err!("executing {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| err!("fetching {name} result: {e:?}"))?;
            let out = result
                .to_tuple1()
                .map_err(|e| err!("untupling {name} result: {e:?}"))?;
            out.to_vec::<f32>()
                .map_err(|e| err!("reading {name} result: {e:?}"))
        }

        /// Build a square `n×n` f32 literal from a flat row-major vector.
        pub fn square_literal(&self, flat: &[f32]) -> Result<xla::Literal> {
            let n = self.manifest.n;
            crate::ensure!(flat.len() == n * n, "expected {}², got {}", n, flat.len());
            xla::Literal::vec1(flat)
                .reshape(&[n as i64, n as i64])
                .map_err(|e| err!("reshape: {e:?}"))
        }

        /// Build an `n`-vector f32 literal.
        pub fn vec_literal(&self, v: &[f32]) -> Result<xla::Literal> {
            crate::ensure!(
                v.len() == self.manifest.n,
                "expected {}, got {}",
                self.manifest.n,
                v.len()
            );
            Ok(xla::Literal::vec1(v))
        }

        /// Build an f32 scalar literal.
        pub fn scalar_literal(&self, v: f32) -> xla::Literal {
            xla::Literal::scalar(v)
        }

        /// Build an `n×B` f32 literal from a flat row-major vector (the
        /// multi-source distance matrix).
        pub fn batch_literal(&self, flat: &[f32]) -> Result<xla::Literal> {
            let n = self.manifest.n;
            let b = self.manifest.multi_sources;
            crate::ensure!(flat.len() == n * b, "expected {n}×{b}, got {}", flat.len());
            xla::Literal::vec1(flat)
                .reshape(&[n as i64, b as i64])
                .map_err(|e| err!("reshape: {e:?}"))
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_backend::{DeviceBuf, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub_backend {
    use super::Manifest;
    use crate::bail;
    use crate::util::error::Result;
    use std::path::Path;

    /// Uninhabited: proves a stub [`Runtime`] can never be constructed, so
    /// its methods are statically unreachable.
    enum Never {}

    /// Placeholder for the device buffer type when the backend is absent.
    pub struct DeviceBuf {
        _never: Never,
    }

    /// Stub runtime compiled when the `pjrt` feature is off. Parses
    /// nothing, executes nothing: [`Runtime::load`] always errors, which
    /// callers already treat as "accel path unavailable, skip".
    pub struct Runtime {
        /// The manifest the artifacts were built under.
        pub manifest: Manifest,
        _never: Never,
    }

    impl Runtime {
        /// Always fails: the crate was built without the `pjrt` feature.
        pub fn load(dir: &Path) -> Result<Runtime> {
            bail!(
                "PJRT backend unavailable: ipregel was built without the \
                 `pjrt` cargo feature (artifacts dir: {})",
                dir.display()
            );
        }

        pub(crate) fn absent(&self) -> ! {
            match self._never {}
        }

        /// PJRT platform name (diagnostics).
        pub fn platform(&self) -> String {
            self.absent()
        }

        /// Names of loaded executables.
        pub fn executables(&self) -> Vec<&str> {
            self.absent()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub_backend::{DeviceBuf, Runtime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_roundtrip() {
        let text = "n=1024\ntile=256\ndtype=f32\ndamping=0.85\npr_iterations=10\n\
                    artifact=pagerank_step.hlo.txt\nartifact=cc_label.hlo.txt\n";
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.n, 1024);
        assert_eq!(m.tile, 256);
        assert_eq!(m.pr_iterations, 10);
        assert_eq!(m.artifacts.len(), 2);
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(Manifest::parse("nonsense").is_err());
        assert!(Manifest::parse("tile=256\n").is_err(), "missing n");
        assert!(Manifest::parse("n=4\ntile=2\ndtype=f64\n").is_err(), "bad dtype");
        assert!(Manifest::parse("n=4\ntile=2\nwat=1\n").is_err(), "unknown key");
    }

    #[test]
    fn default_dir_env_override() {
        // NOTE: do not mutate the env (tests run multithreaded); just
        // check the default path shape.
        let d = default_artifact_dir();
        assert!(d.ends_with("artifacts") || d.is_absolute());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        let e = Runtime::load(Path::new("/nonexistent")).unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
    }
}
