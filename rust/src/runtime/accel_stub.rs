//! Stub of the accelerated dense-block backend, compiled when the `pjrt`
//! cargo feature is off (the default, offline build).
//!
//! [`super::Runtime::load`] always errors in this configuration, so a
//! stub [`Runtime`](super::Runtime) value can never exist and none of
//! these functions is reachable; they exist so callers (CLI, examples,
//! tests) compile unchanged and skip the accel path at runtime.

use crate::graph::csr::{Csr, VertexId};
use crate::runtime::Runtime;
use crate::util::error::Result;

/// A graph embedded in the runtime's padded dense block (stub).
pub struct DenseBlock {
    /// Real (unpadded) vertex count.
    pub n_real: usize,
}

impl DenseBlock {
    /// Embed `g` into the runtime's block (unreachable without `pjrt`).
    pub fn from_graph(rt: &Runtime, _g: &Csr) -> Result<DenseBlock> {
        rt.absent()
    }
}

/// PageRank via the fused `pagerank_run` artifact (unreachable stub).
pub fn pagerank(rt: &Runtime, _g: &Csr, _block: &DenseBlock) -> Result<Vec<f32>> {
    rt.absent()
}

/// Unweighted SSSP fixpoint iteration (unreachable stub).
pub fn sssp(rt: &Runtime, _g: &Csr, _block: &DenseBlock, _source: VertexId) -> Result<Vec<f32>> {
    rt.absent()
}

/// Connected components fixpoint iteration (unreachable stub).
pub fn connected_components(rt: &Runtime, _g: &Csr, _block: &DenseBlock) -> Result<Vec<u32>> {
    rt.absent()
}

/// One raw PageRank step (unreachable stub).
pub fn pagerank_step(rt: &Runtime, _block: &DenseBlock, _contrib: &[f32]) -> Result<Vec<f32>> {
    rt.absent()
}

/// Batched multi-source SSSP (unreachable stub).
pub fn multi_sssp(rt: &Runtime, _block: &DenseBlock, _sources: &[VertexId]) -> Result<Vec<Vec<f32>>> {
    rt.absent()
}
