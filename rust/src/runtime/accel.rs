//! The accelerated dense-block backend: drive supersteps through the
//! AOT-compiled XLA computations.
//!
//! Small graphs (≤ the artifact block size, default 1024) are embedded in
//! a padded dense in-neighbour matrix and the paper's three benchmarks run
//! as PJRT executions. This demonstrates the full three-layer
//! composition: Rust coordinator → XLA executable → Pallas kernel.
//! Results are bit-compatible with the pure-Rust engine up to f32
//! rounding and validated against it in `rust/tests/test_accel.rs`.

use crate::bail;
use crate::graph::csr::{Csr, VertexId};
use crate::runtime::Runtime;
use crate::util::error::Result;

/// A graph embedded in the runtime's padded dense block.
pub struct DenseBlock {
    /// Real (unpadded) vertex count.
    pub n_real: usize,
    /// The padded in-neighbour matrix, uploaded to the device once and
    /// reused across every superstep execution (§Perf: avoids re-staging
    /// the n² matrix on each of the O(diameter) iterated calls).
    adj: crate::runtime::DeviceBuf,
}

impl DenseBlock {
    /// Embed `g` into the runtime's block. Fails if the graph exceeds the
    /// compiled block size — the accel path is a small-graph backend; use
    /// the pure-Rust engine beyond it.
    pub fn from_graph(rt: &Runtime, g: &Csr) -> Result<DenseBlock> {
        let n = rt.manifest.n;
        let n_real = g.num_vertices();
        if n_real > n {
            bail!(
                "graph has {n_real} vertices but artifacts were compiled \
                 for n={n}; regenerate with `make artifacts` at a larger --n"
            );
        }
        // adj[i][j] = 1 iff edge j -> i (row i gathers i's in-neighbours).
        let mut flat = vec![0f32; n * n];
        for (src, dst) in g.edges() {
            flat[dst as usize * n + src as usize] = 1.0;
        }
        Ok(DenseBlock {
            n_real,
            adj: rt.to_device(rt.square_literal(&flat)?)?,
        })
    }

    /// Pad an `n_real` vector to the block size with `fill`.
    fn pad(&self, rt: &Runtime, v: &[f32], fill: f32) -> Vec<f32> {
        let mut out = vec![fill; rt.manifest.n];
        out[..v.len()].copy_from_slice(v);
        out
    }
}

/// PageRank via the fused `pagerank_run` artifact (10 damped iterations,
/// dangling mass dropped — identical semantics to [`crate::algos::PageRank`]).
pub fn pagerank(rt: &Runtime, g: &Csr, block: &DenseBlock) -> Result<Vec<f32>> {
    let n_real = block.n_real;
    let rank0: Vec<f32> = vec![1.0 / n_real as f32; n_real];
    let inv_outdeg: Vec<f32> = g
        .vertices()
        .map(|v| {
            let d = g.out_degree(v);
            if d > 0 {
                1.0 / d as f32
            } else {
                0.0
            }
        })
        .collect();
    let rank_b = rt.to_device(rt.vec_literal(&block.pad(rt, &rank0, 0.0))?)?;
    let inv_b = rt.to_device(rt.vec_literal(&block.pad(rt, &inv_outdeg, 0.0))?)?;
    let n_b = rt.to_device(rt.scalar_literal(n_real as f32))?;
    let out = rt.call_vec_b(
        "pagerank_run",
        &[&block.adj.buf, &rank_b.buf, &inv_b.buf, &n_b.buf],
    )?;
    Ok(out[..n_real].to_vec())
}

/// Unweighted SSSP: iterate the `sssp_relax` artifact until fixpoint.
/// Returns distances with `f32::INFINITY` for unreached vertices.
pub fn sssp(rt: &Runtime, g: &Csr, block: &DenseBlock, source: VertexId) -> Result<Vec<f32>> {
    let n_real = block.n_real;
    crate::ensure!((source as usize) < n_real, "source out of range");
    let mut dist = vec![f32::INFINITY; n_real];
    dist[source as usize] = 0.0;
    let mut cur = block.pad(rt, &dist, f32::INFINITY);
    // Unit weights: the fixpoint arrives within n_real waves.
    for _ in 0..n_real.max(1) {
        let cur_b = rt.to_device(rt.vec_literal(&cur)?)?;
        let next = rt.call_vec_b("sssp_relax", &[&block.adj.buf, &cur_b.buf])?;
        if next == cur {
            break;
        }
        cur = next;
    }
    let _ = g;
    Ok(cur[..n_real].to_vec())
}

/// Connected components: iterate `cc_label` to fixpoint. Returns the
/// min-vertex-id component labels (as f32 ids, exact for n < 2^24).
pub fn connected_components(rt: &Runtime, g: &Csr, block: &DenseBlock) -> Result<Vec<u32>> {
    let n_real = block.n_real;
    crate::ensure!(
        n_real < (1 << 24),
        "labels-as-f32 require n < 2^24 for exactness"
    );
    let labels: Vec<f32> = (0..n_real).map(|v| v as f32).collect();
    let mut cur = block.pad(rt, &labels, f32::INFINITY);
    for _ in 0..n_real.max(1) {
        let cur_b = rt.to_device(rt.vec_literal(&cur)?)?;
        let next = rt.call_vec_b("cc_label", &[&block.adj.buf, &cur_b.buf])?;
        if next == cur {
            break;
        }
        cur = next;
    }
    let _ = g;
    Ok(cur[..n_real].iter().map(|&l| l as u32).collect())
}

/// One raw PageRank step via the `pagerank_step` artifact (used by tests
/// and the quickstart example to show single-superstep offload).
pub fn pagerank_step(rt: &Runtime, block: &DenseBlock, contrib: &[f32]) -> Result<Vec<f32>> {
    let contrib_b = rt.to_device(rt.vec_literal(&block.pad(rt, contrib, 0.0))?)?;
    let n_b = rt.to_device(rt.scalar_literal(block.n_real as f32))?;
    let out = rt.call_vec_b(
        "pagerank_step",
        &[&block.adj.buf, &contrib_b.buf, &n_b.buf],
    )?;
    Ok(out[..block.n_real].to_vec())
}

/// Multi-source unweighted SSSP via the batched `multi_sssp_relax`
/// artifact: up to `manifest.multi_sources` sources solved in one
/// iterated fixpoint — the MXU-utilisation variant (EXPERIMENTS.md §Perf
/// L1). Returns one distance vector per source.
pub fn multi_sssp(
    rt: &Runtime,
    block: &DenseBlock,
    sources: &[VertexId],
) -> Result<Vec<Vec<f32>>> {
    let n = rt.manifest.n;
    let b = rt.manifest.multi_sources;
    let n_real = block.n_real;
    crate::ensure!(
        !sources.is_empty() && sources.len() <= b,
        "need 1..={b} sources, got {}",
        sources.len()
    );
    crate::ensure!(
        sources.iter().all(|&s| (s as usize) < n_real),
        "source out of range"
    );
    // Row-major (n, B): column k is source k's distance vector; unused
    // columns stay all-infinity and converge immediately.
    let mut cur = vec![f32::INFINITY; n * b];
    for (k, &src) in sources.iter().enumerate() {
        cur[src as usize * b + k] = 0.0;
    }
    for _ in 0..n_real.max(1) {
        let cur_b = rt.to_device(rt.batch_literal(&cur)?)?;
        let next = rt.call_vec_b("multi_sssp_relax", &[&block.adj.buf, &cur_b.buf])?;
        if next == cur {
            break;
        }
        cur = next;
    }
    Ok((0..sources.len())
        .map(|k| (0..n_real).map(|v| cur[v * b + k]).collect())
        .collect())
}
