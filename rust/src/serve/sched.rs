//! The interleaving policy: how a batch run shares the machine with a
//! stream of interactive queries.
//!
//! Two mechanisms, both calibrated from the simulator's
//! [`CostModel`] rather than guessed:
//!
//! 1. **Slicing** — a batch run executes at most
//!    [`InterleavePolicy::slice_supersteps`] supersteps per admission
//!    permit, then re-enters the gate (where interactive waiters
//!    overtake it — `serve/admission.rs`). The quantum is priced so a
//!    queued interactive query waits a bounded multiple of its *own*
//!    cost, not an unbounded fraction of the batch run's.
//!    **Opt-in per query**: several benchmark programs branch on
//!    `superstep() == 0` (PageRank's init wave, SSSP's seed), so a
//!    warm-started continuation is not bit-identical for them — the
//!    default policy therefore interleaves by admission priority and
//!    thread partitioning only, and slicing is reserved for programs
//!    whose compute is superstep-oblivious.
//! 2. **Thread partitioning** — reserve
//!    [`InterleavePolicy::reserved_interactive_threads`] of the team for
//!    interactive queries and hand the batch run the rest, sized at the
//!    cost model's diminishing-returns point: small queries are
//!    superstep-sync-bound, so a few threads serve them at near-full
//!    speed while the batch run keeps the bulk.
//!
//! This file is on the `ipregel audit` panic-deny list: policy
//! arithmetic runs inside the serving loop and must never unwind.

use crate::sim::CostModel;

/// Shape of one batch-run superstep, for pricing: how many vertices
/// compute and how many messages fly.
#[derive(Clone, Copy, Debug)]
pub struct SuperstepShape {
    /// Active vertices per superstep.
    pub active: u64,
    /// Messages delivered per superstep.
    pub messages: u64,
}

/// Shape of a bounded interactive query, for pricing.
#[derive(Clone, Copy, Debug)]
pub struct QueryShape {
    /// Supersteps (an ego-net's radius + 1, a point SSSP's wave count).
    pub waves: usize,
    /// Active vertices per wave.
    pub active_per_wave: u64,
    /// Messages per wave.
    pub messages_per_wave: u64,
}

/// The calibrated interleaving policy (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct InterleavePolicy {
    /// Batch-run supersteps per admission permit (slicing quantum);
    /// `usize::MAX` disables slicing.
    pub slice_supersteps: usize,
    /// Threads reserved for interactive queries while a batch run holds
    /// the rest.
    pub reserved_interactive_threads: usize,
    /// Threads the batch run keeps (`team - reserved`, floored at 1).
    pub batch_threads: usize,
}

impl InterleavePolicy {
    /// Fixed policy, no cost model consulted.
    pub fn fixed(slice_supersteps: usize, reserved: usize, team: usize) -> InterleavePolicy {
        let team = team.max(1);
        let reserved = reserved.min(team.saturating_sub(1));
        InterleavePolicy {
            slice_supersteps: slice_supersteps.max(1),
            reserved_interactive_threads: reserved,
            batch_threads: (team - reserved).max(1),
        }
    }

    /// Calibrate from the simulator's cost model:
    ///
    /// - the **slice** is the largest number of batch supersteps whose
    ///   virtual cost stays under `slack ×` the small query's own cost —
    ///   a query that arrives mid-slice waits, in expectation, half
    ///   that, so its queueing delay is a bounded multiple of its
    ///   service time (clamped to `1..=64`);
    /// - the **reservation** is the smallest thread count that serves
    ///   the small query within 2× its full-team cost (small queries
    ///   are sync-bound, so this is typically 1-2 threads), capped at
    ///   half the team so the batch run always keeps a majority.
    pub fn from_cost_model(
        m: &CostModel,
        team: usize,
        large: SuperstepShape,
        small: QueryShape,
        slack: f64,
    ) -> InterleavePolicy {
        let team = team.max(1);
        let big_step = m.plain_superstep(large.active, large.messages, team);
        let small_cost = m.query_cost(
            small.waves,
            small.active_per_wave,
            small.messages_per_wave,
            team,
        );
        let slack = if slack.is_finite() && slack > 0.0 { slack } else { 1.0 };
        let raw = (slack * small_cost / big_step).floor();
        let slice = if raw.is_finite() && raw >= 1.0 {
            (raw as usize).min(64)
        } else {
            1
        };

        let mut reserved = 0usize;
        if team > 1 {
            let budget = 2.0 * small_cost;
            for r in 1..=(team / 2).max(1) {
                reserved = r;
                let at_r = m.query_cost(
                    small.waves,
                    small.active_per_wave,
                    small.messages_per_wave,
                    r,
                );
                if at_r <= budget {
                    break;
                }
            }
            reserved = reserved.min(team - 1);
        }
        InterleavePolicy {
            slice_supersteps: slice,
            reserved_interactive_threads: reserved,
            batch_threads: (team - reserved).max(1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const LARGE: SuperstepShape = SuperstepShape {
        active: 1_000_000,
        messages: 8_000_000,
    };
    const SMALL: QueryShape = QueryShape {
        waves: 4,
        active_per_wave: 1_000,
        messages_per_wave: 2_000,
    };

    #[test]
    fn fixed_policy_clamps_sanely() {
        let p = InterleavePolicy::fixed(0, 99, 8);
        assert_eq!(p.slice_supersteps, 1);
        assert_eq!(p.reserved_interactive_threads, 7);
        assert_eq!(p.batch_threads, 1);
        let solo = InterleavePolicy::fixed(4, 2, 1);
        assert_eq!(solo.reserved_interactive_threads, 0);
        assert_eq!(solo.batch_threads, 1);
    }

    #[test]
    fn calibration_bounds_the_slice_by_query_cost() {
        let m = CostModel::default();
        let p = InterleavePolicy::from_cost_model(&m, 32, LARGE, SMALL, 2.0);
        assert!(p.slice_supersteps >= 1);
        // The defining inequality: slice × big_step ≤ slack × small_cost
        // (unless clamped up to the minimum slice of 1).
        let big = m.plain_superstep(LARGE.active, LARGE.messages, 32);
        let small = m.query_cost(SMALL.waves, SMALL.active_per_wave, SMALL.messages_per_wave, 32);
        if p.slice_supersteps > 1 {
            assert!(p.slice_supersteps as f64 * big <= 2.0 * small + big);
        }
        // A heavier big step can only shrink the slice.
        let heavier = SuperstepShape {
            active: LARGE.active * 10,
            messages: LARGE.messages * 10,
        };
        let p2 = InterleavePolicy::from_cost_model(&m, 32, heavier, SMALL, 2.0);
        assert!(p2.slice_supersteps <= p.slice_supersteps);
    }

    #[test]
    fn reservation_is_small_because_queries_are_sync_bound() {
        let m = CostModel::default();
        let p = InterleavePolicy::from_cost_model(&m, 32, LARGE, SMALL, 2.0);
        assert!(p.reserved_interactive_threads >= 1);
        assert!(
            p.reserved_interactive_threads <= 16,
            "batch keeps the majority: {p:?}"
        );
        assert_eq!(p.batch_threads, 32 - p.reserved_interactive_threads);
        // One-thread teams reserve nothing.
        let solo = InterleavePolicy::from_cost_model(&m, 1, LARGE, SMALL, 2.0);
        assert_eq!(solo.reserved_interactive_threads, 0);
        assert_eq!(solo.batch_threads, 1);
    }
}
