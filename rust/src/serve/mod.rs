//! The multi-tenant serving layer: many concurrent, context-tagged
//! queries over one shared — optionally evolving — graph.
//!
//! Everything below this module is built for *one run at a time*; a
//! serving workload is the opposite shape: a stream of small
//! bounded-scope queries (ego-net BFS, point SSSP, top-k rank deltas —
//! [`crate::algos::query`]) arriving while occasional whole-graph batch
//! runs grind through their supersteps, all against the same graph, all
//! wanting predictable tail latency. This module adds that front-end
//! without touching any algorithm (the paper's programmability thesis
//! extends to serving: the same `compute` text runs solo or served,
//! bit-for-bit):
//!
//! - [`QueryServer`] — admits queries against a shared
//!   [`crate::engine::GraphSession`]; `run_with(&self, ..)` is already
//!   re-entrant, so N queries execute concurrently over one pooled
//!   session (the keyed multi-checkout pools of `engine/session.rs`
//!   hand each its own warm store);
//! - [`AdmissionController`] — bounds in-flight runs and lets
//!   [`Priority::Interactive`] queries overtake queued
//!   [`Priority::Batch`] work;
//! - [`QueryBudget`] — per-query superstep and work-token caps, lowered
//!   into the engine's [`crate::engine::Halt`] so exhaustion surfaces as
//!   [`crate::metrics::HaltReason::BudgetExhausted`] without poisoning
//!   any pool;
//! - **snapshot isolation** — the server owns a master
//!   [`crate::graph::dynamic::DynamicGraph`] plus an immutable published
//!   [`Snapshot`]; [`QueryServer::apply_mutations`] builds the next
//!   snapshot copy-on-mutate and swaps a pointer, so readers pinned to
//!   the old epoch ([`crate::engine::epoch::EpochPins`]) never block the
//!   writer and never observe a half-applied batch;
//! - [`InterleavePolicy`] — slices batch runs into bounded superstep
//!   quanta between which interactive queries drain, with the quantum
//!   priced from the simulator's calibrated [`crate::sim::CostModel`];
//! - per-query [`crate::metrics::QueryMetrics`] and
//!   [`crate::metrics::LatencyStats`] (p50/p99) — the numbers the
//!   `ipregel serve` CLI mode and `bench_serve` report.

pub mod admission;
pub mod handle;
pub mod sched;
pub mod server;

pub use admission::{AdmissionController, AdmitError, AdmitPermit};
pub use handle::{Priority, QueryBudget, QueryResponse, QuerySpec};
pub use sched::{InterleavePolicy, QueryShape, SuperstepShape};
pub use server::{PinnedSnapshot, QueryServer, Snapshot};
