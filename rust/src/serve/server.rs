//! The [`QueryServer`]: concurrent context-tagged runs over one shared
//! graph, with snapshot isolation against a single writer.
//!
//! **Read path.** The server publishes an immutable [`Snapshot`] — a
//! mutation epoch plus a [`GraphSession`] owning a compacted copy of the
//! graph at that epoch. `GraphSession::run_with` takes `&self`, so any
//! number of admitted queries run concurrently over one snapshot, each
//! popping its own warm store from the session's keyed multi-checkout
//! pools. A query *pins* its snapshot's epoch
//! ([`crate::engine::EpochPins`]) for its duration; the `Arc` it holds
//! keeps the snapshot alive even if the server republishes mid-run.
//!
//! **Write path (copy-on-mutate).** [`QueryServer::apply_mutations`]
//! applies the batch to the server's private master
//! [`DynamicGraph`] — never read by queries — then builds a fresh
//! session over the rebuilt CSR and swaps the published `Arc` pointer.
//! Writers never wait for pinned readers; pinned readers keep seeing
//! exactly the epoch they pinned. The cost is a graph copy per batch
//! (acceptable at serving mutation rates) in exchange for zero reader
//! stalls and trivially-auditable isolation.
//!
//! Solo-path guarantee: a served query is the same `run_with` call a
//! solo caller would make — same config, same halt, same store pooling —
//! so values *and* per-superstep traces are bit-identical to a solo run
//! over the same graph (`rust/tests/test_serve.rs` pins this down).

use crate::engine::epoch::{EpochPin, EpochPins};
use crate::engine::{EngineConfig, GraphSession, PoolStats, RunOptions, VertexProgram};
use crate::graph::csr::Csr;
use crate::graph::dynamic::{DynamicGraph, MutationReceipt, MutationSet};
use crate::metrics::{LatencyStats, QueryMetrics};
use crate::serve::admission::{AdmissionController, AdmitError, AdmitPermit};
use crate::serve::handle::{Priority, QueryResponse, QuerySpec};
use crate::util::timer::Timer;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Default concurrent-run bound for [`QueryServer::new`].
const DEFAULT_MAX_CONCURRENT: usize = 8;

/// One published graph state: a mutation epoch and a session over an
/// immutable copy of the graph as of that epoch. Shared by `Arc`; a
/// snapshot is never mutated after publication.
pub struct Snapshot {
    epoch: u64,
    session: GraphSession<'static>,
}

impl Snapshot {
    /// The mutation epoch this snapshot reflects.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The shared session queries run against.
    pub fn session(&self) -> &GraphSession<'static> {
        &self.session
    }
}

/// A snapshot held open by an explicit reader pin: the snapshot stays
/// retrievable (and its epoch observable via
/// [`QueryServer::pinned_readers`]) until this guard drops, regardless
/// of how many batches the writer publishes meanwhile.
pub struct PinnedSnapshot {
    snapshot: Arc<Snapshot>,
    pin: EpochPin,
}

impl PinnedSnapshot {
    /// The pinned epoch.
    pub fn epoch(&self) -> u64 {
        self.pin.epoch()
    }

    /// The pinned snapshot's session.
    pub fn session(&self) -> &GraphSession<'static> {
        self.snapshot.session()
    }
}

/// The serving front-end (see module docs).
pub struct QueryServer {
    /// The writer's private graph — queries never read it.
    master: Mutex<DynamicGraph>,
    /// The published snapshot; readers clone the `Arc` and drop the lock.
    snapshot: Mutex<Arc<Snapshot>>,
    /// Refcounts of reader-pinned epochs.
    pins: Arc<EpochPins>,
    /// The admission gate.
    admission: AdmissionController,
    /// Session default config, reused for every republished snapshot.
    cfg: EngineConfig,
    /// Query-id allocator. Relaxed: ids only need uniqueness, and the
    /// admission mutex orders everything else a query observes.
    next_id: AtomicU64,
    /// Queries fully served. Relaxed: a statistic, read after joins.
    completed: AtomicU64,
    /// Every served query's [`QueryMetrics`], in completion order.
    log: Mutex<Vec<QueryMetrics>>,
}

impl QueryServer {
    /// Server over `g` with default engine config and admission bound.
    pub fn new(g: Csr) -> QueryServer {
        Self::with_config(
            g,
            EngineConfig::default(),
            AdmissionController::new(DEFAULT_MAX_CONCURRENT),
        )
    }

    /// Server over `g` with an explicit session config and admission
    /// gate. The config becomes the default for every query (a
    /// [`QuerySpec::config`] overrides it per query) and is inherited by
    /// every snapshot republished after a mutation batch.
    pub fn with_config(g: Csr, cfg: EngineConfig, admission: AdmissionController) -> QueryServer {
        let master = DynamicGraph::new(g);
        let snapshot = Arc::new(Snapshot {
            epoch: master.epoch(),
            session: GraphSession::dynamic_with_config(
                DynamicGraph::new(master.graph().rebuilt()),
                cfg,
            ),
        });
        QueryServer {
            master: Mutex::new(master),
            snapshot: Mutex::new(snapshot),
            pins: EpochPins::new(),
            admission,
            cfg,
            next_id: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            log: Mutex::new(Vec::new()),
        }
    }

    /// The currently published snapshot.
    pub fn snapshot(&self) -> Arc<Snapshot> {
        Arc::clone(&self.snapshot.lock().expect("snapshot poisoned"))
    }

    /// The currently published mutation epoch.
    pub fn epoch(&self) -> u64 {
        self.snapshot().epoch
    }

    /// Pin the current snapshot: the returned guard keeps it (and its
    /// epoch's pin count) alive across any number of mutation batches.
    pub fn pin_current(&self) -> PinnedSnapshot {
        let snapshot = self.snapshot();
        let pin = self.pins.pin(snapshot.epoch);
        PinnedSnapshot { snapshot, pin }
    }

    /// Serve one query against the current snapshot: admit (interactive
    /// overtakes queued batch), pin the snapshot's epoch, run, release.
    ///
    /// # Errors
    /// [`AdmitError::QueueFull`] when the gate's wait queue is capped
    /// and full.
    pub fn execute<P: VertexProgram>(
        &self,
        program: &P,
        spec: &QuerySpec,
    ) -> Result<QueryResponse<P::Value>, AdmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let t_queue = Timer::start();
        let permit = self.admission.admit(spec.class())?;
        let queue_wait = t_queue.elapsed();
        // Pin *after* admission: a query stuck at the gate must not hold
        // an old epoch open.
        let snapshot = self.snapshot();
        let pin = self.pins.pin(snapshot.epoch);
        self.run_admitted(program, spec, id, queue_wait, &snapshot, pin, permit)
    }

    /// Serve one query against an explicitly pinned snapshot — the
    /// time-travel read path: `pinned` may be epochs behind the
    /// published state.
    ///
    /// # Errors
    /// [`AdmitError::QueueFull`] as for [`QueryServer::execute`].
    pub fn execute_on<P: VertexProgram>(
        &self,
        pinned: &PinnedSnapshot,
        program: &P,
        spec: &QuerySpec,
    ) -> Result<QueryResponse<P::Value>, AdmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let t_queue = Timer::start();
        let permit = self.admission.admit(spec.class())?;
        let queue_wait = t_queue.elapsed();
        let pin = self.pins.pin(pinned.snapshot.epoch);
        self.run_admitted(program, spec, id, queue_wait, &pinned.snapshot, pin, permit)
    }

    /// The admitted tail shared by both execute paths: run with the
    /// spec's config/budget/tag, record [`QueryMetrics`], release the
    /// permit (dropping it wakes the gate) and the epoch pin.
    #[allow(clippy::too_many_arguments)]
    fn run_admitted<P: VertexProgram>(
        &self,
        program: &P,
        spec: &QuerySpec,
        id: u64,
        queue_wait: std::time::Duration,
        snapshot: &Arc<Snapshot>,
        pin: EpochPin,
        permit: AdmitPermit<'_>,
    ) -> Result<QueryResponse<P::Value>, AdmitError> {
        let tag = spec.tag.unwrap_or(id);
        let mut opts = RunOptions::new().halt(spec.budget.to_halt()).tag(tag);
        if let Some(cfg) = spec.config {
            opts = opts.config(cfg);
        }
        let t_run = Timer::start();
        let result = snapshot.session.run_with(program, opts);
        let run_time = t_run.elapsed();
        drop(permit);
        drop(pin);
        let query = QueryMetrics {
            id,
            tag,
            class: spec.class().name(),
            queue_wait,
            run_time,
            latency: queue_wait + run_time,
            supersteps: result.metrics.num_supersteps(),
            halt_reason: result.metrics.halt_reason,
            epoch: snapshot.epoch,
            store_reused: result.metrics.store_reused,
        };
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.log
            .lock()
            .expect("query log poisoned")
            .push(query.clone());
        Ok(QueryResponse {
            values: result.values,
            metrics: result.metrics,
            query,
        })
    }

    /// Apply one mutation batch and publish the next snapshot
    /// (copy-on-mutate). Takes `&self`: the master mutex serialises
    /// writers against each other only — in-flight readers keep their
    /// pinned snapshots and are never waited on.
    pub fn apply_mutations(&self, m: &MutationSet) -> MutationReceipt {
        let mut master = self.master.lock().expect("master graph poisoned");
        let receipt = master.apply(m);
        let next = Arc::new(Snapshot {
            epoch: master.epoch(),
            session: GraphSession::dynamic_with_config(
                DynamicGraph::new(master.graph().rebuilt()),
                self.cfg,
            ),
        });
        // Swap the pointer while still holding the master lock so
        // published epochs are monotone even across racing writers.
        *self.snapshot.lock().expect("snapshot poisoned") = next;
        receipt
    }

    /// Live reader pins on `epoch`.
    pub fn pinned_readers(&self, epoch: u64) -> usize {
        self.pins.pinned_readers(epoch)
    }

    /// The oldest epoch still pinned by a reader, if any.
    pub fn oldest_pinned(&self) -> Option<u64> {
        self.pins.oldest_pinned()
    }

    /// The admission gate (for observability: running/waiting counts).
    pub fn admission(&self) -> &AdmissionController {
        &self.admission
    }

    /// Pool checkout/hit counters of the *current* snapshot's session —
    /// the evidence that concurrent queries share warm stores.
    pub fn pool_stats(&self) -> PoolStats {
        self.snapshot().session.pool_stats()
    }

    /// Engine runs completed by the current snapshot's session.
    pub fn runs_completed(&self) -> u64 {
        self.snapshot().session.runs_completed()
    }

    /// Queries fully served over the server's lifetime (all snapshots).
    pub fn queries_completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    /// Copy of the per-query metrics log, in completion order.
    pub fn query_log(&self) -> Vec<QueryMetrics> {
        self.log.lock().expect("query log poisoned").clone()
    }

    /// End-to-end latency order statistics over served queries,
    /// optionally restricted to one priority class.
    pub fn latency_stats(&self, class: Option<Priority>) -> LatencyStats {
        let log = self.log.lock().expect("query log poisoned");
        let samples: Vec<std::time::Duration> = log
            .iter()
            .filter(|q| class.map_or(true, |c| q.class == c.name()))
            .map(|q| q.latency)
            .collect();
        LatencyStats::from_durations(&samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::query::EgoNetBfs;
    use crate::algos::ConnectedComponents;
    use crate::graph::gen;
    use crate::metrics::HaltReason;

    #[test]
    fn serves_and_logs_a_query() {
        let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 7);
        let server = QueryServer::new(g.rebuilt());
        let solo = GraphSession::new(&g).run(&ConnectedComponents);
        let got = server
            .execute(&ConnectedComponents, &QuerySpec::interactive())
            .unwrap();
        assert_eq!(got.values, solo.values);
        assert_eq!(got.query.epoch, 0);
        assert_eq!(got.query.class, "interactive");
        assert_eq!(got.metrics.query_tag, Some(got.query.tag));
        assert_eq!(server.queries_completed(), 1);
        assert_eq!(server.query_log().len(), 1);
        assert_eq!(server.latency_stats(None).count, 1);
        assert_eq!(server.latency_stats(Some(Priority::Batch)).count, 0);
    }

    #[test]
    fn mutation_publishes_new_epoch_without_waiting_for_pins() {
        let g = gen::ring(32);
        let server = QueryServer::new(g);
        let pinned = server.pin_current();
        assert_eq!(server.pinned_readers(0), 1);
        let mut m = MutationSet::new();
        m.insert_undirected(0, 16);
        let receipt = server.apply_mutations(&m);
        assert_eq!(receipt.epoch, 1);
        assert_eq!(server.epoch(), 1, "writer published without blocking");
        assert_eq!(pinned.epoch(), 0, "reader still on its pinned epoch");
        assert_eq!(server.oldest_pinned(), Some(0));
        drop(pinned);
        assert_eq!(server.oldest_pinned(), None);
    }

    #[test]
    fn budget_exhaustion_is_a_clean_halt() {
        let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 11);
        let server = QueryServer::new(g);
        let spec = QuerySpec::interactive().budget(crate::serve::QueryBudget::tokens(1));
        let got = server.execute(&ConnectedComponents, &spec).unwrap();
        assert_eq!(got.query.halt_reason, HaltReason::BudgetExhausted);
        // The pool survives: a fresh unbounded query converges normally.
        let again = server
            .execute(&ConnectedComponents, &QuerySpec::interactive())
            .unwrap();
        assert_eq!(again.query.halt_reason, HaltReason::Quiescence);
        assert!(again.query.store_reused, "exhausted run handed its store back");
    }

    #[test]
    fn explicit_tag_beats_assigned_id() {
        let g = gen::grid(6, 6);
        let server = QueryServer::new(g);
        let got = server
            .execute(
                &EgoNetBfs { root: 0, radius: 2 },
                &QuerySpec::interactive().tag(0xBEEF),
            )
            .unwrap();
        assert_eq!(got.query.tag, 0xBEEF);
        assert_eq!(got.metrics.query_tag, Some(0xBEEF));
    }
}
