//! The admission gate: bounds in-flight runs and lets interactive
//! queries overtake queued batch work.
//!
//! A `Mutex<state> + Condvar` turnstile rather than anything lock-free:
//! admission happens once per *query*, not per vertex, so the gate is
//! admission-rate code — the hot loops below it never see it. Fairness
//! is priority-then-wakeup-order: a batch waiter is never admitted while
//! an interactive waiter is queued; within a class, wakeup order is the
//! platform condvar's (FIFO on the common platforms, not guaranteed).
//!
//! **Load shedding is priority-ordered (reject-batch-first).** With a
//! queue cap set, a batch submission is shed as soon as the *total*
//! waiting census is at the cap, but an interactive submission is shed
//! only when **interactive waiters alone** fill the cap. Queued batch
//! work can therefore never crowd an interactive query out of the gate
//! — under overload the queue drains toward all-interactive occupancy,
//! which is the intended degradation order for a multi-tenant server
//! (batch callers retry on their own schedule; interactive callers are
//! a user waiting). Shed decisions are counted per class
//! ([`AdmissionController::shed`]) so an operator can see *who* is
//! being turned away, not just that rejections happen.

use crate::serve::handle::Priority;
use std::sync::{Condvar, Mutex};

/// Why a submission was turned away at the gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// Shed at the queue cap under the reject-batch-first policy (see
    /// the [module docs](self)): batch sheds on total occupancy,
    /// interactive only on interactive occupancy.
    QueueFull,
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::QueueFull => f.write_str("admission queue full"),
        }
    }
}

#[derive(Default)]
struct GateState {
    /// Runs currently holding a permit.
    running: usize,
    /// Interactive waiters blocked in [`AdmissionController::admit`] —
    /// while non-zero, batch waiters stay blocked even with free slots.
    waiting_interactive: usize,
    /// All waiters, both classes (the queue-cap census).
    waiting_total: usize,
    /// Total permits ever granted (monotone; for observability).
    admitted: u64,
    /// Interactive submissions shed at the cap (monotone).
    shed_interactive: u64,
    /// Batch submissions shed at the cap (monotone).
    shed_batch: u64,
}

/// Concurrency gate for a [`crate::serve::QueryServer`]: at most
/// `max_concurrent` runs in flight, interactive-first admission, and an
/// optional bound on the wait queue (load shedding).
pub struct AdmissionController {
    max_concurrent: usize,
    max_queued: Option<usize>,
    state: Mutex<GateState>,
    turnstile: Condvar,
}

impl AdmissionController {
    /// Gate admitting up to `max_concurrent` (≥ 1 enforced) concurrent
    /// runs, with an unbounded wait queue.
    pub fn new(max_concurrent: usize) -> Self {
        AdmissionController {
            max_concurrent: max_concurrent.max(1),
            max_queued: None,
            state: Mutex::new(GateState::default()),
            turnstile: Condvar::new(),
        }
    }

    /// Bound the wait queue: submissions past the cap get
    /// [`AdmitError::QueueFull`] instead of a slot, shed in
    /// reject-batch-first order — batch counts every waiter against
    /// the cap, interactive counts only interactive waiters (see the
    /// [module docs](self)).
    pub fn with_queue_cap(mut self, n: usize) -> Self {
        self.max_queued = Some(n);
        self
    }

    /// Block until a slot frees (interactive waiters first), returning
    /// the RAII permit whose drop releases the slot.
    ///
    /// # Errors
    /// [`AdmitError::QueueFull`] when the wait queue is at its cap.
    pub fn admit(&self, priority: Priority) -> Result<AdmitPermit<'_>, AdmitError> {
        let mut st = self.state.lock().expect("admission gate poisoned");
        let can_enter = |st: &GateState| {
            st.running < self.max_concurrent
                && (priority == Priority::Interactive || st.waiting_interactive == 0)
        };
        if !can_enter(&st) {
            if let Some(cap) = self.max_queued {
                // Reject-batch-first shedding: batch is shed on total
                // queue occupancy, interactive only when interactive
                // waiters alone fill the cap — parked batch work never
                // crowds an interactive query out of the gate.
                let occupancy = match priority {
                    Priority::Interactive => st.waiting_interactive,
                    Priority::Batch => st.waiting_total,
                };
                if occupancy >= cap {
                    match priority {
                        Priority::Interactive => st.shed_interactive += 1,
                        Priority::Batch => st.shed_batch += 1,
                    }
                    return Err(AdmitError::QueueFull);
                }
            }
            st.waiting_total += 1;
            if priority == Priority::Interactive {
                st.waiting_interactive += 1;
            }
            while !can_enter(&st) {
                st = self
                    .turnstile
                    .wait(st)
                    .expect("admission gate poisoned");
            }
            st.waiting_total -= 1;
            if priority == Priority::Interactive {
                st.waiting_interactive -= 1;
            }
        }
        st.running += 1;
        st.admitted += 1;
        drop(st);
        Ok(AdmitPermit { gate: self })
    }

    /// Runs currently holding a permit.
    pub fn running(&self) -> usize {
        self.state.lock().expect("admission gate poisoned").running
    }

    /// Queries currently blocked at the gate.
    pub fn waiting(&self) -> usize {
        self.state
            .lock()
            .expect("admission gate poisoned")
            .waiting_total
    }

    /// Total permits ever granted.
    pub fn admitted(&self) -> u64 {
        self.state.lock().expect("admission gate poisoned").admitted
    }

    /// Submissions shed at the queue cap, as `(interactive, batch)` —
    /// the per-class view the reject-batch-first policy exists for.
    pub fn shed(&self) -> (u64, u64) {
        let st = self.state.lock().expect("admission gate poisoned");
        (st.shed_interactive, st.shed_batch)
    }

    /// The concurrency bound this gate enforces.
    pub fn max_concurrent(&self) -> usize {
        self.max_concurrent
    }
}

/// RAII admission permit: one in-flight run slot, released (and the
/// turnstile woken) on drop — including the unwind path, so a panicking
/// query cannot leak its slot.
pub struct AdmitPermit<'a> {
    gate: &'a AdmissionController,
}

impl Drop for AdmitPermit<'_> {
    fn drop(&mut self) {
        // Don't double-panic on a poisoned gate during unwind.
        if let Ok(mut st) = self.gate.state.lock() {
            st.running -= 1;
        }
        self.gate.turnstile.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn permits_bound_concurrency() {
        let gate = Arc::new(AdmissionController::new(2));
        // (live, peak) under one lock — observed concurrency census.
        let census = Arc::new(Mutex::new((0usize, 0usize)));
        std::thread::scope(|s| {
            for _ in 0..8 {
                let (gate, census) = (Arc::clone(&gate), Arc::clone(&census));
                s.spawn(move || {
                    let permit = gate.admit(Priority::Batch).unwrap();
                    {
                        let mut c = census.lock().unwrap();
                        c.0 += 1;
                        c.1 = c.1.max(c.0);
                    }
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    census.lock().unwrap().0 -= 1;
                    drop(permit);
                });
            }
        });
        let peak = census.lock().unwrap().1;
        assert!(peak <= 2, "peak {peak}");
        assert_eq!(gate.admitted(), 8);
        assert_eq!(gate.running(), 0);
        assert_eq!(gate.waiting(), 0);
    }

    #[test]
    fn interactive_overtakes_queued_batch() {
        let gate = AdmissionController::new(1);
        let holder = gate.admit(Priority::Batch).unwrap();
        let order = Arc::new(Mutex::new(Vec::new()));
        std::thread::scope(|s| {
            let batch_order = Arc::clone(&order);
            let gate_ref = &gate;
            s.spawn(move || {
                let p = gate_ref.admit(Priority::Batch).unwrap();
                batch_order.lock().unwrap().push("batch");
                drop(p);
            });
            // Let the batch waiter park first, then queue interactive.
            std::thread::sleep(std::time::Duration::from_millis(5));
            let inter_order = Arc::clone(&order);
            s.spawn(move || {
                let p = gate_ref.admit(Priority::Interactive).unwrap();
                inter_order.lock().unwrap().push("interactive");
                drop(p);
            });
            std::thread::sleep(std::time::Duration::from_millis(5));
            assert_eq!(gate.waiting(), 2);
            drop(holder);
        });
        let order = order.lock().unwrap();
        assert_eq!(
            order.as_slice(),
            ["interactive", "batch"],
            "interactive waiter admitted first"
        );
    }

    #[test]
    fn queue_cap_sheds_load() {
        let gate = AdmissionController::new(1).with_queue_cap(0);
        let holder = gate.admit(Priority::Interactive).unwrap();
        assert_eq!(
            gate.admit(Priority::Interactive).err(),
            Some(AdmitError::QueueFull)
        );
        assert_eq!(gate.shed(), (1, 0));
        drop(holder);
        // Slot free again: admission succeeds without queueing.
        assert!(gate.admit(Priority::Batch).is_ok());
        assert_eq!(gate.shed(), (1, 0), "granted permits are not sheds");
    }

    #[test]
    fn shedding_rejects_batch_before_interactive() {
        let gate = AdmissionController::new(1).with_queue_cap(1);
        let holder = gate.admit(Priority::Batch).unwrap();
        std::thread::scope(|s| {
            let gate_ref = &gate;
            // A parked batch waiter occupies the single queue slot.
            s.spawn(move || {
                let _p = gate_ref.admit(Priority::Batch).unwrap();
            });
            std::thread::sleep(std::time::Duration::from_millis(5));
            assert_eq!(gate.waiting(), 1);
            // Queue at cap: the next batch submission is shed…
            assert_eq!(
                gate.admit(Priority::Batch).err(),
                Some(AdmitError::QueueFull)
            );
            // …but an interactive one still queues — batch occupancy
            // never counts against the interactive class.
            s.spawn(move || {
                let _p = gate_ref.admit(Priority::Interactive).unwrap();
            });
            std::thread::sleep(std::time::Duration::from_millis(5));
            assert_eq!(gate.waiting(), 2, "interactive parked, not shed");
            drop(holder);
        });
        assert_eq!(gate.shed(), (0, 1));
        assert_eq!(gate.waiting(), 0);
        assert_eq!(gate.admitted(), 3);
    }
}
