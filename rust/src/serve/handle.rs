//! Per-query types: priority class, budgets, the submitted spec and the
//! returned response.

use crate::engine::{EngineConfig, Halt};
use crate::metrics::{QueryMetrics, RunMetrics};

/// Admission priority class. [`Priority::Interactive`] queries overtake
/// queued [`Priority::Batch`] work at the admission gate — the knob that
/// keeps point-lookup tail latency bounded while a whole-graph run is
/// in flight.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Priority {
    /// Latency-sensitive: admitted ahead of any queued batch work.
    Interactive,
    /// Throughput work: yields the admission gate to interactive queries.
    Batch,
}

impl Priority {
    /// Stable label for metrics/tables.
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }
}

/// Per-query resource caps, lowered into the engine's [`Halt`] policy.
/// Exhaustion stops the run at a superstep barrier with
/// [`crate::metrics::HaltReason::BudgetExhausted`] (tokens) or
/// [`crate::metrics::HaltReason::SuperstepCap`] (supersteps); either way
/// the run completes normally — partial values are returned and every
/// pooled resource is handed back, so an exhausted query cannot poison
/// the server for its neighbours.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueryBudget {
    /// Cap on supersteps (composes with the engine config's own cap).
    pub max_supersteps: Option<usize>,
    /// Cap on cumulative work tokens (messages + activations per
    /// superstep — see [`Halt::tokens`]).
    pub max_tokens: Option<u64>,
}

impl QueryBudget {
    /// No caps: the query runs to its own termination.
    pub fn unbounded() -> Self {
        Self::default()
    }

    /// Cap supersteps at `n`.
    pub fn supersteps(n: usize) -> Self {
        QueryBudget {
            max_supersteps: Some(n),
            ..Self::default()
        }
    }

    /// Cap work tokens at `n`.
    pub fn tokens(n: u64) -> Self {
        QueryBudget {
            max_tokens: Some(n),
            ..Self::default()
        }
    }

    /// Add (or tighten) a superstep cap.
    pub fn and_supersteps(mut self, n: usize) -> Self {
        self.max_supersteps = Some(self.max_supersteps.map_or(n, |old| old.min(n)));
        self
    }

    /// Add (or tighten) a token cap.
    pub fn and_tokens(mut self, n: u64) -> Self {
        self.max_tokens = Some(self.max_tokens.map_or(n, |old| old.min(n)));
        self
    }

    /// Lower the budget into an engine [`Halt`] policy.
    pub fn to_halt<A>(&self) -> Halt<A> {
        let mut halt = Halt::default();
        if let Some(n) = self.max_supersteps {
            halt = halt.and_supersteps(n);
        }
        if let Some(n) = self.max_tokens {
            halt = halt.and_tokens(n);
        }
        halt
    }
}

/// One query submission: priority, budgets, an optional per-query engine
/// configuration (a served query may want fewer threads or a different
/// substrate than the session default) and an optional explicit context
/// tag (defaults to the server-assigned query id).
#[derive(Clone, Copy, Debug, Default)]
pub struct QuerySpec {
    /// Explicit context tag; `None` uses the server-assigned query id.
    pub tag: Option<u64>,
    /// Admission class.
    pub priority: Option<Priority>,
    /// Engine configuration override for this query.
    pub config: Option<EngineConfig>,
    /// Resource caps.
    pub budget: QueryBudget,
}

impl QuerySpec {
    /// An unbounded interactive query with the session's default config.
    pub fn interactive() -> Self {
        QuerySpec {
            priority: Some(Priority::Interactive),
            ..Self::default()
        }
    }

    /// An unbounded batch run with the session's default config.
    pub fn batch() -> Self {
        QuerySpec {
            priority: Some(Priority::Batch),
            ..Self::default()
        }
    }

    /// The effective priority ([`Priority::Interactive`] by default —
    /// a bare spec is a point query, not a batch job).
    pub fn class(&self) -> Priority {
        self.priority.unwrap_or(Priority::Interactive)
    }

    /// Attach an explicit context tag.
    pub fn tag(mut self, tag: u64) -> Self {
        self.tag = Some(tag);
        self
    }

    /// Override the engine configuration for this query.
    pub fn config(mut self, cfg: EngineConfig) -> Self {
        self.config = Some(cfg);
        self
    }

    /// Attach resource caps.
    pub fn budget(mut self, budget: QueryBudget) -> Self {
        self.budget = budget;
        self
    }
}

/// What a served query returns: the run's values and full
/// [`RunMetrics`], plus the serving-layer [`QueryMetrics`] (queue wait,
/// end-to-end latency, pinned epoch, pool provenance).
#[derive(Clone, Debug)]
pub struct QueryResponse<V> {
    /// Final vertex values (partial if a budget fired).
    pub values: Vec<V>,
    /// The engine's own run metrics.
    pub metrics: RunMetrics,
    /// The serving layer's per-query record.
    pub query: QueryMetrics,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_lowers_into_halt() {
        let b = QueryBudget::supersteps(9).and_tokens(500).and_tokens(200);
        let h: Halt<()> = b.to_halt();
        assert_eq!(h.max_supersteps, Some(9));
        assert_eq!(h.max_tokens, Some(200));
        let h: Halt<()> = QueryBudget::unbounded().to_halt();
        assert_eq!(h.max_supersteps, None);
        assert_eq!(h.max_tokens, None);
    }

    #[test]
    fn spec_defaults_are_interactive_and_unbounded() {
        let s = QuerySpec::default();
        assert_eq!(s.class(), Priority::Interactive);
        assert_eq!(s.budget, QueryBudget::unbounded());
        assert_eq!(QuerySpec::batch().class(), Priority::Batch);
        assert_eq!(Priority::Batch.name(), "batch");
    }
}
