//! # iPregel — vertex-centric graph processing for irregular workloads
//!
//! A Rust reproduction of *“iPregel: Strategies to Deal with an Extreme
//! Form of Irregularity in Vertex-Centric Graph Processing”* (Capelli,
//! Brown, Bull — IA³/SC19), structured as a three-layer
//! Rust + JAX + Pallas stack (see `DESIGN.md` at the repository root).
//!
//! The crate provides:
//! - a Pregel-style user API ([`engine::VertexProgram`]) with three
//!   internal execution versions (push+combiner, pull single-broadcast,
//!   selection bypass), weighted-edge iteration
//!   ([`engine::Context::out_edge`]), typed composable aggregators
//!   ([`engine::Aggregator`]) and composable termination
//!   ([`engine::Halt`]);
//! - **pluggable delivery planes** ([`combine::plane`]): the combined
//!   plane (one foldable mailbox slot, the paper's §III machinery) and
//!   the log plane (per-vertex append-only message logs read via
//!   [`engine::Context::recv`]) — opening the non-combinable algorithm
//!   class ([`algos::Lpa`] label propagation, [`algos::Triangles`]
//!   per-vertex triangle counting) behind the same program API;
//! - a long-lived [`engine::GraphSession`] that runs many programs over
//!   one graph with pooled stores/mailboxes/bitsets/delivery planes,
//!   per-run config overrides and warm starts;
//! - the paper's optimisations as composable components: hybrid
//!   combiners ([`combine`]), externalised vertex layouts ([`layout`]),
//!   edge-centric & dynamic scheduling ([`sched`]);
//! - an **adaptive superstep tuner** ([`engine::tune`]): a per-barrier
//!   controller re-selecting schedule / combining strategy /
//!   dense-frontier bypass from live signals (frontier density, message
//!   volume, contention probes, flush imbalance) with hysteresis,
//!   thresholds calibrated from the simulator's cost model, and a
//!   per-superstep decision trace in
//!   [`metrics::RunMetrics::tuner_decisions`] — bit-identical results
//!   to any fixed configuration;
//! - a **partitioned execution substrate**
//!   ([`engine::Partitioning`], [`graph::partition`]): cache-sized,
//!   edge-balanced shards executed scatter/flush/apply with
//!   owner-exclusive shard-local combining and buffered cross-shard
//!   message routing — bit-identical to flat execution across the whole
//!   algorithm matrix;
//! - a **dynamic-graph subsystem** ([`graph::dynamic`],
//!   [`engine::epoch`]): per-vertex delta edge logs over the CSR,
//!   batched mutations under monotone mutation epochs with
//!   spill-threshold compaction, sessions that own the evolving graph
//!   ([`engine::GraphSession::dynamic`], `apply_mutations`) and patch
//!   their partition plans incrementally, and delta-driven incremental
//!   PageRank/SSSP/CC recompute ([`algos::incremental`]) — mutate → run
//!   is bit-identical to rebuild → run across the whole engine matrix;
//! - a graph substrate ([`graph`]) with generators, IO (including
//!   weighted edge lists and the `.ipg` v2 binary format) and the
//!   paper-analogue catalog;
//! - a calibrated virtual-testbed simulator ([`sim`]) reproducing the
//!   paper's 32-thread results on this single-core machine;
//! - an **irregularity observability plane** ([`trace`]): per-worker
//!   phase timelines, per-shard spans with steal attribution,
//!   per-superstep skew/contention/fan-in samples, exported as Chrome
//!   trace-event JSON (`--trace-out`, Perfetto-loadable) or a terminal
//!   summary (`--trace-summary`) — emitted identically by the real
//!   engine and the simulator's virtual clock;
//! - a **multi-tenant serving layer** ([`serve`]): a [`serve::QueryServer`]
//!   admitting many concurrent context-tagged runs over one shared
//!   (optionally dynamic) graph, with priority admission, per-query
//!   superstep/token budgets, snapshot isolation by copy-on-mutate over
//!   the mutation epochs ([`engine::epoch::EpochPins`]), bounded-scope
//!   query programs ([`algos::query`]) and per-query p50/p99 tail-latency
//!   metrics ([`metrics::LatencyStats`]) — every served run bit-identical
//!   to the same program run solo;
//! - a PJRT runtime ([`runtime`]) executing AOT-compiled JAX/Pallas
//!   superstep kernels for the dense-block accelerated path (behind the
//!   `pjrt` cargo feature; a stub otherwise);
//! - the experiment harness ([`exp`]) regenerating Tables I and II.

pub mod algos;
pub mod audit;
pub mod combine;
pub mod config;
pub mod engine;
pub mod exp;
pub mod graph;
pub mod layout;
pub mod metrics;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod trace;
pub mod util;

pub use engine::{EngineConfig, GraphSession, Halt, RunOptions, VertexProgram};
pub use graph::{Csr, GraphBuilder};
