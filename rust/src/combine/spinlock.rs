//! A one-byte test-and-test-and-set spinlock.
//!
//! iPregel guards each vertex mailbox with a tiny lock embedded in the
//! vertex structure (one byte, not a pthread mutex — with 65M vertices the
//! lock's footprint matters). Critical sections are a handful of
//! instructions, so spinning beats parking by a wide margin.

use std::sync::atomic::{AtomicBool, Ordering};

/// One-byte spinlock. `acquire`/`release` pairs establish the usual
/// Acquire/Release happens-before edges.
#[repr(transparent)]
pub struct SpinLock {
    locked: AtomicBool,
}

impl Default for SpinLock {
    fn default() -> Self {
        Self::new()
    }
}

impl SpinLock {
    /// New, unlocked.
    pub const fn new() -> Self {
        SpinLock {
            locked: AtomicBool::new(false),
        }
    }

    /// Spin until the lock is held by the caller.
    #[inline]
    pub fn acquire(&self) {
        // Checked before spinning: a recursive acquire would otherwise
        // spin forever without ever reaching a checkable state.
        #[cfg(feature = "race-check")]
        assert!(
            !self.held_by_current_thread(),
            "race-check: recursive SpinLock::acquire would self-deadlock"
        );
        loop {
            // Test-and-set fast path.
            if self
                .locked
                .compare_exchange_weak(false, true, Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
            {
                #[cfg(feature = "race-check")]
                crate::util::shadow::lock_acquired(self as *const SpinLock as usize);
                return;
            }
            // Test loop: spin on a plain load to avoid cache-line
            // ping-pong while the lock is held.
            while self.locked.load(Ordering::Relaxed) {
                std::hint::spin_loop();
            }
        }
    }

    /// Try once; true on success.
    #[inline]
    pub fn try_acquire(&self) -> bool {
        let won = self
            .locked
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_ok();
        #[cfg(feature = "race-check")]
        if won {
            crate::util::shadow::lock_acquired(self as *const SpinLock as usize);
        }
        won
    }

    /// Release a held lock.
    #[inline]
    pub fn release(&self) {
        // Ownership is checked before the store so a release-by-non-owner
        // panics instead of silently unlocking someone else's section.
        #[cfg(feature = "race-check")]
        crate::util::shadow::lock_released(self as *const SpinLock as usize);
        self.locked.store(false, Ordering::Release);
    }

    /// Does the calling thread hold this lock? (Checker bookkeeping —
    /// the lock itself records no owner.)
    #[cfg(feature = "race-check")]
    #[inline]
    pub fn held_by_current_thread(&self) -> bool {
        crate::util::shadow::lock_held(self as *const SpinLock as usize)
    }

    /// Run `f` under the lock.
    #[inline]
    pub fn with<R>(&self, f: impl FnOnce() -> R) -> R {
        self.acquire();
        let r = f();
        self.release();
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn try_acquire_excludes() {
        let l = SpinLock::new();
        assert!(l.try_acquire());
        assert!(!l.try_acquire());
        l.release();
        assert!(l.try_acquire());
        l.release();
    }

    #[test]
    fn with_runs_closure() {
        let l = SpinLock::new();
        assert_eq!(l.with(|| 7), 7);
        assert!(l.try_acquire());
        l.release();
    }

    #[test]
    fn mutual_exclusion_under_contention() {
        // Non-atomic counter protected only by the lock; races would lose
        // increments.
        struct Shared {
            lock: SpinLock,
            counter: std::cell::UnsafeCell<u64>,
        }
        // SAFETY: every access to `counter` happens inside `lock.with`,
        // so no two threads ever touch the cell concurrently.
        unsafe impl Sync for Shared {}
        let s = Arc::new(Shared {
            lock: SpinLock::new(),
            counter: std::cell::UnsafeCell::new(0),
        });
        const THREADS: usize = 8;
        const INCS: usize = 20_000;
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    for _ in 0..INCS {
                        // SAFETY: the increment runs under `lock`, the
                        // sole synchroniser for `counter`.
                        s.lock.with(|| unsafe { *s.counter.get() += 1 });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // SAFETY: all writer threads joined above; this read is exclusive.
        assert_eq!(unsafe { *s.counter.get() }, (THREADS * INCS) as u64);
    }
}
