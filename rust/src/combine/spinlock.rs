//! A one-byte test-and-test-and-set spinlock.
//!
//! iPregel guards each vertex mailbox with a tiny lock embedded in the
//! vertex structure (one byte, not a pthread mutex — with 65M vertices the
//! lock's footprint matters). Critical sections are a handful of
//! instructions, so spinning beats parking by a wide margin.

use std::sync::atomic::{AtomicBool, Ordering};

/// One-byte spinlock. `acquire`/`release` pairs establish the usual
/// Acquire/Release happens-before edges.
#[repr(transparent)]
pub struct SpinLock {
    locked: AtomicBool,
}

impl Default for SpinLock {
    fn default() -> Self {
        Self::new()
    }
}

impl SpinLock {
    /// New, unlocked.
    pub const fn new() -> Self {
        SpinLock {
            locked: AtomicBool::new(false),
        }
    }

    /// Spin until the lock is held by the caller.
    #[inline]
    pub fn acquire(&self) {
        loop {
            // Test-and-set fast path.
            if self
                .locked
                .compare_exchange_weak(false, true, Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
            {
                return;
            }
            // Test loop: spin on a plain load to avoid cache-line
            // ping-pong while the lock is held.
            while self.locked.load(Ordering::Relaxed) {
                std::hint::spin_loop();
            }
        }
    }

    /// Try once; true on success.
    #[inline]
    pub fn try_acquire(&self) -> bool {
        self.locked
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
    }

    /// Release a held lock.
    #[inline]
    pub fn release(&self) {
        self.locked.store(false, Ordering::Release);
    }

    /// Run `f` under the lock.
    #[inline]
    pub fn with<R>(&self, f: impl FnOnce() -> R) -> R {
        self.acquire();
        let r = f();
        self.release();
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn try_acquire_excludes() {
        let l = SpinLock::new();
        assert!(l.try_acquire());
        assert!(!l.try_acquire());
        l.release();
        assert!(l.try_acquire());
        l.release();
    }

    #[test]
    fn with_runs_closure() {
        let l = SpinLock::new();
        assert_eq!(l.with(|| 7), 7);
        assert!(l.try_acquire());
        l.release();
    }

    #[test]
    fn mutual_exclusion_under_contention() {
        // Non-atomic counter protected only by the lock; races would lose
        // increments.
        struct Shared {
            lock: SpinLock,
            counter: std::cell::UnsafeCell<u64>,
        }
        unsafe impl Sync for Shared {}
        let s = Arc::new(Shared {
            lock: SpinLock::new(),
            counter: std::cell::UnsafeCell::new(0),
        });
        const THREADS: usize = 8;
        const INCS: usize = 20_000;
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    for _ in 0..INCS {
                        s.lock.with(|| unsafe { *s.counter.get() += 1 });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(unsafe { *s.counter.get() }, (THREADS * INCS) as u64);
    }
}
