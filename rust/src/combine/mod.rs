//! Message combination — the fine-grain synchronisation hot spot (§III).
//!
//! Every vertex owns a one-message mailbox; concurrent senders must merge
//! their messages into it through a user-defined, commutative+associative
//! *combine* operation. Three delivery strategies are provided:
//!
//! - [`Strategy::Lock`] — classic per-vertex lock around check+combine;
//! - [`Strategy::CasNeutral`] — pure compare-and-swap; lock-free, but
//!   requires a *neutral element* and loses the notion of an empty
//!   mailbox (the paper's §III discusses why this can produce incorrect
//!   programs — we implement it faithfully as the comparison baseline);
//! - [`Strategy::Hybrid`] — the paper's contribution (Fig. 1): a
//!   lock-protected *first push* that establishes the mailbox value, then
//!   lock-free CAS for every subsequent combine.
//!
//! Strategies operate on [`slot::MsgSlot`]s, which are embedded either in
//! an interleaved vertex record (baseline layout) or in an externalised
//! hot array (§IV) — see [`crate::layout`].
//!
//! Slot + strategy together form the **combined delivery plane**
//! ([`plane::CombinedPlane`]) — one of two pluggable planes. The other,
//! [`plane::LogPlane`], retains every message in per-vertex append-only
//! logs for the non-combinable algorithms (label propagation, triangle
//! counting) no single-slot combine can express — see [`plane`].

pub mod combiner;
pub mod plane;
pub mod slot;
pub mod spinlock;
pub mod strategy;
pub mod vector;

pub use combiner::{Combiner, MaxCombiner, MinCombiner, MonoidKind, NullCombiner, SumCombiner};
pub use plane::{CombinedPlane, DeliveryPlane, LogPlane, MessageLog};
pub use slot::{MessageValue, MsgSlot};
pub use spinlock::SpinLock;
pub use strategy::{ContentionProbe, Strategy};
