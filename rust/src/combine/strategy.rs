//! Message-delivery strategies over [`MsgSlot`]s.
//!
//! [`Strategy::Hybrid`] is the paper's Fig. 1 translated line-for-line:
//! a lock-guarded first push (store message, *then* flag, with the
//! sequential-consistency barrier between them), a double-checked flag
//! after lock acquisition, and pure CAS combining once the mailbox is
//! known to be populated.

use crate::combine::combiner::Combiner;
use crate::combine::slot::{MessageValue, MsgSlot};
use crate::combine::spinlock::SpinLock;
use std::sync::atomic::{AtomicU64, Ordering};

/// Live contention counters for one worker's deliveries, drained once per
/// superstep by the adaptive tuner (`engine/tune.rs`).
///
/// The probe is **opt-in per delivery call**: the plain
/// [`Strategy::deliver`] path takes no probe argument and compiles to
/// exactly the pre-probe code, so fixed-config runs pay nothing. Adaptive
/// runs hand each worker its own cache-padded probe, so the counters
/// themselves never become the contention they measure.
#[derive(Debug, Default)]
pub struct ContentionProbe {
    /// CAS attempts that lost the race and had to re-load + re-combine
    /// (the hybrid/CAS designs' contention signal).
    pub cas_retries: AtomicU64,
    /// Lock acquisitions that found the lock held and had to spin (the
    /// lock design's — and the hybrid first-push's — contention signal).
    pub lock_contended: AtomicU64,
}

impl ContentionProbe {
    /// Fresh probe with zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Drain both counters, returning `(cas_retries, lock_contended)`.
    pub fn take(&self) -> (u64, u64) {
        (
            self.cas_retries.swap(0, Ordering::Relaxed),
            self.lock_contended.swap(0, Ordering::Relaxed),
        )
    }

    /// Read both counters without draining them, returning
    /// `(cas_retries, lock_contended)`. The observability plane samples
    /// the tuner's probes at each barrier *before* `observe` drains
    /// them, so tracing never perturbs the signals the tuner acts on.
    pub fn peek(&self) -> (u64, u64) {
        (
            self.cas_retries.load(Ordering::Relaxed),
            self.lock_contended.load(Ordering::Relaxed),
        )
    }
}

/// Acquire `lock`, counting a contended acquisition into `probe`.
#[inline]
fn acquire_probed(lock: &SpinLock, probe: Option<&ContentionProbe>) {
    if lock.try_acquire() {
        return;
    }
    if let Some(p) = probe {
        p.lock_contended.fetch_add(1, Ordering::Relaxed);
    }
    lock.acquire();
}

/// Which synchronisation design delivers messages into mailboxes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Acquire the vertex lock around every check+combine (§III "lock").
    Lock,
    /// Pure compare-and-swap against a neutral element (§III
    /// "compare-and-swap"). Requires `Combiner::neutral()`; carries the
    /// paper's documented caveat that a combination *producing* the
    /// neutral value is indistinguishable from an empty mailbox.
    CasNeutral,
    /// The paper's hybrid combiner (Fig. 1).
    Hybrid,
}

impl Strategy {
    /// Parse from CLI text.
    pub fn parse(s: &str) -> Option<Strategy> {
        match s {
            "lock" => Some(Strategy::Lock),
            "cas" | "cas-neutral" => Some(Strategy::CasNeutral),
            "hybrid" => Some(Strategy::Hybrid),
            _ => None,
        }
    }

    /// Deliver `msg` into `slot`, merging with any pending message via
    /// `combiner`. Safe to call concurrently from any number of threads.
    #[inline]
    pub fn deliver<M: MessageValue, C: Combiner<M>>(
        self,
        slot: &MsgSlot<M>,
        msg: M,
        combiner: &C,
    ) {
        match self {
            Strategy::Lock => deliver_lock(slot, msg, combiner, None),
            Strategy::CasNeutral => deliver_cas_neutral(slot, msg, combiner, None),
            Strategy::Hybrid => deliver_hybrid(slot, msg, combiner, None),
        }
    }

    /// [`Strategy::deliver`] with live contention accounting: CAS retries
    /// and contended lock acquisitions are counted into `probe`. Same
    /// delivered value, same synchronisation — only the bookkeeping
    /// differs. Adaptive runs (`engine/tune.rs`) call this with one probe
    /// per worker; everything else stays on the probe-free path.
    #[inline]
    pub fn deliver_probed<M: MessageValue, C: Combiner<M>>(
        self,
        slot: &MsgSlot<M>,
        msg: M,
        combiner: &C,
        probe: &ContentionProbe,
    ) {
        match self {
            Strategy::Lock => deliver_lock(slot, msg, combiner, Some(probe)),
            Strategy::CasNeutral => deliver_cas_neutral(slot, msg, combiner, Some(probe)),
            Strategy::Hybrid => deliver_hybrid(slot, msg, combiner, Some(probe)),
        }
    }

    /// Owner-exclusive delivery: the shard-local path of partitioned
    /// execution. The caller guarantees no concurrent delivery to `slot`
    /// (during scatter a shard's mailbox slab is written only by the
    /// worker owning the shard; during flush only by the task owning the
    /// destination shard), so no lock acquisition or CAS retry loop is
    /// needed — plain load/combine/store. Produces exactly the merged
    /// value [`Strategy::deliver`] would, including the CAS-neutral
    /// design's value-is-neutral emptiness convention, so partitioned
    /// runs stay bit-identical to flat runs.
    ///
    /// For known-monoid combiners (see [`Combiner::monoid_kind`]) the
    /// engine may instead fold the same message set through the
    /// lane-parallel gather of `combine::vector` — the exactness of the
    /// monoid laws (associativity + commutativity over the exact integer
    /// domain) makes that reduction value-identical to this left fold,
    /// a contract pinned by the tests below.
    #[inline]
    pub fn deliver_exclusive<M: MessageValue, C: Combiner<M>>(
        self,
        slot: &MsgSlot<M>,
        msg: M,
        combiner: &C,
    ) {
        match self {
            Strategy::Lock | Strategy::Hybrid => {
                if slot.has_msg() {
                    slot.store_msg(combiner.combine(slot.load_msg(), msg));
                } else {
                    slot.store_first(msg);
                }
            }
            // No flag in this design: the slot always holds a value
            // (pre-loaded neutral), so combining is unconditional.
            Strategy::CasNeutral => slot.store_msg(combiner.combine(slot.load_msg(), msg)),
        }
    }

    /// Initialise a slot for this strategy at superstep start.
    /// The CAS-neutral design has no empty flag: it must pre-load the
    /// neutral element and pretend the flag is always set (this is the
    /// user-visible "reset your mailbox to 0 every superstep" burden the
    /// paper describes for Ligra-style designs).
    pub fn reset_slot<M: MessageValue, C: Combiner<M>>(self, slot: &MsgSlot<M>, combiner: &C) {
        match self {
            Strategy::Lock | Strategy::Hybrid => slot.clear(),
            Strategy::CasNeutral => {
                let n = combiner
                    .neutral()
                    // audit:allow(panic): configuration invariant checked
                    // once per superstep, not per message — CasNeutral is
                    // only selectable with a neutral-element combiner.
                    .expect("CasNeutral strategy requires a combiner with a neutral element");
                // Flag stays true forever; emptiness is value == neutral.
                slot.store_first(n);
            }
        }
    }

    /// Read out a slot at superstep end. For CAS-neutral, "empty" is
    /// `value == neutral` (bitwise), reproducing the paper's caveat.
    pub fn collect<M: MessageValue, C: Combiner<M>>(
        self,
        slot: &MsgSlot<M>,
        combiner: &C,
    ) -> Option<M> {
        match self {
            Strategy::Lock | Strategy::Hybrid => slot.take(),
            Strategy::CasNeutral => {
                // audit:allow(panic): same configuration invariant as in
                // `reset_slot` — unreachable for engine-constructed runs.
                let n = combiner.neutral().expect("neutral required");
                let v = slot.load_msg();
                if v.to_bits() == n.to_bits() {
                    None
                } else {
                    Some(v)
                }
            }
        }
    }
}

/// Classic lock design: hold the vertex lock across the whole
/// check-combine-store sequence.
#[inline]
fn deliver_lock<M: MessageValue, C: Combiner<M>>(
    slot: &MsgSlot<M>,
    msg: M,
    combiner: &C,
    probe: Option<&ContentionProbe>,
) {
    match probe {
        // Probe-free path: literally the pre-probe code.
        None => slot.lock().acquire(),
        Some(_) => acquire_probed(slot.lock(), probe),
    }
    if slot.has_msg() {
        let merged = combiner.combine(slot.load_msg(), msg);
        slot.store_msg(merged);
    } else {
        slot.store_first(msg);
    }
    slot.lock().release();
}

/// Pure CAS design against a pre-loaded neutral element.
#[inline]
fn deliver_cas_neutral<M: MessageValue, C: Combiner<M>>(
    slot: &MsgSlot<M>,
    msg: M,
    combiner: &C,
    probe: Option<&ContentionProbe>,
) {
    let mut old = slot.load_msg();
    let mut retries = 0u64;
    loop {
        let new = combiner.combine(old, msg);
        // Identical-value fast path: storing the same bits is a no-op
        // (paper Fig. 1 line 6 applies the same short-circuit).
        if new.to_bits() == old.to_bits() {
            break;
        }
        match slot.cas_msg(old, new) {
            Ok(()) => break,
            Err(observed) => {
                old = observed;
                retries += 1;
            }
        }
    }
    if retries > 0 {
        if let Some(p) = probe {
            p.cas_retries.fetch_add(retries, Ordering::Relaxed);
        }
    }
}

/// The hybrid combiner, translated from paper Fig. 1.
///
/// ```text
/// ip_send_message(dst, msg):
///   if dst.has_msg_next:            // lock-free fast path
///     apply_cas(dst, msg)
///   else:
///     lock(dst)
///     if dst.has_msg_next:          // double-check under the lock
///       unlock(dst); apply_cas(dst, msg)
///     else:
///       dst.msg_next = msg          // store value FIRST
///       dst.has_msg_next = true     // flag second (SeqCst barrier)
///       unlock(dst)
/// ```
#[inline]
fn deliver_hybrid<M: MessageValue, C: Combiner<M>>(
    slot: &MsgSlot<M>,
    msg: M,
    combiner: &C,
    probe: Option<&ContentionProbe>,
) {
    if slot.has_msg() {
        apply_cas(slot, msg, combiner, probe);
    } else {
        match probe {
            None => slot.lock().acquire(),
            Some(_) => acquire_probed(slot.lock(), probe),
        }
        if slot.has_msg() {
            // Another thread won the first push while we waited: the
            // mailbox value is guaranteed set, so drop the lock and CAS.
            slot.lock().release();
            apply_cas(slot, msg, combiner, probe);
        } else {
            slot.store_first(msg);
            slot.lock().release();
        }
    }
}

/// Paper Fig. 1 `apply_cas`: retry until our contribution lands.
#[inline]
fn apply_cas<M: MessageValue, C: Combiner<M>>(
    slot: &MsgSlot<M>,
    msg: M,
    combiner: &C,
    probe: Option<&ContentionProbe>,
) {
    let mut old = slot.load_msg();
    let mut retries = 0u64;
    loop {
        let new = combiner.combine(old, msg);
        if new.to_bits() == old.to_bits() {
            // Combination is a no-op (e.g. min with a larger value).
            break;
        }
        match slot.cas_msg(old, new) {
            Ok(()) => break,
            Err(observed) => {
                old = observed;
                retries += 1;
            }
        }
    }
    if retries > 0 {
        if let Some(p) = probe {
            p.cas_retries.fetch_add(retries, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::combine::combiner::{FnCombiner, MinCombiner, SumCombiner};
    use std::sync::Arc;

    /// Announce to the race checker the happens-before edges these tests
    /// create through raw `thread::spawn`/`join` (outside the engine's
    /// phase brackets). No-op in normal builds.
    fn shadow_sync() {
        #[cfg(feature = "race-check")]
        crate::util::shadow::sync_point();
    }

    fn all_strategies() -> [Strategy; 3] {
        [Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid]
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Strategy::parse("lock"), Some(Strategy::Lock));
        assert_eq!(Strategy::parse("cas"), Some(Strategy::CasNeutral));
        assert_eq!(Strategy::parse("hybrid"), Some(Strategy::Hybrid));
        assert_eq!(Strategy::parse("bogus"), None);
    }

    #[test]
    fn single_thread_semantics_match_fold() {
        for strat in all_strategies() {
            let slot: MsgSlot<u64> = MsgSlot::new();
            let c = MinCombiner;
            strat.reset_slot(&slot, &c);
            for m in [50u64, 20, 90, 30] {
                strat.deliver(&slot, m, &c);
            }
            assert_eq!(strat.collect(&slot, &c), Some(20), "{strat:?}");
        }
    }

    #[test]
    fn empty_slot_collects_none() {
        for strat in all_strategies() {
            let slot: MsgSlot<u64> = MsgSlot::new();
            let c = MinCombiner;
            strat.reset_slot(&slot, &c);
            assert_eq!(strat.collect(&slot, &c), None, "{strat:?}");
        }
    }

    #[test]
    fn cas_neutral_exhibits_papers_lost_message_caveat() {
        // A combination whose *result* equals the neutral value is
        // indistinguishable from an empty mailbox — §III's correctness
        // trap, reproduced deliberately.
        let slot: MsgSlot<i64> = MsgSlot::new();
        let c = SumCombiner;
        Strategy::CasNeutral.reset_slot(&slot, &c);
        Strategy::CasNeutral.deliver(&slot, 5, &c);
        Strategy::CasNeutral.deliver(&slot, -5, &c);
        assert_eq!(Strategy::CasNeutral.collect(&slot, &c), None); // lost!
        // The hybrid combiner keeps it.
        let slot2: MsgSlot<i64> = MsgSlot::new();
        Strategy::Hybrid.reset_slot(&slot2, &c);
        Strategy::Hybrid.deliver(&slot2, 5, &c);
        Strategy::Hybrid.deliver(&slot2, -5, &c);
        assert_eq!(Strategy::Hybrid.collect(&slot2, &c), Some(0));
    }

    #[test]
    fn hybrid_works_without_neutral_element() {
        // Arbitrary user combiner with no neutral value — only lock and
        // hybrid can run it (the paper's programmability argument).
        let c = FnCombiner::new(|a: u64, b: u64| a.min(b).wrapping_mul(2) + a.max(b) % 3);
        let slot: MsgSlot<u64> = MsgSlot::new();
        Strategy::Hybrid.reset_slot(&slot, &c);
        Strategy::Hybrid.deliver(&slot, 9, &c);
        Strategy::Hybrid.deliver(&slot, 4, &c);
        assert_eq!(Strategy::Hybrid.collect(&slot, &c), Some(4 * 2 + 9 % 3));
    }

    #[test]
    fn exclusive_delivery_matches_concurrent_delivery() {
        // The shard-local path must fold to the same value as the
        // synchronised path for every strategy — the bit-identity
        // contract of partitioned execution.
        let msgs = [50u64, 20, 90, 30, 20];
        for strat in all_strategies() {
            let c = MinCombiner;
            let shared: MsgSlot<u64> = MsgSlot::new();
            let owned: MsgSlot<u64> = MsgSlot::new();
            strat.reset_slot(&shared, &c);
            strat.reset_slot(&owned, &c);
            for &m in &msgs {
                strat.deliver(&shared, m, &c);
                strat.deliver_exclusive(&owned, m, &c);
            }
            assert_eq!(
                strat.collect(&shared, &c),
                strat.collect(&owned, &c),
                "{strat:?}"
            );
        }
        // Sum combiner too (adversarial for lost updates).
        for strat in all_strategies() {
            let c = SumCombiner;
            let owned: MsgSlot<i64> = MsgSlot::new();
            strat.reset_slot(&owned, &c);
            for m in [5i64, -2, 9] {
                strat.deliver_exclusive(&owned, m, &c);
            }
            assert_eq!(strat.collect(&owned, &c), Some(12), "{strat:?}");
        }
    }

    #[test]
    fn vector_reduction_matches_exclusive_delivery_for_monoids() {
        use crate::combine::vector::reduce_gather;
        // The §2.9 lane-parallel gather must fold to the exact value the
        // scalar delivery path produces for every monoid combiner and
        // every strategy — the bit-identity contract of the vector pass.
        let msgs: Vec<u64> = (0..37).map(|i| (i * 2654435761u64) % 1000 + 1).collect();
        for strat in all_strategies() {
            let c = MinCombiner;
            assert!(c.monoid_kind().is_some(), "MinCombiner declares its monoid");
            let slot: MsgSlot<u64> = MsgSlot::new();
            strat.reset_slot(&slot, &c);
            for &m in &msgs {
                strat.deliver_exclusive(&slot, m, &c);
            }
            let (acc, found) =
                reduce_gather(msgs.len(), &c, c.neutral().unwrap(), &mut |i| Some(msgs[i]));
            assert_eq!(found, msgs.len() as u64);
            assert_eq!(strat.collect(&slot, &c), acc, "{strat:?}");
        }
        // Sum over signed values (adversarial for a wrong end-merge).
        let vals: Vec<i64> = (0..29).map(|i| (i as i64 % 11) - 5).collect();
        let c = SumCombiner;
        let slot: MsgSlot<i64> = MsgSlot::new();
        Strategy::Hybrid.reset_slot(&slot, &c);
        for &m in &vals {
            Strategy::Hybrid.deliver_exclusive(&slot, m, &c);
        }
        let (acc, _) =
            reduce_gather(vals.len(), &c, c.neutral().unwrap(), &mut |i| Some(vals[i]));
        assert_eq!(Strategy::Hybrid.collect(&slot, &c), acc);
    }

    #[test]
    fn exclusive_delivery_empty_slot_collects_none() {
        for strat in all_strategies() {
            let slot: MsgSlot<u64> = MsgSlot::new();
            let c = MinCombiner;
            strat.reset_slot(&slot, &c);
            assert_eq!(strat.collect(&slot, &c), None, "{strat:?}");
        }
    }

    fn stress<C: Combiner<u64> + Copy + 'static>(
        strat: Strategy,
        c: C,
        msgs_per_thread: usize,
        threads: usize,
        make_msg: fn(usize, usize) -> u64,
        expected: fn(&[u64]) -> u64,
    ) {
        let slot: Arc<MsgSlot<u64>> = Arc::new(MsgSlot::new());
        strat.reset_slot(&slot, &c);
        shadow_sync(); // spawn edge: setup writes precede the workers
        let mut all: Vec<u64> = Vec::new();
        for t in 0..threads {
            for i in 0..msgs_per_thread {
                all.push(make_msg(t, i));
            }
        }
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let slot = Arc::clone(&slot);
                std::thread::spawn(move || {
                    for i in 0..msgs_per_thread {
                        strat.deliver(&slot, make_msg(t, i), &c);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        shadow_sync(); // join edge: worker writes precede the collect
        let got = strat.collect(&slot, &c).expect("message must survive");
        assert_eq!(got, expected(&all), "{strat:?}");
    }

    #[test]
    fn concurrent_min_is_linearisable_all_strategies() {
        for strat in all_strategies() {
            stress(
                strat,
                MinCombiner,
                2000,
                8,
                |t, i| ((t * 2000 + i) as u64 ^ 0x5DEECE66D) % 100_000 + 1,
                |all| *all.iter().min().unwrap(),
            );
        }
    }

    #[test]
    fn concurrent_sum_preserves_every_contribution() {
        // Sum is the adversarial case for atomicity: a lost update changes
        // the total. (Skip CasNeutral+sum only because it is covered above
        // — its neutral 0 works fine when no combination sums to 0.)
        for strat in all_strategies() {
            stress(
                strat,
                SumCombiner,
                2000,
                8,
                |t, i| (t + 1) as u64 * 3 + i as u64 % 7 + 1,
                |all| all.iter().sum(),
            );
        }
    }

    #[test]
    fn probed_delivery_matches_unprobed_and_counts_nothing_serially() {
        // Serial deliveries never contend: the probe must stay zero and
        // the folded value must match the probe-free path exactly.
        for strat in all_strategies() {
            let c = MinCombiner;
            let plain: MsgSlot<u64> = MsgSlot::new();
            let probed: MsgSlot<u64> = MsgSlot::new();
            let probe = ContentionProbe::new();
            strat.reset_slot(&plain, &c);
            strat.reset_slot(&probed, &c);
            for m in [50u64, 20, 90, 30] {
                strat.deliver(&plain, m, &c);
                strat.deliver_probed(&probed, m, &c, &probe);
            }
            assert_eq!(
                strat.collect(&plain, &c),
                strat.collect(&probed, &c),
                "{strat:?}"
            );
            assert_eq!(probe.take(), (0, 0), "{strat:?}: serial = uncontended");
        }
    }

    #[test]
    fn probed_delivery_preserves_every_contribution_under_contention() {
        // The probe must never alter delivery semantics: a contended sum
        // through deliver_probed keeps every contribution, and take()
        // drains the counters.
        for strat in all_strategies() {
            let slot: Arc<MsgSlot<u64>> = Arc::new(MsgSlot::new());
            let probe: Arc<ContentionProbe> = Arc::new(ContentionProbe::new());
            let c = SumCombiner;
            strat.reset_slot(&slot, &c);
            shadow_sync();
            let threads = 8;
            let per = 2000u64;
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    let slot = Arc::clone(&slot);
                    let probe = Arc::clone(&probe);
                    std::thread::spawn(move || {
                        for i in 0..per {
                            strat.deliver_probed(&slot, t * 7 + i % 5 + 1, &c, &probe);
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            shadow_sync();
            let want: u64 = (0..threads)
                .map(|t| (0..per).map(|i| t * 7 + i % 5 + 1).sum::<u64>())
                .sum();
            assert_eq!(strat.collect(&slot, &c), Some(want), "{strat:?}");
            let _ = probe.take();
            assert_eq!(probe.take(), (0, 0), "take() drains");
        }
    }

    #[test]
    fn hybrid_first_push_race_never_loses_first_message() {
        // Many threads race to be the *first* sender; the double-checked
        // flag under the lock must ensure exactly one first-push and no
        // lost combines. Repeat to catch interleavings.
        for round in 0..200 {
            let slot: Arc<MsgSlot<u64>> = Arc::new(MsgSlot::new());
            let c = SumCombiner;
            Strategy::Hybrid.reset_slot(&slot, &c);
            shadow_sync();
            let handles: Vec<_> = (0..4)
                .map(|t| {
                    let slot = Arc::clone(&slot);
                    std::thread::spawn(move || {
                        Strategy::Hybrid.deliver(&slot, 10 + t + round % 3, &c);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            shadow_sync();
            let expected: u64 = (0..4).map(|t| 10 + t + round % 3).sum();
            assert_eq!(Strategy::Hybrid.collect(&slot, &c), Some(expected));
        }
    }
}
