//! Pluggable message-delivery planes.
//!
//! The paper's machinery (one combinable [`MsgSlot`] per vertex, merged
//! through a [`Strategy`]) assumes every algorithm's messages fold into a
//! single slot via a commutative combine. A large class of vertex-centric
//! workloads is **non-combinable** — label propagation needs the full
//! multiset of neighbour labels to take a mode, triangle counting needs
//! every candidate pair — and no combine operation can represent them in
//! one word. This module generalises delivery behind the existing API:
//!
//! - [`CombinedPlane`] — the default: the existing `MsgSlot` +
//!   `Strategy::{deliver, deliver_exclusive}` hybrid/lock/CAS machinery,
//!   untouched and bit-identical to the pre-plane engine. Programs
//!   receive the folded message as `compute`'s `msg` argument.
//! - [`LogPlane`] — per-vertex append-only message logs: each worker
//!   appends `(dst, msg)` pairs to its own segment buffer during the
//!   compute phase (contention-free — the log-plane analogue of the
//!   hybrid combiner's lock-free fast path), and the segments are merged
//!   into a CSR-shaped per-vertex log at the superstep barrier. Programs
//!   read the full multiset via `Context::recv()`.
//!
//! A program selects its plane with the [`VertexProgram::Delivery`]
//! associated type; the two selector types carry no data — the runtime
//! state of the log plane lives in a [`MessageLog`], built (and pooled,
//! epoch-stamped, like vertex stores) by the `GraphSession`.
//!
//! Log order is **unspecified** (it depends on worker scheduling), so
//! log-plane programs must fold `recv()` commutatively — the same
//! discipline combiners already impose, minus the requirement that the
//! fold compress into one message.
//!
//! [`MsgSlot`]: crate::combine::slot::MsgSlot
//! [`Strategy`]: crate::combine::strategy::Strategy
//! [`VertexProgram::Delivery`]: crate::engine::VertexProgram::Delivery

use crate::combine::slot::MessageValue;
use crate::graph::csr::VertexId;
use crate::layout::SyncCell;
use crate::util::CachePadded;

/// Type-level selector for a program's message-delivery plane.
///
/// Implemented by exactly two types — [`CombinedPlane`] and
/// [`LogPlane`] — and consumed by the engine as a compile-time constant,
/// so the combined path monomorphises to exactly the pre-plane code.
pub trait DeliveryPlane<M: MessageValue>: Send + Sync + 'static {
    /// Whether this plane retains messages individually (log plane)
    /// instead of folding them into one mailbox slot (combined plane).
    /// The engine's only plane dispatch; reporting uses
    /// [`DeliveryPlaneKind`](crate::metrics::DeliveryPlaneKind).
    const IS_LOG: bool;
}

/// The combined plane: one [`MsgSlot`](crate::combine::slot::MsgSlot)
/// per vertex, concurrent senders merged by the configured
/// [`Strategy`](crate::combine::strategy::Strategy) — the paper's §III
/// machinery, bit-identical to the engine before planes existed.
#[derive(Clone, Copy, Debug, Default)]
pub struct CombinedPlane;

impl<M: MessageValue> DeliveryPlane<M> for CombinedPlane {
    const IS_LOG: bool = false;
}

/// The log plane: per-vertex append-only message logs, populated through
/// per-worker segment buffers merged at the superstep barrier. Programs
/// receive the full message multiset via `Context::recv()`. Push mode
/// only (a pull-mode program publishes *one* outbox message per
/// superstep, which is the combined plane's shape by construction).
#[derive(Clone, Copy, Debug, Default)]
pub struct LogPlane;

impl<M: MessageValue> DeliveryPlane<M> for LogPlane {
    const IS_LOG: bool = true;
}

/// One worker's append segment: `(destination, message)` pairs in send
/// order. Written by exactly one worker during compute/flush, drained
/// single-threaded at the barrier — the same phase discipline the
/// partitioned engine's remote buffers use.
pub type Segment<M> = Vec<(VertexId, M)>;

/// Runtime state of the log plane for one run: per-worker segment
/// buffers plus the merged per-vertex logs of the current superstep,
/// stored CSR-style (one offsets array, one flat data array) so a
/// vertex's inbox is a contiguous `&[M]`.
///
/// Sessions pool one `MessageLog` per message type and re-prime it with
/// [`MessageLog::ensure_shape`] across runs (epoch-stamped like pooled
/// vertex stores); all slabs keep their capacity.
pub struct MessageLog<M: MessageValue> {
    /// Per-worker append buffers, padded so two workers' headers never
    /// share a cache line. Worker `tid` writes only `segments[tid]`.
    segments: Vec<CachePadded<SyncCell<Segment<M>>>>,
    /// `offsets[v]..offsets[v+1]` indexes `data` — the messages delivered
    /// to `v` last superstep (read by this superstep's compute).
    offsets: Vec<usize>,
    /// Flat message payloads of the current superstep.
    data: Vec<M>,
    /// Scratch for building the next epoch (swapped in at the barrier).
    next_offsets: Vec<usize>,
    next_data: Vec<M>,
    /// Per-vertex fill cursors reused across merges.
    cursors: Vec<usize>,
    /// Graph mutation epoch this log was last primed against (see
    /// `graph/dynamic.rs`; diagnostic only — the log is fully cleared at
    /// every checkout, so a stale tag never leaks state).
    epoch_tag: u64,
}

impl<M: MessageValue> MessageLog<M> {
    /// Empty log for `n` vertices and `workers` segment buffers.
    pub fn new(n: usize, workers: usize) -> Self {
        let mut log = MessageLog {
            segments: Vec::new(),
            offsets: vec![0; n + 1],
            data: Vec::new(),
            next_offsets: Vec::new(),
            next_data: Vec::new(),
            cursors: Vec::new(),
            epoch_tag: 0,
        };
        log.ensure_shape(n, workers);
        log
    }

    /// Re-prime for a fresh run: size to `n` vertices, guarantee at least
    /// `workers` segments, clear every segment and both epoch buffers —
    /// without shrinking any allocation. The post-state is
    /// indistinguishable from a fresh [`MessageLog::new`].
    pub fn ensure_shape(&mut self, n: usize, workers: usize) {
        let workers = workers.max(1);
        if self.segments.len() < workers {
            self.segments
                .resize_with(workers, || CachePadded::new(SyncCell::new(Vec::new())));
        }
        for seg in &mut self.segments {
            seg.get_mut().clear();
        }
        self.offsets.clear();
        self.offsets.resize(n + 1, 0);
        self.data.clear();
        self.next_offsets.clear();
        self.next_data.clear();
        self.cursors.clear();
    }

    /// Number of vertices this log is shaped for.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Worker segments available.
    #[inline]
    pub fn workers(&self) -> usize {
        self.segments.len()
    }

    /// Worker `tid`'s append segment. Compute/flush phases only: each
    /// worker writes its own segment exclusively (the interior
    /// mutability is sound under the engine's phase discipline).
    #[inline]
    pub fn seg(&self, tid: usize) -> &SyncCell<Segment<M>> {
        &self.segments[tid]
    }

    /// The messages delivered to `v` last superstep, in unspecified
    /// order. Empty when nothing arrived.
    #[inline]
    pub fn inbox(&self, v: VertexId) -> &[M] {
        let v = v as usize;
        &self.data[self.offsets[v]..self.offsets[v + 1]]
    }

    /// Messages currently buffered in worker segments (between a compute
    /// phase and its merge; diagnostic/test support).
    pub fn pending(&self) -> usize {
        self.segments.iter().map(|s| s.get().len()).sum()
    }

    /// Merge every worker segment into the per-vertex logs of the next
    /// superstep, clear the segments and swap epochs. Single-threaded
    /// barrier phase. Returns the number of messages merged.
    ///
    /// Deterministic given a deterministic vertex→worker assignment
    /// (worker order, then append order — mirroring
    /// `RemoteBuffers::drain_for`); FCFS schedules may permute the log,
    /// which is why `recv()` folds must be commutative.
    pub fn merge_segments(&mut self) -> u64 {
        let n = self.num_vertices();
        self.next_offsets.clear();
        self.next_offsets.resize(n + 1, 0);
        for seg in &self.segments {
            for &(dst, _) in seg.get().iter() {
                self.next_offsets[dst as usize + 1] += 1;
            }
        }
        for i in 0..n {
            self.next_offsets[i + 1] += self.next_offsets[i];
        }
        let total = self.next_offsets[n];
        self.cursors.clear();
        self.cursors.extend_from_slice(&self.next_offsets[..n]);
        self.next_data.clear();
        self.next_data.resize(total, M::from_bits(0));
        for seg in &self.segments {
            let buf = seg.get_mut();
            for &(dst, m) in buf.iter() {
                let c = &mut self.cursors[dst as usize];
                self.next_data[*c] = m;
                *c += 1;
            }
            buf.clear();
        }
        std::mem::swap(&mut self.offsets, &mut self.next_offsets);
        std::mem::swap(&mut self.data, &mut self.next_data);
        total as u64
    }

    /// The mutation epoch this log was last primed against.
    #[inline]
    pub fn epoch_tag(&self) -> u64 {
        self.epoch_tag
    }

    /// Stamp the log with the mutation epoch it is being primed for.
    pub fn set_epoch_tag(&mut self, epoch: u64) {
        self.epoch_tag = epoch;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plane_selectors_expose_their_kind() {
        assert!(!<CombinedPlane as DeliveryPlane<u64>>::IS_LOG);
        assert!(<LogPlane as DeliveryPlane<u64>>::IS_LOG);
    }

    #[test]
    fn merge_groups_messages_by_destination_in_worker_then_push_order() {
        let mut log: MessageLog<u64> = MessageLog::new(4, 3);
        log.seg(2).get_mut().push((1, 100));
        log.seg(0).get_mut().push((1, 101));
        log.seg(0).get_mut().push((3, 102));
        log.seg(0).get_mut().push((1, 103));
        log.seg(1).get_mut().push((0, 104));
        assert_eq!(log.pending(), 5);
        assert_eq!(log.merge_segments(), 5);
        assert_eq!(log.pending(), 0, "segments drained");
        assert_eq!(log.inbox(0), &[104]);
        assert_eq!(log.inbox(1), &[101, 103, 100], "worker order, then push order");
        assert_eq!(log.inbox(2), &[] as &[u64]);
        assert_eq!(log.inbox(3), &[102]);
    }

    #[test]
    fn merge_replaces_the_previous_epoch() {
        let mut log: MessageLog<u32> = MessageLog::new(2, 1);
        log.seg(0).get_mut().push((0, 7));
        log.merge_segments();
        assert_eq!(log.inbox(0), &[7]);
        // Next superstep sends nothing to 0 — its inbox must empty out.
        log.seg(0).get_mut().push((1, 9));
        assert_eq!(log.merge_segments(), 1);
        assert_eq!(log.inbox(0), &[] as &[u32]);
        assert_eq!(log.inbox(1), &[9]);
    }

    #[test]
    fn ensure_shape_resets_to_fresh_state_without_shrinking() {
        let mut log: MessageLog<u64> = MessageLog::new(3, 2);
        log.seg(1).get_mut().push((2, 5));
        log.merge_segments();
        log.seg(0).get_mut().push((0, 6));
        log.set_epoch_tag(4);
        log.ensure_shape(5, 4);
        assert_eq!(log.num_vertices(), 5);
        assert_eq!(log.workers(), 4);
        assert_eq!(log.pending(), 0);
        for v in 0..5 {
            assert_eq!(log.inbox(v), &[] as &[u64], "v{v}");
        }
        assert_eq!(log.epoch_tag(), 4, "epoch tag survives re-priming");
        // Shrinking the vertex count also works (pooled across graphs is
        // not a thing today — sessions are per-graph — but the shape
        // contract should not depend on growth only).
        log.ensure_shape(2, 1);
        assert_eq!(log.num_vertices(), 2);
        assert!(log.workers() >= 1);
    }

    #[test]
    fn float_messages_round_trip_through_the_log() {
        let mut log: MessageLog<f64> = MessageLog::new(2, 1);
        log.seg(0).get_mut().push((0, -0.0));
        log.seg(0).get_mut().push((0, 2.5));
        log.merge_segments();
        assert_eq!(log.inbox(0).len(), 2);
        assert_eq!(log.inbox(0)[0].to_bits(), (-0.0f64).to_bits());
        assert_eq!(log.inbox(0)[1], 2.5);
    }
}
