//! User-definable combine operations.
//!
//! A combiner merges two messages destined for the same vertex into one
//! (Pregel's message-reduction hook). It must be commutative and
//! associative — the engine combines in arbitrary interleavings.

/// A commutative, associative merge of two messages.
pub trait Combiner<M>: Send + Sync {
    /// Combine `a` and `b` into a single message.
    fn combine(&self, a: M, b: M) -> M;

    /// A neutral element, if one exists for this operation
    /// (`combine(n, x) == x`). Required by the pure-CAS strategy; the
    /// hybrid strategy works without one — that is precisely its point.
    fn neutral(&self) -> Option<M> {
        None
    }
}

/// Minimum (used by CC label propagation, SSSP distances, BFS levels).
#[derive(Clone, Copy, Debug, Default)]
pub struct MinCombiner;

/// Maximum.
#[derive(Clone, Copy, Debug, Default)]
pub struct MaxCombiner;

/// Sum (used by PageRank contributions).
#[derive(Clone, Copy, Debug, Default)]
pub struct SumCombiner;

macro_rules! impl_minmax {
    ($($t:ty => $max:expr, $min:expr);* $(;)?) => {$(
        impl Combiner<$t> for MinCombiner {
            #[inline]
            fn combine(&self, a: $t, b: $t) -> $t {
                if b < a { b } else { a }
            }
            fn neutral(&self) -> Option<$t> {
                Some($max)
            }
        }
        impl Combiner<$t> for MaxCombiner {
            #[inline]
            fn combine(&self, a: $t, b: $t) -> $t {
                if b > a { b } else { a }
            }
            fn neutral(&self) -> Option<$t> {
                Some($min)
            }
        }
    )*};
}

impl_minmax! {
    u32 => u32::MAX, u32::MIN;
    u64 => u64::MAX, u64::MIN;
    i32 => i32::MAX, i32::MIN;
    i64 => i64::MAX, i64::MIN;
    f32 => f32::INFINITY, f32::NEG_INFINITY;
    f64 => f64::INFINITY, f64::NEG_INFINITY;
}

macro_rules! impl_sum {
    ($($t:ty),*) => {$(
        impl Combiner<$t> for SumCombiner {
            #[inline]
            fn combine(&self, a: $t, b: $t) -> $t {
                a + b
            }
            fn neutral(&self) -> Option<$t> {
                Some(0 as $t)
            }
        }
    )*};
}

impl_sum!(u32, u64, i32, i64, f32, f64);

/// Placeholder combiner for log-plane programs.
///
/// [`LogPlane`](crate::combine::plane::LogPlane) delivery retains every
/// message individually, so the program's `Comb` type is never invoked —
/// but [`VertexProgram`](crate::engine::VertexProgram) still requires
/// one. `NullCombiner` fills the slot and panics if anything actually
/// calls it (which would mean a non-combinable program was run on the
/// combined plane — a programming error worth failing loudly on, since
/// silently folding a multiset algorithm's messages corrupts results).
#[derive(Clone, Copy, Debug, Default)]
pub struct NullCombiner;

impl<M: Copy + Send + Sync> Combiner<M> for NullCombiner {
    fn combine(&self, _a: M, _b: M) -> M {
        panic!(
            "NullCombiner cannot combine: it is the log-plane placeholder \
             (log delivery retains messages, it never folds them) — give \
             combined-plane programs a real combiner"
        )
    }
}

/// A combiner defined by a plain function, with optionally-declared
/// neutral element — this is the "user writes any arbitrary combination
/// operation" path the paper's hybrid design enables.
pub struct FnCombiner<M, F: Fn(M, M) -> M + Send + Sync> {
    f: F,
    neutral: Option<M>,
}

impl<M: Copy + Send + Sync, F: Fn(M, M) -> M + Send + Sync> FnCombiner<M, F> {
    /// Combiner from a closure, no neutral element declared.
    pub fn new(f: F) -> Self {
        FnCombiner { f, neutral: None }
    }

    /// Declare a neutral element (enables the pure-CAS strategy).
    pub fn with_neutral(mut self, n: M) -> Self {
        self.neutral = Some(n);
        self
    }
}

impl<M: Copy + Send + Sync, F: Fn(M, M) -> M + Send + Sync> Combiner<M> for FnCombiner<M, F> {
    #[inline]
    fn combine(&self, a: M, b: M) -> M {
        (self.f)(a, b)
    }

    fn neutral(&self) -> Option<M> {
        self.neutral
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_max_sum_basics() {
        assert_eq!(MinCombiner.combine(3u32, 5), 3);
        assert_eq!(MaxCombiner.combine(3u32, 5), 5);
        assert_eq!(SumCombiner.combine(3u32, 5), 8);
        assert_eq!(MinCombiner.combine(1.5f64, -2.0), -2.0);
        assert_eq!(SumCombiner.combine(1.5f32, 2.5), 4.0);
    }

    #[test]
    fn neutral_elements_are_neutral() {
        fn check<C: Combiner<u64>>(c: C, samples: &[u64]) {
            let n = c.neutral().unwrap();
            for &x in samples {
                assert_eq!(c.combine(n, x), x);
                assert_eq!(c.combine(x, n), x);
            }
        }
        check(MinCombiner, &[0, 1, u64::MAX, 42]);
        check(MaxCombiner, &[0, 1, u64::MAX, 42]);
        check(SumCombiner, &[0, 1, 1000]);
    }

    #[test]
    fn fn_combiner_wraps_closures() {
        let c = FnCombiner::new(|a: u32, b: u32| a ^ b).with_neutral(0);
        assert_eq!(c.combine(0b101, 0b011), 0b110);
        assert_eq!(c.neutral(), Some(0));
        let no_neutral = FnCombiner::new(|a: u32, b: u32| a.min(b) + 1);
        assert_eq!(no_neutral.neutral(), None);
    }
}
