//! User-definable combine operations.
//!
//! A combiner merges two messages destined for the same vertex into one
//! (Pregel's message-reduction hook). It must be commutative and
//! associative — the engine combines in arbitrary interleavings.

/// The handful of monoids the engine recognises *structurally*, enabling
/// reassociated (vector/unrolled) combining on the dense-bypass path
/// (DESIGN.md §2.9). Declaring a kind asserts the operation is **exactly**
/// associative and commutative over its message type — true for integer
/// min/max/sum (wrapping add is associative), false for float sums, which
/// is why the float `SumCombiner` impls decline to declare one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MonoidKind {
    /// `combine == min`, neutral is the type's maximum.
    Min,
    /// `combine == max`, neutral is the type's minimum.
    Max,
    /// `combine == +` (exact: integer or bitwise), neutral is zero.
    Sum,
}

/// A commutative, associative merge of two messages.
pub trait Combiner<M>: Send + Sync {
    /// Combine `a` and `b` into a single message.
    fn combine(&self, a: M, b: M) -> M;

    /// A neutral element, if one exists for this operation
    /// (`combine(n, x) == x`). Required by the pure-CAS strategy; the
    /// hybrid strategy works without one — that is precisely its point.
    fn neutral(&self) -> Option<M> {
        None
    }

    /// Declare this combiner an *exact* monoid of a known kind, licensing
    /// the engine to reassociate reductions (4-lane unrolled gather,
    /// SIMD slot ranges — see [`crate::combine::vector`]). Only return
    /// `Some` when `combine` is bit-exactly associative + commutative
    /// **and** `neutral()` is a two-sided identity; float sums must stay
    /// `None` or lane order changes the result bits.
    fn monoid_kind(&self) -> Option<MonoidKind> {
        None
    }
}

/// Minimum (used by CC label propagation, SSSP distances, BFS levels).
#[derive(Clone, Copy, Debug, Default)]
pub struct MinCombiner;

/// Maximum.
#[derive(Clone, Copy, Debug, Default)]
pub struct MaxCombiner;

/// Sum (used by PageRank contributions).
#[derive(Clone, Copy, Debug, Default)]
pub struct SumCombiner;

// The `$exact` flag marks types whose min/max/sum are *bit-exactly*
// associative: true for the integers, false for floats (min/max on floats
// are order-sensitive around NaN, and float sum reassociation changes
// result bits), so only the integer impls declare a `MonoidKind`.
macro_rules! impl_minmax {
    ($($t:ty => $max:expr, $min:expr, $exact:literal);* $(;)?) => {$(
        impl Combiner<$t> for MinCombiner {
            #[inline]
            fn combine(&self, a: $t, b: $t) -> $t {
                if b < a { b } else { a }
            }
            fn neutral(&self) -> Option<$t> {
                Some($max)
            }
            fn monoid_kind(&self) -> Option<MonoidKind> {
                if $exact { Some(MonoidKind::Min) } else { None }
            }
        }
        impl Combiner<$t> for MaxCombiner {
            #[inline]
            fn combine(&self, a: $t, b: $t) -> $t {
                if b > a { b } else { a }
            }
            fn neutral(&self) -> Option<$t> {
                Some($min)
            }
            fn monoid_kind(&self) -> Option<MonoidKind> {
                if $exact { Some(MonoidKind::Max) } else { None }
            }
        }
    )*};
}

impl_minmax! {
    u32 => u32::MAX, u32::MIN, true;
    u64 => u64::MAX, u64::MIN, true;
    i32 => i32::MAX, i32::MIN, true;
    i64 => i64::MAX, i64::MIN, true;
    f32 => f32::INFINITY, f32::NEG_INFINITY, false;
    f64 => f64::INFINITY, f64::NEG_INFINITY, false;
}

macro_rules! impl_sum {
    ($($t:ty => $exact:literal),* $(,)?) => {$(
        impl Combiner<$t> for SumCombiner {
            #[inline]
            fn combine(&self, a: $t, b: $t) -> $t {
                a + b
            }
            fn neutral(&self) -> Option<$t> {
                Some(0 as $t)
            }
            fn monoid_kind(&self) -> Option<MonoidKind> {
                if $exact { Some(MonoidKind::Sum) } else { None }
            }
        }
    )*};
}

impl_sum! {
    u32 => true,
    u64 => true,
    i32 => true,
    i64 => true,
    f32 => false,
    f64 => false,
}

/// Placeholder combiner for log-plane programs.
///
/// [`LogPlane`](crate::combine::plane::LogPlane) delivery retains every
/// message individually, so the program's `Comb` type is never invoked —
/// but [`VertexProgram`](crate::engine::VertexProgram) still requires
/// one. `NullCombiner` fills the slot and panics if anything actually
/// calls it (which would mean a non-combinable program was run on the
/// combined plane — a programming error worth failing loudly on, since
/// silently folding a multiset algorithm's messages corrupts results).
#[derive(Clone, Copy, Debug, Default)]
pub struct NullCombiner;

impl<M: Copy + Send + Sync> Combiner<M> for NullCombiner {
    fn combine(&self, _a: M, _b: M) -> M {
        panic!(
            "NullCombiner cannot combine: it is the log-plane placeholder \
             (log delivery retains messages, it never folds them) — give \
             combined-plane programs a real combiner"
        )
    }
}

/// A combiner defined by a plain function, with optionally-declared
/// neutral element — this is the "user writes any arbitrary combination
/// operation" path the paper's hybrid design enables.
pub struct FnCombiner<M, F: Fn(M, M) -> M + Send + Sync> {
    f: F,
    neutral: Option<M>,
    monoid: Option<MonoidKind>,
}

impl<M: Copy + Send + Sync, F: Fn(M, M) -> M + Send + Sync> FnCombiner<M, F> {
    /// Combiner from a closure, no neutral element declared.
    pub fn new(f: F) -> Self {
        FnCombiner {
            f,
            neutral: None,
            monoid: None,
        }
    }

    /// Declare a neutral element (enables the pure-CAS strategy).
    pub fn with_neutral(mut self, n: M) -> Self {
        self.neutral = Some(n);
        self
    }

    /// Declare the closure an exact monoid of `kind` (enables vector
    /// combining — see [`Combiner::monoid_kind`] for the contract the
    /// caller is vouching for).
    pub fn with_monoid(mut self, kind: MonoidKind) -> Self {
        self.monoid = Some(kind);
        self
    }
}

impl<M: Copy + Send + Sync, F: Fn(M, M) -> M + Send + Sync> Combiner<M> for FnCombiner<M, F> {
    #[inline]
    fn combine(&self, a: M, b: M) -> M {
        (self.f)(a, b)
    }

    fn neutral(&self) -> Option<M> {
        self.neutral
    }

    fn monoid_kind(&self) -> Option<MonoidKind> {
        self.monoid
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_max_sum_basics() {
        assert_eq!(MinCombiner.combine(3u32, 5), 3);
        assert_eq!(MaxCombiner.combine(3u32, 5), 5);
        assert_eq!(SumCombiner.combine(3u32, 5), 8);
        assert_eq!(MinCombiner.combine(1.5f64, -2.0), -2.0);
        assert_eq!(SumCombiner.combine(1.5f32, 2.5), 4.0);
    }

    #[test]
    fn neutral_elements_are_neutral() {
        fn check<C: Combiner<u64>>(c: C, samples: &[u64]) {
            let n = c.neutral().unwrap();
            for &x in samples {
                assert_eq!(c.combine(n, x), x);
                assert_eq!(c.combine(x, n), x);
            }
        }
        check(MinCombiner, &[0, 1, u64::MAX, 42]);
        check(MaxCombiner, &[0, 1, u64::MAX, 42]);
        check(SumCombiner, &[0, 1, 1000]);
    }

    #[test]
    fn fn_combiner_wraps_closures() {
        let c = FnCombiner::new(|a: u32, b: u32| a ^ b).with_neutral(0);
        assert_eq!(c.combine(0b101, 0b011), 0b110);
        assert_eq!(c.neutral(), Some(0));
        assert_eq!(c.monoid_kind(), None, "monoids are opt-in for closures");
        let no_neutral = FnCombiner::new(|a: u32, b: u32| a.min(b) + 1);
        assert_eq!(no_neutral.neutral(), None);
    }

    #[test]
    fn monoid_kinds_only_on_exact_impls() {
        assert_eq!(Combiner::<u64>::monoid_kind(&MinCombiner), Some(MonoidKind::Min));
        assert_eq!(Combiner::<u32>::monoid_kind(&MaxCombiner), Some(MonoidKind::Max));
        assert_eq!(Combiner::<i64>::monoid_kind(&SumCombiner), Some(MonoidKind::Sum));
        // Float reassociation changes bits: no monoid declared.
        assert_eq!(Combiner::<f64>::monoid_kind(&SumCombiner), None);
        assert_eq!(Combiner::<f32>::monoid_kind(&MinCombiner), None);
        // Closures opt in explicitly.
        let c = FnCombiner::new(|a: u64, b: u64| a.wrapping_add(b))
            .with_neutral(0)
            .with_monoid(MonoidKind::Sum);
        assert_eq!(c.monoid_kind(), Some(MonoidKind::Sum));
    }
}
