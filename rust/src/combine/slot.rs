//! The per-vertex mailbox slot and the bit-level message representation.
//!
//! CAS operations need the message in an atomic word, so every message
//! type is represented in a single `AtomicU64` via [`MessageValue`]
//! (floats through their IEEE bit patterns — bit equality is what CAS
//! compares, which also sidesteps NaN `!=` NaN surprises).

use crate::combine::spinlock::SpinLock;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

#[cfg(feature = "race-check")]
use crate::util::shadow::{self, Site};

/// Message types storable in a mailbox slot: plain-old-data with a
/// round-trippable 64-bit representation.
pub trait MessageValue: Copy + Send + Sync + 'static {
    /// Encode to the atomic word.
    fn to_bits(self) -> u64;
    /// Decode from the atomic word.
    fn from_bits(bits: u64) -> Self;
}

macro_rules! impl_int_msg {
    ($($t:ty),*) => {$(
        impl MessageValue for $t {
            #[inline]
            fn to_bits(self) -> u64 {
                self as u64
            }
            #[inline]
            fn from_bits(bits: u64) -> Self {
                bits as $t
            }
        }
    )*};
}

impl_int_msg!(u8, u16, u32, u64, usize);

impl MessageValue for i32 {
    #[inline]
    fn to_bits(self) -> u64 {
        self as u32 as u64
    }
    #[inline]
    fn from_bits(bits: u64) -> Self {
        bits as u32 as i32
    }
}

impl MessageValue for i64 {
    #[inline]
    fn to_bits(self) -> u64 {
        self as u64
    }
    #[inline]
    fn from_bits(bits: u64) -> Self {
        bits as i64
    }
}

impl MessageValue for f32 {
    #[inline]
    fn to_bits(self) -> u64 {
        f32::to_bits(self) as u64
    }
    #[inline]
    fn from_bits(bits: u64) -> Self {
        f32::from_bits(bits as u32)
    }
}

impl MessageValue for f64 {
    #[inline]
    fn to_bits(self) -> u64 {
        f64::to_bits(self)
    }
    #[inline]
    fn from_bits(bits: u64) -> Self {
        f64::from_bits(bits)
    }
}

/// One vertex's mailbox: the paper's `{lock, has_msg_next, msg_next}`
/// triple (Fig. 1), with the message held in an atomic word so both
/// lock-based and CAS-based strategies can operate on the same slot.
///
/// Field order keeps the flag and message adjacent — with the lock — in a
/// single 16-byte unit, so one cache line holds four slots when
/// externalised (§IV).
pub struct MsgSlot<M: MessageValue> {
    /// The pending message's bit pattern; meaningful only when `has_msg`.
    msg: AtomicU64,
    /// True once at least one message has been delivered this superstep.
    has_msg: AtomicBool,
    /// Per-vertex lock for the lock strategy and the hybrid first-push.
    lock: SpinLock,
    /// Last-accessor record for the logical race checker. The flag-ordered
    /// protocol ops (`has_msg`/`load_msg`/`cas_msg`) are deliberately NOT
    /// instrumented — concurrent use of those is the hybrid combiner
    /// working as designed; the checker guards the ops whose soundness
    /// rests on phase discipline or the slot's own lock.
    #[cfg(feature = "race-check")]
    shadow: shadow::ShadowCell,
    _marker: PhantomData<M>,
}

impl<M: MessageValue> Default for MsgSlot<M> {
    fn default() -> Self {
        Self::new()
    }
}

impl<M: MessageValue> MsgSlot<M> {
    /// Fresh, empty slot.
    pub fn new() -> Self {
        MsgSlot {
            msg: AtomicU64::new(0),
            has_msg: AtomicBool::new(false),
            lock: SpinLock::new(),
            #[cfg(feature = "race-check")]
            shadow: shadow::ShadowCell::new(),
            _marker: PhantomData,
        }
    }

    /// The slot's lock (strategies use it; nothing else should).
    #[inline]
    pub fn lock(&self) -> &SpinLock {
        &self.lock
    }

    /// Whether a message is pending. Paper Fig. 1 reads this flag with
    /// sequentially-consistent semantics (C11 `_Atomic` default).
    #[inline]
    pub fn has_msg(&self) -> bool {
        self.has_msg.load(Ordering::SeqCst)
    }

    /// Read the current message bits (caller must know `has_msg`).
    #[inline]
    pub fn load_msg(&self) -> M {
        M::from_bits(self.msg.load(Ordering::SeqCst))
    }

    /// Store the message **then** set the flag. The ordering of the two
    /// stores is the correctness crux of the hybrid combiner: a `true`
    /// flag guarantees the message value is visible (paper §III — the
    /// "full memory barrier in-between", here provided by SeqCst stores).
    #[inline]
    pub fn store_first(&self, msg: M) {
        #[cfg(feature = "race-check")]
        self.shadow
            .on_write(Site::SlotStoreFirst, self.lock.held_by_current_thread());
        self.msg.store(msg.to_bits(), Ordering::SeqCst);
        self.has_msg.store(true, Ordering::SeqCst);
    }

    /// Raw store of the message bits without touching the flag (used by
    /// the neutral-element CAS strategy, which has no flag).
    #[inline]
    pub fn store_msg(&self, msg: M) {
        #[cfg(feature = "race-check")]
        self.shadow
            .on_write(Site::SlotStoreMsg, self.lock.held_by_current_thread());
        self.msg.store(msg.to_bits(), Ordering::SeqCst);
    }

    /// One CAS attempt on the message word: succeed iff the slot still
    /// holds `expected`. On failure returns the observed bits.
    #[inline]
    pub fn cas_msg(&self, expected: M, new: M) -> Result<(), M> {
        match self.msg.compare_exchange(
            expected.to_bits(),
            new.to_bits(),
            Ordering::SeqCst,
            Ordering::SeqCst,
        ) {
            Ok(_) => Ok(()),
            Err(observed) => Err(M::from_bits(observed)),
        }
    }

    /// Take the message and reset the slot (superstep boundary; the
    /// engine guarantees no concurrent senders at this point).
    pub fn take(&self) -> Option<M> {
        #[cfg(feature = "race-check")]
        self.shadow
            .on_write(Site::SlotTake, self.lock.held_by_current_thread());
        if self.has_msg.load(Ordering::SeqCst) {
            let m = M::from_bits(self.msg.load(Ordering::SeqCst));
            self.has_msg.store(false, Ordering::SeqCst);
            Some(m)
        } else {
            None
        }
    }

    /// Non-destructive read (pull-based versions peek neighbours' slots).
    pub fn peek(&self) -> Option<M> {
        #[cfg(feature = "race-check")]
        self.shadow.on_read(Site::SlotPeek);
        if self.has_msg.load(Ordering::SeqCst) {
            Some(M::from_bits(self.msg.load(Ordering::SeqCst)))
        } else {
            None
        }
    }

    /// Relaxed-ordering peek for the pull-mode scan hot path.
    ///
    /// Sound only under the engine's superstep discipline: the slots
    /// scanned were written during the *previous* superstep, and the
    /// barrier between supersteps (thread join) establishes the
    /// happens-before edge, so no ordering is needed on the loads
    /// themselves. This is the §Perf L3 optimisation — SeqCst loads in
    /// the inner pull loop cost ~15% of PR's runtime (EXPERIMENTS.md).
    #[inline]
    pub fn peek_scan(&self) -> Option<M> {
        #[cfg(feature = "race-check")]
        self.shadow.on_read(Site::SlotPeekScan);
        if self.has_msg.load(Ordering::Relaxed) {
            Some(M::from_bits(self.msg.load(Ordering::Relaxed)))
        } else {
            None
        }
    }

    /// Reset without reading.
    pub fn clear(&self) {
        #[cfg(feature = "race-check")]
        self.shadow
            .on_write(Site::SlotClear, self.lock.held_by_current_thread());
        self.has_msg.store(false, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_roundtrips() {
        assert_eq!(u32::from_bits(42u32.to_bits()), 42);
        assert_eq!(u64::from_bits(u64::MAX.to_bits()), u64::MAX);
        assert_eq!(i32::from_bits((-7i32).to_bits()), -7);
        assert_eq!(i64::from_bits(i64::MIN.to_bits()), i64::MIN);
        assert_eq!(f32::from_bits(3.25f32.to_bits()), 3.25);
        assert_eq!(f64::from_bits((-0.0f64).to_bits()).to_bits(), (-0.0f64).to_bits());
        let nan = f64::from_bits(f64::NAN.to_bits());
        assert!(nan.is_nan());
    }

    #[test]
    fn i32_negative_does_not_sign_extend_into_junk() {
        // Round-trip must be exact even though the backing word is u64.
        for v in [-1i32, i32::MIN, i32::MAX, 0, 7] {
            assert_eq!(i32::from_bits(v.to_bits()), v);
        }
    }

    #[test]
    fn store_first_take_roundtrip() {
        let s: MsgSlot<f64> = MsgSlot::new();
        assert!(!s.has_msg());
        assert_eq!(s.take(), None);
        s.store_first(2.5);
        assert!(s.has_msg());
        assert_eq!(s.peek(), Some(2.5));
        assert_eq!(s.take(), Some(2.5));
        assert!(!s.has_msg());
        assert_eq!(s.take(), None);
    }

    #[test]
    fn cas_succeeds_only_on_expected() {
        let s: MsgSlot<u64> = MsgSlot::new();
        s.store_first(10);
        assert_eq!(s.cas_msg(10, 20), Ok(()));
        assert_eq!(s.cas_msg(10, 30), Err(20));
        assert_eq!(s.load_msg(), 20);
    }

    // The shadow record adds 8 bytes per slot, so the compactness
    // guarantee only holds in real (non-checker) builds.
    #[cfg(not(feature = "race-check"))]
    #[test]
    fn slot_is_compact() {
        // lock(1) + flag(1) + padding + msg(8) — must stay within 16 bytes
        // so four externalised slots share a cache line.
        assert!(std::mem::size_of::<MsgSlot<f64>>() <= 16);
    }
}
