//! Vectorised combining for known monoids (DESIGN.md §2.9).
//!
//! The scalar engine folds messages one at a time in source order. When
//! the combiner declares itself an exact [`MonoidKind`] (integer
//! min/max/sum — see [`Combiner::monoid_kind`] for the contract), the
//! fold may be *reassociated*: split across independent accumulator
//! lanes that the compiler can keep in registers (and, for contiguous
//! `u64` ranges, in SIMD registers), then merged once at the end. For an
//! exact monoid every association and commutation of the same multiset
//! yields bit-identical results, so this is a pure speed transform — the
//! bit-identity grid in `tests/test_scatter.rs` holds it to that.
//!
//! Two kernels:
//!
//! - [`reduce_gather`] — the engine's Pull-mode shape: values arrive
//!   through a gather closure (slot peeks down a CSR row), most of which
//!   may be empty. Four accumulator lanes, unrolled by four, absent
//!   values replaced by the neutral element (a two-sided identity, so
//!   substitution does not change the fold).
//! - [`reduce_slice_u64`] — contiguous `u64` ranges (degree/weight sums,
//!   dense slot ranges). Same four-lane shape; on `x86_64` the Sum case
//!   additionally uses baseline SSE2 (`_mm_add_epi64`), behind
//!   `cfg(target_arch)` with a bit-identical scalar fallback everywhere
//!   else — integer lane sums commute exactly.

use crate::combine::combiner::{Combiner, MonoidKind};

/// Accumulator lanes in the unrolled reduction loops. Four `u64`s fill
/// one cache line half / one SSE2 pair per two lanes; wide enough to
/// hide combine latency, narrow enough to stay in registers on every
/// target.
pub const LANES: usize = 4;

/// Fewer gathered values than this and lane setup costs more than it
/// saves; the engine's Pull path falls back to the scalar fold below it.
pub const VECTOR_GATHER_MIN: usize = 8;

/// Reduce `get(0..n)` through `comb` across [`LANES`] accumulator lanes.
///
/// `neutral` **must** be a two-sided identity of `comb` (the caller has
/// already checked `comb.monoid_kind().is_some()` and unwrapped
/// `comb.neutral()`), so empty gather positions fold in as no-ops.
/// Returns the folded value (`None` when every position was empty) and
/// the number of non-empty positions.
///
/// The end-merge is the fixed tree `((a0·a1)·(a2·a3))`; for an exact
/// monoid the whole reduction is bit-identical to the sequential
/// left-fold the scalar path performs.
#[inline]
pub fn reduce_gather<M, C, G>(n: usize, comb: &C, neutral: M, mut get: G) -> (Option<M>, u64)
where
    M: Copy,
    C: Combiner<M> + ?Sized,
    G: FnMut(usize) -> Option<M>,
{
    let mut acc = [neutral; LANES];
    let mut found = 0u64;
    let mut i = 0;
    while i + LANES <= n {
        // Manually unrolled: the four lanes carry independent dependency
        // chains, so the loads (slot peeks) overlap instead of
        // serialising behind one accumulator.
        for lane in 0..LANES {
            if let Some(m) = get(i + lane) {
                acc[lane] = comb.combine(acc[lane], m);
                found += 1;
            }
        }
        i += LANES;
    }
    while i < n {
        if let Some(m) = get(i) {
            acc[i % LANES] = comb.combine(acc[i % LANES], m);
            found += 1;
        }
        i += 1;
    }
    if found == 0 {
        return (None, 0);
    }
    let lo = comb.combine(acc[0], acc[1]);
    let hi = comb.combine(acc[2], acc[3]);
    (Some(comb.combine(lo, hi)), found)
}

#[inline]
fn scalar_kind(kind: MonoidKind, a: u64, b: u64) -> u64 {
    match kind {
        MonoidKind::Min => a.min(b),
        MonoidKind::Max => a.max(b),
        MonoidKind::Sum => a.wrapping_add(b),
    }
}

fn neutral_kind(kind: MonoidKind) -> u64 {
    match kind {
        MonoidKind::Min => u64::MAX,
        MonoidKind::Max => u64::MIN,
        MonoidKind::Sum => 0,
    }
}

/// Reduce a contiguous `u64` slice under `kind`. Returns the neutral
/// element for an empty slice.
///
/// Sum on `x86_64` runs through SSE2 `_mm_add_epi64` (baseline for the
/// target, no feature detection needed); min/max have no unsigned-64
/// SIMD instruction before AVX-512, so they take the four-lane scalar
/// unroll everywhere. Wrapping integer addition is exactly associative
/// and commutative, so every path returns identical bits.
pub fn reduce_slice_u64(xs: &[u64], kind: MonoidKind) -> u64 {
    #[cfg(target_arch = "x86_64")]
    if kind == MonoidKind::Sum && xs.len() >= 2 * LANES {
        return sum_slice_sse2(xs);
    }
    let neutral = neutral_kind(kind);
    let mut acc = [neutral; LANES];
    let mut chunks = xs.chunks_exact(LANES);
    for c in &mut chunks {
        for lane in 0..LANES {
            acc[lane] = scalar_kind(kind, acc[lane], c[lane]);
        }
    }
    let mut out = scalar_kind(
        kind,
        scalar_kind(kind, acc[0], acc[1]),
        scalar_kind(kind, acc[2], acc[3]),
    );
    for &x in chunks.remainder() {
        out = scalar_kind(kind, out, x);
    }
    out
}

/// SSE2 wrapping sum of a `u64` slice (callers guarantee
/// `len >= 2 * LANES`).
#[cfg(target_arch = "x86_64")]
fn sum_slice_sse2(xs: &[u64]) -> u64 {
    use core::arch::x86_64::{__m128i, _mm_add_epi64, _mm_loadu_si128, _mm_setzero_si128};
    let mut chunks = xs.chunks_exact(LANES);
    // SAFETY: `_mm_setzero_si128`/`_mm_add_epi64`/`_mm_loadu_si128` are
    // SSE2, part of the x86_64 baseline, so calling them needs no runtime
    // feature check; every `_mm_loadu_si128` reads 16 bytes from inside a
    // `chunks_exact(4)` block of the `u64` slice (32 bytes, properly
    // initialised), and the unaligned-load intrinsic has no alignment
    // requirement.
    unsafe {
        let mut v0: __m128i = _mm_setzero_si128();
        let mut v1: __m128i = _mm_setzero_si128();
        for c in &mut chunks {
            v0 = _mm_add_epi64(v0, _mm_loadu_si128(c.as_ptr() as *const __m128i));
            v1 = _mm_add_epi64(v1, _mm_loadu_si128(c.as_ptr().add(2) as *const __m128i));
        }
        let v = _mm_add_epi64(v0, v1);
        let mut lanes = [0u64; 2];
        core::ptr::copy_nonoverlapping(&v as *const __m128i as *const u64, lanes.as_mut_ptr(), 2);
        let mut out = lanes[0].wrapping_add(lanes[1]);
        for &x in chunks.remainder() {
            out = out.wrapping_add(x);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::combine::combiner::{MinCombiner, SumCombiner};
    use crate::util::quick;

    #[test]
    fn gather_matches_sequential_fold() {
        // Sparse gather: ~half the positions empty.
        let vals: Vec<Option<u64>> = (0..100u64)
            .map(|i| if i % 3 == 0 { None } else { Some(i * 17) })
            .collect();
        let (got, n) = reduce_gather(vals.len(), &MinCombiner, u64::MAX, |i| vals[i]);
        let seq = vals.iter().flatten().fold(None, |a: Option<u64>, &b| {
            Some(a.map_or(b, |a| MinCombiner.combine(a, b)))
        });
        assert_eq!(got, seq);
        assert_eq!(n, vals.iter().flatten().count() as u64);
    }

    #[test]
    fn gather_of_all_empty_is_none() {
        let (got, n) = reduce_gather(64, &SumCombiner, 0u64, |_| None::<u64>);
        assert_eq!(got, None);
        assert_eq!(n, 0);
    }

    #[test]
    fn gather_handles_short_and_ragged_lengths() {
        for n in 0..20usize {
            let vals: Vec<u64> = (0..n as u64).map(|i| i + 1).collect();
            let (got, cnt) = reduce_gather(n, &SumCombiner, 0u64, |i| Some(vals[i]));
            let want: u64 = vals.iter().sum();
            assert_eq!(cnt as usize, n);
            assert_eq!(got, if n == 0 { None } else { Some(want) }, "n={n}");
        }
    }

    #[test]
    fn slice_kernels_match_sequential_for_all_kinds() {
        quick::check("vector slice reduce", |rng| {
            let n = rng.below(300) as usize;
            let xs: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            for kind in [MonoidKind::Min, MonoidKind::Max, MonoidKind::Sum] {
                let want = xs
                    .iter()
                    .fold(neutral_kind(kind), |a, &b| scalar_kind(kind, a, b));
                let got = reduce_slice_u64(&xs, kind);
                if got != want {
                    return Err(format!("{kind:?} over {n} items: {got} != {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn empty_slice_reduces_to_neutral() {
        assert_eq!(reduce_slice_u64(&[], MonoidKind::Min), u64::MAX);
        assert_eq!(reduce_slice_u64(&[], MonoidKind::Max), 0);
        assert_eq!(reduce_slice_u64(&[], MonoidKind::Sum), 0);
    }
}
