//! `ipregel` — the command-line launcher.
//!
//! Subcommands:
//!
//! ```text
//! ipregel generate  [--tiny] [--dir data/graphs]          generate + cache catalog graphs
//! ipregel info      <graph|name> [--dir …]                degree stats + histogram
//! ipregel run       --algo pr|cc|sssp|wsssp|bfs|lpa|triangles <graph|name>
//!                   real engine run (GraphSession)
//!                   [--threads N] [--schedule S] [--strategy S]
//!                   [--layout aos|soa] [--bypass] [--shards none|K|cache[:bytes]]
//!                   [--steal]  work-stealing shard execution: drained
//!                              workers claim shards from the most-loaded
//!                              peer during scatter and flush
//!                   [--pipeline-depth N]  prefetch N vertices ahead in
//!                              the scatter/gather hot loops (0 = auto)
//!                   [--adaptive]  re-decide schedule/strategy/bypass each
//!                                 superstep from live signals (prints the
//!                                 per-switch decision trace)
//!                   [--trace-summary]  per-superstep phase/skew histogram
//!                              rendering of the observability plane
//!                   [--trace-out FILE]  write the run's Chrome trace-event
//!                              JSON (load in Perfetto / chrome://tracing)
//!                   [--compress | --oocore FILE]  row-storage plane
//!                              (§2.12): sorted rows as delta-gap varint
//!                              blocks decoded per shard on demand, or
//!                              file-streamed out-of-core blocks with only
//!                              the working set resident between barriers
//!                   [--block-size N]       vertices per row block (1024)
//!                   [--resident-blocks N]  oocore: LRU-evict down to N
//!                              READY blocks at each barrier
//!                   [--cold-rounds N]      compress: recycle a decoded
//!                              block after N untouched barriers
//!                   [--iterations N] [--source V] [--rounds R]
//!                   (lpa and triangles are log-plane programs: full
//!                    message multisets, no combiner — see DESIGN.md §2.6)
//!                   [--mutate-batch N [--mutate-rounds R] [--mutate-seed S]]
//!                     stream N-edge mutation batches through a DynamicGraph
//!                     session and recompute incrementally (pr|cc|wsssp)
//! ipregel sim       (same switches)                       virtual-testbed run (32 vthreads)
//! ipregel table1    [--tiny] [--dir …]                    reproduce paper Table I
//! ipregel table2    [--tiny] [--dir …] [--bench pr,cc,sssp] [--threads 32]
//! ipregel calibrate                                        measure cost-model constants
//! ipregel accel     --algo pr|cc|sssp <graph|name>        PJRT dense-block backend
//! ipregel audit     [--root DIR] [--manifest FILE]        pallas-audit: static
//!                   concurrency-correctness pass over this repo's own source
//!                   (SAFETY coverage, ordering manifest, static-mut ban,
//!                    hot-path panic ban); non-zero exit on violations
//! ipregel serve     <graph|name>  multi-tenant serving demo: a seeded
//!                   stream of bounded interactive queries (ego-net BFS /
//!                   point SSSP) served twice — idle, then alongside a
//!                   concurrent batch PageRank — printing per-phase
//!                   p50/p99 latency, throughput and pool-reuse counters
//!                   [--queries N] [--concurrency K] [--seed S]
//!                   [--radius R] [--iterations N]  batch PageRank length
//!                   [--mutate-batch N]  end with a snapshot-isolation
//!                     demo: pin, mutate, time-travel read vs current
//!                   (engine switches as for `run`)
//! ```
//!
//! Graphs are referenced by catalog name (`dblp-s`, `friendster-t`, …) or
//! by path (`.ipg` binary / edge-list text).

use ipregel::algos::{Bfs, ConnectedComponents, Lpa, PageRank, Sssp, Triangles, WeightedSssp};
use ipregel::combine::Strategy;
use ipregel::config::Opts;
use ipregel::engine::{EngineConfig, GraphSession, Partitioning, VertexProgram};
use ipregel::exp::{run_table1, table2, Bench, Table2Options};
use ipregel::graph::csr::Csr;
use ipregel::graph::{catalog, io, stats};
use ipregel::layout::Layout;
use ipregel::metrics::RunMetrics;
use ipregel::sched::Schedule;
use ipregel::sim::{calibrate, SimEngine};
use ipregel::util::error::{Context, Result};
use ipregel::util::timer::fmt_duration;
use ipregel::{bail, err};
use std::path::{Path, PathBuf};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: Vec<String>) -> Result<()> {
    let opts = Opts::parse(args);
    let cmd = opts
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    match cmd {
        "generate" => cmd_generate(&opts),
        "info" => cmd_info(&opts),
        "run" => cmd_run(&opts, false),
        "sim" => cmd_run(&opts, true),
        "table1" => cmd_table1(&opts),
        "table2" => cmd_table2(&opts),
        "calibrate" => cmd_calibrate(&opts),
        "accel" => cmd_accel(&opts),
        "audit" => cmd_audit(&opts),
        "serve" => cmd_serve(&opts),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' — try `ipregel help`"),
    }
}

const HELP: &str = "ipregel — vertex-centric graph processing (iPregel reproduction)\n\
  generate | info | run | sim | table1 | table2 | calibrate | accel | audit | serve | help\n\
  See README.md for full usage.";

fn graph_dir(opts: &Opts) -> PathBuf {
    PathBuf::from(opts.get_or("dir", "data/graphs"))
}

/// Resolve a graph argument: catalog name or file path.
fn load_graph(arg: &str, dir: &Path) -> Result<Csr> {
    if let Some(entry) = catalog::find(arg) {
        return entry.load_or_generate(dir);
    }
    let p = Path::new(arg);
    if p.exists() {
        return io::load(p, false);
    }
    bail!(
        "'{arg}' is neither a catalog name (e.g. dblp-s, friendster-t) \
         nor an existing file"
    )
}

fn cmd_generate(opts: &Opts) -> Result<()> {
    opts.ensure_known(&["tiny", "dir"])?;
    let dir = graph_dir(opts);
    let entries = if opts.flag("tiny") {
        catalog::catalog_tiny()
    } else {
        catalog::catalog()
    };
    for e in &entries {
        let t = ipregel::util::timer::Timer::start();
        let g = e.load_or_generate(&dir)?;
        println!(
            "{:<16} |V|={:<10} directed |E|={:<13} ({})",
            e.name,
            g.num_vertices(),
            g.num_edges(),
            fmt_duration(t.elapsed())
        );
    }
    println!("cached under {}", dir.display());
    Ok(())
}

fn cmd_info(opts: &Opts) -> Result<()> {
    opts.ensure_known(&["dir"])?;
    let arg = opts
        .positional
        .get(1)
        .ok_or_else(|| err!("usage: ipregel info <graph|name>"))?;
    let g = load_graph(arg, &graph_dir(opts))?;
    let s = stats::degree_stats(&g);
    println!("{s:#?}");
    println!("{}", stats::render_histogram(&stats::degree_histogram(&g)));
    Ok(())
}

fn engine_cfg(opts: &Opts) -> Result<EngineConfig> {
    let schedule = Schedule::parse(&opts.get_or("schedule", "static"))
        .ok_or_else(|| err!("--schedule: static|dynamic[:chunk]|guided[:min]|edge-centric"))?;
    let strategy = Strategy::parse(&opts.get_or("strategy", "lock"))
        .ok_or_else(|| err!("--strategy: lock|cas|hybrid"))?;
    let layout = Layout::parse(&opts.get_or("layout", "aos"))
        .ok_or_else(|| err!("--layout: aos|soa"))?;
    let partitioning = Partitioning::parse(&opts.get_or("shards", "none"))
        .ok_or_else(|| err!("--shards: none|<count>|cache[:bytes]"))?;
    Ok(EngineConfig::default()
        .threads(opts.get_num("threads", 4usize)?)
        .schedule(schedule)
        .strategy(strategy)
        .layout(layout)
        .bypass(opts.flag("bypass"))
        .partitioning(partitioning)
        .steal(opts.flag("steal"))
        .pipeline_depth(opts.get_num("pipeline-depth", 0usize)?)
        .adaptive(opts.flag("adaptive"))
        .trace(opts.flag("trace-summary") || opts.get("trace-out").is_some())
        .max_supersteps(opts.get_num("max-supersteps", 100_000usize)?))
}

const RUN_FLAGS: &[&str] = &[
    "algo", "threads", "schedule", "strategy", "layout", "bypass", "shards", "adaptive",
    "steal", "pipeline-depth", "iterations", "source", "rounds", "max-supersteps", "dir",
    "mutate-batch", "mutate-rounds", "mutate-seed", "trace-summary", "trace-out",
    "compress", "oocore", "block-size", "resident-blocks", "cold-rounds",
];

/// `--compress` / `--oocore FILE` (+ `--block-size N`,
/// `--resident-blocks N`, `--cold-rounds N`): move the loaded graph's
/// rows onto the requested storage plane before the run — delta-gap
/// varint blocks decoded on demand (compress) or file-streamed blocks
/// with only the working set resident (oocore). See DESIGN.md §2.12.
fn apply_row_backing(g: Csr, opts: &Opts) -> Result<Csr> {
    use ipregel::graph::RowPolicy;
    let compress = opts.flag("compress");
    let oocore = opts.get("oocore").map(PathBuf::from);
    let policy = RowPolicy {
        resident_blocks: opts
            .get("resident-blocks")
            .map(|s| s.parse().map_err(|_| err!("--resident-blocks: bad '{s}'")))
            .transpose()?,
        cold_rounds: opts
            .get("cold-rounds")
            .map(|s| s.parse().map_err(|_| err!("--cold-rounds: bad '{s}'")))
            .transpose()?,
    };
    if !compress && oocore.is_none() {
        if policy != RowPolicy::default() {
            bail!("--resident-blocks/--cold-rounds need --compress or --oocore");
        }
        return Ok(g);
    }
    if compress && oocore.is_some() {
        bail!("--compress and --oocore are exclusive row backings");
    }
    let block = opts.get_num("block-size", 1024usize)?;
    if block == 0 {
        bail!("--block-size must be positive");
    }
    let raw_bytes = g.memory_bytes();
    let g = match &oocore {
        Some(path) => io::externalize(&g, path, block)?,
        None => g.compress(block),
    };
    let plane = g.row_plane().expect("backing just installed");
    if policy != RowPolicy::default() {
        plane.set_policy(policy);
    }
    eprintln!(
        "rows: {:?} backing, {} blocks of {} vertices, {:.2}x compression \
         ({} -> {} bytes resident)",
        plane.mode(),
        plane.num_blocks(),
        plane.block_size(),
        plane.stats().compression_ratio(),
        raw_bytes,
        g.memory_bytes(),
    );
    Ok(g)
}

/// `--trace-summary` / `--trace-out FILE`, resolved once per `run`/`sim`.
struct TraceSinks<'a> {
    summary: bool,
    out: Option<&'a Path>,
}

/// Render/write a finished [`ipregel::trace::RunTrace`] to the requested
/// sinks. A `None` trace with sinks requested means the binary was built
/// with `--features no-trace`; say so instead of silently dropping it.
fn emit_trace(trace: Option<&ipregel::trace::RunTrace>, sinks: &TraceSinks<'_>) -> Result<()> {
    let Some(tr) = trace else {
        if sinks.summary || sinks.out.is_some() {
            eprintln!("trace: no events recorded (built with --features no-trace?)");
        }
        return Ok(());
    };
    if sinks.summary {
        print!("{}", ipregel::trace::render_summary(tr, 5));
    }
    if let Some(path) = sinks.out {
        std::fs::write(path, ipregel::trace::chrome_trace_json(tr))
            .with_context(|| format!("writing trace to {}", path.display()))?;
        eprintln!(
            "trace: wrote {} events to {} (load in Perfetto / chrome://tracing)",
            tr.events.len(),
            path.display()
        );
    }
    Ok(())
}

fn print_run(label: &str, metrics: &RunMetrics) {
    println!("{label}: {}", metrics.summary());
    if metrics.adaptive {
        print_tuner_trace(&metrics.tuner_decisions);
    }
}

/// Compact per-switch trace of an adaptive run: one line per superstep
/// whose knob plan changed, with the signals that drove the choice.
fn print_tuner_trace(decisions: &[ipregel::metrics::TunerDecision]) {
    for d in decisions.iter().filter(|d| d.switched || d.superstep == 0) {
        println!(
            "  tuner s{}: {:?} / {:?} / {} / depth {} chunk {} (density {:.3}, \
             msgs/active {:.1}, fan-in {:.2}, contention {:.4}, flush-imb {:.2}, \
             steals {}, lanes {:.2})",
            d.superstep,
            d.schedule,
            d.strategy,
            if d.bypass { "list" } else { "scan" },
            d.pipeline_depth,
            d.steal_chunk,
            d.frontier_density,
            d.msgs_per_active,
            d.fan_in,
            d.contention_per_msg,
            d.flush_imbalance,
            d.steals,
            d.lane_utilisation,
        );
    }
}

fn cmd_run(opts: &Opts, simulated: bool) -> Result<()> {
    opts.ensure_known(RUN_FLAGS)?;
    let arg = opts
        .positional
        .get(1)
        .ok_or_else(|| {
            err!("usage: ipregel run --algo pr|cc|sssp|wsssp|bfs|lpa|triangles <graph|name>")
        })?;
    let g = apply_row_backing(load_graph(arg, &graph_dir(opts))?, opts)?;
    let cfg = engine_cfg(opts)?;
    let algo = opts.get_or("algo", "pr");

    let trace_out = opts.get("trace-out").map(PathBuf::from);
    let sinks = TraceSinks {
        summary: opts.flag("trace-summary"),
        out: trace_out.as_deref(),
    };

    if opts.get("mutate-batch").is_some() {
        if simulated {
            bail!("--mutate-batch drives the real engine; drop `sim`");
        }
        if sinks.summary || sinks.out.is_some() {
            bail!("--trace-summary/--trace-out cover single runs; drop --mutate-batch");
        }
        let source = match opts.get("source") {
            Some(s) => Some(
                s.parse()
                    .map_err(|_| err!("--source: cannot parse '{s}'"))?,
            ),
            None => None,
        };
        return cmd_run_dynamic(DynamicRunOpts {
            g,
            cfg,
            algo: &algo,
            batch: opts.get_num("mutate-batch", 16usize)?,
            rounds: opts.get_num("mutate-rounds", 4usize)?,
            seed: opts.get_num("mutate-seed", 42u64)?,
            source,
            pr_iterations: opts.get_num("iterations", 300usize)?,
        });
    }

    fn go<P: VertexProgram>(
        g: &Csr,
        p: &P,
        cfg: EngineConfig,
        simulated: bool,
        label: &str,
        sinks: &TraceSinks<'_>,
        show: impl Fn(&[P::Value]),
    ) -> Result<()> {
        if simulated {
            let r = SimEngine::new(g, p, cfg).run();
            println!(
                "{label} [virtual {} threads]: {:.6} virtual s, {} supersteps, {} messages, \
                 imbalance {:.2} (simulated in {})",
                cfg.threads,
                r.virtual_seconds,
                r.supersteps,
                r.messages,
                r.mean_imbalance,
                fmt_duration(r.wall)
            );
            if !r.decisions.is_empty() {
                print_tuner_trace(&r.decisions);
            }
            emit_trace(r.trace.as_ref(), sinks)?;
            show(&r.values);
        } else {
            let r = GraphSession::with_config(g, cfg).run(p);
            print_run(label, &r.metrics);
            emit_trace(r.metrics.trace.as_ref(), sinks)?;
            show(&r.values);
        }
        Ok(())
    }

    match algo.as_str() {
        "pr" | "pagerank" => {
            let p = PageRank {
                iterations: opts.get_num("iterations", 10usize)?,
                damping: 0.85,
            };
            go(&g, &p, cfg, simulated, "pagerank", &sinks, |vals| {
                let mut idx: Vec<usize> = (0..vals.len()).collect();
                idx.sort_by(|&a, &b| vals[b].partial_cmp(&vals[a]).unwrap());
                let top: Vec<String> = idx
                    .iter()
                    .take(5)
                    .map(|&v| format!("v{v}={:.3e}", vals[v]))
                    .collect();
                println!("  top ranks: {}", top.join(" "));
            })?;
        }
        "cc" => {
            go(&g, &ConnectedComponents, cfg, simulated, "cc", &sinks, |vals| {
                let mut labels = vals.to_vec();
                labels.sort_unstable();
                labels.dedup();
                println!("  components: {}", labels.len());
            })?;
        }
        "sssp" => {
            let source = opts.get_num("source", g.max_out_degree_vertex())?;
            let p = Sssp { source };
            go(&g, &p, cfg, simulated, "sssp", &sinks, |vals| {
                let reached = vals.iter().filter(|&&d| d != u64::MAX).count();
                let ecc = vals
                    .iter()
                    .filter(|&&d| d != u64::MAX)
                    .max()
                    .copied()
                    .unwrap_or(0);
                println!("  reached {reached} vertices, eccentricity {ecc}");
            })?;
        }
        "bfs" => {
            let root = opts.get_num("source", g.max_out_degree_vertex())?;
            let p = Bfs { root };
            go(&g, &p, cfg, simulated, "bfs", &sinks, |vals| {
                let reached = vals.iter().filter(|s| s.level != u32::MAX).count();
                println!("  reached {reached} vertices");
            })?;
        }
        "wsssp" | "weighted-sssp" => {
            let source = opts.get_num("source", g.max_out_degree_vertex())?;
            let p = WeightedSssp { source };
            go(&g, &p, cfg, simulated, "weighted-sssp", &sinks, |vals| {
                let reached = vals.iter().filter(|d| d.is_finite()).count();
                let ecc = vals
                    .iter()
                    .filter(|d| d.is_finite())
                    .fold(0.0f64, |a, &b| a.max(b));
                println!("  reached {reached} vertices, weighted eccentricity {ecc:.3}");
            })?;
        }
        "lpa" | "label-propagation" => {
            let p = Lpa {
                rounds: opts.get_num("rounds", Lpa::default().rounds)?,
            };
            go(&g, &p, cfg, simulated, "lpa", &sinks, |vals| {
                let mut labels = vals.to_vec();
                labels.sort_unstable();
                labels.dedup();
                println!("  communities: {}", labels.len());
            })?;
        }
        "triangles" | "tc" => {
            // Triangles requires a simple undirected graph; catalog
            // generators emit parallel edges, and duplicates would
            // multiply wedge messages and credits. Rebuild the simple
            // symmetric closure first (same as the test harness does).
            let edges: Vec<(u32, u32)> = g.edges().collect();
            let g = apply_row_backing(
                ipregel::graph::GraphBuilder::new(g.num_vertices())
                    .symmetric(true)
                    .dedup(true)
                    .drop_self_loops(true)
                    .edges(&edges)
                    .build(),
                opts,
            )?;
            eprintln!(
                "triangles: counting on the simple symmetric closure \
                 (|E|={} directed edges)",
                g.num_edges()
            );
            go(&g, &Triangles, cfg, simulated, "triangles", &sinks, |vals| {
                let corners: u64 = vals.iter().sum();
                let peak = vals.iter().enumerate().max_by_key(|(_, &c)| c);
                println!(
                    "  triangles: {} (max v{} with {})",
                    corners / 3,
                    peak.map(|(v, _)| v).unwrap_or(0),
                    peak.map(|(_, &c)| c).unwrap_or(0)
                );
            })?;
        }
        other => bail!("--algo {other}: expected pr|cc|sssp|wsssp|bfs|lpa|triangles"),
    }
    Ok(())
}

/// Inputs of [`cmd_run_dynamic`], bundled (source/iterations come from
/// the same `run` flags the static path honors).
struct DynamicRunOpts<'a> {
    g: Csr,
    cfg: EngineConfig,
    algo: &'a str,
    batch: usize,
    rounds: usize,
    seed: u64,
    /// `--source` for wsssp; defaults to the max-out-degree hub.
    source: Option<u32>,
    /// `--iterations` caps DeltaPageRank's rank-update supersteps.
    pr_iterations: usize,
}

/// `run --mutate-batch N [--mutate-rounds R] [--mutate-seed S]`: wrap
/// the graph in a [`DynamicGraph`] session, run the algorithm cold once,
/// then stream `R` random mutation batches of `N` undirected edges and
/// recompute **incrementally** after each (warm start seeded from the
/// mutated vertices), printing incremental-vs-cold supersteps, delta
/// occupancy and compactions per round.
fn cmd_run_dynamic(run: DynamicRunOpts<'_>) -> Result<()> {
    use ipregel::algos::incremental::{
        delta_pagerank_halt, incremental_cc, incremental_pagerank, incremental_sssp,
        DeltaPageRank, IncrementalState,
    };
    use ipregel::engine::{Halt, RunOptions};
    use ipregel::graph::dynamic::{DynamicGraph, MutationSet};
    use ipregel::util::rng::Rng;

    let DynamicRunOpts {
        g,
        cfg,
        algo,
        batch,
        rounds,
        seed,
        source,
        pr_iterations,
    } = run;
    let weighted = g.has_weights();
    let n = g.num_vertices();
    if n < 2 {
        bail!("--mutate-batch needs at least 2 vertices to stage edges (graph has {n})");
    }
    let mut session = GraphSession::dynamic_with_config(DynamicGraph::new(g), cfg);
    let mut rng = Rng::new(seed);
    let mut random_batch = |weighted_inserts: bool| {
        let mut m = MutationSet::new();
        while m.inserts().len() < 2 * batch.max(1) {
            let s = rng.below(n as u64) as u32;
            let d = rng.below(n as u64) as u32;
            if s == d {
                continue;
            }
            if weighted_inserts {
                let w = 0.25 + (rng.below(1000) as f64) / 250.0;
                m.insert_weighted(s, d, w);
                m.insert_weighted(d, s, w);
            } else {
                m.insert_undirected(s, d);
            }
        }
        m
    };
    fn report(label: &str, round: usize, m: &RunMetrics) {
        println!("  round {round} {label}: {}", m.summary());
    }
    fn stats(session: &GraphSession<'_>) {
        let st = session
            .dynamic_graph()
            .expect("dynamic session")
            .stats();
        println!(
            "  graph: epoch={} edges={} delta={} (occ {:.1}%) compactions={} ({:?})",
            st.epoch,
            st.edges,
            st.delta_edges,
            st.occupancy * 100.0,
            st.compactions,
            st.compaction_time
        );
    }

    match algo {
        "cc" => {
            let cold = session.run_with(
                &ConnectedComponents,
                RunOptions::new().config(cfg.bypass(true)),
            );
            print_run("cc cold", &cold.metrics);
            let mut state = IncrementalState::new(cold.values, session.graph_epoch());
            for round in 0..rounds {
                let m = random_batch(false);
                let receipt = session.apply_mutations(&m)?;
                let (inc, next) = incremental_cc(&session, &state, &receipt)?;
                report("incremental", round, &inc);
                let cold = session.run_with(
                    &ConnectedComponents,
                    RunOptions::new().config(cfg.bypass(true)),
                );
                report("cold      ", round, &cold.metrics);
                if next.values != cold.values {
                    bail!("incremental CC diverged from cold recompute");
                }
                stats(&session);
                state = next;
            }
        }
        "pr" | "pagerank" => {
            let p = DeltaPageRank {
                max_iterations: pr_iterations,
                ..DeltaPageRank::default()
            };
            let cold = session.run_with(&p, RunOptions::new().halt(delta_pagerank_halt(&p)));
            print_run("pagerank cold", &cold.metrics);
            let mut state = IncrementalState::new(cold.values, session.graph_epoch());
            for round in 0..rounds {
                let m = random_batch(weighted);
                let receipt = session.apply_mutations(&m)?;
                let (inc, next) = incremental_pagerank(&session, &state, &receipt, &p)?;
                report("incremental", round, &inc);
                let cold =
                    session.run_with(&p, RunOptions::new().halt(delta_pagerank_halt(&p)));
                report("cold      ", round, &cold.metrics);
                stats(&session);
                state = next;
            }
        }
        "wsssp" | "weighted-sssp" => {
            let source = source.unwrap_or_else(|| session.graph().max_out_degree_vertex());
            let p = WeightedSssp { source };
            let cold = session.run_with(&p, RunOptions::new().config(cfg.bypass(true)));
            print_run("weighted-sssp cold", &cold.metrics);
            let mut state = IncrementalState::new(cold.values, session.graph_epoch());
            for round in 0..rounds {
                let m = random_batch(true);
                let receipt = session.apply_mutations(&m)?;
                let (inc, next) = incremental_sssp(&session, &state, &receipt)?;
                report("incremental", round, &inc);
                let cold = session.run_with(
                    &p,
                    RunOptions::new()
                        .config(cfg.bypass(true))
                        .halt(Halt::quiescence()),
                );
                report("cold      ", round, &cold.metrics);
                let agree = next.values.iter().zip(&cold.values).all(|(a, b)| {
                    (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-9
                });
                if !agree {
                    bail!("incremental SSSP diverged from cold recompute");
                }
                stats(&session);
                state = next;
            }
        }
        other => bail!(
            "--mutate-batch supports --algo cc|pr|wsssp (got '{other}'): these have \
             delta-driven incremental recomputations"
        ),
    }
    Ok(())
}

fn cmd_table1(opts: &Opts) -> Result<()> {
    opts.ensure_known(&["tiny", "dir"])?;
    let entries = if opts.flag("tiny") {
        catalog::catalog_tiny()
    } else {
        catalog::catalog()
    };
    println!("{}", run_table1(&entries, &graph_dir(opts))?);
    Ok(())
}

fn cmd_table2(opts: &Opts) -> Result<()> {
    opts.ensure_known(&["tiny", "dir", "bench", "threads", "chunk"])?;
    let entries = if opts.flag("tiny") {
        catalog::catalog_tiny()
    } else {
        catalog::catalog()
    };
    let dir = graph_dir(opts);
    let benches: Vec<Bench> = match opts.get("bench") {
        None => Bench::all().to_vec(),
        Some(list) => list
            .split(',')
            .map(|b| Bench::parse(b).ok_or_else(|| err!("--bench: bad value '{b}'")))
            .collect::<Result<_>>()?,
    };
    let t2 = Table2Options {
        threads: opts.get_num("threads", 32usize)?,
        benches,
        dynamic_chunk_override: opts.get("chunk").map(|c| c.parse()).transpose()?,
    };
    let mut graphs = Vec::new();
    for e in &entries {
        eprintln!("loading {} …", e.name);
        graphs.push((e.stands_for.to_string(), e.load_or_generate(&dir)?));
    }
    let t = ipregel::util::timer::Timer::start();
    let results = table2::run_table2(&graphs, &t2);
    let names: Vec<String> = graphs.iter().map(|(n, _)| n.clone()).collect();
    println!("{}", table2::render(&names, &results));
    println!("{}", table2::summary(&results));
    eprintln!("(table2 computed in {})", fmt_duration(t.elapsed()));
    Ok(())
}

fn cmd_calibrate(opts: &Opts) -> Result<()> {
    opts.ensure_known(&[])?;
    let c = calibrate::calibrate(1);
    println!("{}", c.render());
    println!("\nderived cost model:\n{:#?}", c.to_cost_model());
    Ok(())
}

fn cmd_audit(opts: &Opts) -> Result<()> {
    opts.ensure_known(&["root", "manifest"])?;
    let root = ipregel::audit::resolve_root(opts.get("root"));
    let manifest = opts
        .get("manifest")
        .map(PathBuf::from)
        .unwrap_or_else(|| root.join("audit/orderings.toml"));
    let report = ipregel::audit::audit_tree(&root, &manifest).map_err(|e| err!("{e}"))?;
    print!("{}", report.render());
    if report.ok() {
        Ok(())
    } else {
        bail!("pallas-audit found {} violation(s)", report.violations.len())
    }
}

const SERVE_FLAGS: &[&str] = &[
    "threads", "schedule", "strategy", "layout", "bypass", "shards", "steal",
    "pipeline-depth", "adaptive", "max-supersteps", "dir", "queries", "concurrency",
    "seed", "radius", "iterations", "mutate-batch",
];

/// `serve <graph|name>`: stand up a [`ipregel::serve::QueryServer`] and
/// measure a seeded stream of bounded interactive queries twice — on an
/// idle server, then with a concurrent batch PageRank grinding through
/// the admission gate — so the tail-latency cost of multi-tenancy is one
/// table. Thread split between the classes comes from the simulator's
/// calibrated cost model ([`InterleavePolicy::from_cost_model`]), and
/// `--mutate-batch N` closes with a snapshot-isolation demo: pin the
/// current epoch, mutate, then compare a time-travel read against the
/// republished graph.
fn cmd_serve(opts: &Opts) -> Result<()> {
    use ipregel::algos::query::{EgoNetBfs, PointSssp};
    use ipregel::graph::dynamic::MutationSet;
    use ipregel::metrics::{LatencyStats, TablePrinter};
    use ipregel::serve::{
        AdmissionController, InterleavePolicy, QueryServer, QueryShape, QuerySpec,
        SuperstepShape,
    };
    use ipregel::sim::CostModel;
    use ipregel::util::rng::Rng;
    use ipregel::util::timer::Timer;
    use std::sync::Mutex;
    use std::time::Duration;

    opts.ensure_known(SERVE_FLAGS)?;
    let arg = opts.positional.get(1).ok_or_else(|| {
        err!("usage: ipregel serve <graph|name> [--queries N] [--concurrency K]")
    })?;
    let g = load_graph(arg, &graph_dir(opts))?;
    let cfg = engine_cfg(opts)?;
    let queries = opts.get_num("queries", 32usize)?;
    let concurrency = opts.get_num("concurrency", 4usize)?;
    let seed = opts.get_num("seed", 42u64)?;
    let radius = opts.get_num("radius", 2u64)?;
    let iterations = opts.get_num("iterations", 10usize)?;
    let mutate = opts.get_num("mutate-batch", 0usize)?;

    let n = g.num_vertices();
    if n < 2 {
        bail!("serve needs at least 2 vertices to target queries (graph has {n})");
    }
    let edges = g.num_edges() as u64;

    // Calibrate the interleave policy from the cost model before the
    // server takes ownership of the graph. The small-query shape is a
    // geometric frontier-growth estimate from the mean degree; it only
    // has to be the right order of magnitude to size the thread split.
    let avg_deg = (edges / n as u64).max(1);
    let small = QueryShape {
        waves: radius as usize + 1,
        active_per_wave: avg_deg.saturating_mul(avg_deg).min(n as u64),
        messages_per_wave: avg_deg
            .saturating_mul(avg_deg)
            .saturating_mul(avg_deg)
            .min(edges),
    };
    let policy = InterleavePolicy::from_cost_model(
        &CostModel::default(),
        cfg.threads,
        SuperstepShape {
            active: n as u64,
            messages: edges,
        },
        small,
        2.0,
    );
    println!(
        "interleave policy (cost-model calibrated, team of {}): slice {} supersteps, \
         reserve {} interactive / {} batch threads",
        cfg.threads,
        policy.slice_supersteps,
        policy.reserved_interactive_threads,
        policy.batch_threads,
    );

    // Fixed seeded workload, reused verbatim in both phases so the only
    // difference the table shows is the concurrent batch run.
    let mut rng = Rng::new(seed);
    let workload: Vec<(u32, bool)> = (0..queries)
        .map(|i| (rng.below(n as u64) as u32, i % 2 == 1))
        .collect();

    let server = QueryServer::with_config(g, cfg, AdmissionController::new(concurrency));
    println!(
        "serving {queries} interactive queries (ego-net bfs / point sssp, radius {radius}) \
         over {n} vertices, admission gate of {concurrency}"
    );

    // One phase: drain the workload from `concurrency` submitter threads,
    // optionally alongside a batch PageRank competing at the gate.
    let run_phase = |with_batch: bool| {
        let next = Mutex::new(0usize);
        let latencies = Mutex::new(Vec::new());
        let batch_out = Mutex::new(None);
        let t = Timer::start();
        std::thread::scope(|s| {
            if with_batch {
                s.spawn(|| {
                    let p = PageRank {
                        iterations,
                        damping: 0.85,
                    };
                    let spec = QuerySpec::batch().config(cfg.threads(policy.batch_threads));
                    let r = server
                        .execute(&p, &spec)
                        .expect("admission queue is unbounded");
                    *batch_out.lock().unwrap() = Some((r.query.supersteps, r.query.run_time));
                });
            }
            for _ in 0..concurrency.max(1) {
                s.spawn(|| loop {
                    let i = {
                        let mut ix = next.lock().unwrap();
                        let i = *ix;
                        *ix += 1;
                        i
                    };
                    let Some(&(root, point_sssp)) = workload.get(i) else {
                        break;
                    };
                    // Under contention, interactive queries run on the
                    // calibrated reserved slice of the team.
                    let icfg = if with_batch && policy.reserved_interactive_threads > 0 {
                        cfg.threads(policy.reserved_interactive_threads)
                    } else {
                        cfg
                    };
                    let spec = QuerySpec::interactive().config(icfg);
                    let latency = if point_sssp {
                        let p = PointSssp {
                            source: root,
                            cutoff: radius as f64,
                        };
                        server
                            .execute(&p, &spec)
                            .expect("admission queue is unbounded")
                            .query
                            .latency
                    } else {
                        let p = EgoNetBfs { root, radius };
                        server
                            .execute(&p, &spec)
                            .expect("admission queue is unbounded")
                            .query
                            .latency
                    };
                    latencies.lock().unwrap().push(latency);
                });
            }
        });
        let wall = t.elapsed();
        let stats = LatencyStats::from_durations(&latencies.into_inner().unwrap());
        (stats, batch_out.into_inner().unwrap(), wall)
    };

    let (idle, _, idle_wall) = run_phase(false);
    let (contended, batch, contended_wall) = run_phase(true);

    let mut table = TablePrinter::new(&["phase", "queries", "p50", "p99", "mean", "max", "qps"]);
    let row = |label: &str, st: &LatencyStats, wall: Duration| {
        vec![
            label.to_string(),
            st.count.to_string(),
            fmt_duration(st.p50()),
            fmt_duration(st.p99()),
            fmt_duration(st.mean()),
            fmt_duration(st.max()),
            format!("{:.1}", st.count as f64 / wall.as_secs_f64().max(1e-9)),
        ]
    };
    table.row(row("idle", &idle, idle_wall));
    table.row(row("with-batch", &contended, contended_wall));
    println!("{}", table.render());
    if let Some((steps, run_time)) = batch {
        println!(
            "batch pagerank ({iterations} iterations): {steps} supersteps in {} \
             ({:.1} supersteps/s) on {} threads",
            fmt_duration(run_time),
            steps as f64 / run_time.as_secs_f64().max(1e-9),
            policy.batch_threads,
        );
    }
    let pool = server.pool_stats();
    println!(
        "pool: {} store checkouts, {} served warm from the pool; {} queries through \
         a gate of {} ({} permits granted)",
        pool.store_checkouts,
        pool.store_hits,
        server.queries_completed(),
        concurrency,
        server.admission().admitted(),
    );

    if mutate > 0 {
        let pinned = server.pin_current();
        let mut m = MutationSet::new();
        let mut rng = Rng::new(seed ^ 0x5EED);
        while m.inserts().len() < 2 * mutate {
            let s = rng.below(n as u64) as u32;
            let d = rng.below(n as u64) as u32;
            if s != d {
                m.insert_undirected(s, d);
            }
        }
        let receipt = server.apply_mutations(&m);
        println!(
            "mutation: epoch {} -> {} (+{} directed edges); pinned reader still at \
             epoch {} ({} pin)",
            receipt.from_epoch,
            receipt.epoch,
            receipt.inserted,
            pinned.epoch(),
            server.pinned_readers(pinned.epoch()),
        );
        let (root, _) = workload[0];
        let p = EgoNetBfs { root, radius };
        let old = server
            .execute_on(&pinned, &p, &QuerySpec::interactive())
            .expect("admission queue is unbounded");
        let new = server
            .execute(&p, &QuerySpec::interactive())
            .expect("admission queue is unbounded");
        let changed = old
            .values
            .iter()
            .zip(&new.values)
            .filter(|(a, b)| a != b)
            .count();
        println!(
            "time-travel read: ego-net of v{root} at epoch {} vs epoch {} differs at \
             {changed} vertices",
            old.query.epoch, new.query.epoch,
        );
    }
    Ok(())
}

fn cmd_accel(opts: &Opts) -> Result<()> {
    opts.ensure_known(&["algo", "dir", "artifacts", "source"])?;
    let arg = opts
        .positional
        .get(1)
        .ok_or_else(|| err!("usage: ipregel accel --algo pr|cc|sssp <graph|name>"))?;
    let g = load_graph(arg, &graph_dir(opts))?;
    let adir = opts
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(ipregel::runtime::default_artifact_dir);
    let rt = ipregel::runtime::Runtime::load(&adir)
        .with_context(|| "loading artifacts (run `make artifacts`)")?;
    println!(
        "runtime: platform={} artifacts={:?} block n={}",
        rt.platform(),
        rt.executables(),
        rt.manifest.n
    );
    let block = ipregel::runtime::accel::DenseBlock::from_graph(&rt, &g)?;
    let t = ipregel::util::timer::Timer::start();
    match opts.get_or("algo", "pr").as_str() {
        "pr" | "pagerank" => {
            let ranks = ipregel::runtime::accel::pagerank(&rt, &g, &block)?;
            let top = ranks
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            println!("pagerank via PJRT: top vertex v{} rank {:.3e}", top.0, top.1);
        }
        "cc" => {
            let labels = ipregel::runtime::accel::connected_components(&rt, &g, &block)?;
            let mut u = labels.clone();
            u.sort_unstable();
            u.dedup();
            println!("cc via PJRT: {} components", u.len());
        }
        "sssp" => {
            let source = opts.get_num("source", g.max_out_degree_vertex())?;
            let dist = ipregel::runtime::accel::sssp(&rt, &g, &block, source)?;
            let reached = dist.iter().filter(|d| d.is_finite()).count();
            println!("sssp via PJRT: reached {reached} vertices from v{source}");
        }
        other => bail!("--algo {other}: expected pr|cc|sssp"),
    }
    println!("(accel run in {})", fmt_duration(t.elapsed()));
    Ok(())
}
