//! A miniature property-based testing harness.
//!
//! The offline environment has no `proptest`/`quickcheck`, so this module
//! provides the 10% we need: run a property over many seeded random cases
//! and, on failure, report the exact case seed so the failure replays
//! deterministically (`QUICK_SEED=<n> cargo test ...`).

use crate::util::rng::Rng;

/// Number of cases per property (override with env `QUICK_CASES`).
pub fn default_cases() -> usize {
    std::env::var("QUICK_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Base seed (override with env `QUICK_SEED` to replay one failing case).
pub fn base_seed() -> u64 {
    std::env::var("QUICK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD1CE_5EED)
}

/// Run `prop` against `default_cases()` seeded RNGs. `prop` returns
/// `Err(description)` to fail; the panic message includes the case seed.
pub fn check<F>(name: &str, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let cases = default_cases();
    let base = base_seed();
    let replay_single = std::env::var("QUICK_SEED").is_ok();
    for case in 0..cases {
        let seed = if replay_single { base } else { base.wrapping_add(case as u64) };
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed (case {case}, seed {seed}): {msg}\n\
                 replay with: QUICK_SEED={seed} QUICK_CASES=1"
            );
        }
        if replay_single {
            break;
        }
    }
}

/// Generate a random degree sequence with power-law-ish skew: most entries
/// small, a few heavy hitters — the shape vertex-centric graphs exhibit.
pub fn skewed_degrees(rng: &mut Rng, n: usize, max_degree: usize) -> Vec<u64> {
    (0..n)
        .map(|_| {
            // Inverse-power sampling: P(d) ∝ d^-2 over [1, max_degree].
            let u = rng.f64().max(1e-12);
            let d = (1.0 / u).sqrt();
            (d as usize).clamp(1, max_degree.max(1)) as u64
        })
        .collect()
}

/// Generate a random edge list over `n` vertices (possibly with duplicates
/// and self-loops — builders must tolerate both).
pub fn random_edges(rng: &mut Rng, n: usize, m: usize) -> Vec<(u32, u32)> {
    (0..m)
        .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("below stays below", |rng| {
            let b = 1 + rng.below(100);
            let x = rng.below(b);
            if x < b {
                Ok(())
            } else {
                Err(format!("{x} >= {b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn check_reports_failures() {
        check("always fails", |_| Err("nope".into()));
    }

    #[test]
    fn skewed_degrees_in_range() {
        let mut rng = Rng::new(1);
        let ds = skewed_degrees(&mut rng, 1000, 50);
        assert_eq!(ds.len(), 1000);
        assert!(ds.iter().all(|&d| (1..=50).contains(&d)));
        // Skew sanity: max should exceed mean substantially.
        let mean = ds.iter().sum::<u64>() as f64 / 1000.0;
        let max = *ds.iter().max().unwrap() as f64;
        assert!(max > 2.0 * mean, "max={max} mean={mean}");
    }

    #[test]
    fn random_edges_in_range() {
        let mut rng = Rng::new(2);
        let es = random_edges(&mut rng, 10, 500);
        assert!(es.iter().all(|&(s, d)| s < 10 && d < 10));
    }
}
