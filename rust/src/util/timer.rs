//! Wall-clock timing helpers used by benchmarks and the experiment harness.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    /// Elapsed time since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as f64.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Restart and return the lap duration.
    pub fn lap(&mut self) -> Duration {
        let d = self.start.elapsed();
        self.start = Instant::now();
        d
    }
}

/// Measure the best-of-`reps` wall time of `f`, with one warm-up run.
/// Best-of is the standard noise-resistant estimator for short kernels.
pub fn best_of<F: FnMut()>(reps: usize, mut f: F) -> Duration {
    f(); // warm-up
    let mut best = Duration::MAX;
    for _ in 0..reps.max(1) {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed());
    }
    best
}

/// Measure mean ns/iteration of `f` by running it `iters` times inside one
/// timed region (for very short operations where per-call timing is noise).
pub fn ns_per_iter<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    t.elapsed().as_nanos() as f64 / iters.max(1) as f64
}

/// Render a duration compactly: `1.234s`, `56.7ms`, `890µs`, `12ns`.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns >= 1_000_000_000 {
        format!("{:.3}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.1}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.1}µs", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.secs() > 0.0);
    }

    #[test]
    fn best_of_runs_f() {
        let mut n = 0;
        let _ = best_of(3, || n += 1);
        assert_eq!(n, 4); // warm-up + 3 reps
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(Duration::from_nanos(12)), "12ns");
        assert_eq!(fmt_duration(Duration::from_micros(890)), "890.0µs");
        assert_eq!(fmt_duration(Duration::from_millis(56)), "56.0ms");
        assert_eq!(fmt_duration(Duration::from_secs(1)), "1.000s");
    }
}
