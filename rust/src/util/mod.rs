//! Small self-contained utilities shared across the framework.
//!
//! Everything here is dependency-free: the build environment is offline, so
//! we carry our own PRNG ([`rng`]), bitsets ([`bitset`]), prefix sums
//! ([`prefix`]), timing helpers ([`timer`]) and a miniature property-testing
//! harness ([`quick`]).

pub mod bitset;
pub mod error;
pub mod prefix;
pub mod quick;
pub mod rng;
#[cfg(feature = "race-check")]
pub mod shadow;
pub mod timer;

/// Pads and aligns a value to 128 bytes so neighbouring instances never
/// share a cache line (two 64-byte lines: spatial prefetchers pull pairs).
/// Local stand-in for `crossbeam_utils::CachePadded` — the build is
/// offline and dependency-free.
#[derive(Clone, Copy, Debug, Default)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    /// Wrap a value in cache-line padding.
    pub const fn new(value: T) -> Self {
        CachePadded { value }
    }

    /// Unwrap the inner value.
    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;

    #[inline]
    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

/// Human-readable formatting of a count with thousands separators,
/// e.g. `1806067135` → `"1,806,067,135"`.
pub fn commas(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    let offset = s.len() % 3;
    for (i, c) in s.chars().enumerate() {
        if i != 0 && (i + 3 - offset) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Geometric mean of a slice of positive numbers. Returns `NaN` on empty
/// input (callers decide how to render that).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let log_sum: f64 = xs.iter().map(|x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Integer ceiling division.
#[inline]
pub const fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commas_formats_groups() {
        assert_eq!(commas(0), "0");
        assert_eq!(commas(999), "999");
        assert_eq!(commas(1000), "1,000");
        assert_eq!(commas(317_080), "317,080");
        assert_eq!(commas(1_806_067_135), "1,806,067,135");
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    fn cache_padded_is_line_aligned() {
        assert_eq!(std::mem::align_of::<CachePadded<u64>>(), 128);
        let mut c = CachePadded::new(7u64);
        *c += 1;
        assert_eq!(*c, 8);
        assert_eq!(c.into_inner(), 8);
    }

    #[test]
    fn div_ceil_rounds_up() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
    }
}
