//! Shadow-state logical race checker (compiled only with
//! `--features race-check`).
//!
//! The engine's memory discipline is *phase-based*: unsynchronised access
//! to a cell is sound because at most one thread touches it per parallel
//! phase, and phases are separated by `thread::scope` joins (see
//! DESIGN.md §2.8). That discipline is invisible to the compiler, so this
//! module makes it *checkable*: every instrumented cell carries a packed
//! record of its last accessor — `{phase, thread, access-kind, site}` —
//! and each new access compares itself against that record. Two accesses
//! conflict when they land in the **same phase** from **different
//! threads** and at least one of them is an unsynchronised write (or one
//! side is lock-guarded while the other bypasses the lock). A conflict is
//! a violated engine invariant, never a tolerable data race, so the
//! checker panics with both sites.
//!
//! ## Phase epochs
//!
//! A global counter is bumped at entry *and* exit of every
//! [`parallel_for_hinted`](crate::sched::pool::parallel_for_hinted)
//! region, so each parallel region — and each serial stretch between
//! regions — gets its own epoch. The counter is monotonic; any two
//! accesses separated by a real synchronisation point therefore observe
//! different epochs and can never falsely conflict. Cross-run handover of
//! pooled state through a session `Mutex` is a synchronisation point the
//! pool hooks announce via [`sync_point`].
//!
//! ## What it detects (and what it can't)
//!
//! Detection is *record-based*, not temporal: two sequential accesses in
//! the same epoch conflict exactly like truly simultaneous ones. That
//! makes seeded-bug tests deterministic — no timing window to hit. The
//! cost is the usual last-writer limitation: a cell remembers one prior
//! access (reads never overwrite a same-thread write record, so the
//! common write-then-read pattern stays visible). Under concurrent
//! engine runs the global counter advances while a region is in flight,
//! which can only split an epoch (missed detection), never merge two
//! (false alarm).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

// Record layout (one `AtomicU64` per instrumented cell):
//   bits 63..24  phase epoch (40 bits, monotonic)
//   bits 23..8   thread id   (16 bits; reuse across 65 536 spawns is
//                             harmless — ids can only collide across
//                             different epochs)
//   bits  7..2   site id     (6 bits, diagnostic only)
//   bits  1..0   access kind
const PHASE_SHIFT: u32 = 24;
const TID_SHIFT: u32 = 8;
const TID_MASK: u64 = 0xFFFF;
const SITE_SHIFT: u32 = 2;
const SITE_MASK: u64 = 0x3F;
const KIND_MASK: u64 = 0b11;

const KIND_NONE: u64 = 0;
const KIND_READ: u64 = 1;
const KIND_WRITE_UNSYNC: u64 = 2;
const KIND_WRITE_SYNC: u64 = 3;

/// Instrumented access sites, packed into the record for diagnostics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Site {
    None = 0,
    SlotStoreFirst = 1,
    SlotStoreMsg = 2,
    SlotTake = 3,
    SlotClear = 4,
    SlotPeek = 5,
    SlotPeekScan = 6,
    CellGet = 7,
    CellGetMut = 8,
    /// A work-stealing deque item's execution (`StealSet::mark_execute`):
    /// each item must execute exactly once per phase, so a double claim
    /// shows up as a same-phase write/write conflict.
    StealItem = 9,
}

impl Site {
    fn from_bits(b: u64) -> Site {
        match b {
            1 => Site::SlotStoreFirst,
            2 => Site::SlotStoreMsg,
            3 => Site::SlotTake,
            4 => Site::SlotClear,
            5 => Site::SlotPeek,
            6 => Site::SlotPeekScan,
            7 => Site::CellGet,
            8 => Site::CellGetMut,
            9 => Site::StealItem,
            _ => Site::None,
        }
    }

    fn name(self) -> &'static str {
        match self {
            Site::None => "(none)",
            Site::SlotStoreFirst => "MsgSlot::store_first",
            Site::SlotStoreMsg => "MsgSlot::store_msg",
            Site::SlotTake => "MsgSlot::take",
            Site::SlotClear => "MsgSlot::clear",
            Site::SlotPeek => "MsgSlot::peek",
            Site::SlotPeekScan => "MsgSlot::peek_scan",
            Site::CellGet => "SyncCell::get",
            Site::CellGetMut => "SyncCell::get_mut",
            Site::StealItem => "StealSet::execute",
        }
    }
}

fn kind_name(k: u64) -> &'static str {
    match k {
        KIND_READ => "unsynchronised read",
        KIND_WRITE_UNSYNC => "unsynchronised write",
        KIND_WRITE_SYNC => "lock-guarded write",
        _ => "(none)",
    }
}

/// Global phase epoch. Starts at 1 so a zeroed record (phase 0,
/// `KIND_NONE`) can never alias a live access.
static PHASE: AtomicU64 = AtomicU64::new(1);
/// Thread-id well; each OS thread draws one lazily.
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::SeqCst) & TID_MASK;
    /// Stack of `SpinLock` addresses the current thread holds.
    static HELD_LOCKS: RefCell<Vec<usize>> = const { RefCell::new(Vec::new()) };
}

/// This thread's checker id.
pub fn thread_id() -> u64 {
    TID.with(|t| *t)
}

/// Current phase epoch.
pub fn current_phase() -> u64 {
    PHASE.load(Ordering::SeqCst)
}

/// Advance the global phase epoch: call where real synchronisation
/// happens that the checker cannot see (scope joins are covered by
/// [`PhaseGuard`]; session pools call this at checkout because the pool
/// `Mutex` orders the previous owner's writes before ours).
pub fn sync_point() {
    PHASE.fetch_add(1, Ordering::SeqCst);
}

/// RAII phase bracket for a parallel region: entry gives the region a
/// fresh epoch, drop (after the scope join) gives the following serial
/// stretch one too.
pub struct PhaseGuard(());

impl PhaseGuard {
    pub fn enter() -> PhaseGuard {
        sync_point();
        PhaseGuard(())
    }
}

impl Drop for PhaseGuard {
    fn drop(&mut self) {
        sync_point();
    }
}

/// Record that the current thread acquired the `SpinLock` at `addr`.
/// Panics on recursive acquisition — the engine's spin locks are not
/// re-entrant, so a nested acquire is a guaranteed self-deadlock.
pub fn lock_acquired(addr: usize) {
    HELD_LOCKS.with(|h| {
        let mut held = h.borrow_mut();
        assert!(
            !held.contains(&addr),
            "race-check: recursive SpinLock acquisition (thread {} already \
             holds the lock at {addr:#x}) — this deadlocks outside the checker",
            thread_id(),
        );
        held.push(addr);
    });
}

/// Record that the current thread released the `SpinLock` at `addr`.
/// Panics when this thread does not hold it — an unlock-by-non-owner is
/// a protocol violation even when it happens to "work".
pub fn lock_released(addr: usize) {
    HELD_LOCKS.with(|h| {
        let mut held = h.borrow_mut();
        match held.iter().rposition(|&a| a == addr) {
            Some(i) => {
                held.remove(i);
            }
            None => panic!(
                "race-check: SpinLock at {addr:#x} released by thread {} \
                 which does not hold it",
                thread_id(),
            ),
        }
    });
}

/// Does the current thread hold the `SpinLock` at `addr`?
pub fn lock_held(addr: usize) -> bool {
    HELD_LOCKS.with(|h| h.borrow().contains(&addr))
}

#[inline]
fn pack(phase: u64, tid: u64, site: Site, kind: u64) -> u64 {
    (phase << PHASE_SHIFT)
        | ((tid & TID_MASK) << TID_SHIFT)
        | ((site as u64 & SITE_MASK) << SITE_SHIFT)
        | (kind & KIND_MASK)
}

/// Per-cell shadow record. Embed one next to each protected cell (the
/// owning struct's field is itself `#[cfg(feature = "race-check")]`-gated,
/// so release builds carry no trace of it).
pub struct ShadowCell {
    record: AtomicU64,
}

impl Default for ShadowCell {
    fn default() -> Self {
        Self::new()
    }
}

impl ShadowCell {
    pub const fn new() -> ShadowCell {
        ShadowCell {
            record: AtomicU64::new(0),
        }
    }

    /// Record an unsynchronised read of the cell.
    #[inline]
    pub fn on_read(&self, site: Site) {
        self.on_access(site, KIND_READ);
    }

    /// Record a write: `synced` when the caller holds the cell's own
    /// lock (the checker then only flags cross-discipline overlap).
    #[inline]
    pub fn on_write(&self, site: Site, synced: bool) {
        self.on_access(site, if synced { KIND_WRITE_SYNC } else { KIND_WRITE_UNSYNC });
    }

    fn on_access(&self, site: Site, kind: u64) {
        let phase = current_phase();
        let tid = thread_id();
        let old = self.record.load(Ordering::SeqCst);
        let (ophase, otid) = (old >> PHASE_SHIFT, (old >> TID_SHIFT) & TID_MASK);
        let (osite, okind) = (Site::from_bits((old >> SITE_SHIFT) & SITE_MASK), old & KIND_MASK);
        if okind != KIND_NONE && ophase == phase && otid != tid {
            // Benign combinations: both sides read, or both sides hold
            // the cell's lock. Everything else breaks the discipline.
            let benign = (okind == KIND_READ && kind == KIND_READ)
                || (okind == KIND_WRITE_SYNC && kind == KIND_WRITE_SYNC);
            assert!(
                benign,
                "race-check: same-phase conflicting access in phase {phase}: \
                 {} via {} by thread {otid} overlaps {} via {} by thread {tid}",
                kind_name(okind),
                osite.name(),
                kind_name(kind),
                Site::name(site),
            );
        }
        // Writes dominate reads within a phase: keep a same-thread write
        // record visible so a later cross-thread read still trips on it.
        if kind == KIND_READ
            && ophase == phase
            && otid == tid
            && (okind == KIND_WRITE_UNSYNC || okind == KIND_WRITE_SYNC)
        {
            return;
        }
        self.record.store(pack(phase, tid, site, kind), Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_are_monotonic() {
        let a = current_phase();
        sync_point();
        let b = current_phase();
        assert!(b > a);
        {
            let _g = PhaseGuard::enter();
            assert!(current_phase() > b);
        }
        assert!(current_phase() > b + 1, "drop bumps again");
    }

    #[test]
    fn same_thread_never_conflicts() {
        let c = ShadowCell::new();
        c.on_write(Site::CellGetMut, false);
        c.on_read(Site::CellGet);
        c.on_write(Site::SlotStoreFirst, false);
        c.on_write(Site::SlotStoreMsg, true);
    }

    #[test]
    fn lock_stack_tracks_ownership() {
        assert!(!lock_held(0x10));
        lock_acquired(0x10);
        assert!(lock_held(0x10));
        lock_released(0x10);
        assert!(!lock_held(0x10));
    }

    #[test]
    fn read_does_not_erase_same_thread_write_record() {
        // Other tests in this binary bump the global phase concurrently;
        // retention only applies within one phase, so retry until the
        // write/read pair lands in a stable phase.
        for _ in 0..64 {
            let c = ShadowCell::new();
            let p0 = current_phase();
            c.on_write(Site::CellGetMut, false);
            c.on_read(Site::CellGet);
            if current_phase() == p0 {
                // The record must still be the write — the kind bits say so.
                let raw = c.record.load(Ordering::SeqCst);
                assert_eq!(raw & KIND_MASK, KIND_WRITE_UNSYNC);
                return;
            }
        }
        panic!("no stable phase across 64 attempts");
    }
}
