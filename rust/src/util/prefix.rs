//! Prefix sums and partition search over monotone sequences.
//!
//! The edge-centric workload representation (paper §V-A) is built on an
//! exclusive prefix sum over vertex degrees followed by binary searches
//! that cut the cumulative edge count into equal-work ranges.

/// Exclusive prefix sum: `out[i] = sum(xs[0..i])`, `out[len] = total`.
/// Returns a vector one longer than the input (CSR-offsets shape).
pub fn exclusive_prefix_sum(xs: &[u64]) -> Vec<u64> {
    let mut out = Vec::with_capacity(xs.len() + 1);
    let mut acc = 0u64;
    out.push(0);
    for &x in xs {
        acc += x;
        out.push(acc);
    }
    out
}

/// In-place exclusive prefix sum over `usize` (used by the CSR builder to
/// turn per-vertex counts into offsets). Returns the total.
pub fn exclusive_prefix_sum_in_place(xs: &mut [usize]) -> usize {
    let mut acc = 0usize;
    for x in xs.iter_mut() {
        let v = *x;
        *x = acc;
        acc += v;
    }
    acc
}

/// Largest index `i` such that `prefix[i] <= target`, for a non-decreasing
/// `prefix` with `prefix[0] == 0`. Used to locate which vertex owns the
/// k-th edge in the cumulative-degree array.
pub fn rank_in_prefix(prefix: &[u64], target: u64) -> usize {
    debug_assert!(!prefix.is_empty());
    // partition_point returns the first index where pred is false.
    let idx = prefix.partition_point(|&p| p <= target);
    idx.saturating_sub(1)
}

/// Cut `[0, total)` work (as described by `prefix`, len = n+1) into `parts`
/// contiguous item ranges with near-equal cumulative weight. Returns
/// `parts + 1` item boundaries, first 0, last n, non-decreasing.
///
/// This is exactly the paper's edge-centric split: items are vertices,
/// weights are degrees, and each part receives ≈ total/parts edges.
pub fn balanced_cuts(prefix: &[u64], parts: usize) -> Vec<usize> {
    assert!(!prefix.is_empty(), "prefix must have at least one entry");
    assert!(parts > 0);
    let n = prefix.len() - 1;
    let total = prefix[n];
    let mut cuts = Vec::with_capacity(parts + 1);
    cuts.push(0);
    for p in 1..parts {
        let target = (total as u128 * p as u128 / parts as u128) as u64;
        // First item index whose prefix reaches the target…
        let mut c = prefix.partition_point(|&x| x < target).min(n);
        // …but prefer the boundary *closest* to the target: a single huge
        // item (power-law hub) should not drag every lighter item onto its
        // side of the cut.
        if c > 0 && target - prefix[c - 1] <= prefix[c] - target {
            c -= 1;
        }
        // Clamp to keep boundaries monotone when many items weigh zero.
        if c < *cuts.last().unwrap() {
            c = *cuts.last().unwrap();
        }
        cuts.push(c);
    }
    cuts.push(n);
    cuts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exclusive_prefix_sum_basics() {
        assert_eq!(exclusive_prefix_sum(&[]), vec![0]);
        assert_eq!(exclusive_prefix_sum(&[3, 0, 2]), vec![0, 3, 3, 5]);
    }

    #[test]
    fn in_place_matches_and_returns_total() {
        let mut xs = vec![3usize, 0, 2, 5];
        let total = exclusive_prefix_sum_in_place(&mut xs);
        assert_eq!(xs, vec![0, 3, 3, 5]);
        assert_eq!(total, 10);
    }

    #[test]
    fn rank_in_prefix_finds_owner() {
        let prefix = vec![0u64, 3, 3, 5, 10];
        assert_eq!(rank_in_prefix(&prefix, 0), 0);
        assert_eq!(rank_in_prefix(&prefix, 2), 0);
        assert_eq!(rank_in_prefix(&prefix, 3), 2); // vertex 1 has degree 0
        assert_eq!(rank_in_prefix(&prefix, 4), 2);
        assert_eq!(rank_in_prefix(&prefix, 9), 3);
    }

    #[test]
    fn balanced_cuts_cover_and_balance() {
        // 8 items of weight 1 → 4 parts of 2 items.
        let prefix = exclusive_prefix_sum(&[1; 8]);
        assert_eq!(balanced_cuts(&prefix, 4), vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn balanced_cuts_handle_skew() {
        // One huge item dominates; it must land alone in a part.
        let prefix = exclusive_prefix_sum(&[1, 1, 100, 1, 1]);
        let cuts = balanced_cuts(&prefix, 2);
        assert_eq!(cuts.first(), Some(&0));
        assert_eq!(cuts.last(), Some(&5));
        for w in cuts.windows(2) {
            assert!(w[0] <= w[1]);
        }
        // The heavy item (index 2) is fully inside one part.
        let part_of_heavy = cuts.windows(2).position(|w| w[0] <= 2 && 2 < w[1]);
        assert!(part_of_heavy.is_some());
    }

    #[test]
    fn balanced_cuts_more_parts_than_items() {
        let prefix = exclusive_prefix_sum(&[5, 5]);
        let cuts = balanced_cuts(&prefix, 8);
        assert_eq!(cuts.len(), 9);
        assert_eq!(*cuts.first().unwrap(), 0);
        assert_eq!(*cuts.last().unwrap(), 2);
        for w in cuts.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn balanced_cuts_all_zero_weights() {
        let prefix = exclusive_prefix_sum(&[0, 0, 0]);
        let cuts = balanced_cuts(&prefix, 3);
        assert_eq!(*cuts.last().unwrap(), 3);
        for w in cuts.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }
}
