//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we implement xoshiro256** —
//! the same generator family used by `rand_xoshiro` — seeded through
//! SplitMix64 as its authors recommend. All graph generation and property
//! tests are seeded, so every experiment in the repository is reproducible
//! bit-for-bit.

/// SplitMix64 step; used for seeding and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Not cryptographic; excellent statistical quality and
/// very fast, which matters when generating billions of RMAT edges.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit value (upper half of a 64-bit draw).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased enough
    /// for graph generation; bound ≤ u32::MAX).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply keeps this unbiased to ~2^-64.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork a statistically independent child stream (for per-thread RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(5);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..100).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
