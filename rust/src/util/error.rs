//! Minimal error plumbing for the offline build (no `anyhow` crate).
//!
//! Provides the same ergonomics the codebase needs from `anyhow`: a
//! string-backed [`Error`] that any `std::error::Error` converts into via
//! `?`, a [`Result`] alias, the [`err!`]/[`bail!`]/[`ensure!`] macros, and
//! a [`Context`] extension trait for `Result` and `Option`.

use std::fmt;

/// A string-backed error with an optional chain of context messages.
pub struct Error {
    msg: String,
}

impl Error {
    /// Error from a displayable message.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Error {
            msg: msg.to_string(),
        }
    }

    /// Prepend a context layer, `anyhow`-style (`context: cause`).
    pub fn wrap(self, ctx: impl fmt::Display) -> Self {
        Error {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    // `fn main() -> Result<()>` prints errors with Debug; show the plain
    // message rather than a struct dump.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`, which
// is what makes this blanket conversion legal (same trick as `anyhow`).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string: `err!("bad id {id}")`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::err!($($arg)*));
        }
    };
}

/// Attach context to errors (and to `None`), mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context(self, ctx: impl fmt::Display) -> Result<T>;

    /// Wrap the error with a lazily-built context message.
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| e.into().wrap(ctx))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn context_layers_compose() {
        let e: Result<()> = Err(io_err());
        let wrapped = e.with_context(|| "opening x.txt").unwrap_err();
        assert_eq!(wrapped.to_string(), "opening x.txt: gone");
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        assert!(none.context("missing").is_err());
        fn guarded(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(guarded(3).unwrap(), 3);
        assert_eq!(guarded(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(guarded(7).unwrap_err().to_string(), "unlucky");
        let e = err!("formatted {}", 42);
        assert_eq!(e.to_string(), "formatted 42");
    }
}
