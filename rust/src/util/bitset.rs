//! Plain and atomic fixed-size bitsets.
//!
//! The engine tracks active vertices either with a dense bitset (scanned
//! versions) or an explicit list (selection-bypass versions, §II of the
//! paper / [Capelli et al. ICPP'18]). The atomic variant lets worker
//! threads mark vertices active during message delivery without locks.

use std::sync::atomic::{AtomicU64, Ordering};

const BITS: usize = 64;

/// A dense, non-thread-safe bitset.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl BitSet {
    /// All-zero bitset holding `len` bits.
    pub fn new(len: usize) -> Self {
        BitSet {
            words: vec![0; crate::util::div_ceil(len.max(1), BITS)],
            len,
        }
    }

    /// Number of addressable bits.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if no bits are addressable.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / BITS] |= 1u64 << (i % BITS);
    }

    #[inline]
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / BITS] &= !(1u64 << (i % BITS));
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / BITS] >> (i % BITS) & 1 == 1
    }

    /// Set every bit.
    pub fn set_all(&mut self) {
        for w in &mut self.words {
            *w = !0;
        }
        self.mask_tail();
    }

    /// Clear every bit.
    pub fn clear_all(&mut self) {
        for w in &mut self.words {
            *w = 0;
        }
    }

    /// Population count.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Iterate over set bit indices in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    return None;
                }
                let b = w.trailing_zeros() as usize;
                w &= w - 1;
                Some(wi * BITS + b)
            })
        })
    }

    fn mask_tail(&mut self) {
        let tail = self.len % BITS;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }
}

/// A dense bitset whose bits can be set concurrently from many threads.
///
/// `set` uses a relaxed-failure `fetch_or`; the engine establishes the
/// necessary happens-before edges at superstep barriers, so `Relaxed` is
/// sufficient for the activity bits themselves (the barrier is `SeqCst`).
pub struct AtomicBitSet {
    words: Vec<AtomicU64>,
    len: usize,
}

impl AtomicBitSet {
    /// All-zero atomic bitset holding `len` bits.
    pub fn new(len: usize) -> Self {
        let mut words = Vec::with_capacity(crate::util::div_ceil(len.max(1), BITS));
        words.resize_with(crate::util::div_ceil(len.max(1), BITS), || AtomicU64::new(0));
        AtomicBitSet { words, len }
    }

    /// Number of addressable bits.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if no bits are addressable.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Atomically set bit `i`; returns `true` if this call changed it
    /// (i.e. the bit was previously clear) — used to deduplicate
    /// activations when many messages hit the same vertex.
    #[inline]
    pub fn set(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        let mask = 1u64 << (i % BITS);
        let prev = self.words[i / BITS].fetch_or(mask, Ordering::Relaxed);
        prev & mask == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / BITS].load(Ordering::Relaxed) >> (i % BITS) & 1 == 1
    }

    /// Clear all bits (single-threaded phase between supersteps).
    pub fn clear_all(&mut self) {
        for w in &mut self.words {
            *w.get_mut() = 0;
        }
    }

    /// Set all bits (single-threaded phase).
    pub fn set_all(&mut self) {
        for w in &mut self.words {
            *w.get_mut() = !0;
        }
        let tail = self.len % BITS;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last.get_mut() &= (1u64 << tail) - 1;
            }
        }
    }

    /// Population count (quiescent only — not linearisable mid-superstep).
    pub fn count(&self) -> usize {
        self.words
            .iter()
            .map(|w| w.load(Ordering::Relaxed).count_ones() as usize)
            .sum()
    }

    /// Snapshot into a plain bitset (quiescent only).
    pub fn snapshot(&self) -> BitSet {
        let mut out = BitSet::new(self.len);
        for (i, w) in self.words.iter().enumerate() {
            out.words[i] = w.load(Ordering::Relaxed);
        }
        out
    }

    /// Iterate set bits (quiescent only).
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, w)| {
            let mut bits = w.load(Ordering::Relaxed);
            std::iter::from_fn(move || {
                if bits == 0 {
                    return None;
                }
                let b = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                Some(wi * BITS + b)
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn set_get_clear_roundtrip() {
        let mut bs = BitSet::new(130);
        assert_eq!(bs.count(), 0);
        bs.set(0);
        bs.set(64);
        bs.set(129);
        assert!(bs.get(0) && bs.get(64) && bs.get(129));
        assert!(!bs.get(1) && !bs.get(63) && !bs.get(128));
        assert_eq!(bs.count(), 3);
        bs.clear(64);
        assert!(!bs.get(64));
        assert_eq!(bs.count(), 2);
    }

    #[test]
    fn iter_yields_sorted_set_bits() {
        let mut bs = BitSet::new(200);
        for i in [3usize, 64, 65, 199] {
            bs.set(i);
        }
        assert_eq!(bs.iter().collect::<Vec<_>>(), vec![3, 64, 65, 199]);
    }

    #[test]
    fn set_all_respects_length() {
        let mut bs = BitSet::new(70);
        bs.set_all();
        assert_eq!(bs.count(), 70);
        bs.clear_all();
        assert_eq!(bs.count(), 0);
    }

    #[test]
    fn atomic_set_reports_first_setter() {
        let bs = AtomicBitSet::new(100);
        assert!(bs.set(42));
        assert!(!bs.set(42));
        assert!(bs.get(42));
        assert_eq!(bs.count(), 1);
    }

    #[test]
    fn atomic_concurrent_sets_exactly_one_winner_per_bit() {
        let bs = Arc::new(AtomicBitSet::new(512));
        let winners: Vec<usize> = (0..4)
            .map(|_| {
                let bs = Arc::clone(&bs);
                std::thread::spawn(move || (0..512).filter(|&i| bs.set(i)).count())
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect();
        assert_eq!(winners.iter().sum::<usize>(), 512);
        assert_eq!(bs.count(), 512);
    }

    #[test]
    fn snapshot_matches() {
        let bs = AtomicBitSet::new(99);
        bs.set(0);
        bs.set(98);
        let snap = bs.snapshot();
        assert_eq!(snap.iter().collect::<Vec<_>>(), vec![0, 98]);
    }
}
