//! Run metrics: per-superstep statistics and whole-run summaries.

use crate::combine::Strategy;
use crate::sched::Schedule;
use std::time::Duration;

/// Statistics for one superstep.
#[derive(Clone, Debug, Default)]
pub struct SuperstepStats {
    /// Vertices whose compute ran this superstep.
    pub active_vertices: usize,
    /// Messages delivered (push) or combinations performed (pull).
    pub messages: u64,
    /// Wall-clock time of the compute phase (partitioned runs: scatter).
    pub compute_time: Duration,
    /// Wall-clock time of the cross-shard flush phase (zero on flat
    /// runs, which have no such phase).
    pub flush_time: Duration,
    /// Wall-clock time of the barrier phase (swap/clear/activate;
    /// partitioned runs call this apply).
    pub barrier_time: Duration,
}

/// Which message-delivery plane a run used (see `combine/plane.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DeliveryPlaneKind {
    /// One combinable mailbox slot per vertex (strategy machinery).
    #[default]
    Combined,
    /// Per-vertex append-only message logs (`Context::recv`).
    Log,
}

impl std::fmt::Display for DeliveryPlaneKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeliveryPlaneKind::Combined => write!(f, "combined"),
            DeliveryPlaneKind::Log => write!(f, "log"),
        }
    }
}

/// Why a run stopped.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum HaltReason {
    /// Every vertex halted with no pending messages (classic Pregel).
    #[default]
    Quiescence,
    /// The superstep cap (config or per-run [`Halt`] policy) was reached.
    ///
    /// [`Halt`]: ../engine/session/struct.Halt.html
    SuperstepCap,
    /// The per-run convergence predicate fired.
    Converged,
    /// The per-run token budget ([`Halt::max_tokens`]) ran out: the
    /// cumulative work units (messages + activations per superstep)
    /// crossed the cap at a barrier. Distinct from [`SuperstepCap`] so a
    /// serving layer can tell "ran long" from "did too much work".
    ///
    /// [`Halt::max_tokens`]: ../engine/session/struct.Halt.html
    /// [`SuperstepCap`]: HaltReason::SuperstepCap
    BudgetExhausted,
}

/// A documented scheduling fallback the engine applied because the
/// requested combination cannot run in its zero-overhead form.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleFallback {
    /// `Schedule::EdgeCentric` with selection bypass: the edge-centric
    /// cut needs degree weights over the iteration space, but bypass
    /// changes that space every superstep, so the engine rebuilds the
    /// weight vector from the active list each superstep instead of
    /// using session-cached weights — the §V-A overhead the paper
    /// measures. Previously this happened silently; it is now warned
    /// once per process and surfaced here.
    EdgeCentricBypassRebuild,
}

impl std::fmt::Display for ScheduleFallback {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleFallback::EdgeCentricBypassRebuild => write!(
                f,
                "edge-centric + bypass: degree weights rebuilt from the \
                 active list every superstep"
            ),
        }
    }
}

/// One superstep's knob selection by the adaptive tuner
/// (`engine/tune.rs`), together with the live signals it decided on.
/// Recorded into [`RunMetrics::tuner_decisions`] so mode switching is a
/// testable artefact, not a benchmark anecdote.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TunerDecision {
    /// Superstep this plan applied to.
    pub superstep: usize,
    /// Work-distribution policy selected for the superstep.
    pub schedule: Schedule,
    /// Mailbox synchronisation design selected for the superstep.
    pub strategy: Strategy,
    /// Whether the superstep iterated the explicit active list (`true`)
    /// or full-scanned with a per-vertex activity check (`false`).
    pub bypass: bool,
    /// Active vertices / total vertices at superstep start.
    pub frontier_density: f64,
    /// Previous superstep's messages per active vertex (0 before the
    /// first barrier).
    pub msgs_per_active: f64,
    /// Mean mailbox fan-in of the most recently consumed send
    /// generation: the sends of superstep `k-1` divided by the
    /// recipients that consumed them during superstep `k` (a send is
    /// consumed one superstep after it is made, so the quotient pairs
    /// across that lag; 0 until both sides have been observed).
    pub fan_in: f64,
    /// Previous superstep's (CAS retries + contended lock acquisitions)
    /// per message, from the per-worker [`ContentionProbe`]s (always 0 on
    /// simulator replays, which have no live probes).
    ///
    /// [`ContentionProbe`]: crate::combine::ContentionProbe
    pub contention_per_msg: f64,
    /// Previous superstep's max-over-mean cross-shard flush load (1.0 =
    /// balanced or not partitioned).
    pub flush_imbalance: f64,
    /// Previous superstep's successful work steals (0 when stealing is
    /// off or no worker drained early).
    pub steals: u64,
    /// Previous superstep's vector-gather lane utilisation: useful lanes
    /// over scanned lanes (1.0 when no vector gather ran).
    pub lane_utilisation: f64,
    /// Prefetch look-ahead selected for this superstep (resolved, never
    /// the 0 = auto sentinel).
    pub pipeline_depth: usize,
    /// Steal-episode length selected for this superstep (resolved).
    pub steal_chunk: usize,
    /// Whether this plan differs from the previous superstep's.
    pub switched: bool,
}

impl TunerDecision {
    /// The (schedule, strategy, bypass) knob tuple — the "mode" whose
    /// distinct count the adaptive acceptance tests assert on.
    pub fn mode(&self) -> (Schedule, Strategy, bool) {
        (self.schedule, self.strategy, self.bypass)
    }
}

/// Distinct (schedule, strategy, bypass) modes in a decision trace —
/// the quantity the adaptive acceptance tests assert on. Shared by
/// [`RunMetrics::tuner_modes`] and the simulator's
/// `SimReport::decisions` consumers so "mode" means one thing
/// everywhere.
pub fn distinct_modes(trace: &[TunerDecision]) -> usize {
    let mut seen: Vec<(Schedule, Strategy, bool)> = Vec::new();
    for d in trace {
        if !seen.contains(&d.mode()) {
            seen.push(d.mode());
        }
    }
    seen.len()
}

/// Whole-run metrics returned by every engine.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// One entry per executed superstep.
    pub supersteps: Vec<SuperstepStats>,
    /// Total wall-clock time including setup and teardown.
    pub total_time: Duration,
    /// Why the run stopped.
    pub halt_reason: HaltReason,
    /// Whether this run recycled a pooled vertex store from its
    /// [`GraphSession`](../engine/session/struct.GraphSession.html)
    /// instead of allocating a fresh one.
    pub store_reused: bool,
    /// Shard count of the partitioned substrate (0 = flat execution).
    pub shards: usize,
    /// Max-over-mean shard edge load of the partition plan (1.0 ideal;
    /// 0.0 on flat runs, where no plan exists).
    pub shard_edge_imbalance: f64,
    /// Messages delivered inside their destination's own shard (flat
    /// runs: 0 — the split is only defined under partitioning).
    pub intra_shard_messages: u64,
    /// Messages that crossed shards through the remote buffers (push
    /// sends to foreign shards; pull combines from foreign outboxes).
    pub cross_shard_messages: u64,
    /// A documented scheduling fallback applied to this run, if any.
    pub schedule_fallback: Option<ScheduleFallback>,
    /// Graph mutation epoch the run executed against (0 = static graph
    /// or never mutated — see `graph/dynamic.rs`).
    pub graph_epoch: u64,
    /// Delta-overlay mutation instances live at run start (0 = fully
    /// compacted base CSR).
    pub delta_edges: u64,
    /// Overlay occupancy at run start: `delta_edges / num_edges`.
    pub delta_occupancy: f64,
    /// Whether the pooled vertex store carried an older mutation-epoch
    /// tag and had to be re-primed (epoch-tagged invalidation).
    pub store_epoch_refreshed: bool,
    /// Which delivery plane the run used: `Combined` (one foldable
    /// mailbox slot per vertex) or `Log` (per-vertex append-only logs).
    pub delivery_plane: DeliveryPlaneKind,
    /// Log-plane runs: message payloads retained individually in the
    /// per-vertex logs (every send survives to `Context::recv`). Always
    /// 0 on combined-plane runs.
    pub retained_messages: u64,
    /// Combined-plane runs: message payloads the combiner folded away —
    /// total sends (push) or combines (pull) minus the distinct payloads
    /// handed to `compute`. Always 0 on log-plane runs, whose point is
    /// that nothing is folded.
    pub combined_messages: u64,
    /// Whether a log-plane run recycled a pooled
    /// [`MessageLog`](../combine/plane/struct.MessageLog.html) from its
    /// session instead of allocating a fresh one (the plane analogue of
    /// [`RunMetrics::store_reused`]).
    pub plane_reused: bool,
    /// Whether the run re-decided its Schedule/Strategy/bypass knobs at
    /// every superstep barrier (`EngineConfig::adaptive`).
    pub adaptive: bool,
    /// Whether an adaptive run recycled pooled tuner state (per-worker
    /// contention probes + trace buffer) from its session.
    pub tuner_reused: bool,
    /// Adaptive runs: one entry per superstep — the knob plan applied and
    /// the signals that chose it. Empty on fixed-config runs.
    pub tuner_decisions: Vec<TunerDecision>,
    /// Successful work steals across the run (work-stealing shard
    /// dispatch only — 0 under fixed dispatch or flat execution).
    pub steals: u64,
    /// Vector-gather lanes scanned across the run (monoid Pull combines
    /// only; 0 when the vector path never engaged).
    pub vector_lanes_scanned: u64,
    /// Of [`RunMetrics::vector_lanes_scanned`], lanes that carried a
    /// message (the utilisation numerator).
    pub vector_lanes_useful: u64,
    /// Traced partitioned runs: cumulative *measured* execution time per
    /// shard (scatter + flush spans), indexed by shard id — the timing
    /// vector NUMA-aware placement consumes, as opposed to the edge-count
    /// estimates the deque cuts start from. Empty on untraced or flat
    /// runs.
    pub shard_times: Vec<Duration>,
    /// The run's event trace when [`EngineConfig::trace`] was set (and
    /// the `no-trace` feature is off): what `--trace-out` serialises and
    /// `--trace-summary` renders.
    ///
    /// [`EngineConfig::trace`]: crate::engine::EngineConfig::trace
    pub trace: Option<crate::trace::RunTrace>,
    /// Serving-layer context tag (`RunOptions::tag`) this run carried,
    /// echoed so multiplexed runs stay attributable. `None` on plain
    /// batch runs.
    pub query_tag: Option<u64>,
    /// Row-plane counters for this run (compressed/out-of-core adjacency
    /// only — `None` on raw-CSR runs): decode work, demand faults vs
    /// staged pins, evictions, and the residency gauges at run end. The
    /// cumulative counters are per-run deltas (`RowPlaneStats::delta_from`).
    pub row_plane: Option<crate::graph::RowPlaneStats>,
}

impl RunMetrics {
    /// Number of supersteps executed.
    pub fn num_supersteps(&self) -> usize {
        self.supersteps.len()
    }

    /// Total messages/combinations across the run.
    pub fn total_messages(&self) -> u64 {
        self.supersteps.iter().map(|s| s.messages).sum()
    }

    /// Sum of compute-phase times.
    pub fn compute_time(&self) -> Duration {
        self.supersteps.iter().map(|s| s.compute_time).sum()
    }

    /// Sum of cross-shard flush-phase times (zero on flat runs).
    pub fn flush_time(&self) -> Duration {
        self.supersteps.iter().map(|s| s.flush_time).sum()
    }

    /// Sum of the per-superstep active counts (total vertex activations).
    pub fn total_activations(&self) -> u64 {
        self.supersteps.iter().map(|s| s.active_vertices as u64).sum()
    }

    /// Adaptive runs: supersteps whose knob plan differed from the
    /// previous superstep's (0 on fixed-config runs).
    pub fn tuner_switches(&self) -> usize {
        self.tuner_decisions.iter().filter(|d| d.switched).count()
    }

    /// Adaptive runs: distinct (schedule, strategy, bypass) modes the
    /// tuner selected across the run (0 on fixed-config runs).
    pub fn tuner_modes(&self) -> usize {
        distinct_modes(&self.tuner_decisions)
    }

    /// Compact single-line summary.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "supersteps={} activations={} messages={} compute={} total={}",
            self.num_supersteps(),
            self.total_activations(),
            self.total_messages(),
            crate::util::timer::fmt_duration(self.compute_time()),
            crate::util::timer::fmt_duration(self.total_time),
        );
        if self.shards > 0 {
            // Partitioned runs always print flush time and steal count —
            // explicit zeros included — so this line and a trace summary
            // of the same run never disagree on which fields exist.
            s.push_str(&format!(
                " shards={} cross={} imbalance={:.2} flush={} steals={}",
                self.shards,
                self.cross_shard_messages,
                self.shard_edge_imbalance,
                crate::util::timer::fmt_duration(self.flush_time()),
                self.steals
            ));
        }
        if self.delivery_plane == DeliveryPlaneKind::Log {
            s.push_str(&format!(" plane=log retained={}", self.retained_messages));
        }
        if self.graph_epoch > 0 || self.delta_edges > 0 {
            s.push_str(&format!(
                " epoch={} delta={} (occ {:.1}%)",
                self.graph_epoch,
                self.delta_edges,
                self.delta_occupancy * 100.0
            ));
        }
        if self.adaptive {
            s.push_str(&format!(
                " adaptive switches={} modes={}",
                self.tuner_switches(),
                self.tuner_modes()
            ));
        }
        if self.shards == 0 && self.steals > 0 {
            // Flat runs cannot steal, but defensively keep the section
            // for any metrics assembled by hand.
            s.push_str(&format!(" steals={}", self.steals));
        }
        if self.vector_lanes_scanned > 0 {
            s.push_str(&format!(
                " lanes={}/{}",
                self.vector_lanes_useful, self.vector_lanes_scanned
            ));
        }
        if let Some(rp) = &self.row_plane {
            s.push_str(&format!(
                " rows[decodes={} faults={} evictions={} resident={}KiB ratio={:.2}x]",
                rp.decodes,
                rp.row_faults,
                rp.evictions,
                rp.resident_bytes / 1024,
                rp.compression_ratio()
            ));
        }
        if let Some(fb) = &self.schedule_fallback {
            s.push_str(&format!(" fallback=[{fb}]"));
        }
        if let Some(tag) = self.query_tag {
            s.push_str(&format!(" tag={tag}"));
        }
        s
    }
}

/// Per-query record emitted by the serving layer (`serve/`): one entry
/// per admitted query, pairing the engine's [`RunMetrics`] view with the
/// serving-side timings the engine cannot see (queue wait, end-to-end
/// latency) and the admission identity (tag, priority class).
#[derive(Clone, Debug)]
pub struct QueryMetrics {
    /// Server-assigned query id (admission order).
    pub id: u64,
    /// Caller-chosen context tag (threaded into trace instants and
    /// [`RunMetrics::query_tag`]).
    pub tag: u64,
    /// Priority-class label (`"interactive"` / `"batch"`).
    pub class: &'static str,
    /// Time spent queued in admission before the run started.
    pub queue_wait: Duration,
    /// Engine run time ([`RunMetrics::total_time`]).
    pub run_time: Duration,
    /// End-to-end latency: queue wait + run time.
    pub latency: Duration,
    /// Supersteps the run executed.
    pub supersteps: usize,
    /// Why the run stopped (budget exhaustion included).
    pub halt_reason: HaltReason,
    /// Graph mutation epoch the query's snapshot was pinned to.
    pub epoch: u64,
    /// Whether the run was served from a pooled (warm) vertex store.
    pub store_reused: bool,
}

/// Order statistics over a set of latencies — the serving layer's
/// tail-latency view (p50/p99 are the numbers `ipregel serve` and
/// `bench_serve` report).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencyStats {
    /// Sample count.
    pub count: usize,
    /// Median, nanoseconds.
    pub p50_ns: u64,
    /// 99th percentile, nanoseconds.
    pub p99_ns: u64,
    /// Arithmetic mean, nanoseconds.
    pub mean_ns: u64,
    /// Maximum, nanoseconds.
    pub max_ns: u64,
}

impl LatencyStats {
    /// Stats over raw nanosecond samples. Empty input yields all zeros.
    pub fn from_nanos(samples: &[u64]) -> LatencyStats {
        if samples.is_empty() {
            return LatencyStats::default();
        }
        let mut sorted = samples.to_vec();
        sorted.sort_unstable();
        // Nearest-rank percentile: ceil(p/100 * n) - 1, clamped — p50 of
        // a single sample is that sample, p99 of < 100 samples is max.
        let rank = |p: u64| -> u64 {
            let n = sorted.len() as u64;
            let idx = (p * n).div_ceil(100).saturating_sub(1).min(n - 1);
            sorted[idx as usize]
        };
        let sum: u128 = sorted.iter().map(|&s| s as u128).sum();
        LatencyStats {
            count: sorted.len(),
            p50_ns: rank(50),
            p99_ns: rank(99),
            mean_ns: (sum / sorted.len() as u128) as u64,
            max_ns: sorted[sorted.len() - 1],
        }
    }

    /// Stats over [`Duration`] samples.
    pub fn from_durations(samples: &[Duration]) -> LatencyStats {
        let ns: Vec<u64> = samples.iter().map(|d| d.as_nanos() as u64).collect();
        LatencyStats::from_nanos(&ns)
    }

    /// Median as a [`Duration`].
    pub fn p50(&self) -> Duration {
        Duration::from_nanos(self.p50_ns)
    }

    /// 99th percentile as a [`Duration`].
    pub fn p99(&self) -> Duration {
        Duration::from_nanos(self.p99_ns)
    }

    /// Mean as a [`Duration`].
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns)
    }

    /// Maximum as a [`Duration`].
    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns)
    }
}

/// Fixed-width table printer used by `info`, `table1` and `table2` output.
pub struct TablePrinter {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TablePrinter {
    /// Table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        TablePrinter {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header count).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with padded columns, first column left-aligned, rest right.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i == 0 {
                    line.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
                } else {
                    line.push_str(&format!("  {:>w$}", cells[i], w = widths[i]));
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let total_w: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total_w));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_aggregation() {
        let m = RunMetrics {
            supersteps: vec![
                SuperstepStats {
                    active_vertices: 10,
                    messages: 100,
                    compute_time: Duration::from_millis(5),
                    flush_time: Duration::from_millis(1),
                    barrier_time: Duration::from_millis(1),
                },
                SuperstepStats {
                    active_vertices: 4,
                    messages: 7,
                    compute_time: Duration::from_millis(2),
                    flush_time: Duration::ZERO,
                    barrier_time: Duration::from_millis(1),
                },
            ],
            total_time: Duration::from_millis(10),
            ..Default::default()
        };
        assert_eq!(m.num_supersteps(), 2);
        assert_eq!(m.total_messages(), 107);
        assert_eq!(m.total_activations(), 14);
        assert_eq!(m.compute_time(), Duration::from_millis(7));
        assert_eq!(m.flush_time(), Duration::from_millis(1));
        assert!(m.summary().contains("supersteps=2"));
        // Flat run: no shard section in the summary.
        assert!(!m.summary().contains("shards="));
        let sharded = RunMetrics {
            shards: 8,
            cross_shard_messages: 42,
            shard_edge_imbalance: 1.25,
            schedule_fallback: Some(ScheduleFallback::EdgeCentricBypassRebuild),
            ..Default::default()
        };
        let s = sharded.summary();
        assert!(s.contains("shards=8"));
        assert!(s.contains("cross=42"));
        // Partitioned runs print flush/steals even when zero, so the
        // summary and a trace summary never disagree on field presence.
        assert!(s.contains("flush="), "explicit flush on partitioned runs: {s}");
        assert!(s.contains("steals=0"), "explicit zero steals: {s}");
        assert!(s.contains("fallback="));
        assert!(!s.contains("epoch="), "static run omits the epoch section");
        let dynamic = RunMetrics {
            graph_epoch: 3,
            delta_edges: 12,
            delta_occupancy: 0.05,
            ..Default::default()
        };
        let d = dynamic.summary();
        assert!(d.contains("epoch=3"));
        assert!(d.contains("delta=12"));
    }

    #[test]
    fn log_plane_gets_its_own_summary_section() {
        assert_eq!(DeliveryPlaneKind::default(), DeliveryPlaneKind::Combined);
        assert_eq!(format!("{}", DeliveryPlaneKind::Log), "log");
        let m = RunMetrics {
            delivery_plane: DeliveryPlaneKind::Log,
            retained_messages: 9,
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("plane=log"));
        assert!(s.contains("retained=9"));
        // Combined runs (the default) show no plane section.
        assert!(!RunMetrics::default().summary().contains("plane="));
    }

    #[test]
    fn adaptive_runs_get_a_tuner_summary_section() {
        let d = |superstep: usize, bypass: bool, switched: bool| TunerDecision {
            superstep,
            schedule: Schedule::Static,
            strategy: Strategy::Lock,
            bypass,
            frontier_density: 0.1,
            msgs_per_active: 1.0,
            fan_in: 1.0,
            contention_per_msg: 0.0,
            flush_imbalance: 1.0,
            steals: 0,
            lane_utilisation: 1.0,
            pipeline_depth: 8,
            steal_chunk: 1,
            switched,
        };
        let m = RunMetrics {
            adaptive: true,
            tuner_decisions: vec![d(0, false, false), d(1, true, true), d(2, true, false)],
            ..Default::default()
        };
        assert_eq!(m.tuner_switches(), 1);
        assert_eq!(m.tuner_modes(), 2, "scan and list variants of the same knobs");
        assert_eq!(m.tuner_decisions[1].mode(), (Schedule::Static, Strategy::Lock, true));
        let s = m.summary();
        assert!(s.contains("adaptive switches=1 modes=2"));
        // Fixed-config runs show no adaptive section and count no modes.
        assert!(!RunMetrics::default().summary().contains("adaptive"));
        assert_eq!(RunMetrics::default().tuner_modes(), 0);
    }

    #[test]
    fn steal_and_lane_sections_appear_only_when_nonzero() {
        let m = RunMetrics {
            steals: 12,
            vector_lanes_scanned: 100,
            vector_lanes_useful: 40,
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("steals=12"));
        assert!(s.contains("lanes=40/100"));
        let quiet = RunMetrics::default().summary();
        assert!(!quiet.contains("steals="));
        assert!(!quiet.contains("lanes="));
    }

    #[test]
    fn row_plane_section_appears_only_on_plane_backed_runs() {
        let m = RunMetrics {
            row_plane: Some(crate::graph::RowPlaneStats {
                decodes: 5,
                row_faults: 2,
                evictions: 1,
                resident_bytes: 2048,
                encoded_bytes: 100,
                raw_adj_bytes: 250,
                ..Default::default()
            }),
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("rows[decodes=5 faults=2 evictions=1"));
        assert!(s.contains("resident=2KiB"));
        assert!(s.contains("ratio=2.50x"));
        assert!(!RunMetrics::default().summary().contains("rows["));
    }

    #[test]
    fn latency_stats_order_statistics() {
        assert_eq!(LatencyStats::from_nanos(&[]), LatencyStats::default());
        let one = LatencyStats::from_nanos(&[7]);
        assert_eq!((one.count, one.p50_ns, one.p99_ns, one.max_ns), (1, 7, 7, 7));
        // 1..=100: nearest-rank p50 is the 50th sample, p99 the 99th.
        let samples: Vec<u64> = (1..=100).collect();
        let s = LatencyStats::from_nanos(&samples);
        assert_eq!(s.count, 100);
        assert_eq!(s.p50_ns, 50);
        assert_eq!(s.p99_ns, 99);
        assert_eq!(s.mean_ns, 50); // (5050 / 100) truncated
        assert_eq!(s.max_ns, 100);
        // Under 100 samples the p99 collapses to the max.
        let few = LatencyStats::from_nanos(&[10, 30, 20]);
        assert_eq!(few.p99_ns, 30);
        assert_eq!(few.p50_ns, 20);
        let d = LatencyStats::from_durations(&[Duration::from_micros(3)]);
        assert_eq!(d.p50(), Duration::from_micros(3));
    }

    #[test]
    fn budget_and_tag_surface_in_metrics() {
        let m = RunMetrics {
            halt_reason: HaltReason::BudgetExhausted,
            query_tag: Some(17),
            ..Default::default()
        };
        assert_eq!(m.halt_reason, HaltReason::BudgetExhausted);
        assert!(m.summary().contains("tag=17"));
        // Untagged batch runs keep their summary unchanged.
        assert!(!RunMetrics::default().summary().contains("tag="));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = TablePrinter::new(&["name", "count"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "12345".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[3].ends_with("12345"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = TablePrinter::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
