//! Cost-model calibration from host microbenchmarks.
//!
//! `ipregel calibrate` measures the synchronisation and memory primitives
//! the [`CostModel`](crate::sim::CostModel) prices, on the actual host,
//! and prints a model ready to paste into `CostModel::default()` (the
//! compiled-in defaults were produced this way — see EXPERIMENTS.md
//! §Calibration).

use crate::combine::{MinCombiner, MsgSlot, SpinLock, Strategy};
use crate::sim::CostModel;
use crate::util::rng::Rng;
use crate::util::timer::ns_per_iter;

/// Measured primitive costs.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// ns per uncontended CAS delivery (hybrid steady state).
    pub cas_ns: f64,
    /// ns per uncontended lock delivery.
    pub lock_ns: f64,
    /// ns per cached sequential slot access.
    pub hit_ns: f64,
    /// ns per random DRAM access beyond LLC.
    pub miss_ns: f64,
    /// ns per atomic chunk claim.
    pub claim_ns: f64,
}

/// Run the microbenchmarks. `scale` shrinks iteration counts for tests
/// (1 = full calibration, ~a second of wall time).
pub fn calibrate(scale: usize) -> Calibration {
    let iters = (2_000_000 / scale.max(1)).max(1000);

    // -- CAS delivery: steady-state hybrid combine on a populated slot.
    let slot: MsgSlot<u64> = MsgSlot::new();
    slot.store_first(u64::MAX);
    let mut x = 0u64;
    let cas_ns = ns_per_iter(iters, || {
        x = x.wrapping_add(0x9E3779B9);
        Strategy::Hybrid.deliver(&slot, x | 1, &MinCombiner);
    });

    // -- Lock delivery: same combine through the lock path.
    let slot2: MsgSlot<u64> = MsgSlot::new();
    slot2.store_first(u64::MAX);
    let mut y = 0u64;
    let lock_ns = ns_per_iter(iters, || {
        y = y.wrapping_add(0x9E3779B9);
        Strategy::Lock.deliver(&slot2, y | 1, &MinCombiner);
    });

    // -- Cached access: sequential scan of a small slot array.
    let small: Vec<u64> = (0..1024u64).collect();
    let mut acc = 0u64;
    let mut i = 0usize;
    let hit_ns = ns_per_iter(iters, || {
        acc = acc.wrapping_add(small[i & 1023]);
        i += 1;
    });

    // -- Random DRAM access: index into a buffer several times the LLC.
    let big_len = (96 * 1024 * 1024 / 8) / scale.max(1).min(8);
    let big: Vec<u64> = vec![1; big_len.max(1024)];
    let mut rng = Rng::new(7);
    let idx: Vec<usize> = (0..65_536)
        .map(|_| rng.below(big.len() as u64) as usize)
        .collect();
    let mut j = 0usize;
    let miss_total_ns = ns_per_iter(iters.min(500_000), || {
        acc = acc.wrapping_add(big[idx[j & 0xFFFF]]);
        j += 1;
    });
    let miss_ns = (miss_total_ns - hit_ns).max(10.0);

    // -- Chunk claim: fetch_add on a shared counter.
    let counter = std::sync::atomic::AtomicUsize::new(0);
    let claim_ns = ns_per_iter(iters, || {
        counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    });
    std::hint::black_box((acc, &slot, &slot2));
    let _ = SpinLock::new(); // keep the import honest

    Calibration {
        cas_ns,
        lock_ns,
        hit_ns: hit_ns.max(0.3),
        miss_ns,
        claim_ns: claim_ns.max(1.0),
    }
}

impl Calibration {
    /// Fold the measurements into a [`CostModel`] (contention parameters
    /// keep their analytic defaults — they model cross-thread effects a
    /// single-core host cannot measure directly).
    pub fn to_cost_model(&self) -> CostModel {
        CostModel {
            t_access_hit: self.hit_ns,
            t_miss: self.miss_ns,
            t_lock: self.lock_ns,
            t_cas: self.cas_ns,
            t_crit: self.lock_ns * 0.6,
            t_cas_retry: self.cas_ns * 0.7,
            t_chunk_claim: self.claim_ns.max(8.0),
            ..CostModel::default()
        }
    }

    /// Render for the CLI.
    pub fn render(&self) -> String {
        format!(
            "calibration (host-measured):\n\
             \u{20}  cas delivery    {:>8.2} ns\n\
             \u{20}  lock delivery   {:>8.2} ns\n\
             \u{20}  cached access   {:>8.2} ns\n\
             \u{20}  dram miss       {:>8.2} ns\n\
             \u{20}  chunk claim     {:>8.2} ns",
            self.cas_ns, self.lock_ns, self.hit_ns, self.miss_ns, self.claim_ns
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_produces_sane_orderings() {
        let c = calibrate(64); // fast, reduced iterations
        assert!(c.cas_ns > 0.0 && c.lock_ns > 0.0);
        // Lock path (acquire+check+store+release) costs at least as much
        // as the steady-state CAS path.
        assert!(
            c.lock_ns >= c.cas_ns * 0.8,
            "lock {} vs cas {}",
            c.lock_ns,
            c.cas_ns
        );
        // A DRAM miss dwarfs a cache hit.
        assert!(c.miss_ns > c.hit_ns * 3.0, "miss {} hit {}", c.miss_ns, c.hit_ns);
        let m = c.to_cost_model();
        assert!(m.t_lock > 0.0 && m.t_cas > 0.0 && m.t_chunk_claim >= 8.0);
        assert!(c.render().contains("cas delivery"));
    }
}
