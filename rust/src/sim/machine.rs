//! The virtual parallel machine: per-thread clocks + schedule-faithful
//! chunk assignment.

use crate::sched::Schedule;

/// A `threads`-wide virtual machine accumulating virtual nanoseconds.
#[derive(Clone, Debug)]
pub struct VirtualMachine {
    /// Number of virtual worker threads (the paper's experiments use 32).
    pub threads: usize,
    /// Total virtual time elapsed (ns) — the running makespan.
    pub clock_ns: f64,
}

impl VirtualMachine {
    /// New machine with all clocks at zero.
    pub fn new(threads: usize) -> Self {
        VirtualMachine {
            threads: threads.max(1),
            clock_ns: 0.0,
        }
    }

    /// Execute one parallel region: items `0..costs.len()` with the given
    /// per-item costs (ns), distributed by `sched`. Advances the global
    /// clock by the region's makespan and returns it, along with the
    /// imbalance ratio (makespan / mean-thread-time).
    ///
    /// Pre-partitioned schedules assign chunk `t` to thread `t`.
    /// FCFS schedules replay OpenMP dynamic semantics exactly: each chunk
    /// is claimed by the virtual thread whose clock is lowest when the
    /// chunk reaches the head of the queue, paying the claim cost.
    pub fn region(
        &mut self,
        sched: Schedule,
        costs: &[f64],
        weights: Option<&[u64]>,
        t_chunk_claim: f64,
    ) -> RegionStats {
        self.region_profile(sched, costs, weights, t_chunk_claim).0
    }

    /// [`VirtualMachine::region`] exposing the per-thread busy times the
    /// assignment produced (ns, one entry per virtual thread) — the
    /// observability plane turns them into per-worker spans on the
    /// virtual timeline.
    pub fn region_profile(
        &mut self,
        sched: Schedule,
        costs: &[f64],
        weights: Option<&[u64]>,
        t_chunk_claim: f64,
    ) -> (RegionStats, Vec<f64>) {
        let n = costs.len();
        let mut tclock = vec![0.0f64; self.threads];
        if n > 0 {
            let chunks = sched.chunks(n, self.threads, weights);
            if sched.is_fcfs() {
                // Greedy earliest-free-thread assignment == FCFS claiming.
                for r in chunks {
                    let (t, _) = tclock
                        .iter()
                        .enumerate()
                        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap();
                    let chunk_cost: f64 = costs[r].iter().sum();
                    tclock[t] += t_chunk_claim + chunk_cost;
                }
            } else {
                for (t, r) in chunks.into_iter().enumerate() {
                    let chunk_cost: f64 = costs[r].iter().sum();
                    tclock[t.min(self.threads - 1)] += chunk_cost;
                }
            }
        }
        let makespan = tclock.iter().copied().fold(0.0, f64::max);
        let busy: f64 = tclock.iter().sum();
        let mean = busy / self.threads as f64;
        self.clock_ns += makespan;
        (
            RegionStats {
                makespan_ns: makespan,
                imbalance: if mean > 0.0 { makespan / mean } else { 1.0 },
                busy_ns: busy,
            },
            tclock,
        )
    }

    /// Re-price an already-charged region as if work-stealing had run
    /// over it (see `sched/steal.rs`): drained workers claim whole items
    /// from the most-loaded peer, so the makespan contracts toward the
    /// ideal per-thread mean — floored by the largest indivisible item,
    /// since a single shard never splits across thieves — plus the claim
    /// traffic the steals add (`t_steal` per migrated item, amortised
    /// across the team because thieves CAS concurrently). Refunds the
    /// recovered time from the global clock and returns the adjusted
    /// stats together with the estimated steal count.
    pub fn steal_rebalance(
        &mut self,
        stats: RegionStats,
        max_item: f64,
        items: usize,
        t_steal: f64,
    ) -> (RegionStats, u64) {
        if stats.makespan_ns <= 0.0 || items == 0 {
            return (stats, 0);
        }
        let mean = stats.busy_ns / self.threads as f64;
        let balanced = mean.max(max_item);
        if balanced >= stats.makespan_ns {
            return (stats, 0);
        }
        // Items that must migrate: the fraction of the region's time the
        // original assignment stranded on overloaded workers, expressed
        // in items. Deterministic — the real engine reports measured
        // steal counts; the model only needs the same order of magnitude
        // so the tuner's episode-length rule fires consistently.
        let est = ((1.0 - balanced / stats.makespan_ns) * items as f64).ceil() as u64;
        let makespan =
            (balanced + est as f64 * t_steal / self.threads as f64).min(stats.makespan_ns);
        self.clock_ns -= stats.makespan_ns - makespan;
        let busy = stats.busy_ns + est as f64 * t_steal;
        let mean = busy / self.threads as f64;
        (
            RegionStats {
                makespan_ns: makespan,
                imbalance: if mean > 0.0 { makespan / mean } else { 1.0 },
                busy_ns: busy,
            },
            est,
        )
    }

    /// Charge a serial section (runs on one thread while others wait).
    pub fn serial(&mut self, ns: f64) {
        self.clock_ns += ns;
    }

    /// Virtual seconds elapsed.
    pub fn seconds(&self) -> f64 {
        self.clock_ns / 1e9
    }
}

/// Statistics of one parallel region.
#[derive(Clone, Copy, Debug)]
pub struct RegionStats {
    /// The region's wall time on the virtual machine.
    pub makespan_ns: f64,
    /// makespan / mean-per-thread-busy-time, ≥ 1; 1 = perfect balance.
    pub imbalance: f64,
    /// Total busy ns across threads.
    pub busy_ns: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfectly_uniform_static_is_balanced() {
        let mut vm = VirtualMachine::new(4);
        let costs = vec![1.0; 400];
        let s = vm.region(Schedule::Static, &costs, None, 0.0);
        assert!((s.makespan_ns - 100.0).abs() < 1e-9);
        assert!((s.imbalance - 1.0).abs() < 1e-9);
        assert!((vm.clock_ns - 100.0).abs() < 1e-9);
    }

    #[test]
    fn static_suffers_from_skew_dynamic_recovers() {
        // One hot item at the front of the range: static gives thread 0
        // the hot item plus a quarter of the rest; dynamic spreads the
        // rest across the other threads while thread 0 chews the hot one.
        let mut costs = vec![1.0; 1024];
        costs[0] = 1000.0;
        let mut vm_s = VirtualMachine::new(4);
        let st = vm_s.region(Schedule::Static, &costs, None, 0.0);
        let mut vm_d = VirtualMachine::new(4);
        let dy = vm_d.region(Schedule::Dynamic { chunk: 16 }, &costs, None, 0.0);
        assert!(
            dy.makespan_ns < st.makespan_ns * 0.85,
            "dynamic {dy:?} vs static {st:?}"
        );
        assert!(dy.imbalance < st.imbalance);
    }

    #[test]
    fn edge_centric_balances_weighted_skew() {
        // Item cost proportional to weight (degree) — the edge-centric
        // premise. Static-by-count is imbalanced; edge-centric fixes it.
        let weights: Vec<u64> = (0..1000u64).map(|i| if i < 10 { 500 } else { 1 }).collect();
        let costs: Vec<f64> = weights.iter().map(|&w| w as f64).collect();
        let mut vm_s = VirtualMachine::new(4);
        let st = vm_s.region(Schedule::Static, &costs, None, 0.0);
        let mut vm_e = VirtualMachine::new(4);
        let ec = vm_e.region(Schedule::EdgeCentric, &costs, Some(&weights), 0.0);
        assert!(
            ec.makespan_ns < st.makespan_ns * 0.7,
            "edge-centric {ec:?} vs static {st:?}"
        );
    }

    #[test]
    fn chunk_claim_cost_penalises_tiny_chunks() {
        let costs = vec![10.0; 10_000];
        let mut vm_small = VirtualMachine::new(8);
        let small = vm_small.region(Schedule::Dynamic { chunk: 1 }, &costs, None, 25.0);
        let mut vm_big = VirtualMachine::new(8);
        let big = vm_big.region(Schedule::Dynamic { chunk: 256 }, &costs, None, 25.0);
        assert!(big.makespan_ns < small.makespan_ns);
    }

    #[test]
    fn steal_rebalance_recovers_skew_but_not_below_the_largest_item() {
        // One hot shard on a static split: stealing lets idle threads
        // drain the rest, but the hot shard itself is indivisible.
        let costs = vec![1000.0, 10.0, 10.0, 10.0, 10.0, 10.0, 10.0, 10.0];
        let mut vm = VirtualMachine::new(4);
        let st = vm.region(Schedule::Static, &costs, None, 0.0);
        let before = vm.clock_ns;
        let (re, steals) = vm.steal_rebalance(st, 1000.0, costs.len(), 6.0);
        assert!(re.makespan_ns >= 1000.0, "floored by the hot shard");
        assert!(re.makespan_ns < st.makespan_ns, "but strictly recovers");
        assert!(steals > 0, "migration happened");
        assert!(vm.clock_ns < before, "recovered time refunded");
        assert!(re.imbalance <= st.imbalance + 1e-9);
    }

    #[test]
    fn steal_rebalance_is_a_no_op_on_balanced_regions() {
        let costs = vec![5.0; 64];
        let mut vm = VirtualMachine::new(4);
        let st = vm.region(Schedule::Static, &costs, None, 0.0);
        let before = vm.clock_ns;
        let (re, steals) = vm.steal_rebalance(st, 5.0, costs.len(), 6.0);
        assert_eq!(steals, 0, "nothing to migrate");
        assert!((re.makespan_ns - st.makespan_ns).abs() < 1e-9);
        assert_eq!(vm.clock_ns, before);
    }

    #[test]
    fn serial_section_advances_clock() {
        let mut vm = VirtualMachine::new(8);
        vm.serial(5000.0);
        assert_eq!(vm.clock_ns, 5000.0);
        assert!((vm.seconds() - 5e-6).abs() < 1e-12);
    }

    #[test]
    fn empty_region_is_free_except_nothing() {
        let mut vm = VirtualMachine::new(4);
        let s = vm.region(Schedule::Dynamic { chunk: 4 }, &[], None, 25.0);
        assert_eq!(s.makespan_ns, 0.0);
        assert_eq!(vm.clock_ns, 0.0);
    }
}
