//! The virtual testbed: a calibrated machine model reproducing the
//! paper's 32-thread experiments on a single-core host.
//!
//! **Why this exists.** The paper's evaluation ran on 2×18-core Xeons;
//! this environment has one core, so the parallel phenomena Table II
//! measures — load imbalance across threads, lock/CAS contention, cache
//! pollution — cannot be observed as wall-clock here. They are, however,
//! *structural* properties of how work is distributed and synchronised,
//! so we reproduce them in **virtual time**:
//!
//! 1. [`engine::SimEngine`] executes the *real* algorithm serially
//!    (actual deliveries, actual convergence — results are
//!    cross-validated against the real engine), while recording the work
//!    profile of every vertex: combinations performed, messages sent,
//!    recipients' fan-in, bytes touched.
//! 2. [`CostModel`] prices each work item in nanoseconds, using constants
//!    calibrated from microbenchmarks on this host
//!    ([`calibrate::calibrate`]) — CAS cost, lock cost, cache hit/miss
//!    costs, chunk-claim cost.
//! 3. [`machine::VirtualMachine`] distributes the priced items to 32
//!    virtual threads with *exactly* the chunk semantics of the real
//!    schedules ([`crate::sched::Schedule::chunks`]) and advances
//!    per-thread clocks; the superstep's virtual duration is the makespan.
//!
//! Speed-ups in the reproduced Table II are ratios of virtual times, so
//! only *relative* model fidelity matters, not absolute nanoseconds.

pub mod calibrate;
pub mod engine;
pub mod machine;

pub use engine::{SimEngine, SimReport};
pub use machine::VirtualMachine;

use crate::combine::Strategy;
use crate::layout::Layout;

/// Calibrated cost constants (nanoseconds of virtual time).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Fixed per-vertex compute overhead (loop + call + user logic).
    pub t_vertex: f64,
    /// Reading a hot slot that is resident in cache (pull scan hit).
    pub t_access_hit: f64,
    /// DRAM penalty for a missed cache line.
    pub t_miss: f64,
    /// Applying the user combine function once.
    pub t_combine: f64,
    /// Uncontended lock acquire+release pair.
    pub t_lock: f64,
    /// The lock-held critical section (check + combine + store) —
    /// waiters spin for this long per contender ahead of them.
    pub t_crit: f64,
    /// One uncontended CAS (load + combine + compare-exchange).
    pub t_cas: f64,
    /// Extra cost of one CAS retry (re-load + re-combine + retry).
    pub t_cas_retry: f64,
    /// Probability that one *concurrent* contender forces a retry.
    pub cas_retry_rate: f64,
    /// Claiming one FCFS chunk from the shared atomic counter.
    pub t_chunk_claim: f64,
    /// Claiming one stolen work item from a peer's deque: a CAS on the
    /// victim's top cursor plus the seq-cst fence the Chase-Lev protocol
    /// needs (see `sched/steal.rs`). Priced between a bare CAS and a
    /// chunk claim — the steal also drags the victim's cursor line over.
    pub t_steal: f64,
    /// Storing one word (activation bit, outbox clear, list append).
    pub t_store: f64,
    /// Appending one message to a log-plane worker segment (payload
    /// store + length bump; contention-free by construction, so no
    /// lock/CAS term — the log plane's delivery cost is paid here and
    /// in the serial barrier merge instead of in synchronisation).
    pub t_log_append: f64,
    /// Per-superstep synchronisation (fork/join of the thread team).
    pub t_superstep_sync: f64,
    /// Mid-level (L2) cache capacity in bytes.
    pub l2_bytes: f64,
    /// Extra latency of an L2-capacity miss served by the LLC.
    pub t_l2_miss: f64,
    /// Last-level cache capacity in bytes (capacity-miss threshold).
    pub llc_bytes: f64,
    /// Cache line size in bytes.
    pub line_bytes: f64,
    /// Per-edge varint decode cost when a row block materialises
    /// (compressed/out-of-core planes — `graph/rows.rs`): shift/or/add
    /// chain plus the append, sequential-access friendly.
    pub t_decode: f64,
    /// Fixed per-block residency-miss overhead on first touch: slot CAS,
    /// pool pop, span lookup (plus, for the on-disk arena, the syscall
    /// setup — the streamed bytes themselves are priced via `t_decode`).
    pub t_row_fault: f64,
}

impl Default for CostModel {
    /// Constants measured on this host by `ipregel calibrate` (see
    /// EXPERIMENTS.md §Calibration); kept as compiled-in defaults so
    /// simulated experiments are deterministic and reproducible.
    fn default() -> Self {
        CostModel {
            t_vertex: 4.0,
            t_access_hit: 2.0,
            // Misses are priced at *throughput*, not latency: the pull
            // scan issues independent loads, so out-of-order cores keep
            // ~7-8 misses in flight. The measured 75 ns latency
            // (`ipregel calibrate`) divided by that MLP factor gives the
            // effective per-access cost a bandwidth-bound loop sees.
            t_miss: 10.0,
            t_combine: 1.5,
            t_lock: 26.0,
            t_crit: 16.0,
            t_cas: 5.0,
            t_cas_retry: 3.5,
            cas_retry_rate: 0.25,
            t_chunk_claim: 13.0,
            t_steal: 6.0,
            t_store: 1.0,
            t_log_append: 2.0,
            t_superstep_sync: 5_000.0,
            l2_bytes: 1024.0 * 1024.0,
            t_l2_miss: 3.0,
            llc_bytes: 32.0 * 1024.0 * 1024.0,
            line_bytes: 64.0,
            t_decode: 1.2,
            t_row_fault: 120.0,
        }
    }
}

impl CostModel {
    /// Capacity-miss probability for uniformly random accesses into a
    /// working set of `ws` bytes against a cache of `capacity` bytes.
    #[inline]
    fn capacity_miss(ws_bytes: f64, capacity: f64) -> f64 {
        if ws_bytes <= capacity {
            0.02 // cold/compulsory floor
        } else {
            (1.0 - capacity / ws_bytes).clamp(0.02, 0.98)
        }
    }

    /// LLC miss probability (DRAM-bound fraction).
    #[inline]
    pub fn miss_rate(&self, ws_bytes: f64) -> f64 {
        Self::capacity_miss(ws_bytes, self.llc_bytes)
    }

    /// Cost of one random access into a working set of `ws` bytes,
    /// through the two modelled cache levels. A larger per-vertex stride
    /// (interleaved layout) inflates `ws`, raising both miss terms — the
    /// §IV mechanism.
    #[inline]
    pub fn random_access(&self, ws_bytes: f64) -> f64 {
        self.t_access_hit
            + Self::capacity_miss(ws_bytes, self.l2_bytes) * self.t_l2_miss
            + Self::capacity_miss(ws_bytes, self.llc_bytes) * self.t_miss
    }

    /// Fraction of the capacity-miss penalty hidden by a software
    /// prefetch pipeline issuing `depth` slots ahead (the staged scatter
    /// pipeline of `engine/core.rs`, DESIGN §2.9). Each in-flight
    /// prefetch overlaps roughly one hit-time of useful work with the
    /// outstanding miss, and coverage saturates smoothly below 1.0 —
    /// the prefetch stream competes for the same bandwidth the demand
    /// stream needs, so it can never hide the miss entirely (which also
    /// keeps the layout orderings of §IV intact under any depth).
    #[inline]
    pub fn prefetch_cover(&self, depth: usize) -> f64 {
        let ahead = depth as f64 * self.t_access_hit;
        ahead / (ahead + self.t_miss)
    }

    /// [`Self::random_access`] under a prefetch pipeline of `depth`:
    /// the hit term is untouched, the miss terms shrink by the coverage
    /// fraction.
    #[inline]
    pub fn prefetched_access(&self, ws_bytes: f64, depth: usize) -> f64 {
        let miss = self.random_access(ws_bytes) - self.t_access_hit;
        self.t_access_hit + miss * (1.0 - self.prefetch_cover(depth))
    }

    /// Effective per-vertex hot-data stride for a layout: how many bytes
    /// a neighbour-slot access drags into cache. The interleaved record
    /// spans value + metadata + two slots (≥ 64 B ⇒ a full line per
    /// access); the externalised slot is 16 B (4 per line).
    #[inline]
    pub fn layout_stride(&self, layout: Layout) -> f64 {
        match layout {
            Layout::Interleaved => 64.0,
            Layout::Externalised => 16.0,
        }
    }

    /// Average cost of delivering one of `c` messages that a recipient
    /// receives in a superstep of `total` deliveries, for `threads`
    /// workers.
    ///
    /// Contention is *temporal*: of the `c` messages aimed at this
    /// mailbox, only those in flight at the same instant collide. With
    /// `threads` deliveries in flight at any moment, spread over `total`
    /// mailbox operations, the expected concurrent senders to this
    /// mailbox is `c·threads/total`, capped by both `c` and the team
    /// size. (When one mailbox receives *all* traffic — the stress-test
    /// case — this degenerates to `min(c, threads)`.)
    ///
    /// - Lock: every delivery pays the lock pair and waits, on average,
    ///   behind half the other concurrent contenders' critical sections.
    /// - CAS-neutral: one CAS, retrying with probability proportional to
    ///   concurrent contenders.
    /// - Hybrid: the *first* push pays the lock path once; the remaining
    ///   `c-1` deliveries are pure CAS — the paper Fig. 1 design. Its
    ///   *uncontended* edge over Lock (one atomic op vs a lock pair) is
    ///   what grows with the graph's edge count, the paper's §VII-A
    ///   explanation.
    /// Virtual duration of one balanced combined-plane push superstep:
    /// `active` vertices computed and `messages` delivered (priced at the
    /// uncontended CAS + combine each), spread across `threads`, plus the
    /// team synchronisation. The serving layer's pricing unit: the
    /// interleave policy (`serve/sched.rs`) slices large runs so that a
    /// queued interactive query waits a bounded number of *these* —
    /// calibrated from the same constants the Table II simulations use.
    #[inline]
    pub fn plain_superstep(&self, active: u64, messages: u64, threads: usize) -> f64 {
        let work = active as f64 * self.t_vertex
            + messages as f64 * (self.t_cas + self.t_combine);
        work / threads.max(1) as f64 + self.t_superstep_sync
    }

    /// Virtual cost of a bounded-scope query: `waves` supersteps of
    /// roughly `active_per_wave` vertices and `messages_per_wave`
    /// deliveries each (an ego-net BFS's wave count is its radius; a
    /// point SSSP's tracks its cutoff).
    #[inline]
    pub fn query_cost(
        &self,
        waves: usize,
        active_per_wave: u64,
        messages_per_wave: u64,
        threads: usize,
    ) -> f64 {
        waves as f64 * self.plain_superstep(active_per_wave, messages_per_wave, threads)
    }

    #[inline]
    pub fn delivery_cost(&self, strategy: Strategy, c: u32, threads: usize, total: u64) -> f64 {
        debug_assert!(c >= 1);
        let concurrent = (c as f64 * threads as f64 / total.max(1) as f64)
            .min(c as f64)
            .min(threads as f64);
        let contenders = concurrent.max(1.0);
        let cas_one = self.t_cas
            + self.t_cas_retry * (self.cas_retry_rate * (contenders - 1.0)).min(4.0);
        match strategy {
            Strategy::Lock => self.t_lock + self.t_crit * (contenders - 1.0) / 2.0,
            Strategy::CasNeutral => cas_one,
            Strategy::Hybrid => {
                // Average over the c deliveries: 1 first push (locked) +
                // (c-1) CAS combines.
                (self.t_lock + (c as f64 - 1.0) * cas_one) / c as f64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_rate_monotone_in_working_set() {
        let m = CostModel::default();
        assert!(m.miss_rate(1e6) <= m.miss_rate(1e8));
        assert!(m.miss_rate(1e6) < 0.05);
        assert!(m.miss_rate(1e10) > 0.9);
    }

    #[test]
    fn prefetch_cover_deepens_monotonically_but_never_hides_everything() {
        let m = CostModel::default();
        assert_eq!(m.prefetch_cover(0), 0.0, "no pipeline, no cover");
        assert!(m.prefetch_cover(4) < m.prefetch_cover(8));
        assert!(m.prefetch_cover(8) < m.prefetch_cover(32));
        assert!(m.prefetch_cover(1024) < 1.0, "bandwidth bound");
        // A DRAM-bound working set stays more expensive than a resident
        // one at every depth — prefetch discounts misses, it does not
        // erase the layout/working-set distinctions the model is for.
        let hot = 64.0 * 1024.0;
        let cold = 1e9;
        for d in [0, 8, 32] {
            assert!(m.prefetched_access(cold, d) > m.prefetched_access(hot, d));
        }
        assert!(m.prefetched_access(cold, 8) < m.random_access(cold));
    }

    #[test]
    fn externalised_stride_is_smaller() {
        let m = CostModel::default();
        assert!(m.layout_stride(Layout::Externalised) < m.layout_stride(Layout::Interleaved));
    }

    #[test]
    fn hybrid_beats_lock_under_contention() {
        let m = CostModel::default();
        let threads = 32;
        // Uncontended (c=1): hybrid pays the first-push lock, same as lock.
        assert!(
            (m.delivery_cost(Strategy::Hybrid, 1, threads, 1)
                - m.delivery_cost(Strategy::Lock, 1, threads, 1))
            .abs()
                < 1e-9
        );
        // Heavy fan-in: hybrid must be much cheaper than lock.
        let hub = 10_000;
        let lock = m.delivery_cost(Strategy::Lock, hub, threads, hub as u64);
        let hybrid = m.delivery_cost(Strategy::Hybrid, hub, threads, hub as u64);
        assert!(
            lock / hybrid > 3.0,
            "lock {lock:.1}ns vs hybrid {hybrid:.1}ns"
        );
        // And hybrid converges to pure CAS (one amortised lock among
        // thousands of CAS combines).
        let cas = m.delivery_cost(Strategy::CasNeutral, hub, threads, hub as u64);
        assert!((hybrid / cas - 1.0).abs() < 0.1, "hybrid {hybrid} cas {cas}");
    }

    #[test]
    fn log_append_is_cheaper_than_any_synchronised_delivery() {
        // The log plane's pitch: an uncontended segment append beats
        // every slot-delivery design (it pays at the barrier merge
        // instead, and in retained memory).
        let m = CostModel::default();
        for strat in [Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid] {
            assert!(
                m.t_log_append < m.delivery_cost(strat, 1, 32, 1),
                "{strat:?}"
            );
        }
    }

    #[test]
    fn superstep_pricing_scales_with_work_and_threads() {
        let m = CostModel::default();
        // More work costs more; more threads cost less (down to the sync
        // floor, which no thread count removes).
        assert!(m.plain_superstep(1_000, 2_000, 8) < m.plain_superstep(1_000_000, 8_000_000, 8));
        assert!(m.plain_superstep(1_000_000, 8_000_000, 32) < m.plain_superstep(1_000_000, 8_000_000, 4));
        assert!(m.plain_superstep(0, 0, 32) >= m.t_superstep_sync);
        // A query is its waves, exactly.
        let one = m.plain_superstep(500, 1_500, 8);
        assert!((m.query_cost(4, 500, 1_500, 8) - 4.0 * one).abs() < 1e-9);
        // The serving premise in model terms: a bounded ego-net query is
        // orders of magnitude cheaper than one full-graph sweep superstep.
        assert!(m.query_cost(3, 1_000, 2_000, 32) < m.plain_superstep(10_000_000, 80_000_000, 32));
    }

    #[test]
    fn contention_grows_with_fan_in_until_thread_cap() {
        let m = CostModel::default();
        let c32 = m.delivery_cost(Strategy::Lock, 32, 32, 32);
        let c64 = m.delivery_cost(Strategy::Lock, 64, 32, 64);
        let c4 = m.delivery_cost(Strategy::Lock, 4, 32, 4);
        assert!(c4 < c32);
        assert!((c32 - c64).abs() < 1e-9, "capped at thread count");
    }
}
