//! The instrumented serial engine feeding the virtual machine.
//!
//! Executes a [`VertexProgram`] with *real* semantics — actual message
//! delivery through the configured [`Strategy`], actual convergence —
//! on one OS thread, while recording each vertex's work profile. After
//! each superstep the profile is priced by the [`CostModel`] and
//! dispatched to the [`VirtualMachine`] under the configured
//! [`Schedule`], yielding the superstep's virtual-time makespan.
//!
//! Final values are cross-validated against the real multithreaded engine
//! in `rust/tests/test_sim.rs` — the simulator may only differ in *time*,
//! never in *answers*.

use crate::combine::plane::DeliveryPlane;
use crate::combine::vector::{LANES, VECTOR_GATHER_MIN};
use crate::combine::{Combiner, Strategy};
use crate::engine::core::step_mode_label;
use crate::engine::tune::{AdaptiveTuner, DecisionTable, StepPlan, TunerState};
use crate::engine::{AggValue, Aggregator, Context, EngineConfig, Mode, VertexProgram};
use crate::graph::csr::{Csr, EdgeWeight, VertexId};
use crate::graph::partition::PartitionPlan;
use crate::layout::{SoaStore, VertexStore};
use crate::metrics::TunerDecision;
use crate::sim::machine::VirtualMachine;
use crate::sim::CostModel;
use crate::trace::{Event, InstantKind, Phase, RunTrace};
use crate::util::bitset::BitSet;
use crate::util::timer::Timer;
use std::time::Duration;

/// Per-active-vertex work record for one superstep.
#[derive(Clone, Copy, Debug, Default)]
struct ItemRec {
    v: VertexId,
    /// Pull: in-neighbour slots inspected.
    scanned: u32,
    /// Pull: messages actually combined.
    combined: u32,
    /// Push: consumed a mailbox message.
    got_msg: bool,
    /// Log plane: messages read from the vertex's inbox.
    received: u32,
    /// Broadcast issued this superstep.
    did_broadcast: bool,
    /// Range into the explicit-send log.
    sends: (u32, u32),
}

/// Simulation outcome.
#[derive(Clone, Debug)]
pub struct SimReport<V> {
    /// Final vertex values (identical to a real engine run).
    pub values: Vec<V>,
    /// Virtual time on the modelled machine, in seconds.
    pub virtual_seconds: f64,
    /// Single-core wall time of the simulation itself (diagnostic).
    pub wall: Duration,
    /// Supersteps executed.
    pub supersteps: usize,
    /// Total messages delivered / combinations performed.
    pub messages: u64,
    /// Mean imbalance (makespan / mean busy) across compute regions.
    pub mean_imbalance: f64,
    /// Adaptive runs (`EngineConfig::adaptive`): the per-superstep knob
    /// trace, decided from the same [`DecisionTable`] the real engine
    /// uses — derived here from *this simulator's* cost model, so a
    /// recalibrated model re-decides both worlds consistently. Empty on
    /// fixed-config simulations.
    pub decisions: Vec<TunerDecision>,
    /// Observability-plane trace over the *virtual* timeline
    /// (`EngineConfig::trace`; `None` when untraced or under the
    /// `no-trace` feature): per-worker region spans from the machine's
    /// modelled per-thread busy times, engine-lane barrier spans, tuner
    /// and steal instants, and one per-superstep [`Event::Counter`]
    /// sample — the same schema the real engine emits, so both open
    /// side-by-side in Perfetto.
    pub trace: Option<RunTrace>,
}

/// Serial instrumented engine. Construct with the *same*
/// [`EngineConfig`] a real run would use; `cfg.threads` becomes the
/// virtual machine width.
pub struct SimEngine<'g, P: VertexProgram> {
    g: &'g Csr,
    program: &'g P,
    cfg: EngineConfig,
    cost: CostModel,
}

/// Mutable per-superstep state shared with the context. Generic over the
/// program's aggregated-value and message types.
struct StepState<AV, M> {
    /// Push: messages received per recipient this superstep.
    counts: Vec<u32>,
    /// Push: recipients touched this superstep (for cheap reset).
    touched: Vec<VertexId>,
    /// Vertices active next superstep.
    active_next: BitSet,
    /// Pull: vertices that broadcast this superstep.
    bcast_next: BitSet,
    /// Explicit (non-broadcast) send destinations.
    sends_log: Vec<VertexId>,
    /// Log plane: per-vertex messages being delivered this superstep
    /// (rotated into the inbox at the barrier). Empty on combined runs.
    log_next: Vec<Vec<M>>,
    /// Aggregator partial of the current superstep: (value, contributed?).
    agg_cur: (AV, bool),
}

/// Serial context: delivers for real, records for the model.
struct SimCtx<'a, P: VertexProgram> {
    g: &'a Csr,
    store: &'a SoaStore<P::Value, P::Message>,
    comb: &'a P::Comb,
    agg: &'a P::Agg,
    agg_prev: Option<&'a AggValue<P>>,
    strategy: Strategy,
    mode: Mode,
    step: &'a mut StepState<AggValue<P>, P::Message>,
    /// Log plane: this vertex's inbox from last superstep.
    inbox: &'a [P::Message],
    /// Whether the program runs on the log plane.
    is_log: bool,
    superstep: usize,
    v: VertexId,
    halted: bool,
    did_broadcast: bool,
}

impl<'a, P: VertexProgram> Context<P::Value, P::Message, AggValue<P>> for SimCtx<'a, P> {
    fn id(&self) -> VertexId {
        self.v
    }
    fn superstep(&self) -> usize {
        self.superstep
    }
    fn num_vertices(&self) -> usize {
        self.g.num_vertices()
    }
    fn value(&self) -> &P::Value {
        self.store.value(self.v)
    }
    fn value_mut(&mut self) -> &mut P::Value {
        self.store.value_mut(self.v)
    }
    fn out_neighbors(&self) -> &[VertexId] {
        self.g.out_neighbors(self.v)
    }
    fn in_degree(&self) -> usize {
        self.g.in_degree(self.v)
    }

    fn out_edge(&self, i: usize) -> (VertexId, EdgeWeight) {
        self.g.out_edge(self.v, i)
    }

    fn send(&mut self, dst: VertexId, msg: P::Message) {
        assert!(
            self.mode == Mode::Push,
            "send() requires a push-mode program"
        );
        if self.is_log {
            self.step.log_next[dst as usize].push(msg);
        } else {
            self.strategy
                .deliver(self.store.next_slot(dst), msg, self.comb);
        }
        self.step.record_delivery(dst);
        self.step.sends_log.push(dst);
    }

    fn broadcast(&mut self, msg: P::Message) {
        self.did_broadcast = true;
        match self.mode {
            Mode::Push => {
                for &dst in self.g.out_neighbors(self.v) {
                    if self.is_log {
                        self.step.log_next[dst as usize].push(msg);
                    } else {
                        self.strategy
                            .deliver(self.store.next_slot(dst), msg, self.comb);
                    }
                    self.step.record_delivery(dst);
                }
            }
            Mode::Pull => {
                self.store.next_slot(self.v).store_first(msg);
                self.step.bcast_next.set(self.v as usize);
                for &dst in self.g.out_neighbors(self.v) {
                    self.step.active_next.set(dst as usize);
                }
            }
        }
    }

    fn vote_to_halt(&mut self) {
        self.halted = true;
    }

    fn contribute(&mut self, x: AggValue<P>) {
        let (acc, used) = self.step.agg_cur.clone();
        self.step.agg_cur = (
            if used { self.agg.combine(acc, x) } else { x },
            true,
        );
    }

    fn aggregated(&self) -> Option<&AggValue<P>> {
        self.agg_prev
    }

    fn recv(&self) -> &[P::Message] {
        assert!(
            self.is_log,
            "recv() requires a log-plane program; set `type Delivery = \
             LogPlane` — combined-plane messages arrive pre-folded as \
             compute's `msg` argument"
        );
        self.inbox
    }
}

/// Append per-worker spans `[t0, t0 + busy]` for every virtual thread a
/// region assignment kept busy (idle lanes emit nothing — an empty lane
/// on the timeline *is* the imbalance the plane visualises). `t0` is the
/// virtual clock at region entry; per-thread busy times come from
/// [`VirtualMachine::region_profile`].
fn emit_worker_spans(
    trace: &mut Option<RunTrace>,
    superstep: usize,
    phase: Phase,
    t0: f64,
    tclock: &[f64],
) {
    let Some(tr) = trace.as_mut() else { return };
    for (w, &busy) in tclock.iter().enumerate() {
        if busy > 0.0 {
            tr.events.push(Event::Span {
                tid: w as u32,
                superstep: superstep as u32,
                phase,
                shard: None,
                start_ns: t0 as u64,
                end_ns: (t0 + busy) as u64,
            });
        }
    }
}

/// Append one engine-lane span over the virtual interval `[t0, t1]`.
fn emit_engine_span(
    trace: &mut Option<RunTrace>,
    superstep: usize,
    phase: Phase,
    t0: f64,
    t1: f64,
) {
    let Some(tr) = trace.as_mut() else { return };
    let tid = tr.engine_lane();
    tr.events.push(Event::Span {
        tid,
        superstep: superstep as u32,
        phase,
        shard: None,
        start_ns: t0 as u64,
        end_ns: t1 as u64,
    });
}

impl<AV: Clone, M> StepState<AV, M> {
    fn record_delivery(&mut self, dst: VertexId) {
        if self.counts[dst as usize] == 0 {
            self.touched.push(dst);
        }
        self.counts[dst as usize] += 1;
        self.active_next.set(dst as usize);
    }
}

impl<'g, P: VertexProgram> SimEngine<'g, P> {
    /// New simulator with the default cost model.
    pub fn new(g: &'g Csr, program: &'g P, cfg: EngineConfig) -> Self {
        SimEngine {
            g,
            program,
            cfg,
            cost: CostModel::default(),
        }
    }

    /// Override the cost model (e.g. with freshly calibrated constants).
    pub fn with_cost(mut self, cost: CostModel) -> Self {
        self.cost = cost;
        self
    }

    /// Run to quiescence; returns values + virtual-time report.
    pub fn run(&self) -> SimReport<P::Value> {
        let wall = Timer::start();
        let g = self.g;
        let n = g.num_vertices();
        let cfg = &self.cfg;
        let cost = &self.cost;
        let comb = self.program.combiner();
        let agg = self.program.aggregator();
        let mode = self.program.mode();
        let is_log = <P::Delivery as DeliveryPlane<P::Message>>::IS_LOG;
        assert!(
            !is_log || mode == Mode::Push,
            "log-plane programs must use Mode::Push (same contract as the \
             real engine)"
        );
        let mut init = |v: VertexId| self.program.init(g, v);
        let mut store: SoaStore<P::Value, P::Message> = SoaStore::build(g, &mut init);

        if mode == Mode::Push && cfg.strategy == Strategy::CasNeutral && !is_log {
            for v in g.vertices() {
                cfg.strategy.reset_slot(store.cur_slot(v), &comb);
                cfg.strategy.reset_slot(store.next_slot(v), &comb);
            }
        }

        let mut vm = VirtualMachine::new(cfg.threads);
        // Observability plane over the virtual clock (`for_run` is the
        // `no-trace` compile-out gate — constant `None` there).
        let mut trace = RunTrace::for_run(cfg.trace, cfg.threads.max(1));
        let mut step: StepState<AggValue<P>, P::Message> = StepState {
            counts: vec![0; n],
            touched: Vec::new(),
            active_next: BitSet::new(n),
            bcast_next: BitSet::new(n),
            sends_log: Vec::new(),
            log_next: if is_log {
                (0..n).map(|_| Vec::new()).collect()
            } else {
                Vec::new()
            },
            agg_cur: (agg.neutral(), false),
        };
        // Log plane: each vertex's inbox of the *current* superstep, and
        // the owners filled last rotation (for O(touched) clearing).
        let mut inbox_cur: Vec<Vec<P::Message>> = if is_log {
            (0..n).map(|_| Vec::new()).collect()
        } else {
            Vec::new()
        };
        let mut prev_inbox_owners: Vec<VertexId> = Vec::new();
        for v in g.vertices() {
            if self.program.initially_active(g, v) {
                step.active_next.set(v as usize);
            }
        }
        let mut bcast_cur = BitSet::new(n);

        // Scan-mode edge-centric weights: full degree vector, built once
        // (adaptive runs always get one, mirroring the session, so the
        // tuner may select edge-centric scans).
        let scan_weights: Option<Vec<u64>> =
            if (cfg.schedule.needs_weights() && !cfg.bypass) || cfg.adaptive {
                Some(match mode {
                    Mode::Push => g.out_degrees_u64(),
                    Mode::Pull => g.in_degrees_u64(),
                })
            } else {
                None
            };

        // Partitioned substrate: the same plan the real engine would
        // build. Values are unaffected (pass A delivers for real either
        // way); only the pricing of the scatter/flush phases changes.
        let plan: Option<PartitionPlan> = match cfg.partitioning.resolve(n) {
            0 => None,
            s => Some(PartitionPlan::build(g, s)),
        };

        // Adaptive replay: the same controller the real engine runs,
        // with thresholds derived from THIS simulator's cost model (the
        // shared decision table) and no live probes (one serial thread
        // never contends, so the contention signal is honestly zero).
        let mut tuner: Option<AdaptiveTuner> = if cfg.adaptive {
            Some(
                AdaptiveTuner::new(
                    cfg,
                    mode,
                    is_log,
                    plan.is_some(),
                    scan_weights.is_some(),
                    TunerState::default(),
                    0,
                )
                .with_table(DecisionTable::from_cost_model(cost)),
            )
        } else {
            None
        };

        // Vector dense-bypass combining (§2.9): known-monoid combiners
        // fold long pull rows through LANES accumulators, shortening the
        // combine dependency chain by the lane width.
        let monoid = comb.monoid_kind().is_some();

        // Row-plane residency model (§2.12): the first iterated vertex of
        // each (direction, block) pair prices that block's materialisation
        // — one fault (seek/latch) plus a per-edge varint decode — exactly
        // once per run. Later rows in the same block slice the decoded
        // scratch for free, mirroring the once-cell residency protocol of
        // graph/rows.rs. The sim keeps blocks resident for the whole run;
        // modelling cold eviction would need a virtual eviction clock for
        // little pricing fidelity on fixed-policy runs.
        let plane_geom = g.row_plane().map(|p| (p.block_size(), p.num_blocks()));
        let nb = plane_geom.map_or(0, |(_, nb)| nb);
        let mut blocks_hot = [vec![false; nb], vec![false; nb]];

        let mut agg_prev: Option<AggValue<P>> = None;
        let mut superstep = 0usize;
        let mut total_messages = 0u64;
        let mut imbalance_sum = 0.0;
        let mut regions = 0usize;

        loop {
            let active: Vec<VertexId> = step.active_next.iter().map(|i| i as VertexId).collect();
            if active.is_empty() || superstep >= cfg.max_supersteps {
                break;
            }
            // Per-superstep knob plan: the adaptive controller re-decides
            // schedule/strategy/bypass for *pricing* (execution below is
            // serial and value-identical under every knob).
            let knobs = match tuner.as_mut() {
                Some(t) => t.decide(superstep, active.len(), n),
                None => StepPlan::of(cfg),
            };
            if tuner.is_some() {
                if let Some(tr) = trace.as_mut() {
                    let tid = tr.engine_lane();
                    tr.events.push(Event::Instant {
                        tid,
                        superstep: superstep as u32,
                        kind: InstantKind::TunerDecision {
                            mode: step_mode_label(&knobs),
                        },
                        ts_ns: vm.clock_ns as u64,
                    });
                }
            }
            step.active_next.clear_all();
            step.touched.clear();
            step.sends_log.clear();

            // ---- Pass A: execute every active vertex, record profiles --
            let mut items: Vec<ItemRec> = Vec::with_capacity(active.len());
            let mut pull_combined_total = 0u64;
            let mut pull_scanned_total = 0u64;
            for &v in &active {
                let (msg, scanned, combined) = match mode {
                    _ if is_log => (None, 0u32, 0u32),
                    Mode::Push => {
                        let slot = store.cur_slot(v);
                        let m = cfg.strategy.collect(slot, &comb);
                        if cfg.strategy == Strategy::CasNeutral && m.is_some() {
                            cfg.strategy.reset_slot(slot, &comb);
                        }
                        (m, 0u32, 0u32)
                    }
                    Mode::Pull => {
                        let mut acc: Option<P::Message> = None;
                        let mut combined = 0u32;
                        let in_nbrs = g.in_neighbors(v);
                        for &src in in_nbrs {
                            if let Some(m) = store.cur_slot(src).peek_scan() {
                                combined += 1;
                                acc = Some(match acc {
                                    None => m,
                                    Some(a) => comb.combine(a, m),
                                });
                            }
                        }
                        (acc, in_nbrs.len() as u32, combined)
                    }
                };
                pull_scanned_total += scanned as u64;
                pull_combined_total += combined as u64;
                let got_msg = msg.is_some();
                let inbox: &[P::Message] = if is_log { &inbox_cur[v as usize] } else { &[] };
                let received = inbox.len() as u32;
                let sends_start = step.sends_log.len() as u32;
                let mut ctx: SimCtx<'_, P> = SimCtx {
                    g,
                    store: &store,
                    comb: &comb,
                    agg: &agg,
                    agg_prev: agg_prev.as_ref(),
                    strategy: cfg.strategy,
                    mode,
                    step: &mut step,
                    inbox,
                    is_log,
                    superstep,
                    v,
                    halted: false,
                    did_broadcast: false,
                };
                self.program.compute(&mut ctx, msg);
                let halted = ctx.halted;
                let did_broadcast = ctx.did_broadcast;
                let sends_end = step.sends_log.len() as u32;
                if !halted {
                    step.active_next.set(v as usize);
                }
                items.push(ItemRec {
                    v,
                    scanned,
                    combined,
                    got_msg,
                    received,
                    did_broadcast,
                    sends: (sends_start, sends_end),
                });
            }

            // ---- Pass B: price each item ------------------------------
            let push_deliveries: u64 = step.touched.iter().map(|&d| step.counts[d as usize] as u64).sum();
            total_messages += push_deliveries + pull_combined_total;

            let stride = cost.layout_stride(cfg.layout);
            // Pull working set: slots the scans touch. The staged
            // prefetch pipeline (§2.9) issues slot loads `depth` vertices
            // ahead, discounting the miss portion by its coverage.
            let ws_pull = (pull_scanned_total.min(n as u64)) as f64 * stride;
            let pull_access =
                cost.prefetched_access(ws_pull, knobs.effective_pipeline_depth());
            // Push working set: recipient slots written.
            let ws_push = step.touched.len() as f64 * stride;
            let push_mem = cost.random_access(ws_push) - cost.t_access_hit;

            let price_delivery = |dst: VertexId| -> f64 {
                let c = step.counts[dst as usize].max(1);
                cost.delivery_cost(knobs.strategy, c, cfg.threads, push_deliveries)
                    + push_mem
                    + cost.t_store
            };
            // Log plane: a contention-free segment append replaces the
            // synchronised slot delivery (same memory + activation terms,
            // no lock/CAS term — the fold cost moves to the reader).
            let log_append = cost.t_log_append + push_mem + cost.t_store;

            // Item costs over the *iterated* index space: the active list
            // (bypass) or the whole vertex range with a per-vertex flag
            // check (scan) — the scan overhead bypass exists to remove.
            let mut active_costs: Vec<f64> = Vec::with_capacity(items.len());
            for it in &items {
                let mut c = cost.t_vertex;
                // Delta-merge surcharge (dynamic graphs): a row served
                // from the delta overlay lives outside the base CSR slab,
                // so iterating it pays one extra indirection per access
                // direction the superstep touches. Zero on static and
                // freshly compacted graphs.
                let overlaid = match mode {
                    Mode::Pull => g.in_row_overlaid(it.v),
                    Mode::Push => g.out_row_overlaid(it.v),
                };
                if overlaid {
                    c += cost.t_access_hit;
                }
                // Compressed/out-of-core rows: the first touch of a row
                // block pays the whole block's fault + decode; the rest
                // of the block rides free for the remainder of the run.
                if let Some((bs, _)) = plane_geom {
                    let (hot, offs) = match mode {
                        Mode::Pull => (&mut blocks_hot[1], &g.in_offsets),
                        Mode::Push => (&mut blocks_hot[0], &g.out_offsets),
                    };
                    let b = it.v as usize / bs;
                    if !hot[b] {
                        hot[b] = true;
                        let span = offs[((b + 1) * bs).min(n)] - offs[b * bs];
                        c += cost.t_row_fault + span as f64 * cost.t_decode;
                    }
                }
                match mode {
                    Mode::Pull => {
                        // Rows past the gather threshold vectorise when
                        // the combiner is a known monoid.
                        let t_comb = if monoid && it.scanned as usize >= VECTOR_GATHER_MIN {
                            cost.t_combine / LANES as f64
                        } else {
                            cost.t_combine
                        };
                        c += it.scanned as f64 * pull_access + it.combined as f64 * t_comb;
                        if it.did_broadcast {
                            // Outbox store + activation of out-neighbours.
                            c += cost.t_store
                                + g.out_degree(it.v) as f64 * cost.t_store;
                        }
                    }
                    Mode::Push => {
                        if is_log {
                            // Sequential read of the inbox slice plus the
                            // user's per-message fold.
                            c += it.received as f64 * (cost.t_access_hit + cost.t_combine);
                        } else if it.got_msg {
                            c += cost.t_store + cost.t_combine;
                        }
                        if it.did_broadcast {
                            for &dst in g.out_neighbors(it.v) {
                                c += if is_log { log_append } else { price_delivery(dst) };
                            }
                        }
                        for &dst in &step.sends_log[it.sends.0 as usize..it.sends.1 as usize] {
                            c += if is_log { log_append } else { price_delivery(dst) };
                        }
                    }
                }
                active_costs.push(c);
            }

            // ---- Dispatch to the virtual machine ----------------------
            let mut flush_imb = 1.0f64;
            let mut est_steals = 0u64;
            let stats = if let Some(plan) = &plan {
                // Partitioned scatter: whole shards are the dispatch
                // unit. Each shard's cost is the sum of its active items
                // (cross-shard sends paying a buffer append instead of a
                // delivery), plus — when scanning — the activity check of
                // its inactive vertices.
                let shards = plan.num_shards();
                let mut shard_costs = vec![0.0f64; shards];
                let mut cross_to = vec![0u64; shards];
                for (it, &c) in items.iter().zip(&active_costs) {
                    let s = plan.shard_of(it.v);
                    shard_costs[s] += c;
                    if mode == Mode::Push {
                        // `active_costs` priced every send as a *contended*
                        // delivery; under the sharded substrate no scatter
                        // delivery contends. Swap the price per target:
                        // intra-shard → owner-exclusive combine+store (keeps
                        // the memory-access term, drops the lock/CAS term);
                        // cross-shard → a buffer append (the delivery happens
                        // owner-exclusively in the flush region below).
                        let exclusive = push_mem + cost.t_store + cost.t_combine;
                        let mut reprice = |dst: VertexId, shard_costs: &mut Vec<f64>| {
                            let d = plan.shard_of(dst);
                            // What `active_costs` already charged per send.
                            let paid = if is_log { log_append } else { price_delivery(dst) };
                            if d != s {
                                cross_to[d] += 1;
                                shard_costs[s] += cost.t_store - paid;
                            } else {
                                // Intra-shard: owner-exclusive combine for
                                // the combined plane; a log append is
                                // already contention-free, so its price
                                // does not change under sharding.
                                let intra = if is_log { log_append } else { exclusive };
                                shard_costs[s] += intra - paid;
                            }
                        };
                        if it.did_broadcast {
                            for &dst in g.out_neighbors(it.v) {
                                reprice(dst, &mut shard_costs);
                            }
                        }
                        for &dst in &step.sends_log[it.sends.0 as usize..it.sends.1 as usize] {
                            reprice(dst, &mut shard_costs);
                        }
                    }
                }
                if !knobs.bypass {
                    let mut active_in = vec![0usize; shards];
                    for it in &items {
                        active_in[plan.shard_of(it.v)] += 1;
                    }
                    for s in 0..shards {
                        shard_costs[s] +=
                            (plan.shard_len(s) - active_in[s]) as f64 * cost.t_access_hit * 0.5;
                    }
                }
                let shard_sched = knobs.schedule.for_shards();
                let shard_weights: Option<Vec<u64>> = if shard_sched.needs_weights() {
                    Some(if knobs.bypass {
                        let mut w = vec![0u64; shards];
                        for it in &items {
                            w[plan.shard_of(it.v)] += match mode {
                                Mode::Push => g.out_degree(it.v) as u64,
                                Mode::Pull => g.in_degree(it.v) as u64,
                            };
                        }
                        w
                    } else {
                        match mode {
                            Mode::Push => plan.out_edges().to_vec(),
                            Mode::Pull => plan.in_edges().to_vec(),
                        }
                    })
                } else {
                    None
                };
                let t0 = vm.clock_ns;
                let (mut scatter, scatter_tclock) = vm.region_profile(
                    shard_sched,
                    &shard_costs,
                    shard_weights.as_deref(),
                    cost.t_chunk_claim,
                );
                // Spans show the modelled pre-steal assignment; steal
                // migration appears as instants (the rebalance model
                // estimates counts, not per-thread reassignments).
                emit_worker_spans(&mut trace, superstep, Phase::Scatter, t0, &scatter_tclock);
                if cfg.steal {
                    // Work-stealing scatter (§2.9): drained workers
                    // migrate whole shards from the most-loaded peer.
                    let max_shard = shard_costs.iter().copied().fold(0.0, f64::max);
                    let (re, st) =
                        vm.steal_rebalance(scatter, max_shard, shards, cost.t_steal);
                    scatter = re;
                    est_steals += st;
                }
                // Flush: destination shards drain their buffered
                // cross-shard messages owner-exclusively.
                let total_cross: u64 = cross_to.iter().sum();
                if total_cross > 0 {
                    flush_imb = cross_to.iter().copied().max().unwrap_or(0) as f64
                        * shards as f64
                        / total_cross as f64;
                    let per_flush = if is_log {
                        // Drain a buffered message into the flush task's
                        // log segment.
                        cost.t_log_append + cost.t_store
                    } else {
                        cost.t_store + cost.t_combine
                    };
                    let flush_costs: Vec<f64> =
                        cross_to.iter().map(|&c| c as f64 * per_flush).collect();
                    let t0f = vm.clock_ns;
                    let (flush, flush_tclock) = vm.region_profile(
                        shard_sched,
                        &flush_costs,
                        if shard_sched.needs_weights() {
                            Some(cross_to.as_slice())
                        } else {
                            None
                        },
                        cost.t_chunk_claim,
                    );
                    emit_worker_spans(&mut trace, superstep, Phase::Flush, t0f, &flush_tclock);
                    if cfg.steal {
                        // The flush barrier is where stealing pays most:
                        // a few hot destination shards strand their
                        // drainers while the rest of the team idles.
                        let max_flush = flush_costs.iter().copied().fold(0.0, f64::max);
                        let (_, st) =
                            vm.steal_rebalance(flush, max_flush, shards, cost.t_steal);
                        est_steals += st;
                    }
                }
                scatter
            } else if knobs.bypass {
                let weights: Option<Vec<u64>> = if knobs.schedule.needs_weights() {
                    Some(
                        active
                            .iter()
                            .map(|&v| match mode {
                                Mode::Push => g.out_degree(v) as u64,
                                Mode::Pull => g.in_degree(v) as u64,
                            })
                            .collect(),
                    )
                } else {
                    None
                };
                let t0 = vm.clock_ns;
                let (stats, tclock) = vm.region_profile(
                    knobs.schedule,
                    &active_costs,
                    weights.as_deref(),
                    cost.t_chunk_claim,
                );
                emit_worker_spans(&mut trace, superstep, Phase::Compute, t0, &tclock);
                stats
            } else {
                // Scan: expand costs to the full range; inactive vertices
                // still pay the activity check.
                let mut full = vec![cost.t_access_hit * 0.5; n];
                for (it, &c) in items.iter().zip(&active_costs) {
                    full[it.v as usize] = c;
                }
                let t0 = vm.clock_ns;
                let (stats, tclock) = vm.region_profile(
                    knobs.schedule,
                    &full,
                    scan_weights.as_deref(),
                    cost.t_chunk_claim,
                );
                emit_worker_spans(&mut trace, superstep, Phase::Compute, t0, &tclock);
                stats
            };
            imbalance_sum += stats.imbalance;
            regions += 1;
            if est_steals > 0 {
                if let Some(tr) = trace.as_mut() {
                    // One instant per estimated migrated shard, on the
                    // engine lane with `shard: 0` — the rebalance model
                    // knows *how many* shards move, not which (the real
                    // engine's instants carry true shard ids and lanes).
                    let tid = tr.engine_lane();
                    let ts_ns = vm.clock_ns as u64;
                    for _ in 0..est_steals {
                        tr.events.push(Event::Instant {
                            tid,
                            superstep: superstep as u32,
                            kind: InstantKind::Steal { shard: 0 },
                            ts_ns,
                        });
                    }
                }
            }

            // ---- Barrier: serial bookkeeping charged to the clock ------
            let mut serial_ns = cost.t_superstep_sync;
            if knobs.bypass {
                serial_ns += step.active_next.count() as f64 * cost.t_store;
                if knobs.schedule.needs_weights() {
                    // §V-A overhead: edge-centric + bypass rebuilds the
                    // weight prefix every superstep.
                    serial_ns += active.len() as f64 * 2.0 * cost.t_store;
                }
            }
            if mode == Mode::Pull {
                serial_ns += bcast_cur.count() as f64 * cost.t_store;
                for v in bcast_cur.iter() {
                    store.cur_slot(v as VertexId).clear();
                }
                std::mem::swap(&mut bcast_cur, &mut step.bcast_next);
                step.bcast_next.clear_all();
            }
            if is_log {
                // The barrier merge walks every appended message three
                // times (count pass, zero-fill of the flat data slab,
                // scatter pass — see MessageLog::merge_segments) — the
                // log plane's deferred delivery cost.
                serial_ns += push_deliveries as f64 * 3.0 * cost.t_store;
                // Rotate: consumed inboxes empty out, freshly delivered
                // logs become next superstep's inboxes.
                for &v in &prev_inbox_owners {
                    inbox_cur[v as usize].clear();
                }
                prev_inbox_owners.clear();
                for &d in &step.touched {
                    std::mem::swap(
                        &mut inbox_cur[d as usize],
                        &mut step.log_next[d as usize],
                    );
                    prev_inbox_owners.push(d);
                }
            }
            let b0 = vm.clock_ns;
            vm.serial(serial_ns);
            emit_engine_span(
                &mut trace,
                superstep,
                if plan.is_some() { Phase::Apply } else { Phase::Barrier },
                b0,
                vm.clock_ns,
            );

            // Barrier signals, shared by the adaptive controller's
            // observe (mirroring the real engine) and the trace sample.
            let delivered = items.iter().filter(|it| it.got_msg).count() as u64;
            // Serial analogue of the engine's LaneCounters: the
            // fraction of scanned pull slots that held a message,
            // 1.0 when nothing vectorises (same convention as
            // LaneCounters::ratio).
            let lane_util = if monoid && pull_scanned_total > 0 {
                pull_combined_total as f64 / pull_scanned_total as f64
            } else {
                1.0
            };
            if let Some(t) = tuner.as_mut() {
                t.observe(
                    push_deliveries + pull_combined_total,
                    delivered,
                    flush_imb,
                    est_steals,
                    lane_util,
                );
            }
            if let Some(tr) = trace.as_mut() {
                let messages = push_deliveries + pull_combined_total;
                tr.events.push(Event::Counter {
                    superstep: superstep as u32,
                    ts_ns: vm.clock_ns as u64,
                    // Modelled region imbalance stands in for the real
                    // engine's measured shard-time skew; one serial
                    // thread never contends, so the probe counts are
                    // honestly zero.
                    skew: stats.imbalance,
                    fan_in: if delivered > 0 {
                        messages as f64 / delivered as f64
                    } else {
                        0.0
                    },
                    cas_retries: 0,
                    lock_contended: 0,
                    lane_utilisation: lane_util,
                });
            }

            // Reset recipient counts (touched list keeps this O(touched)).
            for &d in &step.touched {
                step.counts[d as usize] = 0;
            }
            let (agg_val, agg_used) =
                std::mem::replace(&mut step.agg_cur, (agg.neutral(), false));
            agg_prev = if agg_used { Some(agg_val) } else { None };
            store.swap_epochs();
            superstep += 1;
        }

        let values = g.vertices().map(|v| store.value(v).clone()).collect();
        SimReport {
            values,
            virtual_seconds: vm.seconds(),
            wall: wall.elapsed(),
            supersteps: superstep,
            messages: total_messages,
            mean_imbalance: if regions > 0 {
                imbalance_sum / regions as f64
            } else {
                1.0
            },
            decisions: tuner.as_mut().map(|t| t.take_trace()).unwrap_or_default(),
            trace,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{ConnectedComponents, PageRank, Sssp};
    use crate::engine::GraphSession;
    use crate::graph::gen;
    use crate::layout::Layout;
    use crate::sched::Schedule;

    #[test]
    fn sim_values_match_real_engine_pagerank() {
        let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 41);
        let pr = PageRank::default();
        let real = GraphSession::new(&g).run(&pr);
        let sim = SimEngine::new(&g, &pr, EngineConfig::default()).run();
        for v in g.vertices() {
            let (a, b) = (real.values[v as usize], sim.values[v as usize]);
            assert!((a - b).abs() < 1e-12, "v{v}");
        }
        assert_eq!(sim.supersteps, real.metrics.num_supersteps());
    }

    #[test]
    fn sim_values_match_real_engine_cc_and_sssp() {
        let g = gen::barabasi_albert(500, 3, 2);
        let session = GraphSession::with_config(&g, EngineConfig::default().bypass(true));
        let real_cc = session.run(&ConnectedComponents);
        let sim_cc = SimEngine::new(&g, &ConnectedComponents, EngineConfig::default().bypass(true)).run();
        assert_eq!(real_cc.values, sim_cc.values);

        let p = Sssp::from_hub(&g);
        let real_s = session.run(&p);
        let sim_s = SimEngine::new(&g, &p, EngineConfig::default().bypass(true)).run();
        assert_eq!(real_s.values, sim_s.values);
    }

    #[test]
    fn partitioned_sim_matches_real_partitioned_engine() {
        let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 21);
        let p = Sssp::from_hub(&g);
        let cfg = EngineConfig::default().bypass(true).shards(4);
        let real = GraphSession::with_config(&g, cfg).run(&p);
        let sim = SimEngine::new(&g, &p, cfg).run();
        assert_eq!(real.values, sim.values);
        assert_eq!(real.metrics.num_supersteps(), sim.supersteps);
        // Pull-mode too (PageRank), against the flat reference values.
        let pr = PageRank::default();
        let flat = SimEngine::new(&g, &pr, EngineConfig::default()).run();
        let sharded = SimEngine::new(&g, &pr, EngineConfig::default().shards(4)).run();
        assert_eq!(flat.values, sharded.values);
        assert!(sharded.virtual_seconds > 0.0);
    }

    #[test]
    fn sim_prices_overlaid_rows_and_matches_real_values() {
        use crate::graph::dynamic::{DynamicGraph, MutationSet};
        let base = gen::rmat(8, 4, 0.57, 0.19, 0.19, 77);
        let mut dg = DynamicGraph::with_spill_threshold(base, 1_000_000);
        let mut m = MutationSet::new();
        for v in 0..40u32 {
            m.insert_undirected(v, v + 60);
        }
        dg.apply(&m);
        let g = dg.graph();
        assert!(g.has_overlay());
        let pr = PageRank::default();
        let sim = SimEngine::new(g, &pr, EngineConfig::default()).run();
        let real = GraphSession::new(g).run(&pr);
        for v in g.vertices() {
            assert!((sim.values[v as usize] - real.values[v as usize]).abs() < 1e-12, "v{v}");
        }
        // Same logical graph, compacted: identical values, and the
        // compacted run can only be cheaper (no overlay surcharge).
        dg.compact();
        let g2 = dg.graph();
        let sim2 = SimEngine::new(g2, &pr, EngineConfig::default()).run();
        assert_eq!(sim.values, sim2.values);
        assert!(
            sim2.virtual_seconds <= sim.virtual_seconds,
            "compacted {} vs overlaid {}",
            sim2.virtual_seconds,
            sim.virtual_seconds
        );
    }

    #[test]
    fn sim_prices_compressed_row_decode_and_matches_raw_values() {
        let raw = gen::rmat(8, 4, 0.57, 0.19, 0.19, 21);
        let comp = raw.clone().compress(32);
        assert!(comp.row_plane().is_some());
        let pr = PageRank::default();
        // One virtual thread: item costs become serial-additive, so the
        // decode surcharge shows up in the makespan undiluted.
        let cfg = EngineConfig::default().threads(1);
        let sim_raw = SimEngine::new(&raw, &pr, cfg).run();
        let sim_comp = SimEngine::new(&comp, &pr, cfg).run();
        // Bit-identical values: the plane only changes row storage.
        assert_eq!(sim_raw.values, sim_comp.values);
        assert_eq!(sim_raw.supersteps, sim_comp.supersteps);
        assert_eq!(sim_raw.messages, sim_comp.messages);
        // Every edge decoded at least once, so the compressed run must
        // price strictly above the raw run.
        let floor = raw.num_edges() as f64 * 1.2 * 1e-9;
        assert!(
            sim_comp.virtual_seconds > sim_raw.virtual_seconds + floor * 0.5,
            "compressed {} vs raw {}",
            sim_comp.virtual_seconds,
            sim_raw.virtual_seconds
        );
        // Block faults are priced once per run, not once per superstep:
        // doubling t_row_fault moves time by at most num_blocks faults.
        let mut dear = crate::sim::CostModel::default();
        dear.t_row_fault *= 2.0;
        let sim_dear = SimEngine::new(&comp, &pr, EngineConfig::default())
            .with_cost(dear)
            .run();
        let cap = sim_comp.virtual_seconds
            + 2.0 * comp.row_plane().unwrap().num_blocks() as f64 * 120.0 * 1e-9
            + 1e-9;
        assert!(
            sim_dear.virtual_seconds <= cap,
            "dear {} vs cap {cap}",
            sim_dear.virtual_seconds
        );
    }

    #[test]
    fn sim_values_match_real_engine_on_log_plane_programs() {
        use crate::algos::{Lpa, Triangles};
        let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 13);
        let p = Lpa { rounds: 4 };
        let real = GraphSession::new(&g).run(&p);
        let sim = SimEngine::new(&g, &p, EngineConfig::default()).run();
        assert_eq!(real.values, sim.values);
        assert_eq!(sim.supersteps, real.metrics.num_supersteps());
        assert_eq!(sim.messages, real.metrics.total_messages());

        // Triangles under flat and partitioned pricing (values must be
        // identical either way — only virtual time may differ).
        let edges: Vec<(u32, u32)> = g.edges().collect();
        let tg = crate::graph::GraphBuilder::new(g.num_vertices())
            .symmetric(true)
            .dedup(true)
            .drop_self_loops(true)
            .edges(&edges)
            .build();
        let real_tri = GraphSession::new(&tg).run(&Triangles);
        for cfg in [EngineConfig::default(), EngineConfig::default().shards(4)] {
            let sim = SimEngine::new(&tg, &Triangles, cfg).run();
            assert_eq!(real_tri.values, sim.values);
            assert!(sim.virtual_seconds > 0.0);
        }
    }

    #[test]
    fn adaptive_sim_is_value_identical_and_records_its_decisions() {
        use crate::algos::Bfs;
        let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 5);
        let p = Bfs {
            root: g.max_out_degree_vertex(),
        };
        for base in [EngineConfig::default(), EngineConfig::default().shards(4)] {
            let fixed = SimEngine::new(&g, &p, base).run();
            let adaptive = SimEngine::new(&g, &p, base.adaptive(true)).run();
            assert_eq!(fixed.values, adaptive.values, "values are knob-independent");
            assert_eq!(fixed.supersteps, adaptive.supersteps);
            assert_eq!(fixed.messages, adaptive.messages);
            assert!(fixed.decisions.is_empty(), "fixed sims record no trace");
            assert_eq!(adaptive.decisions.len(), adaptive.supersteps);
            // Single-root BFS starts at one vertex: the density rule must
            // move at least one knob, giving ≥ 2 distinct modes.
            assert!(
                crate::metrics::distinct_modes(&adaptive.decisions) >= 2,
                "expected mode switching, got {:?}",
                adaptive.decisions
            );
            assert!(adaptive.decisions.iter().any(|d| d.switched));
        }
    }

    #[test]
    fn stealing_sim_is_value_identical_and_never_slower() {
        // Skewed push workload on a static shard split: stealing can
        // only migrate work, never change answers — and the rebalanced
        // makespan is capped at the fixed one by construction.
        let g = gen::rmat(11, 16, 0.57, 0.19, 0.19, 6);
        let p = Sssp::from_hub(&g);
        let cfg = EngineConfig::default().threads(32).bypass(true).shards(64);
        let fixed = SimEngine::new(&g, &p, cfg).run();
        let steal = SimEngine::new(&g, &p, cfg.steal(true)).run();
        assert_eq!(fixed.values, steal.values);
        assert_eq!(fixed.supersteps, steal.supersteps);
        assert!(
            steal.virtual_seconds <= fixed.virtual_seconds,
            "steal {} vs fixed {}",
            steal.virtual_seconds,
            fixed.virtual_seconds
        );
    }

    #[test]
    fn dynamic_beats_static_on_skewed_pull_workload() {
        // Power-law graph: per-vertex pull work ∝ in-degree, so static
        // vertex splits are imbalanced and FCFS chunks recover — the
        // §V-B effect.
        let g = gen::rmat(11, 16, 0.57, 0.19, 0.19, 6);
        let pr = PageRank::default();
        // Chunk must subdivide finer than the thread count for FCFS to
        // balance (the paper's 256 assumes million-vertex graphs; scale
        // it to this 2k-vertex test graph).
        let base = SimEngine::new(&g, &pr, EngineConfig::default().threads(32)).run();
        let dyn_ = SimEngine::new(
            &g,
            &pr,
            EngineConfig::default()
                .threads(32)
                .schedule(Schedule::Dynamic { chunk: 16 }),
        )
        .run();
        assert!(
            dyn_.virtual_seconds < base.virtual_seconds,
            "dynamic {} vs static {}",
            dyn_.virtual_seconds,
            base.virtual_seconds
        );
        assert!(dyn_.mean_imbalance < base.mean_imbalance);
    }

    #[test]
    fn hybrid_beats_lock_on_push_sssp() {
        let g = gen::rmat(11, 16, 0.57, 0.19, 0.19, 9);
        let p = Sssp::from_hub(&g);
        let cfg = EngineConfig::default().threads(32).bypass(true);
        let lock = SimEngine::new(&g, &p, cfg.strategy(Strategy::Lock)).run();
        let hybrid = SimEngine::new(&g, &p, cfg.strategy(Strategy::Hybrid)).run();
        assert_eq!(lock.values, hybrid.values);
        assert!(
            hybrid.virtual_seconds < lock.virtual_seconds,
            "hybrid {} vs lock {}",
            hybrid.virtual_seconds,
            lock.virtual_seconds
        );
    }

    #[test]
    fn externalised_layout_is_cheaper_on_large_pull() {
        // A 4k-vertex test graph fits any real LLC; shrink the modelled
        // LLC so the hot arrays spill, as the catalog graphs do at full
        // scale against the real 32 MB.
        let tiny_llc = CostModel {
            l2_bytes: 16.0 * 1024.0,
            llc_bytes: 64.0 * 1024.0,
            ..CostModel::default()
        };
        let g = gen::rmat(12, 16, 0.57, 0.19, 0.19, 3);
        let pr = PageRank::default();
        let aos = SimEngine::new(
            &g,
            &pr,
            EngineConfig::default().threads(32).layout(Layout::Interleaved),
        )
        .with_cost(tiny_llc)
        .run();
        let soa = SimEngine::new(
            &g,
            &pr,
            EngineConfig::default().threads(32).layout(Layout::Externalised),
        )
        .with_cost(tiny_llc)
        .run();
        assert!(
            soa.virtual_seconds < aos.virtual_seconds,
            "soa {} vs aos {}",
            soa.virtual_seconds,
            aos.virtual_seconds
        );
    }
}
