//! Compressed sparse-row graph storage.
//!
//! A [`Csr`] holds both directions of adjacency:
//! - `out_offsets`/`out_targets` — outgoing neighbours (push traversal,
//!   broadcasting along outgoing edges as in Pregel `send_message`);
//! - `in_offsets`/`in_sources` — incoming neighbours (pull traversal used
//!   by iPregel's single-broadcast versions, which read from the *sender's*
//!   outbox).
//!
//! Edges may optionally carry weights: `out_weights`/`in_weights` run
//! parallel to the adjacency arrays (both present or both absent). An
//! unweighted graph reports weight `1.0` for every edge through
//! [`Csr::out_edge`], so weight-aware programs (weighted SSSP) run
//! unchanged on unweighted inputs.
//!
//! Vertex ids are `u32` (the paper's largest graph has 65.6M vertices; our
//! scaled analogues are far below 4.29B), keeping adjacency arrays compact —
//! cache behaviour is a first-class concern in this paper.
//!
//! A `Csr` may additionally carry a **delta overlay**
//! ([`crate::graph::dynamic`]): per-vertex merged-row overrides applied by
//! a [`crate::graph::dynamic::DynamicGraph`]. Every accessor consults the
//! overlay first, so consumers transparently see the mutated graph; a
//! `Csr` without an overlay behaves exactly as before (one well-predicted
//! `Option` branch per row access).
//!
//! Adjacency storage itself is pluggable (DESIGN.md §2.12): a `Csr` may
//! hand its target slabs to a [`crate::graph::rows::RowPlane`] — delta-gap
//! varint blocks held in RAM ([`Csr::compress`]) or streamed from an
//! on-disk arena (`graph/io.rs::externalize`). Offsets always stay raw
//! (degrees and row slicing are O(1) under every backing), and accessors
//! consult overlay → plane → raw slab in that order, so the engine's hot
//! loops still iterate plain slices.

use std::sync::Arc;

use crate::graph::dynamic::DeltaOverlay;
use crate::graph::rows::{Dir, RowMode, RowPlane, RowSpec};

/// Vertex identifier type used throughout the framework.
pub type VertexId = u32;

/// Edge weight type. Unweighted graphs behave as all-ones.
pub type EdgeWeight = f64;

/// An immutable directed graph in CSR form with both adjacency directions
/// and optional per-edge weights.
#[derive(Clone, Debug)]
pub struct Csr {
    /// `out_offsets[v]..out_offsets[v+1]` indexes `out_targets`.
    pub out_offsets: Vec<usize>,
    /// Flattened outgoing neighbour lists (empty when a row plane holds
    /// the adjacency — see [`Csr::compress`]).
    pub out_targets: Vec<VertexId>,
    /// `in_offsets[v]..in_offsets[v+1]` indexes `in_sources`.
    pub in_offsets: Vec<usize>,
    /// Flattened incoming neighbour lists (empty under a row plane).
    pub in_sources: Vec<VertexId>,
    /// Weight of `out_targets[i]`'s edge, when the graph is weighted.
    /// External weighted arenas serve weights from the plane instead
    /// (this stays `None`; see [`Csr::has_weights`]).
    pub out_weights: Option<Vec<EdgeWeight>>,
    /// Weight of `in_sources[i]`'s edge, when the graph is weighted.
    pub in_weights: Option<Vec<EdgeWeight>>,
    /// Live delta overlay, present only while a
    /// [`crate::graph::dynamic::DynamicGraph`] holds uncompacted
    /// mutations. `None` on every statically built graph.
    pub(crate) overlay: Option<Box<DeltaOverlay>>,
    /// Non-raw adjacency backing (compressed blob / on-disk arena).
    /// `Arc`-shared so serving-layer snapshots clone without copying the
    /// encoded bytes or the residency state. `None` = raw slabs.
    pub(crate) rows: Option<Arc<RowPlane>>,
}

/// `PartialEq` is structural on the raw fields and *descriptive* on the
/// plane (mode, block size, geometry, encoded size): two clones sharing
/// one plane compare equal, and a compressed graph never equals its raw
/// original (the slabs moved into the plane).
impl PartialEq for Csr {
    fn eq(&self, other: &Self) -> bool {
        let key = |c: &Csr| {
            c.rows
                .as_ref()
                .map(|p| (p.mode(), p.block_size(), p.num_blocks(), p.stats().encoded_bytes))
        };
        self.out_offsets == other.out_offsets
            && self.out_targets == other.out_targets
            && self.in_offsets == other.in_offsets
            && self.in_sources == other.in_sources
            && self.out_weights == other.out_weights
            && self.in_weights == other.in_weights
            && self.overlay == other.overlay
            && key(self) == key(other)
    }
}

impl Csr {
    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.out_offsets.len() - 1
    }

    /// Number of directed edges (merged view: base plus overlay delta).
    #[inline]
    pub fn num_edges(&self) -> usize {
        let base = match &self.rows {
            Some(p) => p.base_edges(Dir::Out) as usize,
            None => self.out_targets.len(),
        };
        let delta = self.overlay.as_ref().map_or(0, |o| o.edge_delta());
        (base as isize + delta) as usize
    }

    /// Whether edges carry weights (raw slabs, or an external weighted
    /// arena serving them from the plane's blocks).
    #[inline]
    pub fn has_weights(&self) -> bool {
        self.out_weights.is_some()
            || self.rows.as_ref().is_some_and(|p| p.weights_in_blocks())
    }

    /// Out-degree of `v`.
    #[inline]
    pub fn out_degree(&self, v: VertexId) -> usize {
        if let Some(ov) = &self.overlay {
            if let Some(r) = ov.out_row(v) {
                return r.targets.len();
            }
        }
        let v = v as usize;
        self.out_offsets[v + 1] - self.out_offsets[v]
    }

    /// In-degree of `v`.
    #[inline]
    pub fn in_degree(&self, v: VertexId) -> usize {
        if let Some(ov) = &self.overlay {
            if let Some(r) = ov.in_row(v) {
                return r.targets.len();
            }
        }
        let v = v as usize;
        self.in_offsets[v + 1] - self.in_offsets[v]
    }

    /// Outgoing neighbours of `v`.
    #[inline]
    pub fn out_neighbors(&self, v: VertexId) -> &[VertexId] {
        if let Some(ov) = &self.overlay {
            if let Some(r) = ov.out_row(v) {
                return &r.targets;
            }
        }
        let vi = v as usize;
        let (s, e) = (self.out_offsets[vi], self.out_offsets[vi + 1]);
        match &self.rows {
            Some(p) => p.row(Dir::Out, v, s, e),
            None => &self.out_targets[s..e],
        }
    }

    /// Incoming neighbours of `v`.
    #[inline]
    pub fn in_neighbors(&self, v: VertexId) -> &[VertexId] {
        if let Some(ov) = &self.overlay {
            if let Some(r) = ov.in_row(v) {
                return &r.targets;
            }
        }
        let vi = v as usize;
        let (s, e) = (self.in_offsets[vi], self.in_offsets[vi + 1]);
        match &self.rows {
            Some(p) => p.row(Dir::In, v, s, e),
            None => &self.in_sources[s..e],
        }
    }

    /// Weights of `v`'s outgoing edges (parallel to
    /// [`Csr::out_neighbors`]); `None` on unweighted graphs.
    #[inline]
    pub fn out_weights_of(&self, v: VertexId) -> Option<&[EdgeWeight]> {
        if !self.has_weights() {
            return None;
        }
        if let Some(ov) = &self.overlay {
            if let Some(r) = ov.out_row(v) {
                return Some(&r.weights);
            }
        }
        let vi = v as usize;
        let (s, e) = (self.out_offsets[vi], self.out_offsets[vi + 1]);
        match &self.out_weights {
            Some(w) => Some(&w[s..e]),
            // Weighted with no raw slab ⇒ an external arena serves them.
            None => self.rows.as_ref().map(|p| p.row_weights(Dir::Out, v, s, e)),
        }
    }

    /// Weights of `v`'s incoming edges (parallel to
    /// [`Csr::in_neighbors`]); `None` on unweighted graphs.
    #[inline]
    pub fn in_weights_of(&self, v: VertexId) -> Option<&[EdgeWeight]> {
        if !self.has_weights() {
            return None;
        }
        if let Some(ov) = &self.overlay {
            if let Some(r) = ov.in_row(v) {
                return Some(&r.weights);
            }
        }
        let vi = v as usize;
        let (s, e) = (self.in_offsets[vi], self.in_offsets[vi + 1]);
        match &self.in_weights {
            Some(w) => Some(&w[s..e]),
            None => self.rows.as_ref().map(|p| p.row_weights(Dir::In, v, s, e)),
        }
    }

    /// The `i`-th outgoing edge of `v` as `(target, weight)`; weight is
    /// `1.0` on unweighted graphs. `i` must be below `out_degree(v)`.
    #[inline]
    pub fn out_edge(&self, v: VertexId, i: usize) -> (VertexId, EdgeWeight) {
        if let Some(ov) = &self.overlay {
            if let Some(r) = ov.out_row(v) {
                let w = if r.weights.is_empty() { 1.0 } else { r.weights[i] };
                return (r.targets[i], w);
            }
        }
        let vi = v as usize;
        let (s, e) = (self.out_offsets[vi], self.out_offsets[vi + 1]);
        let dst = match &self.rows {
            Some(p) => p.row(Dir::Out, v, s, e)[i],
            None => self.out_targets[s + i],
        };
        let w = match &self.out_weights {
            Some(ws) => ws[s + i],
            None => match &self.rows {
                Some(p) if p.weights_in_blocks() => p.row_weights(Dir::Out, v, s, e)[i],
                _ => 1.0,
            },
        };
        (dst, w)
    }

    /// The `i`-th incoming edge of `v` as `(source, weight)`.
    #[inline]
    pub fn in_edge(&self, v: VertexId, i: usize) -> (VertexId, EdgeWeight) {
        if let Some(ov) = &self.overlay {
            if let Some(r) = ov.in_row(v) {
                let w = if r.weights.is_empty() { 1.0 } else { r.weights[i] };
                return (r.targets[i], w);
            }
        }
        let vi = v as usize;
        let (s, e) = (self.in_offsets[vi], self.in_offsets[vi + 1]);
        let src = match &self.rows {
            Some(p) => p.row(Dir::In, v, s, e)[i],
            None => self.in_sources[s + i],
        };
        let w = match &self.in_weights {
            Some(ws) => ws[s + i],
            None => match &self.rows {
                Some(p) if p.weights_in_blocks() => p.row_weights(Dir::In, v, s, e)[i],
                _ => 1.0,
            },
        };
        (src, w)
    }

    /// Whether a live delta overlay is present (the graph is serving
    /// uncompacted mutations).
    #[inline]
    pub fn has_overlay(&self) -> bool {
        self.overlay.is_some()
    }

    /// Whether `v`'s out-row is served from the delta overlay rather
    /// than the base slab (the simulator prices the extra indirection).
    #[inline]
    pub fn out_row_overlaid(&self, v: VertexId) -> bool {
        self.overlay
            .as_ref()
            .is_some_and(|ov| ov.out_row(v).is_some())
    }

    /// Whether `v`'s in-row is served from the delta overlay.
    #[inline]
    pub fn in_row_overlaid(&self, v: VertexId) -> bool {
        self.overlay
            .as_ref()
            .is_some_and(|ov| ov.in_row(v).is_some())
    }

    /// Mutation instances (insertions + deletions) held in the overlay
    /// since the last compaction; 0 on static/compacted graphs.
    pub fn delta_edge_count(&self) -> usize {
        self.overlay.as_ref().map_or(0, |o| o.delta_edges())
    }

    /// Overlay occupancy: `delta_edge_count / num_edges` (0.0 when fully
    /// compacted or edgeless).
    pub fn delta_occupancy(&self) -> f64 {
        let m = self.num_edges();
        if m == 0 {
            0.0
        } else {
            self.delta_edge_count() as f64 / m as f64
        }
    }

    /// Number of vertices whose adjacency is currently overlaid.
    pub fn overlaid_vertices(&self) -> usize {
        self.overlay.as_ref().map_or(0, |o| o.overlaid_vertices())
    }

    // ------------------------------------------------ row-storage plane

    /// The attached row plane, if adjacency is compressed/external.
    #[inline]
    pub fn row_plane(&self) -> Option<&RowPlane> {
        self.rows.as_deref()
    }

    /// Move the adjacency slabs into an in-RAM compressed
    /// [`RowPlane`] (delta-gap varint blocks of `block_size` vertices;
    /// see `graph/rows.rs`). Offsets and weight slabs stay raw; the
    /// target slabs are dropped. No-op if a plane is already attached.
    /// Compact any live overlay first — compressing under uncompacted
    /// mutations would freeze a stale base.
    pub fn compress(mut self, block_size: usize) -> Csr {
        assert!(
            self.overlay.is_none(),
            "compress a compacted graph — a live delta overlay would shadow the plane"
        );
        if self.rows.is_some() {
            return self;
        }
        let plane = RowPlane::new_compressed(
            &self.out_offsets,
            &self.out_targets,
            &self.in_offsets,
            &self.in_sources,
            block_size,
        );
        self.out_targets = Vec::new();
        self.in_sources = Vec::new();
        self.rows = Some(Arc::new(plane));
        self
    }

    /// Attach a plane built elsewhere (`graph/io.rs::externalize` /
    /// `open_external`). The caller has already emptied or never
    /// populated the slabs the plane replaces.
    pub(crate) fn with_plane(mut self, plane: RowPlane) -> Csr {
        self.rows = Some(Arc::new(plane));
        self
    }

    /// Decode every row back into raw slabs, dropping the plane — the
    /// inverse of [`Csr::compress`], used by compaction and the
    /// bit-identity tests. Weights served from an external arena are
    /// materialised into raw slabs too.
    pub fn decompressed(&self) -> Csr {
        let Some(p) = self.rows.as_deref() else {
            return self.clone();
        };
        let n = self.num_vertices();
        let mut out_targets = Vec::with_capacity(p.base_edges(Dir::Out) as usize);
        let mut in_sources = Vec::with_capacity(p.base_edges(Dir::In) as usize);
        let mut out_w: Vec<EdgeWeight> = Vec::new();
        let mut in_w: Vec<EdgeWeight> = Vec::new();
        for vi in 0..n {
            let v = vi as VertexId;
            let (os, oe) = (self.out_offsets[vi], self.out_offsets[vi + 1]);
            let (is_, ie) = (self.in_offsets[vi], self.in_offsets[vi + 1]);
            out_targets.extend_from_slice(p.row(Dir::Out, v, os, oe));
            in_sources.extend_from_slice(p.row(Dir::In, v, is_, ie));
            if p.weights_in_blocks() {
                out_w.extend_from_slice(p.row_weights(Dir::Out, v, os, oe));
                in_w.extend_from_slice(p.row_weights(Dir::In, v, is_, ie));
            }
        }
        let (out_weights, in_weights) = if p.weights_in_blocks() {
            (Some(out_w), Some(in_w))
        } else {
            (self.out_weights.clone(), self.in_weights.clone())
        };
        Csr {
            out_offsets: self.out_offsets.clone(),
            out_targets,
            in_offsets: self.in_offsets.clone(),
            in_sources,
            out_weights,
            in_weights,
            overlay: self.overlay.clone(),
            rows: None,
        }
    }

    /// Reapplicable description of the current backing (`None` = raw).
    /// `DynamicGraph::compact` captures this before rebuilding and
    /// restores it with [`Csr::with_backing`].
    pub fn backing_spec(&self) -> Option<RowSpec> {
        self.rows.as_ref().map(|p| p.spec())
    }

    /// Re-apply a captured backing to a raw graph: compress in place, or
    /// rewrite the external arena at its recorded path (fresh inode, so
    /// snapshot readers holding the old file keep their bytes).
    pub fn with_backing(self, spec: &RowSpec) -> crate::util::error::Result<Csr> {
        let g = match spec.mode {
            RowMode::Compressed => self.compress(spec.block_size),
            RowMode::External => {
                let Some(path) = spec.path.as_ref() else {
                    return Err(crate::err!("external backing spec lacks an arena path"));
                };
                crate::graph::io::externalize(&self, path, spec.block_size)?
            }
        };
        if let Some(p) = g.row_plane() {
            p.set_policy(spec.policy);
        }
        Ok(g)
    }

    /// Rebuild this graph's merged view from scratch through the
    /// builder: the canonical overlay-free base CSR a
    /// [`crate::graph::dynamic::DynamicGraph`] compaction produces, and
    /// the ground truth the dynamic-graph tests compare delta-merged
    /// iteration against. On a graph without an overlay this is a
    /// structural deep copy.
    pub fn rebuilt(&self) -> Csr {
        let mut gb = crate::graph::builder::GraphBuilder::new(self.num_vertices());
        if self.has_weights() {
            for (s, d, w) in self.weighted_edges() {
                gb.push_weighted_edge(s, d, w);
            }
        } else {
            for (s, d) in self.edges() {
                gb.push_edge(s, d);
            }
        }
        gb.build()
    }

    /// Iterate all vertex ids.
    #[inline]
    pub fn vertices(&self) -> impl Iterator<Item = VertexId> {
        0..self.num_vertices() as VertexId
    }

    /// Iterate all directed edges as `(src, dst)` pairs.
    pub fn edges(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        self.vertices().flat_map(move |v| {
            self.out_neighbors(v).iter().map(move |&d| (v, d))
        })
    }

    /// Iterate all directed edges as `(src, dst, weight)` triples (weight
    /// `1.0` throughout on unweighted graphs).
    pub fn weighted_edges(&self) -> impl Iterator<Item = (VertexId, VertexId, EdgeWeight)> + '_ {
        self.vertices().flat_map(move |v| {
            (0..self.out_degree(v)).map(move |i| {
                let (d, w) = self.out_edge(v, i);
                (v, d, w)
            })
        })
    }

    /// Out-degrees of all vertices as weights for edge-centric scheduling.
    pub fn out_degrees_u64(&self) -> Vec<u64> {
        self.vertices().map(|v| self.out_degree(v) as u64).collect()
    }

    /// In-degrees of all vertices as weights for pull-side scheduling.
    pub fn in_degrees_u64(&self) -> Vec<u64> {
        self.vertices().map(|v| self.in_degree(v) as u64).collect()
    }

    /// Vertex of maximum out-degree (SSSP experiments source from a hub so
    /// that the traversal reaches the giant component, mirroring common
    /// practice for SNAP social graphs).
    pub fn max_out_degree_vertex(&self) -> VertexId {
        self.vertices()
            .max_by_key(|&v| self.out_degree(v))
            .unwrap_or(0)
    }

    /// Approximate resident memory of the adjacency arrays in bytes.
    pub fn memory_bytes(&self) -> usize {
        let weight_bytes = self
            .out_weights
            .as_ref()
            .map_or(0, |w| w.len() * std::mem::size_of::<EdgeWeight>())
            + self
                .in_weights
                .as_ref()
                .map_or(0, |w| w.len() * std::mem::size_of::<EdgeWeight>());
        // Plane-backed graphs pay the encoded blob (compressed mode only —
        // external blobs live on disk) plus whatever blocks are resident.
        let plane_bytes = self.rows.as_ref().map_or(0, |p| {
            let s = p.stats();
            let blob = match p.mode() {
                RowMode::Compressed => s.encoded_bytes,
                RowMode::External => 0,
            };
            (blob + s.resident_bytes) as usize
        });
        self.out_offsets.len() * std::mem::size_of::<usize>()
            + self.in_offsets.len() * std::mem::size_of::<usize>()
            + self.out_targets.len() * std::mem::size_of::<VertexId>()
            + self.in_sources.len() * std::mem::size_of::<VertexId>()
            + weight_bytes
            + plane_bytes
            + self.overlay.as_ref().map_or(0, |o| o.memory_bytes())
    }

    /// Structural validation used by tests and after deserialisation:
    /// offsets monotone and bounded, targets in range, the in/out
    /// adjacency views describe the same edge multiset, and weight arrays
    /// (when present) are consistent between directions.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.num_vertices();
        // Base edge counts regardless of backing (raw slabs are empty
        // under a plane; the plane knows its encoded totals).
        let (out_base, in_base) = match self.rows.as_deref() {
            Some(p) => (
                p.base_edges(Dir::Out) as usize,
                p.base_edges(Dir::In) as usize,
            ),
            None => (self.out_targets.len(), self.in_sources.len()),
        };
        for (name, offs, adj_len) in [
            ("out", &self.out_offsets, out_base),
            ("in", &self.in_offsets, in_base),
        ] {
            if offs.is_empty() {
                return Err(format!("{name}_offsets empty"));
            }
            if offs[0] != 0 || *offs.last().unwrap() != adj_len {
                return Err(format!("{name}_offsets endpoints wrong"));
            }
            if offs.windows(2).any(|w| w[0] > w[1]) {
                return Err(format!("{name}_offsets not monotone"));
            }
        }
        match self.rows.as_deref() {
            None => {
                if self.out_targets.iter().any(|&t| (t as usize) >= n) {
                    return Err("out target out of range".into());
                }
                if self.in_sources.iter().any(|&s| (s as usize) >= n) {
                    return Err("in source out of range".into());
                }
            }
            Some(p) => {
                if !self.out_targets.is_empty() || !self.in_sources.is_empty() {
                    return Err("plane-backed graph still holds raw adjacency slabs".into());
                }
                for vi in 0..n {
                    let v = vi as VertexId;
                    let (s, e) = (self.out_offsets[vi], self.out_offsets[vi + 1]);
                    if p.row(Dir::Out, v, s, e).iter().any(|&t| (t as usize) >= n) {
                        return Err("out target out of range (plane)".into());
                    }
                    let (s, e) = (self.in_offsets[vi], self.in_offsets[vi + 1]);
                    if p.row(Dir::In, v, s, e).iter().any(|&t| (t as usize) >= n) {
                        return Err("in source out of range (plane)".into());
                    }
                }
            }
        }
        if out_base != in_base {
            return Err("edge count mismatch between directions".into());
        }
        match (&self.out_weights, &self.in_weights) {
            (None, None) => {}
            (Some(ow), Some(iw)) => {
                if ow.len() != out_base {
                    return Err("out_weights length mismatch".into());
                }
                if iw.len() != in_base {
                    return Err("in_weights length mismatch".into());
                }
                if ow.iter().chain(iw.iter()).any(|w| !w.is_finite()) {
                    return Err("non-finite edge weight".into());
                }
            }
            _ => return Err("weights present in only one direction".into()),
        }
        if let Some(ov) = &self.overlay {
            ov.validate(n, self.has_weights())?;
            // Merged degrees must account for the merged edge count.
            let out_sum: usize = self.vertices().map(|v| self.out_degree(v)).sum();
            let in_sum: usize = self.vertices().map(|v| self.in_degree(v)).sum();
            if out_sum != self.num_edges() || in_sum != self.num_edges() {
                return Err(format!(
                    "overlay degree sums (out {out_sum}, in {in_sum}) disagree with \
                     merged edge count {}",
                    self.num_edges()
                ));
            }
        }
        if self.has_weights() {
            // Same weighted edge multiset in both directions.
            let mut fwd: Vec<(VertexId, VertexId, u64)> = self
                .weighted_edges()
                .map(|(s, d, w)| (s, d, w.to_bits()))
                .collect();
            let mut bwd: Vec<(VertexId, VertexId, u64)> = self
                .vertices()
                .flat_map(|v| {
                    (0..self.in_degree(v)).map(move |i| {
                        let (s, w) = self.in_edge(v, i);
                        (s, v, w.to_bits())
                    })
                })
                .collect();
            fwd.sort_unstable();
            bwd.sort_unstable();
            if fwd != bwd {
                return Err("in/out weighted adjacency describe different edge sets".into());
            }
        } else {
            // Same edge multiset in both directions (checked via sorted pairs).
            let mut fwd: Vec<(VertexId, VertexId)> = self.edges().collect();
            let mut bwd: Vec<(VertexId, VertexId)> = self
                .vertices()
                .flat_map(|v| self.in_neighbors(v).iter().map(move |&s| (s, v)))
                .collect();
            fwd.sort_unstable();
            bwd.sort_unstable();
            if fwd != bwd {
                return Err("in/out adjacency describe different edge sets".into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::GraphBuilder;

    #[test]
    fn small_graph_accessors() {
        // 0 -> 1, 0 -> 2, 1 -> 2, 2 -> 0
        let g = GraphBuilder::new(3)
            .edges(&[(0, 1), (0, 2), (1, 2), (2, 0)])
            .build();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.out_neighbors(0), &[1, 2]);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.in_degree(2), 2);
        assert_eq!(g.in_neighbors(2), &[0, 1]);
        assert_eq!(g.max_out_degree_vertex(), 0);
        assert!(!g.has_weights());
        g.validate().unwrap();
    }

    #[test]
    fn edges_iterator_enumerates_all() {
        let g = GraphBuilder::new(3)
            .edges(&[(0, 1), (1, 2), (2, 0)])
            .build();
        let es: Vec<_> = g.edges().collect();
        assert_eq!(es, vec![(0, 1), (1, 2), (2, 0)]);
    }

    #[test]
    fn isolated_vertices_have_zero_degree() {
        let g = GraphBuilder::new(5).edges(&[(0, 4)]).build();
        assert_eq!(g.out_degree(2), 0);
        assert_eq!(g.in_degree(2), 0);
        assert_eq!(g.out_neighbors(2), &[] as &[u32]);
        g.validate().unwrap();
    }

    #[test]
    fn memory_estimate_positive() {
        let g = GraphBuilder::new(2).edges(&[(0, 1)]).build();
        assert!(g.memory_bytes() > 0);
    }

    #[test]
    fn unweighted_graph_reports_unit_weights() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (0, 2)]).build();
        assert_eq!(g.out_edge(0, 0), (1, 1.0));
        assert_eq!(g.out_edge(0, 1), (2, 1.0));
        assert_eq!(g.in_edge(2, 0), (0, 1.0));
        assert_eq!(g.out_weights_of(0), None);
        let triples: Vec<_> = g.weighted_edges().collect();
        assert_eq!(triples, vec![(0, 1, 1.0), (0, 2, 1.0)]);
    }

    #[test]
    fn weighted_graph_roundtrips_weights_both_directions() {
        let g = GraphBuilder::new(3)
            .weighted_edges(&[(0, 1, 2.5), (0, 2, 0.5), (1, 2, 4.0)])
            .build();
        assert!(g.has_weights());
        assert_eq!(g.out_edge(0, 0), (1, 2.5));
        assert_eq!(g.out_edge(0, 1), (2, 0.5));
        assert_eq!(g.out_weights_of(0), Some(&[2.5, 0.5][..]));
        // In-direction carries the same weights.
        assert_eq!(g.in_edge(2, 0), (0, 0.5));
        assert_eq!(g.in_edge(2, 1), (1, 4.0));
        g.validate().unwrap();
    }

    #[test]
    fn weight_validation_catches_direction_mismatch() {
        let mut g = GraphBuilder::new(2)
            .weighted_edges(&[(0, 1, 3.0)])
            .build();
        g.in_weights = None;
        assert!(g.validate().is_err());
        let mut g2 = GraphBuilder::new(2)
            .weighted_edges(&[(0, 1, 3.0)])
            .build();
        g2.in_weights = Some(vec![7.0]);
        assert!(g2.validate().is_err(), "weight value mismatch must fail");
    }
}
