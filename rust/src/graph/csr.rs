//! Compressed sparse-row graph storage.
//!
//! A [`Csr`] holds both directions of adjacency:
//! - `out_offsets`/`out_targets` — outgoing neighbours (push traversal,
//!   broadcasting along outgoing edges as in Pregel `send_message`);
//! - `in_offsets`/`in_sources` — incoming neighbours (pull traversal used
//!   by iPregel's single-broadcast versions, which read from the *sender's*
//!   outbox).
//!
//! Edges may optionally carry weights: `out_weights`/`in_weights` run
//! parallel to the adjacency arrays (both present or both absent). An
//! unweighted graph reports weight `1.0` for every edge through
//! [`Csr::out_edge`], so weight-aware programs (weighted SSSP) run
//! unchanged on unweighted inputs.
//!
//! Vertex ids are `u32` (the paper's largest graph has 65.6M vertices; our
//! scaled analogues are far below 4.29B), keeping adjacency arrays compact —
//! cache behaviour is a first-class concern in this paper.
//!
//! A `Csr` may additionally carry a **delta overlay**
//! ([`crate::graph::dynamic`]): per-vertex merged-row overrides applied by
//! a [`crate::graph::dynamic::DynamicGraph`]. Every accessor consults the
//! overlay first, so consumers transparently see the mutated graph; a
//! `Csr` without an overlay behaves exactly as before (one well-predicted
//! `Option` branch per row access).

use crate::graph::dynamic::DeltaOverlay;

/// Vertex identifier type used throughout the framework.
pub type VertexId = u32;

/// Edge weight type. Unweighted graphs behave as all-ones.
pub type EdgeWeight = f64;

/// An immutable directed graph in CSR form with both adjacency directions
/// and optional per-edge weights.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    /// `out_offsets[v]..out_offsets[v+1]` indexes `out_targets`.
    pub out_offsets: Vec<usize>,
    /// Flattened outgoing neighbour lists.
    pub out_targets: Vec<VertexId>,
    /// `in_offsets[v]..in_offsets[v+1]` indexes `in_sources`.
    pub in_offsets: Vec<usize>,
    /// Flattened incoming neighbour lists.
    pub in_sources: Vec<VertexId>,
    /// Weight of `out_targets[i]`'s edge, when the graph is weighted.
    pub out_weights: Option<Vec<EdgeWeight>>,
    /// Weight of `in_sources[i]`'s edge, when the graph is weighted.
    pub in_weights: Option<Vec<EdgeWeight>>,
    /// Live delta overlay, present only while a
    /// [`crate::graph::dynamic::DynamicGraph`] holds uncompacted
    /// mutations. `None` on every statically built graph.
    pub(crate) overlay: Option<Box<DeltaOverlay>>,
}

impl Csr {
    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.out_offsets.len() - 1
    }

    /// Number of directed edges (merged view: base plus overlay delta).
    #[inline]
    pub fn num_edges(&self) -> usize {
        let delta = self.overlay.as_ref().map_or(0, |o| o.edge_delta());
        (self.out_targets.len() as isize + delta) as usize
    }

    /// Whether edges carry weights.
    #[inline]
    pub fn has_weights(&self) -> bool {
        self.out_weights.is_some()
    }

    /// Out-degree of `v`.
    #[inline]
    pub fn out_degree(&self, v: VertexId) -> usize {
        if let Some(ov) = &self.overlay {
            if let Some(r) = ov.out_row(v) {
                return r.targets.len();
            }
        }
        let v = v as usize;
        self.out_offsets[v + 1] - self.out_offsets[v]
    }

    /// In-degree of `v`.
    #[inline]
    pub fn in_degree(&self, v: VertexId) -> usize {
        if let Some(ov) = &self.overlay {
            if let Some(r) = ov.in_row(v) {
                return r.targets.len();
            }
        }
        let v = v as usize;
        self.in_offsets[v + 1] - self.in_offsets[v]
    }

    /// Outgoing neighbours of `v`.
    #[inline]
    pub fn out_neighbors(&self, v: VertexId) -> &[VertexId] {
        if let Some(ov) = &self.overlay {
            if let Some(r) = ov.out_row(v) {
                return &r.targets;
            }
        }
        let v = v as usize;
        &self.out_targets[self.out_offsets[v]..self.out_offsets[v + 1]]
    }

    /// Incoming neighbours of `v`.
    #[inline]
    pub fn in_neighbors(&self, v: VertexId) -> &[VertexId] {
        if let Some(ov) = &self.overlay {
            if let Some(r) = ov.in_row(v) {
                return &r.targets;
            }
        }
        let v = v as usize;
        &self.in_sources[self.in_offsets[v]..self.in_offsets[v + 1]]
    }

    /// Weights of `v`'s outgoing edges (parallel to
    /// [`Csr::out_neighbors`]); `None` on unweighted graphs.
    #[inline]
    pub fn out_weights_of(&self, v: VertexId) -> Option<&[EdgeWeight]> {
        self.out_weights.as_ref()?; // unweighted graphs report None
        if let Some(ov) = &self.overlay {
            if let Some(r) = ov.out_row(v) {
                return Some(&r.weights);
            }
        }
        let v = v as usize;
        self.out_weights
            .as_ref()
            .map(|w| &w[self.out_offsets[v]..self.out_offsets[v + 1]])
    }

    /// Weights of `v`'s incoming edges (parallel to
    /// [`Csr::in_neighbors`]); `None` on unweighted graphs.
    #[inline]
    pub fn in_weights_of(&self, v: VertexId) -> Option<&[EdgeWeight]> {
        self.in_weights.as_ref()?; // unweighted graphs report None
        if let Some(ov) = &self.overlay {
            if let Some(r) = ov.in_row(v) {
                return Some(&r.weights);
            }
        }
        let v = v as usize;
        self.in_weights
            .as_ref()
            .map(|w| &w[self.in_offsets[v]..self.in_offsets[v + 1]])
    }

    /// The `i`-th outgoing edge of `v` as `(target, weight)`; weight is
    /// `1.0` on unweighted graphs. `i` must be below `out_degree(v)`.
    #[inline]
    pub fn out_edge(&self, v: VertexId, i: usize) -> (VertexId, EdgeWeight) {
        if let Some(ov) = &self.overlay {
            if let Some(r) = ov.out_row(v) {
                let w = if r.weights.is_empty() { 1.0 } else { r.weights[i] };
                return (r.targets[i], w);
            }
        }
        let base = self.out_offsets[v as usize];
        let dst = self.out_targets[base + i];
        let w = match &self.out_weights {
            Some(ws) => ws[base + i],
            None => 1.0,
        };
        (dst, w)
    }

    /// The `i`-th incoming edge of `v` as `(source, weight)`.
    #[inline]
    pub fn in_edge(&self, v: VertexId, i: usize) -> (VertexId, EdgeWeight) {
        if let Some(ov) = &self.overlay {
            if let Some(r) = ov.in_row(v) {
                let w = if r.weights.is_empty() { 1.0 } else { r.weights[i] };
                return (r.targets[i], w);
            }
        }
        let base = self.in_offsets[v as usize];
        let src = self.in_sources[base + i];
        let w = match &self.in_weights {
            Some(ws) => ws[base + i],
            None => 1.0,
        };
        (src, w)
    }

    /// Whether a live delta overlay is present (the graph is serving
    /// uncompacted mutations).
    #[inline]
    pub fn has_overlay(&self) -> bool {
        self.overlay.is_some()
    }

    /// Whether `v`'s out-row is served from the delta overlay rather
    /// than the base slab (the simulator prices the extra indirection).
    #[inline]
    pub fn out_row_overlaid(&self, v: VertexId) -> bool {
        self.overlay
            .as_ref()
            .is_some_and(|ov| ov.out_row(v).is_some())
    }

    /// Whether `v`'s in-row is served from the delta overlay.
    #[inline]
    pub fn in_row_overlaid(&self, v: VertexId) -> bool {
        self.overlay
            .as_ref()
            .is_some_and(|ov| ov.in_row(v).is_some())
    }

    /// Mutation instances (insertions + deletions) held in the overlay
    /// since the last compaction; 0 on static/compacted graphs.
    pub fn delta_edge_count(&self) -> usize {
        self.overlay.as_ref().map_or(0, |o| o.delta_edges())
    }

    /// Overlay occupancy: `delta_edge_count / num_edges` (0.0 when fully
    /// compacted or edgeless).
    pub fn delta_occupancy(&self) -> f64 {
        let m = self.num_edges();
        if m == 0 {
            0.0
        } else {
            self.delta_edge_count() as f64 / m as f64
        }
    }

    /// Number of vertices whose adjacency is currently overlaid.
    pub fn overlaid_vertices(&self) -> usize {
        self.overlay.as_ref().map_or(0, |o| o.overlaid_vertices())
    }

    /// Rebuild this graph's merged view from scratch through the
    /// builder: the canonical overlay-free base CSR a
    /// [`crate::graph::dynamic::DynamicGraph`] compaction produces, and
    /// the ground truth the dynamic-graph tests compare delta-merged
    /// iteration against. On a graph without an overlay this is a
    /// structural deep copy.
    pub fn rebuilt(&self) -> Csr {
        let mut gb = crate::graph::builder::GraphBuilder::new(self.num_vertices());
        if self.has_weights() {
            for (s, d, w) in self.weighted_edges() {
                gb.push_weighted_edge(s, d, w);
            }
        } else {
            for (s, d) in self.edges() {
                gb.push_edge(s, d);
            }
        }
        gb.build()
    }

    /// Iterate all vertex ids.
    #[inline]
    pub fn vertices(&self) -> impl Iterator<Item = VertexId> {
        0..self.num_vertices() as VertexId
    }

    /// Iterate all directed edges as `(src, dst)` pairs.
    pub fn edges(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        self.vertices().flat_map(move |v| {
            self.out_neighbors(v).iter().map(move |&d| (v, d))
        })
    }

    /// Iterate all directed edges as `(src, dst, weight)` triples (weight
    /// `1.0` throughout on unweighted graphs).
    pub fn weighted_edges(&self) -> impl Iterator<Item = (VertexId, VertexId, EdgeWeight)> + '_ {
        self.vertices().flat_map(move |v| {
            (0..self.out_degree(v)).map(move |i| {
                let (d, w) = self.out_edge(v, i);
                (v, d, w)
            })
        })
    }

    /// Out-degrees of all vertices as weights for edge-centric scheduling.
    pub fn out_degrees_u64(&self) -> Vec<u64> {
        self.vertices().map(|v| self.out_degree(v) as u64).collect()
    }

    /// In-degrees of all vertices as weights for pull-side scheduling.
    pub fn in_degrees_u64(&self) -> Vec<u64> {
        self.vertices().map(|v| self.in_degree(v) as u64).collect()
    }

    /// Vertex of maximum out-degree (SSSP experiments source from a hub so
    /// that the traversal reaches the giant component, mirroring common
    /// practice for SNAP social graphs).
    pub fn max_out_degree_vertex(&self) -> VertexId {
        self.vertices()
            .max_by_key(|&v| self.out_degree(v))
            .unwrap_or(0)
    }

    /// Approximate resident memory of the adjacency arrays in bytes.
    pub fn memory_bytes(&self) -> usize {
        let weight_bytes = self
            .out_weights
            .as_ref()
            .map_or(0, |w| w.len() * std::mem::size_of::<EdgeWeight>())
            + self
                .in_weights
                .as_ref()
                .map_or(0, |w| w.len() * std::mem::size_of::<EdgeWeight>());
        self.out_offsets.len() * std::mem::size_of::<usize>()
            + self.in_offsets.len() * std::mem::size_of::<usize>()
            + self.out_targets.len() * std::mem::size_of::<VertexId>()
            + self.in_sources.len() * std::mem::size_of::<VertexId>()
            + weight_bytes
            + self.overlay.as_ref().map_or(0, |o| o.memory_bytes())
    }

    /// Structural validation used by tests and after deserialisation:
    /// offsets monotone and bounded, targets in range, the in/out
    /// adjacency views describe the same edge multiset, and weight arrays
    /// (when present) are consistent between directions.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.num_vertices();
        for (name, offs, adj_len) in [
            ("out", &self.out_offsets, self.out_targets.len()),
            ("in", &self.in_offsets, self.in_sources.len()),
        ] {
            if offs.is_empty() {
                return Err(format!("{name}_offsets empty"));
            }
            if offs[0] != 0 || *offs.last().unwrap() != adj_len {
                return Err(format!("{name}_offsets endpoints wrong"));
            }
            if offs.windows(2).any(|w| w[0] > w[1]) {
                return Err(format!("{name}_offsets not monotone"));
            }
        }
        if self.out_targets.iter().any(|&t| (t as usize) >= n) {
            return Err("out target out of range".into());
        }
        if self.in_sources.iter().any(|&s| (s as usize) >= n) {
            return Err("in source out of range".into());
        }
        if self.out_targets.len() != self.in_sources.len() {
            return Err("edge count mismatch between directions".into());
        }
        match (&self.out_weights, &self.in_weights) {
            (None, None) => {}
            (Some(ow), Some(iw)) => {
                if ow.len() != self.out_targets.len() {
                    return Err("out_weights length mismatch".into());
                }
                if iw.len() != self.in_sources.len() {
                    return Err("in_weights length mismatch".into());
                }
                if ow.iter().chain(iw.iter()).any(|w| !w.is_finite()) {
                    return Err("non-finite edge weight".into());
                }
            }
            _ => return Err("weights present in only one direction".into()),
        }
        if let Some(ov) = &self.overlay {
            ov.validate(n, self.has_weights())?;
            // Merged degrees must account for the merged edge count.
            let out_sum: usize = self.vertices().map(|v| self.out_degree(v)).sum();
            let in_sum: usize = self.vertices().map(|v| self.in_degree(v)).sum();
            if out_sum != self.num_edges() || in_sum != self.num_edges() {
                return Err(format!(
                    "overlay degree sums (out {out_sum}, in {in_sum}) disagree with \
                     merged edge count {}",
                    self.num_edges()
                ));
            }
        }
        if self.has_weights() {
            // Same weighted edge multiset in both directions.
            let mut fwd: Vec<(VertexId, VertexId, u64)> = self
                .weighted_edges()
                .map(|(s, d, w)| (s, d, w.to_bits()))
                .collect();
            let mut bwd: Vec<(VertexId, VertexId, u64)> = self
                .vertices()
                .flat_map(|v| {
                    (0..self.in_degree(v)).map(move |i| {
                        let (s, w) = self.in_edge(v, i);
                        (s, v, w.to_bits())
                    })
                })
                .collect();
            fwd.sort_unstable();
            bwd.sort_unstable();
            if fwd != bwd {
                return Err("in/out weighted adjacency describe different edge sets".into());
            }
        } else {
            // Same edge multiset in both directions (checked via sorted pairs).
            let mut fwd: Vec<(VertexId, VertexId)> = self.edges().collect();
            let mut bwd: Vec<(VertexId, VertexId)> = self
                .vertices()
                .flat_map(|v| self.in_neighbors(v).iter().map(move |&s| (s, v)))
                .collect();
            fwd.sort_unstable();
            bwd.sort_unstable();
            if fwd != bwd {
                return Err("in/out adjacency describe different edge sets".into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::GraphBuilder;

    #[test]
    fn small_graph_accessors() {
        // 0 -> 1, 0 -> 2, 1 -> 2, 2 -> 0
        let g = GraphBuilder::new(3)
            .edges(&[(0, 1), (0, 2), (1, 2), (2, 0)])
            .build();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.out_neighbors(0), &[1, 2]);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.in_degree(2), 2);
        assert_eq!(g.in_neighbors(2), &[0, 1]);
        assert_eq!(g.max_out_degree_vertex(), 0);
        assert!(!g.has_weights());
        g.validate().unwrap();
    }

    #[test]
    fn edges_iterator_enumerates_all() {
        let g = GraphBuilder::new(3)
            .edges(&[(0, 1), (1, 2), (2, 0)])
            .build();
        let es: Vec<_> = g.edges().collect();
        assert_eq!(es, vec![(0, 1), (1, 2), (2, 0)]);
    }

    #[test]
    fn isolated_vertices_have_zero_degree() {
        let g = GraphBuilder::new(5).edges(&[(0, 4)]).build();
        assert_eq!(g.out_degree(2), 0);
        assert_eq!(g.in_degree(2), 0);
        assert_eq!(g.out_neighbors(2), &[] as &[u32]);
        g.validate().unwrap();
    }

    #[test]
    fn memory_estimate_positive() {
        let g = GraphBuilder::new(2).edges(&[(0, 1)]).build();
        assert!(g.memory_bytes() > 0);
    }

    #[test]
    fn unweighted_graph_reports_unit_weights() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (0, 2)]).build();
        assert_eq!(g.out_edge(0, 0), (1, 1.0));
        assert_eq!(g.out_edge(0, 1), (2, 1.0));
        assert_eq!(g.in_edge(2, 0), (0, 1.0));
        assert_eq!(g.out_weights_of(0), None);
        let triples: Vec<_> = g.weighted_edges().collect();
        assert_eq!(triples, vec![(0, 1, 1.0), (0, 2, 1.0)]);
    }

    #[test]
    fn weighted_graph_roundtrips_weights_both_directions() {
        let g = GraphBuilder::new(3)
            .weighted_edges(&[(0, 1, 2.5), (0, 2, 0.5), (1, 2, 4.0)])
            .build();
        assert!(g.has_weights());
        assert_eq!(g.out_edge(0, 0), (1, 2.5));
        assert_eq!(g.out_edge(0, 1), (2, 0.5));
        assert_eq!(g.out_weights_of(0), Some(&[2.5, 0.5][..]));
        // In-direction carries the same weights.
        assert_eq!(g.in_edge(2, 0), (0, 0.5));
        assert_eq!(g.in_edge(2, 1), (1, 4.0));
        g.validate().unwrap();
    }

    #[test]
    fn weight_validation_catches_direction_mismatch() {
        let mut g = GraphBuilder::new(2)
            .weighted_edges(&[(0, 1, 3.0)])
            .build();
        g.in_weights = None;
        assert!(g.validate().is_err());
        let mut g2 = GraphBuilder::new(2)
            .weighted_edges(&[(0, 1, 3.0)])
            .build();
        g2.in_weights = Some(vec![7.0]);
        assert!(g2.validate().is_err(), "weight value mismatch must fail");
    }
}
