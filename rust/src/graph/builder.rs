//! Graph construction from edge lists.
//!
//! The builder accepts arbitrary (possibly duplicated, possibly self-loop)
//! edge streams, then produces a [`Csr`] via counting sort — O(V + E), no
//! per-vertex allocation, which matters when materialising the ~113M-edge
//! Friendster analogue on a single core.
//!
//! Edges may carry weights ([`GraphBuilder::weighted_edge`]): mixing
//! weighted and unweighted pushes is allowed (unweighted edges default to
//! weight `1.0`), and the weight arrays are carried through both counting
//! sorts so the out- and in-CSR views stay consistent.

use crate::graph::csr::{Csr, EdgeWeight, VertexId};
use crate::util::prefix::exclusive_prefix_sum_in_place;

/// Accumulates edges and builds a [`Csr`].
pub struct GraphBuilder {
    num_vertices: usize,
    edge_list: Vec<(VertexId, VertexId)>,
    /// Parallel to `edge_list` once any weighted edge has been pushed.
    weights: Option<Vec<EdgeWeight>>,
    dedup: bool,
    drop_self_loops: bool,
    symmetric: bool,
}

impl GraphBuilder {
    /// Builder over `num_vertices` vertices (ids `0..num_vertices`).
    pub fn new(num_vertices: usize) -> Self {
        assert!(
            num_vertices <= VertexId::MAX as usize,
            "vertex ids are u32"
        );
        GraphBuilder {
            num_vertices,
            edge_list: Vec::new(),
            weights: None,
            dedup: false,
            drop_self_loops: false,
            symmetric: false,
        }
    }

    /// Remove duplicate edges at build time. On weighted graphs parallel
    /// edges collapse to the one with the **minimum** weight (the useful
    /// semantics for shortest-path workloads).
    pub fn dedup(mut self, yes: bool) -> Self {
        self.dedup = yes;
        self
    }

    /// Remove self-loops at build time.
    pub fn drop_self_loops(mut self, yes: bool) -> Self {
        self.drop_self_loops = yes;
        self
    }

    /// Insert the reverse of every edge (undirected graphs; the paper's
    /// four SNAP graphs are undirected, stored as two directed edges each).
    /// Reversed edges keep the original edge's weight.
    pub fn symmetric(mut self, yes: bool) -> Self {
        self.symmetric = yes;
        self
    }

    /// Add one edge.
    pub fn edge(mut self, src: VertexId, dst: VertexId) -> Self {
        self.push_edge(src, dst);
        self
    }

    /// Add one weighted edge.
    pub fn weighted_edge(mut self, src: VertexId, dst: VertexId, w: EdgeWeight) -> Self {
        self.push_weighted_edge(src, dst, w);
        self
    }

    /// Add many edges.
    pub fn edges(mut self, es: &[(VertexId, VertexId)]) -> Self {
        self.edge_list.reserve(es.len());
        for &(s, d) in es {
            self.push_edge(s, d);
        }
        self
    }

    /// Add many weighted edges.
    pub fn weighted_edges(mut self, es: &[(VertexId, VertexId, EdgeWeight)]) -> Self {
        self.edge_list.reserve(es.len());
        for &(s, d, w) in es {
            self.push_weighted_edge(s, d, w);
        }
        self
    }

    /// Add an edge without consuming the builder (streaming use).
    pub fn push_edge(&mut self, src: VertexId, dst: VertexId) {
        debug_assert!((src as usize) < self.num_vertices, "src {src} out of range");
        debug_assert!((dst as usize) < self.num_vertices, "dst {dst} out of range");
        self.edge_list.push((src, dst));
        if let Some(w) = &mut self.weights {
            w.push(1.0);
        }
    }

    /// Add a weighted edge without consuming the builder. The first
    /// weighted push switches the builder (and the built graph) to
    /// weighted mode; earlier unweighted edges get weight `1.0`.
    pub fn push_weighted_edge(&mut self, src: VertexId, dst: VertexId, w: EdgeWeight) {
        debug_assert!((src as usize) < self.num_vertices, "src {src} out of range");
        debug_assert!((dst as usize) < self.num_vertices, "dst {dst} out of range");
        assert!(w.is_finite(), "edge weight must be finite, got {w}");
        let ws = self
            .weights
            .get_or_insert_with(|| vec![1.0; self.edge_list.len()]);
        ws.push(w);
        self.edge_list.push((src, dst));
    }

    /// Number of edges currently staged (before symmetrisation/dedup).
    pub fn staged_edges(&self) -> usize {
        self.edge_list.len()
    }

    /// Whether any weighted edge has been staged.
    pub fn is_weighted(&self) -> bool {
        self.weights.is_some()
    }

    /// Build the CSR (consumes the builder).
    pub fn build(mut self) -> Csr {
        match self.weights.take() {
            Some(weights) => self.build_weighted(weights),
            None => self.build_unweighted(),
        }
    }

    /// The original unweighted path: counting sort, no per-edge payload.
    fn build_unweighted(mut self) -> Csr {
        if self.symmetric {
            let rev: Vec<(VertexId, VertexId)> = self
                .edge_list
                .iter()
                .filter(|&&(s, d)| s != d)
                .map(|&(s, d)| (d, s))
                .collect();
            self.edge_list.extend(rev);
        }
        if self.drop_self_loops {
            self.edge_list.retain(|&(s, d)| s != d);
        }
        if self.dedup {
            self.edge_list.sort_unstable();
            self.edge_list.dedup();
        }
        let n = self.num_vertices;
        let edges = &self.edge_list;

        // Counting sort into out-CSR.
        let mut out_offsets = vec![0usize; n + 1];
        for &(s, _) in edges {
            out_offsets[s as usize + 1] += 1;
        }
        exclusive_prefix_sum_in_place(&mut out_offsets[1..]);
        // out_offsets[1..] now holds the start cursor of each vertex row;
        // out_offsets[0] is already 0 so the array is valid offsets after fill.
        let mut out_targets = vec![0 as VertexId; edges.len()];
        {
            let mut cursor = out_offsets[1..].to_vec();
            for &(s, d) in edges {
                let c = &mut cursor[s as usize];
                out_targets[*c] = d;
                *c += 1;
            }
            // Rebuild offsets properly: offsets[v+1] = cursor[v].
            for v in 0..n {
                out_offsets[v + 1] = cursor[v];
            }
        }

        // Counting sort into in-CSR.
        let mut in_offsets = vec![0usize; n + 1];
        for &(_, d) in edges {
            in_offsets[d as usize + 1] += 1;
        }
        exclusive_prefix_sum_in_place(&mut in_offsets[1..]);
        let mut in_sources = vec![0 as VertexId; edges.len()];
        {
            let mut cursor = in_offsets[1..].to_vec();
            for &(s, d) in edges {
                let c = &mut cursor[d as usize];
                in_sources[*c] = s;
                *c += 1;
            }
            for v in 0..n {
                in_offsets[v + 1] = cursor[v];
            }
        }

        // Sort each adjacency row for deterministic iteration order and
        // binary-searchable neighbour lists.
        for v in 0..n {
            out_targets[out_offsets[v]..out_offsets[v + 1]].sort_unstable();
            in_sources[in_offsets[v]..in_offsets[v + 1]].sort_unstable();
        }

        Csr {
            out_offsets,
            out_targets,
            in_offsets,
            in_sources,
            out_weights: None,
            in_weights: None,
            overlay: None,
            rows: None,
        }
    }

    /// Weighted path: same counting sorts, carrying the weight payload.
    fn build_weighted(self, weights: Vec<EdgeWeight>) -> Csr {
        debug_assert_eq!(weights.len(), self.edge_list.len());
        let mut triples: Vec<(VertexId, VertexId, EdgeWeight)> = self
            .edge_list
            .iter()
            .zip(&weights)
            .map(|(&(s, d), &w)| (s, d, w))
            .collect();
        if self.symmetric {
            let rev: Vec<_> = triples
                .iter()
                .filter(|&&(s, d, _)| s != d)
                .map(|&(s, d, w)| (d, s, w))
                .collect();
            triples.extend(rev);
        }
        if self.drop_self_loops {
            triples.retain(|&(s, d, _)| s != d);
        }
        // Sort by (src, dst, weight) once: the sequential counting fill
        // below then emits every out-row already sorted, so no per-row
        // permutation buffers are needed (keeping the builder's
        // no-per-vertex-allocation property from the unweighted path).
        triples.sort_unstable_by(|a, b| {
            (a.0, a.1)
                .cmp(&(b.0, b.1))
                .then(a.2.total_cmp(&b.2))
        });
        if self.dedup {
            // Keeping the first of each (src, dst) run collapses parallel
            // edges to their minimum weight.
            triples.dedup_by_key(|t| (t.0, t.1));
        }
        let n = self.num_vertices;
        let m = triples.len();

        // Counting fill into out-CSR, weights riding along; rows come out
        // sorted by (target, weight) because the triples are.
        let mut out_offsets = vec![0usize; n + 1];
        for &(s, _, _) in &triples {
            out_offsets[s as usize + 1] += 1;
        }
        exclusive_prefix_sum_in_place(&mut out_offsets[1..]);
        let mut out_targets = vec![0 as VertexId; m];
        let mut out_weights = vec![0.0 as EdgeWeight; m];
        {
            let mut cursor = out_offsets[1..].to_vec();
            for &(s, d, w) in &triples {
                let c = &mut cursor[s as usize];
                out_targets[*c] = d;
                out_weights[*c] = w;
                *c += 1;
            }
            for v in 0..n {
                out_offsets[v + 1] = cursor[v];
            }
        }

        // Re-sort by (dst, src, weight) and fill the in-CSR the same way.
        triples.sort_unstable_by(|a, b| {
            (a.1, a.0)
                .cmp(&(b.1, b.0))
                .then(a.2.total_cmp(&b.2))
        });
        let mut in_offsets = vec![0usize; n + 1];
        for &(_, d, _) in &triples {
            in_offsets[d as usize + 1] += 1;
        }
        exclusive_prefix_sum_in_place(&mut in_offsets[1..]);
        let mut in_sources = vec![0 as VertexId; m];
        let mut in_weights = vec![0.0 as EdgeWeight; m];
        {
            let mut cursor = in_offsets[1..].to_vec();
            for &(s, d, w) in &triples {
                let c = &mut cursor[d as usize];
                in_sources[*c] = s;
                in_weights[*c] = w;
                *c += 1;
            }
            for v in 0..n {
                in_offsets[v + 1] = cursor[v];
            }
        }

        Csr {
            out_offsets,
            out_targets,
            in_offsets,
            in_sources,
            out_weights: Some(out_weights),
            in_weights: Some(in_weights),
            overlay: None,
            rows: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick;

    #[test]
    fn dedup_removes_duplicates() {
        let g = GraphBuilder::new(3)
            .dedup(true)
            .edges(&[(0, 1), (0, 1), (0, 1), (1, 2)])
            .build();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.out_neighbors(0), &[1]);
    }

    #[test]
    fn self_loops_dropped_when_asked() {
        let g = GraphBuilder::new(2)
            .drop_self_loops(true)
            .edges(&[(0, 0), (0, 1), (1, 1)])
            .build();
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn self_loops_kept_by_default() {
        let g = GraphBuilder::new(2).edges(&[(0, 0), (0, 1)]).build();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.out_neighbors(0), &[0, 1]);
        g.validate().unwrap();
    }

    #[test]
    fn symmetric_adds_reverse_edges() {
        let g = GraphBuilder::new(3)
            .symmetric(true)
            .edges(&[(0, 1), (1, 2)])
            .build();
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.out_neighbors(1), &[0, 2]);
        assert_eq!(g.in_neighbors(1), &[0, 2]);
        g.validate().unwrap();
    }

    #[test]
    fn symmetric_does_not_duplicate_self_loops() {
        let g = GraphBuilder::new(2)
            .symmetric(true)
            .edges(&[(0, 0), (0, 1)])
            .build();
        // (0,0) once + (0,1) + (1,0)
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn rows_are_sorted() {
        let g = GraphBuilder::new(4)
            .edges(&[(0, 3), (0, 1), (0, 2)])
            .build();
        assert_eq!(g.out_neighbors(0), &[1, 2, 3]);
    }

    #[test]
    fn weighted_rows_sorted_with_weights_attached() {
        let g = GraphBuilder::new(4)
            .weighted_edges(&[(0, 3, 0.3), (0, 1, 0.1), (0, 2, 0.2)])
            .build();
        assert_eq!(g.out_neighbors(0), &[1, 2, 3]);
        assert_eq!(g.out_weights_of(0), Some(&[0.1, 0.2, 0.3][..]));
        g.validate().unwrap();
    }

    #[test]
    fn mixed_pushes_default_unweighted_edges_to_one() {
        let mut gb = GraphBuilder::new(3);
        gb.push_edge(0, 1); // before weighted mode engages
        gb.push_weighted_edge(1, 2, 5.5);
        gb.push_edge(2, 0); // after: still defaults to 1.0
        let g = gb.build();
        assert!(g.has_weights());
        assert_eq!(g.out_weights_of(0), Some(&[1.0][..]));
        assert_eq!(g.out_weights_of(1), Some(&[5.5][..]));
        assert_eq!(g.out_weights_of(2), Some(&[1.0][..]));
        g.validate().unwrap();
    }

    #[test]
    fn symmetric_weighted_mirrors_weights() {
        let g = GraphBuilder::new(3)
            .symmetric(true)
            .weighted_edges(&[(0, 1, 2.0), (1, 2, 3.0)])
            .build();
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.out_weights_of(1), Some(&[2.0, 3.0][..]));
        g.validate().unwrap();
    }

    #[test]
    fn weighted_dedup_keeps_minimum_weight() {
        let g = GraphBuilder::new(2)
            .dedup(true)
            .weighted_edges(&[(0, 1, 4.0), (0, 1, 2.0), (0, 1, 9.0)])
            .build();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.out_weights_of(0), Some(&[2.0][..]));
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn non_finite_weight_rejected() {
        GraphBuilder::new(2).weighted_edge(0, 1, f64::NAN);
    }

    #[test]
    fn prop_built_csr_always_validates() {
        quick::check("builder produces valid CSR", |rng| {
            let n = 1 + rng.below(50) as usize;
            let m = rng.below(200) as usize;
            let edges = quick::random_edges(rng, n, m);
            let g = GraphBuilder::new(n)
                .symmetric(rng.chance(0.5))
                .dedup(rng.chance(0.5))
                .drop_self_loops(rng.chance(0.5))
                .edges(&edges)
                .build();
            g.validate()
        });
    }

    #[test]
    fn prop_weighted_csr_always_validates() {
        quick::check("weighted builder produces valid CSR", |rng| {
            let n = 1 + rng.below(40) as usize;
            let m = rng.below(150) as usize;
            let edges: Vec<(u32, u32, f64)> = quick::random_edges(rng, n, m)
                .into_iter()
                .map(|(s, d)| (s, d, (rng.below(1000) as f64) / 10.0))
                .collect();
            let g = GraphBuilder::new(n)
                .symmetric(rng.chance(0.5))
                .dedup(rng.chance(0.5))
                .drop_self_loops(rng.chance(0.5))
                .weighted_edges(&edges)
                .build();
            g.validate()
        });
    }

    #[test]
    fn prop_degree_sums_equal_edge_count() {
        quick::check("degree sums", |rng| {
            let n = 1 + rng.below(40) as usize;
            let edges = quick::random_edges(rng, n, 100);
            let g = GraphBuilder::new(n).edges(&edges).build();
            let out_sum: usize = g.vertices().map(|v| g.out_degree(v)).sum();
            let in_sum: usize = g.vertices().map(|v| g.in_degree(v)).sum();
            if out_sum == g.num_edges() && in_sum == g.num_edges() {
                Ok(())
            } else {
                Err(format!("out={out_sum} in={in_sum} m={}", g.num_edges()))
            }
        });
    }
}
