//! Graph construction from edge lists.
//!
//! The builder accepts arbitrary (possibly duplicated, possibly self-loop)
//! edge streams, then produces a [`Csr`] via counting sort — O(V + E), no
//! per-vertex allocation, which matters when materialising the ~113M-edge
//! Friendster analogue on a single core.

use crate::graph::csr::{Csr, VertexId};
use crate::util::prefix::exclusive_prefix_sum_in_place;

/// Accumulates edges and builds a [`Csr`].
pub struct GraphBuilder {
    num_vertices: usize,
    edge_list: Vec<(VertexId, VertexId)>,
    dedup: bool,
    drop_self_loops: bool,
    symmetric: bool,
}

impl GraphBuilder {
    /// Builder over `num_vertices` vertices (ids `0..num_vertices`).
    pub fn new(num_vertices: usize) -> Self {
        assert!(
            num_vertices <= VertexId::MAX as usize,
            "vertex ids are u32"
        );
        GraphBuilder {
            num_vertices,
            edge_list: Vec::new(),
            dedup: false,
            drop_self_loops: false,
            symmetric: false,
        }
    }

    /// Remove duplicate edges at build time.
    pub fn dedup(mut self, yes: bool) -> Self {
        self.dedup = yes;
        self
    }

    /// Remove self-loops at build time.
    pub fn drop_self_loops(mut self, yes: bool) -> Self {
        self.drop_self_loops = yes;
        self
    }

    /// Insert the reverse of every edge (undirected graphs; the paper's
    /// four SNAP graphs are undirected, stored as two directed edges each).
    pub fn symmetric(mut self, yes: bool) -> Self {
        self.symmetric = yes;
        self
    }

    /// Add one edge.
    pub fn edge(mut self, src: VertexId, dst: VertexId) -> Self {
        self.push_edge(src, dst);
        self
    }

    /// Add many edges.
    pub fn edges(mut self, es: &[(VertexId, VertexId)]) -> Self {
        self.edge_list.reserve(es.len());
        for &(s, d) in es {
            self.push_edge(s, d);
        }
        self
    }

    /// Add an edge without consuming the builder (streaming use).
    pub fn push_edge(&mut self, src: VertexId, dst: VertexId) {
        debug_assert!((src as usize) < self.num_vertices, "src {src} out of range");
        debug_assert!((dst as usize) < self.num_vertices, "dst {dst} out of range");
        self.edge_list.push((src, dst));
    }

    /// Number of edges currently staged (before symmetrisation/dedup).
    pub fn staged_edges(&self) -> usize {
        self.edge_list.len()
    }

    /// Build the CSR (consumes the builder).
    pub fn build(mut self) -> Csr {
        if self.symmetric {
            let rev: Vec<(VertexId, VertexId)> = self
                .edge_list
                .iter()
                .filter(|&&(s, d)| s != d)
                .map(|&(s, d)| (d, s))
                .collect();
            self.edge_list.extend(rev);
        }
        if self.drop_self_loops {
            self.edge_list.retain(|&(s, d)| s != d);
        }
        if self.dedup {
            self.edge_list.sort_unstable();
            self.edge_list.dedup();
        }
        let n = self.num_vertices;
        let edges = &self.edge_list;

        // Counting sort into out-CSR.
        let mut out_offsets = vec![0usize; n + 1];
        for &(s, _) in edges {
            out_offsets[s as usize + 1] += 1;
        }
        exclusive_prefix_sum_in_place(&mut out_offsets[1..]);
        // out_offsets[1..] now holds the start cursor of each vertex row;
        // out_offsets[0] is already 0 so the array is valid offsets after fill.
        let mut out_targets = vec![0 as VertexId; edges.len()];
        {
            let mut cursor = out_offsets[1..].to_vec();
            for &(s, d) in edges {
                let c = &mut cursor[s as usize];
                out_targets[*c] = d;
                *c += 1;
            }
            // Rebuild offsets properly: offsets[v+1] = cursor[v].
            for v in 0..n {
                out_offsets[v + 1] = cursor[v];
            }
        }

        // Counting sort into in-CSR.
        let mut in_offsets = vec![0usize; n + 1];
        for &(_, d) in edges {
            in_offsets[d as usize + 1] += 1;
        }
        exclusive_prefix_sum_in_place(&mut in_offsets[1..]);
        let mut in_sources = vec![0 as VertexId; edges.len()];
        {
            let mut cursor = in_offsets[1..].to_vec();
            for &(s, d) in edges {
                let c = &mut cursor[d as usize];
                in_sources[*c] = s;
                *c += 1;
            }
            for v in 0..n {
                in_offsets[v + 1] = cursor[v];
            }
        }

        // Sort each adjacency row for deterministic iteration order and
        // binary-searchable neighbour lists.
        for v in 0..n {
            out_targets[out_offsets[v]..out_offsets[v + 1]].sort_unstable();
            in_sources[in_offsets[v]..in_offsets[v + 1]].sort_unstable();
        }

        Csr {
            out_offsets,
            out_targets,
            in_offsets,
            in_sources,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick;

    #[test]
    fn dedup_removes_duplicates() {
        let g = GraphBuilder::new(3)
            .dedup(true)
            .edges(&[(0, 1), (0, 1), (0, 1), (1, 2)])
            .build();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.out_neighbors(0), &[1]);
    }

    #[test]
    fn self_loops_dropped_when_asked() {
        let g = GraphBuilder::new(2)
            .drop_self_loops(true)
            .edges(&[(0, 0), (0, 1), (1, 1)])
            .build();
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn self_loops_kept_by_default() {
        let g = GraphBuilder::new(2).edges(&[(0, 0), (0, 1)]).build();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.out_neighbors(0), &[0, 1]);
        g.validate().unwrap();
    }

    #[test]
    fn symmetric_adds_reverse_edges() {
        let g = GraphBuilder::new(3)
            .symmetric(true)
            .edges(&[(0, 1), (1, 2)])
            .build();
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.out_neighbors(1), &[0, 2]);
        assert_eq!(g.in_neighbors(1), &[0, 2]);
        g.validate().unwrap();
    }

    #[test]
    fn symmetric_does_not_duplicate_self_loops() {
        let g = GraphBuilder::new(2)
            .symmetric(true)
            .edges(&[(0, 0), (0, 1)])
            .build();
        // (0,0) once + (0,1) + (1,0)
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn rows_are_sorted() {
        let g = GraphBuilder::new(4)
            .edges(&[(0, 3), (0, 1), (0, 2)])
            .build();
        assert_eq!(g.out_neighbors(0), &[1, 2, 3]);
    }

    #[test]
    fn prop_built_csr_always_validates() {
        quick::check("builder produces valid CSR", |rng| {
            let n = 1 + rng.below(50) as usize;
            let m = rng.below(200) as usize;
            let edges = quick::random_edges(rng, n, m);
            let g = GraphBuilder::new(n)
                .symmetric(rng.chance(0.5))
                .dedup(rng.chance(0.5))
                .drop_self_loops(rng.chance(0.5))
                .edges(&edges)
                .build();
            g.validate()
        });
    }

    #[test]
    fn prop_degree_sums_equal_edge_count() {
        quick::check("degree sums", |rng| {
            let n = 1 + rng.below(40) as usize;
            let edges = quick::random_edges(rng, n, 100);
            let g = GraphBuilder::new(n).edges(&edges).build();
            let out_sum: usize = g.vertices().map(|v| g.out_degree(v)).sum();
            let in_sum: usize = g.vertices().map(|v| g.in_degree(v)).sum();
            if out_sum == g.num_edges() && in_sum == g.num_edges() {
                Ok(())
            } else {
                Err(format!("out={out_sum} in={in_sum} m={}", g.num_edges()))
            }
        });
    }
}
