//! Pluggable row-storage plane: compressed and out-of-core CSR adjacency
//! (DESIGN.md §2.12).
//!
//! The engine's hot loops iterate plain `&[VertexId]` slices; this module
//! keeps that contract while letting the *bytes behind the slice* live in
//! one of three places:
//!
//!   - **raw** — the classic in-RAM slabs on [`super::csr::Csr`] itself
//!     (no plane attached; nothing here runs),
//!   - **compressed** — rows stored as delta-gap varints in one in-RAM
//!     blob, decoded block-at-a-time into pooled scratch,
//!   - **external** — the same encoded blocks (plus the raw weight slabs)
//!     living in an on-disk arena file, streamed in on demand so only the
//!     working set of blocks is resident between barriers.
//!
//! ## Encoding
//!
//! A *block* covers `block_size` consecutive vertex ids in one direction
//! (out or in). Each row is self-delimiting: a LEB128 varint degree
//! prefix, then one zigzag-LEB128 value per edge — the first is the
//! absolute target id, the rest are deltas from the previous target.
//! Zigzag keeps the codec total (unsorted rows still round-trip); the
//! builder emits sorted rows, whose small positive gaps are what make the
//! ≥1.5x ratios in BENCH_memory.
//!
//! ## Residency protocol
//!
//! Every (direction, block) pair owns a once-cell style slot:
//! `EMPTY → BUSY → READY`. Readers spin through `ensure()`: a READY slot
//! hands out a borrow of the decoded [`Block`]; on EMPTY the winning
//! `CAS(Acquire)` decodes into a pooled buffer and publishes with a
//! `Release` store; losers spin on BUSY. Between decode and eviction a
//! READY block is immutable, so concurrent readers need no further
//! synchronisation.
//!
//! Eviction is only legal when **no borrow can be outstanding**:
//! [`RowPlane::barrier_advise`] runs on the engine thread at a superstep
//! barrier (workers joined) and bails unless exactly one run is active on
//! the plane (`run_enter`/`run_exit` — the serving layer runs many
//! engines over one snapshot). External mode evicts least-recently-touched
//! blocks down to the `resident_blocks` budget; compressed mode only
//! evicts blocks that stayed cold for `cold_rounds` consecutive barriers,
//! and only when the tuner opted in (adaptive runs set the policy from
//! the shared decision table — see `engine/tune.rs`).

use std::cell::UnsafeCell;
use std::fs::File;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::csr::{EdgeWeight, VertexId};

// ---------------------------------------------------------------- codec

/// LEB128-encode `x` into `buf` (7 bits per byte, high bit = continue).
pub fn write_varint(buf: &mut Vec<u8>, mut x: u64) {
    loop {
        let byte = (x & 0x7f) as u8;
        x >>= 7;
        if x == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

/// Decode one LEB128 varint from `bytes` starting at `*pos`, advancing
/// `pos` past it. Input comes from the trusted block builder; a truncated
/// buffer is a corrupt-file bug and fails loudly on the slice bound.
pub fn read_varint(bytes: &[u8], pos: &mut usize) -> u64 {
    let mut x = 0u64;
    let mut shift = 0u32;
    loop {
        let byte = bytes[*pos];
        *pos += 1;
        x |= u64::from(byte & 0x7f) << shift;
        if byte & 0x80 == 0 {
            return x;
        }
        shift += 7;
    }
}

/// Zigzag-map a signed delta to an unsigned varint payload (small
/// magnitudes of either sign stay small).
pub fn zigzag(x: i64) -> u64 {
    ((x << 1) ^ (x >> 63)) as u64
}

/// Inverse of [`zigzag`].
pub fn unzigzag(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

/// Append one encoded row: varint degree, then zigzag deltas (first value
/// is the absolute id, i.e. a delta from 0).
pub fn encode_row(buf: &mut Vec<u8>, row: &[VertexId]) {
    write_varint(buf, row.len() as u64);
    let mut prev: i64 = 0;
    for &t in row {
        write_varint(buf, zigzag(i64::from(t) - prev));
        prev = i64::from(t);
    }
}

/// Decode one row in place, appending its targets to `out` and advancing
/// `pos` past the row's bytes.
pub fn decode_row(bytes: &[u8], pos: &mut usize, out: &mut Vec<VertexId>) {
    let deg = read_varint(bytes, pos) as usize;
    out.reserve(deg);
    let mut prev: i64 = 0;
    for _ in 0..deg {
        prev += unzigzag(read_varint(bytes, pos));
        out.push(prev as VertexId);
    }
}

// ------------------------------------------------------- public surface

/// Which non-raw backing a plane uses (raw CSR is the *absence* of a
/// plane).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RowMode {
    /// Encoded blocks in one in-RAM blob; weights stay on the raw slabs.
    Compressed,
    /// Encoded blocks + weight slabs in an on-disk arena file; only the
    /// resident working set occupies RAM.
    External,
}

/// Residency policy, settable per run (the tuner and the CLI both write
/// it through [`RowPlane::set_policy`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RowPolicy {
    /// External mode: evict least-recently-touched READY blocks down to
    /// this many at each barrier. `None` = keep everything touched.
    pub resident_blocks: Option<usize>,
    /// Compressed mode: evict a decoded block after this many consecutive
    /// barriers without a touch. `None` (fixed-config runs) = decoded
    /// blocks stay resident; adaptive runs set the decision-table band.
    pub cold_rounds: Option<u32>,
}

/// Reapplicable description of a plane — how `DynamicGraph::compact`
/// restores the backing after rebuilding the raw CSR.
#[derive(Clone, Debug, PartialEq)]
pub struct RowSpec {
    pub mode: RowMode,
    pub block_size: usize,
    pub policy: RowPolicy,
    /// Arena file path (external mode only).
    pub path: Option<PathBuf>,
}

/// Adjacency direction — the plane stores out- and in-rows as separate
/// block sequences (slot index = `dir * num_blocks + block`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Dir {
    Out,
    In,
}

impl Dir {
    #[inline]
    pub(crate) fn idx(self) -> usize {
        match self {
            Dir::Out => 0,
            Dir::In => 1,
        }
    }
}

/// Cumulative plane counters, snapshotted into `RunMetrics` (the engine
/// stamps a start snapshot and reports the per-run delta).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RowPlaneStats {
    /// Blocks decoded (demand faults + staged pins).
    pub decodes: u64,
    /// Edges materialised by those decodes.
    pub decoded_edges: u64,
    /// Wall time spent decoding (whole-block decode + arena reads).
    pub decode_ns: u64,
    /// Decodes triggered by a row access that found its block absent.
    pub row_faults: u64,
    /// Decodes triggered by the engine's pre-scatter `pin_range` staging.
    pub staged_blocks: u64,
    /// Blocks evicted by `barrier_advise`.
    pub evictions: u64,
    /// READY blocks right now (instantaneous, not a delta).
    pub resident_blocks: u64,
    /// Bytes held by READY blocks right now (instantaneous).
    pub resident_bytes: u64,
    /// High-water mark of `resident_bytes` since plane construction.
    pub peak_resident_bytes: u64,
    /// Size of the encoded adjacency (blob or arena block region).
    pub encoded_bytes: u64,
    /// Size the same adjacency occupies as raw `u32` slabs.
    pub raw_adj_bytes: u64,
}

impl RowPlaneStats {
    /// Raw-over-encoded adjacency ratio (≥ 1.0 when compression wins).
    pub fn compression_ratio(&self) -> f64 {
        if self.encoded_bytes == 0 {
            1.0
        } else {
            self.raw_adj_bytes as f64 / self.encoded_bytes as f64
        }
    }

    /// Per-run view: cumulative counters minus a start snapshot;
    /// instantaneous gauges (resident/peak/sizes) keep their end values.
    pub fn delta_from(&self, start: &RowPlaneStats) -> RowPlaneStats {
        RowPlaneStats {
            decodes: self.decodes - start.decodes,
            decoded_edges: self.decoded_edges - start.decoded_edges,
            decode_ns: self.decode_ns - start.decode_ns,
            row_faults: self.row_faults - start.row_faults,
            staged_blocks: self.staged_blocks - start.staged_blocks,
            evictions: self.evictions - start.evictions,
            ..*self
        }
    }
}

// --------------------------------------------------------------- blocks

/// One decoded block (one direction): the concatenated targets of its
/// rows, plus the matching weight run when the plane serves weights
/// (external weighted arenas), plus the byte scratch arena reads land in.
/// Pooled through the plane free-list so steady-state decoding allocates
/// nothing.
#[derive(Default)]
struct Block {
    targets: Vec<VertexId>,
    weights: Vec<EdgeWeight>,
    raw: Vec<u8>,
}

impl Block {
    fn heap_bytes(&self) -> u64 {
        (self.targets.len() * std::mem::size_of::<VertexId>()
            + self.weights.len() * std::mem::size_of::<EdgeWeight>()
            + self.raw.len()) as u64
    }
}

const EMPTY: u8 = 0;
const BUSY: u8 = 1;
const READY: u8 = 2;

/// Once-cell residency slot for one (direction, block) pair.
struct Slot {
    state: AtomicU8,
    block: UnsafeCell<Option<Box<Block>>>,
    /// Plane-clock stamp of the last `ensure` touch (LRU key).
    last_touch: AtomicU64,
    /// 1 if touched since the last `barrier_advise` (cold detector).
    touched: AtomicU32,
    /// Consecutive advises with no touch.
    cold: AtomicU32,
}

impl Slot {
    fn new() -> Self {
        Slot {
            state: AtomicU8::new(EMPTY),
            block: UnsafeCell::new(None),
            last_touch: AtomicU64::new(0),
            touched: AtomicU32::new(0),
            cold: AtomicU32::new(0),
        }
    }
}

// SAFETY: `block` is written exactly once per residency cycle, by the
// thread that won the EMPTY→BUSY CAS, and published by the READY Release
// store; readers only dereference it after an Acquire load observes
// READY, and the only writer after that point is eviction, which requires
// barrier-time run-exclusivity (no reader exists). See module docs.
unsafe impl Sync for Slot {}

/// Byte range of one encoded block within the blob / arena file.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub(crate) struct Span {
    pub offset: u64,
    pub len: u64,
}

// ---------------------------------------------------------------- arena

/// Positioned-read handle on the on-disk arena (external mode). Unix gets
/// true positional reads (`read_at`, no shared cursor); other platforms
/// serialise a seek+read pair behind a mutex.
pub(crate) struct Arena {
    file: File,
    path: PathBuf,
    #[cfg(not(unix))]
    cursor: Mutex<()>,
}

impl Arena {
    pub(crate) fn new(file: File, path: PathBuf) -> Arena {
        Arena {
            file,
            path,
            #[cfg(not(unix))]
            cursor: Mutex::new(()),
        }
    }

    pub(crate) fn path(&self) -> &PathBuf {
        &self.path
    }

    #[cfg(unix)]
    fn read_exact_at(&self, buf: &mut [u8], offset: u64) -> std::io::Result<()> {
        use std::os::unix::fs::FileExt;
        self.file.read_exact_at(buf, offset)
    }

    #[cfg(not(unix))]
    fn read_exact_at(&self, buf: &mut [u8], offset: u64) -> std::io::Result<()> {
        use std::io::{Read, Seek, SeekFrom};
        let _guard = self.cursor.lock().unwrap_or_else(|p| p.into_inner());
        let mut f = &self.file;
        f.seek(SeekFrom::Start(offset))?;
        f.read_exact(buf)
    }
}

// ---------------------------------------------------------------- plane

enum Backing {
    Compressed { blob: Vec<u8> },
    External { arena: Arena },
}

/// Residency bookkeeping serialised behind one mutex: the decode path
/// takes it once per *block* (not per row), the barrier path once per
/// superstep — never per message.
struct Residency {
    /// Engine runs currently executing over this plane (serving layer
    /// runs many). Eviction requires exactly one.
    active_runs: usize,
    policy: RowPolicy,
    /// Recycled block buffers (capacity retained).
    free: Vec<Box<Block>>,
}

#[derive(Default)]
struct PlaneCounters {
    decodes: AtomicU64,
    decoded_edges: AtomicU64,
    decode_ns: AtomicU64,
    row_faults: AtomicU64,
    staged_blocks: AtomicU64,
    evictions: AtomicU64,
    resident_blocks: AtomicU64,
    resident_bytes: AtomicU64,
    peak_resident_bytes: AtomicU64,
}

/// The row-storage plane attached to a [`super::csr::Csr`] (shared via
/// `Arc` so snapshots clone cheaply). Offsets stay raw on the `Csr` —
/// degrees are O(1) under every backing — and this plane owns only the
/// adjacency bytes and the residency machinery.
pub struct RowPlane {
    mode: RowMode,
    block_size: usize,
    n: usize,
    num_blocks: usize,
    /// External weighted arenas serve weights from blocks; compressed
    /// planes leave weights on the Csr's raw slabs.
    weights_in_blocks: bool,
    /// Encoded byte span per slot (`dir * num_blocks + block`).
    spans: Vec<Span>,
    /// Per-direction cumulative edge counts at block starts
    /// (`num_blocks + 1` entries): decode pre-sizing, row slicing and
    /// weight-run addressing all index off these.
    first: [Vec<u64>; 2],
    /// File offsets of the raw weight slabs (external weighted only).
    wbase: [u64; 2],
    backing: Backing,
    slots: Vec<Slot>,
    res: Mutex<Residency>,
    stats: PlaneCounters,
    /// Monotone barrier clock stamped into `last_touch` (LRU recency).
    clock: AtomicU64,
    encoded_bytes: u64,
    raw_adj_bytes: u64,
}

impl std::fmt::Debug for RowPlane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RowPlane")
            .field("mode", &self.mode)
            .field("block_size", &self.block_size)
            .field("num_blocks", &self.num_blocks)
            .field("encoded_bytes", &self.encoded_bytes)
            .field("raw_adj_bytes", &self.raw_adj_bytes)
            .finish_non_exhaustive()
    }
}

/// Encode one direction's rows into `blob`, one span per block. Returns
/// the spans and the cumulative first-edge array (`num_blocks + 1`).
pub(crate) fn encode_blocks(
    offsets: &[usize],
    adj: &[VertexId],
    block_size: usize,
    num_blocks: usize,
    blob: &mut Vec<u8>,
) -> (Vec<Span>, Vec<u64>) {
    let n = offsets.len() - 1;
    let mut spans = Vec::with_capacity(num_blocks);
    for b in 0..num_blocks {
        let sv = b * block_size;
        let ev = (sv + block_size).min(n);
        let start = blob.len() as u64;
        for v in sv..ev {
            encode_row(blob, &adj[offsets[v]..offsets[v + 1]]);
        }
        spans.push(Span {
            offset: start,
            len: blob.len() as u64 - start,
        });
    }
    let first = (0..=num_blocks)
        .map(|b| offsets[(b * block_size).min(n)] as u64)
        .collect();
    (spans, first)
}

impl RowPlane {
    /// Build an in-RAM compressed plane from raw CSR parts. Weights (if
    /// any) stay on the caller's raw slabs.
    pub(crate) fn new_compressed(
        out_offsets: &[usize],
        out_targets: &[VertexId],
        in_offsets: &[usize],
        in_sources: &[VertexId],
        block_size: usize,
    ) -> RowPlane {
        let block_size = block_size.max(1);
        let n = out_offsets.len() - 1;
        let num_blocks = n.div_ceil(block_size);
        let mut blob = Vec::new();
        let (mut spans, out_first) =
            encode_blocks(out_offsets, out_targets, block_size, num_blocks, &mut blob);
        let (in_spans, in_first) =
            encode_blocks(in_offsets, in_sources, block_size, num_blocks, &mut blob);
        spans.extend(in_spans);
        let encoded_bytes = blob.len() as u64;
        let raw_adj_bytes =
            ((out_targets.len() + in_sources.len()) * std::mem::size_of::<VertexId>()) as u64;
        RowPlane {
            mode: RowMode::Compressed,
            block_size,
            n,
            num_blocks,
            weights_in_blocks: false,
            spans,
            first: [out_first, in_first],
            wbase: [0, 0],
            backing: Backing::Compressed { blob },
            slots: (0..2 * num_blocks).map(|_| Slot::new()).collect(),
            res: Mutex::new(Residency {
                active_runs: 0,
                policy: RowPolicy::default(),
                free: Vec::new(),
            }),
            stats: PlaneCounters::default(),
            clock: AtomicU64::new(0),
            encoded_bytes,
            raw_adj_bytes,
        }
    }

    /// Wrap an on-disk arena (opened + header-parsed by `graph/io.rs`).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new_external(
        arena: Arena,
        block_size: usize,
        n: usize,
        weighted: bool,
        spans: Vec<Span>,
        first: [Vec<u64>; 2],
        wbase: [u64; 2],
        encoded_bytes: u64,
    ) -> RowPlane {
        let block_size = block_size.max(1);
        let num_blocks = n.div_ceil(block_size);
        debug_assert_eq!(spans.len(), 2 * num_blocks);
        let raw_adj_bytes = ((first[0][num_blocks] + first[1][num_blocks]) as usize
            * std::mem::size_of::<VertexId>()) as u64;
        RowPlane {
            mode: RowMode::External,
            block_size,
            n,
            num_blocks,
            weights_in_blocks: weighted,
            spans,
            first,
            wbase,
            backing: Backing::External { arena },
            slots: (0..2 * num_blocks).map(|_| Slot::new()).collect(),
            res: Mutex::new(Residency {
                active_runs: 0,
                policy: RowPolicy::default(),
                free: Vec::new(),
            }),
            stats: PlaneCounters::default(),
            clock: AtomicU64::new(0),
            encoded_bytes,
            raw_adj_bytes,
        }
    }

    pub fn mode(&self) -> RowMode {
        self.mode
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn num_blocks(&self) -> usize {
        self.num_blocks
    }

    /// True when edge weights live in the arena blocks (external
    /// weighted) rather than on the Csr's raw slabs.
    pub fn weights_in_blocks(&self) -> bool {
        self.weights_in_blocks
    }

    /// Total base edges in one direction (the count the raw slab would
    /// hold) — `Csr::num_edges` under a plane.
    pub(crate) fn base_edges(&self, dir: Dir) -> u64 {
        *self.first[dir.idx()].last().unwrap_or(&0)
    }

    /// Reapplicable backing description (see [`RowSpec`]).
    pub fn spec(&self) -> RowSpec {
        let path = match &self.backing {
            Backing::Compressed { .. } => None,
            Backing::External { arena } => Some(arena.path().clone()),
        };
        RowSpec {
            mode: self.mode,
            block_size: self.block_size,
            policy: self.policy(),
            path,
        }
    }

    pub fn set_policy(&self, policy: RowPolicy) {
        self.res.lock().unwrap_or_else(|p| p.into_inner()).policy = policy;
    }

    pub fn policy(&self) -> RowPolicy {
        self.res.lock().unwrap_or_else(|p| p.into_inner()).policy
    }

    pub fn stats(&self) -> RowPlaneStats {
        let s = &self.stats;
        RowPlaneStats {
            decodes: s.decodes.load(Ordering::Relaxed),
            decoded_edges: s.decoded_edges.load(Ordering::Relaxed),
            decode_ns: s.decode_ns.load(Ordering::Relaxed),
            row_faults: s.row_faults.load(Ordering::Relaxed),
            staged_blocks: s.staged_blocks.load(Ordering::Relaxed),
            evictions: s.evictions.load(Ordering::Relaxed),
            resident_blocks: s.resident_blocks.load(Ordering::Relaxed),
            resident_bytes: s.resident_bytes.load(Ordering::Relaxed),
            peak_resident_bytes: s.peak_resident_bytes.load(Ordering::Relaxed),
            encoded_bytes: self.encoded_bytes,
            raw_adj_bytes: self.raw_adj_bytes,
        }
    }

    // ---------------------------------------------------- row accessors

    /// The decoded row of `v` in direction `dir`. `start..end` is the
    /// edge-index range from the Csr's (raw, always-resident) offsets;
    /// the borrow is valid until the next eviction point, which cannot
    /// occur before the caller's superstep barrier (module docs).
    #[inline]
    pub(crate) fn row(&self, dir: Dir, v: VertexId, start: usize, end: usize) -> &[VertexId] {
        let b = v as usize / self.block_size;
        let blk = self.ensure(dir, b, false);
        let base = self.first[dir.idx()][b] as usize;
        &blk.targets[start - base..end - base]
    }

    /// The weight run matching [`RowPlane::row`] (external weighted
    /// arenas only — callers check [`RowPlane::weights_in_blocks`]).
    #[inline]
    pub(crate) fn row_weights(
        &self,
        dir: Dir,
        v: VertexId,
        start: usize,
        end: usize,
    ) -> &[EdgeWeight] {
        let b = v as usize / self.block_size;
        let blk = self.ensure(dir, b, false);
        let base = self.first[dir.idx()][b] as usize;
        &blk.weights[start - base..end - base]
    }

    /// Pre-decode every block covering vertex range `v_start..v_end` in
    /// `dir` — the engine's per-shard staging step, so the scatter loop
    /// itself only ever takes the READY fast path.
    pub(crate) fn pin_range(&self, dir: Dir, v_start: usize, v_end: usize) {
        if v_start >= v_end {
            return;
        }
        let b0 = v_start / self.block_size;
        let b1 = (v_end - 1) / self.block_size;
        for b in b0..=b1 {
            let _ = self.ensure(dir, b, true);
        }
    }

    /// Resolve a block to READY and borrow it. `staged` only labels the
    /// decode statistic (pin vs demand fault); the protocol is identical.
    fn ensure(&self, dir: Dir, b: usize, staged: bool) -> &Block {
        let slot = &self.slots[dir.idx() * self.num_blocks + b];
        loop {
            match slot.state.load(Ordering::Acquire) {
                READY => {
                    slot.touched.store(1, Ordering::Relaxed);
                    slot.last_touch
                        .store(self.clock.load(Ordering::Relaxed), Ordering::Relaxed);
                    // SAFETY: the Acquire load above saw READY, which is
                    // only published (Release) after the BUSY winner fully
                    // initialised the block; the cell stays written until
                    // eviction, which requires barrier-time run
                    // exclusivity, so no writer races this read and the
                    // Option is necessarily Some.
                    return unsafe { (*slot.block.get()).as_deref().unwrap_unchecked() };
                }
                EMPTY => {
                    if slot
                        .state
                        .compare_exchange(EMPTY, BUSY, Ordering::Acquire, Ordering::Relaxed)
                        .is_ok()
                    {
                        let blk = self.decode_block(dir, b, staged);
                        // SAFETY: winning the EMPTY→BUSY CAS grants this
                        // thread exclusive write access to the cell until
                        // the Release store below publishes READY.
                        unsafe {
                            *slot.block.get() = Some(blk);
                        }
                        slot.touched.store(1, Ordering::Relaxed);
                        slot.cold.store(0, Ordering::Relaxed);
                        slot.last_touch
                            .store(self.clock.load(Ordering::Relaxed), Ordering::Relaxed);
                        slot.state.store(READY, Ordering::Release);
                    }
                    // Either we published READY or someone else holds
                    // BUSY — loop re-reads and takes the READY arm.
                }
                _ => std::hint::spin_loop(),
            }
        }
    }

    /// Decode (and for external mode, read) one block into a pooled
    /// buffer. Called only by the slot's BUSY winner.
    fn decode_block(&self, dir: Dir, b: usize, staged: bool) -> Box<Block> {
        let t0 = Instant::now();
        let mut blk = self
            .res
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .free
            .pop()
            .unwrap_or_default();
        blk.targets.clear();
        blk.weights.clear();
        let span = self.spans[dir.idx() * self.num_blocks + b];
        let first = &self.first[dir.idx()];
        let edges = (first[b + 1] - first[b]) as usize;
        blk.targets.reserve(edges);
        let sv = b * self.block_size;
        let ev = (sv + self.block_size).min(self.n);
        match &self.backing {
            Backing::Compressed { blob } => {
                let bytes = &blob[span.offset as usize..(span.offset + span.len) as usize];
                let mut pos = 0usize;
                for _ in sv..ev {
                    decode_row(bytes, &mut pos, &mut blk.targets);
                }
            }
            Backing::External { arena } => {
                blk.raw.resize(span.len as usize, 0);
                arena
                    .read_exact_at(&mut blk.raw, span.offset)
                    // audit:allow(panic): arena I/O failure (file truncated
                    // or unlinked storage gone) is unrecoverable mid-run —
                    // fail loudly rather than serve wrong adjacency.
                    .expect("row arena read failed");
                let mut pos = 0usize;
                for _ in sv..ev {
                    decode_row(&blk.raw, &mut pos, &mut blk.targets);
                }
                if self.weights_in_blocks && edges > 0 {
                    const W: usize = std::mem::size_of::<EdgeWeight>();
                    blk.raw.resize(edges * W, 0);
                    let woff = self.wbase[dir.idx()] + first[b] * W as u64;
                    arena
                        .read_exact_at(&mut blk.raw, woff)
                        // audit:allow(panic): same arena-corruption
                        // invariant as the adjacency read above.
                        .expect("row arena weight read failed");
                    blk.weights.extend(
                        blk.raw
                            .chunks_exact(W)
                            .map(|c| EdgeWeight::from_le_bytes([
                                c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7],
                            ])),
                    );
                }
                blk.raw.clear();
            }
        }
        debug_assert_eq!(blk.targets.len(), edges);
        let s = &self.stats;
        s.decodes.fetch_add(1, Ordering::Relaxed);
        s.decoded_edges.fetch_add(edges as u64, Ordering::Relaxed);
        s.decode_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if staged {
            s.staged_blocks.fetch_add(1, Ordering::Relaxed);
        } else {
            s.row_faults.fetch_add(1, Ordering::Relaxed);
        }
        s.resident_blocks.fetch_add(1, Ordering::Relaxed);
        let bytes = s
            .resident_bytes
            .fetch_add(blk.heap_bytes(), Ordering::Relaxed)
            + blk.heap_bytes();
        s.peak_resident_bytes.fetch_max(bytes, Ordering::Relaxed);
        blk
    }

    // ------------------------------------------------------- run fences

    /// A run over this plane is starting (serving layer: many at once).
    pub fn run_enter(&self) {
        self.res.lock().unwrap_or_else(|p| p.into_inner()).active_runs += 1;
    }

    /// The matching exit — after the run's final barrier.
    pub fn run_exit(&self) {
        let mut res = self.res.lock().unwrap_or_else(|p| p.into_inner());
        res.active_runs = res.active_runs.saturating_sub(1);
    }

    /// Barrier-time residency maintenance, called by the engine thread
    /// between supersteps (workers joined). Advances the LRU clock, and —
    /// only when this is the sole active run, so no row borrow can be
    /// outstanding anywhere — applies the eviction policy: external
    /// planes evict least-recently-touched blocks down to the
    /// `resident_blocks` budget; compressed planes evict blocks cold for
    /// `cold_rounds` consecutive barriers.
    pub fn barrier_advise(&self) {
        self.clock.fetch_add(1, Ordering::Relaxed);
        let mut res = self.res.lock().unwrap_or_else(|p| p.into_inner());
        if res.active_runs != 1 {
            return;
        }
        let policy = res.policy;
        match self.mode {
            RowMode::External => {
                let Some(budget) = policy.resident_blocks else {
                    return;
                };
                let resident = self.stats.resident_blocks.load(Ordering::Relaxed) as usize;
                if resident <= budget {
                    return;
                }
                // Oldest-touch-first victim order over READY slots.
                let mut victims: Vec<(u64, usize)> = self
                    .slots
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| s.state.load(Ordering::Relaxed) == READY)
                    .map(|(i, s)| (s.last_touch.load(Ordering::Relaxed), i))
                    .collect();
                victims.sort_unstable();
                for &(_, i) in victims.iter().take(resident - budget) {
                    self.evict_slot(i, &mut res);
                }
            }
            RowMode::Compressed => {
                let Some(cold_rounds) = policy.cold_rounds else {
                    return;
                };
                for i in 0..self.slots.len() {
                    let slot = &self.slots[i];
                    if slot.state.load(Ordering::Relaxed) != READY {
                        continue;
                    }
                    if slot.touched.swap(0, Ordering::Relaxed) == 1 {
                        slot.cold.store(0, Ordering::Relaxed);
                    } else {
                        let streak = slot.cold.fetch_add(1, Ordering::Relaxed) + 1;
                        if streak >= cold_rounds {
                            self.evict_slot(i, &mut res);
                        }
                    }
                }
            }
        }
    }

    /// Evict one READY slot. Caller holds the residency lock with
    /// `active_runs == 1` at a barrier (workers joined).
    fn evict_slot(&self, idx: usize, res: &mut Residency) {
        let slot = &self.slots[idx];
        // SAFETY: run-exclusive at a barrier (caller contract) — no
        // reader holds a borrow of this block and no decoder can be
        // running, so taking the cell contents is unobserved.
        let blk = unsafe { (*slot.block.get()).take() };
        slot.state.store(EMPTY, Ordering::Release);
        slot.cold.store(0, Ordering::Relaxed);
        if let Some(mut b) = blk {
            let s = &self.stats;
            s.resident_blocks.fetch_sub(1, Ordering::Relaxed);
            s.resident_bytes.fetch_sub(b.heap_bytes(), Ordering::Relaxed);
            s.evictions.fetch_add(1, Ordering::Relaxed);
            b.targets.clear();
            b.weights.clear();
            b.raw.clear();
            res.free.push(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        let vals = [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX];
        for &v in &vals {
            write_varint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(read_varint(&buf, &mut pos), v);
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn zigzag_roundtrip() {
        for x in [-5i64, -1, 0, 1, 5, i64::MIN, i64::MAX] {
            assert_eq!(unzigzag(zigzag(x)), x);
        }
    }

    #[test]
    fn row_codec_roundtrip_sorted_unsorted_empty() {
        let rows: Vec<Vec<VertexId>> = vec![
            vec![],
            vec![7],
            vec![1, 2, 3, 100, 1000],
            vec![9, 3, 0, u32::MAX, 4], // unsorted: zigzag keeps it total
        ];
        let mut buf = Vec::new();
        for r in &rows {
            encode_row(&mut buf, r);
        }
        let mut pos = 0;
        for r in &rows {
            let mut out = Vec::new();
            decode_row(&buf, &mut pos, &mut out);
            assert_eq!(&out, r);
        }
        assert_eq!(pos, buf.len());
    }

    /// Tiny 5-vertex graph used across the plane tests:
    /// out rows: 0→{1,2}, 1→{2}, 2→{}, 3→{0,1,2,4}, 4→{3}.
    fn toy() -> (Vec<usize>, Vec<VertexId>) {
        (vec![0, 2, 3, 3, 7, 8], vec![1, 2, 2, 0, 1, 2, 4, 3])
    }

    fn toy_plane(block_size: usize) -> RowPlane {
        let (offs, adj) = toy();
        // Symmetric enough for a test: reuse the same arrays as "in".
        RowPlane::new_compressed(&offs, &adj, &offs, &adj, block_size)
    }

    #[test]
    fn compressed_rows_match_raw_slices() {
        let (offs, adj) = toy();
        for bs in [1, 2, 3, 16] {
            let plane = toy_plane(bs);
            for v in 0..5u32 {
                let (s, e) = (offs[v as usize], offs[v as usize + 1]);
                assert_eq!(plane.row(Dir::Out, v, s, e), &adj[s..e], "bs={bs} v={v}");
                assert_eq!(plane.row(Dir::In, v, s, e), &adj[s..e], "bs={bs} v={v}");
            }
        }
    }

    #[test]
    fn stats_count_faults_and_staging() {
        let (offs, adj) = toy();
        let plane = toy_plane(2);
        plane.pin_range(Dir::Out, 0, 5); // blocks 0..=2 staged
        let s = plane.stats();
        assert_eq!(s.staged_blocks, 3);
        assert_eq!(s.row_faults, 0);
        assert_eq!(s.decoded_edges, adj.len() as u64);
        // Demand access on the other direction faults.
        let _ = plane.row(Dir::In, 0, offs[0], offs[1]);
        assert_eq!(plane.stats().row_faults, 1);
        assert!(plane.stats().resident_blocks == 4);
    }

    #[test]
    fn cold_eviction_recycles_and_redecodes_identically() {
        let (offs, adj) = toy();
        let plane = toy_plane(2);
        plane.set_policy(RowPolicy {
            resident_blocks: None,
            cold_rounds: Some(1),
        });
        plane.run_enter();
        let r0: Vec<VertexId> = plane.row(Dir::Out, 0, offs[0], offs[1]).to_vec();
        // Advise 1 consumes the touch; advise 2 finds the block cold for
        // one full round and evicts it.
        plane.barrier_advise();
        plane.barrier_advise();
        assert_eq!(plane.stats().evictions, 1);
        assert_eq!(plane.stats().resident_blocks, 0);
        // Re-decode (from the pooled buffer) returns identical bits.
        assert_eq!(plane.row(Dir::Out, 0, offs[0], offs[1]), r0.as_slice());
        assert_eq!(&adj[offs[0]..offs[1]], r0.as_slice());
        plane.run_exit();
    }

    #[test]
    fn no_eviction_while_other_runs_active() {
        let plane = toy_plane(2);
        plane.set_policy(RowPolicy {
            resident_blocks: None,
            cold_rounds: Some(1),
        });
        plane.run_enter();
        plane.run_enter(); // a second concurrent run pins residency
        let (offs, _) = toy();
        let _ = plane.row(Dir::Out, 0, offs[0], offs[1]);
        plane.barrier_advise();
        plane.barrier_advise();
        assert_eq!(plane.stats().evictions, 0);
        plane.run_exit();
        plane.run_exit();
    }

    #[test]
    fn compression_beats_raw_on_sorted_rows() {
        // 64 vertices, dense-ish sorted rows with small gaps: varint
        // gap coding must beat 4-byte raw targets comfortably.
        let n = 64usize;
        let mut offs = vec![0usize];
        let mut adj: Vec<VertexId> = Vec::new();
        for v in 0..n {
            for t in 0..8u32 {
                adj.push((v as u32 + t) % n as u32);
            }
            let row_start = adj.len() - 8;
            adj[row_start..].sort_unstable();
            offs.push(adj.len());
        }
        let plane = RowPlane::new_compressed(&offs, &adj, &offs, &adj, 8);
        assert!(
            plane.stats().compression_ratio() >= 1.5,
            "ratio {}",
            plane.stats().compression_ratio()
        );
    }
}
