//! The paper-graph catalog: synthetic analogues of the four SNAP graphs.
//!
//! The originals (Table I of the paper) are not downloadable in this
//! offline environment and Friendster (1.8B undirected edges) would not
//! fit the testbed regardless, so each graph is replaced by a generated
//! analogue that preserves the properties the paper's optimisations
//! respond to: **average degree**, **power-law skew** and **relative
//! ordering by edge count**. See DESIGN.md §3 for the substitution
//! rationale. Absolute sizes are scaled to a single-core 35 GB machine.
//!
//! | analogue       | generator          | vertices  | ~directed edges | original (scale)      |
//! |----------------|--------------------|-----------|-----------------|-----------------------|
//! | dblp-s         | Barabási–Albert m=3| 317,080   | ~1.9M           | DBLP (1:1 vertices)   |
//! | livejournal-s  | RMAT s=20 ef=8     | 1,048,576 | ~16M            | LiveJournal (¼)       |
//! | orkut-s        | Barabási–Albert m=38| 768,110  | ~58M            | Orkut (¼)             |
//! | friendster-s   | RMAT s=21 ef=27    | 2,097,152 | ~108M           | Friendster (1/32)     |

use crate::graph::csr::Csr;
use crate::graph::{gen, io};
use crate::util::error::Result;
use std::path::{Path, PathBuf};

/// How an analogue graph is generated.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GenSpec {
    /// RMAT with Graph500 quadrants (0.57, 0.19, 0.19).
    Rmat { scale: u32, edge_factor: usize },
    /// Barabási–Albert preferential attachment.
    Ba { n: usize, m: usize },
}

/// One catalog entry.
#[derive(Debug, Clone)]
pub struct CatalogEntry {
    /// Short analogue name, e.g. `dblp-s`.
    pub name: &'static str,
    /// The SNAP graph this stands in for.
    pub stands_for: &'static str,
    /// Vertex/undirected-edge counts of the original (paper Table I).
    pub original_vertices: u64,
    pub original_edges: u64,
    /// Linear scale factor applied (1 = full size).
    pub scale_divisor: u32,
    pub spec: GenSpec,
    pub seed: u64,
}

/// The four paper graphs, ordered by ascending edge count as in Table II.
pub fn catalog() -> Vec<CatalogEntry> {
    vec![
        CatalogEntry {
            name: "dblp-s",
            stands_for: "DBLP",
            original_vertices: 317_080,
            original_edges: 1_049_866,
            scale_divisor: 1,
            spec: GenSpec::Ba {
                n: 317_080,
                m: 3,
            },
            seed: 0xDB11,
        },
        CatalogEntry {
            name: "livejournal-s",
            stands_for: "LiveJournal",
            original_vertices: 4_036_538,
            original_edges: 34_681_189,
            scale_divisor: 4,
            spec: GenSpec::Rmat {
                scale: 20,
                edge_factor: 8,
            },
            seed: 0x11FE,
        },
        CatalogEntry {
            name: "orkut-s",
            stands_for: "Orkut",
            original_vertices: 3_072_441,
            original_edges: 117_185_083,
            scale_divisor: 4,
            spec: GenSpec::Ba {
                n: 768_110,
                m: 38,
            },
            seed: 0x0CC7,
        },
        CatalogEntry {
            name: "friendster-s",
            stands_for: "Friendster",
            original_vertices: 65_608_366,
            original_edges: 1_806_067_135,
            scale_divisor: 32,
            spec: GenSpec::Rmat {
                scale: 21,
                edge_factor: 27,
            },
            seed: 0xF12E,
        },
    ]
}

/// A smaller catalog (every graph shrunk ~64×) for CI-speed smoke runs:
/// same generators, same skew, tractable in seconds.
pub fn catalog_tiny() -> Vec<CatalogEntry> {
    vec![
        CatalogEntry {
            name: "dblp-t",
            stands_for: "DBLP",
            original_vertices: 317_080,
            original_edges: 1_049_866,
            scale_divisor: 64,
            spec: GenSpec::Ba { n: 4954, m: 3 },
            seed: 0xDB11,
        },
        CatalogEntry {
            name: "livejournal-t",
            stands_for: "LiveJournal",
            original_vertices: 4_036_538,
            original_edges: 34_681_189,
            scale_divisor: 256,
            spec: GenSpec::Rmat {
                scale: 14,
                edge_factor: 8,
            },
            seed: 0x11FE,
        },
        CatalogEntry {
            name: "orkut-t",
            stands_for: "Orkut",
            original_vertices: 3_072_441,
            original_edges: 117_185_083,
            scale_divisor: 256,
            spec: GenSpec::Ba { n: 12_002, m: 38 },
            seed: 0x0CC7,
        },
        CatalogEntry {
            name: "friendster-t",
            stands_for: "Friendster",
            original_vertices: 65_608_366,
            original_edges: 1_806_067_135,
            scale_divisor: 2048,
            spec: GenSpec::Rmat {
                scale: 15,
                edge_factor: 27,
            },
            seed: 0xF12E,
        },
    ]
}

/// Look up an entry by name in either catalog.
pub fn find(name: &str) -> Option<CatalogEntry> {
    catalog()
        .into_iter()
        .chain(catalog_tiny())
        .find(|e| e.name == name)
}

impl CatalogEntry {
    /// Generate the analogue graph (expensive for the full catalog).
    ///
    /// A partial shuffle decorrelates vertex ids from degrees to the
    /// moderate level real SNAP orderings exhibit (0.92 of vertices relabelled, tuned so the
    /// static-baseline imbalance matches the paper's dynamic-scheduling
    /// speed-up band — see EXPERIMENTS.md §Perf) (see
    /// [`gen::partial_shuffle`]) — without it, static scheduling looks
    /// far worse than the paper's baseline measurements.
    pub fn generate(&self) -> Csr {
        let raw = match self.spec {
            GenSpec::Rmat { scale, edge_factor } => {
                gen::rmat(scale, edge_factor, 0.57, 0.19, 0.19, self.seed)
            }
            GenSpec::Ba { n, m } => gen::barabasi_albert(n, m, self.seed),
        };
        gen::partial_shuffle(&raw, 0.92, self.seed ^ 0x51AF_u64)
    }

    /// Cache path under `dir`.
    pub fn cache_path(&self, dir: &Path) -> PathBuf {
        dir.join(format!("{}.ipg", self.name))
    }

    /// Load from cache if present, else generate and cache.
    pub fn load_or_generate(&self, dir: &Path) -> Result<Csr> {
        let p = self.cache_path(dir);
        if p.exists() {
            return io::read_binary(&p);
        }
        let g = self.generate();
        std::fs::create_dir_all(dir)?;
        io::write_binary(&g, &p)?;
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn catalogs_ordered_by_edge_count() {
        for cat in [catalog(), catalog_tiny()] {
            for w in cat.windows(2) {
                assert!(w[0].original_edges < w[1].original_edges);
            }
        }
    }

    #[test]
    fn find_locates_entries() {
        assert!(find("dblp-s").is_some());
        assert!(find("friendster-t").is_some());
        assert!(find("nonexistent").is_none());
    }

    #[test]
    fn tiny_analogues_have_matching_degree_shape() {
        // Average degree of each tiny analogue should be within 2× of the
        // original's (that is the property the paper's results key on).
        for e in catalog_tiny() {
            let g = e.generate();
            let s = stats::degree_stats(&g);
            let orig_avg = 2.0 * e.original_edges as f64 / e.original_vertices as f64;
            assert!(
                s.avg_out_degree > orig_avg / 2.0 && s.avg_out_degree < orig_avg * 2.0,
                "{}: analogue avg {} vs original {}",
                e.name,
                s.avg_out_degree,
                orig_avg
            );
            // All analogues must be skewed (power-law-ish).
            assert!(
                s.max_out_degree as f64 > 5.0 * s.avg_out_degree,
                "{}: not skewed (max {} avg {})",
                e.name,
                s.max_out_degree,
                s.avg_out_degree
            );
        }
    }

    #[test]
    fn cache_roundtrip() {
        let e = &catalog_tiny()[0];
        let dir = std::env::temp_dir().join(format!("ipregel_cat_{}", std::process::id()));
        let g1 = e.load_or_generate(&dir).unwrap();
        assert!(e.cache_path(&dir).exists());
        let g2 = e.load_or_generate(&dir).unwrap(); // from cache
        assert_eq!(g1, g2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
