//! Dynamic-graph subsystem: a delta edge log over the immutable CSR.
//!
//! The engine's substrate ([`Csr`]) is built once and never changes —
//! which is exactly right for the paper's benchmarks and exactly wrong
//! for a service whose graph evolves under it. This module adds the
//! smallest structure that fixes that without touching the engine's hot
//! loops:
//!
//! - a [`DeltaOverlay`] carried *inside* the `Csr`: per-vertex
//!   **materialised merged rows** for the (few) vertices whose adjacency
//!   has diverged from the base arrays. Every `Csr` accessor
//!   (`out_neighbors`, `out_edge`, `in_edge`, degrees, weights) consults
//!   the overlay first, so the whole stack — engine scatter/flush,
//!   pull combining, partition planning, the simulator, every algorithm
//!   — sees the *merged* graph through the unchanged API. Overlay rows
//!   are kept in exactly the order a [`GraphBuilder`](crate::graph::GraphBuilder) rebuild would
//!   produce (sorted by target, ties by weight), which is what makes
//!   mutate-then-run **bit-identical** to rebuild-then-run
//!   (`rust/tests/test_dynamic.rs` pins this across the Strategy ×
//!   Layout × Schedule × Partitioning grid);
//! - a [`DynamicGraph`] owning the `Csr` and the mutation lifecycle:
//!   batched [`MutationSet`]s applied under a monotonically increasing
//!   **mutation epoch**, each returning a [`MutationReceipt`] (the
//!   edge-instance deltas downstream caches patch themselves with — see
//!   `engine/epoch.rs`), and **compaction** back into a fresh base CSR
//!   (via [`GraphBuilder`](crate::graph::GraphBuilder)) once the overlay crosses a spill threshold.
//!
//! The vertex set is fixed at construction (ids `0..n`); growing it is a
//! rebuild, not a mutation. Deleting `(s, d)` removes **every** parallel
//! `s → d` edge, matching what a rebuild from the surviving edge list
//! would produce.

use crate::graph::csr::{Csr, EdgeWeight, VertexId};
use crate::util::timer::Timer;
use std::collections::BTreeMap;
use std::time::Duration;

/// Sentinel in the overlay's per-vertex index: no overlay row.
const NO_ROW: u32 = u32::MAX;

/// Staged edits for one adjacency row: insertions as
/// `(neighbour, weight)` pairs plus deletion targets.
type RowEdits = (Vec<(VertexId, EdgeWeight)>, Vec<VertexId>);

/// One materialised merged adjacency row (targets sorted as a rebuilt
/// CSR row would be; `weights` parallel to `targets`, empty on
/// unweighted graphs).
#[derive(Clone, Debug, Default, PartialEq)]
pub(crate) struct OverlayRow {
    pub(crate) targets: Vec<VertexId>,
    pub(crate) weights: Vec<EdgeWeight>,
}

/// The delta edge log: per-vertex merged-row overrides over the base
/// CSR arrays, for both adjacency directions, plus the bookkeeping the
/// spill policy and metrics read.
#[derive(Clone, Debug, PartialEq)]
pub struct DeltaOverlay {
    /// `out_index[v]` = index into `out_rows`, or [`NO_ROW`].
    out_index: Vec<u32>,
    out_rows: Vec<OverlayRow>,
    /// `in_index[v]` = index into `in_rows`, or [`NO_ROW`].
    in_index: Vec<u32>,
    in_rows: Vec<OverlayRow>,
    /// Merged edge count minus base edge count.
    edge_delta: isize,
    /// Mutation instances (insertions + deletions) absorbed since the
    /// last compaction — the spill-policy gauge.
    delta_edges: usize,
}

impl DeltaOverlay {
    /// Empty overlay for an `n`-vertex graph.
    pub(crate) fn new(n: usize) -> Self {
        DeltaOverlay {
            out_index: vec![NO_ROW; n],
            out_rows: Vec::new(),
            in_index: vec![NO_ROW; n],
            in_rows: Vec::new(),
            edge_delta: 0,
            delta_edges: 0,
        }
    }

    /// The overriding out-row of `v`, if any.
    #[inline]
    pub(crate) fn out_row(&self, v: VertexId) -> Option<&OverlayRow> {
        match self.out_index.get(v as usize) {
            Some(&i) if i != NO_ROW => Some(&self.out_rows[i as usize]),
            _ => None,
        }
    }

    /// The overriding in-row of `v`, if any.
    #[inline]
    pub(crate) fn in_row(&self, v: VertexId) -> Option<&OverlayRow> {
        match self.in_index.get(v as usize) {
            Some(&i) if i != NO_ROW => Some(&self.in_rows[i as usize]),
            _ => None,
        }
    }

    /// Merged-minus-base edge count.
    #[inline]
    pub(crate) fn edge_delta(&self) -> isize {
        self.edge_delta
    }

    /// Mutation instances absorbed since the last compaction.
    #[inline]
    pub(crate) fn delta_edges(&self) -> usize {
        self.delta_edges
    }

    /// Number of vertices with an overriding row (union over both
    /// directions — an insert overlays its source's out-row and its
    /// target's in-row, two distinct vertices).
    pub(crate) fn overlaid_vertices(&self) -> usize {
        self.out_index
            .iter()
            .zip(&self.in_index)
            .filter(|&(&o, &i)| o != NO_ROW || i != NO_ROW)
            .count()
    }

    /// Approximate overlay heap bytes (for `Csr::memory_bytes`).
    pub(crate) fn memory_bytes(&self) -> usize {
        let row_bytes = |rows: &[OverlayRow]| {
            rows.iter()
                .map(|r| {
                    r.targets.len() * std::mem::size_of::<VertexId>()
                        + r.weights.len() * std::mem::size_of::<EdgeWeight>()
                })
                .sum::<usize>()
        };
        (self.out_index.len() + self.in_index.len()) * std::mem::size_of::<u32>()
            + row_bytes(&self.out_rows)
            + row_bytes(&self.in_rows)
    }

    /// Store `row` as the overriding row of `v` on the given side.
    fn set_row(&mut self, out: bool, v: VertexId, row: Vec<(VertexId, EdgeWeight)>, weighted: bool) {
        let (index, rows) = if out {
            (&mut self.out_index, &mut self.out_rows)
        } else {
            (&mut self.in_index, &mut self.in_rows)
        };
        let i = index[v as usize];
        let slot = if i == NO_ROW {
            index[v as usize] = rows.len() as u32;
            rows.push(OverlayRow::default());
            rows.last_mut().expect("just pushed")
        } else {
            &mut rows[i as usize]
        };
        slot.targets.clear();
        slot.weights.clear();
        for (t, w) in row {
            slot.targets.push(t);
            if weighted {
                slot.weights.push(w);
            }
        }
    }

    /// Give every overlay row a unit-weight array (weight promotion —
    /// mirrors a [`GraphBuilder`](crate::graph::GraphBuilder) switching to weighted mode).
    fn promote_rows(&mut self) {
        for r in self.out_rows.iter_mut().chain(self.in_rows.iter_mut()) {
            if r.weights.is_empty() {
                r.weights = vec![1.0; r.targets.len()];
            }
        }
    }

    /// Validate overlay structure against the graph shape (called from
    /// [`Csr::validate`]).
    pub(crate) fn validate(&self, n: usize, weighted: bool) -> Result<(), String> {
        if self.out_index.len() != n || self.in_index.len() != n {
            return Err("overlay index length mismatch".into());
        }
        for (side, index, rows) in [
            ("out", &self.out_index, &self.out_rows),
            ("in", &self.in_index, &self.in_rows),
        ] {
            for (v, &i) in index.iter().enumerate() {
                if i != NO_ROW && i as usize >= rows.len() {
                    return Err(format!("overlay {side}_index[{v}] out of range"));
                }
            }
            for r in rows.iter() {
                if r.targets.iter().any(|&t| (t as usize) >= n) {
                    return Err(format!("overlay {side} row target out of range"));
                }
                if weighted {
                    if r.weights.len() != r.targets.len() {
                        return Err(format!("overlay {side} row weights length mismatch"));
                    }
                    if r.weights.iter().any(|w| !w.is_finite()) {
                        return Err(format!("overlay {side} row non-finite weight"));
                    }
                } else if !r.weights.is_empty() {
                    return Err(format!("overlay {side} row weighted on unweighted graph"));
                }
                // Rebuild-order invariant: sorted by (target, weight).
                let sorted = r.targets.windows(2).enumerate().all(|(i, w)| {
                    w[0] < w[1]
                        || (w[0] == w[1]
                            && (r.weights.is_empty()
                                || r.weights[i].total_cmp(&r.weights[i + 1]).is_le()))
                });
                if !sorted {
                    return Err(format!("overlay {side} row not in rebuild order"));
                }
            }
        }
        Ok(())
    }
}

/// A batch of edge insertions and deletions, applied atomically under
/// one mutation epoch by [`DynamicGraph::apply`]. Deletions are applied
/// before insertions, and a deletion removes every parallel copy of its
/// edge.
#[derive(Clone, Debug, Default)]
pub struct MutationSet {
    inserts: Vec<(VertexId, VertexId, EdgeWeight)>,
    deletes: Vec<(VertexId, VertexId)>,
    weighted: bool,
}

impl MutationSet {
    /// Empty batch.
    pub fn new() -> Self {
        Self::default()
    }

    /// Stage inserting `src → dst` with weight `1.0`.
    pub fn insert(&mut self, src: VertexId, dst: VertexId) {
        self.inserts.push((src, dst, 1.0));
    }

    /// Stage inserting `src → dst` with an explicit weight. Applying a
    /// weighted insert to an unweighted graph promotes the whole graph
    /// to weighted (existing edges read `1.0`), exactly as mixing
    /// weighted pushes into a [`GraphBuilder`](crate::graph::GraphBuilder) does.
    pub fn insert_weighted(&mut self, src: VertexId, dst: VertexId, w: EdgeWeight) {
        assert!(w.is_finite(), "edge weight must be finite, got {w}");
        self.weighted = true;
        self.inserts.push((src, dst, w));
    }

    /// Stage inserting both directions of an undirected edge.
    pub fn insert_undirected(&mut self, a: VertexId, b: VertexId) {
        self.insert(a, b);
        if a != b {
            self.insert(b, a);
        }
    }

    /// Stage deleting every parallel `src → dst` edge.
    pub fn delete(&mut self, src: VertexId, dst: VertexId) {
        self.deletes.push((src, dst));
    }

    /// Stage deleting both directions of an undirected edge.
    pub fn delete_undirected(&mut self, a: VertexId, b: VertexId) {
        self.delete(a, b);
        if a != b {
            self.delete(b, a);
        }
    }

    /// Staged insertions as `(src, dst, weight)` triples.
    pub fn inserts(&self) -> &[(VertexId, VertexId, EdgeWeight)] {
        &self.inserts
    }

    /// Staged deletions.
    pub fn deletes(&self) -> &[(VertexId, VertexId)] {
        &self.deletes
    }

    /// Whether the batch stages nothing.
    pub fn is_empty(&self) -> bool {
        self.inserts.is_empty() && self.deletes.is_empty()
    }

    /// Number of staged mutations.
    pub fn len(&self) -> usize {
        self.inserts.len() + self.deletes.len()
    }

    /// Whether any staged insert carries an explicit weight.
    pub fn has_weighted_inserts(&self) -> bool {
        self.weighted
    }

    /// Sorted, deduplicated endpoints of every staged mutation — the
    /// frontier seed for incremental recomputation.
    pub fn touched(&self) -> Vec<VertexId> {
        let mut t: Vec<VertexId> = self
            .inserts
            .iter()
            .flat_map(|&(s, d, _)| [s, d])
            .chain(self.deletes.iter().flat_map(|&(s, d)| [s, d]))
            .collect();
        t.sort_unstable();
        t.dedup();
        t
    }
}

/// What one [`DynamicGraph::apply`] call actually did: the epoch step,
/// the edge instances inserted and removed (deletions expanded per
/// parallel copy — exactly what [`PartitionPlan::apply_edge_deltas`]
/// needs to patch shard censuses), the touched frontier, and whether
/// the batch tripped a compaction.
///
/// [`PartitionPlan::apply_edge_deltas`]: crate::graph::partition::PartitionPlan::apply_edge_deltas
#[derive(Clone, Debug)]
pub struct MutationReceipt {
    /// Epoch the graph was at before this batch.
    pub from_epoch: u64,
    /// Epoch after this batch (`from_epoch + 1` for a non-empty batch).
    pub epoch: u64,
    /// Inserted edge instances `(src, dst, weight)`.
    pub inserted: Vec<(VertexId, VertexId, EdgeWeight)>,
    /// Removed edge instances `(src, dst)`, one entry per parallel copy
    /// that actually existed.
    pub removed: Vec<(VertexId, VertexId)>,
    /// Sorted unique endpoints of the staged mutations — seed these
    /// instead of restarting cold ([`crate::algos::incremental`]).
    pub touched: Vec<VertexId>,
    /// Whether applying this batch crossed the spill threshold and
    /// compacted the overlay back into a fresh base CSR.
    pub compacted: bool,
}

impl MutationReceipt {
    /// Whether the batch only inserted edges (the warm-start-safe case
    /// for monotone algorithms like CC and SSSP).
    pub fn insert_only(&self) -> bool {
        self.removed.is_empty() && !self.inserted.is_empty()
    }
}

/// Point-in-time counters of a [`DynamicGraph`] (delta occupancy,
/// compaction census — surfaced through `RunMetrics` and the CLI).
#[derive(Clone, Copy, Debug)]
pub struct DynamicStats {
    /// Current mutation epoch.
    pub epoch: u64,
    /// Merged (served) edge count.
    pub edges: usize,
    /// Mutation instances held in the overlay since the last compaction.
    pub delta_edges: usize,
    /// `delta_edges / edges` (0.0 when fully compacted).
    pub occupancy: f64,
    /// Compactions performed so far.
    pub compactions: u64,
    /// Total wall-clock time spent compacting.
    pub compaction_time: Duration,
    /// Overlay mutation instances that trigger the next compaction.
    pub spill_threshold: usize,
}

/// A mutable graph: the base [`Csr`] plus its live delta overlay, the
/// mutation epoch, and the compaction policy. See the [module
/// docs](self) for the lifecycle.
#[derive(Clone, Debug)]
pub struct DynamicGraph {
    csr: Csr,
    epoch: u64,
    spill_threshold: usize,
    compactions: u64,
    compaction_time: Duration,
}

impl DynamicGraph {
    /// Wrap `csr` with the default spill threshold (a quarter of the
    /// base edge count, floored at 256 mutation instances).
    pub fn new(csr: Csr) -> Self {
        let threshold = (csr.num_edges() / 4).max(256);
        Self::with_spill_threshold(csr, threshold)
    }

    /// Wrap `csr`, compacting whenever the overlay holds at least
    /// `spill_threshold` mutation instances (minimum 1).
    pub fn with_spill_threshold(csr: Csr, spill_threshold: usize) -> Self {
        DynamicGraph {
            csr,
            epoch: 0,
            spill_threshold: spill_threshold.max(1),
            compactions: 0,
            compaction_time: Duration::ZERO,
        }
    }

    /// The merged graph view (base + overlay) every consumer reads.
    #[inline]
    pub fn graph(&self) -> &Csr {
        &self.csr
    }

    /// Take the graph back out (drops the mutation machinery).
    pub fn into_graph(self) -> Csr {
        self.csr
    }

    /// Current mutation epoch (0 = never mutated).
    #[inline]
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Mutation instances currently held in the overlay.
    pub fn delta_edges(&self) -> usize {
        self.csr.delta_edge_count()
    }

    /// Point-in-time counters.
    pub fn stats(&self) -> DynamicStats {
        let edges = self.csr.num_edges();
        let delta = self.delta_edges();
        DynamicStats {
            epoch: self.epoch,
            edges,
            delta_edges: delta,
            occupancy: if edges == 0 {
                0.0
            } else {
                delta as f64 / edges as f64
            },
            compactions: self.compactions,
            compaction_time: self.compaction_time,
            spill_threshold: self.spill_threshold,
        }
    }

    /// Apply one batch under the next mutation epoch. Deletions apply
    /// before insertions. Returns the receipt downstream caches patch
    /// themselves with; an empty batch is a no-op (no epoch step).
    pub fn apply(&mut self, m: &MutationSet) -> MutationReceipt {
        let from = self.epoch;
        if m.is_empty() {
            return MutationReceipt {
                from_epoch: from,
                epoch: from,
                inserted: Vec::new(),
                removed: Vec::new(),
                touched: Vec::new(),
                compacted: false,
            };
        }
        let n = self.csr.num_vertices();
        for &(s, d, _) in m.inserts() {
            assert!(
                (s as usize) < n && (d as usize) < n,
                "mutation endpoint out of range: ({s}, {d}) on {n} vertices"
            );
        }
        for &(s, d) in m.deletes() {
            assert!(
                (s as usize) < n && (d as usize) < n,
                "mutation endpoint out of range: ({s}, {d}) on {n} vertices"
            );
        }

        // Weight promotion before anything reads `has_weights`. Sized
        // from the offset totals, not the target slabs — under a row
        // plane the raw slabs are empty but the base edge count is not.
        if m.has_weighted_inserts() && !self.csr.has_weights() {
            let out_base = *self.csr.out_offsets.last().expect("offsets non-empty");
            let in_base = *self.csr.in_offsets.last().expect("offsets non-empty");
            self.csr.out_weights = Some(vec![1.0; out_base]);
            self.csr.in_weights = Some(vec![1.0; in_base]);
            if let Some(ov) = &mut self.csr.overlay {
                ov.promote_rows();
            }
        }

        if self.csr.overlay.is_none() {
            self.csr.overlay = Some(Box::new(DeltaOverlay::new(n)));
        }
        let weighted = self.csr.has_weights();

        // ---- Out side: rows keyed by src (removals recorded here; the
        // in side applies the identical edits keyed by dst, so its
        // removal multiset is the same by the CSR invariant) -----------
        let mut by_src: BTreeMap<VertexId, RowEdits> = BTreeMap::new();
        for &(s, d, w) in m.inserts() {
            by_src.entry(s).or_default().0.push((d, w));
        }
        for &(s, d) in m.deletes() {
            by_src.entry(s).or_default().1.push(d);
        }
        let mut removed: Vec<(VertexId, VertexId)> = Vec::new();
        rewrite_rows(&mut self.csr, &by_src, true, weighted, Some(&mut removed));

        // ---- In side: same edits keyed by dst ------------------------
        let mut by_dst: BTreeMap<VertexId, RowEdits> = BTreeMap::new();
        for &(s, d, w) in m.inserts() {
            by_dst.entry(d).or_default().0.push((s, w));
        }
        for &(s, d) in m.deletes() {
            by_dst.entry(d).or_default().1.push(s);
        }
        rewrite_rows(&mut self.csr, &by_dst, false, weighted, None);

        let ov = self.csr.overlay.as_mut().expect("overlay just ensured");
        ov.edge_delta += m.inserts().len() as isize - removed.len() as isize;
        ov.delta_edges += m.inserts().len() + removed.len();
        self.epoch += 1;

        let compacted = if self.delta_edges() >= self.spill_threshold {
            self.compact()
        } else {
            false
        };
        MutationReceipt {
            from_epoch: from,
            epoch: self.epoch,
            inserted: m.inserts().to_vec(),
            removed,
            touched: m.touched(),
            compacted,
        }
    }

    /// Fold the overlay back into a fresh base CSR via
    /// [`Csr::rebuilt`] (O(V + E); the logical graph — and thus every
    /// run result — is unchanged), then re-apply any row-plane backing
    /// the graph carried: compress in place, or rewrite the external
    /// arena at its recorded path (fresh inode, so serving-layer
    /// snapshot readers keep their old bytes — see `graph/io.rs`).
    /// Returns whether anything was compacted.
    pub fn compact(&mut self) -> bool {
        if self.csr.overlay.is_none() {
            return false;
        }
        let t = Timer::start();
        let spec = self.csr.backing_spec();
        let mut g = self.csr.rebuilt();
        if let Some(spec) = &spec {
            g = g
                .with_backing(spec)
                .expect("re-applying row backing after compaction");
        }
        self.csr = g;
        self.compactions += 1;
        self.compaction_time += t.elapsed();
        true
    }
}

/// Apply one side's staged row edits to the overlay: for each dirty
/// row key, snapshot the current merged row, apply deletions (recording
/// actually-removed instances as `(key, target)` when asked), append
/// insertions, and store the result in rebuild order. Shared by the
/// out side (keyed by src) and the in side (keyed by dst) so the two
/// CSR views cannot drift apart. Row snapshots go through the `Csr`
/// accessors (overlay → row plane → raw slab), so mutation is
/// backing-agnostic: compressed and out-of-core graphs mutate exactly
/// like raw ones.
fn rewrite_rows(
    g: &mut Csr,
    edits: &BTreeMap<VertexId, RowEdits>,
    out: bool,
    weighted: bool,
    mut removed: Option<&mut Vec<(VertexId, VertexId)>>,
) {
    for (&key, (ins, dels)) in edits {
        let mut row = snapshot_row(g, out, key);
        for &t in dels.iter() {
            let before = row.len();
            row.retain(|&(x, _)| x != t);
            if let Some(r) = removed.as_deref_mut() {
                for _ in 0..(before - row.len()) {
                    r.push((key, t));
                }
            }
        }
        row.extend(ins.iter().copied());
        sort_row(&mut row, weighted);
        g.overlay
            .as_mut()
            .expect("overlay ensured by apply")
            .set_row(out, key, row, weighted);
    }
}

/// Current merged row of one vertex as owned `(neighbour, weight)`
/// pairs, read through the merged accessors (weight `1.0` throughout on
/// unweighted graphs).
fn snapshot_row(g: &Csr, out: bool, v: VertexId) -> Vec<(VertexId, EdgeWeight)> {
    let (nbrs, ws) = if out {
        (g.out_neighbors(v), g.out_weights_of(v))
    } else {
        (g.in_neighbors(v), g.in_weights_of(v))
    };
    match ws {
        Some(ws) => nbrs.iter().zip(ws).map(|(&t, &w)| (t, w)).collect(),
        None => nbrs.iter().map(|&t| (t, 1.0)).collect(),
    }
}

/// Sort a merged row into rebuild order: by target, ties by weight —
/// exactly the order [`GraphBuilder`](crate::graph::GraphBuilder) leaves rows in.
fn sort_row(row: &mut [(VertexId, EdgeWeight)], weighted: bool) {
    if weighted {
        row.sort_unstable_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)));
    } else {
        row.sort_unstable_by_key(|e| e.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::gen;
    use crate::util::quick;
    use crate::util::rng::Rng;

    /// Rebuild the merged view from scratch through the builder — the
    /// ground truth every delta-merged row must match exactly.
    fn rebuild(g: &Csr) -> Csr {
        g.rebuilt()
    }

    fn assert_rows_match(dyn_g: &Csr, rebuilt: &Csr) {
        assert_eq!(dyn_g.num_vertices(), rebuilt.num_vertices());
        assert_eq!(dyn_g.num_edges(), rebuilt.num_edges());
        assert_eq!(dyn_g.has_weights(), rebuilt.has_weights());
        for v in rebuilt.vertices() {
            assert_eq!(dyn_g.out_degree(v), rebuilt.out_degree(v), "out deg v{v}");
            assert_eq!(dyn_g.in_degree(v), rebuilt.in_degree(v), "in deg v{v}");
            for i in 0..rebuilt.out_degree(v) {
                assert_eq!(dyn_g.out_edge(v, i), rebuilt.out_edge(v, i), "out v{v}#{i}");
            }
            for i in 0..rebuilt.in_degree(v) {
                assert_eq!(dyn_g.in_edge(v, i), rebuilt.in_edge(v, i), "in v{v}#{i}");
            }
        }
    }

    #[test]
    fn inserts_appear_in_both_directions_in_rebuild_order() {
        let g = gen::ring(6); // v -> v+1, v -> v-1 (symmetric ring)
        let mut dg = DynamicGraph::new(g);
        let mut m = MutationSet::new();
        m.insert(0, 3);
        m.insert(3, 0);
        let r = dg.apply(&m);
        assert_eq!(r.from_epoch, 0);
        assert_eq!(r.epoch, 1);
        assert_eq!(r.touched, vec![0, 3]);
        assert!(r.insert_only());
        assert!(!r.compacted);
        assert_eq!(dg.graph().out_neighbors(0), &[1, 3, 5]);
        assert_eq!(dg.graph().in_neighbors(0), &[1, 3, 5]);
        dg.graph().validate().unwrap();
        assert_rows_match(dg.graph(), &rebuild(dg.graph()));
    }

    #[test]
    fn delete_removes_every_parallel_copy() {
        let g = GraphBuilder::new(3)
            .edges(&[(0, 1), (0, 1), (0, 2), (1, 2)])
            .build();
        let mut dg = DynamicGraph::new(g);
        let mut m = MutationSet::new();
        m.delete(0, 1);
        let r = dg.apply(&m);
        assert_eq!(r.removed, vec![(0, 1), (0, 1)]);
        assert!(!r.insert_only());
        assert_eq!(dg.graph().out_neighbors(0), &[2]);
        assert_eq!(dg.graph().in_neighbors(1), &[] as &[u32]);
        assert_eq!(dg.graph().num_edges(), 2);
        dg.graph().validate().unwrap();
    }

    #[test]
    fn delete_then_insert_same_batch_deletes_first() {
        let g = GraphBuilder::new(2).edges(&[(0, 1)]).build();
        let mut dg = DynamicGraph::new(g);
        let mut m = MutationSet::new();
        m.delete(0, 1);
        m.insert(0, 1);
        let r = dg.apply(&m);
        assert_eq!(r.removed, vec![(0, 1)]);
        assert_eq!(r.inserted, vec![(0, 1, 1.0)]);
        assert_eq!(dg.graph().out_neighbors(0), &[1]);
        assert_eq!(dg.graph().num_edges(), 1);
    }

    #[test]
    fn deleting_missing_edge_is_a_recorded_noop() {
        let g = gen::path(4);
        let mut dg = DynamicGraph::new(g);
        let mut m = MutationSet::new();
        m.delete(0, 3);
        let r = dg.apply(&m);
        assert!(r.removed.is_empty());
        assert_eq!(r.epoch, 1, "epoch still advances for a non-empty batch");
        assert_rows_match(dg.graph(), &rebuild(dg.graph()));
    }

    #[test]
    fn weighted_insert_promotes_unweighted_graph() {
        let g = gen::path(3); // unweighted
        let mut dg = DynamicGraph::new(g);
        let mut m = MutationSet::new();
        m.insert_weighted(0, 2, 2.5);
        dg.apply(&m);
        let g = dg.graph();
        assert!(g.has_weights());
        // Pre-existing edges read 1.0 — the builder's mixing rule.
        assert_eq!(g.out_edge(1, 0), (2, 1.0));
        assert_eq!(g.out_edge(0, 1), (2, 2.5));
        g.validate().unwrap();
        assert_rows_match(g, &rebuild(g));
    }

    #[test]
    fn weighted_parallel_edges_sort_by_weight_like_a_rebuild() {
        let g = GraphBuilder::new(2)
            .weighted_edges(&[(0, 1, 5.0)])
            .build();
        let mut dg = DynamicGraph::new(g);
        let mut m = MutationSet::new();
        m.insert_weighted(0, 1, 2.0);
        m.insert_weighted(0, 1, 9.0);
        dg.apply(&m);
        assert_eq!(dg.graph().out_weights_of(0), Some(&[2.0, 5.0, 9.0][..]));
        assert_rows_match(dg.graph(), &rebuild(dg.graph()));
    }

    #[test]
    fn empty_batch_is_a_true_noop() {
        let g = gen::ring(5);
        let mut dg = DynamicGraph::new(g);
        let r = dg.apply(&MutationSet::new());
        assert_eq!(r.from_epoch, 0);
        assert_eq!(r.epoch, 0);
        assert_eq!(dg.epoch(), 0);
        assert!(!dg.graph().has_overlay());
    }

    #[test]
    fn spill_threshold_triggers_compaction() {
        let g = gen::ring(8);
        let mut dg = DynamicGraph::with_spill_threshold(g, 3);
        let mut m = MutationSet::new();
        m.insert(0, 4);
        dg.apply(&m); // 1 instance < 3
        assert!(dg.graph().has_overlay());
        let mut m2 = MutationSet::new();
        m2.insert(1, 5);
        m2.insert(2, 6);
        let r = dg.apply(&m2); // 3 instances >= 3 → compact
        assert!(r.compacted);
        assert!(!dg.graph().has_overlay());
        assert_eq!(dg.stats().compactions, 1);
        assert_eq!(dg.stats().delta_edges, 0);
        assert_eq!(dg.graph().num_edges(), 8 * 2 + 3);
        dg.graph().validate().unwrap();
        // Compaction preserved the logical graph.
        assert_rows_match(dg.graph(), &rebuild(dg.graph()));
    }

    #[test]
    fn stats_track_occupancy_and_epoch() {
        let g = gen::ring(10);
        let mut dg = DynamicGraph::with_spill_threshold(g, 1_000_000);
        assert_eq!(dg.stats().occupancy, 0.0);
        let mut m = MutationSet::new();
        m.insert_undirected(0, 5);
        dg.apply(&m);
        let st = dg.stats();
        assert_eq!(st.epoch, 1);
        assert_eq!(st.delta_edges, 2);
        assert_eq!(st.edges, 22);
        assert!(st.occupancy > 0.0);
        assert_eq!(st.compactions, 0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_mutation_rejected() {
        let mut dg = DynamicGraph::new(gen::ring(4));
        let mut m = MutationSet::new();
        m.insert(0, 99);
        dg.apply(&m);
    }

    #[test]
    fn mutations_over_compressed_backing_match_rebuild() {
        let g = gen::ring(8).compress(3);
        let mut dg = DynamicGraph::with_spill_threshold(g, 1_000_000);
        let mut m = MutationSet::new();
        m.insert(0, 4);
        m.delete(0, 1);
        let r = dg.apply(&m);
        assert_eq!(r.removed, vec![(0, 1)]);
        assert_eq!(dg.graph().out_neighbors(0), &[4, 7]);
        dg.graph().validate().unwrap();
        assert_rows_match(dg.graph(), &rebuild(dg.graph()));
    }

    #[test]
    fn compaction_restores_compressed_backing() {
        let g = gen::ring(8).compress(4);
        let mut dg = DynamicGraph::with_spill_threshold(g, 1);
        let mut m = MutationSet::new();
        m.insert(1, 5);
        let r = dg.apply(&m);
        assert!(r.compacted);
        let p = dg.graph().row_plane().expect("backing restored");
        assert_eq!(p.mode(), crate::graph::RowMode::Compressed);
        assert_eq!(p.block_size(), 4);
        assert!(!dg.graph().has_overlay());
        assert_eq!(dg.graph().out_neighbors(1), &[0, 2, 5]);
        dg.graph().validate().unwrap();
        assert_rows_match(dg.graph(), &rebuild(dg.graph()));
    }

    #[test]
    fn prop_random_mutation_sequences_match_rebuild() {
        quick::check("dynamic rows == rebuilt rows", |rng| {
            let n = 2 + rng.below(40) as usize;
            let m0 = rng.below(3 * n as u64) as usize;
            let weighted = rng.chance(0.5);
            let g = random_graph(rng, n, m0, weighted);
            let threshold = if rng.chance(0.3) {
                1 + rng.below(6) as usize // exercise mid-sequence compaction
            } else {
                1_000_000
            };
            let mut dg = DynamicGraph::with_spill_threshold(g, threshold);
            for _ in 0..(1 + rng.below(4)) {
                let m = random_mutations(rng, dg.graph(), weighted);
                dg.apply(&m);
                dg.graph().validate()?;
                let rebuilt = rebuild(dg.graph());
                for v in rebuilt.vertices() {
                    let got: Vec<_> = (0..dg.graph().out_degree(v))
                        .map(|i| dg.graph().out_edge(v, i))
                        .collect();
                    let want: Vec<_> =
                        (0..rebuilt.out_degree(v)).map(|i| rebuilt.out_edge(v, i)).collect();
                    if got != want {
                        return Err(format!("v{v}: {got:?} vs rebuilt {want:?}"));
                    }
                }
                if dg.graph().num_edges() != rebuilt.num_edges() {
                    return Err("edge count diverged from rebuild".into());
                }
            }
            Ok(())
        });
    }

    fn random_graph(rng: &mut Rng, n: usize, m: usize, weighted: bool) -> Csr {
        let edges = quick::random_edges(rng, n, m);
        let mut gb = GraphBuilder::new(n);
        for (s, d) in edges {
            if weighted {
                gb.push_weighted_edge(s, d, (1 + rng.below(80)) as f64 / 8.0);
            } else {
                gb.push_edge(s, d);
            }
        }
        gb.build()
    }

    fn random_mutations(rng: &mut Rng, g: &Csr, weighted: bool) -> MutationSet {
        let n = g.num_vertices() as u64;
        let mut m = MutationSet::new();
        for _ in 0..rng.below(6) {
            let (s, d) = (rng.below(n) as VertexId, rng.below(n) as VertexId);
            if weighted {
                m.insert_weighted(s, d, (1 + rng.below(80)) as f64 / 8.0);
            } else {
                m.insert(s, d);
            }
        }
        for _ in 0..rng.below(4) {
            // Half the deletes target real edges, half are misses.
            if rng.chance(0.5) && g.num_edges() > 0 {
                let v = (0..g.num_vertices() as VertexId)
                    .find(|&v| g.out_degree(v) > 0)
                    .unwrap();
                let d = g.out_neighbors(v)[rng.below(g.out_degree(v) as u64) as usize];
                m.delete(v, d);
            } else {
                m.delete(rng.below(n) as VertexId, rng.below(n) as VertexId);
            }
        }
        m
    }
}
