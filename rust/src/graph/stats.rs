//! Degree statistics and distribution summaries.
//!
//! Used by `ipregel info`, the Table I reproduction, and by tests that
//! assert our synthetic analogues match the originals' degree shapes.

use crate::graph::csr::Csr;

/// Summary statistics of a graph's degree distribution.
#[derive(Debug, Clone, PartialEq)]
pub struct DegreeStats {
    pub num_vertices: usize,
    pub num_directed_edges: usize,
    pub avg_out_degree: f64,
    pub max_out_degree: usize,
    pub max_in_degree: usize,
    /// Out-degree Gini coefficient ∈ [0,1): 0 = perfectly regular,
    /// →1 = extremely skewed. Our power-law analogues sit well above a
    /// same-size Erdős–Rényi graph.
    pub gini: f64,
    /// Fraction of directed edges owned by the top 1% highest-degree
    /// vertices — the hub concentration that breaks per-vertex work
    /// distribution (paper §V-A).
    pub top1pct_edge_share: f64,
    pub isolated_vertices: usize,
}

/// Compute [`DegreeStats`] for a graph.
pub fn degree_stats(g: &Csr) -> DegreeStats {
    let n = g.num_vertices();
    let m = g.num_edges();
    let mut degs: Vec<usize> = g.vertices().map(|v| g.out_degree(v)).collect();
    let max_out = degs.iter().copied().max().unwrap_or(0);
    let max_in = g.vertices().map(|v| g.in_degree(v)).max().unwrap_or(0);
    let isolated = degs.iter().filter(|&&d| d == 0).count();
    degs.sort_unstable();

    // Gini via the sorted-sum formula.
    let total: f64 = m as f64;
    let gini = if n == 0 || total == 0.0 {
        0.0
    } else {
        let weighted: f64 = degs
            .iter()
            .enumerate()
            .map(|(i, &d)| (i as f64 + 1.0) * d as f64)
            .sum();
        (2.0 * weighted) / (n as f64 * total) - (n as f64 + 1.0) / n as f64
    };

    let top = (n / 100).max(1);
    let top_edges: usize = degs.iter().rev().take(top).sum();
    let top1pct_edge_share = if m == 0 { 0.0 } else { top_edges as f64 / m as f64 };

    DegreeStats {
        num_vertices: n,
        num_directed_edges: m,
        avg_out_degree: if n == 0 { 0.0 } else { m as f64 / n as f64 },
        max_out_degree: max_out,
        max_in_degree: max_in,
        gini,
        top1pct_edge_share,
        isolated_vertices: isolated,
    }
}

/// Log2-bucketed out-degree histogram: `hist[k]` counts vertices with
/// degree in `[2^k, 2^(k+1))`; `hist[0]` additionally includes degree 0.
pub fn degree_histogram(g: &Csr) -> Vec<usize> {
    let mut hist = Vec::new();
    for v in g.vertices() {
        let d = g.out_degree(v);
        let bucket = if d <= 1 { 0 } else { (usize::BITS - (d as usize).leading_zeros()) as usize - 1 };
        if hist.len() <= bucket {
            hist.resize(bucket + 1, 0);
        }
        hist[bucket] += 1;
    }
    hist
}

/// Render a small text table of the histogram for `ipregel info`.
pub fn render_histogram(hist: &[usize]) -> String {
    let total: usize = hist.iter().sum();
    let mut out = String::from("degree      vertices\n");
    for (k, &c) in hist.iter().enumerate() {
        if c == 0 {
            continue;
        }
        let lo = if k == 0 { 0 } else { 1usize << k };
        let hi = (1usize << (k + 1)) - 1;
        let bar_len = (c * 40 / total.max(1)).max(if c > 0 { 1 } else { 0 });
        out.push_str(&format!(
            "{:>6}-{:<6} {:>10} {}\n",
            lo,
            hi,
            c,
            "#".repeat(bar_len)
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn regular_graph_has_zero_gini() {
        let g = gen::ring(100);
        let s = degree_stats(&g);
        assert_eq!(s.max_out_degree, 2);
        assert!(s.gini.abs() < 1e-9, "gini={}", s.gini);
        assert_eq!(s.isolated_vertices, 0);
    }

    #[test]
    fn star_is_maximally_skewed() {
        let g = gen::star(1000);
        let s = degree_stats(&g);
        assert_eq!(s.max_out_degree, 999);
        // Every leaf still has degree 1, so the Gini of a star tops out
        // near 0.5 — the hub owns half of all directed edges.
        assert!(s.gini > 0.45, "gini={}", s.gini);
        assert!(s.top1pct_edge_share > 0.4);
    }

    #[test]
    fn rmat_more_skewed_than_er() {
        let rmat = gen::rmat(11, 8, 0.57, 0.19, 0.19, 3);
        let er = gen::erdos_renyi(2048, 2048 * 8, 3);
        let (sr, se) = (degree_stats(&rmat), degree_stats(&er));
        assert!(
            sr.gini > se.gini + 0.1,
            "rmat gini {} vs er gini {}",
            sr.gini,
            se.gini
        );
    }

    #[test]
    fn histogram_counts_all_vertices() {
        let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 9);
        let h = degree_histogram(&g);
        assert_eq!(h.iter().sum::<usize>(), g.num_vertices());
        let rendered = render_histogram(&h);
        assert!(rendered.contains("vertices"));
    }

    #[test]
    fn stats_on_empty_graph() {
        let g = crate::graph::GraphBuilder::new(5).build();
        let s = degree_stats(&g);
        assert_eq!(s.num_directed_edges, 0);
        assert_eq!(s.isolated_vertices, 5);
        assert_eq!(s.gini, 0.0);
    }
}
