//! Graph substrate: compressed sparse-row storage, construction,
//! generation, persistence and statistics.
//!
//! Everything downstream (engine, schedulers, experiments) consumes the
//! [`Csr`] type, which stores both out- and in-adjacency so that push- and
//! pull-based engine versions can traverse in either direction.

pub mod builder;
pub mod catalog;
pub mod csr;
pub mod dynamic;
pub mod gen;
pub mod io;
pub mod partition;
pub mod rows;
pub mod stats;

pub use builder::GraphBuilder;
pub use csr::{Csr, EdgeWeight, VertexId};
pub use dynamic::{DynamicGraph, DynamicStats, MutationReceipt, MutationSet};
pub use partition::{PartitionPlan, Partitioning};
pub use rows::{RowMode, RowPlaneStats, RowPolicy, RowSpec};
