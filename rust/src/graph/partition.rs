//! Edge-balanced graph partitioning: the substrate for sharded execution.
//!
//! A [`PartitionPlan`] cuts a [`Csr`]'s vertex range into contiguous,
//! edge-balanced shards using the same degree-prefix machinery as the
//! edge-centric schedule ([`crate::util::prefix::balanced_cuts`], paper
//! §V-A — the partitioner *is* the edge-centric cut promoted to a
//! persistent runtime object). Each shard owns:
//!
//! - a contiguous vertex id range (`cuts[s]..cuts[s+1]`), which makes the
//!   shard's mailbox slots a contiguous slab of the vertex store — the
//!   cache-locality property the whole design exists for;
//! - an entry in the **owner map** (`shard_of`), the O(1) routing oracle
//!   the engine consults on every cross-shard send;
//! - intra/cross **edge classification** counts: an out-edge is *interior*
//!   when both endpoints share a shard (delivered in place during
//!   scatter) and *cross* otherwise (buffered and flushed shard-at-a-time
//!   — see `engine/core.rs`).
//!
//! Shard weights are `out_degree + in_degree`, so one plan balances both
//! push scatter (out-edges) and pull gather (in-edges) work.
//!
//! [`Partitioning`] is the user-facing knob in
//! [`EngineConfig`](crate::engine::EngineConfig): `None` preserves the
//! flat engine, `Shards(k)` asks for an explicit shard count, and
//! `CacheSized` derives the count from a per-shard hot-state byte budget.

use crate::graph::csr::{Csr, VertexId};
use crate::util::prefix::{balanced_cuts, exclusive_prefix_sum};
use std::ops::Range;
use std::sync::Arc;

/// Estimated hot bytes per vertex for [`Partitioning::CacheSized`]: two
/// 16-byte mailbox slots, the user value and activity bits, rounded to a
/// cache line.
pub const HOT_BYTES_PER_VERTEX: usize = 64;

/// Default per-shard hot-state budget: half of a typical 4 MiB per-core
/// L2/LLC slice, leaving room for the CSR rows the scatter walks.
pub const DEFAULT_SHARD_BUDGET: usize = 2 * 1024 * 1024;

/// How (and whether) a run shards the graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Partitioning {
    /// Flat execution: one vertex range, one global mailbox array — the
    /// pre-partition engine, bit-for-bit.
    #[default]
    None,
    /// Exactly `k` edge-balanced shards (clamped to the vertex count).
    Shards(usize),
    /// As many shards as needed so each shard's hot vertex state fits in
    /// `budget_bytes` ([`HOT_BYTES_PER_VERTEX`] per vertex).
    CacheSized {
        /// Per-shard hot-state byte budget.
        budget_bytes: usize,
    },
}

impl Partitioning {
    /// Parse from CLI text: `none`, a shard count (`8`), or
    /// `cache[:bytes]`.
    pub fn parse(s: &str) -> Option<Partitioning> {
        match s {
            "none" | "flat" | "0" => Some(Partitioning::None),
            "cache" => Some(Partitioning::CacheSized {
                budget_bytes: DEFAULT_SHARD_BUDGET,
            }),
            _ => match s.split_once(':') {
                Some(("cache", b)) => Some(Partitioning::CacheSized {
                    budget_bytes: b.parse().ok()?,
                }),
                Some(_) => None,
                None => s.parse().ok().map(Partitioning::Shards),
            },
        }
    }

    /// Resolve to a concrete shard count for an `n`-vertex graph.
    /// Returns 0 for flat execution ([`Partitioning::None`], and
    /// `Shards(0)` — every entry point treats 0 shards as "no
    /// partitioning"); otherwise at least 1 and at most `n.max(1)`.
    pub fn resolve(self, n: usize) -> usize {
        match self {
            Partitioning::None | Partitioning::Shards(0) => 0,
            Partitioning::Shards(k) => k.clamp(1, n.max(1)),
            Partitioning::CacheSized { budget_bytes } => {
                let per_shard = (budget_bytes / HOT_BYTES_PER_VERTEX).max(1);
                crate::util::div_ceil(n.max(1), per_shard).clamp(1, n.max(1))
            }
        }
    }
}

/// Snap interior cut points to row-block boundaries (DESIGN.md §2.12).
/// On plane-backed graphs a shard whose range covers whole blocks
/// decodes nothing another shard also needs: scatter staging
/// ([`crate::graph::rows::RowPlane`]'s `pin_range`) never races a
/// neighbour shard for a boundary block, and residency budgets count
/// whole shards. Each cut moves at most half a block — bounded extra
/// edge imbalance — and the 0/`n` endpoints stay pinned. Cut placement
/// is an execution knob: the parity grid pins that shard boundaries
/// never change values or traces.
fn align_to_blocks(mut cuts: Vec<usize>, block: usize, n: usize) -> Vec<usize> {
    for i in 1..cuts.len().saturating_sub(1) {
        let snapped = (cuts[i] + block / 2) / block * block;
        cuts[i] = snapped.clamp(cuts[i - 1], n);
    }
    cuts
}

/// An immutable partition of one graph into contiguous, edge-balanced
/// shards. Built once per (graph, shard count) and shared by `Arc`
/// across runs (the session caches plans keyed by resolved shard count).
#[derive(Clone, Debug)]
pub struct PartitionPlan {
    /// Shard boundaries over vertex ids: `shards + 1` entries, first 0,
    /// last `n`, non-decreasing. Shard `s` owns `cuts[s]..cuts[s+1]`.
    /// `Arc`-shared: cuts never change short of a full re-partition, so
    /// an epoch-patched clone (see `engine/epoch.rs`) shares them.
    cuts: Arc<Vec<usize>>,
    /// `owner[v]` = shard owning vertex `v` (redundant with `cuts`, kept
    /// dense for O(1) routing on the send hot path). `Arc`-shared like
    /// `cuts`, keeping plan clones O(shards) rather than O(V) — only
    /// the per-shard censuses below are deep-copied when a mutation
    /// batch patches a cached plan.
    owner: Arc<Vec<u32>>,
    /// Per-shard total out-edges (scatter-side work, push mode).
    out_edges: Vec<u64>,
    /// Per-shard total in-edges (gather-side work, pull mode).
    in_edges: Vec<u64>,
    /// Per-shard out-edges whose target lives in the same shard.
    interior_out: Vec<u64>,
    /// Per-shard out-edges whose target lives in another shard.
    cross_out: Vec<u64>,
}

impl PartitionPlan {
    /// Cut `g` into `shards` contiguous ranges balanced by
    /// `out_degree + in_degree`, then classify every out-edge as
    /// interior or cross.
    pub fn build(g: &Csr, shards: usize) -> PartitionPlan {
        let n = g.num_vertices();
        let shards = shards.clamp(1, n.max(1));
        let weights: Vec<u64> = g
            .vertices()
            .map(|v| (g.out_degree(v) + g.in_degree(v)) as u64)
            .collect();
        let prefix = exclusive_prefix_sum(&weights);
        let cuts = match g.row_plane() {
            Some(p) => align_to_blocks(balanced_cuts(&prefix, shards), p.block_size(), n),
            None => balanced_cuts(&prefix, shards),
        };

        let mut owner = vec![0u32; n];
        for s in 0..shards {
            for o in &mut owner[cuts[s]..cuts[s + 1]] {
                *o = s as u32;
            }
        }

        let mut out_edges = vec![0u64; shards];
        let mut in_edges = vec![0u64; shards];
        let mut interior_out = vec![0u64; shards];
        let mut cross_out = vec![0u64; shards];
        for v in g.vertices() {
            let s = owner[v as usize] as usize;
            out_edges[s] += g.out_degree(v) as u64;
            in_edges[s] += g.in_degree(v) as u64;
            for &dst in g.out_neighbors(v) {
                if owner[dst as usize] as usize == s {
                    interior_out[s] += 1;
                } else {
                    cross_out[s] += 1;
                }
            }
        }

        PartitionPlan {
            cuts: Arc::new(cuts),
            owner: Arc::new(owner),
            out_edges,
            in_edges,
            interior_out,
            cross_out,
        }
    }

    /// Number of shards.
    #[inline]
    pub fn num_shards(&self) -> usize {
        self.cuts.len() - 1
    }

    /// Number of vertices covered.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.owner.len()
    }

    /// Shard owning vertex `v` — the routing oracle.
    #[inline]
    pub fn shard_of(&self, v: VertexId) -> usize {
        self.owner[v as usize] as usize
    }

    /// Vertex id range of shard `s`.
    #[inline]
    pub fn shard_range(&self, s: usize) -> Range<usize> {
        self.cuts[s]..self.cuts[s + 1]
    }

    /// Number of vertices in shard `s`.
    #[inline]
    pub fn shard_len(&self, s: usize) -> usize {
        self.cuts[s + 1] - self.cuts[s]
    }

    /// Shard boundaries (`shards + 1` entries).
    #[inline]
    pub fn cuts(&self) -> &[usize] {
        &self.cuts
    }

    /// Per-shard total out-edges.
    #[inline]
    pub fn out_edges(&self) -> &[u64] {
        &self.out_edges
    }

    /// Per-shard total in-edges.
    #[inline]
    pub fn in_edges(&self) -> &[u64] {
        &self.in_edges
    }

    /// Per-shard interior out-edges (both endpoints in the shard).
    #[inline]
    pub fn interior_out(&self) -> &[u64] {
        &self.interior_out
    }

    /// Per-shard cross out-edges (target owned elsewhere).
    #[inline]
    pub fn cross_out(&self) -> &[u64] {
        &self.cross_out
    }

    /// Total cross-shard out-edges.
    pub fn total_cross(&self) -> u64 {
        self.cross_out.iter().sum()
    }

    /// Incrementally patch the per-shard edge censuses after a graph
    /// mutation batch (see [`crate::graph::dynamic::MutationReceipt`]):
    /// the cuts and owner map are untouched — vertex ranges never move
    /// short of a full re-partition — so only the out/in/interior/cross
    /// counts need adjusting, one O(1) update per edge instance. `removed`
    /// entries must be edge instances that actually existed (the receipt
    /// guarantees this), otherwise the counts would underflow.
    pub fn apply_edge_deltas(
        &mut self,
        inserted: &[(VertexId, VertexId, crate::graph::csr::EdgeWeight)],
        removed: &[(VertexId, VertexId)],
    ) {
        for &(s, d, _) in inserted {
            self.bump_edge(s, d, true);
        }
        for &(s, d) in removed {
            self.bump_edge(s, d, false);
        }
    }

    fn bump_edge(&mut self, s: VertexId, d: VertexId, add: bool) {
        let ss = self.shard_of(s);
        let ds = self.shard_of(d);
        if add {
            self.out_edges[ss] += 1;
            self.in_edges[ds] += 1;
            if ss == ds {
                self.interior_out[ss] += 1;
            } else {
                self.cross_out[ss] += 1;
            }
        } else {
            self.out_edges[ss] -= 1;
            self.in_edges[ds] -= 1;
            if ss == ds {
                self.interior_out[ss] -= 1;
            } else {
                self.cross_out[ss] -= 1;
            }
        }
    }

    /// Edge imbalance: max shard weight over mean shard weight (weights
    /// as used for the cut: out + in degree). 1.0 is a perfect cut; an
    /// edgeless graph reports 1.0.
    pub fn edge_imbalance(&self) -> f64 {
        let loads: Vec<u64> = (0..self.num_shards())
            .map(|s| self.out_edges[s] + self.in_edges[s])
            .collect();
        let total: u64 = loads.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / loads.len() as f64;
        let max = *loads.iter().max().unwrap() as f64;
        max / mean
    }

    /// Structural validation used by tests: cuts cover `0..n` monotonely,
    /// the owner map agrees with the cuts, and the interior/cross counts
    /// classify every out-edge exactly once.
    pub fn validate(&self, g: &Csr) -> Result<(), String> {
        let n = g.num_vertices();
        if self.owner.len() != n {
            return Err("owner map length mismatch".into());
        }
        if self.cuts.first() != Some(&0) || self.cuts.last() != Some(&n) {
            return Err("cuts endpoints wrong".into());
        }
        if self.cuts.windows(2).any(|w| w[0] > w[1]) {
            return Err("cuts not monotone".into());
        }
        for (v, &o) in self.owner.iter().enumerate() {
            let s = o as usize;
            if s >= self.num_shards() || !self.shard_range(s).contains(&v) {
                return Err(format!("owner[{v}] disagrees with cuts"));
            }
        }
        let mut interior = vec![0u64; self.num_shards()];
        let mut cross = vec![0u64; self.num_shards()];
        for (src, dst) in g.edges() {
            let s = self.shard_of(src);
            if s == self.shard_of(dst) {
                interior[s] += 1;
            } else {
                cross[s] += 1;
            }
        }
        if interior != self.interior_out || cross != self.cross_out {
            return Err("interior/cross classification mismatch".into());
        }
        let classified: u64 = interior.iter().chain(cross.iter()).sum();
        if classified != g.num_edges() as u64 {
            return Err("edge classification does not cover every edge once".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::util::quick;

    #[test]
    fn parse_all_forms() {
        assert_eq!(Partitioning::parse("none"), Some(Partitioning::None));
        assert_eq!(Partitioning::parse("0"), Some(Partitioning::None));
        assert_eq!(Partitioning::parse("8"), Some(Partitioning::Shards(8)));
        assert_eq!(
            Partitioning::parse("cache"),
            Some(Partitioning::CacheSized {
                budget_bytes: DEFAULT_SHARD_BUDGET
            })
        );
        assert_eq!(
            Partitioning::parse("cache:4096"),
            Some(Partitioning::CacheSized { budget_bytes: 4096 })
        );
        assert_eq!(Partitioning::parse("bogus"), None);
        // Malformed cache forms must not silently use the default budget.
        assert_eq!(Partitioning::parse("cache4096"), None);
        assert_eq!(Partitioning::parse("cache:lots"), None);
    }

    #[test]
    fn resolve_clamps_and_sizes() {
        assert_eq!(Partitioning::None.resolve(100), 0);
        assert_eq!(Partitioning::Shards(4).resolve(100), 4);
        assert_eq!(Partitioning::Shards(500).resolve(100), 100);
        // 0 shards means flat everywhere, including the raw enum.
        assert_eq!(Partitioning::Shards(0).resolve(100), 0);
        // 4096-byte budget = 64 vertices per shard.
        assert_eq!(
            Partitioning::CacheSized { budget_bytes: 4096 }.resolve(640),
            10
        );
        assert_eq!(
            Partitioning::CacheSized { budget_bytes: 1 }.resolve(100),
            100
        );
    }

    #[test]
    fn plan_covers_and_classifies_small_graph() {
        let g = gen::grid(8, 8);
        let plan = PartitionPlan::build(&g, 4);
        assert_eq!(plan.num_shards(), 4);
        plan.validate(&g).unwrap();
        let interior: u64 = plan.interior_out().iter().sum();
        let cross: u64 = plan.total_cross();
        assert_eq!(interior + cross, g.num_edges() as u64);
        // A grid cut into contiguous ranges has few cross edges.
        assert!(cross < g.num_edges() as u64 / 2);
    }

    #[test]
    fn single_shard_has_no_cross_edges() {
        let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 7);
        let plan = PartitionPlan::build(&g, 1);
        assert_eq!(plan.num_shards(), 1);
        plan.validate(&g).unwrap();
        assert_eq!(plan.total_cross(), 0);
        assert_eq!(plan.edge_imbalance(), 1.0);
    }

    #[test]
    fn prop_every_edge_interior_xor_cross_and_owner_consistent() {
        quick::check("partition invariants", |rng| {
            let scale = 5 + rng.below(4) as u32;
            let g = gen::rmat(scale, 4, 0.45, 0.22, 0.22, rng.below(1000));
            let shards = 1 + rng.below(9) as usize;
            let plan = PartitionPlan::build(&g, shards);
            plan.validate(&g)?;
            // Owner map is a cover: every vertex owned exactly once, and
            // shard lengths sum to n.
            let total_len: usize = (0..plan.num_shards()).map(|s| plan.shard_len(s)).sum();
            if total_len != g.num_vertices() {
                return Err(format!(
                    "shard lengths sum to {total_len}, want {}",
                    g.num_vertices()
                ));
            }
            // Edge balance: no shard exceeds ideal + max vertex weight
            // (the balanced_cuts guarantee carried through).
            let maxw = g
                .vertices()
                .map(|v| (g.out_degree(v) + g.in_degree(v)) as u64)
                .max()
                .unwrap_or(0);
            let total: u64 = plan
                .out_edges()
                .iter()
                .zip(plan.in_edges())
                .map(|(o, i)| o + i)
                .sum();
            let ideal = total as f64 / plan.num_shards() as f64;
            for s in 0..plan.num_shards() {
                let load = plan.out_edges()[s] + plan.in_edges()[s];
                if load as f64 > ideal + maxw as f64 {
                    return Err(format!(
                        "shard {s} load {load} exceeds ideal {ideal} + max weight {maxw}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn patched_plan_matches_plan_rebuilt_from_mutated_graph() {
        use crate::graph::dynamic::{DynamicGraph, MutationSet};
        let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 13);
        let mut plan = PartitionPlan::build(&g, 4);
        let mut dg = DynamicGraph::with_spill_threshold(g, 1_000_000);
        let n = dg.graph().num_vertices() as u32;
        let mut m = MutationSet::new();
        m.insert(0, n - 1);
        m.insert(n / 2, 1);
        // Delete a real edge so the receipt carries removals too.
        let src = (0..n).find(|&v| dg.graph().out_degree(v) > 0).unwrap();
        let dst = dg.graph().out_neighbors(src)[0];
        m.delete(src, dst);
        let receipt = dg.apply(&m);
        assert!(!receipt.compacted);
        plan.apply_edge_deltas(&receipt.inserted, &receipt.removed);
        // validate() recomputes the interior/cross classification of the
        // mutated graph under the plan's (unchanged) cuts.
        plan.validate(dg.graph()).unwrap();
        // The out/in censuses must equal a recount under the same cuts
        // (a fresh build may cut elsewhere — degrees changed — which is
        // exactly why patching, not rebuilding, is the epoch-cheap path).
        let g2 = dg.graph();
        let mut out_want = vec![0u64; plan.num_shards()];
        let mut in_want = vec![0u64; plan.num_shards()];
        for v in g2.vertices() {
            out_want[plan.shard_of(v)] += g2.out_degree(v) as u64;
            in_want[plan.shard_of(v)] += g2.in_degree(v) as u64;
        }
        assert_eq!(plan.out_edges(), &out_want[..]);
        assert_eq!(plan.in_edges(), &in_want[..]);
    }

    #[test]
    fn cuts_align_to_row_blocks_on_plane_backed_graphs() {
        let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 7).compress(32);
        let plan = PartitionPlan::build(&g, 5);
        plan.validate(&g).unwrap();
        for &c in &plan.cuts()[1..plan.num_shards()] {
            assert_eq!(c % 32, 0, "interior cut {c} not block-aligned");
        }
        // Degenerate shapes survive snapping: more shards than blocks
        // just leaves some shards empty, still a valid monotone cover.
        let tiny = gen::star(16).compress(64);
        let plan = PartitionPlan::build(&tiny, 6);
        plan.validate(&tiny).unwrap();
    }

    #[test]
    fn imbalance_reports_skew() {
        // A star graph: the hub dominates, so any multi-shard cut is
        // imbalanced; the metric must reflect that (> 1).
        let g = gen::star(256);
        let plan = PartitionPlan::build(&g, 4);
        plan.validate(&g).unwrap();
        assert!(plan.edge_imbalance() > 1.0);
    }
}
