//! Synthetic graph generators.
//!
//! The paper's evaluation runs on four SNAP graphs we cannot download in
//! this offline environment, so the catalog (see [`crate::graph::catalog`])
//! builds analogues from these generators. RMAT is the workhorse: its
//! recursive-quadrant sampling yields the power-law degree distributions
//! that drive every optimisation the paper studies.

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::{Csr, VertexId};
use crate::util::rng::Rng;

/// Recursive-MATrix (Graph500-style) generator.
///
/// `scale` = log2(#vertices); `edge_factor` = undirected edges per vertex.
/// `(a, b, c)` are the standard quadrant probabilities (d = 1-a-b-c);
/// Graph500 uses (0.57, 0.19, 0.19).
pub fn rmat(
    scale: u32,
    edge_factor: usize,
    a: f64,
    b: f64,
    c: f64,
    seed: u64,
) -> Csr {
    assert!(a + b + c < 1.0, "quadrant probabilities must leave room for d");
    let n = 1usize << scale;
    let m = n * edge_factor;
    let mut rng = Rng::new(seed);
    let mut gb = GraphBuilder::new(n).symmetric(true).drop_self_loops(true);
    for _ in 0..m {
        let (mut src, mut dst) = (0usize, 0usize);
        for _ in 0..scale {
            src <<= 1;
            dst <<= 1;
            let r = rng.f64();
            if r < a {
                // top-left: neither bit set
            } else if r < a + b {
                dst |= 1;
            } else if r < a + b + c {
                src |= 1;
            } else {
                src |= 1;
                dst |= 1;
            }
        }
        gb.push_edge(src as VertexId, dst as VertexId);
    }
    gb.build()
}

/// Barabási–Albert preferential attachment: each new vertex attaches to
/// `m_per_vertex` existing vertices with probability proportional to their
/// degree. Produces power-law degree graphs with a connected core —
/// a good analogue for social networks (Orkut/LiveJournal shapes).
pub fn barabasi_albert(n: usize, m_per_vertex: usize, seed: u64) -> Csr {
    assert!(n > m_per_vertex && m_per_vertex >= 1);
    let mut rng = Rng::new(seed);
    let mut gb = GraphBuilder::new(n).symmetric(true).drop_self_loops(true);
    // `targets` holds one entry per edge endpoint → sampling uniformly from
    // it is sampling proportional to degree.
    let mut endpoints: Vec<VertexId> = Vec::with_capacity(2 * n * m_per_vertex);
    // Seed clique over the first m_per_vertex+1 vertices.
    for i in 0..=m_per_vertex {
        for j in 0..i {
            gb.push_edge(i as VertexId, j as VertexId);
            endpoints.push(i as VertexId);
            endpoints.push(j as VertexId);
        }
    }
    for v in (m_per_vertex + 1)..n {
        let mut chosen = [VertexId::MAX; 64];
        assert!(m_per_vertex <= 64);
        let mut count = 0;
        while count < m_per_vertex {
            let t = endpoints[rng.below(endpoints.len() as u64) as usize];
            if !chosen[..count].contains(&t) {
                chosen[count] = t;
                count += 1;
            }
        }
        for &t in &chosen[..m_per_vertex] {
            gb.push_edge(v as VertexId, t);
            endpoints.push(v as VertexId);
            endpoints.push(t);
        }
    }
    gb.build()
}

/// Erdős–Rényi G(n, m): `m` undirected edges sampled uniformly.
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut gb = GraphBuilder::new(n).symmetric(true).drop_self_loops(true);
    for _ in 0..m {
        let s = rng.below(n as u64) as VertexId;
        let d = rng.below(n as u64) as VertexId;
        gb.push_edge(s, d);
    }
    gb.build()
}

/// Undirected path 0–1–…–(n-1). Worst case for BFS-style frontier growth.
pub fn path(n: usize) -> Csr {
    let mut gb = GraphBuilder::new(n).symmetric(true);
    for v in 1..n {
        gb.push_edge((v - 1) as VertexId, v as VertexId);
    }
    gb.build()
}

/// Undirected cycle.
pub fn ring(n: usize) -> Csr {
    assert!(n >= 3);
    let mut gb = GraphBuilder::new(n).symmetric(true);
    for v in 0..n {
        gb.push_edge(v as VertexId, ((v + 1) % n) as VertexId);
    }
    gb.build()
}

/// Star: hub 0 connected to all others — maximal degree skew, the
/// adversarial case for vertex-count work distribution (paper §V-A).
pub fn star(n: usize) -> Csr {
    assert!(n >= 2);
    let mut gb = GraphBuilder::new(n).symmetric(true);
    for v in 1..n {
        gb.push_edge(0, v as VertexId);
    }
    gb.build()
}

/// Complete graph K_n (small n only).
pub fn complete(n: usize) -> Csr {
    let mut gb = GraphBuilder::new(n).symmetric(true);
    for i in 0..n {
        for j in (i + 1)..n {
            gb.push_edge(i as VertexId, j as VertexId);
        }
    }
    gb.build()
}

/// 2-D grid (rows × cols), 4-neighbourhood — regular degrees, the
/// counterpoint workload where edge-centric balancing should not help.
pub fn grid(rows: usize, cols: usize) -> Csr {
    let n = rows * cols;
    let mut gb = GraphBuilder::new(n).symmetric(true);
    let id = |r: usize, c: usize| (r * cols + c) as VertexId;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                gb.push_edge(id(r, c), id(r, c + 1));
            }
            if r + 1 < rows {
                gb.push_edge(id(r, c), id(r + 1, c));
            }
        }
    }
    gb.build()
}

/// Relabel a fraction of the vertices with a seeded random permutation.
///
/// RMAT and preferential-attachment generators put their hubs at low
/// vertex ids, which makes contiguous static thread ranges pathologically
/// imbalanced — far worse than real SNAP orderings, whose crawl order has
/// only *partial* degree-id correlation. A partial shuffle (`fraction` of
/// vertices relabelled, the rest kept in place) reproduces that moderate
/// correlation; the catalog applies 0.5 (see DESIGN.md §3).
pub fn partial_shuffle(g: &Csr, fraction: f64, seed: u64) -> Csr {
    let n = g.num_vertices();
    let mut rng = Rng::new(seed);
    // Select exactly ≈fraction·n vertices and permute them among
    // themselves; the rest keep their (clustered) positions.
    let mut perm: Vec<VertexId> = (0..n as VertexId).collect();
    let chosen: Vec<VertexId> = (0..n as VertexId)
        .filter(|_| rng.chance(fraction.clamp(0.0, 1.0)))
        .collect();
    let mut targets = chosen.clone();
    rng.shuffle(&mut targets);
    for (src, dst) in chosen.iter().zip(&targets) {
        perm[*src as usize] = *dst;
    }
    let mut gb = GraphBuilder::new(n);
    for (s, d) in g.edges() {
        gb.push_edge(perm[s as usize], perm[d as usize]);
    }
    gb.build()
}

/// Attach deterministic pseudo-random weights in `[lo, hi)` to every edge
/// of `g`. The weight is a pure function of `(src, dst, seed)`, so
/// parallel edges and the two directions of a symmetrised edge pair get
/// consistent values, and regeneration is reproducible.
pub fn randomly_weighted(g: &Csr, lo: f64, hi: f64, seed: u64) -> Csr {
    assert!(lo.is_finite() && hi.is_finite() && lo < hi);
    let mut gb = GraphBuilder::new(g.num_vertices());
    for (s, d) in g.edges() {
        // Order-independent key: (u,v) and (v,u) hash identically, so a
        // symmetrised edge pair shares one weight.
        let (a, b) = if s <= d { (s, d) } else { (d, s) };
        let mut state =
            seed ^ ((a as u64) << 32 | b as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let r = crate::util::rng::splitmix64(&mut state);
        let unit = (r >> 11) as f64 / (1u64 << 53) as f64; // [0, 1)
        gb.push_weighted_edge(s, d, lo + unit * (hi - lo));
    }
    gb.build()
}

/// Disjoint union of `k` rings of `size` vertices each — ground truth for
/// connected-components tests (k components by construction).
pub fn disjoint_rings(k: usize, size: usize) -> Csr {
    assert!(size >= 3);
    let n = k * size;
    let mut gb = GraphBuilder::new(n).symmetric(true);
    for comp in 0..k {
        let base = comp * size;
        for v in 0..size {
            gb.push_edge((base + v) as VertexId, (base + (v + 1) % size) as VertexId);
        }
    }
    gb.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn rmat_shape_and_validity() {
        let g = rmat(10, 8, 0.57, 0.19, 0.19, 42);
        assert_eq!(g.num_vertices(), 1024);
        // symmetric, self-loops dropped → directed edges ≤ 2 * n * ef
        assert!(g.num_edges() <= 2 * 1024 * 8);
        assert!(g.num_edges() > 1024 * 8); // most edges survive
        g.validate().unwrap();
    }

    #[test]
    fn rmat_is_deterministic() {
        let a = rmat(8, 4, 0.57, 0.19, 0.19, 7);
        let b = rmat(8, 4, 0.57, 0.19, 0.19, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn randomly_weighted_is_deterministic_and_in_range() {
        let base = ring(20);
        let a = randomly_weighted(&base, 1.0, 3.0, 5);
        let b = randomly_weighted(&base, 1.0, 3.0, 5);
        assert_eq!(a, b);
        assert!(a.has_weights());
        a.validate().unwrap();
        for (_, _, w) in a.weighted_edges() {
            assert!((1.0..3.0).contains(&w), "{w}");
        }
        // Same topology, just weights attached.
        assert_eq!(a.out_targets, base.out_targets);
        // Mirrored directions of the symmetric ring share one weight.
        let weight_of = |g: &Csr, s: u32, d: u32| {
            (0..g.out_degree(s))
                .map(|i| g.out_edge(s, i))
                .find(|&(t, _)| t == d)
                .map(|(_, w)| w)
                .unwrap()
        };
        for (s, d) in base.edges() {
            assert_eq!(weight_of(&a, s, d), weight_of(&a, d, s), "{s}<->{d}");
        }
    }

    #[test]
    fn rmat_is_skewed() {
        let g = rmat(12, 8, 0.57, 0.19, 0.19, 1);
        let s = stats::degree_stats(&g);
        assert!(
            s.max_out_degree as f64 > 8.0 * s.avg_out_degree,
            "rmat should be heavy-tailed: max={} avg={}",
            s.max_out_degree,
            s.avg_out_degree
        );
    }

    #[test]
    fn ba_degrees_and_validity() {
        let g = barabasi_albert(500, 3, 11);
        g.validate().unwrap();
        // Every vertex (beyond the seed clique) attaches with m edges.
        assert!(g.num_edges() >= 2 * (500 - 4) * 3);
        let s = stats::degree_stats(&g);
        assert!(s.max_out_degree > 3 * s.avg_out_degree as usize);
    }

    #[test]
    fn erdos_renyi_is_symmetric() {
        let g = erdos_renyi(100, 300, 5);
        g.validate().unwrap();
        for v in g.vertices() {
            for &u in g.out_neighbors(v) {
                assert!(g.out_neighbors(u).binary_search(&v).is_ok());
            }
        }
    }

    #[test]
    fn structured_generators() {
        let p = path(10);
        assert_eq!(p.num_edges(), 18); // 9 undirected
        assert_eq!(p.out_degree(0), 1);
        assert_eq!(p.out_degree(5), 2);

        let r = ring(10);
        assert!(r.vertices().all(|v| r.out_degree(v) == 2));

        let s = star(10);
        assert_eq!(s.out_degree(0), 9);
        assert!(s.vertices().skip(1).all(|v| s.out_degree(v) == 1));

        let k = complete(6);
        assert!(k.vertices().all(|v| k.out_degree(v) == 5));

        let g = grid(4, 5);
        assert_eq!(g.num_vertices(), 20);
        assert_eq!(g.out_degree(0), 2); // corner
        assert_eq!(g.out_degree(6), 4); // interior

        let d = disjoint_rings(3, 5);
        assert_eq!(d.num_vertices(), 15);
        assert!(d.vertices().all(|v| d.out_degree(v) == 2));
        for gg in [&p, &r, &s, &k, &g, &d] {
            gg.validate().unwrap();
        }
    }
}
